//! Failure-injection integration tests: the training engine must survive
//! dropped transfers, link outages, and extreme fluctuation without
//! losing correctness (training completes, accuracy unharmed by retries).
//! Requires artifacts (PJRT runs the real numerics).

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::net::LinkSpec;
use cloudless::runtime::PjrtRuntime;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig};

fn rt() -> PjrtRuntime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    PjrtRuntime::new(dir).expect("PJRT CPU client")
}

fn cfg_with_link(link: LinkSpec) -> TrainConfig {
    let mut cfg = TrainConfig::new("lenet");
    cfg.epochs = 3;
    cfg.n_train = 1024;
    cfg.n_eval = 256;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    cfg.link = link;
    cfg.skip_eval = true;
    cfg
}

#[test]
fn survives_heavy_drop_rates() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 512, 512);
    let clean = run_geo_training(
        &rt(),
        &env,
        env.greedy_plan(),
        cfg_with_link(LinkSpec::wan_100mbps()),
    )
    .unwrap();
    let lossy = run_geo_training(
        &rt(),
        &env,
        env.greedy_plan(),
        cfg_with_link(LinkSpec { drop_prob: 0.3, ..LinkSpec::wan_100mbps() }),
    )
    .unwrap();
    // Training still completes every step on both sides.
    assert_eq!(
        lossy.partitions.iter().map(|p| p.steps).sum::<u64>(),
        clean.partitions.iter().map(|p| p.steps).sum::<u64>(),
    );
    // Some syncs were dropped -> fewer bytes actually carried.
    assert!(lossy.wan_bytes < clean.wan_bytes, "{} vs {}", lossy.wan_bytes, clean.wan_bytes);
}

#[test]
fn survives_total_blackout() {
    // 100% drop: partitions train fully isolated (degenerates to local
    // training; the engine must not deadlock waiting for receives).
    let env = CloudEnv::tencent_two_region(Device::Skylake, 512, 512);
    let report = run_geo_training(
        &rt(),
        &env,
        env.greedy_plan(),
        cfg_with_link(LinkSpec { drop_prob: 1.0, ..LinkSpec::wan_100mbps() }),
    )
    .unwrap();
    assert_eq!(report.wan_bytes, 0);
    assert!(report.partitions.iter().all(|p| p.syncs_received == 0));
    assert!(report.total_time > 0.0);
}

#[test]
fn extreme_fluctuation_slows_but_completes() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 512, 512);
    let stable = run_geo_training(
        &rt(),
        &env,
        env.greedy_plan(),
        cfg_with_link(LinkSpec { fluct_sigma: 0.0, ..LinkSpec::wan_100mbps() }),
    )
    .unwrap();
    let wild = run_geo_training(
        &rt(),
        &env,
        env.greedy_plan(),
        cfg_with_link(LinkSpec { fluct_sigma: 1.0, ..LinkSpec::wan_100mbps() }),
    )
    .unwrap();
    assert!(wild.total_time.is_finite());
    assert_eq!(
        wild.partitions.iter().map(|p| p.steps).sum::<u64>(),
        stable.partitions.iter().map(|p| p.steps).sum::<u64>(),
    );
}

#[test]
fn sma_with_drops_does_not_deadlock() {
    // Barrier strategy + lossy link: exchanges retry until they land;
    // the barrier must still release.
    let env = CloudEnv::tencent_two_region(Device::Skylake, 384, 384);
    let mut cfg = cfg_with_link(LinkSpec { drop_prob: 0.4, ..LinkSpec::self_hosted() });
    cfg.sync = SyncConfig::new(Strategy::Sma, 8);
    let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();
    assert!(report.total_time.is_finite());
    assert!(report.partitions.iter().all(|p| p.steps > 0));
}

//! Federated edge-cohort acceptance suite (the ISSUE-7 cases): the
//! recursive composite partition must be *accounting-preserving* and
//! cheap, not just plausible. Driven by the built-in synthetic model, so
//! this suite runs everywhere tier-1 runs.
//!
//! - Zero cohorts (or zero clients) is the flat per-cloud engine, byte
//!   for byte — the composite layer costs nothing when off.
//! - Sampled rounds do *exactly* the update counts of full participation
//!   (population-reweighted FedAvg), and dropout churn conserves step
//!   and epoch totals.
//! - The Dirichlet cohort carve is deterministic: same seed, same
//!   report, byte for byte.
//! - Sampling pays: fewer WAN bytes than full participation at equal
//!   update counts.
//! - A 100k-client round costs a few hundred model executions, not a
//!   hundred thousand (cohort pooling).

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::runtime::PjrtRuntime;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 2;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 17;
    cfg
}

fn fed_cfg(clients: usize, cohorts: usize, sample_frac: f64, dropout: f64) -> TrainConfig {
    let mut cfg = base_cfg();
    cfg.federated.clients = clients;
    cfg.federated.cohorts = cohorts;
    cfg.federated.sample_frac = sample_frac;
    cfg.federated.dropout = dropout;
    cfg
}

fn run(cfg: TrainConfig) -> TrainReport {
    let rt = rt();
    let env = four_cloud_env();
    run_geo_training(&rt, &env, env.greedy_plan(), cfg).unwrap()
}

/// Serialize with wall time pinned (the only non-deterministic field).
fn train_json(mut r: TrainReport) -> String {
    r.wall_seconds = 0.0;
    r.to_json().to_string_pretty()
}

fn total_steps(r: &TrainReport) -> u64 {
    r.partitions.iter().map(|p| p.steps).sum()
}

fn per_part_steps(r: &TrainReport) -> Vec<u64> {
    r.partitions.iter().map(|p| p.steps).collect()
}

// ----------------------------------------------- flat-path byte identity

#[test]
fn zero_cohorts_is_the_flat_engine_byte_for_byte() {
    let flat = run(base_cfg());
    assert!(flat.federated.is_none(), "flat runs carry no federated block");
    // Half-configured edge tiers (either knob zero) must not perturb the
    // engine in any way: same events, same RNG draws, same JSON.
    let no_cohorts = run(fed_cfg(100_000, 0, 1.0, 0.0));
    let no_clients = run(fed_cfg(0, 40, 1.0, 0.0));
    assert_eq!(
        train_json(flat.clone()),
        train_json(no_cohorts),
        "cohorts: 0 must reproduce the flat TrainReport byte for byte"
    );
    assert_eq!(
        train_json(flat),
        train_json(no_clients),
        "clients: 0 must reproduce the flat TrainReport byte for byte"
    );
}

// ------------------------------------------- update-count conservation

#[test]
fn sampled_rounds_do_exactly_the_full_participation_update_counts() {
    let full = run(fed_cfg(10_000, 16, 1.0, 0.0));
    let sampled = run(fed_cfg(10_000, 16, 0.1, 0.0));
    assert_eq!(
        per_part_steps(&full),
        per_part_steps(&sampled),
        "population-reweighted rounds must conserve per-cloud step totals"
    );
    let updates = |r: &TrainReport| -> u64 { r.partitions.iter().map(|p| p.local_updates).sum() };
    assert_eq!(updates(&full), updates(&sampled), "PS update counters must match exactly");
    // The budget is client-granular: every client trains once per epoch.
    let fed = full.federated.as_ref().expect("federated block present");
    assert_eq!(fed.clients, 10_000, "every configured client was carved into a cohort");
    assert_eq!(total_steps(&full), 10_000 * 2, "clients x epochs client-updates");
    // Sampling showed up physically: ~10x fewer arrived uploads.
    let sfed = sampled.federated.as_ref().unwrap();
    assert!(
        sfed.participants * 5 < fed.participants,
        "sampled participants {} must be well under full {}",
        sfed.participants,
        fed.participants
    );
}

#[test]
fn dropout_churn_conserves_step_and_epoch_totals() {
    let calm = run(fed_cfg(10_000, 16, 0.5, 0.0));
    let churny = run(fed_cfg(10_000, 16, 0.5, 0.3));
    assert_eq!(
        per_part_steps(&calm),
        per_part_steps(&churny),
        "dropout loses uploads, never the cohort's aggregate step weight"
    );
    assert_eq!(total_steps(&churny), 10_000 * 2);
    let fed = churny.federated.as_ref().unwrap();
    assert!(fed.dropouts > 0, "30% dropout over thousands of samples must drop someone");
    // Dropped clients are the sampled-minus-arrived remainder, never
    // phantom extras.
    let sampled_total = fed.participants + fed.dropouts;
    assert!(
        fed.dropouts * 2 < sampled_total,
        "dropouts {} must stay the minority of {} sampled",
        fed.dropouts,
        sampled_total
    );
    assert_eq!(calm.federated.as_ref().unwrap().dropouts, 0, "zero dropout drops no one");
}

// ------------------------------------------------ carve determinism

#[test]
fn cohort_carving_and_sampling_are_deterministic() {
    let a = run(fed_cfg(10_000, 16, 0.2, 0.1));
    let b = run(fed_cfg(10_000, 16, 0.2, 0.1));
    assert_eq!(
        train_json(a),
        train_json(b),
        "same seed must reproduce the federated TrainReport byte for byte"
    );
    // A different seed moves the Dirichlet carve and the sampling draws.
    let mut other = fed_cfg(10_000, 16, 0.2, 0.1);
    other.seed = 18;
    let c = run(other);
    let p = |r: &TrainReport| r.federated.as_ref().unwrap().participants;
    let d = |r: &TrainReport| r.federated.as_ref().unwrap().dropouts;
    let a2 = run(fed_cfg(10_000, 16, 0.2, 0.1));
    assert!(
        p(&a2) != p(&c) || d(&a2) != d(&c) || a2.total_time != c.total_time,
        "a different seed must change the sampled trajectory"
    );
}

// ---------------------------------------------- sampling saves WAN bytes

#[test]
fn sampled_participation_sends_fewer_wan_bytes_at_equal_update_counts() {
    let full = run(fed_cfg(100_000, 40, 1.0, 0.0));
    let sampled = run(fed_cfg(100_000, 40, 0.1, 0.05));
    assert_eq!(
        per_part_steps(&full),
        per_part_steps(&sampled),
        "equal update counts are the premise of the comparison"
    );
    assert!(
        sampled.wan_bytes < full.wan_bytes,
        "sampling must cut WAN bytes: sampled {} vs full {}",
        sampled.wan_bytes,
        full.wan_bytes
    );
    let up = |r: &TrainReport| r.federated.as_ref().unwrap().uplink_bytes;
    assert!(
        up(&sampled) * 5 < up(&full),
        "~10x sampling must cut uplink bytes well past 5x: {} vs {}",
        up(&sampled),
        up(&full)
    );
}

// --------------------------------------------------- cohort-pool scale

#[test]
fn a_hundred_thousand_clients_round_in_a_few_hundred_executions() {
    let r = run(fed_cfg(100_000, 40, 0.1, 0.05));
    let fed = r.federated.as_ref().expect("federated block present");
    assert_eq!(fed.clients, 100_000);
    assert_eq!(fed.cohorts, 40 * 4, "40 cohorts carved per cloud");
    assert_eq!(total_steps(&r), 100_000 * 2, "every client trained every epoch");
    // Cohort pooling: one model execution per cohort round, not one per
    // client — the whole run is a few hundred executions / rounds, so
    // the simulator stays in the low thousands of events.
    assert!(
        r.pjrt_executions < 2_000,
        "100k clients must pool into cohort rounds, got {} executions",
        r.pjrt_executions
    );
    assert!(
        fed.rounds < 2_000,
        "round count must scale with cohorts x epochs, got {}",
        fed.rounds
    );
    assert!(fed.rounds >= 160, "every cohort rounds at least once per epoch floor");
}

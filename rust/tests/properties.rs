//! Property-based tests over the coordinator's pure logic: scheduler
//! invariants, sync-strategy invariants, network invariants, data
//! sharding invariants, and JSON round-trips. No PJRT needed — these run
//! in milliseconds.

use cloudless::cloud::devices::Device;
use cloudless::cloud::{Allocation, CloudEnv, Region};
use cloudless::engine::{SyncPlan, TopologyKind};
use cloudless::net::{Fabric, LinkSpec};
use cloudless::prop::{forall, vec_f32};
use cloudless::ps::PsState;
use cloudless::runtime::vecops;
use cloudless::sched::elastic::{ElasticConfig, ElasticController, MonitorSample};
use cloudless::sched::{imbalance, load_power, optimal_matching, optimal_matching_observed};
use cloudless::sync::{
    apply_payload, make_payload, plan_topology, Payload, Strategy, SyncConfig,
};
use cloudless::util::json::Json;
use cloudless::util::rng::Pcg32;

const CPUS: [Device; 3] = [Device::IceLake, Device::CascadeLake, Device::Skylake];

fn random_env(rng: &mut Pcg32) -> CloudEnv {
    let n = 2 + rng.usize_below(3); // 2..4 regions
    let regions = (0..n)
        .map(|i| {
            let dev = CPUS[rng.usize_below(3)];
            let units = 2 + rng.below(23);
            let data = 100 + rng.usize_below(5000);
            Region::new(i, &format!("r{i}"), vec![(dev, units)], data)
        })
        .collect();
    CloudEnv::new(regions)
}

// -------------------------------------------------------------- scheduler

#[test]
fn prop_plan_fits_inventory_and_is_nonempty() {
    forall(
        150,
        |r| random_env(r),
        |env| {
            let plan = optimal_matching(env);
            for (alloc, region) in plan.allocations.iter().zip(&env.regions) {
                assert!(alloc.fits(region), "plan over-allocates {region:?}");
                assert!(alloc.power() > 0.0, "plan gave {} zero power", region.name);
            }
        },
    );
}

#[test]
fn prop_straggler_keeps_greedy_allocation() {
    forall(
        150,
        |r| random_env(r),
        |env| {
            let plan = optimal_matching(env);
            let greedy = env.greedy_plan();
            assert_eq!(
                plan.allocations[plan.straggler], greedy[plan.straggler],
                "the reference straggler must not be cut"
            );
        },
    );
}

#[test]
fn prop_planned_lp_never_below_straggler() {
    forall(
        150,
        |r| random_env(r),
        |env| {
            let plan = optimal_matching(env);
            let floor = plan.full_lp[plan.straggler];
            for lp in &plan.planned_lp {
                assert!(*lp + 1e-9 >= floor, "planned LP {lp} below straggler {floor}");
            }
        },
    );
}

#[test]
fn prop_plan_never_increases_imbalance_or_units() {
    forall(
        150,
        |r| random_env(r),
        |env| {
            let plan = optimal_matching(env);
            let greedy = env.greedy_plan();
            let planned = imbalance(&plan.planned_lp).expect("plan has regions");
            let full = imbalance(&plan.full_lp).expect("plan has regions");
            assert!(planned.is_finite(), "no planned cloud may stall");
            assert!(planned <= full + 1e-9, "plan worsened imbalance");
            let planned_units: u32 = plan.allocations.iter().map(|a| a.total_units()).sum();
            let greedy_units: u32 = greedy.iter().map(|a| a.total_units()).sum();
            assert!(planned_units <= greedy_units);
        },
    );
}

#[test]
fn prop_load_power_monotone_in_units_and_data() {
    forall(
        200,
        |r| (CPUS[r.usize_below(3)], 1 + r.below(23), 1 + r.usize_below(10_000)),
        |&(dev, units, data)| {
            let a = Allocation::new(0, vec![(dev, units)]);
            let b = Allocation::new(0, vec![(dev, units + 1)]);
            assert!(load_power(&b, data).unwrap() > load_power(&a, data).unwrap());
            assert!(load_power(&a, data + 1).unwrap() < load_power(&a, data).unwrap());
            assert_eq!(load_power(&a, 0), None, "total: no data has no load power");
        },
    );
}

// ----------------------------------------------------- elastic controller

fn controller_for(env: &CloudEnv, cfg: ElasticConfig) -> ElasticController {
    let initial = optimal_matching(env).allocations;
    let n = env.regions.len();
    let bw: Vec<(usize, usize, f64)> = (0..n)
        .flat_map(|a| (0..n).filter(move |b| *b != a).map(move |b| (a, b, 100e6)))
        .collect();
    ElasticController::new(cfg, env.clone(), &initial, bw)
}

fn scales_sample(scales: Vec<Option<f64>>) -> MonitorSample {
    let finished = vec![false; scales.len()];
    let mean_iter_s = vec![None; scales.len()];
    MonitorSample { t: 0.0, power_scale: scales, mean_iter_s, finished, link_bw: Vec::new() }
}

#[test]
fn prop_replanning_is_idempotent_under_unchanged_observations() {
    forall(
        60,
        |r| {
            let env = random_env(r);
            let scales: Vec<Option<f64>> =
                (0..env.regions.len()).map(|_| Some(0.1 + 0.9 * r.f64())).collect();
            (env, scales)
        },
        |(env, scales)| {
            let mut c = controller_for(
                env,
                ElasticConfig { enabled: true, smoothing: 1.0, ..Default::default() },
            );
            // Feed the identical observation repeatedly: at most ONE
            // re-plan may commit, after which the controller holds.
            let mut commits = 0;
            for _ in 0..8 {
                if c.observe(&scales_sample(scales.clone())).is_some() {
                    commits += 1;
                }
            }
            assert!(commits <= 1, "unchanged observations replanned {commits} times");
        },
    );
}

#[test]
fn prop_hysteresis_prevents_plan_oscillation_under_noise() {
    forall(
        60,
        |r| (random_env(r), r.next_u64()),
        |&(ref env, seed)| {
            let mut c = controller_for(
                env,
                ElasticConfig { enabled: true, hysteresis: 0.35, ..Default::default() },
            );
            // ±10% multiplicative sample noise around nominal: with EWMA
            // smoothing and hysteresis the plan must never move.
            let mut rng = Pcg32::new(seed, 17);
            for _ in 0..30 {
                let scales: Vec<Option<f64>> = (0..env.regions.len())
                    .map(|_| Some(0.9 + 0.2 * rng.f64()))
                    .collect();
                assert!(
                    c.observe(&scales_sample(scales)).is_none(),
                    "noise within hysteresis oscillated the plan"
                );
            }
        },
    );
}

#[test]
fn prop_replans_never_exceed_region_inventories() {
    forall(
        80,
        |r| {
            let env = random_env(r);
            let rounds: Vec<Vec<Option<f64>>> = (0..5)
                .map(|_| {
                    (0..env.regions.len())
                        .map(|_| {
                            if r.below(4) == 0 {
                                None // stalled / finished cloud: no signal
                            } else {
                                Some(0.05 + 1.5 * r.f64())
                            }
                        })
                        .collect()
                })
                .collect();
            (env, rounds)
        },
        |(env, rounds)| {
            let mut c = controller_for(
                env,
                ElasticConfig { enabled: true, hysteresis: 0.05, ..Default::default() },
            );
            for scales in rounds {
                if let Some(dec) = c.observe(&scales_sample(scales.clone())) {
                    for (alloc, region) in dec.allocations.iter().zip(&env.regions) {
                        assert!(
                            alloc.fits(region),
                            "replan over-allocated {}: {alloc:?}",
                            region.name
                        );
                        assert!(alloc.power() > 0.0, "replan emptied {}", region.name);
                    }
                }
            }
        },
    );
}

#[test]
fn prop_observed_matching_fits_and_clears_the_observed_floor() {
    forall(
        100,
        |r| {
            let env = random_env(r);
            let scales: Vec<f64> =
                (0..env.regions.len()).map(|_| 0.1 + 1.4 * r.f64()).collect();
            (env, scales)
        },
        |(env, scales)| {
            let plan = optimal_matching_observed(env, scales);
            let floor = plan.full_lp[plan.straggler];
            for ((alloc, region), lp) in
                plan.allocations.iter().zip(&env.regions).zip(&plan.planned_lp)
            {
                assert!(alloc.fits(region), "observed plan over-allocates");
                assert!(
                    *lp + 1e-9 >= floor,
                    "observed LP {lp} fell below the straggler floor {floor}"
                );
            }
        },
    );
}

// ------------------------------------------------------------------ sync

#[test]
fn prop_accumulated_gradient_equals_sum() {
    forall(
        100,
        |r| {
            let n = 1 + r.usize_below(200);
            let k = 1 + r.usize_below(10);
            let grads: Vec<Vec<f32>> = (0..k).map(|_| vec_f32(r, n)).collect();
            grads
        },
        |grads| {
            let n = grads[0].len();
            let mut ps = PsState::new(vec![0.0; n], 0.1);
            let mut expect = vec![0.0f32; n];
            for g in grads {
                ps.push_gradient(g, 0);
                vecops::accumulate_inplace(&mut expect, g);
            }
            let cfg = SyncConfig::new(Strategy::AsgdGa, grads.len() as u32);
            match make_payload(&cfg, &mut ps) {
                Payload::Gradient { grad, steps } => {
                    assert_eq!(steps as usize, grads.len());
                    for i in 0..n {
                        assert!((grad[i] - expect[i]).abs() < 1e-4, "accum mismatch at {i}");
                    }
                }
                _ => panic!("GA sends gradients"),
            }
        },
    );
}

#[test]
fn prop_model_average_is_midpoint_and_bounded() {
    forall(
        100,
        |r| {
            let n = 1 + r.usize_below(300);
            (vec_f32(r, n), vec_f32(r, n))
        },
        |(a, b)| {
            let mut ps = PsState::new(a.clone(), 0.1);
            let cfg = SyncConfig::new(Strategy::Ama, 4);
            apply_payload(&cfg, &mut ps, &Payload::Params(b.clone()), 0.5);
            for i in 0..a.len() {
                let lo = a[i].min(b[i]) - 1e-6;
                let hi = a[i].max(b[i]) + 1e-6;
                assert!(ps.params[i] >= lo && ps.params[i] <= hi, "avg out of bounds at {i}");
                assert!((ps.params[i] - (a[i] + b[i]) / 2.0).abs() < 1e-5);
            }
        },
    );
}

#[test]
fn prop_sync_semantics_commute_with_accumulation_order() {
    // Applying k remote gradients one by one == applying their sum once
    // (SGD linearity — the invariant ASGD-GA relies on for correctness).
    forall(
        100,
        |r| {
            let n = 1 + r.usize_below(100);
            let k = 2 + r.usize_below(6);
            let init = vec_f32(r, n);
            let grads: Vec<Vec<f32>> = (0..k).map(|_| vec_f32(r, n)).collect();
            (init, grads)
        },
        |(init, grads)| {
            let n = init.len();
            let mut one_by_one = PsState::new(init.clone(), 0.05);
            for g in grads {
                one_by_one.apply_remote_gradient(g);
            }
            let mut summed = PsState::new(init.clone(), 0.05);
            let mut total = vec![0.0f32; n];
            for g in grads {
                vecops::accumulate_inplace(&mut total, g);
            }
            summed.apply_remote_gradient(&total);
            for i in 0..n {
                assert!((one_by_one.params[i] - summed.params[i]).abs() < 1e-4);
            }
        },
    );
}

#[test]
fn prop_topology_is_permutation_with_no_self_loops() {
    forall(
        50,
        |r| 2 + r.usize_below(16),
        |&n| {
            let topo = plan_topology(n);
            assert_eq!(topo.len(), n);
            let mut seen = vec![false; n];
            for (i, &t) in topo.iter().enumerate() {
                assert_ne!(i, t, "self-loop at {i}");
                assert!(!seen[t], "node {t} receives twice");
                seen[t] = true;
            }
        },
    );
}

// ------------------------------------------------------ engine topology

/// A fully-meshed fabric with per-link bandwidths drawn from the rng, so
/// the bandwidth-aware topologies see a non-trivial planning input.
fn random_mesh(rng: &mut Pcg32, n: usize) -> Fabric {
    let mut f = Fabric::new(5);
    for a in 0..n {
        for b in 0..n {
            if a != b {
                let mbps = 20.0 + rng.range_f64(0.0, 480.0);
                f.add_link(
                    a,
                    b,
                    LinkSpec { bandwidth_bps: mbps * 1e6, ..LinkSpec::wan_100mbps() },
                );
            }
        }
    }
    f
}

const KINDS: [TopologyKind; 3] =
    [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree];

fn check_weights_sum(plan: &SyncPlan) {
    // Metropolis weights: every edge carries 1/(1 + max degree of its
    // endpoints) over the undirected support, symmetric pairs agree, and
    // total incoming weight stays < 1 so the receiver's residual local
    // share is positive.
    for e in plan.edges() {
        let d = plan.support_degree(e.from).max(plan.support_degree(e.to)) as f32;
        assert!(
            (e.weight - 1.0 / (d + 1.0)).abs() < 1e-6,
            "edge ({},{}): weight {} != 1/(1+{d})",
            e.from,
            e.to,
            e.weight
        );
        if let Some(rev) = plan.outgoing(e.to).iter().find(|r| r.to == e.from) {
            assert_eq!(rev.weight, e.weight, "asymmetric pair ({},{})", e.from, e.to);
        }
    }
    for r in 0..plan.n() {
        let incoming = plan.incoming_weight(r);
        assert!(
            (0.0..1.0).contains(&incoming),
            "receiver {r}: incoming weight {incoming} leaves no local share"
        );
    }
}

#[test]
fn prop_ring_plans_one_outgoing_edge_per_region() {
    for n in 2..=16usize {
        let seed = n as u64;
        let fabric = random_mesh(&mut Pcg32::new(seed, 1), n);
        let plan = TopologyKind::Ring.plan(n, &fabric);
        for i in 0..n {
            assert_eq!(plan.outgoing(i).len(), 1, "ring n={n}: region {i}");
            assert_eq!(plan.in_degree(i), 1);
        }
        assert!(plan.is_connected(), "ring n={n} must be connected");
        check_weights_sum(&plan);
    }
}

#[test]
fn prop_no_topology_plans_self_loops_or_duplicates() {
    forall(
        60,
        |r| (2 + r.usize_below(15), r.next_u64()),
        |&(n, seed)| {
            let fabric = random_mesh(&mut Pcg32::new(seed, 2), n);
            for kind in KINDS {
                let plan = kind.plan(n, &fabric);
                let mut seen = std::collections::BTreeSet::new();
                for e in plan.edges() {
                    assert_ne!(e.from, e.to, "{kind:?} n={n}: self-loop at {}", e.from);
                    assert!(e.from < n && e.to < n);
                    assert!(seen.insert((e.from, e.to)), "{kind:?} n={n}: duplicate edge");
                }
            }
        },
    );
}

#[test]
fn prop_hierarchical_and_tree_plans_are_spanning_trees() {
    forall(
        60,
        |r| (2 + r.usize_below(15), r.next_u64()),
        |&(n, seed)| {
            let fabric = random_mesh(&mut Pcg32::new(seed, 3), n);
            for kind in [TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
                let plan = kind.plan(n, &fabric);
                assert!(plan.is_connected(), "{kind:?} n={n} must be connected");
                assert!(
                    plan.is_tree(),
                    "{kind:?} n={n} must be acyclic (undirected support size {})",
                    plan.undirected_support().len()
                );
            }
        },
    );
}

#[test]
fn prop_per_edge_weights_sum_at_every_receiver() {
    forall(
        60,
        |r| (2 + r.usize_below(15), r.next_u64()),
        |&(n, seed)| {
            let fabric = random_mesh(&mut Pcg32::new(seed, 4), n);
            for kind in KINDS {
                check_weights_sum(&kind.plan(n, &fabric));
            }
        },
    );
}

// --------------------------------------------------------------- network

#[test]
fn prop_link_fifo_and_nonnegative() {
    forall(
        100,
        |r| {
            let n = 1 + r.usize_below(50);
            let submits: Vec<(f64, u64)> = (0..n)
                .map(|_| (r.range_f64(0.0, 100.0), 1 + r.next_u32() as u64 % 5_000_000))
                .collect();
            submits
        },
        |submits| {
            let mut sorted = submits.clone();
            sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut fabric = Fabric::new(9);
            fabric.add_link(0, 1, LinkSpec::wan_100mbps());
            let mut last_done = 0.0f64;
            for (at, bytes) in sorted {
                let t = fabric.transfer(0, 1, bytes, at);
                assert!(!t.dropped);
                assert!(t.start + 1e-12 >= at, "transfer started before submit");
                assert!(t.start + 1e-12 >= last_done, "FIFO violated");
                assert!(t.done > t.start && t.arrival > t.done);
                last_done = t.done;
            }
        },
    );
}

#[test]
fn prop_transfer_time_scales_with_bytes() {
    forall(
        100,
        |r| (1 + r.next_u32() as u64 % 10_000_000, 1 + r.next_u32() as u64 % 10_000_000),
        |&(a, b)| {
            let spec =
                LinkSpec { fluct_sigma: 0.0, setup_s: 0.0, ..LinkSpec::wan_100mbps() };
            let mut f1 = Fabric::new(1);
            f1.add_link(0, 1, spec.clone());
            let mut f2 = Fabric::new(1);
            f2.add_link(0, 1, spec);
            let ta = f1.transfer(0, 1, a, 0.0);
            let tb = f2.transfer(0, 1, b, 0.0);
            if a < b {
                assert!(ta.done <= tb.done + 1e-12);
            } else {
                assert!(tb.done <= ta.done + 1e-12);
            }
        },
    );
}

// ------------------------------------------------------------------ data

#[test]
fn prop_shards_partition_the_dataset() {
    forall(
        100,
        |r| {
            let n = 10 + r.usize_below(5000);
            let k = 1 + r.usize_below(5);
            let fractions: Vec<f64> = (0..k).map(|_| 0.1 + r.f64()).collect();
            (n, fractions)
        },
        |(n, fractions)| {
            let shards = cloudless::data::shard_by_fraction(*n, fractions, 3);
            let mut all: Vec<usize> =
                shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
            all.sort();
            assert_eq!(all, (0..*n).collect::<Vec<_>>(), "shards must partition [0, n)");
        },
    );
}

#[test]
fn prop_shard_epoch_covers_every_index() {
    forall(
        50,
        |r| (1 + r.usize_below(500), 1 + r.usize_below(64)),
        |&(n, b)| {
            let mut shard = cloudless::data::Shard::new((0..n).collect(), 7, 0);
            let steps = shard.steps_per_epoch(b);
            let mut seen = vec![0u32; n];
            for _ in 0..steps {
                for idx in shard.next_batch(b) {
                    seen[idx] += 1;
                }
            }
            // every index appears at least once per epoch (tail wraps may
            // duplicate a few)
            assert!(seen.iter().all(|&c| c >= 1), "epoch missed an index");
        },
    );
}

// ------------------------------------------------------------------ json

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0 * 0.0).round()),
            3 => {
                let len = rng.usize_below(12);
                Json::Str(
                    (0..len)
                        .map(|_| char::from_u32(0x20 + rng.below(0x50)).unwrap())
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.usize_below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        200,
        |r| random_json(r, 3),
        |j| {
            let compact = Json::parse(&j.to_string_compact()).unwrap();
            assert_eq!(&compact, j);
            let pretty = Json::parse(&j.to_string_pretty()).unwrap();
            assert_eq!(&pretty, j);
        },
    );
}

// ---------------------------------------------------------------- vecops

#[test]
fn prop_vecops_algebra() {
    forall(
        150,
        |r| {
            let n = 1 + r.usize_below(1000);
            (vec_f32(r, n), vec_f32(r, n), r.f32())
        },
        |(p, g, lr)| {
            // sgd(p, g, lr) == p - lr*g elementwise
            let mut out = p.clone();
            vecops::sgd_apply_inplace(&mut out, g, *lr);
            for i in 0..p.len() {
                assert!((out[i] - (p[i] - lr * g[i])).abs() <= 1e-5);
            }
            // average(x, x) == x
            let mut same = p.clone();
            vecops::average_inplace(&mut same, p, 0.5);
            for i in 0..p.len() {
                assert!((same[i] - p[i]).abs() <= 1e-6);
            }
            // mean_of is permutation-invariant
            let m1 = vecops::mean_of(&[p, g]);
            let m2 = vecops::mean_of(&[g, p]);
            for i in 0..p.len() {
                assert!((m1[i] - m2[i]).abs() <= 1e-6);
            }
        },
    );
}

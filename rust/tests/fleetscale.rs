//! Fleet-scale performance equivalence suite (the ISSUE-6 acceptance
//! cases): the simulator's three asymptotic optimizations — the indexed
//! merged clock, worker-cohort aggregation, and incremental admission
//! planning — must be *accounting-preserving*, not just fast. Driven by
//! the built-in synthetic model, so this suite runs everywhere tier-1
//! runs.
//!
//! - The indexed clock is byte-identical to the linear scan it replaced
//!   (same `FleetReport` JSON on a multi-job Poisson trace).
//! - Cohort size 1 (threshold 0, or pools under the threshold) is the
//!   per-worker path, byte for byte.
//! - Real cohorts (>1) preserve step totals exactly and time/billing
//!   within 1%, at a >=10x PJRT-execution reduction.
//! - Incremental admission planning seeded from *any* incumbent is never
//!   worse than either pure placement mode, and the joint optimum is a
//!   fixed point of seeding.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::fleet::{
    poisson_arrivals, run_fleet, solo_estimate_s, FleetConfig, FleetReport, JobRequest,
    LeasePolicy,
};
use cloudless::dataplane::{self, DataPlaneConfig, Layout, PlacementMode, PlacementSpec};
use cloudless::net::LinkSpec;
use cloudless::runtime::PjrtRuntime;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

/// A 4-region GPU env: one PS worker per unit, so pools are 64 wide and
/// cohort aggregation actually engages (CPU pools clamp at 8 workers).
fn gpu_env(n_train: usize) -> CloudEnv {
    let per = n_train / 4;
    CloudEnv::multi_region(vec![
        ("gpu0", Device::T4, 64, per),
        ("gpu1", Device::V100, 64, per),
        ("gpu2", Device::T4, 64, per),
        ("gpu3", Device::V100, 64, n_train - 3 * per),
    ])
}

fn job_template() -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 6;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 17;
    cfg
}

/// Four jobs on a Poisson trace dense enough that they overlap, so the
/// merged clock actually interleaves simulators.
fn requests(rt: &PjrtRuntime) -> Vec<JobRequest> {
    let template = job_template();
    let batch = rt.load_model("synthetic").unwrap().meta.batch_size;
    let est = solo_estimate_s(&template, &four_cloud_env(), batch).max(0.1);
    let arrivals = poisson_arrivals(4, est * 0.1, 99);
    arrivals
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), at, train)
        })
        .collect()
}

/// Serialize a fleet report with wall time pinned (the only
/// non-deterministic field; `events_per_wall_second` derives from it).
fn fleet_json(mut r: FleetReport) -> String {
    r.wall_seconds = 0.0;
    r.to_json().to_string_pretty()
}

fn train_json(mut r: TrainReport) -> String {
    r.wall_seconds = 0.0;
    r.to_json().to_string_pretty()
}

// ------------------------------------------------ indexed merged clock

#[test]
fn indexed_clock_is_byte_identical_to_linear_scan() {
    let rt = rt();
    let reqs = requests(&rt);
    let run = |indexed: bool| -> FleetReport {
        let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
        cfg.indexed_clock = indexed;
        run_fleet(&rt, &cfg, &reqs).unwrap()
    };
    let scan = run(false);
    let heap = run(true);
    assert!(scan.events_executed > 0, "the fleet must execute events");
    assert_eq!(
        scan.events_executed, heap.events_executed,
        "both paths step the same merged-event sequence"
    );
    assert_eq!(
        fleet_json(scan),
        fleet_json(heap),
        "indexed clock must reproduce the scan's FleetReport byte for byte"
    );
}

#[test]
fn same_seed_fleet_reports_are_identical_run_to_run() {
    let rt = rt();
    let reqs = requests(&rt);
    let run = || {
        let cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
        run_fleet(&rt, &cfg, &reqs).unwrap()
    };
    assert_eq!(fleet_json(run()), fleet_json(run()));
}

// --------------------------------------------- worker-cohort aggregation

#[test]
fn cohort_size_one_reproduces_the_per_worker_path_exactly() {
    // CPU pools clamp at 8 workers, far under the threshold, so the
    // threshold knob must leave the run byte-identical to threshold 0.
    let rt = rt();
    let env = four_cloud_env();
    let run = |threshold: usize| -> TrainReport {
        let mut cfg = job_template();
        cfg.cohort_threshold = threshold;
        run_geo_training(&rt, &env, env.greedy_plan(), cfg).unwrap()
    };
    assert_eq!(
        train_json(run(0)),
        train_json(run(64)),
        "pools under the threshold must take the per-worker path byte for byte"
    );
}

#[test]
fn cohorts_preserve_step_totals_exactly_and_billing_within_one_percent() {
    let rt = rt();
    // 64-worker GPU pools, 32768 steps per partition: the pools are
    // work-conserving, so drift comes only from jitter variance over
    // the number of waves (sigma ~ 0.14/sqrt(waves)); 2048 waves per
    // partition puts the worst case well under the 1% bound.
    let batch = rt.load_model("synthetic").unwrap().meta.batch_size;
    let n_train = 16384 * batch * 4;
    let env = gpu_env(n_train);
    let run = |threshold: usize| -> TrainReport {
        let mut cfg = TrainConfig::new("synthetic");
        cfg.epochs = 2;
        cfg.n_train = n_train;
        cfg.n_eval = batch * 8;
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 32);
        cfg.skip_eval = true;
        cfg.seed = 17;
        cfg.cohort_threshold = threshold;
        run_geo_training(&rt, &env, env.greedy_plan(), cfg).unwrap()
    };
    let per_worker = run(0);
    let cohort = run(4); // 64 workers / threshold 4 -> 16-step waves

    // Step accounting is exact: the budget drives both paths.
    let steps = |r: &TrainReport| -> Vec<u64> { r.partitions.iter().map(|p| p.steps).collect() };
    assert_eq!(steps(&per_worker), steps(&cohort), "per-partition step totals must match exactly");
    let updates = |r: &TrainReport| -> u64 { r.partitions.iter().map(|p| p.local_updates).sum() };
    assert_eq!(updates(&per_worker), updates(&cohort), "PS update counters must match exactly");

    // Time and billing drift only by wave-granular jitter: within 1%.
    let drift = |a: f64, b: f64| (a - b).abs() / a.max(1e-12);
    assert!(
        drift(per_worker.total_time, cohort.total_time) < 0.01,
        "total time drifted {:.2}% ({:.2}s vs {:.2}s)",
        drift(per_worker.total_time, cohort.total_time) * 100.0,
        per_worker.total_time,
        cohort.total_time
    );
    assert!(
        drift(per_worker.compute_cost, cohort.compute_cost) < 0.01,
        "compute cost drifted {:.2}% (${:.4} vs ${:.4})",
        drift(per_worker.compute_cost, cohort.compute_cost) * 100.0,
        per_worker.compute_cost,
        cohort.compute_cost
    );

    // The point of it all: >=10x fewer real model executions.
    assert!(
        per_worker.pjrt_executions >= 10 * cohort.pjrt_executions.max(1),
        "expected >=10x execution reduction: {} vs {}",
        per_worker.pjrt_executions,
        cohort.pjrt_executions
    );
}

// --------------------------------------- incremental admission planning

fn skewed_cfg(mode: PlacementMode) -> TrainConfig {
    let mut cfg = job_template();
    cfg.seed = 23;
    cfg.dataplane = DataPlaneConfig {
        placement: Some(PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 })),
        mode,
        sample_bytes: 256 * 1024,
        ..DataPlaneConfig::default()
    };
    cfg
}

/// Uniform 100 Mbps link view (None on the diagonal), the shape fleet
/// admission passes from its live fabric.
fn uniform_links(n: usize) -> Vec<Vec<Option<LinkSpec>>> {
    (0..n)
        .map(|a| {
            (0..n)
                .map(|b| if a == b { None } else { Some(LinkSpec::wan_100mbps()) })
                .collect()
        })
        .collect()
}

#[test]
fn seeded_admission_is_never_worse_than_either_pure_mode() {
    let rt = rt();
    let env = four_cloud_env();
    let meta = rt.load_model("synthetic").unwrap().meta;
    let pure = |mode: PlacementMode| -> f64 {
        dataplane::plan_for_on(&env, &skewed_cfg(mode), &meta, uniform_links(4))
            .unwrap()
            .plan
            .est_objective
    };
    let cfd = pure(PlacementMode::ComputeFollowsData);
    let dfc = pure(PlacementMode::DataFollowsCompute);

    // Incumbents a real fleet could hand the planner: stale-but-valid
    // assignments of every shape, plus geometry mismatches the planner
    // must ignore rather than trust.
    let shards = 8usize;
    let mut incumbents: Vec<Vec<usize>> = vec![
        vec![0; shards],
        vec![3; shards],
        (0..shards).map(|s| s % 4).collect(),
        (0..shards).map(|s| (s * 2654435761) % 4).collect(),
        vec![0; shards + 1], // wrong shard count: must be ignored
        vec![99; shards],    // out-of-range region: must be ignored
    ];
    incumbents.push((0..shards).map(|s| (s * 7 + 1) % 4).collect());
    for inc in &incumbents {
        let seeded = dataplane::plan_for_on_seeded(
            &env,
            &skewed_cfg(PlacementMode::Joint),
            &meta,
            uniform_links(4),
            Some(inc),
        )
        .unwrap()
        .plan;
        assert!(
            seeded.est_objective <= cfd + 1e-9 && seeded.est_objective <= dfc + 1e-9,
            "incumbent {inc:?}: seeded objective {} must not exceed cfd {} / dfc {}",
            seeded.est_objective,
            cfd,
            dfc
        );
    }
}

#[test]
fn the_joint_optimum_is_a_fixed_point_of_seeding() {
    let rt = rt();
    let env = four_cloud_env();
    let meta = rt.load_model("synthetic").unwrap().meta;
    let scratch = dataplane::plan_for_on(&env, &skewed_cfg(PlacementMode::Joint), &meta, uniform_links(4))
        .unwrap()
        .plan;
    let seeded = dataplane::plan_for_on_seeded(
        &env,
        &skewed_cfg(PlacementMode::Joint),
        &meta,
        uniform_links(4),
        Some(&scratch.assign),
    )
    .unwrap()
    .plan;
    assert_eq!(scratch.assign, seeded.assign, "re-seeding the optimum must not move shards");
    assert_eq!(scratch.est_objective, seeded.est_objective);
}

#[test]
fn fleet_admission_with_incumbent_cache_completes_every_job() {
    // End-to-end: a fleet whose jobs each carry a data plane exercises
    // the admission-time incumbent cache (every admission after the
    // first is seeded); all jobs must still complete their workloads.
    let rt = rt();
    let template = skewed_cfg(PlacementMode::Joint);
    let reqs: Vec<JobRequest> = (0..3)
        .map(|i| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), i as f64 * 0.5, train)
        })
        .collect();
    let cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    let report = run_fleet(&rt, &cfg, &reqs).unwrap();
    assert_eq!(report.jobs.len(), 3);
    for j in &report.jobs {
        assert!(
            j.report.dataplane.is_some(),
            "{}: every admitted job planned a data plane",
            j.name
        );
        let total: u64 = j.report.partitions.iter().map(|p| p.steps).sum();
        assert!(total > 0, "{}: job trained", j.name);
    }
    // Determinism survives the cache (same seed, same incumbents).
    let again = run_fleet(&rt, &cfg, &reqs).unwrap();
    assert_eq!(fleet_json(report), fleet_json(again));
}

//! End-to-end multi-job control plane: N concurrent training workflows
//! co-simulated over one shared 4-cloud inventory and one shared WAN
//! fabric, driven by the built-in synthetic model — no artifacts
//! required, so this suite runs everywhere tier-1 runs.
//!
//! Scenario (the ISSUE-3 acceptance case): four identical jobs arrive on
//! a Poisson trace dense enough to overlap. Under FIFO the first job's
//! solo plan saturates the straggler region, so later jobs queue and the
//! fleet serializes; under fair-share every arrival re-divides each
//! region's units across the active jobs (shrinking running jobs through
//! autoscaler resizes — preemption-by-resize, never a kill). Fair-share
//! must deliver a higher Jain's fairness index than FIFO while total
//! fleet cost stays within 10%.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::fleet::{
    poisson_arrivals, run_fleet, solo_estimate_s, FleetConfig, FleetReport, JobRequest,
    LeasePolicy,
};
use cloudless::runtime::PjrtRuntime;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::metrics::replan_cause;
use cloudless::train::TrainConfig;

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn job_template() -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 6;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 17;
    cfg
}

/// Four jobs on a Poisson trace dense enough that they overlap (mean gap
/// a tenth of one solo run).
fn requests(rt: &PjrtRuntime) -> Vec<JobRequest> {
    let template = job_template();
    let batch = rt.load_model("synthetic").unwrap().meta.batch_size;
    let est = solo_estimate_s(&template, &four_cloud_env(), batch).max(0.1);
    let arrivals = poisson_arrivals(4, est * 0.1, 99);
    arrivals
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), at, train)
        })
        .collect()
}

fn run(policy: LeasePolicy) -> FleetReport {
    let rt = rt();
    let reqs = requests(&rt);
    let cfg = FleetConfig::new(policy, four_cloud_env());
    run_fleet(&rt, &cfg, &reqs).unwrap()
}

#[test]
fn fair_share_beats_fifo_on_fairness_at_comparable_cost() {
    let fifo = run(LeasePolicy::Fifo);
    let fair = run(LeasePolicy::FairShare);

    // Every job completes its full workload under both policies.
    let steps = |r: &FleetReport| -> u64 {
        r.jobs.iter().map(|j| j.report.partitions.iter().map(|p| p.steps).sum::<u64>()).sum()
    };
    assert_eq!(fifo.jobs.len(), 4);
    assert_eq!(steps(&fifo), steps(&fair), "same total work under both policies");

    // The acceptance bar: fair-share is fairer, at comparable total cost.
    assert!(
        fair.jain_fairness > fifo.jain_fairness,
        "fair-share Jain {:.3} must beat FIFO {:.3}",
        fair.jain_fairness,
        fifo.jain_fairness
    );
    assert!(
        (fair.total_cost - fifo.total_cost).abs() <= 0.10 * fifo.total_cost,
        "total cost must stay within 10%: fair ${} vs fifo ${}",
        fair.total_cost,
        fifo.total_cost
    );
}

#[test]
fn fifo_queues_what_fair_share_admits() {
    let fifo = run(LeasePolicy::Fifo);
    let fair = run(LeasePolicy::FairShare);

    // FIFO: the first job's solo plan saturates the straggler region, so
    // later jobs wait (head-of-line blocking) and nothing ever resizes.
    assert!(fifo.total_queue_wait() > 0.0, "FIFO must queue overlapping jobs");
    assert_eq!(fifo.lease_events, 0, "FIFO never resizes a running job");

    // Fair-share: everyone is admitted on arrival; each arrival shrinks
    // the running jobs through the autoscaler instead of killing them.
    assert_eq!(fair.total_queue_wait(), 0.0, "fair-share admits every arrival immediately");
    assert!(fair.lease_events > 0, "re-divisions must resize running jobs");
    assert!(
        fair.jobs.iter().any(|j| {
            j.report.replan_events.iter().any(|e| e.cause == replan_cause::LEASE)
        }),
        "lease re-divisions are recorded on the job's own re-plan log"
    );
    // Sharing is work-conserving: overlapping the fleet must not cost
    // meaningful fleet makespan vs FIFO's serialization (both keep the
    // straggler region saturated; rounding and resize cold-starts are the
    // only slack).
    assert!(
        fair.makespan <= fifo.makespan * 1.15,
        "sharing lost too much fleet makespan: fair {:.0}s vs fifo {:.0}s",
        fair.makespan,
        fifo.makespan
    );
}

#[test]
fn shared_inventory_is_never_oversubscribed() {
    for policy in [LeasePolicy::Fifo, LeasePolicy::FairShare, LeasePolicy::CostAware] {
        let report = run(policy);
        let env = four_cloud_env();
        for (r, region) in env.regions.iter().enumerate() {
            let cap: u32 = region.inventory.iter().map(|(_, n)| n).sum();
            assert!(
                report.peak_units[r] <= cap,
                "{}: region {} leased {} of {} units",
                report.policy,
                region.name,
                report.peak_units[r],
                cap
            );
        }
        // Per-job WAN accounting conserves the shared fabric's totals.
        let per_job: u64 = report.jobs.iter().map(|j| j.report.wan_bytes).sum();
        assert_eq!(per_job, report.wan_bytes, "{}: per-job WAN bytes must sum", report.policy);
        assert!(report.wan_bytes > 0, "jobs must actually sync over the WAN");
    }
}

#[test]
fn unadmittable_job_is_an_error_not_a_panic() {
    // min_units larger than any region's inventory: no lease can ever
    // satisfy it under fair-share, so the fleet must surface a
    // descriptive Err instead of hanging or panicking.
    let rt = rt();
    let reqs = vec![JobRequest::new("doomed", 0.0, job_template())];
    let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    cfg.min_units = 13;
    let err = run_fleet(&rt, &cfg, &reqs).unwrap_err().to_string();
    assert!(err.contains("doomed") && err.contains("min_units"), "unhelpful error: {err}");
}

#[test]
fn fleet_runs_are_deterministic() {
    let a = run(LeasePolicy::FairShare);
    let b = run(LeasePolicy::FairShare);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.total_cost, b.total_cost);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    assert_eq!(a.lease_events, b.lease_events);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.finish, y.finish);
        assert_eq!(x.report.total_time, y.report.total_time);
    }
}

#[test]
fn cost_aware_never_leases_more_than_fair_share_uses() {
    let fair = run(LeasePolicy::FairShare);
    let cost = run(LeasePolicy::CostAware);
    assert_eq!(cost.jobs.len(), 4, "cost-aware completes the fleet");
    for (r, peak) in cost.peak_units.iter().enumerate() {
        assert!(
            *peak <= fair.peak_units[r],
            "trimmed leases can't exceed fair shares in region {r}: {} vs {}",
            peak,
            fair.peak_units[r]
        );
    }
    // Trimming shed capacity must not make the fleet meaningfully slower
    // than FIFO's full serialization.
    let fifo = run(LeasePolicy::Fifo);
    assert!(
        cost.makespan <= fifo.makespan * 1.15,
        "cost-aware {:.0}s vs fifo {:.0}s",
        cost.makespan,
        fifo.makespan
    );
}

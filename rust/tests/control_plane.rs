//! Integration tests over the serverless control plane: workflow
//! deployment, addressing, autoscaling lifecycle, and config-driven job
//! construction. Pure logic — no PJRT required.

use cloudless::cloud::devices::Device;
use cloudless::config;
use cloudless::coordinator::SchedulingMode;
use cloudless::faas::workflow::{WorkflowDef, WorkflowInstance};
use cloudless::faas::{Endpoint, FaasRuntime, FunctionKind, FunctionSpec};
use cloudless::sync::Strategy;

/// Build the full Cloudless-Training startup workflow the trainer deploys
/// (control plane + one sub-workflow per cloud) and walk it to completion.
#[test]
fn training_startup_workflow_walks_to_completion() {
    let mut rt = FaasRuntime::new();

    let mut wf = WorkflowDef::new("cloudless-startup");
    let sched = wf.add(FunctionSpec::new("scheduler", "ctl", FunctionKind::Scheduler, 0), vec![]);
    let comm = wf.add(
        FunctionSpec::new("global-comm", "ctl", FunctionKind::GlobalCommunicator, 0),
        vec![sched],
    );
    let mut ps_nodes = Vec::new();
    for cloud in 0..2 {
        let ps = wf.add(
            FunctionSpec::new("ps", &format!("c{cloud}"), FunctionKind::ParameterServer, cloud),
            vec![comm],
        );
        let ps_comm = wf.add(
            FunctionSpec::new("ps-comm", &format!("c{cloud}"), FunctionKind::PsCommunicator, cloud),
            vec![ps],
        );
        for w in 0..3 {
            wf.add(
                FunctionSpec::new(&format!("worker{w}"), &format!("c{cloud}"), FunctionKind::Worker, cloud),
                vec![ps_comm],
            );
        }
        ps_nodes.push(ps);
    }

    let mut inst = WorkflowInstance::deploy(wf, &mut rt).unwrap();
    let mut done = 0;
    let total = inst.def.nodes.len();
    // Drive nodes in waves until the whole DAG completes.
    while !inst.all_done() {
        let ready = inst.ready_nodes();
        assert!(!ready.is_empty(), "DAG stalled with {done}/{total} done");
        for node in ready {
            inst.start(node);
            // every function is really registered and invocable
            let key = inst.keys[node].clone();
            let inv = rt.invoke(&key, done as f64).unwrap();
            rt.mark_ready(inv.replica);
            inst.complete(node);
            done += 1;
        }
    }
    assert_eq!(done, total);
    let (invocations, cold) = rt.stats();
    assert_eq!(invocations as usize, total);
    assert_eq!(cold as usize, total, "first invocation of each function is cold");
}

#[test]
fn wan_identities_only_for_ps_communicators() {
    let mut rt = FaasRuntime::new();
    let ps_comm = rt.register(FunctionSpec::new("ps-comm", "c0", FunctionKind::PsCommunicator, 0));
    let worker = rt.register(FunctionSpec::new("w", "c0", FunctionKind::Worker, 0));
    let (comm_rep, _) = rt.scale_up(&ps_comm, 0.0).unwrap();
    let (worker_rep, _) = rt.scale_up(&worker, 0.0).unwrap();

    // Global communicator behavior: map each PS communicator's serverless
    // identity to a public <IP, Port>.
    rt.addressing.assign_wan_identity(comm_rep, Endpoint { ip: [101, 6, 0, 10], port: 7000 });
    assert!(rt.addressing.wan_identity(comm_rep).is_some());
    assert!(rt.addressing.wan_identity(worker_rep).is_none());
}

#[test]
fn addressing_survives_replica_churn() {
    let mut rt = FaasRuntime::new();
    let key = rt.register(FunctionSpec::new("ps", "c0", FunctionKind::ParameterServer, 0));
    let (rep, _) = rt.scale_up(&key, 0.0).unwrap();
    rt.mark_ready(rep);
    let before = rt.addressing.lookup(rep).unwrap();
    // Reschedule the replica several times; the table must follow.
    let mut last = before;
    for _ in 0..5 {
        let ep = rt.reschedule(rep).unwrap();
        assert_ne!(ep, last);
        assert_eq!(rt.addressing.lookup(rep), Some(ep));
        last = ep;
    }
    assert_eq!(rt.addressing.remap_count(), 5);
}

#[test]
fn worker_scale_to_zero_releases_resources() {
    let mut rt = FaasRuntime::new();
    let key = rt.register(FunctionSpec::new("worker", "c1", FunctionKind::Worker, 1));
    let mut reps = Vec::new();
    for _ in 0..4 {
        let (rep, _) = rt.scale_up(&key, 10.0).unwrap();
        rt.mark_ready(rep);
        reps.push(rep);
    }
    assert_eq!(rt.ready_replicas_of(&key).len(), 4);
    // local training finishes at t=110: everything terminates
    for rep in &reps {
        rt.terminate(*rep, 110.0);
    }
    assert!(rt.ready_replicas_of(&key).is_empty());
    let held = rt.held_seconds_of(&key, 500.0);
    assert!((held - 400.0).abs() < 1e-9, "4 workers x 100 s, got {held}");
}

// ------------------------------------------------------------- config

#[test]
fn config_files_in_repo_parse() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut count = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if path.extension().map_or(false, |e| e == "json") {
            let spec = config::load_job(&path)
                .unwrap_or_else(|e| panic!("{} failed to parse: {e}", path.display()));
            assert!(!spec.env.regions.is_empty());
            count += 1;
        }
    }
    assert!(count >= 4, "expected the shipped config set, found {count}");
}

#[test]
fn config_drives_scheduling_and_strategy() {
    let spec = config::parse_job(
        r#"{
            "model": "lenet", "strategy": "sma", "sync_freq": 16,
            "scheduling": "greedy",
            "regions": [
                {"name": "a", "device": "cascade", "units": 4, "data": 100},
                {"name": "b", "device": "t4", "units": 1, "data": 100}
            ]
        }"#,
    )
    .unwrap();
    assert_eq!(spec.scheduling, SchedulingMode::Greedy);
    assert_eq!(spec.train.sync.strategy, Strategy::Sma);
    assert_eq!(spec.train.sync.freq, 16);
    assert_eq!(spec.env.regions[1].max_units(Device::T4), 1);
}

//! Integration tests for the `cloudless lint` static-analysis pass
//! (`rust/src/lint/`): fixture-based self-tests for every rule (a known-bad
//! snippet each rule must flag and a clean sibling it must pass), the
//! `lint:allow` suppression round-trip, and findings-determinism (same tree →
//! byte-identical report). The last test runs the real repo tree and pins it
//! clean, which is what gates tier-1.

use cloudless::lint::{lint_files, lint_repo, DocContext, LintReport};

/// Synthetic doc-sync inputs for fixtures. The doc-sync fixtures build their
/// own variants; the code-rule fixtures just need something well-formed.
fn docs() -> DocContext {
    DocContext {
        config_md: "| `alpha` | int | 1 | first knob. CLI: `--alpha` |\n".to_string(),
        experiments_md: "## Extensions beyond the paper\n\n| exp id |\n|---|\n| `alpha` |\n"
            .to_string(),
        ci_yml: "  run: cargo run --release -- exp --id alpha\n".to_string(),
    }
}

fn lint_one(path: &str, code: &str) -> LintReport {
    lint_files(vec![(path.to_string(), code.to_string())], docs())
}

fn hits<'a>(report: &'a LintReport, rule: &str) -> Vec<&'a str> {
    report
        .findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| f.message.as_str())
        .collect()
}

// ---------------------------------------------------------------- determinism

#[test]
fn flags_hash_collections_in_src() {
    let bad = r#"
        use std::collections::BTreeMap;
        pub fn f() -> usize { let m: HashMap<u32, u32> = HashMap::new(); m.len() }
    "#;
    let r = lint_one("rust/src/sim/bad.rs", bad);
    assert_eq!(hits(&r, "no-unordered-collections").len(), 2, "{}", r.render());

    let clean = bad.replace("HashMap", "BTreeMap");
    let r = lint_one("rust/src/sim/bad.rs", &clean);
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn hash_collections_allowed_in_test_scope() {
    let code = r#"
        #[cfg(test)]
        mod tests {
            #[test]
            fn t() { let m: HashSet<u32> = HashSet::new(); assert!(m.is_empty()); }
        }
    "#;
    let r = lint_one("rust/src/sim/mod.rs", code);
    assert!(r.clean(), "{}", r.render());
    // Same tokens in a tests/ file: whole file is test scope.
    let r = lint_one("rust/tests/foo.rs", code);
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn flags_ambient_entropy_everywhere() {
    for snippet in [
        "fn f() { let t = SystemTime::now(); }",
        "fn f() { let mut rng = thread_rng(); }",
        "fn f() -> f64 { rand::random() }",
    ] {
        let r = lint_one("rust/tests/foo.rs", snippet);
        assert_eq!(hits(&r, "no-wallclock").len(), 1, "{snippet}: {}", r.render());
    }
    let r = lint_one("rust/tests/foo.rs", "fn f() { let rng = Pcg32::new(7, 1); }");
    assert!(hits(&r, "no-wallclock").is_empty(), "{}", r.render());
}

#[test]
fn instant_now_only_at_allowlisted_sites() {
    let one = "fn f() -> Instant { Instant::now() }";
    // Allowlisted file, single site: clean.
    let r = lint_one("rust/src/train/calib.rs", one);
    assert!(r.clean(), "{}", r.render());
    // Same code anywhere else: flagged.
    let r = lint_one("rust/src/sched/elastic.rs", one);
    assert_eq!(hits(&r, "instant-now-allowlist").len(), 1, "{}", r.render());
    // Two sites in an allowlisted file: the second is flagged.
    let two = "fn f() -> f64 { let a = Instant::now(); a.elapsed().as_secs_f64() }\n\
               fn g() -> Instant { Instant::now() }";
    let r = lint_one("rust/src/engine/driver.rs", two);
    assert_eq!(hits(&r, "instant-now-allowlist").len(), 1, "{}", r.render());
}

#[test]
fn pcg32_seed_must_be_explicitly_derived() {
    // Neither a literal nor anything seed-named in the first argument.
    let r = lint_one("rust/src/net/x.rs", "fn f(n: usize) { let g = Pcg32::new(n as u64, 1); }");
    assert_eq!(hits(&r, "pcg32-explicit-seed").len(), 1, "{}", r.render());
    // Literal-derived and seed-named first arguments pass.
    for ok in [
        "fn f(cfg: &C) { let g = Pcg32::new(cfg.seed ^ 0x5A17, 2); }",
        "fn f(seed: u64) { let g = Pcg32::new(seed.wrapping_add(3), 0); }",
        "fn f() { let g = Pcg32::new(1000 + 7, 1); }",
    ] {
        let r = lint_one("rust/src/net/x.rs", ok);
        assert!(hits(&r, "pcg32-explicit-seed").is_empty(), "{ok}: {}", r.render());
    }
    // Raw struct literals bypass seed derivation — banned outside util/rng.rs.
    let raw = "fn f() { let g = Pcg32 { state: 1, inc: 2 }; }";
    let r = lint_one("rust/src/net/x.rs", raw);
    assert_eq!(hits(&r, "pcg32-explicit-seed").len(), 1, "{}", r.render());
    let r = lint_one("rust/src/util/rng.rs", raw);
    assert!(r.clean(), "{}", r.render());
}

// ----------------------------------------------------------------- accounting

#[test]
fn billing_sites_must_be_registered() {
    // A construction site outside the registry is flagged...
    let r = lint_one(
        "rust/src/exp/foo.rs",
        "fn sneaky(d: Device) { let b = BilledAllocation { device: d, units: 1, held_s: 2.0, rate: 1.0 }; }",
    );
    assert_eq!(hits(&r, "billing-site-registry").len(), 1, "{}", r.render());
    // ...registered (file, fn) pairs and type-position uses are not.
    let r = lint_one(
        "rust/src/engine/driver.rs",
        "fn finalize_report(v: &mut Vec<BilledAllocation>) { v.push(BilledAllocation::on_demand(1)); }",
    );
    assert!(r.clean(), "{}", r.render());
    // Same for segment opens: alloc_since writes outside the registry.
    let r = lint_one("rust/src/engine/driver.rs", "fn helper(p: &mut P) { p.alloc_since = 0.0; }");
    assert_eq!(hits(&r, "billing-site-registry").len(), 1, "{}", r.render());
    let r = lint_one(
        "rust/src/engine/driver.rs",
        "fn deploy_job_planned(p: &mut P, t: f64) { p.alloc_since = t; }",
    );
    assert!(r.clean(), "{}", r.render());
    // Reads and field declarations are not writes.
    let r = lint_one(
        "rust/src/engine/partition.rs",
        "pub struct Partition { pub alloc_since: f64 }\nfn held(p: &Partition, t: f64) -> f64 { t - p.alloc_since }",
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn replan_causes_come_from_the_registry() {
    let r = lint_one(
        "rust/src/engine/foo.rs",
        r#"fn f(w: &mut W) { w.replans.push(ReplanEvent { cause: "manual".to_string() }); }"#,
    );
    assert_eq!(hits(&r, "replan-cause-registry").len(), 1, "{}", r.render());
    // Constants from the registry module pass...
    let r = lint_one(
        "rust/src/engine/foo.rs",
        "fn f(causes: &mut Vec<&str>) { causes.push(replan_cause::LEASE); }",
    );
    assert!(r.clean(), "{}", r.render());
    // ...and the registry's own definitions are exempt.
    let r = lint_one(
        "rust/src/train/metrics.rs",
        r#"pub mod replan_cause { pub const LEASE: &str = "lease"; }"#,
    );
    assert!(r.clean(), "{}", r.render());
}

#[test]
fn default_spread_banned_in_drift_prone_literals() {
    let r = lint_one(
        "rust/src/exp/foo.rs",
        "fn f() -> ElasticConfig { ElasticConfig { enabled: true, ..Default::default() } }",
    );
    assert_eq!(hits(&r, "no-default-spread").len(), 1, "{}", r.render());
    // Non-drift-prone struct names and test scope are out of bounds.
    let r = lint_one(
        "rust/src/exp/foo.rs",
        "fn f() -> Cursor { Cursor { pos: 0, ..Default::default() } }",
    );
    assert!(r.clean(), "{}", r.render());
    let r = lint_one(
        "rust/tests/foo.rs",
        "fn f() -> ElasticConfig { ElasticConfig { enabled: true, ..Default::default() } }",
    );
    assert!(r.clean(), "{}", r.render());
}

// ------------------------------------------------------------------- doc-sync

#[test]
fn config_keys_must_have_doc_rows() {
    let code = r#"fn parse(j: &Json) { let a = j.get("alpha"); let b = j.get("beta"); }"#;
    let r = lint_files(vec![("rust/src/config/mod.rs".to_string(), code.to_string())], docs());
    let found = hits(&r, "config-doc-sync");
    assert_eq!(found.len(), 1, "{}", r.render());
    assert!(found[0].contains("beta"), "{}", r.render());
    // Indexed/non-literal gets are not config keys.
    let code = "fn f(v: &[u32], m: &M) { let a = v.get(0); let b = m.get(&key); }";
    let r = lint_files(vec![("rust/src/config/mod.rs".to_string(), code.to_string())], docs());
    assert!(r.clean(), "{}", r.render());
}

/// A minimal `main.rs` registering ids `alpha` and `beta`/`beta2`.
const FIXTURE_MAIN: &str = r#"
fn cmd_exp(args: &Args) -> Result<()> {
    match id {
        "alpha" => run_alpha(),
        "beta" | "beta2" => run_beta(),
        other => bail!("unknown experiment id {other:?}"),
    }
}
"#;

fn lint_main(experiments_md: &str, ci_yml: &str) -> LintReport {
    let ctx = DocContext {
        config_md: docs().config_md,
        experiments_md: experiments_md.to_string(),
        ci_yml: ci_yml.to_string(),
    };
    lint_files(vec![("rust/src/main.rs".to_string(), FIXTURE_MAIN.to_string())], ctx)
}

#[test]
fn exp_ids_sync_against_docs_and_ci() {
    let good_md = concat!(
        "## Extensions beyond the paper\n\n| exp id |\n|---|\n",
        "| `alpha` |\n| `beta` (alias `beta2`) |\n\nrun `--id alpha` first.\n"
    );
    let good_ci = "  run: |\n    cargo run -- exp --id alpha\n    cargo run -- exp --id beta\n";
    let r = lint_main(good_md, good_ci);
    assert!(hits(&r, "exp-doc-sync").is_empty(), "{}", r.render());

    // A registered id with no doc row.
    let r = lint_main("## Extensions beyond the paper\n\n| `alpha` |\n", "exp --id alpha\n");
    assert!(
        hits(&r, "exp-doc-sync").iter().any(|m| m.contains("beta")),
        "{}",
        r.render()
    );
    // An extension id with no CI smoke.
    let r = lint_main(good_md, "  run: cargo run -- exp --id alpha\n");
    assert!(
        hits(&r, "exp-doc-sync").iter().any(|m| m.contains("no CI smoke")),
        "{}",
        r.render()
    );
    // CI smoking an unknown id.
    let r = lint_main(good_md, "exp --id alpha\nexp --id beta\nexp --id gamma\n");
    assert!(
        hits(&r, "exp-doc-sync").iter().any(|m| m.contains("gamma")),
        "{}",
        r.render()
    );
    // Docs mentioning an unknown id.
    let md = format!("{good_md}\nalso run `--id gamma`.\n");
    let r = lint_main(&md, "exp --id alpha\nexp --id beta\n");
    assert!(
        hits(&r, "exp-doc-sync").iter().any(|m| m.contains("gamma")),
        "{}",
        r.render()
    );
    // A documented extension that is not registered at all.
    let md = format!("{good_md}| `delta` |\n");
    let r = lint_main(&md, "exp --id alpha\nexp --id beta\n");
    assert!(
        hits(&r, "exp-doc-sync").iter().any(|m| m.contains("delta")),
        "{}",
        r.render()
    );
}

#[test]
fn cli_flags_must_be_documented() {
    let code = r#"fn cmd(args: &Args) { args.get_or("alpha", "1"); args.flag("missing"); }"#;
    let ctx = docs();
    let r = lint_files(vec![("rust/src/main.rs".to_string(), code.to_string())], ctx);
    let found = hits(&r, "flag-doc-sync");
    assert_eq!(found.len(), 1, "{}", r.render());
    assert!(found[0].contains("--missing"), "{}", r.render());
}

// ---------------------------------------------------------------- suppression

#[test]
fn lint_allow_suppresses_same_line_and_line_above() {
    let same_line = concat!(
        "fn f() { let m: HashMap<u32, u32> = make(); }",
        " // lint:allow(no-unordered-collections)"
    );
    let r = lint_one("rust/src/sim/bad.rs", same_line);
    assert!(r.clean(), "{}", r.render());
    assert_eq!(r.suppressed, 1);

    let line_above = concat!(
        "// lint:allow(no-unordered-collections)\n",
        "fn f() { let m: HashMap<u32, u32> = make(); }"
    );
    let r = lint_one("rust/src/sim/bad.rs", line_above);
    assert!(r.clean(), "{}", r.render());
    assert_eq!(r.suppressed, 1);

    // Several rules in one allow.
    let multi = concat!(
        "// lint:allow(no-unordered-collections, no-wallclock)\n",
        "fn f() { let m: HashMap<u32, SystemTime> = make(); }"
    );
    let r = lint_one("rust/src/sim/bad.rs", multi);
    assert!(r.clean(), "{}", r.render());
    assert_eq!(r.suppressed, 2);
}

#[test]
fn lint_allow_hygiene_is_itself_linted() {
    // Unknown rule id.
    let r = lint_one("rust/src/sim/bad.rs", "// lint:allow(not-a-rule)\nfn f() {}");
    assert!(
        hits(&r, "lint-allow").iter().any(|m| m.contains("not-a-rule")),
        "{}",
        r.render()
    );
    // Malformed grammar (no parenthesized list).
    let r = lint_one("rust/src/sim/bad.rs", "// lint:allow no-wallclock\nfn f() {}");
    assert!(
        hits(&r, "lint-allow").iter().any(|m| m.contains("malformed")),
        "{}",
        r.render()
    );
    // A well-formed allow that suppresses nothing is dead weight.
    let r = lint_one("rust/src/sim/bad.rs", "// lint:allow(no-wallclock)\nfn f() {}");
    assert!(
        hits(&r, "lint-allow").iter().any(|m| m.contains("suppresses nothing")),
        "{}",
        r.render()
    );
}

#[test]
fn lint_allow_mentions_in_prose_and_doc_comments_are_not_directives() {
    // The directive must BE the comment. Doc comments and prose that merely
    // quote the grammar (as this module's own docs do) parse as plain text —
    // no allow entry, no malformed-grammar finding.
    let prose = concat!(
        "//! Suppression grammar: `// lint:allow(rule-id)` on the line.\n",
        "/// See lint:allow(not-a-rule, ...) in docs/DEVELOPMENT.md.\n",
        "fn f() {} // grammar is lint:allow(...) as documented\n"
    );
    let r = lint_one("rust/src/sim/doc.rs", prose);
    assert!(r.clean(), "{}", r.render());
    assert_eq!(r.suppressed, 0);
}

// -------------------------------------------------------- determinism + repo

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn findings_are_sorted_and_report_is_deterministic() {
    // Multiple files, multiple findings: the report must come out sorted by
    // (file, line, rule) and byte-identical across runs.
    let files = vec![
        (
            "rust/src/zeta.rs".to_string(),
            "fn f() { let t = SystemTime::now(); }".to_string(),
        ),
        (
            "rust/src/alpha.rs".to_string(),
            "fn f() { let m: HashMap<u32, u32> = make(); }\nfn g() { let s: HashSet<u32> = make(); }"
                .to_string(),
        ),
    ];
    let a = lint_files(files.clone(), docs());
    let b = lint_files(files, docs());
    assert_eq!(a.render(), b.render(), "same tree must render byte-identically");
    let keys: Vec<(String, u32)> =
        a.findings.iter().map(|f| (f.file.clone(), f.line)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "{}", a.render());
}

#[test]
fn repo_lint_runs_deterministically() {
    let a = lint_repo(&repo_root()).expect("lint_repo");
    let b = lint_repo(&repo_root()).expect("lint_repo");
    assert_eq!(a.render(), b.render(), "same tree must render byte-identically");
    assert!(a.files_scanned > 40, "walker found only {} files", a.files_scanned);
}

/// The repaired tree is clean — this is the gate that runs inside tier-1.
#[test]
fn repo_tree_is_lint_clean() {
    let report = lint_repo(&repo_root()).expect("lint_repo");
    assert!(report.clean(), "repo lint violations:\n{}", report.render());
}

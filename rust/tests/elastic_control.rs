//! End-to-end elastic re-scheduling: the full engine (driver, FaaS
//! substrate, WAN fabric, monitor -> controller -> apply loop) driven by
//! the built-in synthetic model — no artifacts required, so this suite
//! runs everywhere tier-1 runs.
//!
//! Scenario (the ISSUE-2 acceptance case): a 4-cloud heterogeneous WAN
//! launches on the elastic initial plan; Beijing — a cloud the initial
//! plan cut down — loses 65% of its delivered compute. The static run
//! drags at Beijing's crippled pace; the elastic run must observe the
//! slowdown, scale Beijing back up through the autoscaler, and finish
//! sooner (throughput >= static), with the re-plans on the record.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::engine::ChurnEvent;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::elastic::ElasticConfig;
use cloudless::sched::optimal_matching;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::metrics::replan_cause;
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn churned_cfg(elastic: bool) -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 8;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 11;
    // Beijing loses 65% of its compute as soon as training starts
    // (PowerFactor events clamp to the training start).
    cfg.churn = vec![ChurnEvent::PowerFactor { t: 0.0, region: 2, factor: 0.35 }];
    if elastic {
        cfg.elastic = ElasticConfig {
            enabled: true,
            interval_s: 0.5,
            ..ElasticConfig::default()
        };
    }
    cfg
}

fn run(elastic: bool) -> TrainReport {
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    run_geo_training(&rt(), &env, initial, churned_cfg(elastic)).unwrap()
}

#[test]
fn elastic_recovers_throughput_after_mid_run_resource_loss() {
    let static_run = run(false);
    let elastic_run = run(true);

    // Both complete every planned step.
    let steps = |r: &TrainReport| r.partitions.iter().map(|p| p.steps).sum::<u64>();
    assert_eq!(steps(&static_run), steps(&elastic_run));

    // The static run never re-plans; the elastic run does, and records it.
    assert!(static_run.replan_events.is_empty());
    assert!(
        !elastic_run.replan_events.is_empty(),
        "a 65% compute loss must trigger at least one re-plan"
    );
    assert!(
        elastic_run.replan_events.len() <= 5,
        "hysteresis must keep the loop from thrashing: {:?}",
        elastic_run.replan_events
    );
    let last = elastic_run.replan_events.last().unwrap();
    assert_eq!(last.straggler, 2, "the slowed cloud becomes the reference");
    assert!(
        last.units[2] > 8,
        "Beijing must scale back up past its cut-down 8 units: {:?}",
        last.units
    );

    // The acceptance bar: elastic throughput recovers to at least the
    // static plan's (in practice it finishes measurably sooner).
    let throughput = |r: &TrainReport| steps(r) as f64 / r.total_time;
    assert!(
        throughput(&elastic_run) >= throughput(&static_run),
        "elastic {:.3} steps/s < static {:.3} steps/s",
        throughput(&elastic_run),
        throughput(&static_run)
    );
}

#[test]
fn elastic_run_is_deterministic() {
    let a = run(true);
    let b = run(true);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    assert_eq!(a.replan_events.len(), b.replan_events.len());
    for (x, y) in a.replan_events.iter().zip(&b.replan_events) {
        assert_eq!(x.t, y.t);
        assert_eq!(x.units, y.units);
    }
}

#[test]
fn calm_run_never_replans() {
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    let mut cfg = churned_cfg(true);
    cfg.churn.clear();
    let report = run_geo_training(&rt(), &env, initial, cfg).unwrap();
    assert!(
        report.replan_events.is_empty(),
        "nominal powers within hysteresis must hold the launch plan: {:?}",
        report.replan_events
    );
}

#[test]
fn elastic_costs_no_more_than_static_under_churn() {
    // Re-planning sheds idle units from the fast clouds while the
    // straggler works, so compute cost must not exceed the static run's.
    let static_run = run(false);
    let elastic_run = run(true);
    assert!(
        elastic_run.compute_cost <= static_run.compute_cost * 1.05,
        "elastic ${} vs static ${}",
        elastic_run.compute_cost,
        static_run.compute_cost
    );
}

#[test]
fn auto_compression_picks_a_codec_on_collapse_and_reverts_on_recovery() {
    // Compression-only control loop (`auto_compression` with `enabled`
    // off): the Shanghai<->Beijing star edges of the bandwidth-tree plan
    // collapse to 10% of nominal mid-run, then recover. The controller
    // must switch the collapsed pair to a lossy codec (recorded as a
    // "compression" re-plan event), put smaller payloads on the wire,
    // revert to dense after recovery — and never move load or re-plan
    // the topology, because `enabled` is off.
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    let mut cfg = churned_cfg(false);
    cfg.churn.clear();
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    cfg.topology = cloudless::engine::TopologyKind::BandwidthTree;

    // Baseline pass sizes the churn schedule in virtual time.
    let baseline = run_geo_training(&rt(), &env, initial.clone(), cfg.clone()).unwrap();
    assert!(baseline.replan_events.is_empty(), "no controller, no events");
    let t_total = baseline.total_time;
    let (t_collapse, t_recover) = (0.15 * t_total, 0.55 * t_total);

    cfg.churn = vec![
        ChurnEvent::LinkBandwidth { t: t_collapse, from: 0, to: 2, bps: 10e6 },
        ChurnEvent::LinkBandwidth { t: t_collapse, from: 2, to: 0, bps: 10e6 },
        ChurnEvent::LinkBandwidth { t: t_recover, from: 0, to: 2, bps: 100e6 },
        ChurnEvent::LinkBandwidth { t: t_recover, from: 2, to: 0, bps: 100e6 },
    ];
    cfg.elastic = ElasticConfig {
        auto_compression: true,
        interval_s: (t_total / 40.0).max(1e-3),
        ..ElasticConfig::default()
    };
    let report = run_geo_training(&rt(), &env, initial, cfg).unwrap();

    // Compression-only: every event is a pure codec event.
    assert!(!report.replan_events.is_empty(), "the collapse must be acted on");
    for ev in &report.replan_events {
        assert_eq!(ev.cause, replan_cause::COMPRESSION, "{ev:?}");
        assert!(!ev.topology_replanned, "{ev:?}");
        assert_eq!(ev.plan_delta, 0.0, "{ev:?}");
        assert!(!ev.compression_changes.is_empty(), "{ev:?}");
    }

    // The collapsed pair picks a lossy codec after the collapse...
    let changes = |pred: &dyn Fn(&str) -> bool| {
        report
            .replan_events
            .iter()
            .flat_map(|ev| ev.compression_changes.iter().map(move |c| (ev.t, c)))
            .filter(|(_, (f, t, codec))| (*f, *t) == (0, 2) && pred(codec))
            .map(|(t, _)| t)
            .collect::<Vec<_>>()
    };
    let picks = changes(&|c| c != "none");
    assert!(
        picks.iter().any(|&t| t > t_collapse),
        "collapsed link never picked a codec: {:?}",
        report.replan_events
    );
    // ...and reverts to dense once the recovery has been observed.
    let reverts = changes(&|c| c == "none");
    assert!(
        reverts.iter().any(|&t| t > t_recover),
        "recovered link never reverted (reverts {reverts:?}): {:?}",
        report.replan_events
    );

    // The codec override reached the wire: same count-based send
    // schedule, smaller payloads on the collapsed pair.
    let steps = |r: &TrainReport| r.partitions.iter().map(|p| p.steps).sum::<u64>();
    assert_eq!(steps(&baseline), steps(&report));
    assert!(
        report.wan_bytes < baseline.wan_bytes,
        "compressed run shipped {} B >= dense {} B",
        report.wan_bytes,
        baseline.wan_bytes
    );
}

#[test]
fn bandwidth_churn_replans_the_topology() {
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    let mut cfg = churned_cfg(true);
    // No compute churn; instead the Shanghai<->Beijing links (tree edges
    // of the bandwidth-tree plan on a uniform mesh, which stars at
    // region 0) collapse to a tenth of nominal mid-run.
    cfg.churn = vec![
        ChurnEvent::LinkBandwidth { t: 1.0, from: 0, to: 2, bps: 10e6 },
        ChurnEvent::LinkBandwidth { t: 1.0, from: 2, to: 0, bps: 10e6 },
    ];
    cfg.sync = SyncConfig::new(Strategy::Ama, 4);
    cfg.topology = cloudless::engine::TopologyKind::BandwidthTree;
    cfg.elastic.bw_threshold = 0.5;
    let report = run_geo_training(&rt(), &env, initial, cfg).unwrap();
    assert!(
        report.replan_events.iter().any(|e| e.topology_replanned),
        "a 10x collapse on a planned tree edge must re-plan the topology: {:?}",
        report.replan_events
    );
    // Load re-plans need a real compute signal; none was injected.
    for ev in &report.replan_events {
        assert!(
            ev.topology_replanned || ev.plan_delta > 0.0,
            "recorded replan did nothing: {ev:?}"
        );
    }
}

//! Integration: full geo-distributed training jobs through the DES engine
//! against real artifacts (requires `make artifacts`).

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::engine::TopologyKind;
use cloudless::net::LinkSpec;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::optimal_matching;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig};

fn rt() -> PjrtRuntime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    PjrtRuntime::new(dir).expect("PJRT CPU client")
}

fn quick_cfg(model: &str) -> TrainConfig {
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = 2;
    cfg.n_train = 512;
    cfg.n_eval = 256;
    cfg
}

#[test]
fn lenet_two_region_asgd_ga_learns() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 256, 256);
    let mut cfg = quick_cfg("lenet");
    cfg.epochs = 8;
    cfg.n_train = 3072;
    cfg.n_eval = 512;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();

    assert_eq!(report.partitions.len(), 2);
    assert!(report.final_accuracy > 0.6, "should beat chance by a lot: {}", report.final_accuracy);
    assert!(!report.curve.is_empty(), "accuracy curve recorded");
    assert!(report.total_time > 0.0);
    assert!(report.wan_bytes > 0, "syncs must cross the WAN");
    assert!(report.partitions.iter().all(|p| p.steps > 0));
    // loss should drop from the first eval to the last
    let first = report.curve.first().unwrap().loss;
    assert!(report.final_loss < first + 1e-6, "loss rose: {first} -> {}", report.final_loss);
}

#[test]
fn deterministic_under_seed() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 200, 312);
    let run = || {
        let mut cfg = quick_cfg("lenet");
        cfg.sync = SyncConfig::new(Strategy::Ama, 4);
        cfg.seed = 1234;
        run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    assert_eq!(a.curve.len(), b.curve.len());
}

#[test]
fn elastic_plan_reduces_waiting_vs_greedy() {
    // Uneven data (2:1) + heterogeneous CPUs: the greedy plan leaves the
    // Sky region waiting; the elastic plan matches LPs.
    let env = CloudEnv::tencent_two_region(Device::Skylake, 342, 170);
    let plan = optimal_matching(&env);
    assert_eq!(plan.allocations[1].total_units(), 4); // Table IV case 3

    let mk = || {
        let mut cfg = quick_cfg("lenet");
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
        cfg.skip_eval = true;
        cfg
    };
    let greedy = run_geo_training(&rt(), &env, env.greedy_plan(), mk()).unwrap();
    let elastic = run_geo_training(&rt(), &env, plan.allocations, mk()).unwrap();

    assert!(
        elastic.total_waiting() < greedy.total_waiting(),
        "elastic should cut waiting: {} vs {}",
        elastic.total_waiting(),
        greedy.total_waiting()
    );
    assert!(
        elastic.cost < greedy.cost,
        "elastic should cut cost: {} vs {}",
        elastic.cost,
        greedy.cost
    );
    // total time stays in the same ballpark (straggler unchanged)
    assert!(elastic.total_time < greedy.total_time * 1.3);
}

#[test]
fn higher_sync_freq_cuts_wan_traffic() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 256, 256);
    let mk = |freq| {
        let mut cfg = quick_cfg("lenet");
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, freq);
        cfg.skip_eval = true;
        cfg
    };
    let f1 = run_geo_training(&rt(), &env, env.greedy_plan(), mk(1)).unwrap();
    let f4 = run_geo_training(&rt(), &env, env.greedy_plan(), mk(4)).unwrap();
    // Backpressure coalesces saturated freq-1 sends, so the ratio can land
    // below the nominal 4x; it must still be a clear reduction.
    let ratio = f1.wan_bytes as f64 / f4.wan_bytes as f64;
    assert!(
        (1.8..6.0).contains(&ratio),
        "freq 4 should clearly cut traffic, got {ratio} ({} vs {})",
        f1.wan_bytes,
        f4.wan_bytes
    );
    assert!(f4.total_time <= f1.total_time, "less sync pressure should not slow training");
}

#[test]
fn sma_barrier_runs_and_syncs() {
    let env = CloudEnv::tencent_two_region(Device::Skylake, 256, 128);
    let mut cfg = quick_cfg("lenet");
    cfg.epochs = 8;
    cfg.n_train = 3072;
    cfg.sync = SyncConfig::new(Strategy::Sma, 8);
    cfg.link = LinkSpec::self_hosted();
    let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();
    assert!(report.final_accuracy > 0.5, "acc {}", report.final_accuracy);
    assert!(report.partitions.iter().all(|p| p.syncs_sent > 0));
    assert!(report.total_comm_wait() > 0.0, "barriers must cost some waiting");
}

#[test]
fn single_region_trivial_training() {
    // The paper's fig-7 baseline: trivial PS training in one cloud.
    let env = CloudEnv::new(vec![cloudless::cloud::Region::new(
        0,
        "Shanghai",
        vec![(Device::CascadeLake, 24)],
        512,
    )]);
    let mut cfg = quick_cfg("lenet");
    cfg.epochs = 12;
    cfg.n_train = 3072;
    cfg.worker_cores = 6; // per-PS worker parity with 12-core partitions
    let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();
    assert_eq!(report.partitions.len(), 1);
    assert_eq!(report.wan_bytes, 0, "no WAN in a single cloud");
    assert!(report.final_accuracy > 0.5, "acc {}", report.final_accuracy);
}

/// N identical Skylake regions splitting `n_train` evenly.
fn n_cloud_env(n: usize, n_train: usize) -> CloudEnv {
    CloudEnv::multi_region(
        (0..n)
            .map(|i| {
                let name: &'static str = ["c0", "c1", "c2", "c3"][i];
                (name, Device::Skylake, 12, n_train / n)
            })
            .collect(),
    )
}

#[test]
fn n_cloud_sma_matches_single_cloud_accuracy() {
    // The paper's model-correctness guarantee, extended past 2 clouds:
    // SMA on the randomly-sharded (IID) dataset must land near the same
    // fixed point as one cloud training on the merged shard. (The exact
    // fixed-point identity is covered numerically in ncloud_averaging.rs;
    // here we check the end-to-end engine on real lenet training.)
    let n_train = 3072;
    let single_env = CloudEnv::new(vec![cloudless::cloud::Region::new(
        0,
        "merged",
        vec![(Device::Skylake, 24)],
        n_train,
    )]);
    let mk = |env: &CloudEnv| {
        let mut cfg = quick_cfg("lenet");
        cfg.epochs = 8;
        cfg.n_train = n_train;
        cfg.n_eval = 512;
        cfg.sync = SyncConfig::new(Strategy::Sma, 8);
        cfg.link = LinkSpec::self_hosted();
        run_geo_training(&rt(), env, env.greedy_plan(), cfg).unwrap()
    };
    let single = mk(&single_env);
    for n in [3usize, 4] {
        let report = mk(&n_cloud_env(n, n_train));
        assert_eq!(report.partitions.len(), n);
        assert!(report.partitions.iter().all(|p| p.syncs_sent > 0 && p.syncs_received > 0));
        assert!(
            report.final_accuracy > 0.5,
            "{n}-cloud SMA should learn: acc {}",
            report.final_accuracy
        );
        assert!(
            (report.final_accuracy - single.final_accuracy).abs() < 0.2,
            "{n}-cloud SMA acc {} too far from merged single-cloud acc {}",
            report.final_accuracy,
            single.final_accuracy
        );
    }
}

#[test]
fn four_cloud_topologies_run_and_sync() {
    for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
        let env = n_cloud_env(4, 1024);
        let mut cfg = quick_cfg("lenet");
        cfg.sync = SyncConfig::new(Strategy::Ama, 4);
        cfg.topology = kind;
        cfg.skip_eval = true;
        let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();
        assert_eq!(report.topology, kind.name());
        assert!(report.wan_bytes > 0, "{kind:?}: syncs must cross the WAN");
        assert!(report.wan_transfers > 0, "{kind:?}");
        assert!(report.partitions.iter().all(|p| p.steps > 0));
    }
}

#[test]
fn resume_refuses_mismatched_topology() {
    let dir = std::env::temp_dir().join(format!("cloudless_topo_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let env = CloudEnv::tencent_two_region(Device::Skylake, 256, 256);
    let mk = |topology, strategy| {
        let mut cfg = quick_cfg("lenet");
        cfg.epochs = 2;
        cfg.skip_eval = true;
        cfg.sync = SyncConfig::new(strategy, 4);
        cfg.topology = topology;
        cfg.checkpoint_dir = Some(dir.clone());
        run_geo_training(&rt(), &env, env.greedy_plan(), cfg)
    };
    mk(TopologyKind::Ring, Strategy::AsgdGa).expect("fresh run checkpoints fine");
    // Same strategy+topology resumes; a different topology or strategy refuses.
    mk(TopologyKind::Ring, Strategy::AsgdGa).expect("matching rerun accepted");
    let err = mk(TopologyKind::Hierarchical, Strategy::AsgdGa).unwrap_err();
    assert!(err.to_string().contains("topology"), "{err}");
    let err = mk(TopologyKind::Ring, Strategy::Ama).unwrap_err();
    assert!(err.to_string().contains("strategy"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoints_written_and_restorable() {
    use cloudless::train::checkpoint::CheckpointStore;
    let dir = std::env::temp_dir().join(format!("cloudless_geo_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let env = CloudEnv::tencent_two_region(Device::Skylake, 256, 256);
    let mut cfg = quick_cfg("lenet");
    cfg.epochs = 2;
    cfg.skip_eval = true;
    cfg.checkpoint_dir = Some(dir.clone());
    let report = run_geo_training(&rt(), &env, env.greedy_plan(), cfg).unwrap();
    let store = CheckpointStore::new(&dir).unwrap();
    for p in &report.partitions {
        assert!(store.exists(&p.region), "missing checkpoint for {}", p.region);
        let ckpt = store.load(&p.region).unwrap();
        let restored = ckpt.restore(0.03);
        assert_eq!(restored.params.len(), 61706);
        assert!(restored.total_updates > 0);
    }
    assert!(dir.join("manifest.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

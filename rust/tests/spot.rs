//! End-to-end spot market: deterministic price/revocation traces,
//! preemption recovery with exact step accounting, and the
//! spot-vs-on-demand cost trade — the ISSUE-9 acceptance cases, driven
//! by the built-in synthetic model so the suite runs everywhere tier-1
//! runs.

use cloudless::cloud::devices::Device;
use cloudless::cloud::spot::SpotConfig;
use cloudless::cloud::CloudEnv;
use cloudless::engine::ChurnEvent;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::optimal_matching;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 8;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 11;
    cfg
}

fn run(cfg: TrainConfig) -> TrainReport {
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    run_geo_training(&rt(), &env, initial, cfg).unwrap()
}

fn total_steps(r: &TrainReport) -> u64 {
    r.partitions.iter().map(|p| p.steps).sum()
}

fn total_updates(r: &TrainReport) -> u64 {
    r.partitions.iter().map(|p| p.local_updates).sum()
}

#[test]
fn spot_disabled_is_byte_identical_to_the_seed_path() {
    // Run A: no spot block at all (the seed path).
    let plain = run(base_cfg());

    // Run B: a spot block with wildly different knobs — but disabled —
    // plus an injected revocation, which is a market phenomenon and must
    // be a no-op with the market off.
    let mut cfg = base_cfg();
    cfg.spot = SpotConfig {
        enabled: false,
        discount: 0.10,
        volatility: 0.9,
        preempt_per_hour: 100.0,
        restore_stall_s: 500.0,
        ..SpotConfig::default()
    };
    cfg.churn = vec![ChurnEvent::Preemption { t: 1.0, region: 1 }];
    let disabled = run(cfg);

    // Full-report byte identity (wall-clock diagnostic excluded — it is
    // the one genuinely nondeterministic field).
    let json = |r: &TrainReport| {
        let mut r = r.clone();
        r.wall_seconds = 0.0;
        r.to_json().to_string_pretty()
    };
    assert_eq!(json(&plain), json(&disabled));
    assert_eq!(plain.preemptions, 0);
    assert_eq!(plain.spot_savings, 0.0);
    assert_eq!(plain.restore_cost, 0.0);
}

#[test]
fn spot_traces_and_market_are_deterministic() {
    let spot_cfg = || {
        let mut cfg = base_cfg();
        cfg.spot = SpotConfig {
            enabled: true,
            preempt_per_hour: 6.0,
            restore_stall_s: 20.0,
            ..SpotConfig::default()
        };
        cfg
    };
    let a = run(spot_cfg());
    let b = run(spot_cfg());
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    assert_eq!(a.cost, b.cost);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.spot_savings, b.spot_savings);
    assert_eq!(a.restore_cost, b.restore_cost);
    assert!(a.spot_savings > 0.0, "the discounted market must bill below list price");
    // A different seed redraws the whole market.
    let mut other = spot_cfg();
    other.seed = 12;
    let c = run(other);
    assert!(
        (c.spot_savings - a.spot_savings).abs() > 1e-12,
        "a different seed must redraw the price trace"
    );
}

#[test]
fn preemption_conserves_step_and_update_totals() {
    // Market on, but the market's own revocation trace silenced
    // (preempt_per_hour = 0): the single injected revocation is the only
    // preemption, so the recovery path is exercised in isolation.
    let quiet = || {
        let mut cfg = base_cfg();
        cfg.spot = SpotConfig {
            enabled: true,
            preempt_per_hour: 0.0,
            restore_stall_s: 25.0,
            ..SpotConfig::default()
        };
        cfg
    };
    let baseline = run(quiet());
    assert_eq!(baseline.preemptions, 0);

    let mut cfg = quiet();
    cfg.churn = vec![ChurnEvent::Preemption { t: 2.0, region: 1 }];
    let preempted = run(cfg);

    assert_eq!(preempted.preemptions, 1, "exactly the injected revocation");
    // Exact accounting: lost in-flight steps are re-run, so step and
    // PS-update totals match the undisturbed run exactly.
    assert_eq!(total_steps(&preempted), total_steps(&baseline));
    assert_eq!(total_updates(&preempted), total_updates(&baseline));
    // The restore stall is real simulated time, and the checkpoint
    // save/fetch traffic is billed.
    assert!(
        preempted.total_time > baseline.total_time,
        "restore stall must cost makespan: {} vs {}",
        preempted.total_time,
        baseline.total_time
    );
    assert!(preempted.restore_cost > 0.0);
    // The itemized sum stays exact.
    let itemized = preempted.compute_cost
        + preempted.wan_cost
        + preempted.egress_cost
        + preempted.storage_cost
        + preempted.restore_cost;
    assert!(
        (preempted.cost - itemized).abs() < 1e-9,
        "cost {} != itemized sum {itemized}",
        preempted.cost
    );
}

#[test]
fn spot_run_is_cheaper_at_bounded_makespan() {
    let ondemand = run(base_cfg());
    assert_eq!(ondemand.preemptions, 0);
    assert_eq!(ondemand.spot_savings, 0.0);

    let mut cfg = base_cfg();
    cfg.spot = SpotConfig {
        enabled: true,
        discount: 0.35,
        volatility: 0.2,
        preempt_per_hour: 2.0,
        restore_stall_s: 20.0,
        ..SpotConfig::default()
    };
    let spot = run(cfg);

    assert!(
        spot.cost < ondemand.cost,
        "spot ${} must beat on-demand ${}",
        spot.cost,
        ondemand.cost
    );
    assert!(spot.spot_savings > 0.0);
    assert!(
        spot.total_time <= 1.35 * ondemand.total_time,
        "revocation overhead must stay bounded: {}s vs {}s",
        spot.total_time,
        ondemand.total_time
    );
    // Cheaper in dollars, identical in work done.
    assert_eq!(total_steps(&spot), total_steps(&ondemand));
}

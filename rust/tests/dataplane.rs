//! End-to-end data plane: catalog, joint placement, and physical WAN
//! shard migration driven through the full engine on the built-in
//! synthetic model — no artifacts required, so this suite runs
//! everywhere tier-1 runs.
//!
//! Scenario (the ISSUE-4 acceptance case): a 4-cloud WAN where 70% of
//! the dataset bytes sit in Shanghai — the *weakest* region — and
//! Guangzhou hangs off thin 30 Mbps links. Compute-follows-data
//! straggles on Shanghai; data-follows-compute blindly ships a
//! power-proportional share through the thin pipe (staging stalls +
//! egress); the joint planner must beat the first on makespan and the
//! second on total cost, with every byte accounted: a job's WAN bytes
//! are exactly its gradient payloads plus its migrated shard bytes, and
//! per-job totals reconcile against the shared fabric.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::fleet::{run_fleet, FleetConfig, JobRequest, LeasePolicy};
use cloudless::dataplane::{
    self, DataPlaneConfig, DatasetCatalog, Layout, PlacementMode, PlacementSpec,
};
use cloudless::engine::ChurnEvent;
use cloudless::net::LinkSpec;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::elastic::ElasticConfig;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn wan_at(mbps: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: mbps * 1e6, ..LinkSpec::wan_100mbps() }
}

/// Fat 300 Mbps core between regions 0-2, thin 30 Mbps Guangzhou spurs.
fn overrides() -> Vec<(usize, usize, LinkSpec)> {
    let mut ov = Vec::new();
    for (a, b) in [(0usize, 1usize), (0, 2), (1, 2)] {
        ov.push((a, b, wan_at(300.0)));
        ov.push((b, a, wan_at(300.0)));
    }
    for r in 0..3usize {
        ov.push((r, 3, wan_at(30.0)));
        ov.push((3, r, wan_at(30.0)));
    }
    ov
}

fn skewed_spec() -> PlacementSpec {
    PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 })
}

fn skewed_cfg(mode: PlacementMode) -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 6;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 23;
    cfg.link_overrides = overrides();
    cfg.dataplane = DataPlaneConfig {
        placement: Some(skewed_spec()),
        mode,
        sample_bytes: 256 * 1024,
        ..DataPlaneConfig::default()
    };
    cfg
}

fn run_cfg(cfg: TrainConfig) -> TrainReport {
    let rt = rt();
    let env = four_cloud_env();
    let meta = rt.load_model("synthetic").unwrap().meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
    run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap()
}

fn run_mode(mode: PlacementMode) -> TrainReport {
    run_cfg(skewed_cfg(mode))
}

#[test]
fn joint_beats_both_pure_modes_on_the_skewed_catalog() {
    let cfd = run_mode(PlacementMode::ComputeFollowsData);
    let dfc = run_mode(PlacementMode::DataFollowsCompute);
    let joint = run_mode(PlacementMode::Joint);

    let moved = |r: &TrainReport| r.dataplane.as_ref().unwrap().moved_bytes;
    assert_eq!(moved(&cfd), 0, "compute-follows-data never migrates");
    assert!(moved(&dfc) > 0, "a 70% skew forces the balancing mode to move");
    assert!(moved(&joint) > 0, "the joint planner must find payoff-positive moves");
    assert!(
        moved(&joint) <= moved(&dfc),
        "joint moves no more than blind balancing: {} vs {}",
        moved(&joint),
        moved(&dfc)
    );

    // The acceptance bar: joint beats compute-follows-data on makespan
    // (the data straggler is relieved) and data-follows-compute on total
    // cost (no thin-pipe staging, less egress, less idle billing).
    assert!(
        joint.total_time < 0.8 * cfd.total_time,
        "joint {:.1}s must clearly beat compute-follows-data {:.1}s",
        joint.total_time,
        cfd.total_time
    );
    assert!(
        joint.cost < 0.8 * dfc.cost,
        "joint ${:.4} must clearly beat data-follows-compute ${:.4}",
        joint.cost,
        dfc.cost
    );

    // The blind balancer pays for the thin Guangzhou pipe with stalls.
    let dfc_dp = dfc.dataplane.as_ref().unwrap();
    assert!(
        dfc_dp.stall_time > 0.0,
        "shipping through 30 Mbps must stall the cold destination"
    );
}

#[test]
fn wan_bytes_are_gradients_plus_shards() {
    // Ring topology: every sync ships exactly one uncompressed gradient
    // payload along one edge, so the job's WAN bytes must decompose
    // exactly into gradient payloads + migrated shard bytes.
    let report = run_mode(PlacementMode::Joint);
    let dp = report.dataplane.as_ref().unwrap();
    let meta = rt().load_model("synthetic").unwrap().meta;
    let wire = meta.param_count as u64 * 4 + 64;
    let sends: u64 = report.partitions.iter().map(|p| p.syncs_sent).sum();
    assert!(dp.moved_bytes > 0);
    assert_eq!(
        report.wan_bytes,
        sends * wire + dp.moved_bytes,
        "byte conservation: wan = {} sends x {} + {} shard bytes",
        sends,
        wire,
        dp.moved_bytes
    );
    // Egress was priced per source region on every moved byte.
    assert!(dp.egress_cost > 0.0);
    assert!(report.wan_cost > dp.egress_cost - 1e-12);
    assert!((report.cost - (report.compute_cost + report.wan_cost)).abs() < 1e-9);
}

#[test]
fn per_job_bytes_reconcile_on_a_shared_fabric_with_migrations() {
    // Two concurrent jobs, both migrating shards over one shared WAN,
    // with the fleet's shared catalog steering the data split: per-job
    // accounting must still sum exactly to the fabric's totals.
    let rt = rt();
    let template = skewed_cfg(PlacementMode::Joint);
    let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    cfg.link_overrides = overrides();
    cfg.catalog = Some(
        DatasetCatalog::from_spec(&skewed_spec(), 512, 4, 256 * 1024, &[1; 4]).unwrap(),
    );
    let requests: Vec<JobRequest> = (0..2)
        .map(|i| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), i as f64 * 1.0, train)
        })
        .collect();
    let fleet = run_fleet(&rt, &cfg, &requests).unwrap();
    assert_eq!(fleet.jobs.len(), 2);
    let per_job: u64 = fleet.jobs.iter().map(|j| j.report.wan_bytes).sum();
    assert_eq!(per_job, fleet.wan_bytes, "per-job WAN bytes must sum to the fabric's");
    for j in &fleet.jobs {
        let dp = j.report.dataplane.as_ref().expect("each job ran a data plane");
        assert!(dp.moved_bytes > 0, "{} migrated nothing", j.name);
        assert!(j.report.wan_bytes > dp.moved_bytes, "gradient traffic also flowed");
    }
}

#[test]
fn dataplane_runs_are_deterministic() {
    let a = run_mode(PlacementMode::Joint);
    let b = run_mode(PlacementMode::Joint);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    let (da, db) = (a.dataplane.as_ref().unwrap(), b.dataplane.as_ref().unwrap());
    assert_eq!(da.moved_bytes, db.moved_bytes);
    assert_eq!(da.moved_shards, db.moved_shards);
    assert_eq!(da.stall_time, db.stall_time);
    assert_eq!(da.staging_done, db.staging_done);
}

#[test]
fn data_less_regions_finish_instantly_without_compute() {
    // single:0 + compute-follows-data: three regions hold no data, get
    // no allocation, and must close out cleanly at startup instead of
    // panicking (`load_power` totality end to end).
    let rt = rt();
    let env = four_cloud_env();
    let mut cfg = skewed_cfg(PlacementMode::ComputeFollowsData);
    cfg.dataplane.placement = Some(PlacementSpec::new(Layout::Single { region: 0 }));
    let meta = rt.load_model("synthetic").unwrap().meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
    let report = run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap();
    for p in &report.partitions[1..] {
        assert_eq!(p.steps, 0, "{} trained without data", p.region);
        assert_eq!(p.units, 0, "{} was allocated compute for nothing", p.region);
    }
    assert!(report.partitions[0].steps > 0);
    assert_eq!(report.dataplane.as_ref().unwrap().moved_bytes, 0);
}

#[test]
fn replica_sets_beat_single_homes_on_makespan_at_bounded_egress() {
    // The ISSUE-5 acceptance case: the same 70%-skewed catalog seeded
    // with two replica copies per shard (`skewed:8:0.7:r2`). The joint
    // planner reads from the nearest pre-existing copy — the hot
    // region's load spreads without the staged copies (and egress) the
    // single-home run has to pay, so the run is strictly faster and the
    // migration bill can only shrink.
    let r1 = run_mode(PlacementMode::Joint);
    let mut cfg = skewed_cfg(PlacementMode::Joint);
    cfg.dataplane.placement = Some(skewed_spec().with_replication(2));
    let r2 = run_cfg(cfg);

    let (d1, d2) = (r1.dataplane.as_ref().unwrap(), r2.dataplane.as_ref().unwrap());
    assert_eq!(d2.placement, "skewed:8:0.7:r2", "the spec records its replica factor");
    assert!(
        r2.total_time < 0.99 * r1.total_time,
        "r2 must be strictly faster: {:.2}s vs r1 {:.2}s",
        r2.total_time,
        r1.total_time
    );
    assert!(
        d2.moved_bytes <= d1.moved_bytes,
        "pre-existing replicas reduce staged copies: {} vs {}",
        d2.moved_bytes,
        d1.moved_bytes
    );
    assert!(
        d2.egress_cost <= d1.egress_cost + 1e-9,
        "extra egress stays within the single-home copy bill: ${} vs ${}",
        d2.egress_cost,
        d1.egress_cost
    );
    // WAN byte conservation with replicas: each created copy's bytes
    // are counted exactly once, however many epochs read the copy.
    let meta = rt().load_model("synthetic").unwrap().meta;
    let wire = meta.param_count as u64 * 4 + 64;
    let sends: u64 = r2.partitions.iter().map(|p| p.syncs_sent).sum();
    assert_eq!(
        r2.wan_bytes,
        sends * wire + d2.moved_bytes,
        "byte conservation at r2: wan = {sends} sends x {wire} + {} copy bytes",
        d2.moved_bytes
    );
    assert_eq!(d2.replicas_created.len(), d2.moved_shards, "one provenance entry per copy");
}

#[test]
fn fleet_jobs_with_private_dataplane_plan_on_the_live_shared_fabric() {
    // Regression (ROADMAP data-plane defect): the fleet's WAN has thin
    // 30 Mbps Guangzhou spurs, but the job's own TrainConfig still
    // carries the default uniform 100 Mbps template. Admission used to
    // plan the joint placement against the template — and ship the
    // fast-but-unreachable Guangzhou region a share of the hot data.
    // Planning must read the live SharedFabric's link specs instead and
    // leave Guangzhou alone.
    let rt = rt();
    let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    cfg.link_overrides = overrides(); // the *fleet* WAN is thin to GZ
    let mut train = skewed_cfg(PlacementMode::Joint);
    train.link_overrides = Vec::new(); // the job template claims uniform 100 Mbps
    let fleet = run_fleet(&rt, &cfg, &[JobRequest::new("j0", 0.0, train)]).unwrap();
    let dp = fleet.jobs[0].report.dataplane.as_ref().expect("job ran a data plane");
    assert!(dp.moved_bytes > 0, "the skew still forces migration");
    assert!(
        dp.replicas_created.iter().all(|&(_, _, to)| to != 3),
        "hot shards must not be shipped through the thin Guangzhou links: {:?}",
        dp.replicas_created
    );
}

#[test]
fn later_fleet_jobs_benefit_from_earlier_migrations() {
    // Regression (ROADMAP data-plane defect): a shared-catalog fleet
    // never let one job's migration benefit later jobs — admission read
    // the admission-time snapshot. Now the coordinator re-reads the live
    // replica map between arrivals: the second job, arriving after the
    // first finished, plans against the already-created replicas and
    // moves strictly fewer bytes.
    let rt = rt();
    let template = skewed_cfg(PlacementMode::Joint);
    let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    cfg.link_overrides = overrides();
    cfg.catalog = Some(
        DatasetCatalog::from_spec(&skewed_spec(), 512, 4, 256 * 1024, &[1; 4]).unwrap(),
    );
    let requests: Vec<JobRequest> = (0..2)
        .map(|i| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            // Job 1 arrives long after job 0's virtual completion.
            JobRequest::new(&format!("job{i}"), i as f64 * 10_000.0, train)
        })
        .collect();
    let fleet = run_fleet(&rt, &cfg, &requests).unwrap();
    let d0 = fleet.jobs[0].report.dataplane.as_ref().unwrap();
    let d1 = fleet.jobs[1].report.dataplane.as_ref().unwrap();
    assert!(d0.moved_bytes > 0, "the first job pays for the copies");
    assert!(
        d1.moved_bytes < d0.moved_bytes,
        "the second job must reuse job 0's replicas: {} vs {}",
        d1.moved_bytes,
        d0.moved_bytes
    );
}

#[test]
fn observed_power_drift_rebalances_shards() {
    // The elastic loop's data-plane hook: after the joint staging
    // settles, Chongqing (a data-heavy destination) loses 75% of its
    // compute. The committed load re-plan must carry rebalancing moves
    // that relocate shards off the slowed cloud, and the run must still
    // complete deterministically.
    let run = || {
        let rt = rt();
        let env = four_cloud_env();
        let mut cfg = skewed_cfg(PlacementMode::Joint);
        cfg.epochs = 10;
        cfg.elastic = ElasticConfig {
            enabled: true,
            interval_s: 0.5,
            ..ElasticConfig::default()
        };
        cfg.churn = vec![ChurnEvent::PowerFactor { t: 1.0, region: 1, factor: 0.25 }];
        let meta = rt.load_model("synthetic").unwrap().meta;
        let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
        run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap()
    };
    let report = run();
    let dp = report.dataplane.as_ref().unwrap();
    assert!(
        report.replan_events.iter().any(|e| e.data_moves > 0),
        "a 75% compute loss on a data-heavy cloud must trigger shard rebalancing: {:?}",
        report.replan_events
    );
    assert!(dp.rebalances >= 1);
    assert!(dp.rebalances <= 2, "rebalance churn must stay bounded");
    let again = run();
    assert_eq!(report.total_time, again.total_time, "rebalancing stays deterministic");
    assert_eq!(report.wan_bytes, again.wan_bytes);
}

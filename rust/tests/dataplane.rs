//! End-to-end data plane: catalog, joint placement, and physical WAN
//! shard migration driven through the full engine on the built-in
//! synthetic model — no artifacts required, so this suite runs
//! everywhere tier-1 runs.
//!
//! Scenario (the ISSUE-4 acceptance case): a 4-cloud WAN where 70% of
//! the dataset bytes sit in Shanghai — the *weakest* region — and
//! Guangzhou hangs off thin 30 Mbps links. Compute-follows-data
//! straggles on Shanghai; data-follows-compute blindly ships a
//! power-proportional share through the thin pipe (staging stalls +
//! egress); the joint planner must beat the first on makespan and the
//! second on total cost, with every byte accounted: a job's WAN bytes
//! are exactly its gradient payloads plus its migrated shard bytes, and
//! per-job totals reconcile against the shared fabric.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::fleet::{run_fleet, FleetConfig, JobRequest, LeasePolicy};
use cloudless::dataplane::{
    self, DataPlaneConfig, DatasetCatalog, PlacementMode, PlacementSpec,
};
use cloudless::engine::ChurnEvent;
use cloudless::net::LinkSpec;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::elastic::ElasticConfig;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 128),
        ("Chongqing", Device::Skylake, 12, 128),
        ("Beijing", Device::Skylake, 12, 128),
        ("Guangzhou", Device::IceLake, 12, 128),
    ])
}

fn wan_at(mbps: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: mbps * 1e6, ..LinkSpec::wan_100mbps() }
}

/// Fat 300 Mbps core between regions 0-2, thin 30 Mbps Guangzhou spurs.
fn overrides() -> Vec<(usize, usize, LinkSpec)> {
    let mut ov = Vec::new();
    for (a, b) in [(0usize, 1usize), (0, 2), (1, 2)] {
        ov.push((a, b, wan_at(300.0)));
        ov.push((b, a, wan_at(300.0)));
    }
    for r in 0..3usize {
        ov.push((r, 3, wan_at(30.0)));
        ov.push((3, r, wan_at(30.0)));
    }
    ov
}

fn skewed_cfg(mode: PlacementMode) -> TrainConfig {
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 6;
    cfg.n_train = 512;
    cfg.n_eval = 64;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.seed = 23;
    cfg.link_overrides = overrides();
    cfg.dataplane = DataPlaneConfig {
        placement: Some(PlacementSpec::Skewed { shards: 8, frac: 0.7 }),
        mode,
        sample_bytes: 256 * 1024,
        ..DataPlaneConfig::default()
    };
    cfg
}

fn run_mode(mode: PlacementMode) -> TrainReport {
    let rt = rt();
    let env = four_cloud_env();
    let cfg = skewed_cfg(mode);
    let meta = rt.load_model("synthetic").unwrap().meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
    run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap()
}

#[test]
fn joint_beats_both_pure_modes_on_the_skewed_catalog() {
    let cfd = run_mode(PlacementMode::ComputeFollowsData);
    let dfc = run_mode(PlacementMode::DataFollowsCompute);
    let joint = run_mode(PlacementMode::Joint);

    let moved = |r: &TrainReport| r.dataplane.as_ref().unwrap().moved_bytes;
    assert_eq!(moved(&cfd), 0, "compute-follows-data never migrates");
    assert!(moved(&dfc) > 0, "a 70% skew forces the balancing mode to move");
    assert!(moved(&joint) > 0, "the joint planner must find payoff-positive moves");
    assert!(
        moved(&joint) <= moved(&dfc),
        "joint moves no more than blind balancing: {} vs {}",
        moved(&joint),
        moved(&dfc)
    );

    // The acceptance bar: joint beats compute-follows-data on makespan
    // (the data straggler is relieved) and data-follows-compute on total
    // cost (no thin-pipe staging, less egress, less idle billing).
    assert!(
        joint.total_time < 0.8 * cfd.total_time,
        "joint {:.1}s must clearly beat compute-follows-data {:.1}s",
        joint.total_time,
        cfd.total_time
    );
    assert!(
        joint.cost < 0.8 * dfc.cost,
        "joint ${:.4} must clearly beat data-follows-compute ${:.4}",
        joint.cost,
        dfc.cost
    );

    // The blind balancer pays for the thin Guangzhou pipe with stalls.
    let dfc_dp = dfc.dataplane.as_ref().unwrap();
    assert!(
        dfc_dp.stall_time > 0.0,
        "shipping through 30 Mbps must stall the cold destination"
    );
}

#[test]
fn wan_bytes_are_gradients_plus_shards() {
    // Ring topology: every sync ships exactly one uncompressed gradient
    // payload along one edge, so the job's WAN bytes must decompose
    // exactly into gradient payloads + migrated shard bytes.
    let report = run_mode(PlacementMode::Joint);
    let dp = report.dataplane.as_ref().unwrap();
    let meta = rt().load_model("synthetic").unwrap().meta;
    let wire = meta.param_count as u64 * 4 + 64;
    let sends: u64 = report.partitions.iter().map(|p| p.syncs_sent).sum();
    assert!(dp.moved_bytes > 0);
    assert_eq!(
        report.wan_bytes,
        sends * wire + dp.moved_bytes,
        "byte conservation: wan = {} sends x {} + {} shard bytes",
        sends,
        wire,
        dp.moved_bytes
    );
    // Egress was priced per source region on every moved byte.
    assert!(dp.egress_cost > 0.0);
    assert!(report.wan_cost > dp.egress_cost - 1e-12);
    assert!((report.cost - (report.compute_cost + report.wan_cost)).abs() < 1e-9);
}

#[test]
fn per_job_bytes_reconcile_on_a_shared_fabric_with_migrations() {
    // Two concurrent jobs, both migrating shards over one shared WAN,
    // with the fleet's shared catalog steering the data split: per-job
    // accounting must still sum exactly to the fabric's totals.
    let rt = rt();
    let template = skewed_cfg(PlacementMode::Joint);
    let mut cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
    cfg.link_overrides = overrides();
    cfg.catalog = Some(
        DatasetCatalog::from_spec(
            &PlacementSpec::Skewed { shards: 8, frac: 0.7 },
            512,
            4,
            256 * 1024,
            &[1; 4],
        )
        .unwrap(),
    );
    let requests: Vec<JobRequest> = (0..2)
        .map(|i| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), i as f64 * 1.0, train)
        })
        .collect();
    let fleet = run_fleet(&rt, &cfg, &requests).unwrap();
    assert_eq!(fleet.jobs.len(), 2);
    let per_job: u64 = fleet.jobs.iter().map(|j| j.report.wan_bytes).sum();
    assert_eq!(per_job, fleet.wan_bytes, "per-job WAN bytes must sum to the fabric's");
    for j in &fleet.jobs {
        let dp = j.report.dataplane.as_ref().expect("each job ran a data plane");
        assert!(dp.moved_bytes > 0, "{} migrated nothing", j.name);
        assert!(j.report.wan_bytes > dp.moved_bytes, "gradient traffic also flowed");
    }
}

#[test]
fn dataplane_runs_are_deterministic() {
    let a = run_mode(PlacementMode::Joint);
    let b = run_mode(PlacementMode::Joint);
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.wan_bytes, b.wan_bytes);
    let (da, db) = (a.dataplane.as_ref().unwrap(), b.dataplane.as_ref().unwrap());
    assert_eq!(da.moved_bytes, db.moved_bytes);
    assert_eq!(da.moved_shards, db.moved_shards);
    assert_eq!(da.stall_time, db.stall_time);
    assert_eq!(da.staging_done, db.staging_done);
}

#[test]
fn data_less_regions_finish_instantly_without_compute() {
    // single:0 + compute-follows-data: three regions hold no data, get
    // no allocation, and must close out cleanly at startup instead of
    // panicking (`load_power` totality end to end).
    let rt = rt();
    let env = four_cloud_env();
    let mut cfg = skewed_cfg(PlacementMode::ComputeFollowsData);
    cfg.dataplane.placement = Some(PlacementSpec::Single { region: 0 });
    let meta = rt.load_model("synthetic").unwrap().meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
    let report = run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap();
    for p in &report.partitions[1..] {
        assert_eq!(p.steps, 0, "{} trained without data", p.region);
        assert_eq!(p.units, 0, "{} was allocated compute for nothing", p.region);
    }
    assert!(report.partitions[0].steps > 0);
    assert_eq!(report.dataplane.as_ref().unwrap().moved_bytes, 0);
}

#[test]
fn observed_power_drift_rebalances_shards() {
    // The elastic loop's data-plane hook: after the joint staging
    // settles, Chongqing (a data-heavy destination) loses 75% of its
    // compute. The committed load re-plan must carry rebalancing moves
    // that relocate shards off the slowed cloud, and the run must still
    // complete deterministically.
    let run = || {
        let rt = rt();
        let env = four_cloud_env();
        let mut cfg = skewed_cfg(PlacementMode::Joint);
        cfg.epochs = 10;
        cfg.elastic = ElasticConfig {
            enabled: true,
            interval_s: 0.5,
            ..ElasticConfig::default()
        };
        cfg.churn = vec![ChurnEvent::PowerFactor { t: 1.0, region: 1, factor: 0.25 }];
        let meta = rt.load_model("synthetic").unwrap().meta;
        let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
        run_geo_training(&rt, &env, planned.plan.allocations, cfg).unwrap()
    };
    let report = run();
    let dp = report.dataplane.as_ref().unwrap();
    assert!(
        report.replan_events.iter().any(|e| e.data_moves > 0),
        "a 75% compute loss on a data-heavy cloud must trigger shard rebalancing: {:?}",
        report.replan_events
    );
    assert!(dp.rebalances >= 1);
    assert!(dp.rebalances <= 2, "rebalance churn must stay bounded");
    let again = run();
    assert_eq!(report.total_time, again.total_time, "rebalancing stays deterministic");
    assert_eq!(report.wan_bytes, again.wan_bytes);
}

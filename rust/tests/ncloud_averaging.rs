//! N-cloud model-averaging correctness — the paper's model-correctness
//! guarantee (§III.C: averaging preserves the training fixed point),
//! extended past 2 clouds.
//!
//! Pure numerics, no PJRT: each "cloud" minimizes a quadratic over its
//! shard (`grad = w - shard_mean`, the exact SGD gradient of
//! `|w - x|^2/2` data), using real `PsState` updates and the engine's
//! real topology plans + `apply_payload` weights — including the
//! Metropolis weights and the `sequential_weight` compensation the
//! engine's communicator applies — with an SMA-style barrier exchange per
//! round and a decaying learning rate.
//!
//! Facts verified (tolerances validated against a float64 reference
//! simulation of the same dynamics):
//!
//! 1. With IID shards (every cloud's shard mean equals the merged mean —
//!    the random-shuffle sharding the paper assumes), 3- and 4-cloud SMA
//!    converges to **exactly** the fixed point of a single-cloud run on
//!    the merged shard, for every topology.
//! 2. With heterogeneous shards, every topology's per-round mixing matrix
//!    is now doubly stochastic (Metropolis weights + sequential
//!    compensation), so ring AND hub shapes land on the single-cloud
//!    fixed point to within the decayed-step tolerance — the old
//!    in-degree weights left hub topologies with a ~0.24 "hub authority"
//!    drift (reference sim); the Metropolis scheme pins it below 0.05
//!    (reference: ring 0.026, star 0.046 at n=4), order-independently.

use cloudless::engine::{sequential_weight, SyncPlan, TopologyKind};
use cloudless::net::{Fabric, LinkSpec};
use cloudless::ps::PsState;
use cloudless::sync::{apply_payload, Payload, Strategy, SyncConfig};

const DIM: usize = 6;
const ROUNDS: usize = 800;
const F_LOCAL: usize = 2;

fn uniform_fabric(n: usize) -> Fabric {
    let mut f = Fabric::new(3);
    for a in 0..n {
        for b in 0..n {
            if a != b {
                f.add_link(a, b, LinkSpec::wan_100mbps());
            }
        }
    }
    f
}

/// Deterministic heterogeneous shard means in [-1, 1].
fn shard_means(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..DIM).map(|d| ((i * 7 + d * 13 + 3) % 17) as f32 / 8.5 - 1.0).collect()
        })
        .collect()
}

fn merged_mean(means: &[Vec<f32>]) -> Vec<f32> {
    let n = means.len() as f32;
    (0..DIM).map(|d| means.iter().map(|m| m[d]).sum::<f32>() / n).collect()
}

fn lr_at(round: usize) -> f32 {
    0.4 / (1.0 + 0.05 * round as f32)
}

/// One SMA round: `F_LOCAL` local steps per cloud, then a barrier
/// exchange along the plan (snapshots first — everyone ships its
/// pre-exchange model, as the engine's barrier does; arrivals apply with
/// the same sequential compensation as `engine::comm::receive_payload`).
fn sma_round(
    cfg: &SyncConfig,
    plan: &SyncPlan,
    clouds: &mut [PsState],
    means: &[Vec<f32>],
    lr: f32,
    reverse_order: bool,
) {
    for (i, ps) in clouds.iter_mut().enumerate() {
        ps.lr = lr;
        for _ in 0..F_LOCAL {
            let grad: Vec<f32> =
                ps.params.iter().zip(&means[i]).map(|(w, m)| w - m).collect();
            let v = ps.version;
            ps.push_gradient(&grad, v);
        }
    }
    let snaps: Vec<Vec<f32>> = clouds.iter_mut().map(|ps| ps.snapshot_params()).collect();
    let mut senders: Vec<usize> = (0..clouds.len()).collect();
    if reverse_order {
        senders.reverse();
    }
    for s in senders {
        for e in plan.outgoing(s) {
            let applied = clouds[e.to].applied_weight_since_snapshot;
            let eff = sequential_weight(e.weight, plan.incoming_weight(e.to), applied);
            clouds[e.to].note_applied_weight(e.weight);
            apply_payload(cfg, &mut clouds[e.to], &Payload::Params(snaps[s].clone()), eff);
        }
    }
}

fn run_geo(kind: TopologyKind, means: &[Vec<f32>], reverse_order: bool) -> Vec<Vec<f32>> {
    let n = means.len();
    let cfg = SyncConfig::new(Strategy::Sma, F_LOCAL as u32);
    let plan = kind.plan(n, &uniform_fabric(n));
    let mut clouds: Vec<PsState> =
        (0..n).map(|_| PsState::new(vec![0.0; DIM], 0.1)).collect();
    for t in 0..ROUNDS {
        sma_round(&cfg, &plan, &mut clouds, means, lr_at(t), reverse_order);
    }
    clouds.into_iter().map(|ps| ps.params).collect()
}

/// The single-cloud reference: same step schedule on the merged shard.
fn run_single(merged: &[f32]) -> Vec<f32> {
    let mut ps = PsState::new(vec![0.0; DIM], 0.1);
    for t in 0..ROUNDS {
        ps.lr = lr_at(t);
        for _ in 0..F_LOCAL {
            let grad: Vec<f32> =
                ps.params.iter().zip(merged).map(|(w, m)| w - m).collect();
            let v = ps.version;
            ps.push_gradient(&grad, v);
        }
    }
    ps.params
}

fn max_dev(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

const KINDS: [TopologyKind; 3] =
    [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree];

#[test]
fn iid_shards_reach_the_single_cloud_fixed_point_exactly() {
    for n in [3usize, 4] {
        let merged = merged_mean(&shard_means(n));
        // IID sharding: every shard mean equals the merged mean.
        let means: Vec<Vec<f32>> = (0..n).map(|_| merged.clone()).collect();
        let single = run_single(&merged);
        assert!(max_dev(&single, &merged) < 1e-4, "single-cloud must reach the merged optimum");
        for kind in KINDS {
            let clouds = run_geo(kind, &means, false);
            for (i, w) in clouds.iter().enumerate() {
                // Float32 running means round by ~1 ulp per apply; the
                // contraction keeps the equilibrium error ~1e-5.
                assert!(
                    max_dev(w, &single) < 1e-3,
                    "{kind:?} n={n}: cloud {i} off the single-cloud fixed point by {}",
                    max_dev(w, &single)
                );
            }
        }
    }
}

#[test]
fn ring_matches_single_cloud_under_heterogeneous_shards() {
    // The ring's per-round mixing matrix is doubly stochastic, so even
    // with heterogeneous shards the decayed-step limit is the merged
    // optimum (reference float64 sim: dev 0.016 at n=3, 0.027 at n=4
    // with the Metropolis 1/3 ring weight).
    for n in [3usize, 4] {
        let means = shard_means(n);
        let single = run_single(&merged_mean(&means));
        for (i, w) in run_geo(TopologyKind::Ring, &means, false).iter().enumerate() {
            assert!(
                max_dev(w, &single) < 0.05,
                "ring n={n}: cloud {i} drifted {} from the merged fixed point",
                max_dev(w, &single)
            );
        }
    }
}

#[test]
fn all_topologies_pin_the_merged_optimum_without_hub_drift() {
    for n in [3usize, 4] {
        let means = shard_means(n);
        let single = run_single(&merged_mean(&means));
        for kind in KINDS {
            let clouds = run_geo(kind, &means, false);
            // Near-consensus across clouds (reference sim: spread <=
            // 0.091 at n=4 — Metropolis mixes slower than the old
            // in-degree weights but without concentrating mass).
            for a in &clouds {
                for b in &clouds {
                    assert!(
                        max_dev(a, b) < 0.13,
                        "{kind:?} n={n}: clouds disagree by {}",
                        max_dev(a, b)
                    );
                }
            }
            // The tightened bound the Metropolis weights buy: every
            // topology — hub shapes included — stays within the decayed-
            // step tolerance of the merged optimum (reference sim: ring
            // 0.026, star 0.046 at n=4; the old in-degree weights sat at
            // 0.242 for the hub fan-out).
            for (i, w) in clouds.iter().enumerate() {
                assert!(
                    max_dev(w, &single) < 0.08,
                    "{kind:?} n={n}: cloud {i} drifted {} — hub authority is back",
                    max_dev(w, &single)
                );
            }
        }
    }
}

#[test]
fn compensated_mix_is_arrival_order_independent() {
    // The sequential compensation reconstructs the synchronous Metropolis
    // row, so reversing the sender application order must not move the
    // result (reference sim: bit-identical in f64; allow f32 slack).
    for n in [3usize, 4] {
        let means = shard_means(n);
        for kind in KINDS {
            let fwd = run_geo(kind, &means, false);
            let rev = run_geo(kind, &means, true);
            for (a, b) in fwd.iter().zip(&rev) {
                assert!(
                    max_dev(a, b) < 1e-3,
                    "{kind:?} n={n}: arrival order changed the fixed point by {}",
                    max_dev(a, b)
                );
            }
        }
    }
}

//! Property suite for the WAN link scheduler (the `net` layer's priority
//! lanes) and the compression wire-byte accounting the elastic controller
//! builds on.
//!
//! The load-bearing invariants, in order:
//!
//! 1. **Lanes-off equivalence** — with lanes disabled (the default), the
//!    class-tagged scheduling path is byte-for-byte identical to the
//!    historical single-FIFO fabric: same `Transfer` timings, same RNG
//!    stream consumption, same aggregate statistics, for any class mix.
//! 2. **Priority ordering** — with lanes on, a latency-critical transfer
//!    never queues behind lower-priority backlog; its own lane stays
//!    FIFO.
//! 3. **No starvation** — a bulk transfer yields to higher lanes for at
//!    most `MAX_PRIORITY_WAIT_S` beyond its own-lane backlog, even under
//!    an adversarial flood of Control traffic.
//! 4. **Conservation** — per-lane statistics partition the link totals
//!    exactly (bytes, delivered transfers), drops included.
//! 5. **Barrier isolation** (the ISSUE acceptance case) — barrier
//!    transfer times are bit-identical whether the concurrent bulk
//!    backlog on the same link is 10 MB or 1.5 GB.
//! 6. **Exact wire accounting** — end-to-end over `run_geo_training`,
//!    gradient sync is count-based, so `wan_bytes` is exactly
//!    `sends x wire(codec)` for each codec's closed-form wire size.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::net::{Fabric, LinkSpec, TrafficClass, MAX_PRIORITY_WAIT_S};
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::optimal_matching;
use cloudless::sync::{Compression, Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig, TrainReport};

const CLASSES: [TrafficClass; 4] = [
    TrafficClass::Control,
    TrafficClass::Barrier,
    TrafficClass::Gradient,
    TrafficClass::BulkData,
];

fn stable_wan() -> LinkSpec {
    LinkSpec {
        bandwidth_bps: 100e6,
        latency_s: 0.015,
        fluct_sigma: 0.0,
        drop_prob: 0.0,
        setup_s: 0.0,
    }
}

/// Deterministic test-local generator (splitmix64) so the adversarial
/// workloads are reproducible without touching the fabric's RNG streams.
struct Mix(u64);

impl Mix {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[test]
fn lanes_off_fabric_is_byte_identical_to_the_seed_fifo() {
    // Same seed, same mesh (one lossy fluctuating link, two clean ones);
    // one fabric driven through the historical `transfer`, the other
    // through `transfer_class` with an arbitrary class mix. Every
    // Transfer and every aggregate statistic must match exactly.
    let lossy = LinkSpec { drop_prob: 0.2, ..LinkSpec::wan_100mbps() };
    let build = || {
        Fabric::full_mesh(9, 3, &LinkSpec::wan_100mbps(), &[(0, 1, lossy.clone())])
    };
    let mut fifo = build();
    let mut tagged = build();
    assert!(!tagged.lanes_enabled(), "lanes must default off");

    let mut mix = Mix(7);
    for i in 0..240u64 {
        let from = (mix.next() % 3) as usize;
        let to = (from + 1 + (mix.next() % 2) as usize) % 3;
        let bytes = 10_000 + mix.next() % 2_000_000;
        let now = i as f64 * 0.04;
        let class = CLASSES[(mix.next() % 4) as usize];
        let a = fifo.transfer(from, to, bytes, now);
        let b = tagged.transfer_class(from, to, bytes, now, class);
        assert_eq!(a, b, "op {i} ({from}->{to}, {bytes} B, {class:?}) diverged");
    }
    for from in 0..3 {
        for to in 0..3 {
            if from == to {
                continue;
            }
            let sa = fifo.stats(from, to).unwrap();
            let sb = tagged.stats(from, to).unwrap();
            // Everything but the lane attribution (which intentionally
            // differs: the FIFO fabric logs all traffic as Gradient).
            assert_eq!(sa.bytes, sb.bytes);
            assert_eq!(sa.transfers, sb.transfers);
            assert_eq!(sa.drops, sb.drops);
            assert_eq!(sa.busy_time, sb.busy_time);
            assert_eq!(sa.stream_time, sb.stream_time);
            assert_eq!(sa.queue_delay, sb.queue_delay);
        }
    }
}

#[test]
fn lane_priority_ordering_is_strict_and_own_lane_fifo() {
    let mut f = Fabric::new(1);
    f.add_link(0, 1, stable_wan());
    f.set_lanes(true);

    // 10 s of bulk occupies the lowest lane first.
    let bulk = f.transfer_class(0, 1, 125_000_000, 0.0, TrafficClass::BulkData);
    assert!((bulk.done - 10.0).abs() < 1e-9);

    // Every higher class starts at its submit time, through the backlog.
    let g1 = f.transfer_class(0, 1, 12_500_000, 0.2, TrafficClass::Gradient);
    let b1 = f.transfer_class(0, 1, 1_250_000, 0.4, TrafficClass::Barrier);
    let c1 = f.transfer_class(0, 1, 125_000, 0.45, TrafficClass::Control);
    assert!((g1.start - 0.2).abs() < 1e-9, "{g1:?}");
    assert!((b1.start - 0.4).abs() < 1e-9, "{b1:?}");
    assert!((c1.start - 0.45).abs() < 1e-9, "{c1:?}");

    // A second barrier queues behind the first: own lane is FIFO.
    let b2 = f.transfer_class(0, 1, 1_250_000, 0.45, TrafficClass::Barrier);
    assert!((b2.start - b1.done).abs() < 1e-9, "{b2:?}");

    // A second gradient binds on its own lane (1.2 s), not on the small
    // higher-priority horizon.
    let g2 = f.transfer_class(0, 1, 12_500_000, 0.5, TrafficClass::Gradient);
    assert!((g2.start - g1.done).abs() < 1e-9, "{g2:?}");

    // And the yield to a *large* higher-priority backlog is bounded:
    // 20 s of Control delays a fresh gradient by exactly
    // MAX_PRIORITY_WAIT_S, no more.
    let c_big = f.transfer_class(0, 1, 250_000_000, 20.0, TrafficClass::Control);
    assert!((c_big.done - 40.0).abs() < 1e-9);
    let g3 = f.transfer_class(0, 1, 1_000_000, 20.5, TrafficClass::Gradient);
    assert!((g3.start - (20.5 + MAX_PRIORITY_WAIT_S)).abs() < 1e-9, "{g3:?}");
}

#[test]
fn bulk_wait_is_bounded_under_adversarial_control_flood() {
    // ~100 s of Control backlog; bulk submissions at arbitrary instants
    // must each start within MAX_PRIORITY_WAIT_S of max(submit time,
    // their own lane's backlog) — the no-starvation property.
    let mut f = Fabric::new(3);
    f.add_link(0, 1, stable_wan());
    f.set_lanes(true);
    for i in 0..100 {
        f.transfer_class(0, 1, 12_500_000, i as f64 * 0.01, TrafficClass::Control);
    }
    let mut mix = Mix(11);
    let mut own_backlog: f64 = 0.0;
    for _ in 0..20 {
        let submit = (mix.next() % 80) as f64 + (mix.next() % 100) as f64 / 100.0;
        let bytes = 100_000 + mix.next() % 5_000_000;
        let t = f.transfer_class(0, 1, bytes, submit, TrafficClass::BulkData);
        let bound = submit.max(own_backlog) + MAX_PRIORITY_WAIT_S;
        assert!(
            t.start <= bound + 1e-9,
            "bulk starved: submit {submit}, own backlog {own_backlog}, {t:?}"
        );
        own_backlog = own_backlog.max(t.done);
    }
}

#[test]
fn per_lane_stats_conserve_link_totals_under_drops() {
    // Random class mix on a lossy, fluctuating link — in both scheduling
    // modes the per-lane attribution must partition the link totals:
    // bytes and delivered transfers exactly, busy time to float rounding
    // (drops are counted on the link, never attributed to a lane).
    for lanes in [false, true] {
        let mut f = Fabric::new(17);
        f.add_link(0, 1, LinkSpec { drop_prob: 0.3, ..LinkSpec::wan_100mbps() });
        f.set_lanes(lanes);
        let mut mix = Mix(23);
        for i in 0..300u64 {
            let bytes = 1_000 + mix.next() % 3_000_000;
            let class = CLASSES[(mix.next() % 4) as usize];
            f.transfer_class(0, 1, bytes, i as f64 * 0.03, class);
        }
        let st = f.stats(0, 1).unwrap();
        assert!(st.drops > 0, "lossy link must have dropped something");
        assert_eq!(st.lanes.iter().map(|l| l.bytes).sum::<u64>(), st.bytes);
        assert_eq!(
            st.lanes.iter().map(|l| l.transfers).sum::<u64>(),
            st.transfers - st.drops,
            "lanes attribute delivered transfers only (lanes={lanes})"
        );
        let lane_busy: f64 = st.lanes.iter().map(|l| l.busy_time).sum();
        assert!(
            (lane_busy - st.busy_time).abs() < 1e-6,
            "lane busy {lane_busy} != link busy {} (lanes={lanes})",
            st.busy_time
        );
    }
}

#[test]
fn barrier_time_is_independent_of_concurrent_bulk_bytes() {
    // The ISSUE acceptance case: with lanes on, a barrier's wire time
    // must not change when the concurrent shard-migration backlog on the
    // same link grows from 10 MB to 1.5 GB.
    let barrier_schedule = [0.1, 0.35, 6.0];
    let run = |bulk_moves: &[(f64, u64)]| {
        let mut f = Fabric::new(5);
        f.add_link(0, 1, stable_wan());
        f.set_lanes(true);
        let mut events: Vec<(f64, Option<u64>)> = bulk_moves
            .iter()
            .map(|&(t, b)| (t, Some(b)))
            .chain(barrier_schedule.iter().map(|&t| (t, None)))
            .collect();
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut barriers = Vec::new();
        let mut last_bulk_done: f64 = 0.0;
        for (t, bulk) in events {
            match bulk {
                Some(bytes) => {
                    let tr = f.transfer_class(0, 1, bytes, t, TrafficClass::BulkData);
                    last_bulk_done = last_bulk_done.max(tr.done);
                }
                None => barriers.push(f.transfer_class(0, 1, 1_250_000, t, TrafficClass::Barrier)),
            }
        }
        (barriers, last_bulk_done)
    };
    let (light, light_done) = run(&[(0.0, 10_000_000)]);
    let (heavy, heavy_done) = run(&[(0.0, 1_000_000_000), (5.0, 500_000_000)]);
    assert!(heavy_done > 100.0 && light_done < 1.0, "backlogs must actually differ");
    for (i, (a, b)) in light.iter().zip(&heavy).enumerate() {
        assert_eq!(a, b, "barrier {i} felt the bulk backlog");
        assert!((a.start - barrier_schedule[i].max(light[..i].last().map_or(0.0, |p| p.done)))
            .abs()
            < 1e-9);
    }
}

// ------------------------------------------------- end-to-end accounting

fn rt() -> PjrtRuntime {
    // The synthetic model never touches the artifacts directory.
    PjrtRuntime::new("artifacts-not-needed").expect("PJRT CPU client")
}

fn four_cloud_env() -> CloudEnv {
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, 64),
        ("Chongqing", Device::Skylake, 12, 64),
        ("Beijing", Device::Skylake, 12, 64),
        ("Guangzhou", Device::IceLake, 12, 64),
    ])
}

fn codec_run(codec: Compression) -> TrainReport {
    let env = four_cloud_env();
    let initial = optimal_matching(&env).allocations;
    let mut cfg = TrainConfig::new("synthetic");
    cfg.epochs = 4;
    cfg.n_train = 256;
    cfg.n_eval = 64;
    cfg.skip_eval = true;
    cfg.seed = 7;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8).with_compression(codec);
    run_geo_training(&rt(), &env, initial, cfg).unwrap()
}

#[test]
fn static_codecs_account_exact_wire_bytes_end_to_end() {
    // ASGD-GA syncs are count-based (a send fires every `freq` local
    // updates and the step budget is fixed), so the three runs perform
    // identical send sequences and `wan_bytes` must equal
    // `sends x wire(codec)` exactly, from the codecs' closed-form wire
    // sizes — no tolerance.
    let len = rt().load_model("synthetic").unwrap().meta.param_count as u64;
    let dense_wire = 4 * len + 64;
    let k = ((len as f64) * 0.25).ceil() as u64; // TopK keeps ceil(len/4)
    let topk_wire = 8 * k + 64;
    let q8_wire = len + 4 * len.div_ceil(2048) + 64;

    let dense = codec_run(Compression::None);
    let topk = codec_run(Compression::TopK { ratio: 0.25 });
    let q8 = codec_run(Compression::Q8);

    let steps = |r: &TrainReport| r.partitions.iter().map(|p| p.steps).sum::<u64>();
    assert_eq!(steps(&dense), steps(&topk));
    assert_eq!(steps(&dense), steps(&q8));

    assert_eq!(dense.wan_bytes % dense_wire, 0, "non-gradient bytes on the WAN?");
    let sends = dense.wan_bytes / dense_wire;
    assert!(sends > 0, "the run must have synced");
    assert_eq!(topk.wan_bytes, sends * topk_wire, "TopK wire accounting drifted");
    assert_eq!(q8.wan_bytes, sends * q8_wire, "Q8 wire accounting drifted");
    assert!(topk.wan_bytes < q8.wan_bytes && q8.wan_bytes < dense.wan_bytes);
}

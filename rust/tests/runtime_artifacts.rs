//! Integration: the Rust runtime loads and executes real AOT artifacts.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).
//! These tests are the proof that the three layers compose: Pallas (L1)
//! lowered inside JAX graphs (L2) executed from Rust via PJRT (L3).

use cloudless::runtime::{vecops, PjrtRuntime, Tensor};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> PjrtRuntime {
    PjrtRuntime::new(artifacts_dir()).expect("PJRT CPU client")
}

#[test]
fn pallas_matmul_kernel_executes() {
    // kernel_matmul.hlo.txt is the raw L1 Pallas kernel (256x256x256).
    let rt = runtime();
    let exe = rt.compile_artifact("kernel_matmul.hlo.txt").unwrap();
    let n = 256usize;
    // a = I, b = arbitrary -> a@b == b.
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        a[i * n + i] = 1.0;
    }
    let b: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
    let lit_a = xla::Literal::vec1(&a).reshape(&[n as i64, n as i64]).unwrap();
    let lit_b = xla::Literal::vec1(&b).reshape(&[n as i64, n as i64]).unwrap();
    let outs = exe.run(&[lit_a, lit_b]).unwrap();
    let got = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(got.len(), n * n);
    for i in 0..n * n {
        assert!((got[i] - b[i]).abs() < 1e-5, "mismatch at {i}: {} vs {}", got[i], b[i]);
    }
}

#[test]
fn lenet_train_step_runs_and_learns() {
    let rt = runtime();
    let m = rt.load_model("lenet").unwrap();
    assert_eq!(m.meta.name, "lenet");
    let b = m.meta.batch_size;
    let xelem = m.meta.x_elems_per_example();

    // Deterministic toy batch: two blobby "classes".
    let x: Vec<f32> = (0..b * xelem)
        .map(|i| if (i / xelem) % 2 == 0 { 0.5 } else { -0.5 })
        .collect();
    let y: Vec<i32> = (0..b as i32).map(|i| i % 2).collect();
    let xt = Tensor::f32(x, m.meta.x_dims());
    let yt = Tensor::i32(y, m.meta.y_dims());

    let mut params = m.init_params.clone();
    let (grads, loss0) = m.train_step(&params, &xt, &yt).unwrap();
    assert_eq!(grads.len(), m.meta.param_count);
    assert!(loss0.is_finite() && loss0 > 0.0, "loss0={loss0}");

    // A few SGD steps on the fixed batch must reduce the loss.
    let mut loss = loss0;
    for _ in 0..10 {
        let (g, l) = m.train_step(&params, &xt, &yt).unwrap();
        vecops::sgd_apply_inplace(&mut params, &g, 0.05);
        loss = l;
    }
    assert!(loss < loss0 * 0.9, "no learning: {loss0} -> {loss}");

    // Eval agrees on shapes and counts.
    let (loss_sum, correct) = m.eval_batch(&params, &xt, &yt).unwrap();
    assert!(loss_sum.is_finite());
    assert!((0.0..=b as f32).contains(&correct));
}

#[test]
fn pjrt_vecops_match_native() {
    let rt = runtime();
    let m = rt.load_model("lenet").unwrap();
    let p0 = m.init_params.clone();
    let n = p0.len();
    let g: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();

    // sgd_apply
    let via_pjrt = m.sgd_apply(&p0, &g, 0.1).unwrap();
    let mut via_native = p0.clone();
    vecops::sgd_apply_inplace(&mut via_native, &g, 0.1);
    for i in 0..n {
        assert!((via_pjrt[i] - via_native[i]).abs() < 1e-6, "sgd mismatch at {i}");
    }

    // average
    let avg_pjrt = m.model_average(&p0, &g, 0.5).unwrap();
    let mut avg_native = p0.clone();
    vecops::average_inplace(&mut avg_native, &g, 0.5);
    for i in 0..n {
        assert!((avg_pjrt[i] - avg_native[i]).abs() < 1e-6, "avg mismatch at {i}");
    }

    // accumulate
    let acc_pjrt = m.grad_accumulate(&p0, &g).unwrap();
    for i in 0..n {
        assert!((acc_pjrt[i] - (p0[i] + g[i])).abs() < 1e-6, "acc mismatch at {i}");
    }
}

#[test]
fn all_default_models_load() {
    let rt = runtime();
    for name in ["lenet", "resnet", "deepfm", "transformer"] {
        let m = rt.load_model(name).unwrap_or_else(|e| panic!("loading {name}: {e}"));
        assert!(m.meta.param_count > 0);
        assert_eq!(m.init_params.len(), m.meta.param_count);
    }
}

#[test]
fn deepfm_pallas_artifact_runs() {
    // DeepFM's train graph is the Pallas-path lowering (meta.compute):
    // executing it exercises interpret-mode Pallas HLO through PJRT.
    let rt = runtime();
    let m = rt.load_model("deepfm").unwrap();
    assert_eq!(m.meta.compute, "pallas");
    let b = m.meta.batch_size;
    let fields = m.meta.vocab_sizes.len();
    let x: Vec<i32> = (0..b * fields).map(|i| (i % m.meta.vocab_sizes[0]) as i32).collect();
    let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
    let xt = Tensor::i32(x, m.meta.x_dims());
    let yt = Tensor::f32(y, m.meta.y_dims());
    let (grads, loss) = m.train_step(&m.init_params, &xt, &yt).unwrap();
    assert!(loss.is_finite());
    assert!(grads.iter().any(|g| *g != 0.0));
}

//! PS checkpointing — the fault-tolerance piece a deployable framework
//! needs (the paper builds on ElasticDL, whose pitch is Kubernetes-native
//! fault tolerance; our serverless PS functions are stateful and must
//! survive replica reschedules).
//!
//! A checkpoint is a directory with one `{region}.ckpt` per partition
//! (binary: header + flat f32 params + accumulator) plus `manifest.json`
//! describing the job. Atomic via write-to-temp + rename.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::ps::PsState;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"CLDLSSv1";

/// Serialized form of one PS's recoverable state.
#[derive(Debug, Clone, PartialEq)]
pub struct PsCheckpoint {
    pub params: Vec<f32>,
    pub accum: Vec<f32>,
    pub accum_steps: u32,
    pub total_updates: u64,
    pub version: u64,
}

impl PsCheckpoint {
    pub fn capture(ps: &PsState) -> PsCheckpoint {
        PsCheckpoint {
            params: ps.params.clone(),
            accum: ps.accum.clone(),
            accum_steps: ps.accum_steps,
            total_updates: ps.total_updates,
            version: ps.version,
        }
    }

    /// Restore into a fresh PsState with the given learning rate.
    pub fn restore(&self, lr: f32) -> PsState {
        let mut ps = PsState::new(self.params.clone(), lr);
        ps.accum = self.accum.clone();
        ps.accum_steps = self.accum_steps;
        ps.total_updates = self.total_updates;
        ps.version = self.version;
        ps
    }

    fn encode(&self) -> Vec<u8> {
        let n = self.params.len();
        let mut out = Vec::with_capacity(8 + 8 + 4 + 8 + 8 + n * 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(n as u64).to_le_bytes());
        out.extend_from_slice(&self.accum_steps.to_le_bytes());
        out.extend_from_slice(&self.total_updates.to_le_bytes());
        out.extend_from_slice(&self.version.to_le_bytes());
        for x in &self.params {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for x in &self.accum {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    fn decode(bytes: &[u8]) -> Result<PsCheckpoint> {
        anyhow::ensure!(bytes.len() >= 36 && &bytes[..8] == MAGIC, "bad checkpoint header");
        let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let accum_steps = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let total_updates = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let version = u64::from_le_bytes(bytes[28..36].try_into().unwrap());
        anyhow::ensure!(bytes.len() == 36 + n * 8, "truncated checkpoint (n={n})");
        let f32_at = |off: usize, len: usize| -> Vec<f32> {
            bytes[off..off + len * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        };
        Ok(PsCheckpoint {
            params: f32_at(36, n),
            accum: f32_at(36 + n * 4, n),
            accum_steps,
            total_updates,
            version,
        })
    }
}

/// A job-level checkpoint directory.
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<CheckpointStore> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(CheckpointStore { dir: dir.as_ref().to_path_buf() })
    }

    fn path_for(&self, region: &str) -> PathBuf {
        self.dir.join(format!("{region}.ckpt"))
    }

    /// Atomically persist one partition's PS state.
    pub fn save(&self, region: &str, ckpt: &PsCheckpoint) -> Result<()> {
        let tmp = self.dir.join(format!(".{region}.ckpt.tmp"));
        std::fs::write(&tmp, ckpt.encode())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.path_for(region))?;
        Ok(())
    }

    pub fn load(&self, region: &str) -> Result<PsCheckpoint> {
        let path = self.path_for(region);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        PsCheckpoint::decode(&bytes)
    }

    pub fn exists(&self, region: &str) -> bool {
        self.path_for(region).exists()
    }

    /// Write the job manifest (model, sync strategy, topology, step
    /// counts) for operators — and for the resume-compatibility check
    /// ([`ensure_run_compatible`]).
    pub fn write_manifest(
        &self,
        model: &str,
        strategy: &str,
        topology: &str,
        regions: &[(&str, u64)],
    ) -> Result<()> {
        let j = Json::obj(vec![
            ("model", Json::str(model)),
            ("strategy", Json::str(strategy)),
            ("topology", Json::str(topology)),
            (
                "partitions",
                Json::arr(regions.iter().map(|(r, steps)| {
                    Json::obj(vec![
                        ("region", Json::str(*r)),
                        ("updates", Json::num(*steps as f64)),
                    ])
                })),
            ),
        ]);
        std::fs::write(self.dir.join("manifest.json"), j.to_string_pretty())?;
        Ok(())
    }
}

/// Refuse to resume into a checkpoint directory written by an
/// incompatible run: averaging fixed points depend on the sync strategy
/// and topology, so silently mixing them corrupts a resumed model. A
/// missing directory or manifest is fine (fresh run); manifest fields a
/// pre-topology checkpoint lacks are skipped.
pub fn ensure_run_compatible(
    dir: impl AsRef<Path>,
    model: &str,
    strategy: &str,
    topology: &str,
) -> Result<()> {
    let path = dir.as_ref().join("manifest.json");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        // No prior manifest: fresh run, nothing to conflict with. Any
        // other I/O failure must NOT silently disable the gate.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => {
            return Err(anyhow::anyhow!("unreadable manifest {}: {e}", path.display()));
        }
    };
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("unreadable manifest {}: {e:?}", path.display()))?;
    for (key, ours) in [("model", model), ("strategy", strategy), ("topology", topology)] {
        if let Some(theirs) = j.get(key).as_str() {
            anyhow::ensure!(
                theirs == ours,
                "checkpoint dir {} holds a {key}={theirs} run; refusing to resume with \
                 {key}={ours} (use a fresh directory or match the original run)",
                dir.as_ref().display(),
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cloudless_ckpt_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn ps_with_state() -> PsState {
        let mut ps = PsState::new(vec![1.0, -2.0, 3.5, 0.25], 0.1);
        ps.push_gradient(&[0.1, 0.2, -0.3, 0.0], 0);
        ps.push_gradient(&[0.5, -0.5, 0.5, 1.0], 1);
        ps
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = temp_dir("rt");
        let store = CheckpointStore::new(&dir).unwrap();
        let ps = ps_with_state();
        let ckpt = PsCheckpoint::capture(&ps);
        store.save("Shanghai", &ckpt).unwrap();
        let loaded = store.load("Shanghai").unwrap();
        assert_eq!(loaded, ckpt);
        let restored = loaded.restore(0.1);
        assert_eq!(restored.params, ps.params);
        assert_eq!(restored.accum, ps.accum);
        assert_eq!(restored.accum_steps, ps.accum_steps);
        assert_eq!(restored.version, ps.version);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restored_ps_continues_training() {
        let ps = ps_with_state();
        let mut restored = PsCheckpoint::capture(&ps).restore(0.1);
        restored.push_gradient(&[1.0, 1.0, 1.0, 1.0], restored.version);
        assert_eq!(restored.total_updates, 3);
        assert_eq!(restored.accum_steps, 3, "accumulator carries across restarts");
    }

    #[test]
    fn corrupt_files_rejected() {
        let dir = temp_dir("bad");
        let store = CheckpointStore::new(&dir).unwrap();
        std::fs::write(dir.join("X.ckpt"), b"garbage").unwrap();
        assert!(store.load("X").is_err());
        // truncated but valid header
        let ckpt = PsCheckpoint::capture(&ps_with_state());
        let mut bytes = ckpt.encode();
        bytes.truncate(bytes.len() - 4);
        std::fs::write(dir.join("Y.ckpt"), &bytes).unwrap();
        assert!(store.load("Y").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exists_and_manifest() {
        let dir = temp_dir("mf");
        let store = CheckpointStore::new(&dir).unwrap();
        assert!(!store.exists("A"));
        store.save("A", &PsCheckpoint::capture(&ps_with_state())).unwrap();
        assert!(store.exists("A"));
        store.write_manifest("lenet", "SMA", "ring", &[("A", 42)]).unwrap();
        let manifest =
            Json::parse(&std::fs::read_to_string(dir.join("manifest.json")).unwrap()).unwrap();
        assert_eq!(manifest.get("model").as_str().unwrap(), "lenet");
        assert_eq!(manifest.get("strategy").as_str().unwrap(), "SMA");
        assert_eq!(manifest.get("topology").as_str().unwrap(), "ring");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_compat_gate() {
        let dir = temp_dir("compat");
        // No directory / manifest yet: any run may start.
        assert!(ensure_run_compatible(&dir, "lenet", "SMA", "ring").is_ok());
        let store = CheckpointStore::new(&dir).unwrap();
        store.write_manifest("lenet", "SMA", "ring", &[("A", 1)]).unwrap();
        // Matching run resumes fine.
        assert!(ensure_run_compatible(&dir, "lenet", "SMA", "ring").is_ok());
        // Mismatched topology / strategy / model all refuse, descriptively.
        let e = ensure_run_compatible(&dir, "lenet", "SMA", "hierarchical").unwrap_err();
        assert!(e.to_string().contains("topology=ring"), "{e}");
        assert!(ensure_run_compatible(&dir, "lenet", "AMA", "ring").is_err());
        assert!(ensure_run_compatible(&dir, "resnet", "SMA", "ring").is_err());
        // Pre-topology manifests (missing fields) stay resumable.
        std::fs::write(dir.join("manifest.json"), r#"{"model": "lenet"}"#).unwrap();
        assert!(ensure_run_compatible(&dir, "lenet", "SMA", "ring").is_ok());
        assert!(ensure_run_compatible(&dir, "resnet", "SMA", "ring").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Training engines: the DES-driven geo-distributed trainer (`geo`),
//! compute-time calibration (`calib`), and run reports (`metrics`).

pub mod calib;
pub mod checkpoint;
pub mod geo;
pub mod metrics;

pub use geo::{default_lr, run_geo_training, TopologyKind, TrainConfig};
pub use metrics::{EvalPoint, PartitionReport, TrainReport};

//! Compatibility shim — the geo-distributed training engine now lives in
//! [`crate::engine`], decomposed into explicit layers (the full diagram
//! is in docs/ARCHITECTURE.md):
//!
//! - [`crate::engine::driver`] — the discrete-event loop (`World`,
//!   [`run_geo_training`], barriers, eval, reporting; also the
//!   crate-internal multi-job entry points the fleet coordinator
//!   co-simulates jobs through);
//! - [`crate::engine::partition`] — the per-cloud actor (worker gating,
//!   PS state, step accounting; the seed's `Part`);
//! - [`crate::engine::comm`] — the WAN communicator (payload planning,
//!   send-slot backpressure, delivery);
//! - [`crate::engine::topology`] — pluggable N-cloud sync topologies
//!   (Ring / Hierarchical / BandwidthTree) with Metropolis per-edge
//!   averaging weights applied through sequential-arrival compensation.
//!
//! This module re-exports the engine's public surface so seed-era call
//! sites (`crate::train::run_geo_training`, `crate::train::TrainConfig`)
//! keep working unchanged. New code should prefer `crate::engine`
//! directly; multi-job fleets go through `crate::coordinator::fleet`.

pub use crate::engine::driver::{default_lr, run_geo_training, TrainConfig};
pub use crate::engine::topology::TopologyKind;

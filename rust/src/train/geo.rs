//! Compatibility shim — the geo-distributed training engine now lives in
//! [`crate::engine`], decomposed into explicit layers:
//!
//! - [`crate::engine::driver`] — the discrete-event loop (`World`,
//!   [`run_geo_training`], barriers, eval, reporting);
//! - [`crate::engine::partition`] — the per-cloud actor (worker gating,
//!   PS state, step accounting; the seed's `Part`);
//! - [`crate::engine::comm`] — the WAN communicator (payload planning,
//!   send-slot backpressure, delivery);
//! - [`crate::engine::topology`] — pluggable N-cloud sync topologies
//!   (Ring / Hierarchical / BandwidthTree) with in-degree-derived
//!   averaging weights.
//!
//! This module re-exports the engine's public surface so seed-era call
//! sites (`crate::train::run_geo_training`, `crate::train::TrainConfig`)
//! keep working unchanged. New code should prefer `crate::engine`
//! directly.

pub use crate::engine::driver::{default_lr, run_geo_training, TrainConfig};
pub use crate::engine::topology::TopologyKind;

//! Metrics collected from a geo-distributed training run.
//!
//! Everything the paper's figures plot comes out of this report: time
//! decomposition (execution vs waiting, Fig 2/8), WAN communication time
//! (Fig 3/10), monetary cost (Fig 8 d-f), accuracy/loss convergence
//! curves (Fig 7/9/10/11), plus diagnostics (staleness, sync counts,
//! cold starts) used by the ablations.

use crate::sim::Time;
use crate::util::json::Json;

/// One point on the accuracy/loss convergence curve.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalPoint {
    /// Virtual time of the evaluation.
    pub t: Time,
    /// Epoch index (partition-0 local epochs).
    pub epoch: usize,
    pub loss: f64,
    pub accuracy: f64,
}

/// The one registry of [`ReplanEvent::cause`] tags. Everything that files a
/// re-plan — the driver's control loop, the fleet coordinator, experiments
/// filtering events back out — goes through these constants, never ad-hoc
/// string literals: a typo'd cause is silently never matched downstream, so
/// the `replan-cause-registry` lint rule pins all cause strings to this
/// module. A composite cause joins several tags with `"+"`.
pub mod replan_cause {
    /// A spot revocation forced the re-plan past hysteresis.
    pub const PREEMPTION: &str = "preemption";
    /// Allocation movement (load re-plan).
    pub const LOAD: &str = "load";
    /// WAN bandwidth divergence re-planned the sync topology.
    pub const BANDWIDTH: &str = "bandwidth";
    /// Per-link gradient-codec reassignment.
    pub const COMPRESSION: &str = "compression";
    /// Multi-job lease re-division applied by the fleet coordinator.
    pub const LEASE: &str = "lease";
}

/// One committed re-plan of the elastic control loop (`sched::elastic`):
/// the monitor observed resource churn or WAN divergence, the controller
/// produced a new plan past hysteresis, and the driver applied it.
#[derive(Debug, Clone, Default)]
pub struct ReplanEvent {
    /// Virtual time the re-plan was applied.
    pub t: Time,
    /// What tripped it: any "+"-joined combination of the
    /// [`replan_cause`] tags (`PREEMPTION`, `LOAD`, `BANDWIDTH`,
    /// `COMPRESSION`, plus `LEASE` for multi-job lease re-divisions).
    pub cause: String,
    /// Relative plan movement that cleared hysteresis (0 for
    /// topology-only re-plans).
    pub plan_delta: f64,
    /// Straggler index of the new plan.
    pub straggler: usize,
    /// Total allocated units per cloud after the re-plan.
    pub units: Vec<u32>,
    /// True when the sync topology was re-planned from observed
    /// bandwidth.
    pub topology_replanned: bool,
    /// Shard migrations the data-plane rebalancer committed alongside
    /// this re-plan (0 without an active data plane).
    pub data_moves: usize,
    /// Per-link codec reassignments `(from, to, codec_name)` the elastic
    /// controller installed with this re-plan (`auto_compression`);
    /// codec names are "none" / "topk" / "q8".
    pub compression_changes: Vec<(usize, usize, String)>,
}

/// What the federated edge tier did during one training run (`None`
/// when the run was flat — the pre-composite behavior). All counters
/// aggregate over every cloud's cohorts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FederatedReport {
    /// Total edge clients deployed across the clouds.
    pub clients: u64,
    /// Total edge cohorts (stage-1 aggregation pools) across the clouds.
    pub cohorts: usize,
    /// Configured per-round client sampling fraction.
    pub sample_frac: f64,
    /// Configured per-round dropout (churn) probability.
    pub dropout: f64,
    /// Completed stage-1 cohort rounds.
    pub rounds: u64,
    /// Sampled clients that physically uploaded a gradient.
    pub participants: u64,
    /// Sampled clients that dropped mid-round; their uploads were lost
    /// but their cohorts' population weights still landed, so update
    /// totals conserve.
    pub dropouts: u64,
    /// Intra-cohort uplink bytes (counted in `wan_bytes`, unmetered by
    /// the cost model — last-mile edge traffic, not inter-cloud egress).
    pub uplink_bytes: u64,
}

/// Per-partition outcome.
#[derive(Debug, Clone, Default)]
pub struct PartitionReport {
    pub region: String,
    pub units: u32,
    pub power: f64,
    pub steps: u64,
    pub local_updates: u64,
    /// Virtual time this partition finished its local epochs.
    pub local_finish: Time,
    /// global_end - local_finish: resources held idle waiting for
    /// stragglers (the paper's "waiting time").
    pub waiting: Time,
    /// Time workers sat blocked on the PS communicator (WAN backpressure)
    /// + barrier waits.
    pub comm_wait: Time,
    /// Total WAN communication time attributable to this partition:
    /// `comm_wait` + its outgoing link's serialization busy time (the
    /// paper's "communication time on WAN").
    pub wan_time: Time,
    pub syncs_sent: u64,
    pub syncs_received: u64,
    pub mean_staleness: f64,
    pub cold_start_time: Time,
}

/// Full run report.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub model: String,
    pub strategy: String,
    /// Sync topology the run was planned with (`engine::topology` name).
    pub topology: String,
    pub sync_freq: u32,
    /// Virtual end-to-end training time (startup through last partition).
    pub total_time: Time,
    /// Virtual time spent in control-plane startup (scheduling,
    /// addressing, cold starts) before training began.
    pub startup_time: Time,
    pub partitions: Vec<PartitionReport>,
    pub curve: Vec<EvalPoint>,
    pub final_loss: f64,
    pub final_accuracy: f64,
    pub wan_bytes: u64,
    pub wan_transfers: u64,
    /// Monetary cost (USD): the sum of the itemized components below
    /// (compute + WAN sync + object-store egress + storage rent +
    /// preemption restores).
    pub cost: f64,
    /// Compute-only component (instance-seconds billed to global end,
    /// at each billing segment's market rate) — the paper's "training
    /// cost" headline compares this.
    pub compute_cost: f64,
    /// WAN gradient-sync traffic component (flat per-GB rate; shard
    /// migration egress is itemized separately below).
    pub wan_cost: f64,
    /// Object-store egress for data-plane shard migrations (0 without
    /// an active data plane).
    pub egress_cost: f64,
    /// Storage rent on persisted replica copies (0 without a data plane).
    pub storage_cost: f64,
    /// Checkpoint save/fetch traffic for spot-preemption recoveries
    /// (0 without the spot market).
    pub restore_cost: f64,
    /// Spot revocations this job absorbed (each one: pool revoked,
    /// checkpoint restored after the stall, lost in-flight steps re-run).
    pub preemptions: u64,
    /// What the same billed segments would have cost on-demand minus
    /// what they actually cost (0 for on-demand-only runs).
    pub spot_savings: f64,
    /// Real wall-clock seconds the simulation took (diagnostic).
    pub wall_seconds: f64,
    /// PJRT executions (diagnostic / perf accounting).
    pub pjrt_executions: u64,
    /// Mid-run re-plans the elastic control loop committed (empty for
    /// static runs).
    pub replan_events: Vec<ReplanEvent>,
    /// What the data plane did (None when the job ran without one — the
    /// seed behavior of locally-resident, never-moving data).
    pub dataplane: Option<crate::dataplane::DataPlaneReport>,
    /// What the federated edge tier did (None for flat runs).
    pub federated: Option<FederatedReport>,
}

impl TrainReport {
    /// Total waiting time across partitions (Fig 8's shrinking bar).
    pub fn total_waiting(&self) -> Time {
        self.partitions.iter().map(|p| p.waiting).sum()
    }

    /// Total communication-blocked time across partitions.
    pub fn total_comm_wait(&self) -> Time {
        self.partitions.iter().map(|p| p.comm_wait).sum()
    }

    /// Total WAN communication time across partitions (Fig 10's comm-time
    /// series: blocked time + serialization time).
    pub fn total_wan_time(&self) -> Time {
        self.partitions.iter().map(|p| p.wan_time).sum()
    }

    /// Waiting share of (waiting + execution) summed over partitions —
    /// the Fig 2 bar decomposition.
    pub fn waiting_share(&self) -> f64 {
        let total: f64 = self.partitions.len() as f64 * self.total_time;
        if total <= 0.0 {
            0.0
        } else {
            self.total_waiting() / total
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("strategy", Json::str(&self.strategy)),
            ("topology", Json::str(&self.topology)),
            ("sync_freq", Json::num(self.sync_freq as f64)),
            ("total_time_s", Json::num(self.total_time)),
            ("startup_time_s", Json::num(self.startup_time)),
            ("final_loss", Json::num(self.final_loss)),
            ("final_accuracy", Json::num(self.final_accuracy)),
            ("wan_bytes", Json::num(self.wan_bytes as f64)),
            ("wan_transfers", Json::num(self.wan_transfers as f64)),
            ("cost_usd", Json::num(self.cost)),
            ("compute_cost_usd", Json::num(self.compute_cost)),
            ("wan_cost_usd", Json::num(self.wan_cost)),
            ("egress_cost_usd", Json::num(self.egress_cost)),
            ("storage_cost_usd", Json::num(self.storage_cost)),
            ("restore_cost_usd", Json::num(self.restore_cost)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("spot_savings_usd", Json::num(self.spot_savings)),
            ("total_waiting_s", Json::num(self.total_waiting())),
            ("total_comm_wait_s", Json::num(self.total_comm_wait())),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("pjrt_executions", Json::num(self.pjrt_executions as f64)),
            (
                "partitions",
                Json::arr(self.partitions.iter().map(|p| {
                    Json::obj(vec![
                        ("region", Json::str(&p.region)),
                        ("units", Json::num(p.units as f64)),
                        ("power", Json::num(p.power)),
                        ("steps", Json::num(p.steps as f64)),
                        ("local_finish_s", Json::num(p.local_finish)),
                        ("waiting_s", Json::num(p.waiting)),
                        ("comm_wait_s", Json::num(p.comm_wait)),
                        ("wan_time_s", Json::num(p.wan_time)),
                        ("syncs_sent", Json::num(p.syncs_sent as f64)),
                        ("syncs_received", Json::num(p.syncs_received as f64)),
                        ("mean_staleness", Json::num(p.mean_staleness)),
                        ("cold_start_s", Json::num(p.cold_start_time)),
                    ])
                })),
            ),
            (
                "curve",
                Json::arr(self.curve.iter().map(|e| {
                    Json::obj(vec![
                        ("t", Json::num(e.t)),
                        ("epoch", Json::num(e.epoch as f64)),
                        ("loss", Json::num(e.loss)),
                        ("accuracy", Json::num(e.accuracy)),
                    ])
                })),
            ),
            (
                "replan_events",
                Json::arr(self.replan_events.iter().map(|e| {
                    Json::obj(vec![
                        ("t", Json::num(e.t)),
                        ("cause", Json::str(&e.cause)),
                        ("plan_delta", Json::num(e.plan_delta)),
                        ("straggler", Json::num(e.straggler as f64)),
                        (
                            "units",
                            Json::arr(e.units.iter().map(|u| Json::num(*u as f64))),
                        ),
                        ("topology_replanned", Json::Bool(e.topology_replanned)),
                        ("data_moves", Json::num(e.data_moves as f64)),
                        (
                            "compression_changes",
                            Json::arr(e.compression_changes.iter().map(|(f, t, c)| {
                                Json::arr(vec![
                                    Json::num(*f as f64),
                                    Json::num(*t as f64),
                                    Json::str(c),
                                ])
                            })),
                        ),
                    ])
                })),
            ),
            (
                "dataplane",
                match &self.dataplane {
                    None => Json::Null,
                    Some(d) => Json::obj(vec![
                        ("mode", Json::str(&d.mode)),
                        ("placement", Json::str(&d.placement)),
                        ("moved_shards", Json::num(d.moved_shards as f64)),
                        ("moved_bytes", Json::num(d.moved_bytes as f64)),
                        (
                            "replicas_created",
                            Json::arr(d.replicas_created.iter().map(|&(s, from, to)| {
                                Json::arr(
                                    [s, from, to].iter().map(|&v| Json::num(v as f64)),
                                )
                            })),
                        ),
                        ("rerouted_shards", Json::num(d.rerouted_shards as f64)),
                        ("failed_shards", Json::num(d.failed_shards as f64)),
                        ("egress_cost_usd", Json::num(d.egress_cost)),
                        ("storage_cost_usd", Json::num(d.storage_cost)),
                        ("stall_s", Json::num(d.stall_time)),
                        ("staging_done_s", Json::num(d.staging_done)),
                        ("rebalances", Json::num(d.rebalances as f64)),
                    ]),
                },
            ),
            (
                "federated",
                match &self.federated {
                    None => Json::Null,
                    Some(f) => Json::obj(vec![
                        ("clients", Json::num(f.clients as f64)),
                        ("cohorts", Json::num(f.cohorts as f64)),
                        ("sample_frac", Json::num(f.sample_frac)),
                        ("dropout", Json::num(f.dropout)),
                        ("rounds", Json::num(f.rounds as f64)),
                        ("participants", Json::num(f.participants as f64)),
                        ("dropouts", Json::num(f.dropouts as f64)),
                        ("uplink_bytes", Json::num(f.uplink_bytes as f64)),
                    ]),
                },
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let replans = if self.replan_events.is_empty() {
            String::new()
        } else {
            format!(" replans={}", self.replan_events.len())
        };
        let dataplane = match &self.dataplane {
            None => String::new(),
            Some(d) => format!(
                " data[{} moved={:.1}MB replicas={} stall={:.1}s]",
                d.mode,
                d.moved_bytes as f64 / 1e6,
                d.replicas_created.len(),
                d.stall_time
            ),
        };
        let federated = match &self.federated {
            None => String::new(),
            Some(f) => format!(
                " fed[{}c/{}coh rounds={} up={:.1}MB]",
                f.clients,
                f.cohorts,
                f.rounds,
                f.uplink_bytes as f64 / 1e6
            ),
        };
        let spot = if self.preemptions > 0 || self.spot_savings > 0.0 {
            format!(" spot[preempt={} saved=${:.4}]", self.preemptions, self.spot_savings)
        } else {
            String::new()
        };
        format!(
            "{} [{} f={}] time={:.1}s acc={:.4} loss={:.4} cost=${:.4} wan={:.1}MB wait={:.1}s comm={:.1}s{}{}{}{}",
            self.model,
            self.strategy,
            self.sync_freq,
            self.total_time,
            self.final_accuracy,
            self.final_loss,
            self.cost,
            self.wan_bytes as f64 / 1e6,
            self.total_waiting(),
            self.total_comm_wait(),
            replans,
            dataplane,
            federated,
            spot,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> TrainReport {
        TrainReport {
            model: "lenet".into(),
            strategy: "ASGD-GA".into(),
            sync_freq: 4,
            total_time: 100.0,
            partitions: vec![
                PartitionReport { waiting: 0.0, comm_wait: 5.0, ..Default::default() },
                PartitionReport { waiting: 30.0, comm_wait: 2.0, ..Default::default() },
            ],
            final_accuracy: 0.9,
            ..Default::default()
        }
    }

    #[test]
    fn aggregates() {
        let r = report();
        assert_eq!(r.total_waiting(), 30.0);
        assert_eq!(r.total_comm_wait(), 7.0);
        assert!((r.waiting_share() - 0.15).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrips() {
        let r = report();
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("model").as_str().unwrap(), "lenet");
        assert_eq!(parsed.get("partitions").as_arr().unwrap().len(), 2);
        assert!((parsed.get("total_waiting_s").as_f64().unwrap() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn cost_itemization_roundtrips() {
        let mut r = report();
        r.compute_cost = 1.25;
        r.wan_cost = 0.3;
        r.egress_cost = 0.08;
        r.storage_cost = 0.002;
        r.restore_cost = 0.015;
        r.cost = r.compute_cost + r.wan_cost + r.egress_cost + r.storage_cost + r.restore_cost;
        r.preemptions = 3;
        r.spot_savings = 0.4;
        let parsed = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        for (key, want) in [
            ("cost_usd", r.cost),
            ("compute_cost_usd", 1.25),
            ("wan_cost_usd", 0.3),
            ("egress_cost_usd", 0.08),
            ("storage_cost_usd", 0.002),
            ("restore_cost_usd", 0.015),
            ("preemptions", 3.0),
            ("spot_savings_usd", 0.4),
        ] {
            assert!(
                (parsed.get(key).as_f64().unwrap() - want).abs() < 1e-12,
                "{key}: {:?}",
                parsed.get(key)
            );
        }
        // The headline cost is exactly the sum of the itemized parts.
        let sum = ["compute_cost_usd", "wan_cost_usd", "egress_cost_usd", "storage_cost_usd", "restore_cost_usd"]
            .iter()
            .map(|k| parsed.get(k).as_f64().unwrap())
            .sum::<f64>();
        assert!((parsed.get("cost_usd").as_f64().unwrap() - sum).abs() < 1e-12);
        assert!(r.summary().contains("spot[preempt=3"));
        assert!(!report().summary().contains("spot["), "on-demand runs stay quiet");
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = report().summary();
        assert!(s.contains("lenet") && s.contains("ASGD-GA") && s.contains("f=4"));
    }

    #[test]
    fn federated_block_serializes_only_when_present() {
        let flat = report();
        let j = flat.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert!(matches!(parsed.get("federated"), Json::Null), "flat runs carry a null block");
        assert!(!flat.summary().contains("fed["));

        let mut fed = report();
        fed.federated = Some(FederatedReport {
            clients: 100_000,
            cohorts: 40,
            sample_frac: 0.1,
            dropout: 0.05,
            rounds: 160,
            participants: 15_200,
            dropouts: 800,
            uplink_bytes: 9_999,
        });
        let parsed = Json::parse(&fed.to_json().to_string_pretty()).unwrap();
        let block = parsed.get("federated");
        assert!((block.get("clients").as_f64().unwrap() - 100_000.0).abs() < 1e-9);
        assert!((block.get("rounds").as_f64().unwrap() - 160.0).abs() < 1e-9);
        assert!((block.get("uplink_bytes").as_f64().unwrap() - 9_999.0).abs() < 1e-9);
        assert!(fed.summary().contains("fed[100000c/40coh"));
    }
}

//! Compute-time calibration: bridges real PJRT step times to the virtual
//! clock's device model.
//!
//! The local CPU is defined to be the device catalog's baseline row
//! (IceLake, 2 cores, class power 1.0). A worker whose allocation share
//! has class power `p` then takes
//!
//! ```text
//! T_iter(model, worker) = base_step_s(model) / p
//! ```
//!
//! which is exactly the paper's `T_train ∝ S_data / C_device` at batch
//! granularity. `base_step_s` defaults to values measured on this image's
//! 1-core CPU PJRT (re-measure with [`measure_base_step`] / `--calibrate`
//! if the artifacts or host change).

use crate::data::Dataset;
use crate::runtime::ModelRuntime;

/// **Virtual** base step seconds per model — calibrated to the *paper's*
/// testbed, not to this host's wall clock.
///
/// The figures depend on the ratio of WAN send cost (setup + payload
/// serialization + ack RTT) to compute time per iteration. The paper's
/// workloads (Table III payloads 0.4 / 0.6 / 2.4 MB at 100 Mbps; Fig 10
/// speedups 1.2x / 1.2x / 1.7x over 10 / 50 / 20 epochs) pin
/// baseline-device iteration times of ~0.25 s (LeNet), ~0.5 s
/// (ResNet-lite) and ~0.15 s (DeepFM): these place the freq-1 send-slot
/// utilization at ~0.8 / ~1.4 / ~4.5, reproducing the paper's speedup
/// ordering and magnitudes (DeepFM most comm-bound). The transformer
/// runs at its *measured* local step time (the e2e example reports
/// honest wall numbers). See EXPERIMENTS.md §Calibration for the log.
pub fn default_base_step_s(model: &str) -> f64 {
    match model {
        "lenet" => 0.25,
        "resnet" => 0.5,
        "deepfm" => 0.15,
        "transformer" => 1.2,
        "transformer100m" => 30.0,
        // The artifact-free CI model: LeNet-like timing so smoke runs
        // exercise the same WAN/compute regime.
        "synthetic" => 0.25,
        _ => 0.5,
    }
}

/// Step seconds *measured* on this image's 1-core CPU PJRT (wall-clock
/// planning + the §Calibration record; not used by the virtual clock).
pub fn measured_step_s(model: &str) -> f64 {
    match model {
        "lenet" => 0.014,
        "resnet" => 0.13,
        "deepfm" => 0.006,
        "transformer" => 1.2,
        _ => 0.1,
    }
}

/// Time one real train step (median of `reps`) for calibration.
pub fn measure_base_step(rt: &ModelRuntime, ds: &Dataset, reps: usize) -> anyhow::Result<f64> {
    let idxs: Vec<usize> = (0..rt.meta.batch_size).collect();
    let (x, y) = ds.batch(&idxs, &rt.meta);
    let params = rt.init_params.clone();
    // warmup
    rt.train_step(&params, &x, &y)?;
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        rt.train_step(&params, &x, &y)?;
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[times.len() / 2])
}

/// Worker-level iteration time for a worker owning `power` class-power.
pub fn iter_time(base_step_s: f64, power: f64) -> f64 {
    assert!(power > 0.0, "worker with zero compute power");
    base_step_s / power
}

/// Split an allocation's power across `n` worker functions.
pub fn worker_power(total_power: f64, n_workers: usize) -> f64 {
    total_power / n_workers.max(1) as f64
}

/// How many worker functions a partition deploys: one per `worker_cores`
/// CPU cores (GPUs get one worker per device). Mirrors ElasticDL's
/// pod-per-worker deployment granularity.
pub fn worker_count(total_units: u32, is_gpu: bool, worker_cores: u32) -> usize {
    if is_gpu {
        total_units.max(1) as usize
    } else {
        (total_units / worker_cores.max(1)).clamp(1, 8) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iter_time_inverse_in_power() {
        let t1 = iter_time(0.1, 1.0);
        let t2 = iter_time(0.1, 2.0);
        assert!((t1 / t2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn worker_split_preserves_throughput() {
        // throughput = n * (power/n) / base = power / base, invariant.
        let base = 0.1;
        for n in [1usize, 2, 4, 6] {
            let p = worker_power(4.0, n);
            let throughput = n as f64 / iter_time(base, p);
            assert!((throughput - 40.0).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn worker_counts() {
        assert_eq!(worker_count(12, false, 3), 4);
        assert_eq!(worker_count(8, false, 3), 2);
        assert_eq!(worker_count(2, false, 3), 1);
        assert_eq!(worker_count(40, false, 3), 8); // capped
        assert_eq!(worker_count(4, true, 3), 4); // one per GPU
    }

    #[test]
    fn defaults_positive() {
        for m in ["lenet", "resnet", "deepfm", "transformer", "unknown"] {
            assert!(default_base_step_s(m) > 0.0);
        }
    }
}

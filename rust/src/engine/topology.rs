//! Sync-topology planning — generalizes the paper's §III.C "each PS sends
//! its state to exactly one other PS" rule to pluggable N-cloud shapes.
//!
//! The paper evaluates on a fixed two-cloud pair, where "the topology" is
//! just a pairwise exchange with a hardcoded 0.5 averaging weight. This
//! layer makes topology a first-class axis: a [`Topology`] produces a
//! [`SyncPlan`] — per-partition outgoing edges, each carrying the
//! averaging weight the *receiver* applies to the incoming model — so the
//! engine's communicator ([`super::comm`]) never special-cases the region
//! count.
//!
//! Three shapes are provided:
//!
//! - [`Ring`] — the seed behavior: every region sends to `(i+1) % n`; a
//!   pairwise exchange for 2 clouds (bit-identical to the pre-engine
//!   `run_geo_training`), a ring beyond that.
//! - [`Hierarchical`] — HiPS-style (GeoMX) two-stage aggregation: every
//!   leaf syncs to a hub region which averages and fans back out. The hub
//!   defaults to the region with the highest aggregate outgoing WAN
//!   bandwidth.
//! - [`BandwidthTree`] — a greedy maximum-bandwidth spanning tree over the
//!   [`Fabric`] link specs (network-aware aggregation trees, arXiv
//!   2404.11352): payloads travel both directions along tree edges, so
//!   slow links are bypassed entirely.
//!
//! **Averaging weights (Metropolis).** Each directed edge `u -> v`
//! carries the Metropolis–Hastings weight `1/(1 + max(deg(u), deg(v)))`,
//! where `deg` is the node's degree in the plan's *undirected support*.
//! The synchronous per-round mixing matrix this induces is symmetric and
//! doubly stochastic, so averaging preserves the fleet-wide mean model —
//! hub-style topologies no longer concentrate "hub authority" the way the
//! earlier in-degree `1/(in+1)` weights did (see ROADMAP history). For
//! two clouds the formula reduces to the paper's 0.5/0.5 average, and for
//! any `N` consensus (all models equal) is a fixed point, which is what
//! the paper's model-correctness guarantee rests on.
//!
//! Payloads still *apply* sequentially on arrival. A naive sequential
//! apply of weight `w` payloads discounts early arrivals by the residual
//! factors of later ones; [`sequential_weight`] compensates by up-scaling
//! the j-th applied payload to `w / (1 - remaining)` (where `remaining`
//! is the incoming weight not yet applied since the receiver's last
//! snapshot), which telescopes to the *exact* synchronous Metropolis row
//! regardless of arrival order. The communicator applies the
//! compensation on the synchronous (SMA barrier) path only — its
//! full-round premise does not hold for asynchronous AMA, which uses raw
//! Metropolis weights — and `tests/ncloud_averaging.rs` pins the
//! measured consequences.
//!
//! Weights apply to model-averaging payloads (AMA/SMA). Gradient
//! strategies (ASGD/ASGD-GA) ship only the sender's local accumulated
//! gradient one hop — peers beyond a hop are influenced through the
//! receiver's updated parameters, as in the paper's two-cloud design —
//! so AMA/SMA are the primary strategies for fan-in topologies.
//!
//! **The federated edge tier lives *below* this layer.** When a job runs
//! with a `"federated"` block, each cloud partition becomes a composite
//! whose edge cohorts aggregate locally into the cloud's PS (HiPS stage
//! 1, `start_cohort_round` in the driver) before the cloud joins the
//! WAN exchange planned here (stage 2). The WAN planner deliberately sees
//! only the cloud roots: a cohort tree is a *leaf* of whatever ring /
//! hierarchical / bandwidth-tree shape is configured, never a node in it,
//! so `n` stays the region count and the Metropolis mixing analysis above
//! is untouched by millions of clients. [`edge_fan_in`] exposes the
//! resulting per-cloud fan-in so capacity planning can size aggregator
//! pools without consulting the engine.

use crate::net::{Fabric, RegionId};

/// One directed sync edge: when `from` syncs, it ships its payload to
/// `to`, and `to` averages it in with weight `weight` (model-averaging
/// strategies; gradient strategies apply the payload via SGD instead).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanEdge {
    pub from: RegionId,
    pub to: RegionId,
    /// The remote-model weight applied at the receiver — the Metropolis
    /// weight `1/(1 + max(deg(from), deg(to)))` over the plan's
    /// undirected support.
    pub weight: f32,
}

/// A planned sync topology over `n` partitions: for every partition, the
/// edges it sends on whenever its sync condition fires.
#[derive(Debug, Clone)]
pub struct SyncPlan {
    n: usize,
    outgoing: Vec<Vec<PlanEdge>>,
    /// Auxiliary 2-hop relay routes: `(from, to, via)` — the
    /// communicator ships `from -> via -> to` (store-and-forward) instead
    /// of the direct thin link. Planned by [`BandwidthTree`] with
    /// `relay: true`; empty otherwise.
    relays: Vec<(RegionId, RegionId, RegionId)>,
}

impl SyncPlan {
    /// Build a plan from raw directed edges, deriving each edge's weight
    /// by the Metropolis rule: `weight = 1/(1 + max(deg(from), deg(to)))`
    /// over the undirected support (so symmetric edge pairs carry equal
    /// weight and the synchronous mixing matrix is doubly stochastic).
    ///
    /// Panics on self-loops, out-of-range endpoints, or duplicate edges —
    /// a topology that plans those is a bug, not an input error.
    pub fn from_directed_edges(n: usize, edges: &[(RegionId, RegionId)]) -> SyncPlan {
        assert!(n >= 1, "a plan needs at least one partition");
        let mut support: Vec<(RegionId, RegionId)> = Vec::new();
        for &(from, to) in edges {
            assert!(from < n && to < n, "edge ({from},{to}) out of range for n={n}");
            assert_ne!(from, to, "self-loop at {from}");
            support.push((from.min(to), from.max(to)));
        }
        support.sort_unstable();
        support.dedup();
        let mut degree = vec![0usize; n];
        for &(a, b) in &support {
            degree[a] += 1;
            degree[b] += 1;
        }
        let mut outgoing: Vec<Vec<PlanEdge>> = vec![Vec::new(); n];
        for &(from, to) in edges {
            let weight = 1.0 / (1.0 + degree[from].max(degree[to]) as f32);
            assert!(
                !outgoing[from].iter().any(|e| e.to == to),
                "duplicate edge ({from},{to})"
            );
            outgoing[from].push(PlanEdge { from, to, weight });
        }
        SyncPlan { n, outgoing, relays: Vec::new() }
    }

    /// Attach auxiliary 2-hop relay routes (`(from, to, via)` triples).
    /// Routes whose endpoints are not plan edges are harmless — the
    /// communicator only consults [`SyncPlan::relay_via`] for edges it
    /// actually ships on.
    pub fn with_relays(mut self, relays: Vec<(RegionId, RegionId, RegionId)>) -> SyncPlan {
        self.relays = relays;
        self
    }

    /// The relay region for `from -> to`, if the plan routes that edge
    /// around its thin direct link.
    pub fn relay_via(&self, from: RegionId, to: RegionId) -> Option<RegionId> {
        self.relays.iter().find(|(f, t, _)| *f == from && *t == to).map(|(_, _, via)| *via)
    }

    /// Every planned relay route (`(from, to, via)`), in plan order.
    pub fn relays(&self) -> &[(RegionId, RegionId, RegionId)] {
        &self.relays
    }

    /// Degree of partition `i` in the plan's undirected support — the
    /// `deg` the Metropolis weights are derived from.
    pub fn support_degree(&self, i: RegionId) -> usize {
        self.undirected_support().iter().filter(|(a, b)| *a == i || *b == i).count()
    }

    /// Total incoming Metropolis weight at partition `i` (always < 1, so
    /// the receiver's local share stays positive). The communicator needs
    /// this for [`sequential_weight`] compensation.
    pub fn incoming_weight(&self, i: RegionId) -> f32 {
        self.edges().filter(|e| e.to == i).map(|e| e.weight).sum()
    }

    /// Number of partitions the plan covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The edges partition `i` sends on when it syncs.
    pub fn outgoing(&self, i: RegionId) -> &[PlanEdge] {
        &self.outgoing[i]
    }

    /// Number of distinct senders shipping into partition `i`.
    pub fn in_degree(&self, i: RegionId) -> usize {
        self.outgoing
            .iter()
            .map(|es| es.iter().filter(|e| e.to == i).count())
            .sum()
    }

    /// Every directed edge in the plan, in deterministic order.
    pub fn edges(&self) -> impl Iterator<Item = &PlanEdge> {
        self.outgoing.iter().flatten()
    }

    /// The undirected support of the plan: normalized `(min, max)` pairs.
    pub fn undirected_support(&self) -> Vec<(RegionId, RegionId)> {
        let mut pairs: Vec<(RegionId, RegionId)> = self
            .edges()
            .map(|e| (e.from.min(e.to), e.from.max(e.to)))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        pairs
    }

    /// True when every partition can reach every other over the undirected
    /// support (payloads eventually mix every region's model).
    pub fn is_connected(&self) -> bool {
        if self.n <= 1 {
            return true;
        }
        let mut adj: Vec<Vec<RegionId>> = vec![Vec::new(); self.n];
        for (a, b) in self.undirected_support() {
            adj[a].push(b);
            adj[b].push(a);
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &u in &adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    stack.push(u);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }

    /// True when the undirected support is a spanning tree (connected and
    /// acyclic) — the invariant for [`Hierarchical`] and [`BandwidthTree`].
    pub fn is_tree(&self) -> bool {
        self.is_connected() && self.undirected_support().len() == self.n.saturating_sub(1)
    }
}

/// Effective weight for applying one model-averaging payload
/// *sequentially* such that, once every planned incoming payload since
/// the receiver's last snapshot has landed, the combined mix equals the
/// synchronous Metropolis row exactly — independent of arrival order.
///
/// `edge_weight` is the payload's planned (synchronous) weight,
/// `incoming_total` the receiver's total planned incoming weight
/// ([`SyncPlan::incoming_weight`]), and `applied` the planned weight of
/// payloads already applied since the receiver's last snapshot. The j-th
/// applied payload gets `w / (1 - remaining_after_it)`, which telescopes:
/// residual local mass after all `d` applies is `1 - incoming_total` and
/// every payload lands at exactly its planned weight.
///
/// Degenerate cases (payloads beyond plan expectations — async pile-ups,
/// re-sent syncs) clamp to the raw edge weight, which degrades gracefully
/// toward the uncompensated behavior instead of over-weighting.
pub fn sequential_weight(edge_weight: f32, incoming_total: f32, applied: f32) -> f32 {
    let remaining_after = (incoming_total - applied - edge_weight).max(0.0);
    let denom = 1.0 - remaining_after;
    if denom <= edge_weight {
        return edge_weight.min(1.0);
    }
    (edge_weight / denom).clamp(edge_weight, 1.0)
}

/// Per-cloud fan-in of the federated edge tier hanging below one WAN
/// leaf: `(clients per cohort uplink, cohort uplinks into the cloud PS)`.
///
/// A cloud hosting `clients` edge clients carved into `cohorts` pools
/// aggregates in two hops: each cohort round collapses its clients into
/// one uplink (HiPS stage 1), and the cloud PS absorbs one uplink per
/// cohort before the WAN sync ships a single payload upward (stage 2).
/// The WAN plan's `n` never grows — this helper is how callers reason
/// about the invisible tier. Zero `clients` or `cohorts` means the cloud
/// is flat: `(0, 0)`.
pub fn edge_fan_in(clients: u64, cohorts: usize) -> (u64, usize) {
    if clients == 0 || cohorts == 0 {
        return (0, 0);
    }
    // Cohorts never sit empty: carving clamps the pool count to the
    // client population (see `driver::build_cohorts`).
    let k = cohorts.min(clients as usize).max(1);
    (clients.div_ceil(k as u64), k)
}

/// A pluggable sync-topology strategy: given the partition count and the
/// WAN fabric, plan who sends to whom with what averaging weight.
pub trait Topology {
    /// Stable name (CLI / config / checkpoint metadata).
    fn name(&self) -> &'static str;
    /// Plan the per-sync edges over `n` partitions.
    fn plan(&self, n: usize, fabric: &Fabric) -> SyncPlan;
}

/// Symmetric nominal bandwidth between two regions (0 when no link is
/// installed in either direction) — the metric the bandwidth-aware
/// topologies optimize.
fn pair_bandwidth(fabric: &Fabric, a: RegionId, b: RegionId) -> f64 {
    let fwd = fabric.link_bandwidth(a, b).unwrap_or(0.0);
    let rev = fabric.link_bandwidth(b, a).unwrap_or(0.0);
    (fwd + rev) / 2.0
}

/// Best auxiliary 2-hop relay route between `a` and `b`: the relay `r`
/// maximizing the store-and-forward effective bandwidth
/// `1 / (1/bw(a,r) + 1/bw(r,b))` (each hop fully re-serializes the
/// payload, so the route's rate is the harmonic combination, never better
/// than its thinner hop). Returns `Some((via, effective_bw))` only when
/// the route strictly beats the direct edge's bandwidth — thin-link
/// bypass, not a free alternative. Ties break toward the lowest relay
/// index for deterministic planning.
pub fn relay_route(
    fabric: &Fabric,
    n: usize,
    a: RegionId,
    b: RegionId,
) -> Option<(RegionId, f64)> {
    let direct = pair_bandwidth(fabric, a, b);
    let mut best: Option<(RegionId, f64)> = None;
    for r in 0..n {
        if r == a || r == b {
            continue;
        }
        let (h1, h2) = (pair_bandwidth(fabric, a, r), pair_bandwidth(fabric, r, b));
        if h1 <= 0.0 || h2 <= 0.0 {
            continue;
        }
        let eff = 1.0 / (1.0 / h1 + 1.0 / h2);
        if eff > direct && best.map_or(true, |(_, be)| eff > be) {
            best = Some((r, eff));
        }
    }
    best
}

/// Region with the largest aggregate bandwidth to all others (ties break
/// toward the lowest index, so planning is deterministic).
fn best_connected(n: usize, fabric: &Fabric) -> RegionId {
    let mut best = 0usize;
    let mut best_sum = f64::MIN;
    for i in 0..n {
        let sum: f64 = (0..n).filter(|&j| j != i).map(|j| pair_bandwidth(fabric, i, j)).sum();
        if sum > best_sum {
            best_sum = sum;
            best = i;
        }
    }
    best
}

/// The seed topology: partition `i` sends to `(i+1) % n`. A pairwise
/// exchange at `n = 2` (the paper's exact setting), a ring beyond that.
#[derive(Debug, Clone, Copy, Default)]
pub struct Ring;

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn plan(&self, n: usize, _fabric: &Fabric) -> SyncPlan {
        assert!(n >= 1);
        let edges: Vec<(RegionId, RegionId)> =
            if n == 1 { Vec::new() } else { (0..n).map(|i| (i, (i + 1) % n)).collect() };
        SyncPlan::from_directed_edges(n, &edges)
    }
}

/// HiPS-style hierarchical aggregation (GeoMX): leaves sync to a hub
/// region which averages and fans back out on its own sync cadence. With
/// Metropolis weights every star edge carries `1/n` in both directions
/// (hub degree `n-1`), so the hub's model no longer dominates the leaves
/// the way the old `1/2` hub-to-leaf weight did; combined with
/// [`sequential_weight`] compensation the per-round mix is exactly the
/// doubly-stochastic star matrix.
#[derive(Debug, Clone, Copy, Default)]
pub struct Hierarchical {
    /// Fixed hub region; `None` picks the best-connected region.
    pub hub: Option<RegionId>,
}

impl Topology for Hierarchical {
    fn name(&self) -> &'static str {
        "hierarchical"
    }

    fn plan(&self, n: usize, fabric: &Fabric) -> SyncPlan {
        assert!(n >= 1);
        let hub = match self.hub {
            Some(h) => {
                assert!(h < n, "hub {h} out of range for n={n}");
                h
            }
            None => best_connected(n, fabric),
        };
        let mut edges = Vec::new();
        for leaf in 0..n {
            if leaf != hub {
                edges.push((leaf, hub));
                edges.push((hub, leaf));
            }
        }
        SyncPlan::from_directed_edges(n, &edges)
    }
}

/// Network-aware aggregation tree: a greedy maximum-bandwidth spanning
/// tree (Prim) over the fabric's link specs, rooted at the best-connected
/// region. Payloads travel both directions along every tree edge, so the
/// slowest links carry no sync traffic at all.
///
/// With `relay: true`, every candidate pair is additionally scored at its
/// best auxiliary 2-hop route ([`relay_route`]): a pair whose direct link
/// is thin but which can store-and-forward through a well-connected relay
/// competes at the route's effective bandwidth, and when such an edge is
/// selected the plan records the route so the communicator ships both
/// hops instead of the thin link.
#[derive(Debug, Clone, Copy, Default)]
pub struct BandwidthTree {
    /// Consider auxiliary 2-hop relay routes as candidate edges.
    pub relay: bool,
}

impl Topology for BandwidthTree {
    fn name(&self) -> &'static str {
        "bandwidth-tree"
    }

    fn plan(&self, n: usize, fabric: &Fabric) -> SyncPlan {
        assert!(n >= 1);
        if n == 1 {
            return SyncPlan::from_directed_edges(1, &[]);
        }
        let root = best_connected(n, fabric);
        // Prim's algorithm, maximizing bandwidth of the connecting edge
        // (direct, or its best relay route when enabled).
        let mut in_tree = vec![false; n];
        in_tree[root] = true;
        let mut tree_pairs: Vec<(RegionId, RegionId)> = Vec::new();
        let mut relays: Vec<(RegionId, RegionId, RegionId)> = Vec::new();
        for _ in 1..n {
            // (effective bw, tree node, new node, relay)
            let mut best: Option<(f64, RegionId, RegionId, Option<RegionId>)> = None;
            for u in 0..n {
                if !in_tree[u] {
                    continue;
                }
                for v in 0..n {
                    if in_tree[v] {
                        continue;
                    }
                    let direct = pair_bandwidth(fabric, u, v);
                    let relay = if self.relay { relay_route(fabric, n, u, v) } else { None };
                    let (bw, via) = match relay {
                        Some((r, eff)) => (eff, Some(r)),
                        None => (direct, None),
                    };
                    let better = match best {
                        None => true,
                        // Strict > keeps ties at the earliest (u, v) in scan
                        // order — deterministic planning.
                        Some((bb, _, _, _)) => bw > bb,
                    };
                    if better {
                        best = Some((bw, u, v, via));
                    }
                }
            }
            let (_, u, v, via) = best.expect("n >= 2 leaves a node to attach");
            in_tree[v] = true;
            tree_pairs.push((u, v));
            if let Some(r) = via {
                relays.push((u, v, r));
                relays.push((v, u, r));
            }
        }
        let mut edges = Vec::new();
        for (u, v) in tree_pairs {
            edges.push((u, v));
            edges.push((v, u));
        }
        SyncPlan::from_directed_edges(n, &edges).with_relays(relays)
    }
}

/// Topology selector for configs, the CLI, and checkpoint metadata.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    Ring,
    Hierarchical,
    BandwidthTree,
}

impl TopologyKind {
    /// Parse a topology name; the error lists every valid name.
    pub fn from_name(s: &str) -> Result<TopologyKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Ok(TopologyKind::Ring),
            "hierarchical" | "hier" | "hips" | "star" => Ok(TopologyKind::Hierarchical),
            "bandwidth-tree" | "bwtree" | "tree" => Ok(TopologyKind::BandwidthTree),
            other => Err(format!(
                "unknown topology {other:?} (valid: ring, hierarchical, bandwidth-tree)"
            )),
        }
    }

    /// Stable name (inverse of [`TopologyKind::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Hierarchical => "hierarchical",
            TopologyKind::BandwidthTree => "bandwidth-tree",
        }
    }

    /// Instantiate the topology strategy.
    pub fn build(&self) -> Box<dyn Topology> {
        match self {
            TopologyKind::Ring => Box::new(Ring),
            TopologyKind::Hierarchical => Box::new(Hierarchical::default()),
            TopologyKind::BandwidthTree => Box::new(BandwidthTree::default()),
        }
    }

    /// Plan edges over `n` partitions against the given fabric.
    pub fn plan(&self, n: usize, fabric: &Fabric) -> SyncPlan {
        self.plan_with(n, fabric, false)
    }

    /// Plan edges, optionally with auxiliary 2-hop relay routes around
    /// thin links (`--relay-routes`): the bandwidth-tree planner scores
    /// relay routes as extra candidate edges, and every planned directed
    /// edge — whatever the shape — gets a recorded relay when a 2-hop
    /// route strictly beats its direct link ([`relay_route`]). On a
    /// max-bandwidth spanning tree this post-pass is provably vacuous
    /// (each tree edge was selected over both hops of any candidate
    /// relay), so relays fire mainly for fixed-shape plans (a ring edge
    /// across the thin long haul, a star leaf far from the hub).
    pub fn plan_with(&self, n: usize, fabric: &Fabric, relay: bool) -> SyncPlan {
        let plan = match self {
            TopologyKind::BandwidthTree => BandwidthTree { relay }.plan(n, fabric),
            _ => self.build().plan(n, fabric),
        };
        if !relay {
            return plan;
        }
        let mut relays = plan.relays().to_vec();
        let edges: Vec<(RegionId, RegionId)> =
            plan.edges().map(|e| (e.from, e.to)).collect();
        for (from, to) in edges {
            if plan.relay_via(from, to).is_none() {
                if let Some((via, _)) = relay_route(fabric, n, from, to) {
                    relays.push((from, to, via));
                }
            }
        }
        plan.with_relays(relays)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;

    fn uniform_fabric(n: usize) -> Fabric {
        let mut f = Fabric::new(7);
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    f.add_link(a, b, LinkSpec::wan_100mbps());
                }
            }
        }
        f
    }

    fn wan_at(mbps: f64) -> LinkSpec {
        LinkSpec { bandwidth_bps: mbps * 1e6, ..LinkSpec::wan_100mbps() }
    }

    #[test]
    fn ring_matches_seed_behavior() {
        let f = uniform_fabric(4);
        let plan = Ring.plan(4, &f);
        for i in 0..4 {
            let out = plan.outgoing(i);
            assert_eq!(out.len(), 1, "ring: one outgoing edge per region");
            assert_eq!(out[0].to, (i + 1) % 4);
            // Ring support degree is 2 everywhere -> Metropolis 1/3.
            assert!((out[0].weight - 1.0 / 3.0).abs() < 1e-6, "{}", out[0].weight);
        }
        assert!(plan.is_connected());
    }

    #[test]
    fn two_cloud_ring_is_pairwise_exchange() {
        let f = uniform_fabric(2);
        let plan = Ring.plan(2, &f);
        assert_eq!(plan.outgoing(0)[0].to, 1);
        assert_eq!(plan.outgoing(1)[0].to, 0);
        // The paper's hardcoded 0.5 falls out of the Metropolis rule
        // (both endpoints have support degree 1) — seed parity holds.
        assert_eq!(plan.outgoing(0)[0].weight, 0.5);
        assert_eq!(plan.outgoing(1)[0].weight, 0.5);
    }

    #[test]
    fn single_partition_plans_no_edges() {
        let f = uniform_fabric(1);
        for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
            let plan = kind.plan(1, &f);
            assert_eq!(plan.edges().count(), 0, "{kind:?}");
            assert!(plan.is_connected());
        }
    }

    #[test]
    fn hierarchical_is_a_star_with_metropolis_weights() {
        let f = uniform_fabric(5);
        let plan = Hierarchical { hub: Some(2) }.plan(5, &f);
        assert!(plan.is_tree());
        assert_eq!(plan.in_degree(2), 4, "hub receives from every leaf");
        assert_eq!(plan.support_degree(2), 4);
        for leaf in [0usize, 1, 3, 4] {
            assert_eq!(plan.outgoing(leaf).len(), 1);
            assert_eq!(plan.outgoing(leaf)[0].to, 2);
            assert!((plan.outgoing(leaf)[0].weight - 0.2).abs() < 1e-6, "1/(1+max(4,1))");
            assert_eq!(plan.in_degree(leaf), 1);
            assert_eq!(plan.support_degree(leaf), 1);
        }
        // Hub fans back out at the SAME 1/5: symmetric Metropolis edges,
        // no more hub-authority 1/2.
        assert_eq!(plan.outgoing(2).len(), 4);
        assert!(plan.outgoing(2).iter().all(|e| (e.weight - 0.2).abs() < 1e-6));
        // Incoming mass stays below 1 everywhere.
        for r in 0..5 {
            assert!(plan.incoming_weight(r) < 1.0);
        }
        assert!((plan.incoming_weight(2) - 0.8).abs() < 1e-6);
    }

    #[test]
    fn sequential_weights_telescope_to_the_synchronous_row() {
        // Star hub with 3 incoming edges at 1/4 each: the applied
        // sequence must be 1/2, 1/3, 1/4 so every payload ends at 1/4.
        let w = 0.25f32;
        let w_in = 0.75f32;
        let mut applied = 0.0f32;
        let mut local = 1.0f32; // residual local coefficient
        let mut coeffs = Vec::new();
        for expect in [0.5f32, 1.0 / 3.0, 0.25] {
            let eff = sequential_weight(w, w_in, applied);
            assert!((eff - expect).abs() < 1e-6, "{eff} vs {expect}");
            for c in &mut coeffs {
                *c *= 1.0 - eff;
            }
            local *= 1.0 - eff;
            coeffs.push(eff);
            applied += w;
        }
        for c in &coeffs {
            assert!((c - w).abs() < 1e-6, "payload coefficient {c} != planned {w}");
        }
        assert!((local - 0.25).abs() < 1e-6, "local residual = 1 - incoming_total");
        // Past-plan payloads degrade to the raw edge weight.
        assert_eq!(sequential_weight(w, w_in, 0.75), w);
        // Single-edge receivers are uncompensated.
        assert_eq!(sequential_weight(0.5, 0.5, 0.0), 0.5);
    }

    #[test]
    fn hierarchical_auto_hub_prefers_bandwidth() {
        // Region 1 has fat pipes to everyone; it should be chosen as hub.
        let mut f = Fabric::new(1);
        for a in 0..4usize {
            for b in 0..4usize {
                if a != b {
                    let spec = if a == 1 || b == 1 { wan_at(500.0) } else { wan_at(50.0) };
                    f.add_link(a, b, spec);
                }
            }
        }
        let plan = Hierarchical::default().plan(4, &f);
        assert_eq!(plan.in_degree(1), 3, "best-connected region becomes the hub");
    }

    #[test]
    fn bandwidth_tree_avoids_slow_links() {
        // Chain of fat links 0-1-2-3; every other pair is thin. The max
        // spanning tree must be exactly the chain.
        let mut f = Fabric::new(1);
        for a in 0..4usize {
            for b in 0..4usize {
                if a != b {
                    let fat = matches!(
                        (a.min(b), a.max(b)),
                        (0, 1) | (1, 2) | (2, 3)
                    );
                    f.add_link(a, b, if fat { wan_at(400.0) } else { wan_at(10.0) });
                }
            }
        }
        let plan = BandwidthTree::default().plan(4, &f);
        assert!(plan.is_tree());
        assert_eq!(plan.undirected_support(), vec![(0, 1), (1, 2), (2, 3)]);
        assert!(plan.relays().is_empty(), "relay routes are opt-in");
    }

    #[test]
    fn relay_route_only_when_it_beats_the_direct_edge() {
        // 2<->3 direct is 40 Mbps; both reach the Shanghai-like hub 0 at
        // 300 Mbps, so the 2-hop route runs at harmonic 150 Mbps > 40.
        let mut f = Fabric::new(1);
        for a in 0..4usize {
            for b in 0..4usize {
                if a != b {
                    let mbps = match (a.min(b), a.max(b)) {
                        (0, _) => 300.0,
                        (2, 3) => 40.0,
                        _ => 100.0,
                    };
                    f.add_link(a, b, wan_at(mbps));
                }
            }
        }
        let (via, eff) = relay_route(&f, 4, 2, 3).expect("relay beats the thin direct link");
        assert_eq!(via, 0);
        assert!((eff - 150e6).abs() < 1.0, "harmonic of two 300 Mbps hops: {eff}");
        // A fat direct edge is never displaced: the best 2-hop route
        // through 300 Mbps pipes tops out at 150 Mbps < 300 direct.
        assert_eq!(relay_route(&f, 4, 0, 1), None);
        // Symmetric query plans the same relay.
        assert_eq!(relay_route(&f, 4, 3, 2).map(|(r, _)| r), Some(0));
    }

    #[test]
    fn relay_routes_fire_for_fixed_shapes_and_stay_vacuous_on_the_tree() {
        // The thin-GZ testbed: fat 300 Mbps star around 0, a 40 Mbps
        // 2<->3 long haul, 100 Mbps elsewhere.
        let mut f = Fabric::new(1);
        for a in 0..4usize {
            for b in 0..4usize {
                if a != b {
                    let mbps = match (a.min(b), a.max(b)) {
                        (0, _) => 300.0,
                        (2, 3) => 40.0,
                        _ => 100.0,
                    };
                    f.add_link(a, b, wan_at(mbps));
                }
            }
        }
        // Ring must ship 2 -> 3 across the thin haul; with relays on it
        // routes through the hub instead.
        let ring = TopologyKind::Ring.plan_with(4, &f, true);
        assert_eq!(ring.relay_via(2, 3), Some(0), "{:?}", ring.relays());
        // Relays never appear unless asked for.
        assert!(TopologyKind::Ring.plan(4, &f).relays().is_empty());
        // A recorded route always strictly beats its direct edge.
        for &(from, to, via) in ring.relays() {
            let direct = f.link_bandwidth(from, to).unwrap();
            let (r, eff) = relay_route(&f, 4, from, to).unwrap();
            assert_eq!(r, via);
            assert!(eff > direct, "relay {from}->{via}->{to}: {eff} vs {direct}");
        }
        // The max-bandwidth tree already routed around the thin haul, so
        // every tree edge beats any 2-hop route: no relays recorded.
        let tree = TopologyKind::BandwidthTree.plan_with(4, &f, true);
        assert!(tree.is_tree());
        assert!(tree.relays().is_empty(), "{:?}", tree.relays());
    }

    #[test]
    fn weights_follow_metropolis_rule_everywhere() {
        let f = uniform_fabric(6);
        for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
            let plan = kind.plan(6, &f);
            for e in plan.edges() {
                let d = plan.support_degree(e.from).max(plan.support_degree(e.to)) as f32;
                assert!(
                    (e.weight - 1.0 / (d + 1.0)).abs() < 1e-6,
                    "{kind:?}: edge ({},{}) weight {} vs max support degree {d}",
                    e.from,
                    e.to,
                    e.weight
                );
                // Symmetric edge pairs carry equal weight.
                if let Some(rev) = plan.outgoing(e.to).iter().find(|r| r.to == e.from) {
                    assert_eq!(rev.weight, e.weight, "{kind:?}: asymmetric pair");
                }
            }
            for r in 0..6 {
                assert!(plan.incoming_weight(r) < 1.0, "{kind:?}: receiver {r} oversubscribed");
            }
        }
    }

    #[test]
    fn kind_names_roundtrip() {
        for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
            assert_eq!(TopologyKind::from_name(kind.name()), Ok(kind));
        }
        assert_eq!(TopologyKind::from_name("hips"), Ok(TopologyKind::Hierarchical));
        assert_eq!(TopologyKind::from_name("tree"), Ok(TopologyKind::BandwidthTree));
        let err = TopologyKind::from_name("mesh").unwrap_err();
        assert!(err.contains("ring") && err.contains("hierarchical"), "{err}");
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn self_loops_rejected() {
        SyncPlan::from_directed_edges(3, &[(0, 0)]);
    }

    #[test]
    fn edge_fan_in_keeps_the_wan_plan_at_cloud_granularity() {
        // Flat clouds contribute nothing below the leaf.
        assert_eq!(edge_fan_in(0, 8), (0, 0));
        assert_eq!(edge_fan_in(1000, 0), (0, 0));
        // 100k clients over 40 cohorts: 2500 clients per uplink, 40
        // uplinks into the cloud PS — and the WAN plan never sees them.
        assert_eq!(edge_fan_in(100_000, 40), (2_500, 40));
        // Ragged split rounds the per-cohort population up.
        assert_eq!(edge_fan_in(10, 3), (4, 3));
        // More pools than clients clamps to one client per cohort.
        assert_eq!(edge_fan_in(3, 16), (1, 3));
        // However many clients hang below, a 4-cloud job still plans 4
        // WAN nodes.
        let f = uniform_fabric(4);
        for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
            assert_eq!(kind.plan(4, &f).n(), 4, "{kind:?}");
        }
    }
}

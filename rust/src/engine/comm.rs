//! The WAN communicator layer — payload planning, send-slot backpressure,
//! and delivery, reproducing the paper's §III.C sync mechanism (each PS
//! communicator is one gRPC sender; a due sync blocks the partition's
//! workers while the slot is busy — the effect that makes the freq-1 ASGD
//! baseline communication-bound in Fig 10).
//!
//! Generalization over the seed: a sync event ships one payload along
//! *every* outgoing edge of the partition's
//! [`SyncPlan`](super::topology::SyncPlan) (a single edge
//! for [`Ring`](super::topology::Ring), a fan-out for a hierarchical
//! hub), and each model-averaging payload is applied at the receiver
//! with its edge's Metropolis weight — compensated for sequential
//! arrival ([`super::topology::sequential_weight`]) — instead of a
//! hardcoded 0.5.

use std::rc::Rc;

use crate::net::{TrafficClass, Transfer};
use crate::sim::{Sim, Time};
use crate::sync::{apply_payload, encode_gradient, make_payload, Compression, Payload};

use super::driver::{self, World};
use super::partition::Gate;
use super::topology::PlanEdge;

/// The PS communicator's send slot: busy until the previous payload has
/// fully serialized and been acknowledged; workers block behind it.
#[derive(Debug, Clone, Copy, Default)]
pub struct SendSlot {
    /// Virtual time the slot frees (serialization + ack RTT).
    pub free_at: Time,
    /// When the partition entered `Gate::CommBlocked`.
    pub blocked_since: Time,
    /// Accumulated blocked time (backpressure + barrier waits) — the
    /// report's `comm_wait`.
    pub waited: Time,
}

impl SendSlot {
    /// Is the slot free at `now` (tolerant of f64 event-time jitter)?
    pub fn is_free(&self, now: Time) -> bool {
        now + 1e-12 >= self.free_at
    }
}

/// Modeled last-mile uplink bandwidth of one edge client (HiPS stage 1).
/// Intra-cohort traffic never enters the inter-cloud fabric: every
/// client uploads over its own residential-grade link, concurrently.
pub(crate) const EDGE_UPLINK_BPS: f64 = 20e6;

/// One cohort round's worth of intra-cohort uplink traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct CohortUplink {
    /// Total bytes the participating clients put on their uplinks
    /// (counted into the job's WAN-byte total, but unmetered by the cost
    /// model — last-mile edge traffic is cheap, unlike inter-cloud
    /// egress).
    pub bytes: u64,
    /// Modeled seconds until the cohort aggregator holds every surviving
    /// upload.
    pub seconds: Time,
}

/// The intra-cohort half of the composite's communication — cheap,
/// lossy, and sampled, in contrast to the metered inter-cloud payloads
/// below. `participants` clients each upload one `payload_bytes`
/// gradient to their cohort aggregator; dropped-out clients (the lossy
/// part — the caller drew them from the dropout churn) upload nothing.
/// O(1) per round, analytic: uploads run concurrently on independent
/// last-mile links, so the round's uplink time is one serialization
/// stretched by a logarithmic straggler tail, never `n` fabric events.
pub(crate) fn cohort_uplink(participants: u64, payload_bytes: u64) -> CohortUplink {
    if participants == 0 {
        return CohortUplink { bytes: 0, seconds: 0.0 };
    }
    let one = payload_bytes as f64 * 8.0 / EDGE_UPLINK_BPS;
    let straggler = 1.0 + (participants as f64).ln() / 8.0;
    CohortUplink {
        bytes: participants.saturating_mul(payload_bytes),
        seconds: one * straggler,
    }
}

/// Asynchronous strategies: send now if the communicator is free,
/// otherwise block the partition until it is (backpressure).
pub(crate) fn trigger_async_sync(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let now = sim.now();
    if w.parts[p].slot.is_free(now) {
        perform_send(sim, w, p);
    } else if w.parts[p].gate == Gate::Running {
        let part = &mut w.parts[p];
        part.gate = Gate::CommBlocked;
        part.slot.blocked_since = now;
        let free_at = part.slot.free_at;
        sim.schedule_at(free_at, move |sim, w: &mut World| {
            unblock_comm(sim, w, p);
        });
    }
}

/// The send slot freed: account the blocked time, flush any still-due
/// sync, and restart the idled workers.
pub(crate) fn unblock_comm(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let now = sim.now();
    {
        let part = &mut w.parts[p];
        if part.gate != Gate::CommBlocked {
            return;
        }
        part.slot.waited += now - part.slot.blocked_since;
        part.gate = Gate::Running;
    }
    if w.cfg.sync.should_sync(&w.parts[p].ps) {
        perform_send(sim, w, p);
    }
    // Restart whatever the partition idles — worker waves on the flat
    // path, edge-cohort rounds on the composite path.
    driver::kick_partition(sim, w, p);
    if w.parts[p].local_done() && w.parts[p].in_flight == 0 {
        driver::finish_partition(sim, w, p);
    }
}

/// Ship `bytes` from partition `p` toward plan peer `peer` under the
/// given traffic class, following the plan's auxiliary 2-hop relay route
/// when one is recorded (store-and-forward: the relay fully receives the
/// payload before re-serializing it on the second hop, so the route's
/// rate is the harmonic combination `engine::topology::relay_route`
/// planned with). Accounts `wan_transfers`/`wan_bytes` — both hops of a
/// relay are real WAN traffic — and leaves wire-time, acks, and drop
/// recovery to the caller. The returned `done` is the *sender's*
/// serialization finish (hop 1); `arrival` is delivery at `peer`.
pub(crate) fn wan_send(
    w: &mut World,
    p: usize,
    peer: usize,
    bytes: u64,
    now: Time,
    class: TrafficClass,
) -> Transfer {
    let (from, to) = (w.parts[p].region, w.parts[peer].region);
    let via = w.plan.relay_via(p, peer).map(|r| w.parts[r].region);
    let t1 = match via {
        Some(r) => w.fabric.transfer_class(from, r, bytes, now, class),
        None => w.fabric.transfer_class(from, to, bytes, now, class),
    };
    w.wan_transfers += 1;
    if t1.dropped {
        return t1;
    }
    w.wan_bytes += bytes;
    let Some(r) = via else { return t1 };
    let t2 = w.fabric.transfer_class(r, to, bytes, t1.arrival, class);
    w.wan_transfers += 1;
    if t2.dropped {
        return Transfer { start: t1.start, done: t1.done, arrival: f64::INFINITY, dropped: true };
    }
    w.wan_bytes += bytes;
    Transfer { start: t1.start, done: t1.done, arrival: t2.arrival, dropped: false }
}

/// Pack the payload and put it on the WAN along every planned edge.
///
/// Gradient payloads (ASGD/ASGD-GA) carry the sender's *local*
/// accumulated gradient only — remote gradients influence peers through
/// the receiver's parameters (its next local gradients are taken at the
/// updated model), not by re-forwarding, exactly as in the paper's
/// two-cloud design. Model-averaging payloads mix directly, which is why
/// AMA/SMA are the primary strategies for the fan-in N-cloud topologies.
///
/// Edges are grouped by their *effective* codec — the elastic
/// controller's per-link auto-compression overrides (`World::link_codecs`)
/// fall back to the job-wide `sync.compression` — and the accumulated
/// gradient is drained once and encoded once per codec group, so TopK
/// error feedback enters the accumulator only for mass actually withheld.
/// With no overrides there is a single group in plan order: byte- and
/// RNG-identical to the ungrouped path.
pub(crate) fn perform_send(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let edges: Vec<PlanEdge> = w.plan.outgoing(p).to_vec();
    if edges.is_empty() {
        return; // single-partition job: nothing to sync with
    }
    let base = w.cfg.sync;
    let mut groups: Vec<(Compression, Vec<PlanEdge>)> = Vec::new();
    for e in &edges {
        let key = (w.parts[p].region, w.parts[e.to].region);
        let codec = w.link_codecs.get(&key).copied().unwrap_or(base.compression);
        match groups.iter_mut().find(|(c, _)| *c == codec) {
            Some((_, es)) => es.push(*e),
            None => groups.push((codec, vec![*e])),
        }
    }
    let payloads: Vec<(Rc<Payload>, Vec<PlanEdge>)> = if base.strategy.sends_gradient() {
        let (grad, steps) = w.parts[p].ps.take_accumulated();
        groups
            .into_iter()
            .map(|(codec, es)| {
                (Rc::new(encode_gradient(codec, &grad, steps, &mut w.parts[p].ps)), es)
            })
            .collect()
    } else {
        // Model-averaging payloads ship uncompressed parameters: every
        // group carries the same snapshot.
        let payload = Rc::new(Payload::Params(w.parts[p].ps.snapshot_params()));
        groups.into_iter().map(|(_, es)| (Rc::clone(&payload), es)).collect()
    };
    let now = sim.now();
    let mut ack_at: Option<Time> = None;
    let mut any_dropped = false;
    for (payload, es) in payloads {
        let bytes = payload.wire_bytes();
        for e in &es {
            let t = wan_send(w, p, e.to, bytes, now, TrafficClass::Gradient);
            if t.dropped {
                any_dropped = true;
                continue;
            }
            w.parts[p].wire_time += t.done - t.start;
            // The gRPC send slot frees when this edge's payload lands AND
            // its ack returns (one edge-specific RTT; overrides may differ
            // from the uniform mesh latency). Relayed edges approximate
            // the ack with the direct link's RTT share.
            let (from, to) = (w.parts[p].region, w.parts[e.to].region);
            let latency = w.fabric.link_latency(from, to).unwrap_or(w.cfg.link.latency_s);
            let ack = t.arrival + latency;
            ack_at = Some(ack_at.map_or(ack, |a: Time| a.max(ack)));
            let (peer, weight, pl) = (e.to, e.weight, Rc::clone(&payload));
            sim.schedule_at(t.arrival, move |sim, w: &mut World| {
                receive_payload(sim, w, peer, &pl, weight);
            });
        }
    }
    // The PS communicator is a request/response sender: its send slot
    // stays busy until the last ack returns (serialization + RTT).
    if let Some(a) = ack_at {
        w.parts[p].slot.free_at = a;
    }
    if any_dropped {
        // Failure injection path: a dropped edge's payload is lost, as a
        // timed-out gRPC request would be. The retry is a re-armed sync
        // trigger, not a redelivery: it fires only if the sync condition
        // holds again (fresh accumulated state, all planned edges), so a
        // fully-blacked-out link cannot spin the event loop forever and
        // healthy edges never miss an accumulated payload.
        sim.schedule(1.0, move |sim, w: &mut World| {
            if w.cfg.sync.should_sync(&w.parts[p].ps) {
                perform_send(sim, w, p);
            }
        });
    }
}

/// Synchronous (barrier) exchange: every active partition ships its
/// payload along its plan edges at the barrier instant; returns the
/// release time (the latest arrival — a true barrier).
///
/// Each scheduled arrival carries the receiver's total incoming weight
/// *as of this exchange* alongside its edge weight: the compensation in
/// [`receive_payload`] must telescope against the plan the round was
/// planned with, even if the elastic loop swaps `World::plan` while
/// payloads are still on the wire.
pub(crate) fn barrier_exchange(
    sim: &mut Sim<World>,
    w: &mut World,
    active: &[usize],
    now: Time,
) -> Time {
    let mut release_at = now;
    let mut arrivals: Vec<(Time, usize, Rc<Payload>, f32, f32)> = Vec::new();
    for &p in active {
        let edges: Vec<PlanEdge> = w.plan.outgoing(p).to_vec();
        if edges.is_empty() {
            continue;
        }
        let payload = Rc::new(make_payload(&w.cfg.sync, &mut w.parts[p].ps));
        let bytes = payload.wire_bytes();
        let mut slot_busy: Option<Time> = None;
        for e in &edges {
            // Barrier payloads are latency-critical: with lanes enabled
            // they preempt in-flight bulk migration instead of sharing
            // the gradient lane's queue position.
            let t = wan_send(w, p, e.to, bytes, now, TrafficClass::Barrier);
            if t.dropped {
                // Lossy link: this edge's payload is lost; the barrier
                // still releases (the receiver keeps its local model).
                continue;
            }
            w.parts[p].wire_time += t.done - t.start;
            slot_busy = Some(slot_busy.map_or(t.done, |s: Time| s.max(t.done)));
            release_at = release_at.max(t.arrival);
            let incoming = w.plan.incoming_weight(e.to);
            arrivals.push((t.arrival, e.to, Rc::clone(&payload), e.weight, incoming));
        }
        if let Some(s) = slot_busy {
            w.parts[p].slot.free_at = s;
        }
    }
    for (at, peer, payload, weight, incoming) in arrivals {
        sim.schedule_at(at, move |sim, w: &mut World| {
            receive_sync_payload(sim, w, peer, &payload, weight, incoming);
        });
    }
    release_at
}

/// An asynchronous payload landed: apply it per the strategy's update
/// rule at its raw edge weight. Asynchronous averaging (AMA) has no
/// round structure — a fast sender's payload would be up-weighted
/// whenever slower peers miss the window — so sequential compensation
/// is reserved for the barrier path ([`receive_sync_payload`]); gradient
/// payloads ignore weights entirely.
pub(crate) fn receive_payload(
    _sim: &mut Sim<World>,
    w: &mut World,
    p: usize,
    payload: &Payload,
    remote_weight: f32,
) {
    let cfg = w.cfg.sync;
    apply_payload(&cfg, &mut w.parts[p].ps, payload, remote_weight);
}

/// A barrier-round payload landed. Under the synchronous strategy (SMA)
/// every planned payload lands exactly once between receiver snapshots,
/// so the effective weight is run through
/// [`super::topology::sequential_weight`] — compensated against
/// `incoming_total`, the receiver's planned incoming weight captured *at
/// the exchange instant* (not re-read from the live plan, which the
/// elastic loop may have re-planned while this payload was on the wire)
/// — and a full round reconstructs the synchronous doubly-stochastic mix
/// order-independently.
pub(crate) fn receive_sync_payload(
    _sim: &mut Sim<World>,
    w: &mut World,
    p: usize,
    payload: &Payload,
    remote_weight: f32,
    incoming_total: f32,
) {
    let cfg = w.cfg.sync;
    let eff = if matches!(payload, Payload::Params(_)) {
        let applied = w.parts[p].ps.applied_weight_since_snapshot;
        w.parts[p].ps.note_applied_weight(remote_weight);
        super::topology::sequential_weight(remote_weight, incoming_total, applied)
    } else {
        remote_weight
    };
    apply_payload(&cfg, &mut w.parts[p].ps, payload, eff);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cohort_uplink_is_cheap_sampled_and_concurrent() {
        let full = cohort_uplink(1000, 4096);
        let sampled = cohort_uplink(100, 4096);
        assert_eq!(full.bytes, 1000 * 4096);
        assert_eq!(sampled.bytes, 100 * 4096);
        assert!(sampled.seconds < full.seconds, "smaller straggler tail");
        // Concurrent last-mile uploads: 10x the participants costs a
        // logarithmic factor, never 10x the round time.
        assert!(full.seconds < 2.0 * sampled.seconds);
        assert_eq!(cohort_uplink(0, 4096), CohortUplink { bytes: 0, seconds: 0.0 });
        // One participant pays exactly one payload serialization.
        let one = cohort_uplink(1, 4096);
        assert!((one.seconds - 4096.0 * 8.0 / EDGE_UPLINK_BPS).abs() < 1e-12);
    }
}

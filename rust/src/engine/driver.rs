//! The discrete-event training driver — real numerics on a virtual clock
//! (the seed's `World` + `run_geo_training`, extracted).
//!
//! Every training partition executes **real** PJRT train steps (so the
//! accuracy/loss curves are genuine), while the discrete-event simulator
//! advances virtual time by *modeled* durations:
//!
//! - compute: `T_iter = base_step / worker_class_power` (device catalog,
//!   see `train::calib`), with a small deterministic jitter;
//! - WAN: the `net::Fabric` link model (serialization, FIFO queueing,
//!   fluctuation, latency);
//! - serverless startup: FaaS cold starts for the control-plane and
//!   per-cloud training workflows.
//!
//! Gradient staleness is physically real here: a worker trains on the
//! snapshot it pulled at iteration start; PS state moves on (local pushes
//! and WAN arrivals interleave in virtual-time order) before the push
//! lands.
//!
//! Layering (see docs/ARCHITECTURE.md): this driver owns the event loop
//! and barrier logic; each region's actor state lives in
//! [`super::partition`]; all WAN interaction goes through
//! [`super::comm`]; who-talks-to-whom comes from [`super::topology`].
//!
//! Single-job vs multi-job: [`run_geo_training`] deploys one job on a
//! private fabric and drains its simulator to completion. The multi-job
//! coordinator (`crate::coordinator::fleet`) instead calls the split
//! crate-internal entry points — `deploy_job_planned` with a start offset and a
//! [`SharedFabric`](crate::net::SharedFabric), stepping each job's
//! simulator event-by-event on a merged clock, `apply_lease` when it
//! re-divides the shared inventory, and `finalize_report` at job
//! completion.

use std::rc::Rc;

use anyhow::Result;

use crate::cloud::cost::{BilledAllocation, CostModel};
use crate::cloud::devices::{Device, DeviceKind};
use crate::cloud::spot::{Market, SpotConfig, SpotMarket};
use crate::cloud::{Allocation, CloudEnv};
use crate::data::{shard_by_fraction, Dataset, Shard};
use crate::dataplane::migration::{self, DataPlaneState};
use crate::dataplane::placement::{self, PlanInputs};
use crate::dataplane::DataPlaneConfig;
use crate::faas::workflow::{WorkflowDef, WorkflowInstance};
use crate::faas::{autoscaler, FaasRuntime, FunctionKind, FunctionSpec};
use crate::net::{Fabric, LinkSpec, SharedFabric};
use crate::ps::PsState;
use crate::runtime::{ModelRuntime, PjrtRuntime};
use crate::sched::elastic::{
    ElasticConfig, ElasticController, LinkCodec, MonitorSample, ReplanDecision,
};
use crate::sim::{Sim, Time};
use crate::sync::{Compression, SyncConfig};
use crate::train::calib;
use crate::train::metrics::{replan_cause, EvalPoint, PartitionReport, ReplanEvent, TrainReport};
use crate::util::rng::Pcg32;

use super::comm::{self, SendSlot};
use super::partition::{EdgeCohort, Gate, Partition};
use super::topology::{SyncPlan, TopologyKind};

/// A resource/WAN churn injection — what the elastic control loop exists
/// to absorb. Events fire on the virtual clock mid-run (benches and the
/// `exp --id elastic` driver inject these; real deployments observe the
/// same effects from co-tenancy and WAN weather).
#[derive(Debug, Clone)]
pub enum ChurnEvent {
    /// At time `t`, region `region`'s effective compute power is
    /// multiplied down to `factor` of catalog (0.35 = the cloud lost 65%
    /// of its delivered compute).
    PowerFactor { t: Time, region: usize, factor: f64 },
    /// At time `t`, the directed link's nominal bandwidth becomes `bps`.
    LinkBandwidth { t: Time, from: usize, to: usize, bps: f64 },
    /// At time `t`, the spot market revokes region `region`'s worker
    /// pool (an injected revocation on top of the market's own trace —
    /// tests and `exp --id spot` use it for controlled scenarios).
    /// Ignored when `TrainConfig::spot` is disabled: revocations are a
    /// market phenomenon, not generic churn.
    Preemption { t: Time, region: usize },
}

/// The `"federated"` config block / `--clients --cohorts --sample-frac
/// --dropout` CLI surface: the edge tier below the clouds. When active,
/// every cloud partition becomes a recursive composite — its worker pool
/// is replaced by a population of edge clients grouped into cohorts that
/// aggregate locally (HiPS stage 1) before the cloud joins the WAN sync
/// (stage 2). Inactive (the default) leaves the flat per-cloud engine
/// byte-identical to the pre-composite behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct FederatedConfig {
    /// Total edge clients across the job, split over clouds by resident
    /// data share (at least one per data-holding cloud). 0 = off.
    pub clients: usize,
    /// Edge cohorts per cloud (stage-1 aggregation pools; clamped to the
    /// cloud's client count). 0 = off.
    pub cohorts: usize,
    /// Fraction of each cohort's clients sampled into a round (clamped
    /// so at least one client participates).
    pub sample_frac: f64,
    /// Probability a sampled client drops mid-round (dropout-as-churn);
    /// its upload is lost but the cohort's full population weight still
    /// lands (population-reweighted FedAvg), so update totals conserve.
    pub dropout: f64,
}

impl Default for FederatedConfig {
    fn default() -> Self {
        FederatedConfig { clients: 0, cohorts: 0, sample_frac: 1.0, dropout: 0.0 }
    }
}

impl FederatedConfig {
    /// Is the edge tier on? Both knobs must be set: clients without
    /// cohorts (or vice versa) stays flat.
    pub fn active(&self) -> bool {
        self.clients > 0 && self.cohorts > 0
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.sample_frac > 0.0 && self.sample_frac <= 1.0,
            "federated sample_frac must be in (0, 1], got {}",
            self.sample_frac
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.dropout),
            "federated dropout must be in [0, 1), got {}",
            self.dropout
        );
        anyhow::ensure!(
            self.clients <= u32::MAX as usize,
            "federated clients must fit u32 update weights"
        );
        Ok(())
    }
}

/// Configuration for one geo-distributed training job.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    /// Local epochs each partition trains over its shard.
    pub epochs: usize,
    pub lr: f32,
    pub sync: SyncConfig,
    /// Which N-cloud sync topology the global communicator plans.
    pub topology: TopologyKind,
    pub seed: u64,
    /// Total train/eval samples (split across regions by data ratio).
    pub n_train: usize,
    pub n_eval: usize,
    /// CPU cores per worker function (ElasticDL pod granularity).
    pub worker_cores: u32,
    /// Measured base step seconds (0.0 = use calib defaults).
    pub base_step_s: f64,
    /// WAN link spec between distinct regions.
    pub link: LinkSpec,
    /// Per-pair link overrides `(from, to, spec)` applied after the
    /// uniform mesh — heterogeneous WANs for the bandwidth-aware
    /// topologies.
    pub link_overrides: Vec<(usize, usize, LinkSpec)>,
    /// Evaluate every this many partition-0 epochs.
    pub eval_every: usize,
    /// Skip accuracy evaluation entirely (timing-only experiments).
    pub skip_eval: bool,
    /// Checkpoint PS state here at every partition-0 epoch boundary
    /// (None = checkpointing off).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Elastic re-scheduling control loop (off by default — the static
    /// one-shot plan is the paper's §III.B behavior).
    pub elastic: ElasticConfig,
    /// Injected resource/WAN churn events (empty = a calm run).
    pub churn: Vec<ChurnEvent>,
    /// Physical data plane: dataset catalog + placement + migration
    /// (off by default — data stays resident where the `regions` config
    /// put it, the seed behavior).
    pub dataplane: DataPlaneConfig,
    /// Worker-cohort aggregation threshold: a partition whose pool
    /// exceeds this many workers is simulated as ~threshold weighted
    /// cohort waves — each scheduled event carrying
    /// `ceil(workers/threshold)` iterations of step/billing/monitor
    /// accounting ([`super::partition::cohort_size`]) — instead of one
    /// event per worker iteration. 0 (the default) = off: the exact
    /// per-worker path. Aggregation keeps step/epoch/billing totals
    /// within tolerance but coarsens sync and batch granularity to the
    /// wave, so it is opt-in (fleet-scale runs set it; see
    /// docs/CONFIG.md).
    pub cohort_threshold: usize,
    /// The federated edge tier below the clouds (off by default; see
    /// [`FederatedConfig`] and docs/CONFIG.md).
    pub federated: FederatedConfig,
    /// WAN priority lanes: when true the fabric schedules transfers in
    /// per-class lanes (Control > Barrier > Gradient > BulkData) so
    /// latency-critical exchanges preempt bulk shard migration at
    /// serialization boundaries. Off (the default) is byte-identical to
    /// the single-FIFO fabric.
    pub wan_lanes: bool,
    /// Auxiliary 2-hop relay routes: when true the sync planner may route
    /// a planned edge through an intermediate region whenever the
    /// two-hop path's effective bandwidth beats the direct link (see
    /// `engine::topology::relay_route`).
    pub relay_routes: bool,
    /// Spot market (preemptible capacity): when enabled, the placement
    /// planner may commit a region to the spot market — discounted
    /// compute billed at the deterministic price trace, revocable on the
    /// trace's preemption times (see `cloud::spot`). Off (the default)
    /// is byte-identical to the on-demand-only behavior.
    pub spot: SpotConfig,
}

impl TrainConfig {
    pub fn new(model: &str) -> TrainConfig {
        let (n_train, n_eval) = crate::data::default_sizes(model);
        TrainConfig {
            model: model.to_string(),
            epochs: 4,
            lr: default_lr(model),
            sync: SyncConfig::baseline(),
            topology: TopologyKind::Ring,
            seed: 42,
            n_train,
            n_eval,
            worker_cores: 3,
            base_step_s: 0.0,
            link: LinkSpec::wan_100mbps(),
            link_overrides: Vec::new(),
            eval_every: 1,
            skip_eval: false,
            checkpoint_dir: None,
            elastic: ElasticConfig::default(),
            churn: Vec::new(),
            dataplane: DataPlaneConfig::default(),
            cohort_threshold: 0,
            federated: FederatedConfig::default(),
            wan_lanes: false,
            relay_routes: false,
            spot: SpotConfig::default(),
        }
    }
}

/// Default SGD learning rates per model (validated by the usability exp).
pub fn default_lr(model: &str) -> f32 {
    match model {
        "lenet" => 0.03,
        "resnet" => 0.015,
        "deepfm" => 0.1,
        _ => 0.02, // transformers
    }
}

/// Live spot-market state for one job (`TrainConfig::spot` enabled):
/// the deterministic price/revocation trace, the per-region market the
/// placement committed, and the preemption-recovery accounting.
pub(crate) struct SpotState {
    pub(crate) market: SpotMarket,
    /// Per-region market choice (spot vs on-demand) the plan committed —
    /// fixed for the run; billing segments in a spot region carry the
    /// trace-averaged price multiplier.
    pub(crate) markets: Vec<Market>,
    /// The billing-horizon estimate the markets were priced against
    /// (re-used by the mid-run rebalancer's rate scaling).
    pub(crate) horizon_s: f64,
    /// Checkpoint save/fetch traffic billed for preemption recoveries.
    pub(crate) restore_cost: f64,
}

/// The driver's world: partitions + substrates, stepped by `sim::Sim`.
pub(crate) struct World {
    pub(crate) cfg: TrainConfig,
    /// The environment the job deployed into (inventories; the data-plane
    /// rebalancer re-plans against it).
    pub(crate) env: CloudEnv,
    pub(crate) model: Rc<ModelRuntime>,
    pub(crate) train_ds: Rc<Dataset>,
    pub(crate) eval_ds: Rc<Dataset>,
    pub(crate) parts: Vec<Partition>,
    /// The WAN — possibly shared with other concurrently simulated jobs
    /// (multi-job coordinator), in which case its statistics aggregate
    /// every job's traffic and per-job accounting uses `wan_bytes` /
    /// `wan_transfers` / `Partition::wire_time` below.
    pub(crate) fabric: SharedFabric,
    pub(crate) faas: FaasRuntime,
    pub(crate) plan: SyncPlan,
    pub(crate) n_finished: usize,
    pub(crate) global_end: Option<Time>,
    pub(crate) curve: Vec<EvalPoint>,
    pub(crate) train_start: Time,
    /// Calibrated base step seconds (monitor + re-plan recompute t_iter).
    pub(crate) base_step: f64,
    /// Per-partition FaaS worker-pool function key (one function per
    /// cloud, scaled to N replicas — the autoscaler's resize unit).
    pub(crate) worker_keys: Vec<String>,
    /// The elastic re-scheduler, when `cfg.elastic.enabled` or
    /// `cfg.elastic.auto_compression` (compression-only control loop).
    pub(crate) controller: Option<ElasticController>,
    /// Committed re-plan events (copied into the report).
    pub(crate) replans: Vec<ReplanEvent>,
    /// Per-directed-link (bytes, stream_time) at the last monitor tick,
    /// so bandwidth samples are window deltas, not run-lifetime averages
    /// (a late-run collapse must still register).
    pub(crate) mon_link_last: std::collections::BTreeMap<(usize, usize), (u64, f64)>,
    /// Billing segments closed by mid-run re-plans (released/replaced
    /// allocations billed up to their release instant).
    pub(crate) closed_billing: Vec<BilledAllocation>,
    /// This job's own WAN bytes/transfers (counted at send time — the
    /// fabric's totals include every job sharing it).
    pub(crate) wan_bytes: u64,
    pub(crate) wan_transfers: u64,
    /// Virtual time this job was admitted (its billing and report epoch;
    /// 0 for single-job runs).
    pub(crate) start_at: Time,
    /// Live data-plane state (catalog + migrations), when
    /// `cfg.dataplane` is enabled.
    pub(crate) dataplane: Option<DataPlaneState>,
    /// Intra-cohort uplink bytes (HiPS stage 1) — included in
    /// `wan_bytes` (the sampled-participation saving shows up there) but
    /// excluded from the metered inter-cloud WAN cost: last-mile edge
    /// traffic is cheap.
    pub(crate) fed_uplink_bytes: u64,
    /// Per-directed-region-pair gradient codec overrides the elastic
    /// controller installed (`auto_compression`); links not present ship
    /// the configured `sync.compression`.
    pub(crate) link_codecs: std::collections::BTreeMap<(usize, usize), Compression>,
    /// Spot-market state, when `cfg.spot.enabled`.
    pub(crate) spot: Option<SpotState>,
}

impl World {
    fn all_arrived(&self) -> bool {
        self.parts.iter().all(|p| p.barrier_arrived || p.gate == Gate::Finished)
    }
}

/// Run one geo-distributed training job and return its report.
///
/// `allocations` is the resourcing plan (greedy or elastic); data is
/// sharded by the regions' `data_samples` ratio. The job gets a private
/// WAN fabric built from `cfg.link` / `cfg.link_overrides`; multi-job
/// fleets instead deploy through `deploy_job_planned` with a shared fabric.
pub fn run_geo_training(
    rt: &PjrtRuntime,
    env: &CloudEnv,
    allocations: Vec<Allocation>,
    cfg: TrainConfig,
) -> Result<TrainReport> {
    run_geo_training_planned(rt, env, allocations, cfg, None)
}

/// [`run_geo_training`] with an already-computed placement plan: callers
/// that ran `dataplane::plan_for` to pick `allocations` (the coordinator)
/// hand the result down instead of having `deploy_job_planned` recompute the
/// identical deterministic plan.
pub(crate) fn run_geo_training_planned(
    rt: &PjrtRuntime,
    env: &CloudEnv,
    allocations: Vec<Allocation>,
    cfg: TrainConfig,
    planned: Option<crate::dataplane::PlannedDataPlane>,
) -> Result<TrainReport> {
    let wall0 = std::time::Instant::now();
    let mut fabric = Fabric::full_mesh(cfg.seed, env.regions.len(), &cfg.link, &cfg.link_overrides);
    fabric.set_lanes(cfg.wan_lanes);
    let shared = SharedFabric::new(fabric);
    let (mut sim, mut world) = deploy_job_planned(rt, env, allocations, cfg, 0.0, shared, planned)?;
    let drained = sim.run_with_limit(&mut world, 200_000_000);
    anyhow::ensure!(drained, "simulation exceeded event limit — runaway loop?");
    let global_end = world.global_end.unwrap_or_else(|| sim.now());

    // Final evaluation on partition 0's model.
    let (final_loss, final_acc) = if world.cfg.skip_eval {
        (f64::NAN, f64::NAN)
    } else {
        evaluate(&world, 0)
    };
    Ok(finalize_report(&world, global_end, final_loss, final_acc, wall0.elapsed().as_secs_f64()))
}

/// Deploy one training job onto `fabric` with its clocks offset to
/// `start_at` (the virtual instant the control plane begins deploying —
/// a fleet job's admission time, 0 for single-job runs), returning the
/// job's simulator and world with every initial event scheduled. The
/// caller owns stepping: drain to completion (single job) or merge
/// event-by-event with other jobs' simulators on the shared clock
/// (multi-job coordinator). Links are expected to be installed on
/// `fabric` already when it is shared; `run_geo_training` installs them
/// for the private case. `pre_planned` carries an already-computed
/// placement plan (see [`run_geo_training_planned`]; fleet admission
/// plans against the live fabric and catalog); `None` plans here — on
/// the passed fabric's link view — when the data plane is enabled.
pub(crate) fn deploy_job_planned(
    rt: &PjrtRuntime,
    env: &CloudEnv,
    allocations: Vec<Allocation>,
    cfg: TrainConfig,
    start_at: Time,
    fabric: SharedFabric,
    pre_planned: Option<crate::dataplane::PlannedDataPlane>,
) -> Result<(Sim<World>, World)> {
    anyhow::ensure!(allocations.len() == env.regions.len(), "one allocation per region");
    cfg.federated.validate()?;
    // Resumed runs must not silently mix sync strategies or topologies.
    if let Some(dir) = &cfg.checkpoint_dir {
        crate::train::checkpoint::ensure_run_compatible(
            dir,
            &cfg.model,
            cfg.sync.strategy.name(),
            cfg.topology.name(),
        )?;
    }
    let model = Rc::new(rt.load_model(&cfg.model)?);
    let base_step = if cfg.base_step_s > 0.0 {
        cfg.base_step_s
    } else {
        calib::default_base_step_s(&cfg.model)
    };

    // ---- spot market ----
    // The per-region market choice (spot vs on-demand) is committed at
    // deploy time against the same horizon estimate the placement
    // planner prices with; the trace's revocations for the committed
    // spot regions are scheduled below once training start is known.
    let spot = if cfg.spot.enabled {
        let market = SpotMarket::new(&cfg.spot, cfg.seed);
        let shard = cfg.n_train / env.regions.len().max(1);
        let steps = (shard.max(1) as f64 / model.meta.batch_size.max(1) as f64).ceil()
            * cfg.epochs as f64;
        let power = env.greedy_plan().iter().map(|a| a.power()).fold(f64::INFINITY, f64::min);
        let horizon_s = (steps * base_step / power.max(1e-9)).max(1.0);
        let markets = crate::cloud::spot::plan_markets(env, Some(&market), horizon_s);
        Some(SpotState { market, markets, horizon_s, restore_cost: 0.0 })
    } else {
        None
    };

    // ---- data ----
    let (train_ds, eval_ds) = crate::data::generate(&model.meta, cfg.n_train, cfg.n_eval, cfg.seed);
    // With an active data plane, residency comes from the catalog and
    // the placement plan: a partition starts with the indices of the
    // shards that stay home, gains migrated shards as they land, and its
    // step budget is sized to the *final* (post-migration) sample count.
    // Callers that picked `allocations` via `dataplane::plan_for` pass
    // the plan down (`pre_planned`); anyone else gets the identical
    // deterministic plan computed here.
    let planned = match pre_planned {
        Some(pd) => Some(pd),
        None if cfg.dataplane.enabled() => {
            // Plan against the fabric the job will actually run on (for
            // a fleet's shared fabric that is the *live* link state, not
            // the config template).
            let links = fabric.with(|f| PlanInputs::link_view(f, env.regions.len()));
            Some(placement::plan_for_on(env, &cfg, &model.meta, links)?)
        }
        None => None,
    };
    // Per region: (initially-available shard, final sample count). A
    // shard is available at start wherever its assigned trainer already
    // holds a replica; everything else arrives via the staged moves.
    let shards: Vec<(Shard, usize)> = match &planned {
        Some(pd) => {
            let moved: std::collections::BTreeSet<usize> =
                pd.plan.moves.iter().map(|m| m.shard).collect();
            let mut initial: Vec<Vec<usize>> = vec![Vec::new(); env.regions.len()];
            for s in &pd.catalog.shards {
                if !moved.contains(&s.id) {
                    initial[pd.plan.assign[s.id]].extend(s.indices());
                }
            }
            initial
                .into_iter()
                .enumerate()
                .map(|(i, idxs)| (Shard::new(idxs, cfg.seed, i as u64), pd.plan.resident[i]))
                .collect()
        }
        None => {
            let fractions: Vec<f64> =
                env.regions.iter().map(|r| r.data_samples.max(1) as f64).collect();
            shard_by_fraction(cfg.n_train, &fractions, cfg.seed)
                .into_iter()
                .map(|s| {
                    let n = s.len();
                    (s, n)
                })
                .collect()
        }
    };

    // Federated edge tier: split the client population over clouds by
    // final resident data share (at least one per data-holding cloud);
    // the Dirichlet skew parameter for cohort carving comes from the
    // `fed:` catalog layout when one is configured, else a mild default.
    let fed_active = cfg.federated.active();
    let fed_clients: Vec<usize> = if fed_active {
        let finals: Vec<usize> = shards.iter().map(|(_, n)| *n).collect();
        split_clients(cfg.federated.clients, &finals)
    } else {
        vec![0; env.regions.len()]
    };
    let fed_alpha = match cfg.dataplane.placement.as_ref().map(|s| s.layout) {
        Some(crate::dataplane::Layout::Federated { alpha, .. }) => alpha,
        _ => 1.0,
    };

    // ---- serverless control plane + training workflows ----
    let mut faas = FaasRuntime::new();
    let mut sim: Sim<World> = Sim::new();
    let mut startup_done: Time = start_at;

    // Control plane: scheduler -> global communicator (workflow on cloud 0).
    let mut control = WorkflowDef::new("control-plane");
    let sched_node = control.add(
        FunctionSpec::new("scheduler", "cloudless", FunctionKind::Scheduler, 0),
        vec![],
    );
    control.add(
        FunctionSpec::new("global-communicator", "cloudless", FunctionKind::GlobalCommunicator, 0),
        vec![sched_node],
    );
    let mut control_inst = WorkflowInstance::deploy(control, &mut faas)?;
    // scheduler function cold start + plan generation
    let inv = faas.invoke("cloudless/scheduler", start_at)?;
    faas.mark_ready(inv.replica);
    let t_sched = start_at + inv.dispatch_delay + 0.05; // plan generation latency
    control_inst.start(sched_node);
    control_inst.complete(sched_node);
    // global communicator starts after the scheduler
    let inv_comm = faas.invoke("cloudless/global-communicator", t_sched)?;
    faas.mark_ready(inv_comm.replica);
    let t_comm_ready = t_sched + inv_comm.dispatch_delay;

    // Physical plane: one sub-workflow per cloud (PS -> PS-comm -> worker
    // pool). Workers share ONE function key per cloud scaled to N
    // replicas, so the elastic control loop can resize the pool through
    // the plan-driven autoscaler.
    let initial_allocations = allocations.clone();
    let mut parts: Vec<Partition> = Vec::new();
    let mut worker_keys: Vec<String> = Vec::new();
    for (i, (alloc, (shard, final_samples))) in allocations.into_iter().zip(shards).enumerate() {
        let region = &env.regions[i];
        let is_gpu = alloc
            .units
            .first()
            .map(|(d, _)| d.info().kind == DeviceKind::Gpu)
            .unwrap_or(false);
        // A region with no resident (or inbound) data runs no workers —
        // the placement planner legitimately leaves it empty.
        let has_work = final_samples > 0;
        // A composite (federated) partition's "pool" is its edge-client
        // population; its cloud-side FaaS footprint is one aggregator
        // function per cohort. A data-holding cloud that drew zero
        // clients (more clouds than clients) falls back to the flat path.
        let fed_here = fed_active && has_work && fed_clients[i] > 0;
        let workers = if !has_work {
            0
        } else if fed_here {
            fed_clients[i]
        } else {
            calib::worker_count(alloc.total_units(), is_gpu, cfg.worker_cores)
        };
        let power = alloc.power();
        anyhow::ensure!(
            !has_work || power > 0.0,
            "region {} has data but an empty allocation",
            region.name
        );
        // Edge clients train at unit catalog power (a residential-class
        // device), whatever cloud allocation sits behind the aggregators.
        let t_iter = if fed_here {
            calib::iter_time(base_step, 1.0)
        } else if has_work {
            calib::iter_time(base_step, calib::worker_power(power, workers))
        } else {
            base_step // unused: no worker ever starts
        };

        let mut wf = WorkflowDef::new(&format!("train-{}", region.name));
        let ps_node =
            wf.add(FunctionSpec::new("ps", &format!("cloud{i}"), FunctionKind::ParameterServer, i), vec![]);
        let comm_node = wf.add(
            FunctionSpec::new("ps-comm", &format!("cloud{i}"), FunctionKind::PsCommunicator, i),
            vec![ps_node],
        );
        wf.add(
            FunctionSpec::new("worker", &format!("cloud{i}"), FunctionKind::Worker, i),
            vec![comm_node],
        );
        let _inst = WorkflowInstance::deploy(wf, &mut faas)?;
        let worker_key = format!("cloud{i}/worker");

        // Spawn replicas following the DAG: PS, then communicator, then workers.
        let (ps_rep, ps_ready) = faas.scale_up(&format!("cloud{i}/ps"), t_comm_ready)?;
        faas.mark_ready(ps_rep);
        let (comm_rep, comm_ready) = faas.scale_up(&format!("cloud{i}/ps-comm"), ps_ready)?;
        faas.mark_ready(comm_rep);
        // Global communicator assigns the WAN identity once the PS comm is up.
        let wan_ep = crate::faas::Endpoint { ip: [101, 6, i as u8, 10], port: 7000 + i as u16 };
        faas.addressing.assign_wan_identity(comm_rep, wan_ep);
        let mut worker_replicas = Vec::new();
        let mut workers_ready = comm_ready;
        // Composite partitions spawn one aggregator function per cohort,
        // not one pod per edge client — the serverless footprint stays a
        // few functions however large the client population.
        let pool = if fed_here { cfg.federated.cohorts.min(workers) } else { workers };
        for _ in 0..pool {
            let (rep, ready) = faas.scale_up(&worker_key, comm_ready)?;
            faas.mark_ready(rep);
            worker_replicas.push(rep);
            workers_ready = workers_ready.max(ready);
        }
        startup_done = startup_done.max(workers_ready);
        worker_keys.push(worker_key);

        // Step budget sized to the final (post-migration) sample count —
        // or, on the composite path, to the client population: one epoch
        // is one federated round of every client, each cohort pushing
        // one population-weighted wave.
        let steps_per_epoch = if final_samples == 0 {
            0
        } else if fed_here {
            fed_clients[i] as u64
        } else {
            final_samples.div_ceil(model.meta.batch_size).max(1) as u64
        };
        let cohorts = if fed_here {
            build_cohorts(
                &train_ds,
                &shard.indices,
                fed_clients[i] as u64,
                cfg.federated.cohorts,
                fed_alpha,
                cfg.seed,
                i,
            )
        } else {
            Vec::new()
        };
        parts.push(Partition {
            region: i,
            region_name: region.name.clone(),
            alloc,
            shard,
            ps: PsState::new(model.init_params.clone(), cfg.lr),
            workers,
            t_iter,
            power_factor: 1.0,
            steps_total: steps_per_epoch * cfg.epochs as u64,
            steps_started: 0,
            steps_completed: 0,
            epoch_steps: steps_per_epoch,
            steps_into_epoch: 0,
            epochs_done: 0,
            gate: Gate::Running,
            in_flight: 0,
            cohort: super::partition::cohort_size(workers, cfg.cohort_threshold),
            slot: SendSlot::default(),
            local_finish: None,
            barrier_arrived: false,
            barrier_entry: 0.0,
            wire_time: 0.0,
            cold_start_time: workers_ready - t_comm_ready,
            worker_replicas,
            alloc_since: start_at,
            data_blocked_since: 0.0,
            data_stall: 0.0,
            win_iter_sum: 0.0,
            win_iter_count: 0,
            rng: Pcg32::new(cfg.seed ^ 0x7A27, i as u64),
            cohorts,
        });
    }

    let n_parts = parts.len();
    // Elastic control loop: the controller sees the launch plan, the
    // bandwidths the initial sync topology was planned against, and —
    // under an active data plane — the *post-migration* residency (its
    // Algorithm-1 candidates must match the layout actually trained on).
    let controller = if cfg.elastic.enabled || cfg.elastic.auto_compression {
        let nominal_bw: Vec<(usize, usize, f64)> = (0..n_parts)
            .flat_map(|a| (0..n_parts).filter(move |b| *b != a).map(move |b| (a, b)))
            .filter_map(|(a, b)| fabric.link_bandwidth(a, b).map(|bw| (a, b, bw)))
            .collect();
        let mut controller_env = env.clone();
        if let Some(pd) = &planned {
            for (region, &samples) in controller_env.regions.iter_mut().zip(&pd.plan.resident) {
                region.data_samples = samples;
            }
        }
        Some(ElasticController::new(
            cfg.elastic.clone(),
            controller_env,
            &initial_allocations,
            nominal_bw,
        ))
    } else {
        None
    };
    // Live data-plane state: the catalog plus every staged move, queued
    // for transfer at training start.
    let dataplane = planned.map(|pd| {
        let spec = cfg.dataplane.placement.clone().expect("planned implies a spec");
        let mut st =
            DataPlaneState::new(pd.catalog, pd.plan.assign.clone(), cfg.dataplane.mode, spec);
        for mv in pd.plan.moves {
            let indices = st.catalog.shards[mv.shard].indices();
            st.enqueue(mv, indices, false);
        }
        st
    });
    let world = World {
        plan: fabric.with(|f| cfg.topology.plan_with(n_parts, f, cfg.relay_routes)),
        cfg,
        env: env.clone(),
        model,
        train_ds: Rc::new(train_ds),
        eval_ds: Rc::new(eval_ds),
        parts,
        fabric,
        faas,
        n_finished: 0,
        global_end: None,
        curve: Vec::new(),
        train_start: startup_done,
        base_step,
        worker_keys,
        controller,
        replans: Vec::new(),
        mon_link_last: std::collections::BTreeMap::new(),
        closed_billing: Vec::new(),
        wan_bytes: 0,
        wan_transfers: 0,
        start_at,
        dataplane,
        fed_uplink_bytes: 0,
        link_codecs: std::collections::BTreeMap::new(),
        spot,
    };

    // Kick off every partition at training start; a partition with no
    // planned steps (a data-less region the placement planner emptied)
    // finishes immediately instead. One kick saturates the partition —
    // `kick_partition` fills every idle worker wave on the flat path and
    // starts one stage-1 round per edge cohort on the composite path;
    // the resulting event schedule is identical to the historic
    // one-event-per-wave startup (same draws, same order, same times).
    for p in 0..n_parts {
        if world.parts[p].steps_total == 0 {
            sim.schedule_at(startup_done, move |sim, w: &mut World| {
                finish_partition(sim, w, p);
            });
            continue;
        }
        sim.schedule_at(startup_done, move |sim, w: &mut World| {
            kick_partition(sim, w, p);
        });
    }

    // Stage every planned shard migration at training start: prefetch
    // overlaps the first epochs, transfers FIFO-contend on the WAN with
    // gradient syncs (and other jobs on a shared fabric).
    let staged_moves = world.dataplane.as_ref().map_or(0, |d| d.moves.len());
    for m in 0..staged_moves {
        sim.schedule_at(startup_done, move |sim, w: &mut World| {
            migration::begin_move(sim, w, m);
        });
    }

    // Inject resource/WAN churn on the virtual clock. Churn times are
    // job-relative (offset by the job's start); a LinkBandwidth event on a
    // shared fabric mutates the link every sharing job sees — WAN weather
    // is global, not per tenant.
    for ev in world.cfg.churn.clone() {
        match ev {
            ChurnEvent::PowerFactor { t, region, factor } => {
                sim.schedule_at((start_at + t).max(startup_done), move |_, w: &mut World| {
                    if region < w.parts.len() {
                        w.parts[region].power_factor = factor.max(1e-3);
                    }
                });
            }
            ChurnEvent::LinkBandwidth { t, from, to, bps } => {
                sim.schedule_at(start_at + t.max(0.0), move |_, w: &mut World| {
                    w.fabric.set_bandwidth(from, to, bps);
                });
            }
            ChurnEvent::Preemption { t, region } => {
                sim.schedule_at((start_at + t).max(startup_done), move |sim, w: &mut World| {
                    preempt_partition(sim, w, region, 0);
                });
            }
        }
    }

    // Spot revocations from the market's deterministic preemption trace,
    // for every region the plan committed to spot. Times are relative to
    // training start; the trace is cut at 4x the priced horizon — far
    // past any plausible run length, and a revocation event landing
    // after completion is a no-op anyway.
    if let Some(sp) = &world.spot {
        for region in 0..n_parts {
            if sp.markets.get(region) != Some(&Market::Spot) {
                continue;
            }
            for t_rev in sp.market.preemption_times(region, 4.0 * sp.horizon_s) {
                sim.schedule_at(startup_done + t_rev, move |sim, w: &mut World| {
                    preempt_partition(sim, w, region, 0);
                });
            }
        }
    }

    // First monitor tick one interval into training. Compute windows are
    // per-iteration accumulators (they open empty at training start);
    // only the link-bandwidth deltas carry window-start state.
    if world.controller.is_some() {
        let interval = world.cfg.elastic.interval_s.max(1e-3);
        sim.schedule_at(startup_done + interval, move |sim, w: &mut World| {
            monitor_tick(sim, w);
        });
    }

    Ok((sim, world))
}

/// Build the job's report once its simulation reached `global_end`.
/// Whole-job durations (`total_time`, `startup_time`) are measured from
/// the job's own admission (`World::start_at`); per-partition instants
/// stay on the shared virtual clock. WAN bytes/transfers and per-
/// partition wire time come from the job's own counters — on a shared
/// multi-job fabric the link statistics aggregate every tenant.
pub(crate) fn finalize_report(
    world: &World,
    global_end: Time,
    final_loss: f64,
    final_acc: f64,
    wall_seconds: f64,
) -> TrainReport {
    let cost_model = CostModel::default();
    // Billing is segment-based: allocations released or replaced by a
    // mid-run re-plan were closed at their release instant
    // (`closed_billing`); whatever is still held bills to global end.
    let mut billed = world.closed_billing.clone();
    let mut partitions = Vec::new();
    for part in world.parts.iter() {
        for &(dev, n) in &part.alloc.units {
            billed.push(BilledAllocation {
                device: dev,
                units: n,
                held_s: global_end - part.alloc_since,
                rate: billing_rate(world, part.region, dev, part.alloc_since, global_end),
            });
        }
        partitions.push(PartitionReport {
            region: part.region_name.clone(),
            units: part.alloc.total_units(),
            power: part.alloc.power(),
            steps: part.steps_completed,
            local_updates: part.ps.total_updates,
            local_finish: part.local_finish.unwrap_or(global_end),
            waiting: global_end - part.local_finish.unwrap_or(global_end),
            comm_wait: part.slot.waited,
            // comm_wait + this partition's own outgoing serialization
            // time (the on-the-wire share of the paper's "communication
            // time on WAN").
            wan_time: part.slot.waited + part.wire_time,
            syncs_sent: part.ps.sends,
            syncs_received: part.ps.recvs,
            mean_staleness: part.ps.mean_staleness(),
            cold_start_time: part.cold_start_time,
        });
    }
    // Cost split: sync traffic bills at the flat WAN rate; shard
    // migrations (when a data plane ran) bill at their source regions'
    // object-store egress rates instead, plus storage rent on every
    // persisted replica copy; intra-cohort edge uplinks are unmetered
    // (cheap last-mile traffic, not inter-cloud egress) — `wan_bytes`
    // itself counts everything (it must reconcile against the shared
    // fabric's totals plus the analytic uplink model).
    let (dataplane, shard_bytes, egress_cost, storage_cost) = match &world.dataplane {
        Some(dp) => {
            let stall: Time = world.parts.iter().map(|p| p.data_stall).sum();
            let rep = dp.report(stall, world.start_at, global_end);
            let storage = rep.storage_cost;
            (Some(rep), dp.sent_bytes, dp.egress_cost, storage)
        }
        None => (None, 0, 0.0, 0.0),
    };
    let gradient_bytes = world
        .wan_bytes
        .saturating_sub(shard_bytes)
        .saturating_sub(world.fed_uplink_bytes);
    let compute_cost: f64 = billed.iter().map(|a| cost_model.compute_cost(a)).sum();
    let spot_savings: f64 = billed.iter().map(|a| a.savings_vs_on_demand(&cost_model)).sum();
    let wan_cost = cost_model.wan_cost(gradient_bytes);
    let restore_cost = world.spot.as_ref().map_or(0.0, |sp| sp.restore_cost);
    let preemptions: u64 = world.parts.iter().map(|p| p.preemptions as u64).sum();
    let federated = federated_report(world);
    TrainReport {
        model: world.cfg.model.clone(),
        strategy: world.cfg.sync.strategy.name().to_string(),
        topology: world.cfg.topology.name().to_string(),
        sync_freq: world.cfg.sync.freq,
        total_time: global_end - world.start_at,
        startup_time: world.train_start - world.start_at,
        partitions,
        curve: world.curve.clone(),
        final_loss,
        final_accuracy: final_acc,
        wan_bytes: world.wan_bytes,
        wan_transfers: world.wan_transfers,
        cost: compute_cost + wan_cost + egress_cost + storage_cost + restore_cost,
        compute_cost,
        wan_cost,
        egress_cost,
        storage_cost,
        restore_cost,
        preemptions,
        spot_savings,
        wall_seconds,
        pjrt_executions: world.model.exec_counts.get(),
        replan_events: world.replans.clone(),
        dataplane,
        federated,
    }
}

/// Aggregate the edge tier's counters into the report's `federated`
/// block; `None` when the run was flat (no composite partition ever
/// deployed), which keeps flat-run JSON identical to a zero-cohort
/// config.
fn federated_report(world: &World) -> Option<crate::train::metrics::FederatedReport> {
    if !world.cfg.federated.active() || world.parts.iter().all(|p| !p.is_composite()) {
        return None;
    }
    let mut rep = crate::train::metrics::FederatedReport {
        clients: 0,
        cohorts: 0,
        sample_frac: world.cfg.federated.sample_frac,
        dropout: world.cfg.federated.dropout,
        rounds: 0,
        participants: 0,
        dropouts: 0,
        uplink_bytes: world.fed_uplink_bytes,
    };
    for p in &world.parts {
        rep.cohorts += p.cohorts.len();
        for c in &p.cohorts {
            rep.clients += c.clients;
            rep.rounds += c.rounds;
            rep.participants += c.participants;
            rep.dropouts += c.dropouts;
        }
    }
    Some(rep)
}

// ---------------------------------------------------------------- events

/// Start the next worker event on partition `p` — one iteration on the
/// per-worker path, or one *cohort wave* of `wave_size()` iterations
/// under aggregation (`TrainConfig::cohort_threshold`). A wave occupies
/// `wave` pool slots, consumes one batch + one jitter draw + one PS
/// pull, and finishes as one event carrying the whole wave's accounting;
/// with a cohort of 1 every quantity degenerates to exactly the historic
/// per-worker behavior (same RNG stream, same event count).
pub(crate) fn start_worker_iteration(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let b = w.model.meta.batch_size;
    let now = sim.now();
    let part = &mut w.parts[p];
    if part.gate != Gate::Running || part.local_done() {
        return;
    }
    if part.shard.is_empty() {
        // Data-plane staging: every sample this partition will train on
        // is still on the WAN. Gate until the next shard lands
        // (`dataplane::migration::deliver_shard` reopens the pool).
        part.gate = Gate::DataBlocked;
        part.data_blocked_since = now;
        return;
    }
    let wave = part.wave_size();
    if wave == 0 {
        return; // pool saturated (ragged waves self-heal at finishes)
    }
    part.steps_started += wave as u64;
    part.in_flight += wave;
    let (snapshot, version) = part.ps.pull();
    let batch = part.shard.next_batch(b);
    // Deterministic ±25% iteration jitter: serverless pods see real
    // variance (co-tenancy, GC, batch content), and that variance is what
    // makes send slots collide under frequent sync. `power_factor` is the
    // injected churn: a slowed cloud's every iteration stretches.
    let jitter = 0.75 + 0.5 * part.rng.f64();
    let t_iter = part.t_iter * jitter / part.power_factor;
    // Waves capture the partition's preemption epoch at launch: a spot
    // revocation bumps it, marking every in-flight wave stale — its pods
    // are gone, so its completion must not land (the rolled-back steps
    // re-run on the restored pool instead).
    let epoch_guard = part.preempt_epoch;
    sim.schedule(t_iter, move |sim, w: &mut World| {
        finish_worker_iteration(sim, w, p, snapshot, version, batch, t_iter, wave, epoch_guard);
    });
}

#[allow(clippy::too_many_arguments)]
fn finish_worker_iteration(
    sim: &mut Sim<World>,
    w: &mut World,
    p: usize,
    snapshot: Vec<f32>,
    version: u64,
    batch: Vec<usize>,
    iter_s: f64,
    wave: usize,
    epoch_guard: u64,
) {
    if w.parts[p].preempt_epoch != epoch_guard {
        // The pool this wave ran on was revoked mid-flight: its steps
        // were rolled back at preemption time and nothing of it lands —
        // no gradient, no step accounting, no monitor sample.
        return;
    }
    // Real compute: gradient of the model at the pulled snapshot — once
    // per event; a cohort wave's single gradient stands for all `wave`
    // iterations (applied weighted below).
    let (x, y) = w.train_ds.batch(&batch, &w.model.meta);
    let (grads, _loss) = w
        .model
        .train_step(&snapshot, &x, &y)
        .expect("PJRT train_step failed mid-simulation");
    // Step + epoch bookkeeping for every iteration the wave carried; the
    // modeled completion times feed the monitor's per-iteration window
    // (fine-grained even under barriers). One event may close several
    // epochs under aggregation — each crossing is handled in order.
    let mut crossings: Vec<usize> = Vec::new();
    {
        let part = &mut w.parts[p];
        part.in_flight -= wave;
        part.note_iteration_times(iter_s, wave as u64);
        part.ps.push_gradient_weighted(&grads, version, wave as u32);
        for _ in 0..wave {
            if part.note_step_completed() {
                crossings.push(part.epochs_done);
            }
        }
    }
    for epoch in crossings {
        if p == 0 && !w.cfg.skip_eval {
            let every = w.cfg.eval_every.max(1);
            if epoch % every == 0 {
                let (loss, acc) = evaluate(w, 0);
                w.curve.push(EvalPoint { t: sim.now(), epoch, loss, accuracy: acc });
            }
        }
        if p == 0 {
            if let Some(dir) = w.cfg.checkpoint_dir.clone() {
                checkpoint_all(w, &dir);
            }
        }
    }

    // Synchronization condition.
    if w.cfg.sync.should_sync(&w.parts[p].ps) && w.parts[p].gate != Gate::Finished {
        if w.cfg.sync.strategy.is_synchronous() {
            enter_barrier(sim, w, p);
        } else {
            comm::trigger_async_sync(sim, w, p);
        }
    }

    // Continue, block, or finish. A worker only restarts while the pool
    // has room — after an elastic downsize the surplus in-flight
    // iterations drain here instead of respawning.
    match w.parts[p].gate {
        Gate::Running => {
            if !w.parts[p].local_done() {
                if w.parts[p].in_flight < w.parts[p].workers {
                    start_worker_iteration(sim, w, p);
                }
            } else if w.parts[p].in_flight == 0 {
                finish_partition(sim, w, p);
            }
        }
        Gate::AtBarrier => {
            if w.parts[p].in_flight == 0 {
                w.parts[p].barrier_arrived = true;
                w.parts[p].barrier_entry = sim.now();
                try_release_barrier(sim, w);
            }
        }
        Gate::CommBlocked | Gate::DataBlocked | Gate::Preempted | Gate::Finished => {}
    }
}

// ------------------------------------------------- federated edge tier

/// Centralized dispatch: start whatever partition `p` can run — idle
/// worker waves on the flat path, one stage-1 round per idle edge cohort
/// on the composite path. Every restart site (deploy kick, comm unblock,
/// barrier resume, elastic scale-up, shard delivery) routes through
/// here, so flat and composite partitions coexist in one job.
pub(crate) fn kick_partition(sim: &mut Sim<World>, w: &mut World, p: usize) {
    if w.parts[p].gate != Gate::Running || w.parts[p].local_done() {
        return;
    }
    if w.parts[p].is_composite() {
        for c in 0..w.parts[p].cohorts.len() {
            start_cohort_round(sim, w, p, c);
        }
        return;
    }
    let waves = w.parts[p].idle_workers().div_ceil(w.parts[p].cohort.max(1));
    for _ in 0..waves {
        start_worker_iteration(sim, w, p);
    }
}

/// Start one stage-1 round on cohort `c` of composite partition `p`:
/// sample `sample_frac` of the cohort's clients, draw binomial dropout
/// churn, and schedule the round's completion after local client
/// training plus the analytic intra-cohort uplink. The round advances
/// the step budget by the cohort's *full* client population (clamped
/// only at the final ragged round), so sampled and full-participation
/// runs do identical update counts — only uplink traffic differs.
pub(crate) fn start_cohort_round(sim: &mut Sim<World>, w: &mut World, p: usize, c: usize) {
    let b = w.model.meta.batch_size;
    let payload_bytes = (w.parts[p].ps.params.len() * 4) as u64;
    let now = sim.now();
    let (sample_frac, dropout) = (w.cfg.federated.sample_frac, w.cfg.federated.dropout);
    let part = &mut w.parts[p];
    if part.gate != Gate::Running || part.local_done() || part.cohorts[c].in_flight {
        return;
    }
    if part.cohorts[c].shard.is_empty() && part.shard.is_empty() {
        // Data-plane staging: nothing resident on this cloud yet. Gate
        // until the next shard lands (`deliver_shard` reopens the
        // partition and re-kicks it).
        part.gate = Gate::DataBlocked;
        part.data_blocked_since = now;
        return;
    }
    let clients = part.cohorts[c].clients;
    let wave = clients.min(part.steps_total.saturating_sub(part.steps_started));
    if wave == 0 {
        return; // step budget exhausted (final ragged round already ran)
    }
    // Per-round client sampling + dropout-as-churn: dropped clients lose
    // their uploads (lossy uplink), never the cohort's aggregate weight.
    let k = ((sample_frac * clients as f64).round() as u64).clamp(1, clients);
    let dropped = part.rng.binomial(k, dropout);
    let arrived = k - dropped;
    part.steps_started += wave;
    part.in_flight += wave as usize;
    {
        let coh = &mut part.cohorts[c];
        coh.in_flight = true;
        coh.participants += arrived;
        coh.dropouts += dropped;
    }
    let (snapshot, version) = part.ps.pull();
    let batch = if part.cohorts[c].shard.is_empty() {
        part.shard.next_batch(b) // carve was empty: parent's data stands in
    } else {
        part.cohorts[c].shard.next_batch(b)
    };
    let jitter = 0.75 + 0.5 * part.rng.f64();
    let uplink = comm::cohort_uplink(arrived, payload_bytes);
    let t_round = part.t_iter * jitter / part.power_factor + uplink.seconds;
    w.fed_uplink_bytes += uplink.bytes;
    w.wan_bytes += uplink.bytes;
    sim.schedule(t_round, move |sim, w: &mut World| {
        finish_cohort_round(sim, w, p, c, snapshot, version, batch, t_round, wave);
    });
}

/// One stage-1 round completed: the cohort's aggregated gradient lands
/// in the parent's PS state weighted by the full client population
/// (population-reweighted FedAvg — exact update accounting under
/// sampling and dropout), epoch crossings are accounted in bulk, and the
/// parent's ordinary stage-2 WAN sync condition takes over.
#[allow(clippy::too_many_arguments)]
fn finish_cohort_round(
    sim: &mut Sim<World>,
    w: &mut World,
    p: usize,
    c: usize,
    snapshot: Vec<f32>,
    version: u64,
    batch: Vec<usize>,
    iter_s: f64,
    wave: u64,
) {
    let (x, y) = w.train_ds.batch(&batch, &w.model.meta);
    let (grads, _loss) = w
        .model
        .train_step(&snapshot, &x, &y)
        .expect("PJRT train_step failed mid-simulation");
    let first_crossed;
    let crossed;
    {
        let part = &mut w.parts[p];
        part.in_flight -= wave as usize;
        part.cohorts[c].in_flight = false;
        part.cohorts[c].rounds += 1;
        part.note_iteration_times(iter_s, wave);
        part.ps.push_gradient_weighted(&grads, version, wave.min(u32::MAX as u64) as u32);
        first_crossed = part.epochs_done + 1;
        crossed = part.note_steps_completed_bulk(wave);
    }
    for epoch in first_crossed..first_crossed + crossed as usize {
        if p == 0 && !w.cfg.skip_eval {
            let every = w.cfg.eval_every.max(1);
            if epoch % every == 0 {
                let (loss, acc) = evaluate(w, 0);
                w.curve.push(EvalPoint { t: sim.now(), epoch, loss, accuracy: acc });
            }
        }
        if p == 0 {
            if let Some(dir) = w.cfg.checkpoint_dir.clone() {
                checkpoint_all(w, &dir);
            }
        }
    }
    // Stage 2: the parent cloud's ordinary WAN sync condition.
    if w.cfg.sync.should_sync(&w.parts[p].ps) && w.parts[p].gate != Gate::Finished {
        if w.cfg.sync.strategy.is_synchronous() {
            enter_barrier(sim, w, p);
        } else {
            comm::trigger_async_sync(sim, w, p);
        }
    }
    match w.parts[p].gate {
        Gate::Running => {
            if !w.parts[p].local_done() {
                start_cohort_round(sim, w, p, c);
            } else if w.parts[p].in_flight == 0 {
                finish_partition(sim, w, p);
            }
        }
        Gate::AtBarrier => {
            if w.parts[p].in_flight == 0 {
                w.parts[p].barrier_arrived = true;
                w.parts[p].barrier_entry = sim.now();
                try_release_barrier(sim, w);
            }
        }
        Gate::CommBlocked | Gate::DataBlocked | Gate::Preempted | Gate::Finished => {}
    }
}

/// Split the federated client population across clouds proportionally to
/// their final resident sample counts (largest remainder, ties to the
/// lower region id), topping up so every data-holding cloud trains at
/// least one client whenever the population allows.
fn split_clients(total: usize, samples: &[usize]) -> Vec<usize> {
    let mut out = vec![0usize; samples.len()];
    let sum: usize = samples.iter().sum();
    if total == 0 || sum == 0 {
        return out;
    }
    let mut assigned = 0usize;
    let mut rem: Vec<(f64, usize)> = Vec::new();
    for (i, &s) in samples.iter().enumerate() {
        if s == 0 {
            continue;
        }
        let exact = total as f64 * s as f64 / sum as f64;
        out[i] = exact as usize;
        assigned += out[i];
        rem.push((exact - out[i] as f64, i));
    }
    rem.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut left = total.saturating_sub(assigned);
    for &(_, i) in rem.iter().cycle() {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    // Min-one top-up from the most populous cloud (totals stay exact).
    loop {
        let Some(need) = (0..out.len()).find(|&i| samples[i] > 0 && out[i] == 0) else { break };
        let donor = (0..out.len()).max_by_key(|&i| out[i]).expect("non-empty");
        if out[donor] <= 1 {
            break; // fewer clients than data-holding clouds
        }
        out[donor] -= 1;
        out[need] += 1;
    }
    out
}

/// Carve one cloud's resident samples into label-skewed edge cohorts
/// (the composite's stage-1 tier). Deterministic: a pure function of
/// (seed, region, alpha, clients, n_cohorts, resident indices). Client
/// populations and per-cohort label preferences are both
/// Dirichlet(alpha)-drawn — low alpha concentrates clients and labels
/// (severe non-IID), high alpha approaches uniform IID cohorts.
fn build_cohorts(
    ds: &Dataset,
    resident: &[usize],
    clients: u64,
    n_cohorts: usize,
    alpha: f64,
    seed: u64,
    region: usize,
) -> Vec<EdgeCohort> {
    let k = n_cohorts.min(clients.min(usize::MAX as u64) as usize).max(1);
    let mut rng = Pcg32::new(
        seed ^ 0xF3DC_0DE ^ alpha.to_bits().rotate_left(11),
        ((region as u64) << 32) | k as u64,
    );
    // Client populations: Dirichlet proportions via largest remainder,
    // then a min-one top-up (every cohort holds at least one client).
    let props = rng.dirichlet_symmetric(alpha, k);
    let mut counts = vec![0u64; k];
    let mut assigned = 0u64;
    let mut rem: Vec<(f64, usize)> = Vec::new();
    for (c, &w) in props.iter().enumerate() {
        let exact = clients as f64 * w;
        counts[c] = exact as u64;
        assigned += counts[c];
        rem.push((exact - counts[c] as f64, c));
    }
    rem.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut left = clients.saturating_sub(assigned);
    for &(_, c) in rem.iter().cycle() {
        if left == 0 {
            break;
        }
        counts[c] += 1;
        left -= 1;
    }
    loop {
        let Some(need) = (0..k).find(|&c| counts[c] == 0) else { break };
        let donor = (0..k).max_by_key(|&c| counts[c]).expect("non-empty");
        if counts[donor] <= 1 {
            break;
        }
        counts[donor] -= 1;
        counts[need] += 1;
    }
    // Label-skewed sub-shards: group resident indices by label (sorted,
    // so the carve is independent of the parent shard's shuffle order),
    // then split each label's examples across cohorts proportionally to
    // the cohorts' Dirichlet label weights.
    let mut sorted: Vec<usize> = resident.to_vec();
    sorted.sort_unstable();
    let mut by_label: std::collections::BTreeMap<i32, Vec<usize>> = Default::default();
    for &i in &sorted {
        by_label.entry(label_of(ds, i)).or_default().push(i);
    }
    let n_labels = by_label.len().max(1);
    let weights: Vec<Vec<f64>> = (0..k).map(|_| rng.dirichlet_symmetric(alpha, n_labels)).collect();
    let mut cohort_idxs: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (l, idxs) in by_label.values().enumerate() {
        // Normalize this label's column over cohorts; largest remainder.
        let col_sum: f64 = weights.iter().map(|w| w[l]).sum();
        let mut shares = vec![0usize; k];
        let mut taken = 0usize;
        let mut lrem: Vec<(f64, usize)> = Vec::new();
        for c in 0..k {
            let share = if col_sum > 0.0 { weights[c][l] / col_sum } else { 1.0 / k as f64 };
            let exact = idxs.len() as f64 * share;
            shares[c] = exact as usize;
            taken += shares[c];
            lrem.push((exact - shares[c] as f64, c));
        }
        lrem.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let mut left = idxs.len().saturating_sub(taken);
        for &(_, c) in lrem.iter().cycle() {
            if left == 0 {
                break;
            }
            shares[c] += 1;
            left -= 1;
        }
        let mut cursor = 0usize;
        for c in 0..k {
            cohort_idxs[c].extend_from_slice(&idxs[cursor..cursor + shares[c]]);
            cursor += shares[c];
        }
    }
    counts
        .into_iter()
        .zip(cohort_idxs)
        .enumerate()
        .map(|(c, (n, idxs))| {
            // A stream disjoint from partition shards (stream = region)
            // and cohort carves elsewhere: high bit + region page.
            let stream = (1u64 << 40) | ((region as u64) << 20) | c as u64;
            EdgeCohort::new(n, Shard::new(idxs, seed, stream), weights[c].clone())
        })
        .collect()
}

/// One example's label key for cohort-skew grouping: classifier labels
/// directly, CTR's binary f32 labels as 0/1, the first token of an LM
/// window, 0 when the dataset carries no labels at all.
fn label_of(ds: &Dataset, i: usize) -> i32 {
    let i = i % ds.n.max(1);
    if !ds.y_is_f32 && !ds.y_i32.is_empty() {
        ds.y_i32[i * ds.y_elems]
    } else if ds.y_is_f32 && !ds.y_f32.is_empty() {
        (ds.y_f32[i * ds.y_elems] > 0.5) as i32
    } else {
        0
    }
}

// ------------------------------------------------------------- barrier

fn enter_barrier(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let part = &mut w.parts[p];
    if part.gate != Gate::Running {
        return;
    }
    part.gate = Gate::AtBarrier;
    if part.in_flight == 0 {
        part.barrier_arrived = true;
        part.barrier_entry = sim.now();
        try_release_barrier(sim, w);
    }
    // else: the last in-flight completion marks arrival.
}

pub(crate) fn try_release_barrier(sim: &mut Sim<World>, w: &mut World) {
    if !w.all_arrived() {
        return;
    }
    let now = sim.now();
    let active: Vec<usize> =
        (0..w.parts.len()).filter(|&i| w.parts[i].gate == Gate::AtBarrier).collect();
    if active.is_empty() {
        return;
    }
    // Exchange parameters along the topology; everyone resumes at the
    // latest arrival (a true barrier).
    let release_at = comm::barrier_exchange(sim, w, &active, now);
    for &p in &active {
        let entry = w.parts[p].barrier_entry;
        w.parts[p].slot.waited += release_at - entry;
        w.parts[p].barrier_arrived = false;
        sim.schedule_at(release_at, move |sim, w: &mut World| {
            resume_from_barrier(sim, w, p);
        });
    }
}

fn resume_from_barrier(sim: &mut Sim<World>, w: &mut World, p: usize) {
    if w.parts[p].gate != Gate::AtBarrier {
        return;
    }
    w.parts[p].gate = Gate::Running;
    if w.parts[p].local_done() {
        if w.parts[p].in_flight == 0 {
            finish_partition(sim, w, p);
        }
        return;
    }
    kick_partition(sim, w, p);
}

// ------------------------------------------------------------- finish

pub(crate) fn finish_partition(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let now = sim.now();
    if w.parts[p].gate == Gate::Finished {
        return;
    }
    // Ship any residual accumulated state before shutting down workers.
    if w.parts[p].ps.updates_since_sync > 0 && !w.plan.outgoing(p).is_empty() {
        comm::perform_send(sim, w, p);
    }
    let part = &mut w.parts[p];
    part.gate = Gate::Finished;
    part.local_finish = Some(now);
    // Serverless: worker functions terminate immediately on local finish.
    let reps = part.worker_replicas.clone();
    for r in reps {
        w.faas.terminate(r, now);
    }
    w.n_finished += 1;
    if w.n_finished == w.parts.len() {
        w.global_end = Some(now);
    } else if w.cfg.sync.strategy.is_synchronous() {
        // A finished partition no longer blocks the barrier.
        try_release_barrier(sim, w);
    }
}

// ------------------------------------------------------ spot preemption

/// The market rate a billing segment in `region` carries over `[t0, t1]`:
/// the spot trace's average price multiplier when the plan committed the
/// region to the spot market, 1.0 (on-demand) otherwise.
fn billing_rate(w: &World, region: usize, dev: Device, t0: Time, t1: Time) -> f64 {
    match &w.spot {
        Some(sp) if sp.markets.get(region) == Some(&Market::Spot) => {
            sp.market.avg_price_mult(region, dev, t0, t1)
        }
        _ => 1.0,
    }
}

/// A spot-market revocation landed on region `p`: bill the revoked
/// segment at the spot rate, checkpoint the PS, roll back in-flight work
/// (those pods are gone — their completions are discarded by the
/// preemption-epoch guard and their steps re-run after restore, so
/// step/epoch/update totals stay exact), tear the pool down through the
/// autoscaler, and schedule the restore one `restore_stall_s` later.
///
/// Revocation is only safe while the partition is freely `Running`: a
/// partition holding a protocol invariant (mid-barrier, comm- or
/// data-blocked) retries shortly; a revocation that keeps missing, or
/// lands on a finished/locally-done/composite partition, is dropped
/// (composite partitions run edge clients, not spot cloud pools).
pub(crate) fn preempt_partition(sim: &mut Sim<World>, w: &mut World, p: usize, retries: u32) {
    let now = sim.now();
    if w.spot.is_none() || w.global_end.is_some() || p >= w.parts.len() {
        return; // spot disabled (injected churn is ignored) or job done
    }
    if w.parts[p].gate == Gate::Finished || w.parts[p].local_done() || w.parts[p].is_composite()
    {
        return;
    }
    if w.parts[p].gate != Gate::Running {
        if retries < 200 {
            sim.schedule(1.0, move |sim, w: &mut World| {
                preempt_partition(sim, w, p, retries + 1);
            });
        }
        return;
    }
    // Close the revoked allocation's billing segment at the spot rate —
    // the seconds before the revocation were real, paid capacity. The
    // stall window that follows is unbilled (the capacity is gone);
    // billing re-opens when the replacement pool is acquired.
    let since = w.parts[p].alloc_since;
    let closed: Vec<BilledAllocation> = w.parts[p]
        .alloc
        .units
        .iter()
        .map(|&(dev, n)| BilledAllocation {
            device: dev,
            units: n,
            held_s: now - since,
            rate: billing_rate(w, p, dev, since, now),
        })
        .collect();
    w.closed_billing.extend(closed);
    // Checkpoint at the revocation instant; the restored pool resumes
    // from exactly these bytes. In this simulation the PS state never
    // physically leaves memory, so the capture is the recovery point and
    // what the revocation costs is the save + fetch WAN traffic.
    let ckpt = crate::train::checkpoint::PsCheckpoint::capture(&w.parts[p].ps);
    let ckpt_bytes = (36 + ckpt.params.len() * 8) as u64;
    let restore_fee = CostModel::default().wan_cost(2 * ckpt_bytes);
    if let Some(sp) = w.spot.as_mut() {
        sp.restore_cost += restore_fee;
    }
    {
        let part = &mut w.parts[p];
        let lost = part.in_flight as u64;
        part.steps_started -= lost;
        part.in_flight = 0;
        part.preempt_epoch += 1;
        part.preemptions += 1;
        part.gate = Gate::Preempted;
        // Iterations recorded under the revoked pool no longer measure
        // anything the controller should trust.
        part.reset_monitor_window();
    }
    let key = w.worker_keys[p].clone();
    autoscaler::resize_pool(&mut w.faas, &key, 0, now)
        .expect("worker pool registered at deploy time");
    w.parts[p].worker_replicas = Vec::new();
    // The controller learns immediately (hysteresis bypass) instead of
    // waiting for the revoked region's stall to show up in samples.
    if let Some(ctrl) = w.controller.as_mut() {
        ctrl.note_preemption(p);
    }
    let stall = w.cfg.spot.restore_stall_s.max(0.0);
    sim.schedule(stall, move |sim, w: &mut World| {
        restore_partition(sim, w, p);
    });
}

/// The spot stall elapsed: re-acquire region `p`'s worker pool through
/// the autoscaler (replacement capacity cold-starts like any elastic
/// scale-up), open a fresh billing segment at the restore instant, and
/// resume training from the checkpointed PS state. The steps rolled back
/// at revocation re-run from here — totals conserve; the run just takes
/// longer.
pub(crate) fn restore_partition(sim: &mut Sim<World>, w: &mut World, p: usize) {
    let now = sim.now();
    if w.global_end.is_some() || w.parts[p].gate != Gate::Preempted {
        return;
    }
    let workers = w.parts[p].workers;
    let key = w.worker_keys[p].clone();
    let (spawned, live) = autoscaler::resize_pool(&mut w.faas, &key, workers as u32, now)
        .expect("worker pool registered at deploy time");
    let mut ready_at = now;
    for id in &spawned {
        if let Some(r) = w.faas.replica(*id) {
            ready_at = ready_at.max(r.ready_at);
        }
        w.faas.mark_ready(*id);
    }
    {
        let part = &mut w.parts[p];
        part.worker_replicas = live;
        part.alloc_since = now;
        part.gate = Gate::Running;
    }
    // A rebalance may have drained the shard while the region was down.
    if w.parts[p].local_done() {
        if w.parts[p].in_flight == 0 {
            finish_partition(sim, w, p);
        }
        return;
    }
    sim.schedule_at(ready_at, move |sim, w: &mut World| {
        kick_partition(sim, w, p);
    });
}

// ---------------------------------------------------- elastic control loop

/// One control-loop tick: sample the running system, feed the controller,
/// apply whatever re-plan it commits, and re-arm the next tick (the loop
/// stops once the job completes).
pub(crate) fn monitor_tick(sim: &mut Sim<World>, w: &mut World) {
    if w.global_end.is_some() {
        return; // job done — let the event heap drain
    }
    let sample = collect_sample(sim.now(), w);
    let decision = match w.controller.as_mut() {
        Some(ctrl) => ctrl.observe(&sample),
        None => None,
    };
    if let Some(dec) = decision {
        apply_replan(sim, w, &dec);
    }
    let interval = w.cfg.elastic.interval_s.max(1e-3);
    sim.schedule(interval, move |sim, w: &mut World| {
        monitor_tick(sim, w);
    });
}

/// Build the monitoring sample: per-cloud mean per-iteration completion
/// time over the window (recorded at each iteration's finish, so
/// barrier-heavy SMA runs sample at full rate — wall-clock windows only
/// saw freely-running stretches) and per-planned-link delivered
/// bandwidth from the fabric's transfer statistics.
fn collect_sample(now: Time, w: &mut World) -> MonitorSample {
    let mut power_scale = Vec::with_capacity(w.parts.len());
    let mut mean_iter_s = Vec::with_capacity(w.parts.len());
    let finished: Vec<bool> = w.parts.iter().map(|p| p.gate == Gate::Finished).collect();
    for part in &mut w.parts {
        let mean = if part.win_iter_count > 0 {
            Some(part.win_iter_sum / part.win_iter_count as f64)
        } else {
            None
        };
        // Iteration completion times measure compute directly (waits are
        // never inside them); wind-down windows (every step started) and
        // finished partitions still carry no re-plannable signal.
        let scale = match mean {
            Some(m) if part.gate != Gate::Finished && !part.local_done() && m > 0.0 => {
                Some(part.t_iter / m)
            }
            _ => None,
        };
        mean_iter_s.push(mean);
        power_scale.push(scale);
        part.reset_monitor_window();
    }
    // Delivered bandwidth per planned edge over THIS window: byte and
    // stream-time deltas since the previous tick (setup overhead is
    // excluded so small payloads still read the line rate, and window
    // deltas — unlike run-lifetime averages — register a late-run
    // collapse immediately; the controller's EWMA smooths fluctuation
    // noise). Quiet windows produce no sample.
    let mut link_bw = Vec::new();
    for p in 0..w.parts.len() {
        for e in w.plan.outgoing(p) {
            let (from, to) = (w.parts[p].region, w.parts[e.to].region);
            if let Some(s) = w.fabric.stats(from, to) {
                let last = w.mon_link_last.insert((from, to), (s.bytes, s.stream_time));
                let (b0, t0) = last.unwrap_or((0, 0.0));
                let (db, dt_s) = (s.bytes.saturating_sub(b0), s.stream_time - t0);
                if db > 0 && dt_s > 1e-12 {
                    link_bw.push((from, to, db as f64 * 8.0 / dt_s));
                }
            }
        }
    }
    MonitorSample { t: now, power_scale, mean_iter_s, finished, link_bw }
}

/// Apply a committed re-plan mid-run: resize every changed partition's
/// worker pool through the FaaS autoscaler (billing released and spawned
/// replicas at this instant), retime its iterations, and — when the
/// observed WAN diverged — re-plan the sync topology against the
/// controller's bandwidth view.
fn apply_replan(sim: &mut Sim<World>, w: &mut World, dec: &ReplanDecision) {
    let now = sim.now();
    let mut load_changed = false;
    if dec.plan_delta > 0.0 {
        load_changed = resize_to_allocations(sim, w, &dec.allocations);
    }
    let mut topology_replanned = false;
    if dec.replan_topology {
        // Re-plan who-talks-to-whom against the *observed* WAN: a scratch
        // fabric carrying the controller's bandwidth view feeds the same
        // planner the run launched with.
        let mut observed = Fabric::new(w.cfg.seed);
        for &(from, to, bps) in &dec.bw_view {
            observed.add_link(from, to, LinkSpec { bandwidth_bps: bps, ..w.cfg.link.clone() });
        }
        w.plan = w.cfg.topology.plan_with(w.parts.len(), &observed, w.cfg.relay_routes);
        topology_replanned = true;
    }
    // Elastic per-link compression: install the controller's codec
    // reassignments; `comm::perform_send` reads them per edge at the next
    // sync, so the switch takes effect at payload granularity.
    let mut compression_changes: Vec<(usize, usize, String)> = Vec::new();
    for &(from, to, codec) in &dec.codec_changes {
        let wire = match codec {
            LinkCodec::None => Compression::None,
            LinkCodec::TopK => Compression::TopK { ratio: 0.01 },
            LinkCodec::Q8 => Compression::Q8,
        };
        w.link_codecs.insert((from, to), wire);
        compression_changes.push((from, to, codec.name().to_string()));
    }
    // Data-plane rebalancing rides only on *committed* load re-plans
    // (the same hysteresis gate), so observed-power drift can relocate
    // shards away from a persistently slowed cloud.
    let data_moves = if load_changed && w.cfg.dataplane.rebalance {
        maybe_rebalance(sim, w)
    } else {
        0
    };
    if !load_changed
        && !topology_replanned
        && compression_changes.is_empty()
        && !dec.preemption_triggered
    {
        return;
    }
    let mut causes: Vec<&str> = Vec::new();
    if dec.preemption_triggered {
        causes.push(replan_cause::PREEMPTION);
    }
    if load_changed {
        causes.push(replan_cause::LOAD);
    }
    if topology_replanned {
        causes.push(replan_cause::BANDWIDTH);
    }
    if !compression_changes.is_empty() {
        causes.push(replan_cause::COMPRESSION);
    }
    w.replans.push(ReplanEvent {
        t: now,
        cause: causes.join("+"),
        plan_delta: dec.plan_delta,
        straggler: dec.straggler,
        units: w.parts.iter().map(|p| p.alloc.total_units()).collect(),
        topology_replanned,
        data_moves,
        compression_changes,
    });
}

/// Propose and execute mid-run shard rebalancing after a committed load
/// re-plan: re-run the joint placement climb over the *remaining* work
/// at the controller's observed power scales, and execute any move whose
/// payoff clears a 5% objective margin (the data plane's hysteresis).
/// Sources shed their samples immediately (step budgets retimed);
/// destinations gain theirs when the shard physically lands. Returns the
/// number of moves put on the WAN.
///
/// Only the `joint` placement mode rebalances — the pure modes promise a
/// fixed migration story (compute-follows-data: zero moves) — and
/// finished partitions are masked out of the climb: a shard landing on a
/// finished partition would silently drop its remaining epochs.
fn maybe_rebalance(sim: &mut Sim<World>, w: &mut World) -> usize {
    if w.cfg.dataplane.mode != crate::dataplane::PlacementMode::Joint {
        return 0;
    }
    let scales = match w.controller.as_ref() {
        Some(c) => c.scales().to_vec(),
        None => return 0,
    };
    match w.dataplane.as_ref() {
        // One settled staging at a time, and at most a couple of
        // rebalancing rounds per run — migration churn is never free.
        Some(dp) if dp.pending == 0 && dp.rebalances < 2 => {}
        _ => return 0,
    }
    let remaining_epochs = w
        .parts
        .iter()
        .filter(|p| p.gate != Gate::Finished)
        .map(|p| w.cfg.epochs.saturating_sub(p.epochs_done))
        .max()
        .unwrap_or(0);
    if remaining_epochs < 2 {
        return 0; // not enough run left to amortize a transfer
    }
    let movable: Vec<bool> = w.parts.iter().map(|p| p.gate != Gate::Finished).collect();
    let moves = {
        let dp = w.dataplane.as_ref().expect("checked above");
        let links = w.fabric.with(|f| PlanInputs::link_view(f, w.env.regions.len()));
        let time_value = if w.cfg.dataplane.time_value_per_hour > 0.0 {
            w.cfg.dataplane.time_value_per_hour
        } else {
            placement::default_time_value_per_hour(&w.env, &dp.cost)
        };
        let inputs = PlanInputs {
            env: &w.env,
            catalog: &dp.catalog,
            epochs: remaining_epochs,
            base_step_s: w.base_step,
            batch_size: w.model.meta.batch_size,
            links,
            cost: dp.cost.clone(),
            scale: scales,
            time_value_per_hour: time_value,
            rate_scale: match &w.spot {
                Some(sp) => {
                    crate::cloud::spot::rate_scale(&w.env, Some(&sp.market), sp.horizon_s)
                }
                None => vec![1.0; w.env.regions.len()],
            },
        };
        placement::rebalance(&inputs, 0.05, &movable, &dp.assign)
    };
    let moves = {
        // A shed shard's work was already reported lost (abandoned
        // transfer); re-planning it would silently resurrect samples
        // `failed_shards` counted out.
        let dp = w.dataplane.as_ref().expect("data plane active");
        let mut moves = moves;
        moves.retain(|m| !dp.shed[m.shard]);
        moves
    };
    if moves.is_empty() {
        return 0;
    }
    let batch = w.model.meta.batch_size;
    let epochs = w.cfg.epochs;
    let count = moves.len();
    for mv in moves {
        // The region shedding the samples is the shard's *current
        // trainer* — with replica sets that need not be the physical
        // source the bytes stream from (`mv.from`).
        let (start, end, src) = {
            let dp = w.dataplane.as_ref().expect("data plane active");
            let s = &dp.catalog.shards[mv.shard];
            (s.start, s.end, dp.assign[mv.shard])
        };
        {
            let part = &mut w.parts[src];
            part.shard.remove_range(start, end);
            part.retime_step_budget(batch, epochs, 0);
        }
        // A source drained to nothing finishes once its in-flight work
        // lands; if it is already idle, close it out now.
        if w.parts[src].gate == Gate::Running
            && w.parts[src].local_done()
            && w.parts[src].in_flight == 0
        {
            finish_partition(sim, w, src);
        }
        let idx = {
            let dp = w.dataplane.as_mut().expect("data plane active");
            dp.assign[mv.shard] = mv.to;
            dp.enqueue(mv, (start..end).collect(), true)
        };
        migration::begin_move(sim, w, idx);
    }
    w.dataplane.as_mut().expect("data plane active").rebalances += 1;
    // Keep the controller's residency view in sync with the assignment
    // the moves produce (its candidates must plan the new data map).
    sync_controller_residency(w);
    count
}

/// Re-derive the elastic controller's per-region residency from the data
/// plane's current training assignment (after rebalance commits and
/// delivery-time re-routes); no-op without a controller or data plane.
pub(crate) fn sync_controller_residency(w: &mut World) {
    let assigned = match w.dataplane.as_ref() {
        Some(dp) => dp.assigned_samples(),
        None => return,
    };
    if let Some(ctrl) = w.controller.as_mut() {
        ctrl.update_residency(&assigned);
    }
}

/// Resize every changed partition's worker pool to `allocations` through
/// the FaaS autoscaler: close the outgoing allocation's billing segment,
/// spawn/terminate replicas (spawned ones cold-start before joining the
/// loop), retime iterations, and re-open the monitoring window. Finished
/// partitions and unchanged allocations are skipped; returns whether
/// anything moved. Shared by the job's own elastic re-plans and the
/// multi-job coordinator's lease re-divisions (`apply_lease`).
pub(crate) fn resize_to_allocations(
    sim: &mut Sim<World>,
    w: &mut World,
    allocations: &[Allocation],
) -> bool {
    let now = sim.now();
    let mut changed = false;
    for p in 0..w.parts.len() {
        if w.parts[p].gate == Gate::Finished || w.parts[p].gate == Gate::Preempted {
            // A revoked pool cannot be resized — there is nothing there;
            // the restore path re-acquires it at its pre-revocation size.
            continue;
        }
        if w.parts[p].is_composite() {
            // Elastic resizing targets cloud worker pools; a composite
            // partition's pool is its fixed edge-client population and
            // its cloud footprint is the per-cohort aggregators.
            continue;
        }
        let new_alloc = allocations[p].clone();
        if new_alloc.units == w.parts[p].alloc.units {
            continue;
        }
        changed = true;
        // Close the billing segment of the outgoing allocation (at the
        // segment's market rate — a spot region's seconds were cheaper).
        let since = w.parts[p].alloc_since;
        let closed: Vec<BilledAllocation> = w.parts[p]
            .alloc
            .units
            .iter()
            .map(|&(dev, n)| BilledAllocation {
                device: dev,
                units: n,
                held_s: now - since,
                rate: billing_rate(w, p, dev, since, now),
            })
            .collect();
        w.closed_billing.extend(closed);
        let is_gpu = new_alloc
            .units
            .first()
            .map(|(d, _)| d.info().kind == DeviceKind::Gpu)
            .unwrap_or(false);
        let workers = calib::worker_count(new_alloc.total_units(), is_gpu, w.cfg.worker_cores);
        // Resize the serverless pool (spawned replicas cold-start;
        // released ones terminate now and stop billing).
        let key = w.worker_keys[p].clone();
        let (spawned, live) = autoscaler::resize_pool(&mut w.faas, &key, workers as u32, now)
            .expect("worker pool registered at deploy time");
        let mut ready_at = now;
        for id in &spawned {
            if let Some(r) = w.faas.replica(*id) {
                ready_at = ready_at.max(r.ready_at);
            }
            w.faas.mark_ready(*id);
        }
        let part = &mut w.parts[p];
        part.worker_replicas = live;
        part.workers = workers;
        part.cohort = super::partition::cohort_size(workers, w.cfg.cohort_threshold);
        let w_power = calib::worker_power(new_alloc.power(), workers);
        part.t_iter = calib::iter_time(w.base_step, w_power);
        part.alloc = new_alloc;
        part.alloc_since = now;
        // Reset the monitoring window: iterations recorded under the old
        // pool's `t_iter` no longer measure the new expectation.
        part.reset_monitor_window();
        if !spawned.is_empty() {
            // Newly-spawned workers join the loop after cold start.
            sim.schedule_at(ready_at, move |sim, w: &mut World| {
                kick_idle_workers(sim, w, p);
            });
        }
    }
    changed
}

/// Apply a multi-job coordinator lease re-division to this running job:
/// resize its worker pools to the new within-lease `allocations`
/// (preemption-by-resize — a shrunk job keeps running, smaller) and
/// re-base its elastic controller on the leased inventory so subsequent
/// self re-plans stay inside the lease. Records a `"lease"` re-plan event
/// (straggler is carried from the job's own within-lease plan).
pub(crate) fn apply_lease(
    sim: &mut Sim<World>,
    w: &mut World,
    lease_env: &CloudEnv,
    allocations: &[Allocation],
    straggler: usize,
) {
    if w.global_end.is_some() {
        return; // the job finished while the lease event was in flight
    }
    let old_units: Vec<u32> = w.parts.iter().map(|p| p.alloc.total_units()).collect();
    let changed = resize_to_allocations(sim, w, allocations);
    // The job's planning view of its inventory follows the lease: both
    // the elastic controller and the data-plane rebalancer must plan
    // against compute the job actually holds.
    w.env = lease_env.clone();
    if let Some(ctrl) = w.controller.as_mut() {
        ctrl.reset_lease(lease_env.clone(), allocations);
    }
    if changed {
        w.replans.push(ReplanEvent {
            t: sim.now(),
            cause: replan_cause::LEASE.to_string(),
            plan_delta: crate::sched::elastic::plan_delta(&old_units, allocations),
            straggler,
            units: w.parts.iter().map(|p| p.alloc.total_units()).collect(),
            topology_replanned: false,
            data_moves: 0,
            compression_changes: Vec::new(),
        });
    }
}

/// Start work on any idle capacity (used after an elastic scale-up once
/// the new replicas finish cold-starting, and after a staged shard
/// lands). Thin alias over the centralized [`kick_partition`] dispatch.
pub(crate) fn kick_idle_workers(sim: &mut Sim<World>, w: &mut World, p: usize) {
    kick_partition(sim, w, p);
}

// --------------------------------------------------------- checkpoints

/// Persist every partition's PS state (fault-tolerance; see
/// `train::checkpoint`). Failures are logged, not fatal — a missed
/// checkpoint must never kill training.
fn checkpoint_all(w: &World, dir: &std::path::Path) {
    use crate::train::checkpoint::{CheckpointStore, PsCheckpoint};
    match CheckpointStore::new(dir) {
        Ok(store) => {
            for part in &w.parts {
                let ckpt = PsCheckpoint::capture(&part.ps);
                if let Err(e) = store.save(&part.region_name, &ckpt) {
                    eprintln!("checkpoint {} failed: {e}", part.region_name);
                }
            }
            let regions: Vec<(&str, u64)> =
                w.parts.iter().map(|p| (p.region_name.as_str(), p.ps.total_updates)).collect();
            let _ = store.write_manifest(
                &w.cfg.model,
                w.cfg.sync.strategy.name(),
                w.cfg.topology.name(),
                &regions,
            );
        }
        Err(e) => eprintln!("checkpoint store: {e}"),
    }
}

// --------------------------------------------------------------- eval

/// Evaluate partition `p`'s model over the eval set (real compute;
/// measurement only, takes no virtual time).
pub(crate) fn evaluate(w: &World, p: usize) -> (f64, f64) {
    let meta = &w.model.meta;
    let b = meta.batch_size;
    let n = w.eval_ds.n;
    let params = &w.parts[p].ps.params;
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    let mut counted = 0usize;
    let mut i = 0;
    while i < n {
        let idxs: Vec<usize> = (i..i + b).map(|j| j % n).collect();
        let take = b.min(n - i);
        let (x, y) = w.eval_ds.batch(&idxs, meta);
        let (ls, c) = w.model.eval_batch(params, &x, &y).expect("eval failed");
        // full batches only contribute `take` examples' worth: the wrap
        // tail double-counts a few examples; acceptable for curves.
        loss_sum += ls as f64 * take as f64 / b as f64;
        correct += c as f64 * take as f64 / b as f64;
        counted += take;
        i += b;
    }
    (loss_sum / counted as f64, correct / counted as f64)
}

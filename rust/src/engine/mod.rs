//! The geo-distributed training engine — the layered successor of the
//! seed's `train/geo.rs` monolith.
//!
//! ```text
//! driver    discrete-event loop over sim::Sim     (paper §III.A plane)
//!   │          barriers, epochs, eval, reports
//!   ▼
//! partition  per-cloud actor: worker gating, PS   (paper §III.A pods)
//!   │          state, step accounting
//!   ▼
//! comm       WAN communicator: payload planning,  (paper §III.C mech)
//!   │          send-slot backpressure, delivery
//!   ▼
//! topology   pluggable N-cloud sync shapes with   (paper §III.C + GeoMX
//!   │          Metropolis per-edge avg weights      HiPS, arXiv 2404.11352)
//!   ▼
//! net::Fabric  link model (serialization, FIFO, fluctuation)
//! ```
//!
//! The public entry point is [`driver::run_geo_training`] (re-exported
//! through `train::geo` for source compatibility with the seed). The
//! topology layer is the new extension axis: implement [`Topology`] to
//! plug in a custom N-cloud sync shape, or pick one of [`Ring`],
//! [`Hierarchical`], [`BandwidthTree`] via [`TopologyKind`].

pub mod comm;
pub mod driver;
pub mod partition;
pub mod topology;

pub use driver::{default_lr, run_geo_training, ChurnEvent, TrainConfig};
pub use topology::{
    sequential_weight, BandwidthTree, Hierarchical, PlanEdge, Ring, SyncPlan, Topology,
    TopologyKind,
};

//! The per-cloud training partition — the stateful actor behind one
//! region's serverless training workflow (PS + PS-communicator + worker
//! functions), reproducing the paper's §III.A physical training plane.
//!
//! A [`Partition`] owns the region's PS state, its worker-pool gating
//! (the paper's ElasticDL-derived pods), and step/epoch accounting. The
//! WAN side of the actor (send slot, backpressure clock) lives in
//! [`super::comm::SendSlot`]; the event loop that drives it lives in
//! [`super::driver`].

use crate::cloud::Allocation;
use crate::data::Shard;
use crate::faas::ReplicaId;
use crate::ps::PsState;
use crate::sim::Time;
use crate::util::rng::Pcg32;

use super::comm::SendSlot;

/// What a partition's worker pool is currently allowed to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// Workers iterate freely (asynchronous local SGD).
    Running,
    /// Blocked on the PS communicator's send slot (WAN backpressure).
    CommBlocked,
    /// Waiting at a synchronous-strategy barrier (SMA).
    AtBarrier,
    /// All local epochs done; worker functions terminated.
    Finished,
}

/// One cloud-level training partition (the seed's `Part`, extracted).
pub struct Partition {
    /// Region / partition index (identical by construction).
    pub region: usize,
    pub region_name: String,
    pub alloc: Allocation,
    pub shard: Shard,
    pub ps: PsState,
    /// Concurrent worker functions (ElasticDL pod granularity). Live —
    /// the elastic control loop resizes this mid-run; in-flight
    /// iterations beyond a shrunk pool drain without restarting.
    pub workers: usize,
    /// Modeled seconds per worker iteration at *catalog* power for the
    /// current allocation (recomputed on every re-plan).
    pub t_iter: f64,
    /// Observed-compute multiplier from resource churn injection: actual
    /// iteration time is `t_iter / power_factor` (1.0 = nominal, 0.5 =
    /// the cloud lost half its effective compute to co-tenancy).
    pub power_factor: f64,
    pub steps_total: u64,
    pub steps_started: u64,
    pub steps_completed: u64,
    pub epoch_steps: u64,
    pub epochs_done: usize,
    pub gate: Gate,
    /// Worker iterations currently in flight.
    pub in_flight: usize,
    /// The PS communicator's send slot (backpressure state).
    pub slot: SendSlot,
    /// Accumulated on-the-wire serialization seconds of this partition's
    /// own outgoing WAN payloads. Counted per transfer at send time — a
    /// shared multi-job fabric's link statistics aggregate every job's
    /// traffic, so per-job reports must not read them.
    pub wire_time: Time,
    pub local_finish: Option<Time>,
    pub barrier_arrived: bool,
    pub barrier_entry: Time,
    pub cold_start_time: Time,
    pub worker_replicas: Vec<ReplicaId>,
    /// Virtual time the current allocation took effect (billing-segment
    /// start; 0.0 until the first elastic re-plan).
    pub alloc_since: Time,
    /// Monitoring window state: time / completed steps / blocked seconds
    /// at the last control-loop sample.
    pub mon_last_t: Time,
    pub mon_last_steps: u64,
    pub mon_last_waited: Time,
    /// Deterministic per-partition jitter stream.
    pub rng: Pcg32,
}

impl Partition {
    /// True once every planned local step has been started.
    pub fn local_done(&self) -> bool {
        self.steps_started >= self.steps_total
    }

    /// Workers currently idle (available to restart after an unblock).
    /// Saturating: after an elastic downsize, in-flight iterations may
    /// briefly exceed the pool while the extra ones drain.
    pub fn idle_workers(&self) -> usize {
        self.workers.saturating_sub(self.in_flight)
    }

    /// True when the just-completed step closed a local epoch.
    pub fn at_epoch_boundary(&self) -> bool {
        self.epoch_steps > 0 && self.steps_completed % self.epoch_steps == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part() -> Partition {
        Partition {
            region: 0,
            region_name: "test".into(),
            alloc: Allocation::new(0, vec![]),
            shard: Shard::new(vec![0, 1, 2, 3], 1, 0),
            ps: PsState::new(vec![0.0; 4], 0.1),
            workers: 4,
            t_iter: 1.0,
            power_factor: 1.0,
            steps_total: 8,
            steps_started: 0,
            steps_completed: 0,
            epoch_steps: 4,
            epochs_done: 0,
            gate: Gate::Running,
            in_flight: 0,
            slot: SendSlot::default(),
            wire_time: 0.0,
            local_finish: None,
            barrier_arrived: false,
            barrier_entry: 0.0,
            cold_start_time: 0.0,
            worker_replicas: Vec::new(),
            alloc_since: 0.0,
            mon_last_t: 0.0,
            mon_last_steps: 0,
            mon_last_waited: 0.0,
            rng: Pcg32::new(1, 0),
        }
    }

    #[test]
    fn step_accounting() {
        let mut p = part();
        assert!(!p.local_done());
        assert_eq!(p.idle_workers(), 4);
        p.steps_started = 8;
        p.in_flight = 3;
        assert!(p.local_done());
        assert_eq!(p.idle_workers(), 1);
    }

    #[test]
    fn idle_workers_saturates_after_downsize() {
        let mut p = part();
        p.in_flight = 4;
        p.workers = 2; // elastic scale-down while 4 iterations in flight
        assert_eq!(p.idle_workers(), 0, "must not underflow");
    }

    #[test]
    fn epoch_boundary_detection() {
        let mut p = part();
        p.steps_completed = 3;
        assert!(!p.at_epoch_boundary());
        p.steps_completed = 4;
        assert!(p.at_epoch_boundary());
        p.steps_completed = 8;
        assert!(p.at_epoch_boundary());
    }
}

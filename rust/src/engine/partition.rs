//! The per-cloud training partition — the stateful actor behind one
//! region's serverless training workflow (PS + PS-communicator + worker
//! functions), reproducing the paper's §III.A physical training plane.
//!
//! A [`Partition`] owns the region's PS state, its worker-pool gating
//! (the paper's ElasticDL-derived pods), and step/epoch accounting. The
//! WAN side of the actor (send slot, backpressure clock) lives in
//! [`super::comm::SendSlot`]; the event loop that drives it lives in
//! [`super::driver`]; shard arrivals that feed it come from
//! [`crate::dataplane::migration`].

use crate::cloud::Allocation;
use crate::data::Shard;
use crate::faas::ReplicaId;
use crate::ps::PsState;
use crate::sim::Time;
use crate::util::rng::Pcg32;

use super::comm::SendSlot;

/// How many worker iterations one scheduled event carries for a pool of
/// `workers` functions: 1 (today's exact per-worker path) until the pool
/// exceeds `threshold`, then `ceil(workers / threshold)` — so at most
/// ~`threshold` wave events are ever in flight per partition however
/// large the serverless pool grows. `threshold == 0` disables
/// aggregation entirely.
pub fn cohort_size(workers: usize, threshold: usize) -> usize {
    if threshold == 0 || workers <= threshold {
        1
    } else {
        workers.div_ceil(threshold)
    }
}

/// One edge cohort — a weighted sub-partition *below* a cloud partition,
/// the federated tier of the composite (HiPS stage 1, after GeoMX). A
/// cohort stands for `clients` edge devices behind one local aggregator:
/// each round it samples a fraction of them, they train on the cohort's
/// label-skewed sub-shard, and the aggregated update lands in the parent
/// partition's PS state weighted by the *full* client population
/// (population-reweighted FedAvg), so step/epoch/update totals are exact
/// whatever the sampling fraction or dropout churn. The parent then
/// participates in the inter-cloud WAN sync as before (HiPS stage 2).
#[derive(Debug, Clone)]
pub struct EdgeCohort {
    /// Client population — the cohort's FedAvg weight. Every round
    /// advances the parent's step budget by this many client updates
    /// (clamped only at the final partial round).
    pub clients: u64,
    /// The cohort's non-IID local data: a Dirichlet-label-skewed
    /// sub-shard of the parent's resident shard, carved deterministically
    /// at deploy time. Empty cohorts fall back to the parent's shard.
    pub shard: Shard,
    /// The Dirichlet label weights the sub-shard was carved with
    /// (diagnostics and the determinism tests).
    pub label_weights: Vec<f64>,
    /// A stage-1 round is currently aggregating.
    pub in_flight: bool,
    /// Completed stage-1 rounds.
    pub rounds: u64,
    /// Sampled clients that physically uploaded, across all rounds.
    pub participants: u64,
    /// Sampled clients that dropped mid-round (churn), across all rounds.
    pub dropouts: u64,
}

impl EdgeCohort {
    pub fn new(clients: u64, shard: Shard, label_weights: Vec<f64>) -> EdgeCohort {
        EdgeCohort {
            clients,
            shard,
            label_weights,
            in_flight: false,
            rounds: 0,
            participants: 0,
            dropouts: 0,
        }
    }
}

/// What a partition's worker pool is currently allowed to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// Workers iterate freely (asynchronous local SGD).
    Running,
    /// Blocked on the PS communicator's send slot (WAN backpressure).
    CommBlocked,
    /// Waiting for a dataset shard still in flight on the WAN (the data
    /// plane's staging gate).
    DataBlocked,
    /// Waiting at a synchronous-strategy barrier (SMA).
    AtBarrier,
    /// Spot capacity revoked: the pool is released, PS state is being
    /// checkpoint-restored, and training resumes after the market's
    /// restore stall (the spot subsystem's churn class).
    Preempted,
    /// All local epochs done; worker functions terminated.
    Finished,
}

/// One cloud-level training partition (the seed's `Part`, extracted).
pub struct Partition {
    /// Region / partition index (identical by construction).
    pub region: usize,
    pub region_name: String,
    pub alloc: Allocation,
    pub shard: Shard,
    pub ps: PsState,
    /// Concurrent worker functions (ElasticDL pod granularity). Live —
    /// the elastic control loop resizes this mid-run; in-flight
    /// iterations beyond a shrunk pool drain without restarting.
    pub workers: usize,
    /// Modeled seconds per worker iteration at *catalog* power for the
    /// current allocation (recomputed on every re-plan).
    pub t_iter: f64,
    /// Observed-compute multiplier from resource churn injection: actual
    /// iteration time is `t_iter / power_factor` (1.0 = nominal, 0.5 =
    /// the cloud lost half its effective compute to co-tenancy).
    pub power_factor: f64,
    pub steps_total: u64,
    pub steps_started: u64,
    pub steps_completed: u64,
    /// Steps per local epoch. Mutable: data-plane rebalancing moves
    /// samples between partitions mid-run ([`Partition::retime_step_budget`]).
    pub epoch_steps: u64,
    /// Steps completed inside the current epoch (explicit counter, so
    /// `epoch_steps` can change mid-run without corrupting boundaries).
    pub steps_into_epoch: u64,
    pub epochs_done: usize,
    pub gate: Gate,
    /// Worker iterations currently in flight.
    pub in_flight: usize,
    /// Iterations each scheduled worker event aggregates (a *cohort
    /// wave*): 1 = the exact per-worker path; >1 simulates the pool as
    /// ~threshold weighted waves ([`cohort_size`]). Recomputed from the
    /// live pool size on every elastic resize.
    pub cohort: usize,
    /// The PS communicator's send slot (backpressure state).
    pub slot: SendSlot,
    /// Accumulated on-the-wire serialization seconds of this partition's
    /// own outgoing WAN payloads. Counted per transfer at send time — a
    /// shared multi-job fabric's link statistics aggregate every job's
    /// traffic, so per-job reports must not read them.
    pub wire_time: Time,
    pub local_finish: Option<Time>,
    pub barrier_arrived: bool,
    pub barrier_entry: Time,
    pub cold_start_time: Time,
    pub worker_replicas: Vec<ReplicaId>,
    /// Virtual time the current allocation took effect (billing-segment
    /// start; 0.0 until the first elastic re-plan).
    pub alloc_since: Time,
    /// When the partition entered `Gate::DataBlocked`.
    pub data_blocked_since: Time,
    /// Accumulated seconds spent `Gate::DataBlocked` (the data-plane
    /// report's stall time).
    pub data_stall: Time,
    /// Per-iteration completion times over the current monitoring window
    /// (sum + count of modeled iteration durations; ROADMAP open item —
    /// the finer signal barrier-heavy runs need). Reset at every monitor
    /// sample and on every pool resize.
    pub win_iter_sum: f64,
    pub win_iter_count: u64,
    /// Spot-preemption epoch: bumped on every revocation. Worker waves
    /// capture it when scheduled; a completion whose captured epoch is
    /// stale belonged to the revoked pool and is discarded (its steps
    /// were already rolled back at revocation, so totals stay exact).
    pub preempt_epoch: u64,
    /// Revocations this partition survived (reported per region).
    pub preemptions: u32,
    /// Deterministic per-partition jitter stream.
    pub rng: Pcg32,
    /// The federated edge tier: weighted sub-partitions that aggregate
    /// locally into this partition's PS state before it joins the WAN
    /// sync (HiPS stage 1 under stage 2). Empty = the flat per-cloud
    /// actor, byte-identical to the pre-composite engine.
    pub cohorts: Vec<EdgeCohort>,
}

impl Partition {
    /// True once every planned local step has been started.
    pub fn local_done(&self) -> bool {
        self.steps_started >= self.steps_total
    }

    /// Does this partition own an edge tier (recursive composite), or is
    /// it the flat per-cloud actor?
    pub fn is_composite(&self) -> bool {
        !self.cohorts.is_empty()
    }

    /// Workers currently idle (available to restart after an unblock).
    /// Saturating: after an elastic downsize, in-flight iterations may
    /// briefly exceed the pool while the extra ones drain.
    pub fn idle_workers(&self) -> usize {
        self.workers.saturating_sub(self.in_flight)
    }

    /// Iterations the next wave event should carry: the cohort size
    /// clamped to idle pool slots and the remaining step budget. 0 means
    /// nothing to start (pool saturated or budget exhausted).
    pub fn wave_size(&self) -> usize {
        let remaining = self.steps_total.saturating_sub(self.steps_started);
        self.cohort.max(1).min(self.idle_workers()).min(remaining.min(usize::MAX as u64) as usize)
    }

    /// Record `n` iterations' modeled completion times in the monitoring
    /// window (each of duration `seconds` — one cohort wave). `n == 1`
    /// is [`Partition::note_iteration_time`] exactly.
    pub fn note_iteration_times(&mut self, seconds: f64, n: u64) {
        self.win_iter_sum += seconds * n as f64;
        self.win_iter_count += n;
    }

    /// Account one completed step's epoch bookkeeping; returns true when
    /// it closed a local epoch.
    pub fn note_step_completed(&mut self) -> bool {
        self.steps_completed += 1;
        self.steps_into_epoch += 1;
        if self.epoch_steps > 0 && self.steps_into_epoch >= self.epoch_steps {
            self.steps_into_epoch = 0;
            self.epochs_done += 1;
            true
        } else {
            false
        }
    }

    /// Account `n` completed steps' epoch bookkeeping in O(1) — exactly
    /// equivalent to `n` calls of [`Partition::note_step_completed`] —
    /// and return how many local epochs the bulk closed. A cohort round
    /// carries a whole client population's updates in one event; looping
    /// the per-step path would cost O(clients) per round.
    pub fn note_steps_completed_bulk(&mut self, n: u64) -> u64 {
        self.steps_completed += n;
        if self.epoch_steps == 0 {
            self.steps_into_epoch += n;
            return 0;
        }
        let total = self.steps_into_epoch + n;
        let crossed = total / self.epoch_steps;
        self.steps_into_epoch = total % self.epoch_steps;
        self.epochs_done += crossed as usize;
        crossed
    }

    /// Record one iteration's modeled completion time in the monitoring
    /// window.
    pub fn note_iteration_time(&mut self, seconds: f64) {
        self.win_iter_sum += seconds;
        self.win_iter_count += 1;
    }

    /// Reset the monitoring window (after a sample, or when a resize
    /// invalidates the `t_iter` the window was measured against).
    pub fn reset_monitor_window(&mut self) {
        self.win_iter_sum = 0.0;
        self.win_iter_count = 0;
    }

    /// Re-derive the remaining step budget from the shard's *current*
    /// sample count plus `inbound_samples` still expected on the WAN
    /// (pre-counted staged shards that have not landed yet): the current
    /// epoch finishes at the new per-epoch step count, every remaining
    /// full epoch runs at it too. Called when the data plane moves
    /// samples in or out mid-run; `total_epochs` is the job's configured
    /// epoch count.
    ///
    /// Clamped so in-flight iterations stay consistent: the budget never
    /// drops below `steps_started` (a partition shrunk to nothing drains
    /// and finishes instead of blocking forever).
    pub fn retime_step_budget(&mut self, batch: usize, total_epochs: usize, inbound_samples: usize) {
        // All configured epochs already closed: nothing left to budget —
        // without this guard a retime after the final epoch boundary
        // (steps_into_epoch just reset to 0) would grant a phantom epoch.
        let remaining_incl_current =
            (total_epochs as u64).saturating_sub(self.epochs_done as u64);
        if remaining_incl_current == 0 {
            self.steps_total = self.steps_completed.max(self.steps_started);
            return;
        }
        let samples = self.shard.len() + inbound_samples;
        let new_eps =
            if samples == 0 { 0 } else { samples.div_ceil(batch.max(1)).max(1) as u64 };
        let remaining_full = remaining_incl_current - 1;
        let current_left = new_eps.saturating_sub(self.steps_into_epoch.min(new_eps));
        self.epoch_steps = new_eps;
        self.steps_total = (self.steps_completed + current_left + remaining_full * new_eps)
            .max(self.steps_started);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part() -> Partition {
        Partition {
            region: 0,
            region_name: "test".into(),
            alloc: Allocation::new(0, vec![]),
            shard: Shard::new(vec![0, 1, 2, 3], 1, 0),
            ps: PsState::new(vec![0.0; 4], 0.1),
            workers: 4,
            t_iter: 1.0,
            power_factor: 1.0,
            steps_total: 8,
            steps_started: 0,
            steps_completed: 0,
            epoch_steps: 4,
            steps_into_epoch: 0,
            epochs_done: 0,
            gate: Gate::Running,
            in_flight: 0,
            cohort: 1,
            slot: SendSlot::default(),
            wire_time: 0.0,
            local_finish: None,
            barrier_arrived: false,
            barrier_entry: 0.0,
            cold_start_time: 0.0,
            worker_replicas: Vec::new(),
            alloc_since: 0.0,
            data_blocked_since: 0.0,
            data_stall: 0.0,
            win_iter_sum: 0.0,
            win_iter_count: 0,
            preempt_epoch: 0,
            preemptions: 0,
            rng: Pcg32::new(1, 0),
            cohorts: Vec::new(),
        }
    }

    #[test]
    fn step_accounting() {
        let mut p = part();
        assert!(!p.local_done());
        assert_eq!(p.idle_workers(), 4);
        p.steps_started = 8;
        p.in_flight = 3;
        assert!(p.local_done());
        assert_eq!(p.idle_workers(), 1);
    }

    #[test]
    fn idle_workers_saturates_after_downsize() {
        let mut p = part();
        p.in_flight = 4;
        p.workers = 2; // elastic scale-down while 4 iterations in flight
        assert_eq!(p.idle_workers(), 0, "must not underflow");
    }

    #[test]
    fn epoch_boundary_detection() {
        let mut p = part();
        assert!(!p.note_step_completed());
        assert!(!p.note_step_completed());
        assert!(!p.note_step_completed());
        assert!(p.note_step_completed(), "4th step closes the epoch");
        assert_eq!(p.epochs_done, 1);
        assert_eq!(p.steps_into_epoch, 0);
        for _ in 0..3 {
            assert!(!p.note_step_completed());
        }
        assert!(p.note_step_completed());
        assert_eq!(p.epochs_done, 2);
    }

    #[test]
    fn bulk_step_accounting_matches_the_per_step_path() {
        // Any split of the same step count must land on identical state.
        for (eps, chunks) in [
            (4u64, vec![1u64, 3, 4, 2, 6]),
            (7, vec![20, 1, 7, 14]),
            (0, vec![5, 9]), // epoch_steps == 0: counts, never closes
        ] {
            let mut bulk = part();
            let mut single = part();
            bulk.epoch_steps = eps;
            single.epoch_steps = eps;
            for &n in &chunks {
                let mut closed = 0u64;
                for _ in 0..n {
                    if single.note_step_completed() {
                        closed += 1;
                    }
                }
                assert_eq!(bulk.note_steps_completed_bulk(n), closed);
            }
            assert_eq!(bulk.steps_completed, single.steps_completed);
            assert_eq!(bulk.steps_into_epoch, single.steps_into_epoch);
            assert_eq!(bulk.epochs_done, single.epochs_done);
        }
    }

    #[test]
    fn composite_flag_follows_the_cohort_set() {
        let mut p = part();
        assert!(!p.is_composite(), "flat by default");
        p.cohorts.push(EdgeCohort::new(1000, Shard::new(vec![0, 1], 1, 99), vec![0.5, 0.5]));
        assert!(p.is_composite());
        assert_eq!(p.cohorts[0].clients, 1000);
        assert!(!p.cohorts[0].in_flight);
    }

    #[test]
    fn cohort_size_thresholds() {
        // Off, or pool within threshold: the exact per-worker path.
        assert_eq!(cohort_size(1_000_000, 0), 1);
        assert_eq!(cohort_size(64, 64), 1);
        assert_eq!(cohort_size(4, 64), 1);
        // Above threshold: ~threshold waves in flight, ragged tail up.
        assert_eq!(cohort_size(640, 64), 10);
        assert_eq!(cohort_size(650, 64), 11);
        assert_eq!(cohort_size(1_000_000, 64), 15_625);
    }

    #[test]
    fn wave_size_clamps_to_idle_and_budget() {
        let mut p = part();
        p.workers = 640;
        p.cohort = 10;
        assert_eq!(p.wave_size(), 8, "budget-limited: only 8 steps planned");
        p.steps_total = 10_000;
        assert_eq!(p.wave_size(), 10, "full wave");
        p.in_flight = 635;
        assert_eq!(p.wave_size(), 5, "pool-limited to the idle slots");
        p.in_flight = 640;
        assert_eq!(p.wave_size(), 0, "saturated pool starts nothing");
        p.in_flight = 0;
        p.steps_started = 10_000;
        assert_eq!(p.wave_size(), 0, "exhausted budget starts nothing");
    }

    #[test]
    fn weighted_iteration_times_match_singles() {
        let mut a = part();
        let mut b = part();
        for _ in 0..5 {
            a.note_iteration_time(0.3);
        }
        b.note_iteration_times(0.3, 5);
        assert_eq!(a.win_iter_count, b.win_iter_count);
        assert!((a.win_iter_sum - b.win_iter_sum).abs() < 1e-12);
        // n == 1 is bitwise the single-iteration record.
        let mut c = part();
        let mut d = part();
        c.note_iteration_time(0.7);
        d.note_iteration_times(0.7, 1);
        assert_eq!(c.win_iter_sum.to_bits(), d.win_iter_sum.to_bits());
    }

    #[test]
    fn monitor_window_resets() {
        let mut p = part();
        p.note_iteration_time(0.5);
        p.note_iteration_time(0.7);
        assert_eq!(p.win_iter_count, 2);
        assert!((p.win_iter_sum - 1.2).abs() < 1e-12);
        p.reset_monitor_window();
        assert_eq!(p.win_iter_count, 0);
        assert_eq!(p.win_iter_sum, 0.0);
    }

    #[test]
    fn retime_grows_and_shrinks_the_budget() {
        // 4 samples, batch 2, 2 epochs: 2 steps/epoch, 4 total.
        let mut p = part();
        p.epoch_steps = 2;
        p.steps_total = 4;
        // One step into epoch 0, then a shard of 4 more samples lands.
        p.steps_started = 1;
        assert!(!p.note_step_completed());
        p.shard.extend(vec![4, 5, 6, 7]);
        p.retime_step_budget(2, 2, 0);
        // 8 samples -> 4 steps/epoch: finish epoch 0 (3 more) + epoch 1 (4).
        assert_eq!(p.epoch_steps, 4);
        assert_eq!(p.steps_total, 1 + 3 + 4);

        // Shrink to nothing mid-flight: budget clamps to steps_started.
        let mut q = part();
        q.steps_started = 3;
        q.steps_completed = 2;
        q.shard.remove_range(0, 4);
        q.retime_step_budget(2, 2, 0);
        assert_eq!(q.epoch_steps, 0);
        assert_eq!(q.steps_total, 3, "drains in-flight work, then finishes");
        assert!(!q.local_done() || q.steps_started >= q.steps_total);

        // Every configured epoch already closed (steps_into_epoch just
        // reset to 0): a retime must not grant a phantom extra epoch.
        let mut r = part();
        r.epoch_steps = 2;
        r.steps_total = 4;
        r.epochs_done = 2; // == total_epochs below
        r.steps_completed = 4;
        r.steps_started = 4;
        r.shard.extend(vec![8, 9, 10, 11]);
        r.retime_step_budget(2, 2, 0);
        assert_eq!(r.steps_total, 4, "no work may be budgeted past the last epoch");
    }
}

//! `cloudless` — the Cloudless-Training command-line launcher.
//!
//! ```text
//! cloudless train   [--config <file>] [--model lenet] [--strategy asgd-ga]
//!                   [--topology ring] [--freq 4] [--epochs 8]
//!                   [--scheduling elastic|greedy] [--seed 42] [--json]
//! cloudless plan    [--config <file>]          print the elastic plan
//! cloudless exp     --id <table1|fig2|fig3|fig7|table4|fig8|fig9|fig10|
//!                         fig11|topology|elastic|multijob|federated|
//!                         ablations|all>
//!                   [--full]
//! cloudless devices                            print the device catalog
//! cloudless check                              verify artifacts load + run
//! cloudless lint    [--root <repo>]            repo static-analysis pass
//! ```
//!
//! Every flag and config key is documented in docs/CONFIG.md; the
//! experiment ids map to paper figures in docs/EXPERIMENTS.md.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::config;
use cloudless::coordinator::fleet::{LeasePolicy, MultiJobParams};
use cloudless::coordinator::{Coordinator, JobSpec, SchedulingMode};
use cloudless::dataplane::{PlacementMode, PlacementSpec};
use cloudless::engine::TopologyKind;
use cloudless::exp::{self, Scale};
use cloudless::sync::{Compression, Strategy, SyncConfig};
use cloudless::util::args::Args;

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("CLOUDLESS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

const USAGE: &str = "\
cloudless — serverless geo-distributed ML training (paper reproduction)

USAGE:
  cloudless train   [--config f] [--model m] [--strategy s] [--topology t]
                    [--freq n] [--epochs n] [--scheduling elastic|greedy]
                    [--seed n] [--n-train n] [--n-eval n] [--json]
                    [--compression none|topk[:r]|q8]
                    [--elastic] [--replan-interval s] [--replan-hysteresis x]
                    [--bw-threshold x] [--auto-compression]
                    [--wan-lanes] [--relay-routes]
                    [--data-placement spec] [--placement-mode m] [--sample-kb n]
                    [--replica-map f]
                    [--clients n] [--cohorts n] [--sample-frac x] [--dropout x]
                    [--spot] [--spot-discount x] [--spot-preempt-per-hour x]
                    [--spot-restore-stall s]
  cloudless plan    [--config f]
  cloudless exp     --id <table1|fig2|fig3|fig7|table4|scheduling|fig8|fig9|fig10|fig11|topology|elastic|multijob|dataplane|federated|fleetscale|ablations|compression|wanopt|spot|all> [--full] [--model m]
  cloudless devices
  cloudless check
  cloudless lint    [--root d]  static-analysis pass: determinism, billing
                    accounting, doc-sync (rules: docs/DEVELOPMENT.md);
                    nonzero exit on findings

  strategies: asgd (baseline), asgd-ga, ama (alias: ma), sma
  topologies: ring (default), hierarchical, bandwidth-tree
  --elastic turns on the live re-scheduling control loop (monitor ->
  re-plan -> apply): --replan-interval (virtual s between samples),
  --replan-hysteresis (min relative plan movement to act), --bw-threshold
  (relative delivered-bandwidth divergence that re-plans the topology).
  --wan-lanes schedules WAN transfers in priority lanes (Control >
  Barrier > Gradient > BulkData) so barriers preempt bulk migration;
  --auto-compression lets the controller pick per-link gradient codecs
  (none|topk|q8) from observed bandwidth (works without --elastic);
  --relay-routes lets the sync planner route planned edges through a
  2-hop relay when it beats the direct link. exp --id wanopt compares
  all three against the static-FIFO baseline on the thin-GZ WAN.
  --data-placement activates the physical data plane (dataset catalog +
  WAN shard migration): resident | uniform:<shards> | skewed:<shards>:<frac>
  | single:<region> | fed:<clients>:<alpha>, each optionally suffixed
  :r<K> for K replica copies per shard (e.g. skewed:8:0.7:r2 — consumers
  read from the nearest replica, egress is paid once per created copy)
  and/or @<shard>=<r1>,<r2> per-shard residency overrides;
  --placement-mode picks compute-follows-data | data-follows-compute |
  joint (default); --sample-kb sets stored KB per sample; --replica-map
  folds a whole-catalog JSON pin file ({\"<shard>\": [region, ...]})
  into the placement spec (inline @ pins win). exp --id dataplane
  compares the three modes (plus a replicated joint run) on a skewed
  catalog.
  --spot turns on the preemptible-capacity market: spot regions bill at
  a discounted deterministic price trace (--spot-discount, default
  0.35) but are revoked at --spot-preempt-per-hour (default 0.5) and
  pay --spot-restore-stall virtual seconds of checkpoint restore per
  revocation (default 30); the placement planner weighs the expected
  effective rate against on-demand's 1.0. exp --id spot compares
  spot-aware placement against the on-demand-only baseline.
  --clients/--cohorts activate the federated edge tier: each cloud's
  clients are carved into cohort pools that aggregate locally (HiPS
  stage 1) before the cloud joins the WAN sync (stage 2); --sample-frac
  samples that fraction of each cohort per round, --dropout drops
  sampled clients as churn. exp --id federated compares full vs sampled
  participation under dropout on the 4-cloud WAN.
  exp --id multijob: [--config f (multijob block)] [--jobs n]
  [--mean-interarrival s] [--policy fifo|fair-share|cost-aware|all]
  runs concurrent jobs over one shared inventory (docs/EXPERIMENTS.md).
  --cohort-threshold n simulates worker pools larger than n as weighted
  cohort waves (0 = off, the exact per-worker path; docs/CONFIG.md).
  exp --id fleetscale: [--jobs n] [--regions n] synthetic fleet-scale
  throughput benchmark (quick: 200 jobs/16 regions; --full: 1000 jobs).
  The model name \"synthetic\" runs the built-in artifact-free model.
  Full flag/key reference: docs/CONFIG.md.
";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("plan") => cmd_plan(&args),
        Some("exp") => cmd_exp(&args),
        Some("devices") => cmd_devices(),
        Some("check") => cmd_check(),
        Some("lint") => cmd_lint(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn job_from_args(args: &Args) -> anyhow::Result<JobSpec> {
    if let Some(path) = args.get("config") {
        return config::load_job(path);
    }
    let model = args.get_or("model", "lenet").to_string();
    let (n_train_default, n_eval_default) = cloudless::data::default_sizes(&model);
    let env = CloudEnv::tencent_two_region(
        Device::from_name(args.get_or("cq-device", "sky"))
            .ok_or_else(|| anyhow::anyhow!("unknown --cq-device"))?,
        args.usize("sh-data", n_train_default / 2),
        args.usize("cq-data", n_train_default - n_train_default / 2),
    );
    let mut spec = JobSpec::new(&model, env);
    spec.train.epochs = args.usize("epochs", 8);
    spec.train.seed = args.u64("seed", 42);
    spec.train.n_train = args.usize("n-train", n_train_default);
    spec.train.n_eval = args.usize("n-eval", n_eval_default);
    spec.train.lr = args.f64("lr", spec.train.lr as f64) as f32;
    let strategy = args.parsed("strategy", "asgd-ga", Strategy::from_name)?;
    spec.train.sync = SyncConfig::new(strategy, args.usize("freq", 4) as u32)
        .with_compression(args.parsed("compression", "none", Compression::from_name)?);
    spec.train.topology = args.parsed("topology", "ring", TopologyKind::from_name)?;
    spec.scheduling = match args.get_or("scheduling", "elastic") {
        "greedy" => SchedulingMode::Greedy,
        "elastic" => SchedulingMode::Elastic,
        other => anyhow::bail!("unknown --scheduling {other}"),
    };
    if args.flag("skip-eval") {
        spec.train.skip_eval = true;
    }
    if args.flag("elastic") {
        spec.train.elastic.enabled = true;
    }
    if args.flag("auto-compression") {
        spec.train.elastic.auto_compression = true;
    }
    if args.flag("wan-lanes") {
        spec.train.wan_lanes = true;
    }
    if args.flag("relay-routes") {
        spec.train.relay_routes = true;
    }
    spec.train.elastic.interval_s = args.f64("replan-interval", spec.train.elastic.interval_s);
    spec.train.elastic.hysteresis = args.f64("replan-hysteresis", spec.train.elastic.hysteresis);
    spec.train.elastic.bw_threshold = args.f64("bw-threshold", spec.train.elastic.bw_threshold);
    spec.train.elastic.validate().map_err(|e| anyhow::anyhow!(e))?;
    if let Some(p) = args.get("data-placement") {
        spec.train.dataplane.placement =
            Some(PlacementSpec::from_name(p).map_err(|e| anyhow::anyhow!("--data-placement: {e}"))?);
    }
    spec.train.dataplane.mode =
        args.parsed("placement-mode", spec.train.dataplane.mode.name(), PlacementMode::from_name)?;
    let sample_kb = args.f64("sample-kb", spec.train.dataplane.sample_bytes as f64 / 1024.0);
    anyhow::ensure!(sample_kb >= 0.0, "--sample-kb must be >= 0");
    spec.train.dataplane.sample_bytes = (sample_kb * 1024.0) as u64;
    if let Some(path) = args.get("replica-map") {
        let map = cloudless::dataplane::load_replica_map(path)
            .map_err(|e| anyhow::anyhow!("--replica-map: {e}"))?;
        let placement = spec
            .train
            .dataplane
            .placement
            .take()
            .ok_or_else(|| anyhow::anyhow!("--replica-map needs --data-placement"))?;
        spec.train.dataplane.placement = Some(placement.with_replica_map(map));
        spec.train.dataplane.replica_map = Some(path.to_string());
    }
    if args.flag("spot") {
        spec.train.spot.enabled = true;
    }
    spec.train.spot.discount = args.f64("spot-discount", spec.train.spot.discount);
    spec.train.spot.preempt_per_hour =
        args.f64("spot-preempt-per-hour", spec.train.spot.preempt_per_hour);
    spec.train.spot.restore_stall_s =
        args.f64("spot-restore-stall", spec.train.spot.restore_stall_s);
    spec.train.spot.validate().map_err(|e| anyhow::anyhow!(e))?;
    spec.train.cohort_threshold = args.usize("cohort-threshold", spec.train.cohort_threshold);
    spec.train.federated.clients = args.usize("clients", spec.train.federated.clients);
    spec.train.federated.cohorts = args.usize("cohorts", spec.train.federated.cohorts);
    spec.train.federated.sample_frac =
        args.f64("sample-frac", spec.train.federated.sample_frac);
    spec.train.federated.dropout = args.f64("dropout", spec.train.federated.dropout);
    spec.train.federated.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(spec)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let spec = job_from_args(args)?;
    let coord = Coordinator::new(artifacts_dir())?;
    let report = coord.submit(&spec)?;
    if args.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.summary());
        for pt in &report.curve {
            println!(
                "  epoch {:>3}  t={:>8.1}s  acc={:.4}  loss={:.4}",
                pt.epoch, pt.t, pt.accuracy, pt.loss
            );
        }
        for p in &report.partitions {
            println!(
                "  {:<10} units={:<2} steps={:<6} finish={:.1}s wait={:.1}s comm={:.1}s staleness={:.2}",
                p.region, p.units, p.steps, p.local_finish, p.waiting, p.comm_wait, p.mean_staleness
            );
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let spec = job_from_args(args)?;
    let plan = cloudless::sched::optimal_matching(&spec.env);
    println!("elastic resourcing plan (straggler: {}):", spec.env.regions[plan.straggler].name);
    for (alloc, region) in plan.allocations.iter().zip(&spec.env.regions) {
        println!(
            "  {:<12} {:?}  LP full={:.6} planned={:.6}",
            region.name, alloc.units, plan.full_lp[region.id], plan.planned_lp[region.id]
        );
    }
    Ok(())
}

/// Multi-job fleet knobs for `exp --id multijob`: a `--config` file's
/// `"multijob"` block seeds the defaults, CLI flags override.
fn multijob_params(args: &Args) -> anyhow::Result<MultiJobParams> {
    let mut params = if let Some(path) = args.get("config") {
        config::load_job(path)?.multijob.unwrap_or_default()
    } else {
        MultiJobParams::default()
    };
    params.jobs = args.usize("jobs", params.jobs);
    params.mean_interarrival_s = args.f64("mean-interarrival", params.mean_interarrival_s);
    if let Some(p) = args.get("policy") {
        params.policy = match p {
            "all" => None,
            name => Some(
                LeasePolicy::from_name(name).map_err(|e| anyhow::anyhow!("--policy: {e}"))?,
            ),
        };
    }
    params.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(params)
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let id = args.get_or("id", "all").to_string();
    let scale = Scale::from_flag(args.flag("full"));
    let exp_model = args.get_or("model", "lenet").to_string();
    let coord = Coordinator::new(artifacts_dir())?;
    let run = |id: &str, coord: &Coordinator| -> anyhow::Result<()> {
        match id {
            "table1" => {
                exp::motivation::table1();
            }
            "fig2" => {
                exp::motivation::fig2(coord, scale);
            }
            "fig3" => {
                exp::motivation::fig3();
            }
            "fig7" => {
                exp::usability::fig7(coord, scale);
            }
            "table4" | "scheduling" => {
                exp::scheduling::table4(coord);
            }
            "elastic" => {
                exp::elastic_exp::elastic_compare(coord, scale, &exp_model);
            }
            "fig8" => {
                exp::scheduling::fig8_fig9(coord, scale, false);
            }
            "fig9" | "fig8_fig9" => {
                exp::scheduling::fig8_fig9(coord, scale, true);
            }
            "fig10" => {
                exp::sync_exp::fig10(coord, scale);
            }
            "fig11" => {
                exp::sync_exp::fig11(coord, scale);
            }
            "topology" => {
                exp::topology_exp::topology_compare(coord, scale, &exp_model);
            }
            "multijob" => {
                let params = multijob_params(args)?;
                exp::multijob_exp::multijob_compare(coord, scale, &exp_model, &params);
            }
            "dataplane" => {
                exp::dataplane_exp::dataplane_compare(
                    coord,
                    scale,
                    &exp_model,
                    args.get("data-placement"),
                );
            }
            "federated" => {
                exp::federated_exp::federated_compare(coord, scale, &exp_model);
            }
            "fleetscale" => {
                let jobs = args.usize("jobs", 0);
                let regions = args.usize("regions", 0);
                exp::fleetscale_exp::fleetscale(coord, scale, jobs, regions)?;
            }
            "ablations" => exp::ablations::all(coord, scale, &exp_model),
            "compression" => {
                // Historical default: the comm-heavy DeepFM workload.
                let m = args.get_or("model", "deepfm");
                exp::ablations::compression_vs_frequency(coord, scale, m);
            }
            "wanopt" => {
                exp::wanopt_exp::wanopt_compare(coord, scale, &exp_model);
            }
            "spot" => {
                exp::spot_exp::spot_compare(coord, scale, &exp_model);
            }
            other => anyhow::bail!("unknown experiment id {other:?}"),
        }
        Ok(())
    };
    if id == "all" {
        let ids = [
            "table1", "fig3", "fig2", "table4", "fig7", "fig9", "fig10", "fig11", "topology",
            "elastic", "multijob", "dataplane",
        ];
        for id in ids {
            println!("\n=== {id} ===");
            run(id, &coord)?;
        }
    } else {
        run(&id, &coord)?;
    }
    Ok(())
}

/// `cloudless lint [--root <repo>]` — the repo-specific static-analysis pass
/// (determinism / accounting / doc-sync invariants; rule reference and the
/// `lint:allow` grammar live in docs/DEVELOPMENT.md). `--root` defaults to the
/// repo this binary was built from. Exits nonzero when findings remain.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let root = args
        .get("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(".."));
    let report = cloudless::lint::lint_repo(&root)?;
    print!("{}", report.render());
    anyhow::ensure!(report.clean(), "lint found {} violation(s)", report.findings.len());
    Ok(())
}

fn cmd_devices() -> anyhow::Result<()> {
    exp::motivation::table1();
    Ok(())
}

fn cmd_check() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    let rt = cloudless::runtime::PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    for model in ["lenet", "resnet", "deepfm", "transformer", "synthetic"] {
        match rt.load_model(model) {
            Ok(m) => {
                // one real step to prove executability
                let (ds, _) = cloudless::data::generate(&m.meta, m.meta.batch_size, 1, 0);
                let idxs: Vec<usize> = (0..m.meta.batch_size).collect();
                let (x, y) = ds.batch(&idxs, &m.meta);
                let (g, loss) = m.train_step(&m.init_params, &x, &y)?;
                println!(
                    "  {model:<12} OK  P={:<9} loss={loss:.4} |g|={:.4} compute={}",
                    m.meta.param_count,
                    cloudless::runtime::vecops::l2_norm(&g),
                    m.meta.compute,
                );
            }
            Err(e) => println!("  {model:<12} FAILED: {e}"),
        }
    }
    Ok(())
}

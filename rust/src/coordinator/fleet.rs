//! The multi-job control plane: N concurrent training workflows over one
//! shared multi-cloud inventory.
//!
//! The paper's control plane (§III.A) deploys *a* training workflow
//! adaptively; a production deployment schedules *many* — jobs arrive,
//! contend for the same per-region inventories and the same WAN links,
//! and finish, freeing capacity for whoever is queued (HeterPS,
//! arXiv 2111.10635, makes the same move for heterogeneous clusters; the
//! serverless cost study arXiv 2509.14920 motivates per-job cost
//! accounting under shared FaaS capacity). This module adds the
//! inter-job layer on top of the existing single-job machinery:
//!
//! ```text
//!   JobRequest queue ──▶ admission (policy) ──▶ lease division
//!        │                                         │ per-region unit
//!        │ Poisson arrivals                        ▼ leases
//!        │                    per-job Algorithm 1 within the lease
//!        │                                         │ allocations
//!        ▼                                         ▼
//!   co-simulation: every job's engine/driver stepped on ONE merged
//!   clock over ONE SharedFabric (jobs queue behind each other's
//!   payloads on the WAN); on arrival/completion the coordinator
//!   re-divides leases and resizes running jobs through the FaaS
//!   autoscaler (preemption-by-resize — never a kill)
//! ```
//!
//! Three [`LeasePolicy`]s are provided:
//!
//! - **FIFO** — the baseline batch scheduler: a job is admitted only when
//!   its full solo resourcing plan fits what earlier jobs left; running
//!   jobs are never resized. Head-of-line blocking serializes the fleet
//!   under load.
//! - **Fair-share** — every region's units are divided among the active
//!   jobs in proportion to their weights (largest-remainder rounding);
//!   each arrival/completion re-divides, shrinking or growing running
//!   jobs through [`apply_lease`](crate::engine::driver) resizes.
//! - **Cost-aware** — fair shares trimmed to each job's own Algorithm-1
//!   plan within the share (units the load-power matching would idle are
//!   never leased), so freed capacity admits queued jobs earlier.
//!
//! Inside its lease every job keeps its own elastic controller
//! (`sched::elastic`) re-planning against *observed* powers; the lease is
//! the boundary between the two control loops. The fleet outcome is a
//! [`FleetReport`]: per-job makespan/cost plus Jain's fairness index over
//! normalized job progress rates.

use anyhow::Result;

use crate::cloud::devices::Device;
use crate::cloud::{CloudEnv, Region};
use crate::dataplane::placement::PlanInputs;
use crate::dataplane::{self, DatasetCatalog};
use crate::engine::driver::{self, TrainConfig, World};
use crate::net::{Fabric, LinkSpec, SharedFabric};
use crate::runtime::PjrtRuntime;
use crate::sched::optimal_matching;
use crate::sim::{Sim, Time};
use crate::train::calib;
use crate::train::metrics::TrainReport;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// How the coordinator divides the shared inventory among admitted jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeasePolicy {
    /// Admit in arrival order, each job at its full solo plan; later jobs
    /// wait until capacity frees. Never resizes running jobs.
    Fifo,
    /// Weighted proportional division of every region's units among the
    /// active jobs, re-divided on each arrival/completion.
    FairShare,
    /// Fair shares trimmed to each job's Algorithm-1 plan within the
    /// share — capacity the plan would idle admits queued jobs instead.
    CostAware,
}

impl LeasePolicy {
    /// Parse a policy name (case-insensitive). The error lists every
    /// valid name, so CLI/config callers can surface it verbatim.
    pub fn from_name(s: &str) -> Result<LeasePolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Ok(LeasePolicy::Fifo),
            "fair-share" | "fair_share" | "fair" => Ok(LeasePolicy::FairShare),
            "cost-aware" | "cost_aware" | "cost" => Ok(LeasePolicy::CostAware),
            other => Err(format!(
                "unknown lease policy {other:?} (valid: fifo, fair-share, cost-aware)"
            )),
        }
    }

    /// Stable name (inverse of [`LeasePolicy::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            LeasePolicy::Fifo => "fifo",
            LeasePolicy::FairShare => "fair-share",
            LeasePolicy::CostAware => "cost-aware",
        }
    }
}

/// The `"multijob"` config block / `exp --id multijob` knobs.
#[derive(Debug, Clone)]
pub struct MultiJobParams {
    /// Number of jobs on the arrival trace.
    pub jobs: usize,
    /// Mean exponential inter-arrival gap in virtual seconds; 0 =
    /// auto-scale to roughly a third of one solo job's runtime (so the
    /// trace actually overlaps).
    pub mean_interarrival_s: f64,
    /// Lease policy; `None` compares all three.
    pub policy: Option<LeasePolicy>,
    /// Minimum per-region units an admitted job's lease must hold.
    pub min_units: u32,
}

impl Default for MultiJobParams {
    fn default() -> Self {
        MultiJobParams { jobs: 4, mean_interarrival_s: 0.0, policy: None, min_units: 1 }
    }
}

impl MultiJobParams {
    /// Range-check the knobs (shared by the config parser and the CLI).
    pub fn validate(&self) -> Result<(), String> {
        if self.jobs == 0 {
            return Err("multijob jobs must be >= 1".to_string());
        }
        if !(self.mean_interarrival_s >= 0.0) {
            return Err(format!(
                "multijob mean_interarrival_s must be >= 0, got {}",
                self.mean_interarrival_s
            ));
        }
        if self.min_units == 0 {
            return Err("multijob min_units must be >= 1".to_string());
        }
        Ok(())
    }
}

/// One training workflow submitted to the fleet.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub name: String,
    /// Virtual arrival time on the shared clock.
    pub arrival: Time,
    /// Fair-share weight (1.0 = one share).
    pub weight: f64,
    /// The full per-job training configuration. `link`/`link_overrides`
    /// are ignored — the fleet's WAN comes from [`FleetConfig`].
    pub train: TrainConfig,
}

impl JobRequest {
    pub fn new(name: &str, arrival: Time, train: TrainConfig) -> JobRequest {
        JobRequest { name: name.to_string(), arrival, weight: 1.0, train }
    }
}

/// The shared substrate every job contends for.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub policy: LeasePolicy,
    /// Shared inventory; region `data_samples` are the *fractions* each
    /// job's own `n_train` is split by (the resident-data distribution).
    pub env: CloudEnv,
    /// Uniform inter-region WAN spec.
    pub link: LinkSpec,
    /// Per-pair overrides applied after the uniform mesh.
    pub link_overrides: Vec<(usize, usize, LinkSpec)>,
    pub seed: u64,
    /// Minimum per-region units an admitted job's lease must hold.
    pub min_units: u32,
    /// Pick the next simulator to step through the fleet's merged-clock
    /// *index* (a lazily-invalidated min-heap over per-job
    /// `Sim::peek_time`s, O(log jobs) per event) instead of a linear scan
    /// over every running job (O(jobs) per event). The two paths produce
    /// byte-identical `FleetReport`s — the index reproduces the scan's
    /// exact tie-breaking — so this stays configurable only as an
    /// equivalence-test seam and an escape hatch.
    pub indexed_clock: bool,
    /// Shared dataset catalog (the fleet's data plane): when present,
    /// every job's data split follows the catalog's *current* residency
    /// instead of the regions' `data_samples`, so concurrent jobs
    /// colocate their compute with where the shared datasets physically
    /// sit. The coordinator keeps a **live** copy: replica copies
    /// created by one job's migrations are folded back in between
    /// arrivals, so a later job whose `n_train` matches the catalog
    /// plans directly against the migrated replica map (and moves fewer
    /// bytes). Jobs carrying their own `dataplane` config stage their
    /// migrations on the shared fabric (contending with everyone's sync
    /// traffic).
    pub catalog: Option<DatasetCatalog>,
}

impl FleetConfig {
    pub fn new(policy: LeasePolicy, env: CloudEnv) -> FleetConfig {
        FleetConfig {
            policy,
            env,
            link: LinkSpec::wan_100mbps(),
            link_overrides: Vec::new(),
            seed: 42,
            min_units: 1,
            indexed_clock: true,
            catalog: None,
        }
    }

    /// Per-region data fractions jobs split by: catalog residency when a
    /// shared catalog exists, the regions' `data_samples` otherwise.
    fn data_fractions(&self) -> Vec<usize> {
        match &self.catalog {
            Some(c) => c.resident_samples().iter().map(|&s| s.max(1)).collect(),
            None => self.env.regions.iter().map(|r| r.data_samples.max(1)).collect(),
        }
    }
}

/// Deterministic Poisson job-arrival trace: `n` arrivals starting at 0,
/// exponential inter-arrival gaps with mean `mean_s`, drawn from `seed`.
pub fn poisson_arrivals(n: usize, mean_s: f64, seed: u64) -> Vec<Time> {
    let mut rng = Pcg32::new(seed, 0x4A0B);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            let at = t;
            t += -mean_s * (1.0 - rng.f64()).ln();
            at
        })
        .collect()
}

/// Jain's fairness index over non-negative shares: `(Σx)² / (n·Σx²)`,
/// 1.0 when everyone gets the same, → 1/n when one job gets everything.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Analytic solo-runtime estimate of one job on the full inventory: the
/// straggler bound — its even shard's steps at the minimum full-region
/// power (worker counts cancel; startup and WAN excluded). Used to
/// normalize per-job slowdowns and to auto-scale arrival traces, so it
/// only needs to be consistent, not exact.
pub fn solo_estimate_s(train: &TrainConfig, env: &CloudEnv, batch_size: usize) -> f64 {
    let base = if train.base_step_s > 0.0 {
        train.base_step_s
    } else {
        calib::default_base_step_s(&train.model)
    };
    let shard = train.n_train / env.regions.len().max(1);
    let steps = (shard.max(1) as f64 / batch_size.max(1) as f64).ceil() * train.epochs as f64;
    let power = env.greedy_plan().iter().map(|a| a.power()).fold(f64::INFINITY, f64::min);
    steps * base / power.max(1e-9)
}

/// One finished job's fleet-level outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub name: String,
    pub arrival: Time,
    /// When the coordinator admitted (deployed) it.
    pub admitted: Time,
    pub finish: Time,
    /// admitted - arrival: time spent queued, unbilled.
    pub queue_wait: Time,
    /// finish - arrival (queue wait included).
    pub makespan: Time,
    /// makespan / analytic solo estimate (1.0 = as fast as running alone
    /// on the full inventory, ignoring startup/WAN).
    pub slowdown: f64,
    /// The job's own training report (per-job cost, WAN bytes, re-plan
    /// record — `"lease"` events are the coordinator's re-divisions).
    pub report: TrainReport,
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub policy: String,
    /// Outcomes in request order.
    pub jobs: Vec<JobOutcome>,
    /// Σ per-job cost (compute + WAN), USD.
    pub total_cost: f64,
    pub compute_cost: f64,
    pub wan_cost: f64,
    /// Total bytes on the shared fabric (= Σ per-job bytes).
    pub wan_bytes: u64,
    /// Last finish minus first arrival.
    pub makespan: Time,
    pub mean_slowdown: f64,
    /// Jain's index over per-job normalized progress rates
    /// (1 / slowdown): 1.0 = perfectly even service.
    pub jain_fairness: f64,
    /// Lease re-divisions applied to *running* jobs (preemption-by-resize
    /// count; 0 under FIFO).
    pub lease_events: u64,
    /// Σ per-job spot-market revocations recovered (0 with the market
    /// off — distinct from `lease_events`, which counts the fleet's own
    /// voluntary lease re-divisions).
    pub preemptions: u64,
    /// Σ per-job compute billed below list price on spot segments, USD
    /// (what the same allocations would have cost on-demand minus what
    /// was actually billed).
    pub spot_savings: f64,
    /// Maximum simultaneously-leased units per region (inventory-safety
    /// witness: never exceeds the region's inventory).
    pub peak_units: Vec<u32>,
    /// Discrete events executed across every job simulator (the merged
    /// clock's step count) — the quantity the fleetscale perf trajectory
    /// tracks. Deterministic under the seed, unlike `wall_seconds`.
    pub events_executed: u64,
    pub wall_seconds: f64,
}

impl FleetReport {
    pub fn total_queue_wait(&self) -> Time {
        self.jobs.iter().map(|j| j.queue_wait).sum()
    }

    /// Simulation throughput: executed events per wall-clock second
    /// (0 when the run was too fast to time). Derived, so tests that
    /// need run-to-run byte-identical JSON can pin `wall_seconds`.
    pub fn events_per_wall_second(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.events_executed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(&self.policy)),
            ("makespan_s", Json::num(self.makespan)),
            ("total_cost_usd", Json::num(self.total_cost)),
            ("compute_cost_usd", Json::num(self.compute_cost)),
            ("wan_cost_usd", Json::num(self.wan_cost)),
            ("wan_bytes", Json::num(self.wan_bytes as f64)),
            ("mean_slowdown", Json::num(self.mean_slowdown)),
            ("jain_fairness", Json::num(self.jain_fairness)),
            ("lease_events", Json::num(self.lease_events as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("spot_savings_usd", Json::num(self.spot_savings)),
            ("events_executed", Json::num(self.events_executed as f64)),
            ("events_per_wall_second", Json::num(self.events_per_wall_second())),
            ("total_queue_wait_s", Json::num(self.total_queue_wait())),
            (
                "peak_units",
                Json::arr(self.peak_units.iter().map(|u| Json::num(*u as f64))),
            ),
            (
                "jobs",
                Json::arr(self.jobs.iter().map(|j| {
                    Json::obj(vec![
                        ("name", Json::str(&j.name)),
                        ("arrival_s", Json::num(j.arrival)),
                        ("admitted_s", Json::num(j.admitted)),
                        ("finish_s", Json::num(j.finish)),
                        ("queue_wait_s", Json::num(j.queue_wait)),
                        ("makespan_s", Json::num(j.makespan)),
                        ("slowdown", Json::num(j.slowdown)),
                        ("cost_usd", Json::num(j.report.cost)),
                        ("wan_bytes", Json::num(j.report.wan_bytes as f64)),
                        ("replans", Json::num(j.report.replan_events.len() as f64)),
                        ("preemptions", Json::num(j.report.preemptions as f64)),
                        ("spot_savings_usd", Json::num(j.report.spot_savings)),
                    ])
                })),
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let spot = if self.preemptions > 0 || self.spot_savings > 0.0 {
            format!(" spot[preempt={} saved=${:.4}]", self.preemptions, self.spot_savings)
        } else {
            String::new()
        };
        format!(
            "{} jobs={} makespan={:.0}s slowdown={:.2} jain={:.3} cost=${:.4} leases={} queue={:.0}s events={} ({:.0}/s){}",
            self.policy,
            self.jobs.len(),
            self.makespan,
            self.mean_slowdown,
            self.jain_fairness,
            self.total_cost,
            self.lease_events,
            self.total_queue_wait(),
            self.events_executed,
            self.events_per_wall_second(),
            spot,
        )
    }
}

// ------------------------------------------------------- lease division

/// Split one job's `n_train` by the fleet's resident-data fractions
/// (every region keeps at least one sample so load power stays defined).
fn split_data(n_train: usize, fractions: &[usize]) -> Vec<usize> {
    let total: usize = fractions.iter().sum::<usize>().max(1);
    let n = fractions.len();
    let mut out = Vec::with_capacity(n);
    let mut acc = 0usize;
    for (i, f) in fractions.iter().enumerate() {
        let d = if i + 1 == n {
            n_train.saturating_sub(acc).max(1)
        } else {
            (n_train * f / total).max(1)
        };
        acc += d;
        out.push(d);
    }
    out
}

/// The first `units` units of a region's inventory, device classes in
/// inventory order (the same order `greedy_plan` and the plan search
/// enumerate).
fn clip_inventory(inv: &[(Device, u32)], mut units: u32) -> Vec<(Device, u32)> {
    let mut kept = Vec::new();
    for &(dev, max) in inv {
        let take = units.min(max);
        if take > 0 {
            kept.push((dev, take));
            units -= take;
        }
    }
    kept
}

/// A job's private view of the shared environment: inventory clipped to
/// its lease, resident data split by the fleet fractions.
fn lease_env(base: &CloudEnv, data: &[usize], lease: &[u32]) -> CloudEnv {
    CloudEnv::new(
        base.regions
            .iter()
            .enumerate()
            .map(|(i, r)| Region::new(i, &r.name, clip_inventory(&r.inventory, lease[i]), data[i]))
            .collect(),
    )
}

/// Total rentable units per region.
fn inventory_units(env: &CloudEnv) -> Vec<u32> {
    env.regions.iter().map(|r| r.inventory.iter().map(|(_, n)| n).sum()).collect()
}

/// Weighted largest-remainder division of `units` into one share per
/// weight (deterministic: remainder ties break by index).
fn fair_shares(units: u32, weights: &[f64]) -> Vec<u32> {
    if weights.is_empty() {
        return Vec::new(); // nothing to divide among — and the remainder
                           // loop below would otherwise never terminate
    }
    let total_w: f64 = weights.iter().sum();
    let raw: Vec<f64> = weights.iter().map(|w| units as f64 * w / total_w.max(1e-12)).collect();
    let mut shares: Vec<u32> = raw.iter().map(|r| r.floor() as u32).collect();
    let assigned: u32 = shares.iter().sum();
    let mut left = units.saturating_sub(assigned);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = raw[a] - raw[a].floor();
        let fb = raw[b] - raw[b].floor();
        fb.partial_cmp(&fa).unwrap().then_with(|| a.cmp(&b))
    });
    while left > 0 {
        for &i in &order {
            if left == 0 {
                break;
            }
            shares[i] += 1;
            left -= 1;
        }
    }
    shares
}

/// What the division algorithm needs to know about one member job.
struct DivideMember {
    weight: f64,
    /// Solo Algorithm-1 plan units per region (the FIFO demand).
    demand: Vec<u32>,
    /// Per-region resident samples (for within-lease planning).
    data: Vec<usize>,
}

/// Per-member per-region leases under `policy`, or `None` when the set
/// does not fit (a member's share would fall below `min_units` or, under
/// FIFO, below its full demand).
fn try_divide(
    cfg: &FleetConfig,
    policy: LeasePolicy,
    members: &[DivideMember],
) -> Option<Vec<Vec<u32>>> {
    let caps = inventory_units(&cfg.env);
    let n_regions = caps.len();
    let floor = cfg.min_units.max(1);
    match policy {
        LeasePolicy::Fifo => {
            let mut remaining = caps;
            let mut leases = Vec::with_capacity(members.len());
            for m in members {
                for r in 0..n_regions {
                    if m.demand[r] > remaining[r] {
                        return None;
                    }
                }
                for r in 0..n_regions {
                    remaining[r] -= m.demand[r];
                }
                leases.push(m.demand.clone());
            }
            Some(leases)
        }
        LeasePolicy::FairShare | LeasePolicy::CostAware => {
            let weights: Vec<f64> = members.iter().map(|m| m.weight.max(1e-9)).collect();
            let mut leases = vec![vec![0u32; n_regions]; members.len()];
            for (r, &cap) in caps.iter().enumerate() {
                let shares = fair_shares(cap, &weights);
                for (j, &s) in shares.iter().enumerate() {
                    if s < floor {
                        return None;
                    }
                    leases[j][r] = s;
                }
            }
            if policy == LeasePolicy::CostAware {
                // Trim every share to the job's own Algorithm-1 plan
                // within it: units the load-power matching would idle are
                // never leased, so they stay free for queued jobs. The
                // trim still honors the `min_units` floor the share was
                // admitted under (floor <= share was checked above).
                for (m, lease) in members.iter().zip(leases.iter_mut()) {
                    let jenv = lease_env(&cfg.env, &m.data, lease);
                    let plan = optimal_matching(&jenv);
                    *lease = plan
                        .allocations
                        .iter()
                        .map(|a| a.total_units().max(floor))
                        .collect();
                }
            }
            Some(leases)
        }
    }
}

// --------------------------------------------------------- the fleet run

struct RunningJob {
    req: usize,
    admitted: Time,
    lease: Vec<u32>,
    sim: Sim<World>,
    world: World,
    finish: Option<Time>,
}

struct FleetState<'a> {
    rt: &'a PjrtRuntime,
    cfg: &'a FleetConfig,
    requests: &'a [JobRequest],
    /// Per-request solo demand / data split / solo-runtime estimate.
    demands: Vec<Vec<u32>>,
    datas: Vec<Vec<usize>>,
    ideals: Vec<f64>,
    fabric: SharedFabric,
    running: Vec<RunningJob>,
    /// Arrived-but-not-admitted request indices, arrival order.
    waiting: Vec<usize>,
    lease_events: u64,
    peak_units: Vec<u32>,
    /// The fleet catalog's *live* replica map: seeded from
    /// `FleetConfig::catalog`, re-unioned with every job's delivered
    /// migrations at each coordination pass.
    live_catalog: Option<DatasetCatalog>,
    /// [`DatasetCatalog::version`] the queued requests' data splits were
    /// last computed against — when no merge changed residency since,
    /// the coordination pass skips the re-split entirely.
    split_version: u64,
    /// The last admission's joint read assignment — the *incumbent* seed
    /// for the next admission's hill-climb
    /// ([`placement::plan_seeded`](crate::dataplane::placement::plan_seeded)).
    /// Between admissions only the delta changes (one more lease, churned
    /// links, merged replicas), so re-planning from the incumbent usually
    /// converges in one round instead of `2·shards+4`. Stale geometry is
    /// harmless: mismatched seeds are validated and ignored.
    last_assign: Option<Vec<crate::net::RegionId>>,
}

impl<'a> FleetState<'a> {
    fn member_of(&self, req: usize) -> DivideMember {
        DivideMember {
            weight: self.requests[req].weight,
            demand: self.demands[req].clone(),
            data: self.datas[req].clone(),
        }
    }

    /// Active (unfinished) running jobs, in admission order.
    fn active(&self) -> Vec<usize> {
        (0..self.running.len()).filter(|&i| self.running[i].finish.is_none()).collect()
    }

    /// Fold every job's *delivered* migrations into the live catalog
    /// (idempotent replica-set union), then refresh the queued requests'
    /// data splits and solo demands against where the bytes now sit —
    /// admission must re-read shard replica maps between arrivals, not
    /// plan against the admission-time snapshot (ROADMAP data-plane
    /// defect). Already-admitted jobs keep their deployed splits.
    fn refresh_catalog(&mut self) {
        let version = {
            let Some(live) = self.live_catalog.as_mut() else { return };
            for job in &self.running {
                if let Some(dp) = job.world.dataplane.as_ref() {
                    live.merge_replicas(&dp.catalog);
                }
            }
            live.version()
        };
        // Re-split the queued (not-yet-admitted) requests against the
        // current residency — merges from earlier passes must reach
        // arrivals that were not queued yet when they happened. Every
        // request's initial split (computed up front in `run_fleet`) is
        // valid for the seed catalog's version, so when no merge has
        // changed residency since the last pass there is nothing to
        // recompute and the pass skips the O(queue · matching) re-split.
        if self.waiting.is_empty() || version == self.split_version {
            return;
        }
        self.split_version = version;
        let fractions: Vec<usize> = self
            .live_catalog
            .as_ref()
            .expect("checked above")
            .resident_samples()
            .iter()
            .map(|&s| s.max(1))
            .collect();
        let full_units = inventory_units(&self.cfg.env);
        let queued = self.waiting.clone();
        for req in queued {
            let data = split_data(self.requests[req].train.n_train, &fractions);
            let solo_env = lease_env(&self.cfg.env, &data, &full_units);
            self.demands[req] = optimal_matching(&solo_env)
                .allocations
                .iter()
                .map(|a| a.total_units())
                .collect();
            self.datas[req] = data;
        }
    }

    /// Re-divide leases at `now`: admit the longest viable prefix of the
    /// waiting queue, then apply the division — resizing running jobs
    /// whose lease moved (scheduled into their own simulators at `now`)
    /// and deploying the newly admitted.
    fn coordinate(&mut self, now: Time) -> Result<()> {
        self.refresh_catalog();
        let active = self.active();
        let mut members: Vec<DivideMember> =
            active.iter().map(|&i| self.member_of(self.running[i].req)).collect();
        // An already-admitted set always divides (each member was checked
        // at admission and shrinking the set only grows shares).
        let mut division = if members.is_empty() {
            None
        } else {
            Some(
                try_divide(self.cfg, self.cfg.policy, &members)
                    .expect("the admitted member set always fits"),
            )
        };
        // Admit the longest viable queue prefix, extending the member set
        // one candidate at a time and keeping the last good division.
        let mut admit_n = 0;
        while admit_n < self.waiting.len() {
            members.push(self.member_of(self.waiting[admit_n]));
            match try_divide(self.cfg, self.cfg.policy, &members) {
                Some(d) => {
                    division = Some(d);
                    admit_n += 1;
                }
                None => {
                    members.pop(); // head-of-line: later jobs wait behind
                    break; // the first misfit
                }
            }
        }
        let newly: Vec<usize> = self.waiting.drain(..admit_n).collect();
        let Some(leases) = division else {
            return Ok(()); // nothing running, nothing admittable
        };

        // Inventory safety: the division can never oversubscribe a region.
        let caps = inventory_units(&self.cfg.env);
        for r in 0..caps.len() {
            let leased: u32 = leases.iter().map(|l| l[r]).sum();
            debug_assert!(leased <= caps[r], "region {r} oversubscribed: {leased}/{}", caps[r]);
            self.peak_units[r] = self.peak_units[r].max(leased);
        }

        // Resize running jobs whose lease moved.
        for (slot, lease) in active.iter().zip(leases.iter()) {
            let job = &mut self.running[*slot];
            if *lease == job.lease {
                continue;
            }
            let jenv = lease_env(&self.cfg.env, &self.datas[job.req], lease);
            let plan = optimal_matching(&jenv);
            job.lease = lease.clone();
            self.lease_events += 1;
            let (allocs, straggler) = (plan.allocations, plan.straggler);
            job.sim.schedule_at(now, move |sim, w: &mut World| {
                driver::apply_lease(sim, w, &jenv, &allocs, straggler);
            });
        }

        // Deploy the newly admitted at their final lease. A job carrying
        // its own `dataplane` config plans its joint data/compute
        // placement here, at admission, against the **live** shared
        // fabric's current link specs (not the config template) and —
        // when its sample space matches — the live shared catalog's
        // replica map, so earlier jobs' migrations benefit it.
        for (k, &req) in newly.iter().enumerate() {
            let lease = leases[active.len() + k].clone();
            let jenv = lease_env(&self.cfg.env, &self.datas[req], &lease);
            let train = self.requests[req].train.clone();
            let (allocations, planned) = if train.dataplane.enabled() {
                let meta = self.rt.load_model(&train.model)?.meta;
                let links =
                    self.fabric.with(|f| PlanInputs::link_view(f, jenv.regions.len()));
                let seed = self.last_assign.as_deref();
                let planned = match &self.live_catalog {
                    Some(cat) if cat.total_samples() == train.n_train => {
                        dataplane::plan_for_catalog_seeded(
                            &jenv,
                            &train,
                            &meta,
                            cat.clone(),
                            links,
                            seed,
                        )?
                    }
                    _ => dataplane::plan_for_on_seeded(&jenv, &train, &meta, links, seed)?,
                };
                self.last_assign = Some(planned.plan.assign.clone());
                (planned.plan.allocations.clone(), Some(planned))
            } else {
                (optimal_matching(&jenv).allocations, None)
            };
            let (sim, world) = driver::deploy_job_planned(
                self.rt,
                &jenv,
                allocations,
                train,
                now,
                self.fabric.clone(),
                planned,
            )?;
            self.running.push(RunningJob { req, admitted: now, lease, sim, world, finish: None });
        }
        Ok(())
    }

    /// Build the finished job's outcome (final eval + report).
    fn finalize_job(&self, slot: usize, end: Time) -> (usize, JobOutcome) {
        let job = &self.running[slot];
        let req = &self.requests[job.req];
        let (loss, acc) = if job.world.cfg.skip_eval {
            (f64::NAN, f64::NAN)
        } else {
            driver::evaluate(&job.world, 0)
        };
        let report = driver::finalize_report(&job.world, end, loss, acc, 0.0);
        let makespan = end - req.arrival;
        let ideal = self.ideals[job.req].max(1e-9);
        (
            job.req,
            JobOutcome {
                name: req.name.clone(),
                arrival: req.arrival,
                admitted: job.admitted,
                finish: end,
                queue_wait: job.admitted - req.arrival,
                makespan,
                slowdown: (makespan / ideal).max(1e-12),
                report,
            },
        )
    }
}

/// One entry of the fleet's merged-clock index: slot `slot`'s simulator
/// reported `at` as its next-event time when the entry was pushed.
/// Ordered earliest-first with lower slot winning time ties (exactly the
/// linear scan's `min_by` order, inverted for `BinaryHeap`'s max-heap).
/// Entries are lazily invalidated: a pushed entry is never updated in
/// place — when the slot's peek moves (it was stepped) or the job
/// finishes, the stale entry is discarded at pop time instead.
#[derive(Debug, Clone, Copy, PartialEq)]
struct ClockEntry {
    at: Time,
    slot: usize,
}

impl Eq for ClockEntry {}
impl PartialOrd for ClockEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ClockEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Event times are finite by construction (Sim asserts it).
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.slot.cmp(&self.slot))
    }
}

/// Run a job fleet to completion and return the aggregate report.
///
/// Deterministic under (`cfg.seed`, the request list): jobs interleave on
/// one merged virtual clock — always stepping the simulator whose next
/// event is earliest, arrivals first on ties, lower admission slot next —
/// and share one WAN fabric, so their payloads queue behind each other on
/// the same links.
///
/// The merge is indexed: a min-heap of [`ClockEntry`]s keyed per job
/// picks the next simulator in O(log jobs) per event instead of scanning
/// every running job. Only the just-stepped job's entry is refreshed per
/// event; a coordination pass (which may resize any running job and
/// deploy new ones) rebuilds the index wholesale. The linear scan is kept
/// behind [`FleetConfig::indexed_clock`] as the equivalence baseline.
pub fn run_fleet(
    rt: &PjrtRuntime,
    cfg: &FleetConfig,
    requests: &[JobRequest],
) -> Result<FleetReport> {
    let wall0 = std::time::Instant::now();
    anyhow::ensure!(!requests.is_empty(), "a fleet needs at least one job");
    let n_regions = cfg.env.regions.len();
    anyhow::ensure!(n_regions > 0, "a fleet needs at least one region");
    anyhow::ensure!(cfg.min_units >= 1, "min_units must be >= 1");
    for req in requests {
        anyhow::ensure!(req.arrival >= 0.0, "job {} arrives before t=0", req.name);
        anyhow::ensure!(req.weight > 0.0, "job {} needs a positive weight", req.name);
    }

    // Shared WAN: one fabric for the whole fleet.
    let fabric =
        SharedFabric::new(Fabric::full_mesh(cfg.seed, n_regions, &cfg.link, &cfg.link_overrides));

    // Per-request statics: data split, solo demand, solo-runtime ideal.
    // With a shared catalog the split follows where the data physically
    // sits (fleet-level compute-follows-data).
    let fractions: Vec<usize> = cfg.data_fractions();
    let full_units = inventory_units(&cfg.env);
    let mut batch_sizes: std::collections::BTreeMap<String, usize> = Default::default();
    let mut datas = Vec::new();
    let mut demands = Vec::new();
    let mut ideals = Vec::new();
    for req in requests {
        let data = split_data(req.train.n_train, &fractions);
        let solo_env = lease_env(&cfg.env, &data, &full_units);
        demands.push(
            optimal_matching(&solo_env)
                .allocations
                .iter()
                .map(|a| a.total_units())
                .collect::<Vec<u32>>(),
        );
        let batch = match batch_sizes.get(&req.train.model) {
            Some(&b) => b,
            None => {
                let b = rt.load_model(&req.train.model)?.meta.batch_size;
                batch_sizes.insert(req.train.model.clone(), b);
                b
            }
        };
        ideals.push(solo_estimate_s(&req.train, &solo_env, batch));
        datas.push(data);
    }

    // Arrival order (stable on ties).
    let mut order: Vec<usize> = (0..requests.len()).collect();
    order.sort_by(|&a, &b| {
        requests[a].arrival.partial_cmp(&requests[b].arrival).unwrap().then_with(|| a.cmp(&b))
    });

    let mut st = FleetState {
        rt,
        cfg,
        requests,
        demands,
        datas,
        ideals,
        fabric: fabric.clone(),
        running: Vec::new(),
        waiting: Vec::new(),
        lease_events: 0,
        peak_units: vec![0; n_regions],
        live_catalog: cfg.catalog.clone(),
        split_version: cfg.catalog.as_ref().map_or(0, |c| c.version()),
        last_assign: None,
    };
    let mut outcomes: Vec<Option<JobOutcome>> = vec![None; requests.len()];
    let mut arrived = 0usize;
    let mut executed: u64 = 0;
    const EVENT_LIMIT: u64 = 400_000_000;

    // The merged-clock index. Invariant (indexed mode): every active slot
    // with a pending event has at least one entry carrying its *current*
    // peek time; anything else in the heap is stale and discarded lazily.
    let indexed = cfg.indexed_clock;
    let mut clock: std::collections::BinaryHeap<ClockEntry> = std::collections::BinaryHeap::new();
    macro_rules! reindex_clock {
        () => {
            if indexed {
                clock.clear();
                for (i, j) in st.running.iter().enumerate() {
                    if j.finish.is_none() {
                        if let Some(t) = j.sim.peek_time() {
                            clock.push(ClockEntry { at: t, slot: i });
                        }
                    }
                }
            }
        };
    }

    loop {
        let next_arrival: Option<Time> = if arrived < order.len() {
            Some(requests[order[arrived]].arrival)
        } else {
            None
        };
        let next_event: Option<(usize, Time)> = if indexed {
            loop {
                match clock.peek() {
                    None => break None,
                    Some(&ClockEntry { at, slot }) => {
                        let j = &st.running[slot];
                        if j.finish.is_none() && j.sim.peek_time() == Some(at) {
                            break Some((slot, at));
                        }
                        clock.pop();
                    }
                }
            }
        } else {
            st.running
                .iter()
                .enumerate()
                .filter(|(_, j)| j.finish.is_none())
                .filter_map(|(i, j)| j.sim.peek_time().map(|t| (i, t)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then_with(|| a.0.cmp(&b.0)))
        };
        match (next_arrival, next_event) {
            (None, None) => break,
            (Some(ta), ev) if ev.map_or(true, |(_, te)| ta <= te) => {
                // Arrival wave: everything due at ta joins the queue, one
                // coordination pass serves the whole wave.
                while arrived < order.len() && requests[order[arrived]].arrival <= ta {
                    st.waiting.push(order[arrived]);
                    arrived += 1;
                }
                st.coordinate(ta)?;
                // Coordination may have resized any running job (a lease
                // event scheduled at `ta` moves its peek) and deployed new
                // ones: rebuild the index rather than chase every slot.
                reindex_clock!();
            }
            (_, Some((slot, _))) => {
                executed += 1;
                anyhow::ensure!(
                    executed < EVENT_LIMIT,
                    "fleet simulation exceeded event limit — runaway loop?"
                );
                if indexed {
                    clock.pop(); // consume the entry; re-pushed fresh below
                }
                let finished_at: Option<Time> = {
                    let job = &mut st.running[slot];
                    job.sim.step(&mut job.world);
                    match (job.finish, job.world.global_end) {
                        (None, Some(end)) => {
                            job.finish = Some(end);
                            Some(end)
                        }
                        _ => None,
                    }
                };
                match finished_at {
                    Some(end) => {
                        let (req, outcome) = st.finalize_job(slot, end);
                        outcomes[req] = Some(outcome);
                        // Freed capacity: re-divide and admit from queue.
                        st.coordinate(end)?;
                        reindex_clock!();
                    }
                    None => {
                        // Only the stepped slot's peek moved.
                        if indexed {
                            if let Some(t) = st.running[slot].sim.peek_time() {
                                clock.push(ClockEntry { at: t, slot });
                            }
                        }
                    }
                }
            }
            // A pending arrival with no runnable event always satisfies
            // the guarded arrival arm; this arm only exists to make the
            // match exhaustive for the compiler.
            (Some(_), None) => unreachable!("guarded arrival arm handles this case"),
        }
    }

    let mut jobs: Vec<JobOutcome> = Vec::with_capacity(requests.len());
    for (i, outcome) in outcomes.into_iter().enumerate() {
        match outcome {
            Some(o) => jobs.push(o),
            // Starvation is a caller error (e.g. min_units no lease can
            // satisfy), not a crash: surface it through the Result.
            None => anyhow::bail!(
                "job {} ({}) never completed under policy {}: no viable lease \
                 (min_units {} vs the shared inventory?)",
                i,
                requests[i].name,
                cfg.policy.name(),
                cfg.min_units
            ),
        }
    }
    let first_arrival = jobs.iter().map(|j| j.arrival).fold(f64::INFINITY, f64::min);
    let last_finish = jobs.iter().map(|j| j.finish).fold(0.0f64, f64::max);
    let rates: Vec<f64> = jobs.iter().map(|j| 1.0 / j.slowdown).collect();
    let mean_slowdown = jobs.iter().map(|j| j.slowdown).sum::<f64>() / jobs.len() as f64;
    Ok(FleetReport {
        policy: cfg.policy.name().to_string(),
        total_cost: jobs.iter().map(|j| j.report.cost).sum(),
        compute_cost: jobs.iter().map(|j| j.report.compute_cost).sum(),
        wan_cost: jobs.iter().map(|j| j.report.wan_cost).sum(),
        wan_bytes: fabric.total_wan_bytes(),
        makespan: last_finish - first_arrival,
        mean_slowdown,
        jain_fairness: jain_index(&rates),
        lease_events: st.lease_events,
        preemptions: jobs.iter().map(|j| j.report.preemptions).sum(),
        spot_savings: jobs.iter().map(|j| j.report.spot_savings).sum(),
        peak_units: st.peak_units,
        events_executed: executed,
        wall_seconds: wall0.elapsed().as_secs_f64(),
        jobs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn four_cloud_env() -> CloudEnv {
        CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 128),
            ("CQ", Device::Skylake, 12, 128),
            ("BJ", Device::Skylake, 12, 128),
            ("GZ", Device::IceLake, 12, 128),
        ])
    }

    fn member(n_train: usize, env: &CloudEnv) -> DivideMember {
        let fractions: Vec<usize> = env.regions.iter().map(|r| r.data_samples).collect();
        let data = split_data(n_train, &fractions);
        let solo = lease_env(env, &data, &inventory_units(env));
        let demand =
            optimal_matching(&solo).allocations.iter().map(|a| a.total_units()).collect();
        DivideMember { weight: 1.0, demand, data }
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [LeasePolicy::Fifo, LeasePolicy::FairShare, LeasePolicy::CostAware] {
            assert_eq!(LeasePolicy::from_name(p.name()), Ok(p));
        }
        assert_eq!(LeasePolicy::from_name("FAIR"), Ok(LeasePolicy::FairShare));
        let err = LeasePolicy::from_name("lottery").unwrap_err();
        assert!(err.contains("fifo") && err.contains("cost-aware") && err.contains("lottery"));
    }

    #[test]
    fn poisson_trace_is_deterministic_and_sorted() {
        let a = poisson_arrivals(16, 10.0, 7);
        let b = poisson_arrivals(16, 10.0, 7);
        assert_eq!(a, b);
        assert_eq!(a[0], 0.0, "first job arrives immediately");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals are non-decreasing");
        let c = poisson_arrivals(16, 10.0, 8);
        assert_ne!(a, c, "different seed, different trace");
        // Mean gap lands near the requested mean (law of large numbers).
        let long = poisson_arrivals(4000, 10.0, 7);
        let mean = long.last().unwrap() / 3999.0;
        assert!((mean - 10.0).abs() < 1.0, "mean gap {mean}");
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_index(&[]), 1.0);
        assert!((jain_index(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        let skew = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12, "one-job-takes-all -> 1/n");
        let mild = jain_index(&[1.0, 0.5, 0.8, 0.9]);
        assert!(mild > 0.25 && mild < 1.0);
    }

    #[test]
    fn fair_shares_largest_remainder() {
        assert_eq!(fair_shares(12, &[1.0; 4]), vec![3, 3, 3, 3]);
        assert_eq!(fair_shares(12, &[1.0; 5]), vec![3, 3, 2, 2, 2]);
        // Weighted: 2:1:1 over 12 -> 6:3:3.
        assert_eq!(fair_shares(12, &[2.0, 1.0, 1.0]), vec![6, 3, 3]);
        let total: u32 = fair_shares(7, &[0.3, 0.3, 0.4]).iter().sum();
        assert_eq!(total, 7, "every unit is assigned");
    }

    #[test]
    fn split_data_covers_and_floors() {
        assert_eq!(split_data(512, &[128, 128, 128, 128]), vec![128, 128, 128, 128]);
        let skew = split_data(100, &[3, 1]);
        assert_eq!(skew, vec![75, 25]);
        let tiny = split_data(2, &[100, 100, 100]);
        assert!(tiny.iter().all(|&d| d >= 1), "every region keeps >=1 sample: {tiny:?}");
    }

    #[test]
    fn clip_inventory_takes_first_classes() {
        let inv = vec![(Device::CascadeLake, 6), (Device::Skylake, 6)];
        assert_eq!(clip_inventory(&inv, 4), vec![(Device::CascadeLake, 4)]);
        assert_eq!(
            clip_inventory(&inv, 9),
            vec![(Device::CascadeLake, 6), (Device::Skylake, 3)]
        );
        assert_eq!(clip_inventory(&inv, 99), inv, "clip never exceeds the inventory");
        assert!(clip_inventory(&inv, 0).is_empty());
    }

    #[test]
    fn fifo_serializes_on_the_straggler_region() {
        let env = four_cloud_env();
        let cfg = FleetConfig::new(LeasePolicy::Fifo, env.clone());
        let m1 = member(512, &env);
        // Job 1's solo plan keeps the straggler region fully allocated, so
        // a second identical job cannot fit.
        assert!(m1.demand.iter().any(|&u| u == 12), "solo plan saturates a region");
        let one = try_divide(&cfg, LeasePolicy::Fifo, &[member(512, &env)]).unwrap();
        assert_eq!(one[0], m1.demand);
        assert!(
            try_divide(&cfg, LeasePolicy::Fifo, &[member(512, &env), member(512, &env)])
                .is_none(),
            "FIFO queues the second job"
        );
    }

    #[test]
    fn fair_share_admits_what_fifo_queues() {
        let env = four_cloud_env();
        let cfg = FleetConfig::new(LeasePolicy::FairShare, env.clone());
        let members: Vec<DivideMember> = (0..4).map(|_| member(512, &env)).collect();
        let leases = try_divide(&cfg, LeasePolicy::FairShare, &members).unwrap();
        for lease in &leases {
            assert_eq!(lease, &vec![3, 3, 3, 3], "equal weights, equal shares");
        }
        // 13 equal jobs cannot all hold >= 1 unit of a 12-unit region.
        let many: Vec<DivideMember> = (0..13).map(|_| member(512, &env)).collect();
        assert!(try_divide(&cfg, LeasePolicy::FairShare, &many).is_none());
    }

    #[test]
    fn cost_aware_trims_to_the_within_lease_plan() {
        let env = four_cloud_env();
        let cfg = FleetConfig::new(LeasePolicy::CostAware, env.clone());
        let members: Vec<DivideMember> = (0..2).map(|_| member(512, &env)).collect();
        let fair = try_divide(&cfg, LeasePolicy::FairShare, &members).unwrap();
        let cost = try_divide(&cfg, LeasePolicy::CostAware, &members).unwrap();
        for (f, c) in fair.iter().zip(&cost) {
            for r in 0..4 {
                assert!(c[r] <= f[r], "trim never grows a lease: {c:?} vs {f:?}");
                assert!(c[r] >= 1, "trimmed lease keeps every region viable");
            }
        }
        let fair_total: u32 = fair.iter().flatten().sum();
        let cost_total: u32 = cost.iter().flatten().sum();
        assert!(
            cost_total < fair_total,
            "heterogeneous regions must shed some units: {cost_total} vs {fair_total}"
        );
        // The trim still honors the admission floor: with min_units = 2
        // no trimmed lease may fall below 2 units anywhere.
        let mut floor2 = FleetConfig::new(LeasePolicy::CostAware, env.clone());
        floor2.min_units = 2;
        let trimmed = try_divide(&floor2, LeasePolicy::CostAware, &members).unwrap();
        for lease in &trimmed {
            assert!(lease.iter().all(|&u| u >= 2), "min_units floor violated: {lease:?}");
        }
    }

    #[test]
    fn empty_member_set_divides_to_nothing() {
        let cfg = FleetConfig::new(LeasePolicy::FairShare, four_cloud_env());
        assert_eq!(fair_shares(12, &[]), Vec::<u32>::new(), "no members, no spin");
        assert_eq!(try_divide(&cfg, LeasePolicy::FairShare, &[]), Some(Vec::new()));
        assert_eq!(try_divide(&cfg, LeasePolicy::Fifo, &[]), Some(Vec::new()));
    }

    #[test]
    fn shared_catalog_drives_the_data_split() {
        use crate::dataplane::{Layout, PlacementSpec};
        let env = four_cloud_env();
        let mut cfg = FleetConfig::new(LeasePolicy::FairShare, env.clone());
        assert_eq!(cfg.data_fractions(), vec![128; 4], "no catalog: region data");
        cfg.catalog = Some(
            DatasetCatalog::from_spec(
                &PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 }),
                512,
                4,
                1024,
                &[1; 4],
            )
            .unwrap(),
        );
        let fr = cfg.data_fractions();
        assert!(fr[0] > fr[1], "jobs colocate with the hot region: {fr:?}");
        assert!(fr.iter().all(|&f| f >= 1), "zero-resident regions stay plannable");
    }

    #[test]
    fn clock_entries_pop_earliest_time_then_lowest_slot() {
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(ClockEntry { at: 2.0, slot: 0 });
        heap.push(ClockEntry { at: 1.0, slot: 3 });
        heap.push(ClockEntry { at: 1.0, slot: 1 });
        heap.push(ClockEntry { at: 3.0, slot: 2 });
        let order: Vec<(f64, usize)> =
            std::iter::from_fn(|| heap.pop().map(|e| (e.at, e.slot))).collect();
        // Exactly the linear scan's `min_by` order: time, then slot.
        assert_eq!(order, vec![(1.0, 1), (1.0, 3), (2.0, 0), (3.0, 2)]);
    }

    #[test]
    fn multijob_params_validate() {
        assert!(MultiJobParams::default().validate().is_ok());
        assert!(MultiJobParams { jobs: 0, ..Default::default() }.validate().is_err());
        assert!(
            MultiJobParams { mean_interarrival_s: -1.0, ..Default::default() }
                .validate()
                .is_err()
        );
        assert!(MultiJobParams { min_units: 0, ..Default::default() }.validate().is_err());
    }
}

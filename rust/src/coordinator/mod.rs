//! The Cloudless-Training coordinator — the user-facing control plane.
//!
//! This is the paper's "logical view": users submit a training job (model
//! definition name + training configuration + the multi-cloud
//! environment); the control plane probes resources, runs the scheduling
//! strategy (elastic by default, greedy as the paper's baseline), and
//! launches the physical training plane (per-cloud serverless workflows)
//! through the DES engine.
//!
//! One [`Coordinator::submit`] call runs a single job on a private WAN;
//! the [`fleet`] submodule is the multi-job control plane — N concurrent
//! workflows leasing slices of one shared inventory and contending on one
//! shared fabric (see docs/ARCHITECTURE.md).
//!
//! ```no_run
//! use cloudless::coordinator::{Coordinator, JobSpec, SchedulingMode};
//! use cloudless::cloud::{CloudEnv, devices::Device};
//!
//! let coord = Coordinator::new("artifacts").unwrap();
//! let env = CloudEnv::tencent_two_region(Device::Skylake, 2048, 1024);
//! let spec = JobSpec::new("lenet", env);
//! let report = coord.submit(&spec).unwrap();
//! println!("{}", report.summary());
//! ```

pub mod fleet;

use anyhow::Result;

use crate::cloud::{Allocation, CloudEnv};
use crate::runtime::PjrtRuntime;
use crate::sched::{optimal_matching, Plan};
use crate::train::{run_geo_training, TrainConfig, TrainReport};

/// How the control plane provisions resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulingMode {
    /// The paper's baseline: consume every available unit in each region.
    Greedy,
    /// The elastic scheduling strategy (Algorithm 1 / Optimal Matching).
    Elastic,
}

/// A complete training-job submission.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub env: CloudEnv,
    pub train: TrainConfig,
    pub scheduling: SchedulingMode,
    /// Multi-job fleet parameters, when the config carries a
    /// `"multijob"` block (consumed by `exp --id multijob`; a plain
    /// `submit` ignores it).
    pub multijob: Option<fleet::MultiJobParams>,
}

impl JobSpec {
    pub fn new(model: &str, env: CloudEnv) -> JobSpec {
        JobSpec {
            env,
            train: TrainConfig::new(model),
            scheduling: SchedulingMode::Elastic,
            multijob: None,
        }
    }
}

/// The control plane: owns the PJRT runtime and the scheduler function.
pub struct Coordinator {
    rt: PjrtRuntime,
}

impl Coordinator {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Coordinator> {
        Ok(Coordinator { rt: PjrtRuntime::new(artifacts_dir)? })
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }

    /// The scheduler function: probe the environment and produce the
    /// elastic resourcing plan.
    pub fn plan(&self, env: &CloudEnv) -> Plan {
        optimal_matching(env)
    }

    /// Resolve a job's allocations per its scheduling mode. With an
    /// active data plane, elastic scheduling runs the joint data/compute
    /// placement planner (`dataplane::plan_for`) instead of plain
    /// Algorithm 1 — the same deterministic plan the driver stages
    /// migrations from; greedy still rents everything (the baseline
    /// wastes money on data-less regions too).
    pub fn allocations_for(&self, spec: &JobSpec) -> Result<Vec<Allocation>> {
        Ok(match spec.scheduling {
            SchedulingMode::Greedy => spec.env.greedy_plan(),
            SchedulingMode::Elastic if spec.train.dataplane.enabled() => {
                let meta = self.rt.load_model(&spec.train.model)?.meta;
                crate::dataplane::plan_for(&spec.env, &spec.train, &meta)?.plan.allocations
            }
            SchedulingMode::Elastic => self.plan(&spec.env).allocations,
        })
    }

    /// Submit a job: schedule, deploy workflows, train, report. With an
    /// active data plane the placement plan is computed once and handed
    /// to the driver (which would otherwise recompute the identical
    /// deterministic plan).
    pub fn submit(&self, spec: &JobSpec) -> Result<TrainReport> {
        if spec.train.dataplane.enabled() && spec.scheduling == SchedulingMode::Elastic {
            let meta = self.rt.load_model(&spec.train.model)?.meta;
            let planned = crate::dataplane::plan_for(&spec.env, &spec.train, &meta)?;
            let allocations = planned.plan.allocations.clone();
            return crate::engine::driver::run_geo_training_planned(
                &self.rt,
                &spec.env,
                allocations,
                spec.train.clone(),
                Some(planned),
            );
        }
        let allocations = self.allocations_for(spec)?;
        run_geo_training(&self.rt, &spec.env, allocations, spec.train.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::devices::Device;

    #[test]
    fn allocations_follow_mode() {
        // Coordinator::new needs PJRT; test plan logic via free functions.
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let greedy = env.greedy_plan();
        assert_eq!(greedy[1].total_units(), 12);
        let elastic = optimal_matching(&env).allocations;
        assert_eq!(elastic[1].total_units(), 4);
    }

    #[test]
    fn job_spec_defaults() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1, 1);
        let spec = JobSpec::new("lenet", env);
        assert_eq!(spec.scheduling, SchedulingMode::Elastic);
        assert_eq!(spec.train.model, "lenet");
    }
}

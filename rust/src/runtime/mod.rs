//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! them on the request path — Python is never involved here.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! >= 0.5 emits serialized protos with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see python/compile/aot.py and
//! /opt/xla-example/README.md).
//!
//! A [`ModelRuntime`] bundles one model's executables (train_step, eval,
//! and the Pallas-lowered PS vector ops) with its metadata and initial
//! parameters. All tensors cross the boundary as flat buffers; shapes come
//! from `{model}_meta.json`.

pub mod vecops;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Flat tensor crossing the Rust<->PJRT boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, dims: Vec<i64>) -> Tensor {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        Tensor::F32 { data, dims }
    }

    pub fn i32(data: Vec<i32>, dims: Vec<i64>) -> Tensor {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        Tensor::I32 { data, dims }
    }

    pub fn num_elements(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Tensor::F32 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
            Tensor::I32 { data, dims } => xla::Literal::vec1(data).reshape(dims)?,
        })
    }
}

/// Parsed `{model}_meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub param_count: usize,
    pub batch_size: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_dtype: String,
    pub num_classes: usize,
    /// Per-field vocab sizes (DeepFM-style models).
    pub vocab_sizes: Vec<usize>,
    /// LM vocab (transformer models); 0 otherwise.
    pub vocab: usize,
    /// Which compute path the train/eval graphs were lowered with.
    pub compute: String,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let j = Json::parse(text).context("parsing model meta json")?;
        let req_usize = |k: &str| {
            j.get(k).as_usize().ok_or_else(|| anyhow::anyhow!("meta missing field {k}"))
        };
        let inner = j.get("meta");
        Ok(ModelMeta {
            name: j.get("name").as_str().unwrap_or_default().to_string(),
            param_count: req_usize("param_count")?,
            batch_size: req_usize("batch_size")?,
            x_shape: j
                .get("x_shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            x_dtype: j.get("x_dtype").as_str().unwrap_or("f32").to_string(),
            y_dtype: j.get("y_dtype").as_str().unwrap_or("i32").to_string(),
            num_classes: j.get("num_classes").as_usize().unwrap_or(0),
            vocab_sizes: inner
                .get("vocab_sizes")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            vocab: inner.get("vocab").as_usize().unwrap_or(0),
            compute: j.get("compute").as_str().unwrap_or("unknown").to_string(),
        })
    }

    /// Per-example input element count.
    pub fn x_elems_per_example(&self) -> usize {
        self.x_shape.iter().product::<usize>().max(1)
    }

    /// Gradient payload size in bytes (what a sync puts on the WAN).
    pub fn payload_bytes(&self) -> u64 {
        (self.param_count * 4) as u64
    }

    /// Batch input dims (leading batch dimension).
    pub fn x_dims(&self) -> Vec<i64> {
        let mut dims = vec![self.batch_size as i64];
        dims.extend(self.x_shape.iter().map(|&d| d as i64));
        dims
    }

    pub fn y_dims(&self) -> Vec<i64> {
        // LM models label every token; classifiers label the example.
        if self.vocab > 0 {
            self.x_dims()
        } else {
            vec![self.batch_size as i64]
        }
    }
}

/// One compiled HLO entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; unpacks the `return_tuple=True` tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let results = self.exe.execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        anyhow::ensure!(!results.is_empty() && !results[0].is_empty(), "no outputs");
        let lit = results[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Where a model's numerics run.
enum Backend {
    /// Compiled HLO artifacts through PJRT (the real models).
    Pjrt { train: Executable, eval: Executable, sgd: Executable, avg: Executable, acc: Executable },
    /// A built-in linear-softmax classifier computed natively — no
    /// artifacts, no PJRT executions. Exists so CI and artifact-less
    /// hosts can exercise the full engine (driver, partitions, WAN,
    /// elastic control loop) end-to-end with *real* (if tiny) numerics:
    /// genuine gradients, losses, and accuracy curves.
    Synthetic { feats: usize, classes: usize },
}

/// A loaded model: metadata + a compute backend + initial params.
pub struct ModelRuntime {
    pub meta: ModelMeta,
    pub init_params: Vec<f32>,
    backend: Backend,
    /// Cumulative PJRT executions for perf accounting (the synthetic
    /// backend never bumps this).
    pub exec_counts: std::cell::Cell<u64>,
}

/// The PJRT client wrapper; load models through this.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn compile_artifact(&self, file: &str) -> Result<Executable> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable { exe, name: file.to_string() })
    }

    /// Load a model bundle (meta + init + all 5 entry points).
    ///
    /// The reserved name `"synthetic"` skips the artifacts entirely and
    /// returns the built-in native linear-softmax model (see
    /// [`ModelRuntime::synthetic`]).
    pub fn load_model(&self, model: &str) -> Result<ModelRuntime> {
        if model == "synthetic" {
            return Ok(ModelRuntime::synthetic());
        }
        let meta_text = std::fs::read_to_string(self.artifacts_dir.join(format!("{model}_meta.json")))
            .with_context(|| format!("reading {model}_meta.json — run `make artifacts` first"))?;
        let meta = ModelMeta::parse(&meta_text)?;
        let init_params =
            crate::util::read_f32_file(&self.artifacts_dir.join(format!("{model}_init.bin")))?;
        anyhow::ensure!(
            init_params.len() == meta.param_count,
            "init.bin length {} != param_count {}",
            init_params.len(),
            meta.param_count
        );
        Ok(ModelRuntime {
            meta,
            init_params,
            backend: Backend::Pjrt {
                train: self.compile_artifact(&format!("{model}_train_step.hlo.txt"))?,
                eval: self.compile_artifact(&format!("{model}_eval.hlo.txt"))?,
                sgd: self.compile_artifact(&format!("{model}_sgd_apply.hlo.txt"))?,
                avg: self.compile_artifact(&format!("{model}_avg.hlo.txt"))?,
                acc: self.compile_artifact(&format!("{model}_acc.hlo.txt"))?,
            },
            exec_counts: std::cell::Cell::new(0),
        })
    }
}

impl ModelRuntime {
    /// The built-in artifact-free model: a linear-softmax classifier over
    /// the synthetic image-style dataset (8 features, 4 classes; params =
    /// row-major weights + biases). Small enough that CI exercises the
    /// whole engine in milliseconds, real enough that loss falls and
    /// accuracy beats chance.
    pub fn synthetic() -> ModelRuntime {
        let feats = 8usize;
        let classes = 4usize;
        let meta = ModelMeta {
            name: "synthetic".to_string(),
            param_count: feats * classes + classes,
            batch_size: 16,
            x_shape: vec![feats],
            x_dtype: "f32".to_string(),
            y_dtype: "i32".to_string(),
            num_classes: classes,
            vocab_sizes: Vec::new(),
            vocab: 0,
            compute: "native".to_string(),
        };
        ModelRuntime {
            init_params: vec![0.0; meta.param_count],
            meta,
            backend: Backend::Synthetic { feats, classes },
            exec_counts: std::cell::Cell::new(0),
        }
    }

    fn params_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        anyhow::ensure!(
            params.len() == self.meta.param_count,
            "params length {} != {}",
            params.len(),
            self.meta.param_count
        );
        Ok(xla::Literal::vec1(params))
    }

    fn bump(&self) {
        self.exec_counts.set(self.exec_counts.get() + 1);
    }

    /// One SGD gradient computation: (params, batch) -> (grads, loss).
    pub fn train_step(&self, params: &[f32], x: &Tensor, y: &Tensor) -> Result<(Vec<f32>, f32)> {
        match &self.backend {
            Backend::Pjrt { train, .. } => {
                self.bump();
                let outs =
                    train.run(&[self.params_literal(params)?, x.to_literal()?, y.to_literal()?])?;
                anyhow::ensure!(outs.len() == 2, "train_step returned {} outputs", outs.len());
                let grads = outs[0].to_vec::<f32>()?;
                let loss = outs[1].get_first_element::<f32>()?;
                Ok((grads, loss))
            }
            Backend::Synthetic { feats, classes } => {
                synthetic_softmax_step(params, x, y, *feats, *classes, true)
                    .map(|(g, loss, _)| (g.expect("grad requested"), loss))
            }
        }
    }

    /// One eval batch: (params, batch) -> (loss_sum, correct_count).
    pub fn eval_batch(&self, params: &[f32], x: &Tensor, y: &Tensor) -> Result<(f32, f32)> {
        match &self.backend {
            Backend::Pjrt { eval, .. } => {
                self.bump();
                let outs =
                    eval.run(&[self.params_literal(params)?, x.to_literal()?, y.to_literal()?])?;
                anyhow::ensure!(outs.len() == 2, "eval returned {} outputs", outs.len());
                Ok((outs[0].get_first_element::<f32>()?, outs[1].get_first_element::<f32>()?))
            }
            Backend::Synthetic { feats, classes } => {
                synthetic_softmax_step(params, x, y, *feats, *classes, false)
                    .map(|(_, loss_sum, correct)| (loss_sum, correct))
            }
        }
    }

    /// PS vector ops through the Pallas-lowered artifacts (the PJRT
    /// backend; the native backend lives in [`vecops`]).
    pub fn sgd_apply(&self, p: &[f32], g: &[f32], lr: f32) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Pjrt { sgd, .. } => {
                self.bump();
                let outs = sgd.run(&[
                    self.params_literal(p)?,
                    self.params_literal(g)?,
                    xla::Literal::scalar(lr),
                ])?;
                Ok(outs[0].to_vec::<f32>()?)
            }
            Backend::Synthetic { .. } => {
                let mut out = p.to_vec();
                vecops::sgd_apply_inplace(&mut out, g, lr);
                Ok(out)
            }
        }
    }

    pub fn model_average(&self, a: &[f32], b: &[f32], w: f32) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Pjrt { avg, .. } => {
                self.bump();
                let outs = avg.run(&[
                    self.params_literal(a)?,
                    self.params_literal(b)?,
                    xla::Literal::scalar(w),
                ])?;
                Ok(outs[0].to_vec::<f32>()?)
            }
            Backend::Synthetic { .. } => {
                let mut out = a.to_vec();
                vecops::average_inplace(&mut out, b, w);
                Ok(out)
            }
        }
    }

    pub fn grad_accumulate(&self, acc: &[f32], g: &[f32]) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Pjrt { acc: accumulate, .. } => {
                self.bump();
                let outs =
                    accumulate.run(&[self.params_literal(acc)?, self.params_literal(g)?])?;
                Ok(outs[0].to_vec::<f32>()?)
            }
            Backend::Synthetic { .. } => {
                let mut out = acc.to_vec();
                vecops::accumulate_inplace(&mut out, g);
                Ok(out)
            }
        }
    }
}

/// The synthetic backend's forward/backward: softmax cross-entropy over a
/// linear model (`params` = row-major `[classes x feats]` weights then
/// `classes` biases). With `with_grad` returns the batch-mean gradient
/// and mean loss (train); without it returns the batch loss *sum* and
/// correct count (eval), matching the PJRT artifact contracts.
fn synthetic_softmax_step(
    params: &[f32],
    x: &Tensor,
    y: &Tensor,
    feats: usize,
    classes: usize,
    with_grad: bool,
) -> Result<(Option<Vec<f32>>, f32, f32)> {
    let xs = match x {
        Tensor::F32 { data, .. } => data,
        Tensor::I32 { .. } => anyhow::bail!("synthetic model expects f32 features"),
    };
    let ys = match y {
        Tensor::I32 { data, .. } => data,
        Tensor::F32 { .. } => anyhow::bail!("synthetic model expects i32 labels"),
    };
    anyhow::ensure!(params.len() == feats * classes + classes, "bad synthetic params");
    let batch = ys.len();
    anyhow::ensure!(batch > 0 && xs.len() == batch * feats, "bad synthetic batch");
    let (weights, biases) = params.split_at(feats * classes);

    let mut grad = if with_grad { Some(vec![0.0f32; params.len()]) } else { None };
    let mut loss_sum = 0.0f32;
    let mut correct = 0.0f32;
    for b in 0..batch {
        let xb = &xs[b * feats..(b + 1) * feats];
        let label = (ys[b].max(0) as usize).min(classes - 1);
        let mut logits = vec![0.0f32; classes];
        for (c, logit) in logits.iter_mut().enumerate() {
            let row = &weights[c * feats..(c + 1) * feats];
            *logit = biases[c] + row.iter().zip(xb).map(|(w, v)| w * v).sum::<f32>();
        }
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|l| (l - max).exp()).collect();
        let z: f32 = exps.iter().sum();
        let probs: Vec<f32> = exps.iter().map(|e| e / z).collect();
        loss_sum += -(probs[label].max(1e-12)).ln();
        let argmax = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        if argmax == label {
            correct += 1.0;
        }
        if let Some(g) = grad.as_mut() {
            for c in 0..classes {
                let d = probs[c] - if c == label { 1.0 } else { 0.0 };
                let gw = &mut g[c * feats..(c + 1) * feats];
                for (gj, xj) in gw.iter_mut().zip(xb) {
                    *gj += d * xj / batch as f32;
                }
                g[feats * classes + c] += d / batch as f32;
            }
        }
    }
    if with_grad {
        Ok((grad, loss_sum / batch as f32, correct))
    } else {
        Ok((None, loss_sum, correct))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let text = r#"{"name":"m","param_count":10,"batch_size":4,
            "x_shape":[2,3],"x_dtype":"f32","y_dtype":"i32","num_classes":5,
            "meta":{"vocab_sizes":[7,7]},"compute":"xla"}"#;
        let m = ModelMeta::parse(text).unwrap();
        assert_eq!(m.param_count, 10);
        assert_eq!(m.x_dims(), vec![4, 2, 3]);
        assert_eq!(m.y_dims(), vec![4]);
        assert_eq!(m.x_elems_per_example(), 6);
        assert_eq!(m.payload_bytes(), 40);
        assert_eq!(m.vocab_sizes, vec![7, 7]);
        assert_eq!(m.compute, "xla");
    }

    #[test]
    fn lm_meta_labels_every_token() {
        let text = r#"{"name":"t","param_count":1,"batch_size":2,
            "x_shape":[16],"x_dtype":"i32","y_dtype":"i32","num_classes":0,
            "meta":{"vocab":512}}"#;
        let m = ModelMeta::parse(text).unwrap();
        assert_eq!(m.y_dims(), vec![2, 16]);
        assert_eq!(m.vocab, 512);
    }

    #[test]
    fn meta_missing_fields_error() {
        assert!(ModelMeta::parse(r#"{"name":"x"}"#).is_err());
    }

    #[test]
    fn tensor_dims_check() {
        let t = Tensor::f32(vec![0.0; 6], vec![2, 3]);
        assert_eq!(t.num_elements(), 6);
        let t2 = Tensor::i32(vec![1, 2], vec![2]);
        assert_eq!(t2.num_elements(), 2);
    }

    #[test]
    fn synthetic_model_learns_without_artifacts() {
        let m = ModelRuntime::synthetic();
        assert_eq!(m.meta.param_count, m.init_params.len());
        let (train, eval) = crate::data::generate(&m.meta, 256, 64, 7);
        let mut params = m.init_params.clone();
        let idxs: Vec<usize> = (0..m.meta.batch_size).collect();
        let (x0, y0) = train.batch(&idxs, &m.meta);
        let (_, loss0) = m.train_step(&params, &x0, &y0).unwrap();
        assert!((loss0 - (m.meta.num_classes as f32).ln()).abs() < 1e-4, "uniform start");
        // A few hundred SGD steps must cut the loss and beat chance.
        let mut shard = crate::data::Shard::new((0..256).collect(), 3, 0);
        for _ in 0..400 {
            let batch = shard.next_batch(m.meta.batch_size);
            let (x, y) = train.batch(&batch, &m.meta);
            let (g, _) = m.train_step(&params, &x, &y).unwrap();
            params = m.sgd_apply(&params, &g, 0.1).unwrap();
        }
        let mut correct = 0.0;
        let mut total = 0.0;
        let mut i = 0;
        while i < eval.n {
            let idxs: Vec<usize> = (i..i + m.meta.batch_size).map(|j| j % eval.n).collect();
            let (x, y) = eval.batch(&idxs, &m.meta);
            let (_, c) = m.eval_batch(&params, &x, &y).unwrap();
            correct += c;
            total += m.meta.batch_size as f32;
            i += m.meta.batch_size;
        }
        let acc = correct / total;
        assert!(acc > 0.5, "linear model on prototype data beats chance easily: {acc}");
        assert_eq!(m.exec_counts.get(), 0, "synthetic backend never touches PJRT");
    }

    #[test]
    fn synthetic_vecops_match_native() {
        let m = ModelRuntime::synthetic();
        let p: Vec<f32> = (0..m.meta.param_count).map(|i| i as f32 * 0.01).collect();
        let g: Vec<f32> = (0..m.meta.param_count).map(|i| (i as f32 * 0.3).sin()).collect();
        let out = m.sgd_apply(&p, &g, 0.5).unwrap();
        for i in 0..p.len() {
            assert!((out[i] - (p[i] - 0.5 * g[i])).abs() < 1e-6);
        }
        let avg = m.model_average(&p, &g, 0.25).unwrap();
        for i in 0..p.len() {
            assert!((avg[i] - (0.25 * p[i] + 0.75 * g[i])).abs() < 1e-6);
        }
        let acc = m.grad_accumulate(&p, &g).unwrap();
        for i in 0..p.len() {
            assert!((acc[i] - (p[i] + g[i])).abs() < 1e-6);
        }
    }
}

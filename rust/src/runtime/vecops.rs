//! Native f32 vector ops for the PS hot path.
//!
//! The PS-side update rules (SGD apply, gradient accumulation, model
//! averaging) are memory-bound axpy-style loops over the flat parameter
//! vector. They exist in two implementations: these native Rust loops
//! (default on the hot path — no PJRT round-trip for a 2 MB vector) and
//! the Pallas-lowered HLO artifacts (`{model}_sgd_apply.hlo.txt`...)
//! executed via `ModelRuntime` (kept numerically equivalent; the
//! `vecops_backend` ablation bench compares both).
//!
//! Loops are written over exact-size chunks so LLVM auto-vectorizes them.

/// p -= lr * g  (SGD application).
pub fn sgd_apply_inplace(p: &mut [f32], g: &[f32], lr: f32) {
    assert_eq!(p.len(), g.len(), "param/grad length mismatch");
    for (pi, gi) in p.iter_mut().zip(g.iter()) {
        *pi -= lr * *gi;
    }
}

/// acc += g  (gradient accumulation, ASGD-GA's local merge).
pub fn accumulate_inplace(acc: &mut [f32], g: &[f32]) {
    assert_eq!(acc.len(), g.len());
    for (ai, gi) in acc.iter_mut().zip(g.iter()) {
        *ai += *gi;
    }
}

/// a = w*a + (1-w)*b  (inter-PS model averaging).
pub fn average_inplace(a: &mut [f32], b: &[f32], w: f32) {
    assert_eq!(a.len(), b.len());
    let wb = 1.0 - w;
    for (ai, bi) in a.iter_mut().zip(b.iter()) {
        *ai = w * *ai + wb * *bi;
    }
}

/// Element-wise mean of several vectors (SMA's global average).
pub fn mean_of(vs: &[&[f32]]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let n = vs[0].len();
    assert!(vs.iter().all(|v| v.len() == n), "length mismatch");
    let scale = 1.0 / vs.len() as f32;
    let mut out = vec![0.0f32; n];
    for v in vs {
        for (oi, vi) in out.iter_mut().zip(v.iter()) {
            *oi += *vi;
        }
    }
    for oi in out.iter_mut() {
        *oi *= scale;
    }
    out
}

/// Zero a vector in place (accumulator reset after a sync).
pub fn zero(v: &mut [f32]) {
    v.iter_mut().for_each(|x| *x = 0.0);
}

/// L2 norm (metrics / divergence monitoring).
pub fn l2_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_apply() {
        let mut p = vec![1.0, 2.0, 3.0];
        sgd_apply_inplace(&mut p, &[0.5, -1.0, 0.0], 0.1);
        assert_eq!(p, vec![0.95, 2.1, 3.0]);
    }

    #[test]
    fn accumulate_is_sum() {
        let mut acc = vec![0.0; 4];
        for g in [[1.0f32, 2.0, 3.0, 4.0], [0.5, 0.5, 0.5, 0.5]] {
            accumulate_inplace(&mut acc, &g);
        }
        assert_eq!(acc, vec![1.5, 2.5, 3.5, 4.5]);
    }

    #[test]
    fn average_half() {
        let mut a = vec![2.0, 4.0];
        average_inplace(&mut a, &[4.0, 0.0], 0.5);
        assert_eq!(a, vec![3.0, 2.0]);
    }

    #[test]
    fn average_weighted_preserves_endpoints() {
        let mut a = vec![1.0, 5.0];
        let b = vec![3.0, -5.0];
        let orig = a.clone();
        average_inplace(&mut a, &b, 1.0);
        assert_eq!(a, orig);
        let mut a2 = vec![1.0, 5.0];
        average_inplace(&mut a2, &b, 0.0);
        assert_eq!(a2, b);
    }

    #[test]
    fn mean_of_many() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let c = [5.0f32, 6.0];
        assert_eq!(mean_of(&[&a, &b, &c]), vec![3.0, 4.0]);
    }

    #[test]
    fn norm_and_zero() {
        let mut v = vec![3.0, 4.0];
        assert!((l2_norm(&v) - 5.0).abs() < 1e-6);
        zero(&mut v);
        assert_eq!(v, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        let mut p = vec![1.0];
        sgd_apply_inplace(&mut p, &[1.0, 2.0], 0.1);
    }
}

//! The dataset catalog: named shards with sizes and per-cloud homes.
//!
//! A catalog partitions one job's `n_train` global sample indices into
//! contiguous, sized shards, each resident ("homed") in one region. The
//! placement planner ([`super::placement`]) decides which shards move;
//! the migration layer ([`super::migration`]) moves the bytes. Sample
//! *contents* are deterministic everywhere (see `crate::data`) — the
//! catalog models where the physical bytes sit and what egress they pay
//! to leave.

use crate::net::RegionId;
use crate::runtime::ModelMeta;

/// Stored bytes per training sample derived from the model's tensor
/// geometry (f32/i32 features + labels). Experiments usually override
/// this with `DataPlaneConfig::sample_bytes` — the repo's sample counts
/// are scaled far below the paper's datasets, so geometry-derived bytes
/// understate real migration cost by the same factor.
pub fn sample_bytes(meta: &ModelMeta) -> u64 {
    let y_elems = if meta.vocab > 0 { meta.x_shape.first().copied().unwrap_or(1) } else { 1 };
    ((meta.x_elems_per_example() + y_elems) * 4) as u64
}

/// One shard: a contiguous range of global sample indices with a size in
/// bytes and a current home region.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    pub id: usize,
    /// Region the shard's bytes currently reside in.
    pub home: RegionId,
    /// Global sample index range `[start, end)`.
    pub start: usize,
    pub end: usize,
    pub bytes: u64,
}

impl ShardInfo {
    pub fn samples(&self) -> usize {
        self.end - self.start
    }

    /// The shard's global sample indices.
    pub fn indices(&self) -> Vec<usize> {
        (self.start..self.end).collect()
    }
}

/// How the initial shard placement is seeded (config `"dataplane"`
/// `"placement"` key / `--data-placement`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacementSpec {
    /// One shard per region, sized by the regions' `data` fractions —
    /// the seed behavior's residency, now with explicit bytes.
    Resident,
    /// `uniform:<shards>` — equal shards assigned round-robin.
    Uniform { shards: usize },
    /// `skewed:<shards>:<frac>` — fraction `frac` of the samples homed in
    /// region 0, the rest round-robin over the remaining regions.
    Skewed { shards: usize, frac: f64 },
    /// `single:<region>` — everything resident in one region.
    Single { region: RegionId },
}

impl PlacementSpec {
    /// Parse a spec name. The error spells out the grammar so CLI/config
    /// callers can surface it verbatim.
    pub fn from_name(s: &str) -> Result<PlacementSpec, String> {
        let err = || {
            format!(
                "unknown data placement {s:?} (valid: resident, uniform:<shards>, \
                 skewed:<shards>:<frac>, single:<region>)"
            )
        };
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("").to_ascii_lowercase();
        let spec = match head.as_str() {
            "resident" => PlacementSpec::Resident,
            "uniform" => {
                let shards: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                PlacementSpec::Uniform { shards }
            }
            "skewed" => {
                let shards: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                let frac: f64 = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                PlacementSpec::Skewed { shards, frac }
            }
            "single" => {
                let region: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                PlacementSpec::Single { region }
            }
            _ => return Err(err()),
        };
        if parts.next().is_some() {
            return Err(err());
        }
        match spec {
            PlacementSpec::Uniform { shards } | PlacementSpec::Skewed { shards, .. }
                if shards == 0 =>
            {
                Err("data placement needs at least one shard".to_string())
            }
            PlacementSpec::Skewed { frac, .. } if !(0.0..=1.0).contains(&frac) => {
                Err(format!("skew fraction must be in [0, 1], got {frac}"))
            }
            ok => Ok(ok),
        }
    }

    /// Stable name (inverse of [`PlacementSpec::from_name`]).
    pub fn name(&self) -> String {
        match self {
            PlacementSpec::Resident => "resident".to_string(),
            PlacementSpec::Uniform { shards } => format!("uniform:{shards}"),
            PlacementSpec::Skewed { shards, frac } => format!("skewed:{shards}:{frac}"),
            PlacementSpec::Single { region } => format!("single:{region}"),
        }
    }
}

/// The catalog: every shard of one dataset with its current home.
#[derive(Debug, Clone)]
pub struct DatasetCatalog {
    pub shards: Vec<ShardInfo>,
    pub n_regions: usize,
}

/// Split `[0, n)` into `k` contiguous chunks whose sizes differ by at
/// most one; returns `(start, end)` pairs (possibly empty chunks when
/// `k > n`).
fn chunks(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1);
    (0..k).map(|i| (i * n / k, (i + 1) * n / k)).collect()
}

impl DatasetCatalog {
    /// Build the catalog for one job: `n_train` samples at `sample_bytes`
    /// each over `n_regions` clouds. `region_samples` is the config's
    /// per-region `data` distribution (used by [`PlacementSpec::Resident`]
    /// only).
    pub fn from_spec(
        spec: &PlacementSpec,
        n_train: usize,
        n_regions: usize,
        sample_bytes: u64,
        region_samples: &[usize],
    ) -> Result<DatasetCatalog, String> {
        if n_regions == 0 {
            return Err("catalog needs at least one region".to_string());
        }
        if n_train == 0 {
            return Err("catalog needs at least one sample".to_string());
        }
        // `from_name` rejects zero shard counts, but the variants are
        // public: validate here too so direct construction errors
        // instead of panicking in the chunking below.
        if let PlacementSpec::Uniform { shards: 0 } | PlacementSpec::Skewed { shards: 0, .. } =
            spec
        {
            return Err("data placement needs at least one shard".to_string());
        }
        let shard = |id: usize, home: RegionId, start: usize, end: usize| ShardInfo {
            id,
            home,
            start,
            end,
            bytes: (end - start) as u64 * sample_bytes,
        };
        let mut shards = Vec::new();
        match *spec {
            PlacementSpec::Resident => {
                // Mirror data::shard_by_fraction's contiguous split.
                let total: usize = region_samples.iter().map(|s| s.max(&1)).sum();
                let mut start = 0usize;
                for r in 0..n_regions {
                    let frac = *region_samples.get(r).unwrap_or(&1).max(&1);
                    let count = if r + 1 == n_regions {
                        n_train - start
                    } else {
                        (n_train as f64 * frac as f64 / total as f64).round() as usize
                    };
                    let end = (start + count).min(n_train);
                    shards.push(shard(r, r, start, end));
                    start = end;
                }
            }
            PlacementSpec::Uniform { shards: k } => {
                for (i, (s, e)) in chunks(n_train, k).into_iter().enumerate() {
                    shards.push(shard(i, i % n_regions, s, e));
                }
            }
            PlacementSpec::Skewed { shards: k, frac } => {
                let hot_n = ((n_train as f64) * frac).round() as usize;
                let hot_n = hot_n.min(n_train);
                let cold_n = n_train - hot_n;
                // Both sides populated need at least one shard each.
                let k = if hot_n > 0 && cold_n > 0 { k.max(2) } else { k };
                let hot_k = (((k as f64) * frac).round() as usize)
                    .clamp(usize::from(hot_n > 0), k - usize::from(cold_n > 0));
                let cold_k = k - hot_k;
                let mut id = 0;
                for (s, e) in chunks(hot_n, hot_k.max(1)).into_iter() {
                    if hot_n > 0 {
                        shards.push(shard(id, 0, s, e));
                        id += 1;
                    }
                }
                let cold_regions = n_regions.max(2) - 1;
                for (i, (s, e)) in chunks(cold_n, cold_k.max(1)).into_iter().enumerate() {
                    if cold_n > 0 {
                        let home = if n_regions == 1 { 0 } else { 1 + (i % cold_regions) };
                        shards.push(shard(id, home, hot_n + s, hot_n + e));
                        id += 1;
                    }
                }
            }
            PlacementSpec::Single { region } => {
                if region >= n_regions {
                    return Err(format!(
                        "single:{region} names a region outside the {n_regions}-region environment"
                    ));
                }
                // Keep shard granularity so the planner can still split
                // the move decision.
                let k = (2 * n_regions).max(2);
                for (i, (s, e)) in chunks(n_train, k).into_iter().enumerate() {
                    shards.push(shard(i, region, s, e));
                }
            }
        }
        shards.retain(|s| s.samples() > 0);
        for (i, s) in shards.iter_mut().enumerate() {
            s.id = i;
        }
        Ok(DatasetCatalog { shards, n_regions })
    }

    /// Samples currently resident per region.
    pub fn resident_samples(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.n_regions];
        for s in &self.shards {
            out[s.home] += s.samples();
        }
        out
    }

    /// Bytes currently resident per region.
    pub fn resident_bytes(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.n_regions];
        for s in &self.shards {
            out[s.home] += s.bytes;
        }
        out
    }

    pub fn total_samples(&self) -> usize {
        self.shards.iter().map(|s| s.samples()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).sum()
    }

    /// Record a completed migration: the shard's bytes now live in `to`.
    pub fn apply_move(&mut self, shard_id: usize, to: RegionId) {
        if let Some(s) = self.shards.get_mut(shard_id) {
            s.home = to;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_names_round_trip() {
        for name in ["resident", "uniform:8", "skewed:8:0.7", "single:2"] {
            let spec = PlacementSpec::from_name(name).unwrap();
            assert_eq!(spec.name(), name);
        }
        assert_eq!(
            PlacementSpec::from_name("SKEWED:4:0.5").unwrap(),
            PlacementSpec::Skewed { shards: 4, frac: 0.5 }
        );
        for bad in ["", "striped:4", "uniform", "uniform:0", "skewed:4", "skewed:4:1.5",
                    "single:x", "uniform:4:9"] {
            assert!(PlacementSpec::from_name(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn skewed_catalog_holds_the_fraction_hot() {
        let c = DatasetCatalog::from_spec(
            &PlacementSpec::Skewed { shards: 8, frac: 0.7 },
            512,
            4,
            100,
            &[1; 4],
        )
        .unwrap();
        let res = c.resident_samples();
        assert_eq!(res.iter().sum::<usize>(), 512, "every sample is resident somewhere");
        let hot = res[0] as f64 / 512.0;
        assert!((hot - 0.7).abs() < 0.05, "hot region holds ~70%: {res:?}");
        assert!(res[1] > 0 && res[2] > 0, "cold shards spread round-robin: {res:?}");
        assert_eq!(c.total_bytes(), 512 * 100);
        // Shards partition [0, n) contiguously and disjointly.
        let mut all: Vec<usize> = c.shards.iter().flat_map(|s| s.indices()).collect();
        all.sort();
        assert_eq!(all, (0..512).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_and_single_and_resident() {
        let u = DatasetCatalog::from_spec(&PlacementSpec::Uniform { shards: 4 }, 400, 4, 10, &[1; 4])
            .unwrap();
        assert_eq!(u.resident_samples(), vec![100; 4]);

        let s =
            DatasetCatalog::from_spec(&PlacementSpec::Single { region: 3 }, 400, 4, 10, &[1; 4])
                .unwrap();
        assert_eq!(s.resident_samples()[3], 400);
        assert!(s.shards.len() >= 2, "single keeps planner granularity");
        assert!(DatasetCatalog::from_spec(
            &PlacementSpec::Single { region: 4 },
            400,
            4,
            10,
            &[1; 4]
        )
        .is_err());

        let r = DatasetCatalog::from_spec(&PlacementSpec::Resident, 300, 2, 10, &[200, 100])
            .unwrap();
        assert_eq!(r.resident_samples(), vec![200, 100], "mirrors shard_by_fraction");
    }

    #[test]
    fn directly_constructed_zero_shard_specs_error_not_panic() {
        for spec in [
            PlacementSpec::Uniform { shards: 0 },
            PlacementSpec::Skewed { shards: 0, frac: 1.0 },
            PlacementSpec::Skewed { shards: 0, frac: 0.3 },
        ] {
            assert!(
                DatasetCatalog::from_spec(&spec, 100, 3, 1, &[1; 3]).is_err(),
                "{spec:?} must be rejected"
            );
        }
    }

    #[test]
    fn extreme_skews_stay_total() {
        let all_hot =
            DatasetCatalog::from_spec(&PlacementSpec::Skewed { shards: 4, frac: 1.0 }, 100, 3, 1, &[1; 3])
                .unwrap();
        assert_eq!(all_hot.resident_samples(), vec![100, 0, 0]);
        let no_hot =
            DatasetCatalog::from_spec(&PlacementSpec::Skewed { shards: 4, frac: 0.0 }, 100, 3, 1, &[1; 3])
                .unwrap();
        assert_eq!(no_hot.resident_samples()[0], 0);
        assert_eq!(no_hot.total_samples(), 100);
    }

    #[test]
    fn sample_bytes_follows_geometry() {
        let meta = ModelMeta::parse(
            r#"{"name":"lenet","param_count":1,"batch_size":8,"x_shape":[28,28,1],
                "x_dtype":"f32","y_dtype":"i32","num_classes":10,"meta":{}}"#,
        )
        .unwrap();
        assert_eq!(sample_bytes(&meta), (784 + 1) * 4);
    }

    #[test]
    fn apply_move_relocates_bytes() {
        let mut c =
            DatasetCatalog::from_spec(&PlacementSpec::Uniform { shards: 4 }, 400, 4, 10, &[1; 4])
                .unwrap();
        c.apply_move(0, 3);
        assert_eq!(c.resident_samples(), vec![0, 100, 100, 200]);
    }
}

//! The dataset catalog: named shards with sizes and per-cloud replica
//! sets.
//!
//! A catalog partitions one job's `n_train` global sample indices into
//! contiguous, sized shards, each physically resident in a **replica
//! set** of one or more regions (`:rK` in the placement spec grammar).
//! The placement planner ([`super::placement`]) decides which region
//! *trains* each shard and which replica a remote consumer reads from;
//! the migration layer ([`super::migration`]) moves the bytes of replica
//! copies that do not exist yet. Sample *contents* are deterministic
//! everywhere (see `crate::data`) — the catalog models where the
//! physical bytes sit and what egress they pay to leave.

use crate::net::RegionId;
use crate::runtime::ModelMeta;

/// Stored bytes per training sample derived from the model's tensor
/// geometry (f32/i32 features + labels). Experiments usually override
/// this with `DataPlaneConfig::sample_bytes` — the repo's sample counts
/// are scaled far below the paper's datasets, so geometry-derived bytes
/// understate real migration cost by the same factor.
pub fn sample_bytes(meta: &ModelMeta) -> u64 {
    let y_elems = if meta.vocab > 0 { meta.x_shape.first().copied().unwrap_or(1) } else { 1 };
    ((meta.x_elems_per_example() + y_elems) * 4) as u64
}

/// One shard: a contiguous range of global sample indices with a size in
/// bytes and a set of regions holding a physical copy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    pub id: usize,
    /// Regions holding a physical copy of the shard's bytes, in the
    /// order the copies were created (seeded home first). Never empty;
    /// a single-home shard (the PR-4 model) has exactly one entry.
    pub replicas: Vec<RegionId>,
    /// Global sample index range `[start, end)`.
    pub start: usize,
    pub end: usize,
    pub bytes: u64,
}

impl ShardInfo {
    pub fn samples(&self) -> usize {
        self.end - self.start
    }

    /// The shard's global sample indices.
    pub fn indices(&self) -> Vec<usize> {
        (self.start..self.end).collect()
    }

    /// The seeded (primary) copy's region — the single "home" of the
    /// PR-4 model; replicas added later never displace it.
    pub fn home(&self) -> RegionId {
        self.replicas[0]
    }

    /// Does `region` hold a physical copy?
    pub fn has_replica(&self, region: RegionId) -> bool {
        self.replicas.contains(&region)
    }
}

/// How the initial shard layout is seeded (config `"dataplane"`
/// `"placement"` key / `--data-placement`), before replication.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Layout {
    /// One shard per region, sized by the regions' `data` fractions —
    /// the seed behavior's residency, now with explicit bytes.
    Resident,
    /// `uniform:<shards>` — equal shards assigned round-robin.
    Uniform { shards: usize },
    /// `skewed:<shards>:<frac>` — fraction `frac` of the samples homed in
    /// region 0, the rest round-robin over the remaining regions.
    Skewed { shards: usize, frac: f64 },
    /// `single:<region>` — everything resident in one region.
    Single { region: RegionId },
    /// `fed:<clients>:<alpha>` — the federated edge workload: one shard
    /// per cloud whose sizes are Dirichlet(alpha)-proportioned (non-IID
    /// quantity skew across clouds), deterministically seeded from the
    /// layout parameters alone so two runs of the same spec carve the
    /// same shards. `clients` records the edge population the driver
    /// spreads below the clouds (it also perturbs the internal seed so
    /// differently-sized populations do not share a skew draw).
    Federated { clients: usize, alpha: f64 },
}

/// A full placement spec: the seeded layout, the initial replica count
/// per shard (`<layout>[:rK]`, e.g. `skewed:8:0.7:r2`), and optional
/// per-shard replica-set pins (`@<shard>=<r1>,<r2>` suffixes, e.g.
/// `uniform:4:r2@0=1,3@2=0`) that override the seeding rotation.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementSpec {
    pub layout: Layout,
    /// Physical copies each shard starts with (1 = single home, the
    /// PR-4 model; clamped to the region count at catalog build).
    pub replication: usize,
    /// Explicit replica-set pins: `(shard_id, replicas)` pairs applied
    /// after seeding, replacing that shard's whole replica set. Shard
    /// ids refer to the *final* catalog ids (after empty shards are
    /// dropped); out-of-range ids or regions error at build.
    pub overrides: Vec<(usize, Vec<RegionId>)>,
}

impl PlacementSpec {
    /// A single-home spec over `layout`.
    pub fn new(layout: Layout) -> PlacementSpec {
        PlacementSpec { layout, replication: 1, overrides: Vec::new() }
    }

    /// The same spec with shard `shard_id`'s replica set pinned to
    /// exactly `replicas` (first entry is the home).
    pub fn with_override(mut self, shard_id: usize, replicas: Vec<RegionId>) -> PlacementSpec {
        self.overrides.retain(|(id, _)| *id != shard_id);
        self.overrides.push((shard_id, replicas));
        self.overrides.sort_by_key(|(id, _)| *id);
        self
    }

    /// The same layout seeded with `r` copies per shard.
    pub fn with_replication(mut self, r: usize) -> PlacementSpec {
        self.replication = r.max(1);
        self
    }

    /// Parse a spec name. The error spells out the grammar so CLI/config
    /// callers can surface it verbatim.
    pub fn from_name(s: &str) -> Result<PlacementSpec, String> {
        let err = || {
            format!(
                "unknown data placement {s:?} (valid: resident, uniform:<shards>, \
                 skewed:<shards>:<frac>, single:<region>, fed:<clients>:<alpha>, each \
                 optionally suffixed :r<replicas> and/or @<shard>=<r1>,<r2> replica \
                 pins, e.g. skewed:8:0.7:r2@0=1,3)"
            )
        };
        // `@<shard>=<regions>` suffixes pin replica sets; strip them
        // before the layout grammar.
        let mut at_parts = s.split('@');
        let base = at_parts.next().unwrap_or("");
        let mut overrides: Vec<(usize, Vec<RegionId>)> = Vec::new();
        for seg in at_parts {
            let (id, regions) = seg.split_once('=').ok_or_else(err)?;
            let id: usize = id.parse().map_err(|_| err())?;
            let regions: Vec<RegionId> = regions
                .split(',')
                .map(|r| r.parse::<RegionId>().map_err(|_| err()))
                .collect::<Result<_, _>>()?;
            if regions.is_empty() {
                return Err(err());
            }
            if overrides.iter().any(|(prev, _)| *prev == id) {
                return Err(format!("shard {id} pinned twice in {s:?}"));
            }
            overrides.push((id, regions));
        }
        overrides.sort_by_key(|(id, _)| *id);
        // An `:rK` tail is the replication factor; everything before it
        // is the layout grammar.
        let mut parts: Vec<&str> = base.split(':').collect();
        let mut replication = 1usize;
        if parts.len() > 1 {
            let last = parts[parts.len() - 1];
            let tail = last.strip_prefix('r').or_else(|| last.strip_prefix('R'));
            if let Some(digits) = tail {
                if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
                    replication = digits.parse().map_err(|_| err())?;
                    if replication == 0 {
                        return Err("replication factor must be >= 1 (r1 = single home)"
                            .to_string());
                    }
                    parts.pop();
                }
            }
        }
        let mut parts = parts.into_iter();
        let head = parts.next().unwrap_or("").to_ascii_lowercase();
        let layout = match head.as_str() {
            "resident" => Layout::Resident,
            "uniform" => {
                let shards: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                Layout::Uniform { shards }
            }
            "skewed" => {
                let shards: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                let frac: f64 = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                Layout::Skewed { shards, frac }
            }
            "single" => {
                let region: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                Layout::Single { region }
            }
            "fed" => {
                let clients: usize = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                let alpha: f64 = parts.next().and_then(|v| v.parse().ok()).ok_or_else(err)?;
                Layout::Federated { clients, alpha }
            }
            _ => return Err(err()),
        };
        if parts.next().is_some() {
            return Err(err());
        }
        match layout {
            Layout::Uniform { shards } | Layout::Skewed { shards, .. } if shards == 0 => {
                Err("data placement needs at least one shard".to_string())
            }
            Layout::Skewed { frac, .. } if !(0.0..=1.0).contains(&frac) => {
                Err(format!("skew fraction must be in [0, 1], got {frac}"))
            }
            Layout::Federated { clients: 0, .. } => {
                Err("fed layout needs at least one client".to_string())
            }
            Layout::Federated { alpha, .. } if !(alpha > 0.0) || !alpha.is_finite() => {
                Err(format!("fed concentration alpha must be positive and finite, got {alpha}"))
            }
            ok => Ok(PlacementSpec { layout: ok, replication, overrides }),
        }
    }

    /// Stable name (inverse of [`PlacementSpec::from_name`]); the `:rK`
    /// suffix appears only for replicated specs, `@` pins only when
    /// overrides exist.
    pub fn name(&self) -> String {
        let mut out = match self.layout {
            Layout::Resident => "resident".to_string(),
            Layout::Uniform { shards } => format!("uniform:{shards}"),
            Layout::Skewed { shards, frac } => format!("skewed:{shards}:{frac}"),
            Layout::Single { region } => format!("single:{region}"),
            Layout::Federated { clients, alpha } => format!("fed:{clients}:{alpha}"),
        };
        if self.replication > 1 {
            out.push_str(&format!(":r{}", self.replication));
        }
        for (id, regions) in &self.overrides {
            let rs: Vec<String> = regions.iter().map(|r| r.to_string()).collect();
            out.push_str(&format!("@{id}={}", rs.join(",")));
        }
        out
    }

    /// Fold a whole-catalog replica map (see [`parse_replica_map`]) into
    /// the spec as per-shard pins. Inline `@` pins from the spec name
    /// win: a shard the spec already pins keeps its pin and the map's
    /// entry for it is ignored, so a map file can set the catalog-wide
    /// baseline while the spec string spot-corrects individual shards.
    pub fn with_replica_map(mut self, map: Vec<(usize, Vec<RegionId>)>) -> PlacementSpec {
        for (id, regions) in map {
            if self.overrides.iter().any(|(pinned, _)| *pinned == id) {
                continue;
            }
            self.overrides.push((id, regions));
        }
        self.overrides.sort_by_key(|(id, _)| *id);
        self
    }
}

/// Parse a whole-catalog replica map document — the `"replica_map"`
/// dataplane config key and the `--replica-map` CLI flag both point at a
/// JSON file in this shape:
///
/// ```json
/// { "0": [1, 3], "2": [0] }
/// ```
///
/// Keys are final catalog shard ids (decimal strings — JSON object keys
/// are always strings), values are the shard's full replica set with the
/// home region first. Returns `(shard_id, replicas)` pairs sorted by id;
/// out-of-range ids or regions are caught later at catalog build, like
/// inline `@` pins.
pub fn parse_replica_map(text: &str) -> Result<Vec<(usize, Vec<RegionId>)>, String> {
    let err = |what: &str| {
        format!(
            "bad replica map: {what} (expected a JSON object of \
             \"<shard id>\": [region, ...] entries, e.g. {{\"0\": [1, 3]}})"
        )
    };
    let doc = crate::util::json::Json::parse(text)
        .map_err(|e| err(&format!("unparseable JSON ({e:?})")))?;
    let obj = doc.as_obj().ok_or_else(|| err("top level is not an object"))?;
    let mut map: Vec<(usize, Vec<RegionId>)> = Vec::new();
    for (key, value) in obj {
        let id: usize = key
            .parse()
            .map_err(|_| err(&format!("key {key:?} is not a shard id")))?;
        let arr = value
            .as_arr()
            .ok_or_else(|| err(&format!("shard {id}'s value is not an array")))?;
        let regions: Vec<RegionId> = arr
            .iter()
            .map(|r| {
                r.as_usize()
                    .ok_or_else(|| err(&format!("shard {id} lists a non-integer region")))
            })
            .collect::<Result<_, _>>()?;
        if regions.is_empty() {
            return Err(err(&format!("shard {id}'s replica set is empty")));
        }
        map.push((id, regions));
    }
    // BTreeMap iteration sorts keys lexicographically ("10" < "2");
    // re-sort numerically so pins land in catalog order.
    map.sort_by_key(|(id, _)| *id);
    Ok(map)
}

/// [`parse_replica_map`] over a file path (the CLI/config entry point).
pub fn load_replica_map(
    path: impl AsRef<std::path::Path>,
) -> Result<Vec<(usize, Vec<RegionId>)>, String> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading replica map {}: {e}", path.display()))?;
    parse_replica_map(&text)
}

/// The catalog: every shard of one dataset with its current replica set.
#[derive(Debug, Clone)]
pub struct DatasetCatalog {
    pub shards: Vec<ShardInfo>,
    pub n_regions: usize,
    /// Residency version: bumped every time a replica is actually added
    /// ([`DatasetCatalog::add_replica`] / [`DatasetCatalog::merge_replicas`]),
    /// so callers holding derived state (the fleet's queued data splits)
    /// can skip recomputing it when nothing moved. Not part of equality —
    /// two catalogs with identical residency compare equal however they
    /// got there.
    version: u64,
}

impl PartialEq for DatasetCatalog {
    fn eq(&self, other: &Self) -> bool {
        self.shards == other.shards && self.n_regions == other.n_regions
    }
}

/// Split `[0, n)` into `k` contiguous chunks whose sizes differ by at
/// most one; returns `(start, end)` pairs (possibly empty chunks when
/// `k > n`).
fn chunks(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1);
    (0..k).map(|i| (i * n / k, (i + 1) * n / k)).collect()
}

impl DatasetCatalog {
    /// Build the catalog for one job: `n_train` samples at `sample_bytes`
    /// each over `n_regions` clouds. `region_samples` is the config's
    /// per-region `data` distribution (used by [`Layout::Resident`]
    /// only). Replicated specs seed each shard's extra copies
    /// deterministically round-robin over the other regions (rotated by
    /// shard id, so a hot region's shards spread their second copies).
    pub fn from_spec(
        spec: &PlacementSpec,
        n_train: usize,
        n_regions: usize,
        sample_bytes: u64,
        region_samples: &[usize],
    ) -> Result<DatasetCatalog, String> {
        if n_regions == 0 {
            return Err("catalog needs at least one region".to_string());
        }
        if n_train == 0 {
            return Err("catalog needs at least one sample".to_string());
        }
        if spec.replication == 0 {
            return Err("replication factor must be >= 1".to_string());
        }
        // `from_name` rejects zero shard counts, but the fields are
        // public: validate here too so direct construction errors
        // instead of panicking in the chunking below.
        if let Layout::Uniform { shards: 0 } | Layout::Skewed { shards: 0, .. } = spec.layout {
            return Err("data placement needs at least one shard".to_string());
        }
        if let Layout::Federated { clients, alpha } = spec.layout {
            if clients == 0 {
                return Err("fed layout needs at least one client".to_string());
            }
            if !(alpha > 0.0) || !alpha.is_finite() {
                return Err(format!(
                    "fed concentration alpha must be positive and finite, got {alpha}"
                ));
            }
        }
        let shard = |id: usize, home: RegionId, start: usize, end: usize| ShardInfo {
            id,
            replicas: vec![home],
            start,
            end,
            bytes: (end - start) as u64 * sample_bytes,
        };
        let mut shards = Vec::new();
        match spec.layout {
            Layout::Resident => {
                // Mirror data::shard_by_fraction's contiguous split.
                let total: usize = region_samples.iter().map(|s| s.max(&1)).sum();
                let mut start = 0usize;
                for r in 0..n_regions {
                    let frac = *region_samples.get(r).unwrap_or(&1).max(&1);
                    let count = if r + 1 == n_regions {
                        n_train - start
                    } else {
                        (n_train as f64 * frac as f64 / total as f64).round() as usize
                    };
                    let end = (start + count).min(n_train);
                    shards.push(shard(r, r, start, end));
                    start = end;
                }
            }
            Layout::Uniform { shards: k } => {
                for (i, (s, e)) in chunks(n_train, k).into_iter().enumerate() {
                    shards.push(shard(i, i % n_regions, s, e));
                }
            }
            Layout::Skewed { shards: k, frac } => {
                let hot_n = ((n_train as f64) * frac).round() as usize;
                let hot_n = hot_n.min(n_train);
                let cold_n = n_train - hot_n;
                // Both sides populated need at least one shard each.
                let k = if hot_n > 0 && cold_n > 0 { k.max(2) } else { k };
                let hot_k = (((k as f64) * frac).round() as usize)
                    .clamp(usize::from(hot_n > 0), k - usize::from(cold_n > 0));
                let cold_k = k - hot_k;
                let mut id = 0;
                for (s, e) in chunks(hot_n, hot_k.max(1)).into_iter() {
                    if hot_n > 0 {
                        shards.push(shard(id, 0, s, e));
                        id += 1;
                    }
                }
                let cold_regions = n_regions.max(2) - 1;
                for (i, (s, e)) in chunks(cold_n, cold_k.max(1)).into_iter().enumerate() {
                    if cold_n > 0 {
                        let home = if n_regions == 1 { 0 } else { 1 + (i % cold_regions) };
                        shards.push(shard(id, home, hot_n + s, hot_n + e));
                        id += 1;
                    }
                }
            }
            Layout::Single { region } => {
                if region >= n_regions {
                    return Err(format!(
                        "single:{region} names a region outside the {n_regions}-region environment"
                    ));
                }
                // Keep shard granularity so the planner can still split
                // the move decision.
                let k = (2 * n_regions).max(2);
                for (i, (s, e)) in chunks(n_train, k).into_iter().enumerate() {
                    shards.push(shard(i, region, s, e));
                }
            }
            Layout::Federated { clients, alpha } => {
                // One shard per cloud, Dirichlet(alpha)-proportioned:
                // non-IID quantity skew across the clouds' edge
                // populations. The seed is a pure function of the
                // layout parameters (`from_spec` takes none), so the
                // carve is identical across runs — the determinism the
                // federated tests pin. Per-cohort *label* skew below
                // each cloud is drawn by the engine from the same
                // parameters (see `engine/driver`).
                let mut rng = crate::util::rng::Pcg32::new(
                    0xFED5_EED0 ^ (clients as u64).rotate_left(17) ^ alpha.to_bits(),
                    n_regions as u64,
                );
                let weights = rng.dirichlet_symmetric(alpha, n_regions);
                let mut start = 0usize;
                for (r, w) in weights.iter().enumerate() {
                    let end = if r + 1 == n_regions {
                        n_train
                    } else {
                        (start + (n_train as f64 * w).round() as usize).min(n_train)
                    };
                    shards.push(shard(r, r, start, end));
                    start = end;
                }
            }
        }
        shards.retain(|s| s.samples() > 0);
        for (i, s) in shards.iter_mut().enumerate() {
            s.id = i;
        }
        // Seed the extra replicas: shard i's j-th extra copy lands
        // `1 + (i + j) mod (n - 1)` regions past its home — distinct per
        // shard-and-copy, rotated by shard id so a hot region's shards
        // fan their second copies across every other region.
        let copies = spec.replication.min(n_regions);
        if copies > 1 && n_regions > 1 {
            for s in shards.iter_mut() {
                let h = s.replicas[0];
                for j in 0..copies - 1 {
                    let off = 1 + (s.id + j) % (n_regions - 1);
                    let r = (h + off) % n_regions;
                    if !s.replicas.contains(&r) {
                        s.replicas.push(r);
                    }
                }
            }
        }
        // Explicit `@shard=` pins replace the seeded replica sets last,
        // so tests and configs can dictate exact residency.
        for (id, regions) in &spec.overrides {
            if regions.is_empty() {
                return Err(format!("shard {id} override pins an empty replica set"));
            }
            if let Some(bad) = regions.iter().find(|&&r| r >= n_regions) {
                return Err(format!(
                    "shard {id} override names region {bad} outside the \
                     {n_regions}-region environment"
                ));
            }
            let mut dedup = Vec::new();
            for &r in regions {
                if !dedup.contains(&r) {
                    dedup.push(r);
                }
            }
            match shards.get_mut(*id) {
                Some(s) => s.replicas = dedup,
                None => {
                    return Err(format!(
                        "@{id}= override names a shard outside the {}-shard catalog",
                        shards.len()
                    ))
                }
            }
        }
        Ok(DatasetCatalog { shards, n_regions, version: 0 })
    }

    /// Samples physically resident per region, counting every replica
    /// copy (a region holding a copy can train those samples locally).
    pub fn resident_samples(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.n_regions];
        for s in &self.shards {
            for &r in &s.replicas {
                out[r] += s.samples();
            }
        }
        out
    }

    /// Bytes physically resident per region (every replica copy counted).
    pub fn resident_bytes(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.n_regions];
        for s in &self.shards {
            for &r in &s.replicas {
                out[r] += s.bytes;
            }
        }
        out
    }

    pub fn total_samples(&self) -> usize {
        self.shards.iter().map(|s| s.samples()).sum()
    }

    /// Bytes of the logical dataset (each shard counted once, however
    /// many replicas it has).
    pub fn total_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).sum()
    }

    /// Does region `r` hold a copy of shard `shard_id`?
    pub fn has_replica(&self, shard_id: usize, r: RegionId) -> bool {
        self.shards.get(shard_id).map_or(false, |s| s.has_replica(r))
    }

    /// Current residency version (see the field doc). Monotone
    /// non-decreasing; a changed version means residency changed, an
    /// unchanged version means derived state is still valid.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Record a completed replica copy: the shard's bytes now *also*
    /// live in `to` (idempotent; the source copy is not released).
    pub fn add_replica(&mut self, shard_id: usize, to: RegionId) {
        if let Some(s) = self.shards.get_mut(shard_id) {
            if !s.replicas.contains(&to) {
                s.replicas.push(to);
                self.version += 1;
            }
        }
    }

    /// Union another catalog's replica sets into this one (the fleet's
    /// live shared-catalog view absorbing a job's delivered migrations).
    /// No-op returning `false` when the shard geometries differ; returns
    /// whether any replica was actually added.
    pub fn merge_replicas(&mut self, other: &DatasetCatalog) -> bool {
        if self.n_regions != other.n_regions || self.shards.len() != other.shards.len() {
            return false;
        }
        if self
            .shards
            .iter()
            .zip(&other.shards)
            .any(|(a, b)| a.start != b.start || a.end != b.end)
        {
            return false;
        }
        let mut changed = false;
        for (mine, theirs) in self.shards.iter_mut().zip(&other.shards) {
            for &r in &theirs.replicas {
                if !mine.replicas.contains(&r) {
                    mine.replicas.push(r);
                    changed = true;
                }
            }
        }
        if changed {
            self.version += 1;
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_names_round_trip() {
        for name in ["resident", "uniform:8", "skewed:8:0.7", "single:2", "skewed:8:0.7:r2",
                     "uniform:4:r3", "resident:r2", "single:0:r2", "fed:100000:0.5",
                     "fed:64:1:r2", "uniform:4:r2@0=1,3@2=0", "skewed:8:0.7@1=2",
                     "fed:1000:0.1@0=0,1,2"] {
            let spec = PlacementSpec::from_name(name).unwrap();
            assert_eq!(spec.name(), name);
        }
        assert_eq!(
            PlacementSpec::from_name("SKEWED:4:0.5").unwrap(),
            PlacementSpec::new(Layout::Skewed { shards: 4, frac: 0.5 })
        );
        assert_eq!(PlacementSpec::from_name("uniform:4:r1").unwrap().replication, 1);
        assert_eq!(PlacementSpec::from_name("uniform:4:r1").unwrap().name(), "uniform:4");
        assert_eq!(PlacementSpec::from_name("skewed:8:0.7:R2").unwrap().replication, 2);
        let pinned = PlacementSpec::from_name("uniform:4@2=3,1@0=2").unwrap();
        assert_eq!(pinned.overrides, vec![(0, vec![2]), (2, vec![3, 1])], "pins sorted by id");
        assert_eq!(
            pinned,
            PlacementSpec::new(Layout::Uniform { shards: 4 })
                .with_override(2, vec![3, 1])
                .with_override(0, vec![2])
        );
        for bad in ["", "striped:4", "uniform", "uniform:0", "skewed:4", "skewed:4:1.5",
                    "single:x", "uniform:4:9", "uniform:4:r0", "uniform:4:r", "r2",
                    "skewed:8:0.7:r2:r3", "fed:0:0.5", "fed:10:0", "fed:10:-1", "fed:10",
                    "uniform:4@x=1", "uniform:4@0=", "uniform:4@0", "uniform:4@0=1@0=2"] {
            assert!(PlacementSpec::from_name(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn fed_layout_is_deterministic_and_total() {
        let spec = PlacementSpec::from_name("fed:100000:0.5").unwrap();
        let a = DatasetCatalog::from_spec(&spec, 4096, 4, 100, &[1; 4]).unwrap();
        let b = DatasetCatalog::from_spec(&spec, 4096, 4, 100, &[1; 4]).unwrap();
        assert_eq!(a, b, "same spec carves the same shards every run");
        assert_eq!(a.total_samples(), 4096, "every sample lands somewhere");
        assert!(a.shards.len() <= 4, "one shard per cloud at most");
        // The Dirichlet carve is actually skewed (alpha well below the
        // uniform regime): the largest cloud holds more than its even
        // share.
        let max = a.shards.iter().map(|s| s.samples()).max().unwrap();
        assert!(max > 4096 / 4, "alpha=0.5 skews the carve: {:?}",
                a.shards.iter().map(|s| s.samples()).collect::<Vec<_>>());
        // Different client populations reseed the carve.
        let other = DatasetCatalog::from_spec(
            &PlacementSpec::from_name("fed:50000:0.5").unwrap(),
            4096,
            4,
            100,
            &[1; 4],
        )
        .unwrap();
        assert_ne!(
            a.shards.iter().map(|s| s.samples()).collect::<Vec<_>>(),
            other.shards.iter().map(|s| s.samples()).collect::<Vec<_>>(),
            "client count perturbs the seed"
        );
    }

    #[test]
    fn shard_overrides_pin_replica_sets() {
        let spec = PlacementSpec::from_name("uniform:4:r2@1=3,0@3=2").unwrap();
        let c = DatasetCatalog::from_spec(&spec, 400, 4, 10, &[1; 4]).unwrap();
        assert_eq!(c.shards[1].replicas, vec![3, 0], "pin replaces the seeded set");
        assert_eq!(c.shards[3].replicas, vec![2], "a pin may shrink below :rK");
        assert_eq!(c.shards[0].replicas.len(), 2, "unpinned shards keep seeded copies");
        assert_eq!(c.shards[1].home(), 3, "first pinned region is the home");
        // Duplicate regions inside one pin collapse.
        let dup = PlacementSpec::new(Layout::Uniform { shards: 2 }).with_override(0, vec![1, 1]);
        let cd = DatasetCatalog::from_spec(&dup, 100, 2, 1, &[1; 2]).unwrap();
        assert_eq!(cd.shards[0].replicas, vec![1]);
        // Out-of-range shard or region errors at build, not at parse
        // (the grammar doesn't know the environment).
        let bad_shard = PlacementSpec::from_name("uniform:2@9=0").unwrap();
        assert!(DatasetCatalog::from_spec(&bad_shard, 100, 2, 1, &[1; 2]).is_err());
        let bad_region = PlacementSpec::from_name("uniform:2@0=5").unwrap();
        assert!(DatasetCatalog::from_spec(&bad_region, 100, 2, 1, &[1; 2]).is_err());
        let empty_pin = PlacementSpec::new(Layout::Uniform { shards: 2 })
            .with_override(0, Vec::new());
        assert!(DatasetCatalog::from_spec(&empty_pin, 100, 2, 1, &[1; 2]).is_err());
    }

    #[test]
    fn replica_map_parses_and_folds_under_inline_pins() {
        let map = parse_replica_map(r#"{"2": [0], "10": [1, 3], "0": [2, 1]}"#).unwrap();
        assert_eq!(
            map,
            vec![(0, vec![2, 1]), (2, vec![0]), (10, vec![1, 3])],
            "entries sort numerically, not by JSON key order"
        );
        // The map seeds pins for unpinned shards; inline @ pins win.
        let spec = PlacementSpec::from_name("uniform:4@2=3")
            .unwrap()
            .with_replica_map(vec![(0, vec![2, 1]), (2, vec![0])]);
        assert_eq!(spec.overrides, vec![(0, vec![2, 1]), (2, vec![3])]);
        // A folded map behaves exactly like the equivalent inline pins.
        let c = DatasetCatalog::from_spec(&spec, 400, 4, 10, &[1; 4]).unwrap();
        assert_eq!(c.shards[0].replicas, vec![2, 1]);
        assert_eq!(c.shards[2].replicas, vec![3]);
        for bad in [
            "[]",
            "not json",
            r#"{"x": [0]}"#,
            r#"{"0": 1}"#,
            r#"{"0": []}"#,
            r#"{"0": ["east"]}"#,
        ] {
            assert!(parse_replica_map(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn skewed_catalog_holds_the_fraction_hot() {
        let c = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 }),
            512,
            4,
            100,
            &[1; 4],
        )
        .unwrap();
        let res = c.resident_samples();
        assert_eq!(res.iter().sum::<usize>(), 512, "every sample is resident somewhere");
        let hot = res[0] as f64 / 512.0;
        assert!((hot - 0.7).abs() < 0.05, "hot region holds ~70%: {res:?}");
        assert!(res[1] > 0 && res[2] > 0, "cold shards spread round-robin: {res:?}");
        assert_eq!(c.total_bytes(), 512 * 100);
        // Shards partition [0, n) contiguously and disjointly.
        let mut all: Vec<usize> = c.shards.iter().flat_map(|s| s.indices()).collect();
        all.sort();
        assert_eq!(all, (0..512).collect::<Vec<_>>());
    }

    #[test]
    fn replicated_spec_seeds_spread_copies() {
        let c = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 }).with_replication(2),
            512,
            4,
            100,
            &[1; 4],
        )
        .unwrap();
        for s in &c.shards {
            assert_eq!(s.replicas.len(), 2, "every shard gets two copies: {s:?}");
            assert_ne!(s.replicas[0], s.replicas[1]);
        }
        // Logical bytes ignore replication; physical residency counts it.
        assert_eq!(c.total_bytes(), 512 * 100);
        let res: usize = c.resident_samples().iter().sum();
        assert_eq!(res, 2 * 512, "each copy is physically resident");
        // The hot region's shards fan their second copies over every
        // other region, not all onto one neighbor.
        let hot_extras: std::collections::BTreeSet<usize> = c
            .shards
            .iter()
            .filter(|s| s.home() == 0)
            .map(|s| s.replicas[1])
            .collect();
        assert!(hot_extras.len() >= 2, "second copies spread: {hot_extras:?}");
        // Replication clamps to the region count.
        let full = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Uniform { shards: 3 }).with_replication(9),
            90,
            3,
            10,
            &[1; 3],
        )
        .unwrap();
        for s in &full.shards {
            assert_eq!(s.replicas.len(), 3, "clamped to every region: {s:?}");
        }
    }

    #[test]
    fn uniform_and_single_and_resident() {
        let u = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Uniform { shards: 4 }),
            400,
            4,
            10,
            &[1; 4],
        )
        .unwrap();
        assert_eq!(u.resident_samples(), vec![100; 4]);

        let s = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Single { region: 3 }),
            400,
            4,
            10,
            &[1; 4],
        )
        .unwrap();
        assert_eq!(s.resident_samples()[3], 400);
        assert!(s.shards.len() >= 2, "single keeps planner granularity");
        assert!(DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Single { region: 4 }),
            400,
            4,
            10,
            &[1; 4]
        )
        .is_err());

        let r = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Resident),
            300,
            2,
            10,
            &[200, 100],
        )
        .unwrap();
        assert_eq!(r.resident_samples(), vec![200, 100], "mirrors shard_by_fraction");
    }

    #[test]
    fn directly_constructed_zero_shard_specs_error_not_panic() {
        for layout in [
            Layout::Uniform { shards: 0 },
            Layout::Skewed { shards: 0, frac: 1.0 },
            Layout::Skewed { shards: 0, frac: 0.3 },
        ] {
            assert!(
                DatasetCatalog::from_spec(&PlacementSpec::new(layout), 100, 3, 1, &[1; 3])
                    .is_err(),
                "{layout:?} must be rejected"
            );
        }
        let zero_r =
            PlacementSpec { layout: Layout::Resident, replication: 0, overrides: Vec::new() };
        assert!(DatasetCatalog::from_spec(&zero_r, 100, 3, 1, &[1; 3]).is_err());
    }

    #[test]
    fn extreme_skews_stay_total() {
        let all_hot = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 4, frac: 1.0 }),
            100,
            3,
            1,
            &[1; 3],
        )
        .unwrap();
        assert_eq!(all_hot.resident_samples(), vec![100, 0, 0]);
        let no_hot = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 4, frac: 0.0 }),
            100,
            3,
            1,
            &[1; 3],
        )
        .unwrap();
        assert_eq!(no_hot.resident_samples()[0], 0);
        assert_eq!(no_hot.total_samples(), 100);
    }

    #[test]
    fn sample_bytes_follows_geometry() {
        let meta = ModelMeta::parse(
            r#"{"name":"lenet","param_count":1,"batch_size":8,"x_shape":[28,28,1],
                "x_dtype":"f32","y_dtype":"i32","num_classes":10,"meta":{}}"#,
        )
        .unwrap();
        assert_eq!(sample_bytes(&meta), (784 + 1) * 4);
    }

    #[test]
    fn add_replica_is_additive_and_idempotent() {
        let mut c = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Uniform { shards: 4 }),
            400,
            4,
            10,
            &[1; 4],
        )
        .unwrap();
        c.add_replica(0, 3);
        c.add_replica(0, 3);
        assert_eq!(c.shards[0].replicas, vec![0, 3], "copy added once, source kept");
        assert!(c.has_replica(0, 3) && c.has_replica(0, 0));
        assert_eq!(c.resident_samples(), vec![100, 100, 100, 200]);
        assert_eq!(c.total_bytes(), 4000, "logical bytes unchanged by replication");
    }

    #[test]
    fn version_bumps_only_when_residency_changes() {
        let spec = PlacementSpec::new(Layout::Uniform { shards: 4 });
        let mut c = DatasetCatalog::from_spec(&spec, 400, 4, 10, &[1; 4]).unwrap();
        assert_eq!(c.version(), 0);
        c.add_replica(0, 3);
        let v1 = c.version();
        assert!(v1 > 0, "a new copy bumps the version");
        c.add_replica(0, 3); // idempotent re-add
        assert_eq!(c.version(), v1, "no residency change, no bump");
        let mut job = c.clone();
        job.add_replica(1, 2);
        assert!(c.merge_replicas(&job));
        let v2 = c.version();
        assert!(v2 > v1);
        assert!(!c.merge_replicas(&job), "already merged");
        assert_eq!(c.version(), v2);
        // Version is bookkeeping, not identity: the same residency
        // reached through two adds (two bumps) or one merge (one bump)
        // still compares equal.
        let mut adds = c.clone();
        adds.add_replica(2, 0);
        adds.add_replica(2, 1);
        let mut merged = c.clone();
        assert!(merged.merge_replicas(&adds));
        assert_eq!(merged, adds);
        assert_ne!(merged.version(), adds.version());
    }

    #[test]
    fn merge_replicas_unions_matching_catalogs() {
        let spec = PlacementSpec::new(Layout::Uniform { shards: 4 });
        let mut live = DatasetCatalog::from_spec(&spec, 400, 4, 10, &[1; 4]).unwrap();
        let mut job = live.clone();
        job.add_replica(1, 3);
        job.add_replica(2, 0);
        assert!(live.merge_replicas(&job), "new replicas merged");
        assert!(live.has_replica(1, 3) && live.has_replica(2, 0));
        assert!(!live.merge_replicas(&job), "second merge is a no-op");
        // Geometry mismatch: refuse rather than corrupt.
        let other = DatasetCatalog::from_spec(&spec, 444, 4, 10, &[1; 4]).unwrap();
        assert!(!live.merge_replicas(&other));
    }
}

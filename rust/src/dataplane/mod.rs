//! The physical data plane — where training data actually lives, and
//! what it costs to move it.
//!
//! The paper's scheduler deploys workflows "adaptively according to the
//! heterogeneity of available cloud resources **and distribution of
//! pre-existing training datasets**" (§III.B), but the compute half was
//! the only half modeled until this layer: `sched` consumed per-region
//! sample counts as a fixed input and `data` regenerated shards locally.
//! This module makes the dataset a first-class physical object:
//!
//! - [`catalog`] — a [`DatasetCatalog`](catalog::DatasetCatalog) of sized
//!   shards, each resident in a **replica set** of one or more regions
//!   (seeded from the `"dataplane"` config block / `--data-placement`,
//!   e.g. `skewed:8:0.7` or `skewed:8:0.7:r2` for two copies per shard),
//!   plus the per-region object-store egress pricing in
//!   [`cloud::cost`](crate::cloud::cost);
//! - [`placement`] — the joint data/compute planner: for a given catalog
//!   it evaluates *compute-follows-data* (train inside the replica sets),
//!   *data-follows-compute* (migrate toward the power-optimal clouds),
//!   and a *joint* hill-climb over single-shard reassignments that may
//!   *create* replicas when the time-valued makespan saving beats the
//!   copy cost — each consumer reads from its nearest replica and egress
//!   is paid once per created copy, never per reader — returning a
//!   [`PlacementPlan`](placement::PlacementPlan)
//!   `{ allocations, assign, moves }`;
//! - [`migration`] — the physical replica copies, executed as payloads
//!   over the existing [`net::Fabric`](crate::net::Fabric) /
//!   [`SharedFabric`](crate::net::SharedFabric) so migrations FIFO-contend
//!   with gradient syncs and other jobs' traffic, with a staging phase
//!   that overlaps prefetch with the first epochs, gates shard
//!   availability through `Gate::DataBlocked`, and re-routes in-flight
//!   rebalance shards whose destination finished instead of dropping
//!   their remaining epochs.
//!
//! HeterPS (arXiv 2111.10635) schedules data and compute jointly across
//! heterogeneous resources; the modeling split here (pure planner, driver
//! applies) mirrors `sched::elastic`. Numerically nothing changes — every
//! partition still regenerates the same deterministic dataset — but the
//! *bytes* of a migrated shard are physically modeled on the WAN and the
//! destination may not train on a shard before it lands.

pub mod catalog;
pub mod migration;
pub mod placement;

pub use catalog::{
    load_replica_map, parse_replica_map, sample_bytes, DatasetCatalog, Layout, PlacementSpec,
    ShardInfo,
};
pub use placement::{
    plan_for, plan_for_catalog, plan_for_catalog_seeded, plan_for_on, plan_for_on_seeded,
    PlacementMode, PlacementPlan, PlannedDataPlane, ShardMove,
};

use crate::sim::Time;

/// The `"dataplane"` config block / `--data-placement` CLI surface.
#[derive(Debug, Clone)]
pub struct DataPlaneConfig {
    /// Initial shard placement; `None` disables the data plane entirely
    /// (the seed behavior: each region's resident samples come from its
    /// `data` config and never move).
    pub placement: Option<PlacementSpec>,
    /// Which placement strategy the planner runs.
    pub mode: PlacementMode,
    /// Stored bytes per training sample; 0 derives it from the model's
    /// tensor geometry. Real geo-resident datasets are orders of
    /// magnitude larger than the scaled-down sample counts here, so
    /// experiments typically set this explicitly (`sample_kb` in config).
    pub sample_bytes: u64,
    /// Allow the elastic control loop to propose mid-run shard
    /// rebalancing moves when a committed load re-plan shifts the
    /// straggler (hysteresis-gated exactly like compute re-plans).
    pub rebalance: bool,
    /// Dollars an hour of job makespan is worth to the planner's
    /// objective; 0 derives the default from the inventory rental rate
    /// ([`placement::default_time_value_per_hour`]).
    pub time_value_per_hour: f64,
    /// Provenance: path of the whole-catalog replica map file
    /// (`"replica_map"` config key / `--replica-map`) whose per-shard
    /// pins were folded into `placement` at load time; `None` when no
    /// map file was given. The pins themselves live in
    /// [`PlacementSpec::overrides`] — this only records where they came
    /// from.
    pub replica_map: Option<String>,
}

impl Default for DataPlaneConfig {
    fn default() -> Self {
        DataPlaneConfig {
            placement: None,
            mode: PlacementMode::Joint,
            sample_bytes: 0,
            rebalance: true,
            time_value_per_hour: 0.0,
            replica_map: None,
        }
    }
}

impl DataPlaneConfig {
    /// Is the data plane active for this job?
    pub fn enabled(&self) -> bool {
        self.placement.is_some()
    }
}

/// What the data plane did during one training run (reported inside
/// `TrainReport`).
#[derive(Debug, Clone, Default)]
pub struct DataPlaneReport {
    /// Placement mode the run planned with.
    pub mode: String,
    /// The initial-placement spec (`PlacementSpec` name).
    pub placement: String,
    /// Physical replica copies that finished migrating (zero-byte
    /// training-right handoffs onto existing replicas excluded).
    pub moved_shards: usize,
    /// Bytes of shard payloads delivered over the WAN; each created
    /// replica's bytes are counted exactly once, however many epochs
    /// read the copy afterwards.
    pub moved_bytes: u64,
    /// Replica provenance: every physical copy delivered, as
    /// `(shard id, source replica, destination region)` in delivery
    /// order — where each consumer's bytes actually came from.
    pub replicas_created: Vec<(usize, crate::net::RegionId, crate::net::RegionId)>,
    /// In-flight rebalance shards re-routed to another unfinished region
    /// because their planned destination finished before delivery
    /// (previously those shards' remaining epochs were silently dropped).
    pub rerouted_shards: usize,
    /// Moves abandoned after repeated dropped transfers (failure
    /// injection), plus re-routes with no unfinished region left; their
    /// remaining work was shed, not retried forever.
    pub failed_shards: usize,
    /// Object-store egress cost of the migrations (per-source-region
    /// pricing; see `cloud::cost::CostModel::egress_cost`).
    pub egress_cost: f64,
    /// Storage rent billed on every persisted replica copy per second
    /// held — seeded copies from job start, created copies from their
    /// delivery instant (see `cloud::cost::CostModel::storage_cost`).
    pub storage_cost: f64,
    /// Total virtual seconds partitions sat `Gate::DataBlocked` waiting
    /// for a shard to arrive.
    pub stall_time: Time,
    /// Virtual time (job-relative) the last staged shard landed; 0.0 when
    /// nothing moved.
    pub staging_done: Time,
    /// Mid-run rebalancing rounds the elastic loop committed.
    pub rebalances: u32,
}

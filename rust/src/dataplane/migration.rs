//! Physical shard migration over the WAN.
//!
//! A planned [`ShardMove`](super::placement::ShardMove) becomes a real
//! payload on the job's [`net::Fabric`](crate::net::Fabric) /
//! [`SharedFabric`](crate::net::SharedFabric): it serializes FIFO behind
//! whatever else is on the directed link — gradient syncs and, on a
//! shared multi-job fabric, other tenants' traffic — so migration
//! contention is physical, not modeled. Transfers are issued by the
//! chosen **source replica**'s object store, not the PS communicator, so
//! they do not occupy the partition's gRPC send slot (but they do occupy
//! the wire). A delivered copy *adds* a replica (the source keeps its
//! bytes); a zero-byte [`ShardMove`] is a pure training-right handoff
//! onto a region that already holds a replica — it never touches the
//! WAN and pays no egress.
//!
//! **Staging** overlaps with training: every staged move starts at the
//! training start, destinations train on whatever is already resident,
//! and a partition that runs out of available data gates on
//! [`Gate::DataBlocked`] until its next shard lands (the accumulated
//! block time is the report's `stall_time`). Mid-run rebalancing moves
//! (`grow_dest`) additionally retime the destination's step budget,
//! since their samples were not part of the deploy-time plan; if the
//! destination *finishes* while such a shard is still in flight, the
//! delivery re-routes to the next-best unfinished region instead of
//! silently dropping the shard's remaining epochs
//! ([`DataPlaneReport::rerouted_shards`](super::DataPlaneReport)).
//!
//! Numerics are unchanged: sample *contents* regenerate deterministically
//! everywhere (`crate::data`); what moves here is the modeled bytes and
//! the *right to train* on those samples.

use crate::cloud::cost::CostModel;
use crate::engine::driver::{self, World};
use crate::engine::partition::Gate;
use crate::net::{RegionId, TrafficClass};
use crate::sim::{Sim, Time};

use super::catalog::{DatasetCatalog, PlacementSpec};
use super::placement::{PlacementMode, ShardMove};
use super::DataPlaneReport;

/// One in-progress (or finished) shard transfer.
pub(crate) struct MoveState {
    pub mv: ShardMove,
    /// Global sample indices the destination gains on arrival.
    pub indices: Vec<usize>,
    /// Rebalance moves retime the destination's step budget on arrival;
    /// staged moves were already counted at deploy.
    pub grow_dest: bool,
    pub delivered: bool,
    /// Dropped-transfer retries so far (failure injection).
    pub attempts: u32,
}

/// Give up on a dropped shard transfer after this many attempts (with
/// exponential backoff between them): unlike the communicator's
/// optional gradient retries, an unbounded retry on a fully-blacked-out
/// link would spin the event loop forever while the destination waits.
const MAX_MOVE_ATTEMPTS: u32 = 8;

/// The job's live data-plane state (inside `engine::driver::World`).
pub(crate) struct DataPlaneState {
    /// Catalog with *current* replica sets (copies added as they land).
    pub catalog: DatasetCatalog,
    /// Which region currently holds the right to train each shard
    /// (index = shard id; sources shed at move commit, destinations
    /// gain at delivery).
    pub assign: Vec<RegionId>,
    /// Shards whose remaining work was shed for good (an abandoned
    /// transfer, or a re-route with nobody left to train it): excluded
    /// from the controller's residency view and never rebalanced again —
    /// `failed_shards` already reported their work as lost.
    pub shed: Vec<bool>,
    pub mode: PlacementMode,
    pub placement: PlacementSpec,
    pub cost: CostModel,
    pub moves: Vec<MoveState>,
    /// Moves issued or queued but not yet delivered.
    pub pending: usize,
    /// Bytes put on the WAN (egress side; counted at send).
    pub sent_bytes: u64,
    /// Bytes delivered (arrival side).
    pub moved_bytes: u64,
    /// Physical copies delivered (zero-byte handoffs excluded).
    pub moved_shards: usize,
    /// Replica provenance: every physical copy delivered, as
    /// `(shard, source replica, destination)`, delivery order.
    pub replicas_created: Vec<(usize, RegionId, RegionId)>,
    /// Delivery instant of each created copy (absolute virtual time,
    /// parallel to `replicas_created`) — the start of its storage-rent
    /// billing window.
    pub replica_delivered_at: Vec<Time>,
    /// In-flight rebalance shards re-routed because their destination
    /// finished before delivery.
    pub rerouted: usize,
    /// Moves abandoned after [`MAX_MOVE_ATTEMPTS`] dropped transfers
    /// (their samples' remaining work is shed, not silently retried
    /// forever), plus rebalance shards left with no unfinished region
    /// to re-route to.
    pub failed_moves: usize,
    pub egress_cost: f64,
    /// Latest delivery instant (absolute virtual time).
    pub staging_done: Time,
    pub rebalances: u32,
}

impl DataPlaneState {
    pub fn new(
        catalog: DatasetCatalog,
        assign: Vec<RegionId>,
        mode: PlacementMode,
        placement: PlacementSpec,
    ) -> Self {
        debug_assert_eq!(catalog.shards.len(), assign.len(), "one trainer per shard");
        let shed = vec![false; catalog.shards.len()];
        DataPlaneState {
            catalog,
            assign,
            shed,
            mode,
            placement,
            cost: CostModel::default(),
            moves: Vec::new(),
            pending: 0,
            sent_bytes: 0,
            moved_bytes: 0,
            moved_shards: 0,
            replicas_created: Vec::new(),
            replica_delivered_at: Vec::new(),
            rerouted: 0,
            failed_moves: 0,
            egress_cost: 0.0,
            staging_done: 0.0,
            rebalances: 0,
        }
    }

    /// Samples each region currently holds the right to train — the
    /// residency view the elastic controller plans against. Shed shards
    /// (abandoned transfers) count for nobody: their work is lost.
    pub fn assigned_samples(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.catalog.n_regions];
        for ((s, &a), &shed) in self.catalog.shards.iter().zip(&self.assign).zip(&self.shed) {
            if !shed {
                out[a] += s.samples();
            }
        }
        out
    }

    /// Queue a move for execution (caller schedules [`begin_move`]).
    pub fn enqueue(&mut self, mv: ShardMove, indices: Vec<usize>, grow_dest: bool) -> usize {
        self.moves.push(MoveState { mv, indices, grow_dest, delivered: false, attempts: 0 });
        self.pending += 1;
        self.moves.len() - 1
    }

    /// Storage rent over the job's lifetime `[start_at, end_at]`: every
    /// physical copy is billed per second held — seeded copies from the
    /// job start, created copies from their delivery instant. The fix
    /// for the ROADMAP's "replica copies are a free lunch once created".
    pub fn storage_rent(&self, start_at: Time, end_at: Time) -> f64 {
        let end = end_at.max(start_at);
        let mut created_per_shard = vec![0usize; self.catalog.shards.len()];
        let mut rent = 0.0;
        for ((shard, _, _), &at) in self.replicas_created.iter().zip(&self.replica_delivered_at)
        {
            created_per_shard[*shard] += 1;
            rent += self
                .cost
                .storage_cost(self.catalog.shards[*shard].bytes, (end - at).max(0.0));
        }
        for (s, &created) in self.catalog.shards.iter().zip(&created_per_shard) {
            let seeded = s.replicas.len().saturating_sub(created) as u64;
            rent += self.cost.storage_cost(s.bytes * seeded, end - start_at);
        }
        rent
    }

    /// Snapshot the report; `stall` is the summed partition block time,
    /// `start_at` the job's admission epoch (staging time is reported
    /// job-relative), and `end_at` the job end that closes every copy's
    /// rent billing window.
    pub fn report(&self, stall: Time, start_at: Time, end_at: Time) -> DataPlaneReport {
        DataPlaneReport {
            mode: self.mode.name().to_string(),
            placement: self.placement.name(),
            moved_shards: self.moved_shards,
            moved_bytes: self.moved_bytes,
            replicas_created: self.replicas_created.clone(),
            rerouted_shards: self.rerouted,
            failed_shards: self.failed_moves,
            egress_cost: self.egress_cost,
            storage_cost: self.storage_rent(start_at, end_at),
            stall_time: stall,
            staging_done: if self.moved_shards == 0 {
                0.0
            } else {
                (self.staging_done - start_at).max(0.0)
            },
            rebalances: self.rebalances,
        }
    }
}

/// Put move `idx` on the WAN now. The transfer rides the `BulkData`
/// lane: on a lanes-off fabric it FIFO-queues behind any earlier
/// traffic (the seed behavior); with `wan_lanes` it yields to
/// latency-critical barrier/gradient transfers at serialization
/// boundaries. Egress is priced at the
/// source replica's object-store rate at send time. A zero-byte handoff
/// (the destination already holds a replica) delivers immediately
/// without touching the fabric. Dropped transfers (failure injection)
/// retry with exponential backoff and give up after
/// [`MAX_MOVE_ATTEMPTS`] — see [`abandon_move`].
pub(crate) fn begin_move(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (from, to, bytes) = {
        let st = w.dataplane.as_ref().expect("data plane active");
        let m = &st.moves[idx].mv;
        (m.from, m.to, m.bytes)
    };
    if bytes == 0 {
        // Training-right handoff onto an existing replica: local read,
        // no WAN traffic, no egress — deliver on the next event round.
        sim.schedule(0.0, move |sim, w: &mut World| {
            deliver_shard(sim, w, idx);
        });
        return;
    }
    let t = w.fabric.transfer_class(from, to, bytes, now, TrafficClass::BulkData);
    w.wan_transfers += 1;
    if t.dropped {
        let attempts = {
            let st = w.dataplane.as_mut().expect("data plane active");
            let m = &mut st.moves[idx];
            m.attempts += 1;
            m.attempts
        };
        if attempts >= MAX_MOVE_ATTEMPTS {
            abandon_move(sim, w, idx);
        } else {
            sim.schedule(f64::from(1u32 << attempts), move |sim, w: &mut World| {
                begin_move(sim, w, idx);
            });
        }
        return;
    }
    w.wan_bytes += bytes;
    {
        let st = w.dataplane.as_mut().expect("data plane active");
        st.sent_bytes += bytes;
        let egress = st.cost.egress_cost(from, bytes);
        st.egress_cost += egress;
    }
    sim.schedule_at(t.arrival, move |sim, w: &mut World| {
        deliver_shard(sim, w, idx);
    });
}

/// Give up on move `idx` (its link dropped every attempt): the shard's
/// remaining work is shed honestly instead of retrying forever. For a
/// *staged* move the destination's step budget pre-counted the samples,
/// so it is retimed down to what is available now **plus** any sibling
/// staged shards still inbound (those stay pre-counted — shrinking past
/// them would let the destination finish before they land and drop
/// their work on delivery). A rebalance move's samples were already
/// shed at the source; they are simply lost (reported via
/// `failed_shards`).
fn abandon_move(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (dest, was_staged) = {
        let st = w.dataplane.as_mut().expect("data plane active");
        let m = &mut st.moves[idx];
        m.delivered = true; // terminal: no further retries
        st.pending = st.pending.saturating_sub(1);
        st.failed_moves += 1;
        // Nobody will train these samples now: keep the residency view
        // and future rebalance rounds honest about the loss.
        st.shed[m.mv.shard] = true;
        (m.mv.to, !m.grow_dest)
    };
    driver::sync_controller_residency(w);
    if was_staged {
        let inbound: usize = {
            let st = w.dataplane.as_ref().expect("data plane active");
            st.moves
                .iter()
                .filter(|m| !m.delivered && m.mv.to == dest && !m.grow_dest)
                .map(|m| m.mv.samples)
                .sum()
        };
        let finish_now = {
            let part = &mut w.parts[dest];
            if part.gate == Gate::Finished {
                false
            } else {
                part.retime_step_budget(w.model.meta.batch_size, w.cfg.epochs, inbound);
                if part.gate == Gate::DataBlocked && part.local_done() {
                    // Its only awaited data is never coming.
                    part.data_stall += now - part.data_blocked_since;
                    part.gate = Gate::Running;
                }
                part.gate == Gate::Running && part.local_done() && part.in_flight == 0
            }
        };
        if finish_now {
            driver::finish_partition(sim, w, dest);
        }
    }
}

/// Move `idx` landed: the destination may now train on its samples — or,
/// if it finished while a rebalance shard was in flight, the shard
/// re-routes to the next-best unfinished region (the delivered copy
/// still counts: the bytes physically moved and stay usable as a source
/// replica for the re-route).
pub(crate) fn deliver_shard(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (dest, indices, grow, shard_id) = {
        let st = w.dataplane.as_mut().expect("data plane active");
        let m = &mut st.moves[idx];
        debug_assert!(!m.delivered, "double delivery of move {idx}");
        m.delivered = true;
        st.pending = st.pending.saturating_sub(1);
        st.moved_bytes += m.mv.bytes;
        if m.mv.bytes > 0 {
            st.moved_shards += 1;
            st.staging_done = st.staging_done.max(now);
            st.replicas_created.push((m.mv.shard, m.mv.from, m.mv.to));
            st.replica_delivered_at.push(now);
            st.catalog.add_replica(m.mv.shard, m.mv.to);
        }
        (m.mv.to, std::mem::take(&mut m.indices), m.grow_dest, m.mv.shard)
    };
    if w.parts[dest].gate == Gate::Finished {
        if grow {
            // The destination finished while this rebalance shard was in
            // flight: its remaining epochs were shed at the source, so
            // dropping the delivery here would silently lose that work.
            reroute_move(sim, w, shard_id, indices);
        }
        // A *staged* move landing after local completion is benign: the
        // destination's step budget pre-counted these samples and was
        // already executed (batches cycle over what was resident).
        return;
    }
    {
        let part = &mut w.parts[dest];
        part.shard.extend(indices);
        if grow {
            part.retime_step_budget(w.model.meta.batch_size, w.cfg.epochs, 0);
        }
        if part.gate == Gate::DataBlocked {
            part.data_stall += now - part.data_blocked_since;
            part.gate = Gate::Running;
        }
    }
    driver::kick_idle_workers(sim, w, dest);
}

/// Re-route an in-flight rebalance shard whose destination finished
/// before delivery: hand its training right (and, where no replica
/// exists yet, its bytes) to the unfinished region with the cheapest
/// inbound transfer from the shard's current replica set. With no
/// unfinished region left the work is shed honestly (`failed_shards`).
fn reroute_move(sim: &mut Sim<World>, w: &mut World, shard: usize, indices: Vec<usize>) {
    let (bytes, replicas) = {
        let st = w.dataplane.as_ref().expect("data plane active");
        let s = &st.catalog.shards[shard];
        let mut reps = s.replicas.clone();
        reps.sort_unstable();
        (s.bytes, reps)
    };
    // Next-best unfinished target: free if it already holds a replica,
    // else cheapest estimated transfer from any replica; ties break to
    // the lowest region id (deterministic).
    let mut best: Option<(f64, RegionId, RegionId)> = None; // (est, target, source)
    for t in 0..w.parts.len() {
        if w.parts[t].gate == Gate::Finished {
            continue;
        }
        let (est, src) = if replicas.contains(&t) {
            (0.0, t)
        } else {
            let mut pick = (f64::INFINITY, replicas[0]);
            for &r in &replicas {
                let e = w.fabric.with(|f| f.estimate(r, t, bytes));
                if e < pick.0 - 1e-12 {
                    pick = (e, r);
                }
            }
            pick
        };
        // Strict improvement only: `t` ascends, so ties keep the lowest
        // region id by construction.
        if best.map_or(true, |(b, _, _)| est < b - 1e-9) {
            best = Some((est, t, src));
        }
    }
    let Some((_, target, src)) = best else {
        // Every region finished — nobody is left to train the samples.
        let st = w.dataplane.as_mut().expect("data plane active");
        st.failed_moves += 1;
        st.shed[shard] = true;
        return;
    };
    let samples = indices.len();
    let bytes_needed = if replicas.contains(&target) { 0 } else { bytes };
    let mv = ShardMove { shard, from: src, to: target, bytes: bytes_needed, samples };
    let idx = {
        let st = w.dataplane.as_mut().expect("data plane active");
        st.rerouted += 1;
        st.assign[shard] = target;
        st.enqueue(mv, indices, true)
    };
    begin_move(sim, w, idx);
    driver::sync_controller_residency(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::devices::Device;
    use crate::cloud::CloudEnv;
    use crate::dataplane::catalog::Layout;
    use crate::dataplane::{self, DataPlaneConfig};
    use crate::engine::driver::TrainConfig;
    use crate::net::{Fabric, SharedFabric};
    use crate::runtime::PjrtRuntime;
    use crate::sync::{Strategy, SyncConfig};

    /// Regression (ROADMAP data-plane defect): a destination finishing
    /// while a rebalance shard is in flight used to silently drop that
    /// shard's remaining epochs at delivery. Now the delivery re-routes
    /// to the next-best unfinished region and the work survives.
    #[test]
    fn inflight_rebalance_shard_reroutes_when_destination_finishes() {
        let rt = PjrtRuntime::new("artifacts-not-needed").unwrap();
        let env = CloudEnv::multi_region(vec![
            ("A", Device::Skylake, 6, 1),
            ("B", Device::Skylake, 6, 1),
            ("C", Device::Skylake, 6, 1),
        ]);
        let mut cfg = TrainConfig::new("synthetic");
        cfg.epochs = 4;
        cfg.n_train = 96;
        cfg.n_eval = 16;
        cfg.skip_eval = true;
        cfg.sync = SyncConfig::new(Strategy::Asgd, 1_000_000); // never syncs
        cfg.dataplane = DataPlaneConfig {
            placement: Some(crate::dataplane::PlacementSpec::new(Layout::Uniform {
                shards: 3,
            })),
            mode: dataplane::PlacementMode::ComputeFollowsData, // no staged moves
            sample_bytes: 1024 * 1024, // 32 MB shards: seconds on the wire
            ..DataPlaneConfig::default()
        };
        let meta = rt.load_model("synthetic").unwrap().meta;
        let planned = dataplane::plan_for(&env, &cfg, &meta).unwrap();
        assert!(planned.plan.moves.is_empty(), "CFD stages nothing");
        let allocations = planned.plan.allocations.clone();
        let fabric = SharedFabric::new(Fabric::full_mesh(
            cfg.seed,
            3,
            &cfg.link,
            &cfg.link_overrides,
        ));
        let (mut sim, mut world) = driver::deploy_job_planned(
            &rt,
            &env,
            allocations,
            cfg,
            0.0,
            fabric,
            Some(planned),
        )
        .unwrap();

        // Mimic a committed rebalance: shard 0 (trained at region 0)
        // hands its remaining epochs to region 1 over the WAN.
        let (start, end, bytes, samples) = {
            let dp = world.dataplane.as_ref().unwrap();
            let s = &dp.catalog.shards[0];
            (s.start, s.end, s.bytes, s.samples())
        };
        let batch = world.model.meta.batch_size;
        let epochs = world.cfg.epochs;
        {
            let part = &mut world.parts[0];
            part.shard.remove_range(start, end);
            part.retime_step_budget(batch, epochs, 0);
        }
        let idx = {
            let dp = world.dataplane.as_mut().unwrap();
            dp.assign[0] = 1;
            dp.enqueue(
                ShardMove { shard: 0, from: 0, to: 1, bytes, samples },
                (start..end).collect(),
                true,
            )
        };
        begin_move(&mut sim, &mut world, idx);
        // The destination finishes while the 32 MB transfer is on the
        // wire (~2.7 s at 100 Mbps).
        driver::finish_partition(&mut sim, &mut world, 1);
        assert_eq!(world.parts[1].gate, Gate::Finished);

        assert!(sim.run_with_limit(&mut world, 10_000_000), "run must drain");
        let dp = world.dataplane.as_ref().unwrap();
        assert_eq!(dp.rerouted, 1, "the in-flight shard must re-route, not drop");
        assert_eq!(dp.failed_moves, 0);
        // The origin still holds a replica and is unfinished, so it is
        // the cheapest re-route target: the training right comes home as
        // a zero-byte handoff and the remaining epochs actually run.
        let target = dp.assign[0];
        assert_ne!(target, 1, "the finished region cannot train the samples");
        assert_eq!(target, 0, "the origin's local replica is the cheapest target");
        assert_eq!(world.parts[0].shard.len(), samples, "the samples are trainable again");
        let expected_steps = (samples as u64).div_ceil(batch as u64) * epochs as u64;
        assert_eq!(
            world.parts[0].steps_completed, expected_steps,
            "every re-routed epoch was executed, none dropped"
        );
        assert!(world.global_end.is_some(), "the job still completes");
        // The physical copy that landed on the finished region is real
        // and recorded as provenance.
        assert_eq!(dp.replicas_created, vec![(0, 0, 1)]);
        assert!(dp.catalog.has_replica(0, 1));
    }
}

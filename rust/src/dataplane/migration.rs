//! Physical shard migration over the WAN.
//!
//! A planned [`ShardMove`](super::placement::ShardMove) becomes a real
//! payload on the job's [`net::Fabric`](crate::net::Fabric) /
//! [`SharedFabric`](crate::net::SharedFabric): it serializes FIFO behind
//! whatever else is on the directed link — gradient syncs and, on a
//! shared multi-job fabric, other tenants' traffic — so migration
//! contention is physical, not modeled. Transfers are issued by the
//! source region's object store, not the PS communicator, so they do not
//! occupy the partition's gRPC send slot (but they do occupy the wire).
//!
//! **Staging** overlaps with training: every staged move starts at the
//! training start, destinations train on whatever is already resident,
//! and a partition that runs out of available data gates on
//! [`Gate::DataBlocked`] until its next shard lands (the accumulated
//! block time is the report's `stall_time`). Mid-run rebalancing moves
//! (`grow_dest`) additionally retime the destination's step budget,
//! since their samples were not part of the deploy-time plan.
//!
//! Numerics are unchanged: sample *contents* regenerate deterministically
//! everywhere (`crate::data`); what moves here is the modeled bytes and
//! the *right to train* on those samples.

use crate::cloud::cost::CostModel;
use crate::engine::driver::{self, World};
use crate::engine::partition::Gate;
use crate::sim::{Sim, Time};

use super::catalog::{DatasetCatalog, PlacementSpec};
use super::placement::{PlacementMode, ShardMove};
use super::DataPlaneReport;

/// One in-progress (or finished) shard transfer.
pub(crate) struct MoveState {
    pub mv: ShardMove,
    /// Global sample indices the destination gains on arrival.
    pub indices: Vec<usize>,
    /// Rebalance moves retime the destination's step budget on arrival;
    /// staged moves were already counted at deploy.
    pub grow_dest: bool,
    pub delivered: bool,
    /// Dropped-transfer retries so far (failure injection).
    pub attempts: u32,
}

/// Give up on a dropped shard transfer after this many attempts (with
/// exponential backoff between them): unlike the communicator's
/// optional gradient retries, an unbounded retry on a fully-blacked-out
/// link would spin the event loop forever while the destination waits.
const MAX_MOVE_ATTEMPTS: u32 = 8;

/// The job's live data-plane state (inside `engine::driver::World`).
pub(crate) struct DataPlaneState {
    /// Catalog with *current* homes (updated as shards land).
    pub catalog: DatasetCatalog,
    pub mode: PlacementMode,
    pub placement: PlacementSpec,
    pub cost: CostModel,
    pub moves: Vec<MoveState>,
    /// Moves issued or queued but not yet delivered.
    pub pending: usize,
    /// Bytes put on the WAN (egress side; counted at send).
    pub sent_bytes: u64,
    /// Bytes delivered (arrival side).
    pub moved_bytes: u64,
    pub moved_shards: usize,
    /// Moves abandoned after [`MAX_MOVE_ATTEMPTS`] dropped transfers
    /// (their samples' remaining work is shed, not silently retried
    /// forever).
    pub failed_moves: usize,
    pub egress_cost: f64,
    /// Latest delivery instant (absolute virtual time).
    pub staging_done: Time,
    pub rebalances: u32,
}

impl DataPlaneState {
    pub fn new(catalog: DatasetCatalog, mode: PlacementMode, placement: PlacementSpec) -> Self {
        DataPlaneState {
            catalog,
            mode,
            placement,
            cost: CostModel::default(),
            moves: Vec::new(),
            pending: 0,
            sent_bytes: 0,
            moved_bytes: 0,
            moved_shards: 0,
            failed_moves: 0,
            egress_cost: 0.0,
            staging_done: 0.0,
            rebalances: 0,
        }
    }

    /// Queue a move for execution (caller schedules [`begin_move`]).
    pub fn enqueue(&mut self, mv: ShardMove, indices: Vec<usize>, grow_dest: bool) -> usize {
        self.moves.push(MoveState { mv, indices, grow_dest, delivered: false, attempts: 0 });
        self.pending += 1;
        self.moves.len() - 1
    }

    /// Snapshot the report; `stall` is the summed partition block time
    /// and `start_at` the job's admission epoch (staging time is
    /// reported job-relative).
    pub fn report(&self, stall: Time, start_at: Time) -> DataPlaneReport {
        DataPlaneReport {
            mode: self.mode.name().to_string(),
            placement: self.placement.name(),
            moved_shards: self.moved_shards,
            moved_bytes: self.moved_bytes,
            failed_shards: self.failed_moves,
            egress_cost: self.egress_cost,
            stall_time: stall,
            staging_done: if self.moved_shards == 0 {
                0.0
            } else {
                (self.staging_done - start_at).max(0.0)
            },
            rebalances: self.rebalances,
        }
    }
}

/// Put move `idx` on the WAN now. The transfer FIFO-queues on the
/// directed link behind any earlier traffic; egress is priced at the
/// source region's object-store rate at send time. Dropped transfers
/// (failure injection) retry with exponential backoff and give up after
/// [`MAX_MOVE_ATTEMPTS`] — see [`abandon_move`].
pub(crate) fn begin_move(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (from, to, bytes) = {
        let st = w.dataplane.as_ref().expect("data plane active");
        let m = &st.moves[idx].mv;
        (m.from, m.to, m.bytes)
    };
    let t = w.fabric.transfer(from, to, bytes, now);
    w.wan_transfers += 1;
    if t.dropped {
        let attempts = {
            let st = w.dataplane.as_mut().expect("data plane active");
            let m = &mut st.moves[idx];
            m.attempts += 1;
            m.attempts
        };
        if attempts >= MAX_MOVE_ATTEMPTS {
            abandon_move(sim, w, idx);
        } else {
            sim.schedule(f64::from(1u32 << attempts), move |sim, w: &mut World| {
                begin_move(sim, w, idx);
            });
        }
        return;
    }
    w.wan_bytes += bytes;
    {
        let st = w.dataplane.as_mut().expect("data plane active");
        st.sent_bytes += bytes;
        let egress = st.cost.egress_cost(from, bytes);
        st.egress_cost += egress;
    }
    sim.schedule_at(t.arrival, move |sim, w: &mut World| {
        deliver_shard(sim, w, idx);
    });
}

/// Give up on move `idx` (its link dropped every attempt): the shard's
/// remaining work is shed honestly instead of retrying forever. For a
/// *staged* move the destination's step budget pre-counted the samples,
/// so it is retimed down to what is available now **plus** any sibling
/// staged shards still inbound (those stay pre-counted — shrinking past
/// them would let the destination finish before they land and drop
/// their work on delivery). A rebalance move's samples were already
/// shed at the source; they are simply lost (reported via
/// `failed_shards`), mirroring the delivered-after-finish case.
fn abandon_move(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (dest, was_staged) = {
        let st = w.dataplane.as_mut().expect("data plane active");
        let m = &mut st.moves[idx];
        m.delivered = true; // terminal: no further retries
        st.pending = st.pending.saturating_sub(1);
        st.failed_moves += 1;
        (m.mv.to, !m.grow_dest)
    };
    if was_staged {
        let inbound: usize = {
            let st = w.dataplane.as_ref().expect("data plane active");
            st.moves
                .iter()
                .filter(|m| !m.delivered && m.mv.to == dest && !m.grow_dest)
                .map(|m| m.mv.samples)
                .sum()
        };
        let finish_now = {
            let part = &mut w.parts[dest];
            if part.gate == Gate::Finished {
                false
            } else {
                part.retime_step_budget(w.model.meta.batch_size, w.cfg.epochs, inbound);
                if part.gate == Gate::DataBlocked && part.local_done() {
                    // Its only awaited data is never coming.
                    part.data_stall += now - part.data_blocked_since;
                    part.gate = Gate::Running;
                }
                part.gate == Gate::Running && part.local_done() && part.in_flight == 0
            }
        };
        if finish_now {
            driver::finish_partition(sim, w, dest);
        }
    }
}

/// Move `idx` landed: the destination may now train on its samples.
pub(crate) fn deliver_shard(sim: &mut Sim<World>, w: &mut World, idx: usize) {
    let now = sim.now();
    let (dest, indices, grow) = {
        let st = w.dataplane.as_mut().expect("data plane active");
        let m = &mut st.moves[idx];
        debug_assert!(!m.delivered, "double delivery of move {idx}");
        m.delivered = true;
        st.pending = st.pending.saturating_sub(1);
        st.moved_bytes += m.mv.bytes;
        st.moved_shards += 1;
        st.staging_done = st.staging_done.max(now);
        st.catalog.apply_move(m.mv.shard, m.mv.to);
        (m.mv.to, std::mem::take(&mut m.indices), m.grow_dest)
    };
    {
        let part = &mut w.parts[dest];
        if part.gate == Gate::Finished {
            return; // landed after local completion: bytes moved, work done
        }
        part.shard.extend(indices);
        if grow {
            part.retime_step_budget(w.model.meta.batch_size, w.cfg.epochs, 0);
        }
        if part.gate == Gate::DataBlocked {
            part.data_stall += now - part.data_blocked_since;
            part.gate = Gate::Running;
        }
    }
    driver::kick_idle_workers(sim, w, dest);
}

//! The joint data/compute placement planner.
//!
//! Extends Algorithm-1 matching into a *joint* plan over the catalog: for
//! a candidate shard layout the planner re-runs the matching on the
//! implied per-region sample counts, estimates the run (compute time vs
//! inbound staging time per region, prefetch overlapped) and its cost
//! (compute billed to the estimated end + per-region object-store egress
//! for every shard that moves), and searches layouts:
//!
//! - **compute-follows-data** — keep the catalog layout, train where the
//!   shards already sit (zero migration; stragglers where the data is);
//! - **data-follows-compute** — migrate toward the power-proportional
//!   layout (fast compute; pays transfer time + egress);
//! - **joint** — start from the cheaper of the two and hill-climb over
//!   single-shard relocations, keeping only moves whose payoff beats
//!   their cost. By construction the joint plan's estimated objective is
//!   never worse than either pure mode's.
//!
//! The objective is `$cost + time_value · est_run`: pure dollar cost
//! would never move a byte (Algorithm-1 matching already makes compute
//! spend nearly layout-independent), and pure makespan would always
//! fully balance regardless of egress — the explicit time value (default
//! 2× the full inventory's hourly rate: halving the run is worth renting
//! the fleet twice over) is what makes the trade-off real.
//!
//! Like `sched::elastic`, this module is pure planning — no simulator,
//! no FaaS. The driver executes the returned moves through
//! [`super::migration`]; determinism follows from determinism of the
//! inputs.

use crate::cloud::cost::{BilledAllocation, CostModel};
use crate::cloud::{Allocation, CloudEnv};
use crate::net::{Fabric, LinkSpec, RegionId};
use crate::sched::optimal_matching_observed;

use super::catalog::{sample_bytes, DatasetCatalog};

/// Which placement strategy [`plan`] runs (config `"dataplane"` `"mode"`
/// key / `--placement-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    ComputeFollowsData,
    DataFollowsCompute,
    Joint,
}

impl PlacementMode {
    /// Parse a mode name; the error lists every valid name.
    pub fn from_name(s: &str) -> Result<PlacementMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "compute-follows-data" | "cfd" => Ok(PlacementMode::ComputeFollowsData),
            "data-follows-compute" | "dfc" => Ok(PlacementMode::DataFollowsCompute),
            "joint" => Ok(PlacementMode::Joint),
            other => Err(format!(
                "unknown placement mode {other:?} (valid: compute-follows-data, \
                 data-follows-compute, joint)"
            )),
        }
    }

    /// Stable name (inverse of [`PlacementMode::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            PlacementMode::ComputeFollowsData => "compute-follows-data",
            PlacementMode::DataFollowsCompute => "data-follows-compute",
            PlacementMode::Joint => "joint",
        }
    }

    pub const ALL: [PlacementMode; 3] = [
        PlacementMode::ComputeFollowsData,
        PlacementMode::DataFollowsCompute,
        PlacementMode::Joint,
    ];
}

/// One planned shard migration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMove {
    pub shard: usize,
    pub from: RegionId,
    pub to: RegionId,
    pub bytes: u64,
    pub samples: usize,
}

/// The planner's output: a compute plan plus the shard moves that
/// produce the layout it was planned against.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub mode: PlacementMode,
    /// Per-region compute allocations (Algorithm 1 on the final layout;
    /// regions with no resident data after the moves get none).
    pub allocations: Vec<Allocation>,
    /// Shard migrations, origin → final home, shard-id order.
    pub moves: Vec<ShardMove>,
    /// Final resident samples per region (post-migration).
    pub resident: Vec<usize>,
    pub straggler: usize,
    /// Estimated run seconds (straggler compute vs inbound staging).
    pub est_run_s: f64,
    /// Estimated dollar cost: compute billed to `est_run_s` + egress.
    pub est_cost: f64,
    /// The scalar the planner minimized:
    /// `est_cost + time_value · est_run_s`. The joint mode's value is
    /// never worse than either pure mode's.
    pub est_objective: f64,
}

impl PlacementPlan {
    pub fn moved_bytes(&self) -> u64 {
        self.moves.iter().map(|m| m.bytes).sum()
    }
}

/// Everything the planner needs to know, gathered once per plan call.
pub struct PlanInputs<'a> {
    pub env: &'a CloudEnv,
    pub catalog: &'a DatasetCatalog,
    /// Local epochs each region trains (remaining epochs for re-plans).
    pub epochs: usize,
    pub base_step_s: f64,
    pub batch_size: usize,
    /// Directed link specs `links[from][to]` (None on the diagonal).
    pub links: Vec<Vec<Option<LinkSpec>>>,
    pub cost: CostModel,
    /// Observed per-cloud power scales (all ones at launch planning).
    pub scale: Vec<f64>,
    /// Dollars an hour of job makespan is worth (deadline pressure).
    /// [`default_time_value_per_hour`] derives the default from the
    /// inventory's rental rate.
    pub time_value_per_hour: f64,
}

/// The default makespan valuation: twice the full inventory's hourly
/// rental rate — if renting a second fleet could halve the run, the
/// job would pay for it.
pub fn default_time_value_per_hour(env: &CloudEnv, cost: &CostModel) -> f64 {
    let rate: f64 = env
        .greedy_plan()
        .iter()
        .flat_map(|a| a.units.iter())
        .map(|&(dev, units)| {
            cost.compute_cost(&BilledAllocation { device: dev, units, held_s: 3600.0 })
        })
        .sum();
    2.0 * rate
}

impl<'a> PlanInputs<'a> {
    /// Gather the link view from a fabric (planning reads only).
    pub fn link_view(fabric: &Fabric, n: usize) -> Vec<Vec<Option<LinkSpec>>> {
        (0..n)
            .map(|a| (0..n).map(|b| fabric.link_spec(a, b)).collect())
            .collect()
    }

    fn transfer_s(&self, from: RegionId, to: RegionId, bytes: u64) -> f64 {
        let spec = self.links[from][to].clone().unwrap_or_else(LinkSpec::lan);
        spec.setup_s + bytes as f64 * 8.0 / spec.bandwidth_bps.max(1.0) + spec.latency_s
    }
}

/// One evaluated candidate layout.
struct Eval {
    allocations: Vec<Allocation>,
    resident: Vec<usize>,
    straggler: usize,
    run_s: f64,
    cost: f64,
    objective: f64,
}

fn steps_for(samples: usize, batch: usize, epochs: usize) -> f64 {
    if samples == 0 {
        0.0
    } else {
        (samples as f64 / batch.max(1) as f64).ceil() * epochs as f64
    }
}

/// Estimate a candidate layout: matching on the implied sample counts,
/// run = max per region of (compute, inbound staging) — prefetch overlaps
/// the first epochs, so a region stalls only if its inbound bytes take
/// longer than its resident work — cost = compute billed to the run end
/// plus per-source egress on every moved byte.
fn evaluate(inputs: &PlanInputs, homes: &[RegionId]) -> Eval {
    let n = inputs.env.regions.len();
    let mut resident = vec![0usize; n];
    for (s, &h) in inputs.catalog.shards.iter().zip(homes) {
        resident[h] += s.samples();
    }
    let mut env2 = inputs.env.clone();
    for (r, region) in env2.regions.iter_mut().enumerate() {
        region.data_samples = resident[r];
    }
    let plan = optimal_matching_observed(&env2, &inputs.scale);

    // Inbound staging per region: moves on one directed link serialize
    // FIFO; different source links stream in parallel.
    let mut inbound = vec![vec![0.0f64; n]; n]; // [from][to] seconds
    let mut egress = 0.0f64;
    for (s, &h) in inputs.catalog.shards.iter().zip(homes) {
        if h != s.home {
            inbound[s.home][h] += inputs.transfer_s(s.home, h, s.bytes);
            egress += inputs.cost.egress_cost(s.home, s.bytes);
        }
    }
    let mut run = 0.0f64;
    for r in 0..n {
        let power = plan.allocations[r].power() * inputs.scale[r];
        let steps = steps_for(resident[r], inputs.batch_size, inputs.epochs);
        let compute = if steps == 0.0 {
            0.0
        } else if power <= 0.0 {
            f64::INFINITY
        } else {
            steps * inputs.base_step_s / power
        };
        let staging = (0..n).map(|from| inbound[from][r]).fold(0.0f64, f64::max);
        run = run.max(compute.max(staging));
    }
    let mut cost = egress;
    for alloc in &plan.allocations {
        for &(dev, units) in &alloc.units {
            cost += inputs
                .cost
                .compute_cost(&BilledAllocation { device: dev, units, held_s: run });
        }
    }
    let objective = cost + inputs.time_value_per_hour * run / 3600.0;
    Eval {
        allocations: plan.allocations,
        resident,
        straggler: plan.straggler,
        run_s: run,
        cost,
        objective,
    }
}

/// The power-proportional layout: shard homes greedily reassigned toward
/// per-region sample targets proportional to full-inventory (observed)
/// power. Each shard moves at most once; a move is taken only when it
/// strictly reduces the L1 distance to the target.
fn data_follows_compute_homes(inputs: &PlanInputs) -> Vec<RegionId> {
    let n = inputs.env.regions.len();
    let powers: Vec<f64> = inputs
        .env
        .greedy_plan()
        .iter()
        .zip(&inputs.scale)
        .map(|(a, s)| a.power() * s)
        .collect();
    let total_power: f64 = powers.iter().sum();
    let total_samples = inputs.catalog.total_samples() as f64;
    let target: Vec<f64> =
        powers.iter().map(|p| total_samples * p / total_power.max(1e-12)).collect();

    let mut homes: Vec<RegionId> = inputs.catalog.shards.iter().map(|s| s.home).collect();
    let mut resident: Vec<f64> = vec![0.0; n];
    for (s, &h) in inputs.catalog.shards.iter().zip(&homes) {
        resident[h] += s.samples() as f64;
    }
    // Largest shards first (tie: id) so the coarse mass settles before
    // the fine-grained corrections.
    let mut order: Vec<usize> = (0..inputs.catalog.shards.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(inputs.catalog.shards[i].samples()), i));
    for i in order {
        let k = inputs.catalog.shards[i].samples() as f64;
        let src = homes[i];
        let before = (resident[src] - target[src]).abs();
        let mut best: Option<(f64, usize)> = None;
        for dst in 0..n {
            if dst == src {
                continue;
            }
            let after = (resident[src] - k - target[src]).abs()
                + (resident[dst] + k - target[dst]).abs()
                - (resident[dst] - target[dst]).abs();
            let gain = before - after;
            if gain > 1e-9 && best.map_or(true, |(g, _)| gain > g) {
                best = Some((gain, dst));
            }
        }
        if let Some((_, dst)) = best {
            resident[src] -= k;
            resident[dst] += k;
            homes[i] = dst;
        }
    }
    homes
}

/// Greedy hill-climb over single-shard relocations; commits a move only
/// when it improves the objective by more than `margin` (relative).
/// `movable` restricts which regions may participate (None = all):
/// mid-run rebalancing must not strand samples on — or steal them from —
/// partitions that already finished.
fn improve(
    inputs: &PlanInputs,
    homes: &mut Vec<RegionId>,
    margin: f64,
    movable: Option<&[bool]>,
) -> Eval {
    let n = inputs.env.regions.len();
    let shards = inputs.catalog.shards.len();
    let allowed = |r: RegionId| movable.map_or(true, |m| m[r]);
    let mut best = evaluate(inputs, homes);
    for _round in 0..(2 * shards + 4) {
        let mut winner: Option<(f64, usize, RegionId)> = None;
        for i in 0..shards {
            let cur = homes[i];
            if !allowed(cur) {
                continue; // its samples are already trained (or training)
            }
            for dst in 0..n {
                if dst == cur || !allowed(dst) {
                    continue;
                }
                homes[i] = dst;
                let cand = evaluate(inputs, homes);
                if cand.objective < best.objective * (1.0 - margin) - 1e-12
                    && winner.map_or(true, |(c, _, _)| cand.objective < c)
                {
                    winner = Some((cand.objective, i, dst));
                }
            }
            homes[i] = cur;
        }
        match winner {
            Some((_, i, dst)) => {
                homes[i] = dst;
                best = evaluate(inputs, homes);
            }
            None => break,
        }
    }
    best
}

fn moves_from(catalog: &DatasetCatalog, homes: &[RegionId]) -> Vec<ShardMove> {
    catalog
        .shards
        .iter()
        .zip(homes)
        .filter(|(s, &h)| h != s.home)
        .map(|(s, &h)| ShardMove {
            shard: s.id,
            from: s.home,
            to: h,
            bytes: s.bytes,
            samples: s.samples(),
        })
        .collect()
}

/// Run the placement planner in `mode` over the catalog.
pub fn plan(inputs: &PlanInputs, mode: PlacementMode) -> PlacementPlan {
    let initial: Vec<RegionId> = inputs.catalog.shards.iter().map(|s| s.home).collect();
    let homes = match mode {
        PlacementMode::ComputeFollowsData => initial,
        PlacementMode::DataFollowsCompute => data_follows_compute_homes(inputs),
        PlacementMode::Joint => {
            // Start from the better pure layout, then climb: the joint
            // objective can never be worse than either pure mode's.
            let dfc = data_follows_compute_homes(inputs);
            let mut homes =
                if evaluate(inputs, &dfc).objective < evaluate(inputs, &initial).objective {
                    dfc
                } else {
                    initial
                };
            improve(inputs, &mut homes, 0.0, None);
            homes
        }
    };
    let eval = evaluate(inputs, &homes);
    PlacementPlan {
        mode,
        allocations: eval.allocations,
        moves: moves_from(inputs.catalog, &homes),
        resident: eval.resident,
        straggler: eval.straggler,
        est_run_s: eval.run_s,
        est_cost: eval.cost,
        est_objective: eval.objective,
    }
}

/// Mid-run rebalancing: starting from the *current* catalog layout,
/// return the shard moves a joint climb over the remaining work commits.
/// `margin` gates churn the same way re-plan hysteresis does — a move
/// must beat the stay-put objective by that relative margin. Inputs
/// carry observed power scales and remaining epochs; `movable[r]` marks
/// regions still training — finished partitions neither receive shards
/// (the samples would be silently dropped) nor give theirs up (already
/// trained).
pub fn rebalance(inputs: &PlanInputs, margin: f64, movable: &[bool]) -> Vec<ShardMove> {
    let mut homes: Vec<RegionId> = inputs.catalog.shards.iter().map(|s| s.home).collect();
    improve(inputs, &mut homes, margin.max(0.0), Some(movable));
    moves_from(inputs.catalog, &homes)
}

/// Build the catalog and run the configured placement planner for one
/// job — the deterministic entry point shared by the coordinator (which
/// needs `plan.allocations`) and the training driver (which additionally
/// stages `plan.moves`); both must see the identical plan.
pub fn plan_for(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
) -> anyhow::Result<PlannedDataPlane> {
    let spec = cfg
        .dataplane
        .placement
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("dataplane not configured (no placement spec)"))?;
    let per_sample = if cfg.dataplane.sample_bytes > 0 {
        cfg.dataplane.sample_bytes
    } else {
        sample_bytes(meta)
    };
    let region_samples: Vec<usize> = env.regions.iter().map(|r| r.data_samples).collect();
    let catalog = DatasetCatalog::from_spec(
        spec,
        cfg.n_train,
        env.regions.len(),
        per_sample,
        &region_samples,
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    let fabric =
        Fabric::full_mesh(cfg.seed, env.regions.len(), &cfg.link, &cfg.link_overrides);
    let base_step = if cfg.base_step_s > 0.0 {
        cfg.base_step_s
    } else {
        crate::train::calib::default_base_step_s(&cfg.model)
    };
    let cost = CostModel::default();
    let time_value = if cfg.dataplane.time_value_per_hour > 0.0 {
        cfg.dataplane.time_value_per_hour
    } else {
        default_time_value_per_hour(env, &cost)
    };
    let inputs = PlanInputs {
        env,
        catalog: &catalog,
        epochs: cfg.epochs,
        base_step_s: base_step,
        batch_size: meta.batch_size,
        links: PlanInputs::link_view(&fabric, env.regions.len()),
        cost,
        scale: vec![1.0; env.regions.len()],
        time_value_per_hour: time_value,
    };
    let plan = self::plan(&inputs, cfg.dataplane.mode);
    Ok(PlannedDataPlane { catalog, plan })
}

/// A planned data plane: the catalog (initial homes) plus the placement
/// plan derived from it.
#[derive(Debug, Clone)]
pub struct PlannedDataPlane {
    pub catalog: DatasetCatalog,
    pub plan: PlacementPlan,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::devices::Device;
    use crate::dataplane::catalog::PlacementSpec;

    fn four_cloud_env() -> CloudEnv {
        CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 128),
            ("CQ", Device::Skylake, 12, 128),
            ("BJ", Device::Skylake, 12, 128),
            ("GZ", Device::IceLake, 12, 128),
        ])
    }

    fn skewed_catalog() -> DatasetCatalog {
        DatasetCatalog::from_spec(
            &PlacementSpec::Skewed { shards: 8, frac: 0.7 },
            512,
            4,
            256 * 1024,
            &[1; 4],
        )
        .unwrap()
    }

    fn inputs<'a>(env: &'a CloudEnv, catalog: &'a DatasetCatalog) -> PlanInputs<'a> {
        let fabric = Fabric::full_mesh(1, 4, &LinkSpec::wan_100mbps(), &[]);
        let cost = CostModel::default();
        let tv = default_time_value_per_hour(env, &cost);
        PlanInputs {
            env,
            catalog,
            epochs: 6,
            base_step_s: 0.25,
            batch_size: 16,
            links: PlanInputs::link_view(&fabric, 4),
            cost,
            scale: vec![1.0; 4],
            time_value_per_hour: tv,
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for m in PlacementMode::ALL {
            assert_eq!(PlacementMode::from_name(m.name()), Ok(m));
        }
        assert_eq!(PlacementMode::from_name("CFD"), Ok(PlacementMode::ComputeFollowsData));
        let err = PlacementMode::from_name("teleport").unwrap_err();
        assert!(err.contains("joint") && err.contains("teleport"));
    }

    #[test]
    fn compute_follows_data_never_moves() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let p = plan(&inputs(&env, &cat), PlacementMode::ComputeFollowsData);
        assert!(p.moves.is_empty());
        assert_eq!(p.resident, cat.resident_samples());
        assert_eq!(p.straggler, 0, "the hot region is the straggler");
        // The data-less region gets no compute.
        let res = cat.resident_samples();
        for (r, &samples) in res.iter().enumerate() {
            if samples == 0 {
                assert_eq!(p.allocations[r].total_units(), 0, "region {r} idle");
            }
        }
    }

    #[test]
    fn data_follows_compute_balances_toward_power() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let p = plan(&inputs(&env, &cat), PlacementMode::DataFollowsCompute);
        assert!(!p.moves.is_empty(), "a 70% skew must shed load");
        // Final layout tracks power shares (4:6:6:6 of 22) within a shard.
        let total: usize = p.resident.iter().sum();
        assert_eq!(total, 512, "moves conserve samples");
        let hot_share = p.resident[0] as f64 / total as f64;
        assert!(hot_share < 0.45, "hot region sheds toward 4/22: {:?}", p.resident);
        // Every move originates at the shard's catalog home.
        for m in &p.moves {
            assert_eq!(cat.shards[m.shard].home, m.from);
            assert_ne!(m.from, m.to);
        }
    }

    #[test]
    fn joint_estimate_never_worse_than_either_pure_mode() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let inp = inputs(&env, &cat);
        let cfd = plan(&inp, PlacementMode::ComputeFollowsData);
        let dfc = plan(&inp, PlacementMode::DataFollowsCompute);
        let joint = plan(&inp, PlacementMode::Joint);
        assert!(
            joint.est_objective <= cfd.est_objective + 1e-9,
            "{} vs cfd {}",
            joint.est_objective,
            cfd.est_objective
        );
        assert!(
            joint.est_objective <= dfc.est_objective + 1e-9,
            "{} vs dfc {}",
            joint.est_objective,
            dfc.est_objective
        );
        assert!(joint.est_run_s < cfd.est_run_s, "joint must relieve the data straggler");
        assert!(!joint.moves.is_empty(), "a 70% skew is worth moving for");
    }

    #[test]
    fn moves_never_exceed_catalog_bytes_and_plans_are_deterministic() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let inp = inputs(&env, &cat);
        for mode in PlacementMode::ALL {
            let a = plan(&inp, mode);
            let b = plan(&inp, mode);
            assert!(a.moved_bytes() <= cat.total_bytes(), "{mode:?} moved too much");
            assert_eq!(a.moves, b.moves, "{mode:?} must be deterministic");
            assert_eq!(a.resident, b.resident);
            let mut seen = std::collections::BTreeSet::new();
            for m in &a.moves {
                assert!(seen.insert(m.shard), "{mode:?} moves shard {} twice", m.shard);
            }
            let total: usize = a.resident.iter().sum();
            assert_eq!(total, cat.total_samples());
        }
    }

    #[test]
    fn rebalance_is_idempotent_at_the_joint_optimum() {
        let env = four_cloud_env();
        // Apply the joint plan's moves, then ask again: a local optimum
        // must not churn (the hysteresis analogue of replan idempotence).
        let cat = {
            let mut c = skewed_catalog();
            let p = plan(&inputs(&env, &c), PlacementMode::Joint);
            for m in &p.moves {
                c.apply_move(m.shard, m.to);
            }
            c
        };
        let inp = inputs(&env, &cat);
        assert_eq!(
            rebalance(&inp, 0.02, &[true; 4]),
            Vec::new(),
            "settled layout must not churn"
        );
    }

    #[test]
    fn rebalance_never_touches_finished_regions() {
        // Region 1 finished its shard: a slowed region 0 may shed load,
        // but no move may target region 1 (its partition would drop the
        // samples) or take region 1's shards (already trained).
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let mut inp = inputs(&env, &cat);
        inp.scale = vec![0.3, 1.0, 1.0, 1.0]; // hot region slowed hard
        let movable = [true, false, true, true];
        let moves = rebalance(&inp, 0.0, &movable);
        assert!(!moves.is_empty(), "a 70% slowdown on the hot region must move shards");
        for m in &moves {
            assert_ne!(m.to, 1, "moved into a finished region: {m:?}");
            assert_ne!(m.from, 1, "stole a finished region's shard: {m:?}");
        }
    }

    #[test]
    fn zero_data_region_is_planned_not_panicked() {
        // The planner legitimately produces regions with no data; the
        // matching must hand them an empty allocation, not assert.
        let env = four_cloud_env();
        let cat = DatasetCatalog::from_spec(
            &PlacementSpec::Single { region: 0 },
            256,
            4,
            1024,
            &[1; 4],
        )
        .unwrap();
        let p = plan(&inputs(&env, &cat), PlacementMode::ComputeFollowsData);
        assert_eq!(p.resident, vec![256, 0, 0, 0]);
        for alloc in &p.allocations[1..] {
            assert_eq!(alloc.total_units(), 0);
        }
        assert!(p.est_run_s.is_finite());
    }
}

//! The joint data/compute placement planner, replica-aware.
//!
//! Extends Algorithm-1 matching into a *joint* plan over the catalog:
//! every shard physically resides in a **replica set** of one or more
//! regions, and the planner chooses which region *trains* each shard
//! (its assignment) plus, for every shard assigned outside its replica
//! set, **which replica the consumer reads from** — the source whose
//! egress + time-valued transfer seconds is cheapest (nearest by
//! delivered bandwidth; ties break to the cheaper egress region, then
//! the lowest id). Reading from a co-located replica is free; creating
//! a new replica pays egress **once per copy**, never per reader. For a
//! candidate assignment the planner re-runs the matching on the implied
//! per-region sample counts, estimates the run (compute time vs inbound
//! staging time per region, prefetch overlapped) and its cost (compute
//! billed to the estimated end + per-source egress for every replica
//! copy created), and searches assignments:
//!
//! - **compute-follows-data** — train strictly inside each shard's
//!   replica set (zero migration; with `r1` this is "train where the
//!   single copy sits", with `rK` the copies themselves balance load);
//! - **data-follows-compute** — migrate toward the power-proportional
//!   layout (fast compute; pays transfer time + egress for whatever the
//!   replica sets do not already cover);
//! - **joint** — start from the cheaper of the two and hill-climb over
//!   single-shard reassignments, *creating* a replica whenever the
//!   time-valued makespan saving beats the copy cost. By construction
//!   the joint plan's estimated objective is never worse than either
//!   pure mode's.
//!
//! The objective is `$cost + time_value · est_run`: pure dollar cost
//! would never move a byte (Algorithm-1 matching already makes compute
//! spend nearly layout-independent), and pure makespan would always
//! fully balance regardless of egress — the explicit time value (default
//! 2× the full inventory's hourly rate: halving the run is worth renting
//! the fleet twice over) is what makes the trade-off real.
//!
//! Like `sched::elastic`, this module is pure planning — no simulator,
//! no FaaS. The driver executes the returned moves through
//! [`super::migration`]; determinism follows from determinism of the
//! inputs.

use crate::cloud::cost::{BilledAllocation, CostModel};
use crate::cloud::{Allocation, CloudEnv};
use crate::net::{Fabric, LinkSpec, RegionId};
use crate::sched::optimal_matching_observed;

use super::catalog::{sample_bytes, DatasetCatalog, ShardInfo};

/// Which placement strategy [`plan`] runs (config `"dataplane"` `"mode"`
/// key / `--placement-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    ComputeFollowsData,
    DataFollowsCompute,
    Joint,
}

impl PlacementMode {
    /// Parse a mode name; the error lists every valid name.
    pub fn from_name(s: &str) -> Result<PlacementMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "compute-follows-data" | "cfd" => Ok(PlacementMode::ComputeFollowsData),
            "data-follows-compute" | "dfc" => Ok(PlacementMode::DataFollowsCompute),
            "joint" => Ok(PlacementMode::Joint),
            other => Err(format!(
                "unknown placement mode {other:?} (valid: compute-follows-data, \
                 data-follows-compute, joint)"
            )),
        }
    }

    /// Stable name (inverse of [`PlacementMode::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            PlacementMode::ComputeFollowsData => "compute-follows-data",
            PlacementMode::DataFollowsCompute => "data-follows-compute",
            PlacementMode::Joint => "joint",
        }
    }

    pub const ALL: [PlacementMode; 3] = [
        PlacementMode::ComputeFollowsData,
        PlacementMode::DataFollowsCompute,
        PlacementMode::Joint,
    ];
}

/// One planned shard migration: a replica copy read from `from` (the
/// chosen source replica) materializing at `to`. `bytes == 0` marks a
/// pure training-right handoff onto a region that *already* holds a
/// replica (mid-run rebalancing only) — no WAN traffic, no egress.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMove {
    pub shard: usize,
    /// Source replica the copy streams from (`== to` for a zero-byte
    /// handoff onto an existing replica).
    pub from: RegionId,
    pub to: RegionId,
    /// Bytes on the WAN: the shard's size, or 0 for a local handoff.
    pub bytes: u64,
    pub samples: usize,
}

/// The planner's output: a compute plan plus the shard assignment it was
/// planned against and the replica copies that make it physical.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub mode: PlacementMode,
    /// Per-region compute allocations (Algorithm 1 on the final
    /// assignment; regions training no samples get none).
    pub allocations: Vec<Allocation>,
    /// Replica copies to create, shard-id order (shards whose assigned
    /// trainer already holds a replica need none).
    pub moves: Vec<ShardMove>,
    /// Which region trains each shard (index = shard id).
    pub assign: Vec<RegionId>,
    /// Samples trained per region under `assign` (post-migration).
    pub resident: Vec<usize>,
    pub straggler: usize,
    /// Estimated run seconds (straggler compute vs inbound staging).
    pub est_run_s: f64,
    /// Estimated dollar cost: compute billed to `est_run_s` + egress.
    pub est_cost: f64,
    /// The scalar the planner minimized:
    /// `est_cost + time_value · est_run_s`. The joint mode's value is
    /// never worse than either pure mode's.
    pub est_objective: f64,
}

impl PlacementPlan {
    pub fn moved_bytes(&self) -> u64 {
        self.moves.iter().map(|m| m.bytes).sum()
    }
}

/// Everything the planner needs to know, gathered once per plan call.
pub struct PlanInputs<'a> {
    pub env: &'a CloudEnv,
    pub catalog: &'a DatasetCatalog,
    /// Local epochs each region trains (remaining epochs for re-plans).
    pub epochs: usize,
    pub base_step_s: f64,
    pub batch_size: usize,
    /// Directed link specs `links[from][to]` (None on the diagonal).
    pub links: Vec<Vec<Option<LinkSpec>>>,
    pub cost: CostModel,
    /// Observed per-cloud power scales (all ones at launch planning).
    pub scale: Vec<f64>,
    /// Dollars an hour of job makespan is worth (deadline pressure).
    /// [`default_time_value_per_hour`] derives the default from the
    /// inventory's rental rate.
    pub time_value_per_hour: f64,
    /// Per-region compute price multipliers on the on-demand rate
    /// (all ones without a spot market): the market layer's
    /// [`cloud::spot::rate_scale`](crate::cloud::spot::rate_scale)
    /// folds each region's expected spot price *and* its expected
    /// preemption/restore overhead into this one scalar, so the joint
    /// climb weighs cheap-but-revocable capacity honestly.
    pub rate_scale: Vec<f64>,
}

/// The default makespan valuation: twice the full inventory's hourly
/// rental rate — if renting a second fleet could halve the run, the
/// job would pay for it.
pub fn default_time_value_per_hour(env: &CloudEnv, cost: &CostModel) -> f64 {
    let rate: f64 = env
        .greedy_plan()
        .iter()
        .flat_map(|a| a.units.iter())
        .map(|&(dev, units)| {
            cost.compute_cost(&BilledAllocation::on_demand(dev, units, 3600.0))
        })
        .sum();
    2.0 * rate
}

impl<'a> PlanInputs<'a> {
    /// Gather the link view from a fabric (planning reads only). Fleet
    /// admission calls this on the **live** shared fabric, so plans see
    /// churn-mutated bandwidths instead of the config template.
    pub fn link_view(fabric: &Fabric, n: usize) -> Vec<Vec<Option<LinkSpec>>> {
        (0..n)
            .map(|a| (0..n).map(|b| fabric.link_spec(a, b)).collect())
            .collect()
    }

    fn transfer_s(&self, from: RegionId, to: RegionId, bytes: u64) -> f64 {
        if from == to {
            return 0.0;
        }
        let spec = self.links[from][to].clone().unwrap_or_else(LinkSpec::lan);
        spec.setup_s + bytes as f64 * 8.0 / spec.bandwidth_bps.max(1.0) + spec.latency_s
    }

    /// Full-inventory observed powers per region.
    fn powers(&self) -> Vec<f64> {
        self.env
            .greedy_plan()
            .iter()
            .zip(&self.scale)
            .map(|(a, s)| a.power() * s)
            .collect()
    }
}

/// The replica a consumer in `to` reads shard `s` from: `to` itself when
/// co-located, else the replica minimizing egress + time-valued transfer
/// ([`CostModel::copy_objective`]); ties break to the lowest region id.
fn best_source(inputs: &PlanInputs, s: &ShardInfo, to: RegionId) -> RegionId {
    if s.has_replica(to) {
        return to;
    }
    let mut reps = s.replicas.clone();
    reps.sort_unstable();
    let mut best_r = reps[0];
    let mut best_obj = f64::INFINITY;
    for &r in &reps {
        let t = inputs.transfer_s(r, to, s.bytes);
        let obj = inputs.cost.copy_objective(r, s.bytes, t, inputs.time_value_per_hour);
        if obj < best_obj - 1e-12 {
            best_obj = obj;
            best_r = r;
        }
    }
    best_r
}

/// One evaluated candidate assignment.
struct Eval {
    allocations: Vec<Allocation>,
    resident: Vec<usize>,
    straggler: usize,
    run_s: f64,
    cost: f64,
    objective: f64,
}

fn steps_for(samples: usize, batch: usize, epochs: usize) -> f64 {
    if samples == 0 {
        0.0
    } else {
        (samples as f64 / batch.max(1) as f64).ceil() * epochs as f64
    }
}

/// Estimate a candidate assignment: matching on the implied sample
/// counts, run = max per region of (compute, inbound staging) — prefetch
/// overlaps the first epochs, so a region stalls only if its inbound
/// bytes take longer than its resident work — cost = compute billed to
/// the run end plus per-source egress on every replica copy created
/// (shards trained inside their replica set stage nothing).
fn evaluate(inputs: &PlanInputs, assign: &[RegionId]) -> Eval {
    let n = inputs.env.regions.len();
    let mut resident = vec![0usize; n];
    for (s, &a) in inputs.catalog.shards.iter().zip(assign) {
        resident[a] += s.samples();
    }
    let mut env2 = inputs.env.clone();
    for (r, region) in env2.regions.iter_mut().enumerate() {
        region.data_samples = resident[r];
    }
    let plan = optimal_matching_observed(&env2, &inputs.scale);

    // Inbound staging per region: copies on one directed link serialize
    // FIFO; different source links stream in parallel. Each created
    // replica pays its source's egress exactly once.
    let mut inbound = vec![vec![0.0f64; n]; n]; // [from][to] seconds
    let mut egress = 0.0f64;
    for (s, &a) in inputs.catalog.shards.iter().zip(assign) {
        if !s.has_replica(a) {
            let src = best_source(inputs, s, a);
            inbound[src][a] += inputs.transfer_s(src, a, s.bytes);
            egress += inputs.cost.egress_cost(src, s.bytes);
        }
    }
    let mut run = 0.0f64;
    for r in 0..n {
        let power = plan.allocations[r].power() * inputs.scale[r];
        let steps = steps_for(resident[r], inputs.batch_size, inputs.epochs);
        let compute = if steps == 0.0 {
            0.0
        } else if power <= 0.0 {
            f64::INFINITY
        } else {
            steps * inputs.base_step_s / power
        };
        let staging = (0..n).map(|from| inbound[from][r]).fold(0.0f64, f64::max);
        run = run.max(compute.max(staging));
    }
    let mut cost = egress;
    for alloc in &plan.allocations {
        let rate = inputs.rate_scale.get(alloc.region).copied().unwrap_or(1.0);
        for &(dev, units) in &alloc.units {
            cost += inputs
                .cost
                .compute_cost(&BilledAllocation { device: dev, units, held_s: run, rate });
        }
    }
    // Storage rent on the copies this assignment *creates*, held for
    // the estimated run. Pre-existing replicas are sunk at planning
    // time — charging them would couple the objective to run length as
    // phantom time pressure — but each marginal copy now carries a
    // GB-hour price, so a rent-heavy cost model makes the climb
    // replica-shy. The executed run bills every held copy for real in
    // the report (see `engine/driver::finalize_report`).
    if run.is_finite() {
        let created_bytes: u64 = inputs
            .catalog
            .shards
            .iter()
            .zip(assign)
            .filter(|(s, &a)| !s.has_replica(a))
            .map(|(s, _)| s.bytes)
            .sum();
        cost += inputs.cost.storage_cost(created_bytes, run);
    }
    let objective = cost + inputs.time_value_per_hour * run / 3600.0;
    Eval {
        allocations: plan.allocations,
        resident,
        straggler: plan.straggler,
        run_s: run,
        cost,
        objective,
    }
}

/// The migration-free baseline: every shard trains inside its replica
/// set, larger shards placed first on the replica whose accumulated
/// load-per-power stays lowest. At `r1` this degenerates to "train where
/// the single copy sits" (the PR-4 compute-follows-data); with real
/// replica sets the copies themselves already balance load.
fn compute_follows_data_assign(inputs: &PlanInputs) -> Vec<RegionId> {
    let powers = inputs.powers();
    let shards = &inputs.catalog.shards;
    let mut assign: Vec<RegionId> = shards.iter().map(|s| s.home()).collect();
    let mut load = vec![0.0f64; inputs.env.regions.len()];
    // Single-replica shards are immovable mass; place it first.
    for s in shards.iter().filter(|s| s.replicas.len() == 1) {
        load[s.home()] += s.samples() as f64;
    }
    let mut order: Vec<usize> =
        (0..shards.len()).filter(|&i| shards[i].replicas.len() > 1).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(shards[i].samples()), i));
    for i in order {
        let s = &shards[i];
        let k = s.samples() as f64;
        let mut reps = s.replicas.clone();
        reps.sort_unstable();
        let mut best = reps[0];
        let mut best_t = f64::INFINITY;
        for &r in &reps {
            let t = if powers[r] > 0.0 { (load[r] + k) / powers[r] } else { f64::INFINITY };
            if t < best_t - 1e-12 {
                best_t = t;
                best = r;
            }
        }
        assign[i] = best;
        load[best] += k;
    }
    assign
}

/// The power-proportional assignment: starting from the migration-free
/// baseline, shards greedily reassigned toward per-region sample targets
/// proportional to full-inventory (observed) power. Each shard moves at
/// most once; a move is taken only when it strictly reduces the L1
/// distance to the target. Blind to link speed and egress — that is the
/// point of the baseline.
fn data_follows_compute_assign(inputs: &PlanInputs) -> Vec<RegionId> {
    let n = inputs.env.regions.len();
    let powers = inputs.powers();
    let total_power: f64 = powers.iter().sum();
    let total_samples = inputs.catalog.total_samples() as f64;
    let target: Vec<f64> =
        powers.iter().map(|p| total_samples * p / total_power.max(1e-12)).collect();

    let mut assign = compute_follows_data_assign(inputs);
    let mut resident: Vec<f64> = vec![0.0; n];
    for (s, &a) in inputs.catalog.shards.iter().zip(&assign) {
        resident[a] += s.samples() as f64;
    }
    // Largest shards first (tie: id) so the coarse mass settles before
    // the fine-grained corrections.
    let mut order: Vec<usize> = (0..inputs.catalog.shards.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(inputs.catalog.shards[i].samples()), i));
    for i in order {
        let k = inputs.catalog.shards[i].samples() as f64;
        let src = assign[i];
        let before = (resident[src] - target[src]).abs();
        let mut best: Option<(f64, usize)> = None;
        for dst in 0..n {
            if dst == src {
                continue;
            }
            let after = (resident[src] - k - target[src]).abs()
                + (resident[dst] + k - target[dst]).abs()
                - (resident[dst] - target[dst]).abs();
            let gain = before - after;
            if gain > 1e-9 && best.map_or(true, |(g, _)| gain > g) {
                best = Some((gain, dst));
            }
        }
        if let Some((_, dst)) = best {
            resident[src] -= k;
            resident[dst] += k;
            assign[i] = dst;
        }
    }
    assign
}

/// Greedy hill-climb over single-shard reassignments; commits a move
/// only when it improves the objective by more than `margin` (relative).
/// Reassigning onto an existing replica is free; anywhere else implies
/// creating a replica, whose copy cost the objective charges. `movable`
/// restricts which regions may participate (None = all): mid-run
/// rebalancing must not strand samples on — or steal them from —
/// partitions that already finished.
fn improve(
    inputs: &PlanInputs,
    assign: &mut Vec<RegionId>,
    margin: f64,
    movable: Option<&[bool]>,
) -> Eval {
    let n = inputs.env.regions.len();
    let shards = inputs.catalog.shards.len();
    let allowed = |r: RegionId| movable.map_or(true, |m| m[r]);
    let mut best = evaluate(inputs, assign);
    for _round in 0..(2 * shards + 4) {
        let mut winner: Option<(f64, usize, RegionId)> = None;
        for i in 0..shards {
            let cur = assign[i];
            if !allowed(cur) {
                continue; // its samples are already trained (or training)
            }
            for dst in 0..n {
                if dst == cur || !allowed(dst) {
                    continue;
                }
                assign[i] = dst;
                let cand = evaluate(inputs, assign);
                if cand.objective < best.objective * (1.0 - margin) - 1e-12
                    && winner.map_or(true, |(c, _, _)| cand.objective < c)
                {
                    winner = Some((cand.objective, i, dst));
                }
            }
            assign[i] = cur;
        }
        match winner {
            Some((_, i, dst)) => {
                assign[i] = dst;
                best = evaluate(inputs, assign);
            }
            None => break,
        }
    }
    best
}

/// The replica copies an assignment requires: one per shard trained
/// outside its replica set, read from its best source.
fn moves_from(inputs: &PlanInputs, assign: &[RegionId]) -> Vec<ShardMove> {
    inputs
        .catalog
        .shards
        .iter()
        .zip(assign)
        .filter(|(s, &a)| !s.has_replica(a))
        .map(|(s, &a)| ShardMove {
            shard: s.id,
            from: best_source(inputs, s, a),
            to: a,
            bytes: s.bytes,
            samples: s.samples(),
        })
        .collect()
}

/// Run the placement planner in `mode` over the catalog.
pub fn plan(inputs: &PlanInputs, mode: PlacementMode) -> PlacementPlan {
    plan_seeded(inputs, mode, None)
}

/// [`plan`] seeded with an *incumbent* assignment — the previous plan
/// over the same shard geometry (fleet admission passes the last
/// admission's joint assignment; only the delta — the new job's lease,
/// churn-mutated links, merged replicas — has changed). The joint climb
/// starts from the best of {incumbent, compute-follows-data,
/// data-follows-compute} and early-outs on the first round that commits
/// no improving move, so a near-converged incumbent costs one scan
/// instead of the from-scratch `2·shards+4` rounds. The hill-climb only
/// ever lowers the objective, so the incremental estimate is never worse
/// than either pure mode — the same invariant the from-scratch joint
/// plan guarantees. An incumbent whose geometry does not match (wrong
/// shard count, out-of-range region) is ignored. Pure modes ignore the
/// seed entirely.
pub fn plan_seeded(
    inputs: &PlanInputs,
    mode: PlacementMode,
    incumbent: Option<&[RegionId]>,
) -> PlacementPlan {
    let n = inputs.env.regions.len();
    let shards = inputs.catalog.shards.len();
    let incumbent = incumbent
        .filter(|a| a.len() == shards && a.iter().all(|&r| r < n));
    let assign = match mode {
        PlacementMode::ComputeFollowsData => compute_follows_data_assign(inputs),
        PlacementMode::DataFollowsCompute => data_follows_compute_assign(inputs),
        PlacementMode::Joint => {
            // Start from the best seed available, then climb: the joint
            // objective can never be worse than either pure mode's (and
            // never worse than the incumbent re-costed on today's state).
            let cfd = compute_follows_data_assign(inputs);
            let dfc = data_follows_compute_assign(inputs);
            let mut assign = if evaluate(inputs, &dfc).objective
                < evaluate(inputs, &cfd).objective
            {
                dfc
            } else {
                cfd
            };
            if let Some(inc) = incumbent {
                if evaluate(inputs, inc).objective < evaluate(inputs, &assign).objective {
                    assign = inc.to_vec();
                }
            }
            improve(inputs, &mut assign, 0.0, None);
            assign
        }
    };
    let eval = evaluate(inputs, &assign);
    PlacementPlan {
        mode,
        allocations: eval.allocations,
        moves: moves_from(inputs, &assign),
        assign,
        resident: eval.resident,
        straggler: eval.straggler,
        est_run_s: eval.run_s,
        est_cost: eval.cost,
        est_objective: eval.objective,
    }
}

/// Mid-run rebalancing: starting from the *current* training assignment,
/// return the shard moves a joint climb over the remaining work commits.
/// `margin` gates churn the same way re-plan hysteresis does — a move
/// must beat the stay-put objective by that relative margin. Inputs
/// carry observed power scales and remaining epochs; `movable[r]` marks
/// regions still training — finished partitions neither receive shards
/// (the samples would be silently dropped) nor give theirs up (already
/// trained). A reassignment onto a region that already holds a replica
/// comes back as a zero-byte handoff (`ShardMove::bytes == 0`).
pub fn rebalance(
    inputs: &PlanInputs,
    margin: f64,
    movable: &[bool],
    current: &[RegionId],
) -> Vec<ShardMove> {
    let mut assign = current.to_vec();
    improve(inputs, &mut assign, margin.max(0.0), Some(movable));
    inputs
        .catalog
        .shards
        .iter()
        .zip(&assign)
        .zip(current)
        .filter(|((_, &a), &cur)| a != cur)
        .map(|((s, &a), _)| {
            if s.has_replica(a) {
                ShardMove { shard: s.id, from: a, to: a, bytes: 0, samples: s.samples() }
            } else {
                ShardMove {
                    shard: s.id,
                    from: best_source(inputs, s, a),
                    to: a,
                    bytes: s.bytes,
                    samples: s.samples(),
                }
            }
        })
        .collect()
}

/// Build the catalog from the config's spec and run the configured
/// placement planner for one job on a *private* link view derived from
/// the job's own `link`/`link_overrides` — the deterministic entry point
/// shared by the coordinator (which needs `plan.allocations`) and the
/// training driver (which additionally stages `plan.moves`); both must
/// see the identical plan. Fleet admission instead goes through
/// [`plan_for_on`] / [`plan_for_catalog`] with the live shared fabric's
/// link view.
pub fn plan_for(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
) -> anyhow::Result<PlannedDataPlane> {
    let fabric =
        Fabric::full_mesh(cfg.seed, env.regions.len(), &cfg.link, &cfg.link_overrides);
    plan_for_on(env, cfg, meta, PlanInputs::link_view(&fabric, env.regions.len()))
}

/// [`plan_for`] with an explicit link view — what fleet admission passes
/// from the **live** shared fabric, so jobs with private `dataplane`
/// configs plan against current link state instead of the config
/// template.
pub fn plan_for_on(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
    links: Vec<Vec<Option<LinkSpec>>>,
) -> anyhow::Result<PlannedDataPlane> {
    plan_for_on_seeded(env, cfg, meta, links, None)
}

/// [`plan_for_on`] seeded with an incumbent assignment (see
/// [`plan_seeded`]); fleet admission passes its cached last joint
/// assignment so back-to-back admissions over stable geometry converge
/// in one climb round instead of re-running the full search.
pub fn plan_for_on_seeded(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
    links: Vec<Vec<Option<LinkSpec>>>,
    incumbent: Option<&[RegionId]>,
) -> anyhow::Result<PlannedDataPlane> {
    let spec = cfg
        .dataplane
        .placement
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("dataplane not configured (no placement spec)"))?;
    let per_sample = if cfg.dataplane.sample_bytes > 0 {
        cfg.dataplane.sample_bytes
    } else {
        sample_bytes(meta)
    };
    let region_samples: Vec<usize> = env.regions.iter().map(|r| r.data_samples).collect();
    let catalog = DatasetCatalog::from_spec(
        spec,
        cfg.n_train,
        env.regions.len(),
        per_sample,
        &region_samples,
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    plan_for_catalog_seeded(env, cfg, meta, catalog, links, incumbent)
}

/// Plan over an *existing* catalog (the fleet's live shared catalog,
/// replica map included) instead of building one from the config's
/// placement spec: later fleet jobs see the copies earlier jobs'
/// migrations already created and plan correspondingly fewer moves.
pub fn plan_for_catalog(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
    catalog: DatasetCatalog,
    links: Vec<Vec<Option<LinkSpec>>>,
) -> anyhow::Result<PlannedDataPlane> {
    plan_for_catalog_seeded(env, cfg, meta, catalog, links, None)
}

/// [`plan_for_catalog`] seeded with an incumbent assignment (see
/// [`plan_seeded`]).
pub fn plan_for_catalog_seeded(
    env: &CloudEnv,
    cfg: &crate::engine::driver::TrainConfig,
    meta: &crate::runtime::ModelMeta,
    catalog: DatasetCatalog,
    links: Vec<Vec<Option<LinkSpec>>>,
    incumbent: Option<&[RegionId]>,
) -> anyhow::Result<PlannedDataPlane> {
    anyhow::ensure!(
        catalog.n_regions == env.regions.len(),
        "catalog spans {} regions, environment has {}",
        catalog.n_regions,
        env.regions.len()
    );
    anyhow::ensure!(
        catalog.total_samples() == cfg.n_train,
        "catalog holds {} samples, job trains {}",
        catalog.total_samples(),
        cfg.n_train
    );
    let base_step = if cfg.base_step_s > 0.0 {
        cfg.base_step_s
    } else {
        crate::train::calib::default_base_step_s(&cfg.model)
    };
    let cost = CostModel::default();
    let time_value = if cfg.dataplane.time_value_per_hour > 0.0 {
        cfg.dataplane.time_value_per_hour
    } else {
        default_time_value_per_hour(env, &cost)
    };
    // Market rates: spot regions plan at their expected effective rate
    // (price trace + expected preemption/restore overhead) over the
    // straggler-bound horizon estimate; on-demand regions at 1.0.
    let rate_scale = if cfg.spot.enabled {
        let market = crate::cloud::spot::SpotMarket::new(&cfg.spot, cfg.seed);
        let shard = cfg.n_train / env.regions.len().max(1);
        let steps =
            (shard.max(1) as f64 / meta.batch_size.max(1) as f64).ceil() * cfg.epochs as f64;
        let power =
            env.greedy_plan().iter().map(|a| a.power()).fold(f64::INFINITY, f64::min);
        let horizon = (steps * base_step / power.max(1e-9)).max(1.0);
        crate::cloud::spot::rate_scale(env, Some(&market), horizon)
    } else {
        vec![1.0; env.regions.len()]
    };
    let inputs = PlanInputs {
        env,
        catalog: &catalog,
        epochs: cfg.epochs,
        base_step_s: base_step,
        batch_size: meta.batch_size,
        links,
        cost,
        scale: vec![1.0; env.regions.len()],
        time_value_per_hour: time_value,
        rate_scale,
    };
    let plan = plan_seeded(&inputs, cfg.dataplane.mode, incumbent);
    Ok(PlannedDataPlane { catalog, plan })
}

/// A planned data plane: the catalog (initial replica sets) plus the
/// placement plan derived from it.
#[derive(Debug, Clone)]
pub struct PlannedDataPlane {
    pub catalog: DatasetCatalog,
    pub plan: PlacementPlan,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::devices::Device;
    use crate::dataplane::catalog::{Layout, PlacementSpec};

    fn four_cloud_env() -> CloudEnv {
        CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 128),
            ("CQ", Device::Skylake, 12, 128),
            ("BJ", Device::Skylake, 12, 128),
            ("GZ", Device::IceLake, 12, 128),
        ])
    }

    fn skewed_catalog() -> DatasetCatalog {
        DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 }),
            512,
            4,
            256 * 1024,
            &[1; 4],
        )
        .unwrap()
    }

    fn replicated_catalog() -> DatasetCatalog {
        DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Skewed { shards: 8, frac: 0.7 }).with_replication(2),
            512,
            4,
            256 * 1024,
            &[1; 4],
        )
        .unwrap()
    }

    fn inputs<'a>(env: &'a CloudEnv, catalog: &'a DatasetCatalog) -> PlanInputs<'a> {
        let fabric = Fabric::full_mesh(1, 4, &LinkSpec::wan_100mbps(), &[]);
        let cost = CostModel::default();
        let tv = default_time_value_per_hour(env, &cost);
        PlanInputs {
            env,
            catalog,
            epochs: 6,
            base_step_s: 0.25,
            batch_size: 16,
            links: PlanInputs::link_view(&fabric, 4),
            cost,
            scale: vec![1.0; 4],
            time_value_per_hour: tv,
            rate_scale: vec![1.0; 4],
        }
    }

    #[test]
    fn spot_rates_pull_the_joint_plan_toward_discounted_regions() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let base = plan(&inputs(&env, &cat), PlacementMode::Joint);
        let mut discounted = inputs(&env, &cat);
        // Chongqing's compute rents at 20% of list: holding cores there
        // is cheap, so the climb should shed at least as much load onto
        // it as the all-on-demand plan does, never less.
        discounted.rate_scale = vec![1.0, 0.2, 1.0, 1.0];
        let spot = plan(&discounted, PlacementMode::Joint);
        assert!(
            spot.resident[1] >= base.resident[1],
            "discounted region lost samples: {:?} vs {:?}",
            spot.resident,
            base.resident
        );
    }

    #[test]
    fn mode_names_round_trip() {
        for m in PlacementMode::ALL {
            assert_eq!(PlacementMode::from_name(m.name()), Ok(m));
        }
        assert_eq!(PlacementMode::from_name("CFD"), Ok(PlacementMode::ComputeFollowsData));
        let err = PlacementMode::from_name("teleport").unwrap_err();
        assert!(err.contains("joint") && err.contains("teleport"));
    }

    #[test]
    fn compute_follows_data_never_moves() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let p = plan(&inputs(&env, &cat), PlacementMode::ComputeFollowsData);
        assert!(p.moves.is_empty());
        assert_eq!(p.resident, cat.resident_samples());
        assert_eq!(p.straggler, 0, "the hot region is the straggler");
        // The data-less region gets no compute.
        let res = cat.resident_samples();
        for (r, &samples) in res.iter().enumerate() {
            if samples == 0 {
                assert_eq!(p.allocations[r].total_units(), 0, "region {r} idle");
            }
        }
        // Replica-aware CFD still never moves, but balances inside the
        // replica sets: the hot region sheds replicated shards for free.
        let rep = replicated_catalog();
        let p2 = plan(&inputs(&env, &rep), PlacementMode::ComputeFollowsData);
        assert!(p2.moves.is_empty(), "CFD must stay migration-free at r2");
        for (s, &a) in rep.shards.iter().zip(&p2.assign) {
            assert!(s.has_replica(a), "CFD assigned outside the replica set");
        }
        assert!(
            p2.resident[0] < cat.resident_samples()[0],
            "free copies relieve the hot region: {:?} vs {:?}",
            p2.resident,
            cat.resident_samples()
        );
        assert!(p2.est_run_s < p.est_run_s, "r2 CFD beats r1 CFD on makespan");
    }

    #[test]
    fn data_follows_compute_balances_toward_power() {
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let p = plan(&inputs(&env, &cat), PlacementMode::DataFollowsCompute);
        assert!(!p.moves.is_empty(), "a 70% skew must shed load");
        // Final layout tracks power shares (4:6:6:6 of 22) within a shard.
        let total: usize = p.resident.iter().sum();
        assert_eq!(total, 512, "moves conserve samples");
        let hot_share = p.resident[0] as f64 / total as f64;
        assert!(hot_share < 0.45, "hot region sheds toward 4/22: {:?}", p.resident);
        // Every move reads from the shard's only replica at r1.
        for m in &p.moves {
            assert_eq!(cat.shards[m.shard].home(), m.from);
            assert_ne!(m.from, m.to);
            assert!(m.bytes > 0, "a copy outside the replica set is physical");
        }
    }

    #[test]
    fn joint_estimate_never_worse_than_either_pure_mode() {
        let env = four_cloud_env();
        for cat in [skewed_catalog(), replicated_catalog()] {
            let inp = inputs(&env, &cat);
            let cfd = plan(&inp, PlacementMode::ComputeFollowsData);
            let dfc = plan(&inp, PlacementMode::DataFollowsCompute);
            let joint = plan(&inp, PlacementMode::Joint);
            assert!(
                joint.est_objective <= cfd.est_objective + 1e-9,
                "{} vs cfd {}",
                joint.est_objective,
                cfd.est_objective
            );
            assert!(
                joint.est_objective <= dfc.est_objective + 1e-9,
                "{} vs dfc {}",
                joint.est_objective,
                dfc.est_objective
            );
            assert!(
                joint.est_run_s <= cfd.est_run_s + 1e-9,
                "joint must never worsen the data straggler"
            );
        }
        // At r1 a 70% skew is worth physically moving for, and the climb
        // strictly relieves the single-home straggler (at r2 the free
        // copies already balance the load, so CFD can match joint).
        let cat = skewed_catalog();
        let inp = inputs(&env, &cat);
        let cfd = plan(&inp, PlacementMode::ComputeFollowsData);
        let joint = plan(&inp, PlacementMode::Joint);
        assert!(joint.est_run_s < cfd.est_run_s, "joint must relieve the r1 data straggler");
        assert!(!joint.moves.is_empty(), "a 70% skew is worth moving for");
    }

    #[test]
    fn replicas_make_the_joint_plan_cheaper_not_worse() {
        // The same logical layout with a second pre-existing copy per
        // shard: the planner can only do better — lower (or equal)
        // objective, fewer migrated bytes, less egress.
        let env = four_cloud_env();
        let r1 = skewed_catalog();
        let r2 = replicated_catalog();
        let p1 = plan(&inputs(&env, &r1), PlacementMode::Joint);
        let p2 = plan(&inputs(&env, &r2), PlacementMode::Joint);
        // Pointwise dominance (exact property): the identical assignment
        // evaluated against the replica-rich catalog needs a subset of
        // the copies, so its objective can only fall.
        let on_r1 = evaluate(&inputs(&env, &r1), &p1.assign);
        let on_r2 = evaluate(&inputs(&env, &r2), &p1.assign);
        assert!(
            on_r2.objective <= on_r1.objective + 1e-9,
            "replicas must never make an assignment dearer: {} vs {}",
            on_r2.objective,
            on_r1.objective
        );
        assert!(on_r2.run_s <= on_r1.run_s + 1e-9);
        // And the planner banks the advantage end to end.
        assert!(
            p2.est_objective <= p1.est_objective + 1e-9,
            "r2 objective {} must not exceed r1 {}",
            p2.est_objective,
            p1.est_objective
        );
        assert!(
            p2.moved_bytes() <= p1.moved_bytes(),
            "pre-existing replicas reduce copies: {} vs {}",
            p2.moved_bytes(),
            p1.moved_bytes()
        );
    }

    #[test]
    fn read_assignment_prefers_fast_then_cheap_sources() {
        let env = four_cloud_env();
        // One shard replicated at {1, 2}; region 2's link to 3 is 30x
        // faster than region 1's: the consumer at 3 must read from 2.
        let mut cat = skewed_catalog();
        cat.shards[0].replicas = vec![1, 2];
        let slow = LinkSpec { bandwidth_bps: 10e6, ..LinkSpec::wan_100mbps() };
        let fast = LinkSpec { bandwidth_bps: 300e6, ..LinkSpec::wan_100mbps() };
        let fabric =
            Fabric::full_mesh(1, 4, &LinkSpec::wan_100mbps(), &[(1, 3, slow), (2, 3, fast)]);
        let mut inp = inputs(&env, &cat);
        inp.links = PlanInputs::link_view(&fabric, 4);
        assert_eq!(best_source(&inp, &cat.shards[0], 3), 2, "nearest-by-bandwidth wins");
        // Co-located consumer reads locally, for free.
        assert_eq!(best_source(&inp, &cat.shards[0], 1), 1);
        // Symmetric links: the cheaper egress region wins (region 0's
        // hub rate beats region 3's edge rate).
        let mut cat2 = skewed_catalog();
        cat2.shards[0].replicas = vec![0, 3];
        let inp2 = inputs(&env, &cat2);
        assert_eq!(best_source(&inp2, &cat2.shards[0], 1), 0, "cheaper egress breaks the tie");
    }

    #[test]
    fn seeded_joint_never_worse_than_pure_modes_for_any_incumbent() {
        let env = four_cloud_env();
        for cat in [skewed_catalog(), replicated_catalog()] {
            let inp = inputs(&env, &cat);
            let cfd = plan(&inp, PlacementMode::ComputeFollowsData);
            let dfc = plan(&inp, PlacementMode::DataFollowsCompute);
            let shards = cat.shards.len();
            // Adversarial incumbents: all-in-one-region, round-robin, a
            // deterministic pseudo-random scatter, and both pure assigns.
            let mut seeds: Vec<Vec<RegionId>> = vec![
                vec![0; shards],
                vec![3; shards],
                (0..shards).map(|s| s % 4).collect(),
                (0..shards).map(|s| (s * 2654435761) % 4).collect(),
                cfd.assign.clone(),
                dfc.assign.clone(),
            ];
            // Geometry mismatches must be ignored, not panic or skew.
            seeds.push(vec![0; shards + 1]);
            seeds.push(vec![99; shards]);
            for inc in &seeds {
                let seeded = plan_seeded(&inp, PlacementMode::Joint, Some(inc));
                assert!(
                    seeded.est_objective <= cfd.est_objective + 1e-9,
                    "seeded {} vs cfd {}",
                    seeded.est_objective,
                    cfd.est_objective
                );
                assert!(
                    seeded.est_objective <= dfc.est_objective + 1e-9,
                    "seeded {} vs dfc {}",
                    seeded.est_objective,
                    dfc.est_objective
                );
            }
        }
    }

    #[test]
    fn seeding_with_the_joint_optimum_is_a_fixed_point() {
        // Re-planning from a converged incumbent must reproduce the plan
        // exactly (the climb's first round finds no improving move) — the
        // property fleet admission relies on for cheap steady-state
        // re-planning.
        let env = four_cloud_env();
        for cat in [skewed_catalog(), replicated_catalog()] {
            let inp = inputs(&env, &cat);
            let scratch = plan(&inp, PlacementMode::Joint);
            let seeded = plan_seeded(&inp, PlacementMode::Joint, Some(&scratch.assign));
            assert_eq!(seeded.assign, scratch.assign, "converged seed must be a fixed point");
            assert_eq!(seeded.est_objective, scratch.est_objective);
            assert_eq!(seeded.moves, scratch.moves);
        }
        // Pure modes ignore the seed entirely.
        let cat = skewed_catalog();
        let inp = inputs(&env, &cat);
        for mode in [PlacementMode::ComputeFollowsData, PlacementMode::DataFollowsCompute] {
            let plain = plan(&inp, mode);
            let seeded = plan_seeded(&inp, mode, Some(&vec![0; cat.shards.len()]));
            assert_eq!(plain.assign, seeded.assign, "{mode:?} must ignore the incumbent");
        }
    }

    #[test]
    fn moves_never_exceed_catalog_bytes_and_plans_are_deterministic() {
        let env = four_cloud_env();
        for cat in [skewed_catalog(), replicated_catalog()] {
            let inp = inputs(&env, &cat);
            for mode in PlacementMode::ALL {
                let a = plan(&inp, mode);
                let b = plan(&inp, mode);
                assert!(a.moved_bytes() <= cat.total_bytes(), "{mode:?} moved too much");
                assert_eq!(a.moves, b.moves, "{mode:?} must be deterministic");
                assert_eq!(a.assign, b.assign, "{mode:?} read assignment must be deterministic");
                assert_eq!(a.resident, b.resident);
                let mut seen = std::collections::BTreeSet::new();
                for m in &a.moves {
                    assert!(seen.insert(m.shard), "{mode:?} moves shard {} twice", m.shard);
                    assert!(
                        !cat.shards[m.shard].has_replica(m.to),
                        "{mode:?} copied onto an existing replica"
                    );
                    assert!(cat.shards[m.shard].has_replica(m.from), "source must hold a copy");
                }
                let total: usize = a.resident.iter().sum();
                assert_eq!(total, cat.total_samples());
            }
        }
    }

    #[test]
    fn rebalance_is_idempotent_at_the_joint_optimum() {
        let env = four_cloud_env();
        // Apply the joint plan's copies, then ask again from its own
        // assignment: a local optimum must not churn (the hysteresis
        // analogue of replan idempotence).
        let mut cat = skewed_catalog();
        let p = plan(&inputs(&env, &cat), PlacementMode::Joint);
        for m in &p.moves {
            cat.add_replica(m.shard, m.to);
        }
        let inp = inputs(&env, &cat);
        assert_eq!(
            rebalance(&inp, 0.02, &[true; 4], &p.assign),
            Vec::new(),
            "settled layout must not churn"
        );
    }

    #[test]
    fn rebalance_never_touches_finished_regions() {
        // Region 1 finished its shard: a slowed region 0 may shed load,
        // but no move may target region 1 (its partition would drop the
        // samples) or take region 1's shards (already trained).
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let mut inp = inputs(&env, &cat);
        inp.scale = vec![0.3, 1.0, 1.0, 1.0]; // hot region slowed hard
        let movable = [true, false, true, true];
        let current: Vec<RegionId> = cat.shards.iter().map(|s| s.home()).collect();
        let moves = rebalance(&inp, 0.0, &movable, &current);
        assert!(!moves.is_empty(), "a 70% slowdown on the hot region must move shards");
        for m in &moves {
            assert_ne!(m.to, 1, "moved into a finished region: {m:?}");
            assert_ne!(current[m.shard], 1, "stole a finished region's shard: {m:?}");
        }
    }

    #[test]
    fn rebalance_hands_off_without_bytes_when_a_replica_exists() {
        // Region 0 slowed; its shards' second copies already sit on the
        // fast regions, so the rebalance must come back as zero-byte
        // training-right handoffs, not physical copies.
        let env = four_cloud_env();
        let cat = replicated_catalog();
        let mut inp = inputs(&env, &cat);
        inp.scale = vec![0.25, 1.0, 1.0, 1.0];
        let current: Vec<RegionId> = cat.shards.iter().map(|s| s.home()).collect();
        let moves = rebalance(&inp, 0.0, &[true; 4], &current);
        assert!(!moves.is_empty(), "a 75% slowdown must shed the hot region's load");
        for m in &moves {
            if cat.shards[m.shard].has_replica(m.to) {
                assert_eq!(m.bytes, 0, "existing replica must be read locally: {m:?}");
                assert_eq!(m.from, m.to);
            } else {
                assert!(m.bytes > 0);
            }
        }
        assert!(
            moves.iter().any(|m| m.bytes == 0),
            "the replicated catalog must yield at least one free handoff: {moves:?}"
        );
    }

    #[test]
    fn high_storage_rent_makes_the_joint_climb_replica_shy() {
        // The ROADMAP's "copies are a free lunch" fix: with rent near
        // zero the joint climb materializes copies to relieve the 70%
        // skew; priced like gold (dollars per GB-hour instead of
        // fractions of a cent) each marginal copy costs more than the
        // makespan it buys, so the climb must create strictly fewer.
        let env = four_cloud_env();
        let cat = skewed_catalog();
        let mut cheap = inputs(&env, &cat);
        cheap.cost.storage_per_gb_hour = 0.0;
        let free_lunch = plan(&cheap, PlacementMode::Joint);
        assert!(
            !free_lunch.moves.is_empty(),
            "rent-free joint must still relieve the skew with copies"
        );
        let mut dear = inputs(&env, &cat);
        dear.cost.storage_per_gb_hour = 5_000.0;
        let rented = plan(&dear, PlacementMode::Joint);
        assert!(
            rented.moves.len() < free_lunch.moves.len(),
            "high rent must create strictly fewer replicas: {} vs {}",
            rented.moves.len(),
            free_lunch.moves.len()
        );
        // The rent shows up in the estimate of any copy-creating
        // assignment.
        let base = evaluate(&cheap, &free_lunch.assign);
        let billed = evaluate(&dear, &free_lunch.assign);
        assert!(billed.cost > base.cost, "created copies must show up in the cost estimate");
    }

    #[test]
    fn zero_data_region_is_planned_not_panicked() {
        // The planner legitimately produces regions with no data; the
        // matching must hand them an empty allocation, not assert.
        let env = four_cloud_env();
        let cat = DatasetCatalog::from_spec(
            &PlacementSpec::new(Layout::Single { region: 0 }),
            256,
            4,
            1024,
            &[1; 4],
        )
        .unwrap();
        let p = plan(&inputs(&env, &cat), PlacementMode::ComputeFollowsData);
        assert_eq!(p.resident, vec![256, 0, 0, 0]);
        for alloc in &p.allocations[1..] {
            assert_eq!(alloc.total_units(), 0);
        }
        assert!(p.est_run_s.is_finite());
    }
}

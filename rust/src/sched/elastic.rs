//! The elastic re-scheduling control loop — what makes the §III.B plan
//! *live* instead of a one-shot pre-training decision.
//!
//! The paper's headline claim is that training workflows deploy
//! "adaptively according to the heterogeneity of available cloud
//! resources", but resources and WANs are not static: co-tenancy steals
//! cores mid-run (HeterPS, arXiv 2111.10635 schedules against *observed*
//! step times for exactly this reason) and WAN bandwidth drifts enough
//! that NetStorm (arXiv 2404.11352) re-plans its aggregation topology
//! from live measurements. This module is the controller half of that
//! loop:
//!
//! ```text
//!   engine/driver ── MonitorSample ──▶ ElasticController
//!        ▲   (per-cloud effective step time,   │ EWMA-smooth, re-run
//!        │    per-link delivered bandwidth)    │ optimal_matching on
//!        │                                     │ observed powers
//!        └───────── ReplanDecision ◀───────────┘ (only past hysteresis)
//!          (new allocations / stale topology / per-link codecs)
//! ```
//!
//! The controller is pure state-machine logic (no simulator, no FaaS):
//! the driver owns *applying* a decision — resizing worker pools through
//! the `faas` autoscaler and re-planning the sync
//! [`Topology`](crate::engine::topology::Topology) — which keeps this
//! module unit-testable in microseconds and free of layering cycles
//! (`sched` never imports `engine`).
//!
//! Two stability guards make the loop safe on noisy samples:
//!
//! - **EWMA smoothing** of per-cloud power scales (worker iteration
//!   jitter is ±25% by construction; a single sample is never trusted);
//! - **hysteresis**: a candidate plan is applied only when it moves more
//!   than `hysteresis` of the currently-allocated units. Deciding twice
//!   on the same observations is idempotent — the first apply commits the
//!   plan, the second sees delta 0.

use crate::cloud::{Allocation, CloudEnv};
use crate::net::RegionId;

use super::{optimal_matching_among, Plan};

/// Knobs for the control loop (CLI: `--elastic`, `--replan-interval`,
/// `--replan-hysteresis`, `--bw-threshold`; config key `"elastic"`).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Master switch; when false the driver never schedules monitor ticks
    /// and the run is exactly the static (seed) behavior.
    pub enabled: bool,
    /// Virtual seconds between monitor samples / re-plan opportunities.
    pub interval_s: f64,
    /// Minimum relative plan movement (|Δunits| summed over clouds,
    /// normalized by currently-allocated units) before a new plan is
    /// applied. Prevents oscillation under sample noise.
    pub hysteresis: f64,
    /// Relative delivered-bandwidth divergence (per planned link) that
    /// marks the sync topology stale and triggers a topology re-plan.
    pub bw_threshold: f64,
    /// EWMA coefficient for new observations in (0, 1]; 1.0 = trust the
    /// latest sample completely.
    pub smoothing: f64,
    /// When true the controller also assigns a per-link gradient codec
    /// ([`LinkCodec`]) from the EWMA-observed delivered bandwidth: the
    /// further a link falls below its nominal bandwidth, the more
    /// aggressive the codec it is worth paying accuracy for. Works with
    /// `enabled == false` too (compression-only control loop).
    pub auto_compression: bool,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            enabled: false,
            interval_s: 60.0,
            hysteresis: 0.2,
            bw_threshold: 0.5,
            smoothing: 0.5,
            auto_compression: false,
        }
    }
}

impl ElasticConfig {
    /// Range-check the knobs (shared by the config parser and the CLI).
    /// `smoothing == 0` would make an *enabled* loop silently inert —
    /// the EWMA never folds in an observation — so it is rejected, not
    /// clamped.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.interval_s > 0.0) {
            return Err(format!("elastic interval_s must be > 0, got {}", self.interval_s));
        }
        if !(self.hysteresis >= 0.0) {
            return Err(format!("elastic hysteresis must be >= 0, got {}", self.hysteresis));
        }
        if !(self.bw_threshold > 0.0) {
            return Err(format!("elastic bw_threshold must be > 0, got {}", self.bw_threshold));
        }
        if !(self.smoothing > 0.0 && self.smoothing <= 1.0) {
            return Err(format!("elastic smoothing must be in (0, 1], got {}", self.smoothing));
        }
        Ok(())
    }
}

/// Per-link gradient codec the controller assigns when
/// [`ElasticConfig::auto_compression`] is on. A `sched`-local mirror of
/// the sync layer's compression choices (this module never imports
/// `engine` or `sync`); the driver maps it onto the wire codec when it
/// applies a decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkCodec {
    /// Dense f32 gradients — full fidelity, full wire bytes.
    None,
    /// Top-k sparsification (~1% of coordinates): ~50x fewer wire bytes
    /// at the largest staleness-equivalent accuracy penalty.
    TopK,
    /// 8-bit block quantization: ~4x fewer wire bytes at a mild penalty.
    Q8,
}

impl LinkCodec {
    /// Stable lowercase name (matches the `"compression"` config values).
    pub fn name(&self) -> &'static str {
        match self {
            LinkCodec::None => "none",
            LinkCodec::TopK => "topk",
            LinkCodec::Q8 => "q8",
        }
    }
}

/// Pick the codec that maximizes staleness-equivalent utility at link
/// congestion `c = max(0, 1 - delivered/nominal)`.
///
/// The bytes a codec saves only buy anything when the link is actually
/// congested (saved seconds scale with `c`), while its accuracy cost —
/// modeled as a constant staleness-equivalent penalty per sync, the same
/// currency the ASGD staleness analysis uses — is paid regardless:
///
/// ```text
///   utility(none) = 0
///   utility(q8)   = 0.75·c − 0.25   (≈4x byte savings, mild penalty)
///   utility(topk) = 0.98·c − 0.45   (≈50x byte savings, large penalty)
/// ```
///
/// Crossovers: q8 overtakes dense past `c > 1/3` (delivered below ~67%
/// of nominal); topk overtakes q8 past `c > 0.87` (delivered below ~13%
/// of nominal — a genuinely collapsing link). Ties prefer the milder
/// codec, so a healthy link (`c = 0`) always ships dense.
fn codec_for(c: f64) -> LinkCodec {
    let q8 = 0.75 * c - 0.25;
    let topk = 0.98 * c - 0.45;
    if topk > q8 && topk > 0.0 {
        LinkCodec::TopK
    } else if q8 > 0.0 {
        LinkCodec::Q8
    } else {
        LinkCodec::None
    }
}

/// One monitoring sample the driver emits per control interval.
#[derive(Debug, Clone)]
pub struct MonitorSample {
    /// Virtual time of the sample.
    pub t: f64,
    /// Per-cloud observed power scale: (expected per-iteration time at
    /// the current allocation) / (measured mean per-iteration completion
    /// time over the window), i.e. 1.0 when the cloud delivers its
    /// catalog power, <1 when it is slowed by churn. `None` when the
    /// window carried no finished steps (a stalled or finished cloud
    /// gives no fresh signal).
    pub power_scale: Vec<Option<f64>>,
    /// Per-cloud mean per-iteration completion seconds over the window —
    /// the raw signal `power_scale` is derived from, carried for
    /// diagnostics and result dumps. Recorded per completed iteration
    /// (not from wall-clock windows), so barrier-heavy SMA runs sample
    /// at full rate instead of only in freely-running windows (ROADMAP
    /// open item); consumers that need the derived form — the
    /// controller's EWMA, and through it the data-plane rebalancer —
    /// read `power_scale` / [`ElasticController::scales`].
    pub mean_iter_s: Vec<Option<f64>>,
    /// Per-cloud "done with its shard" flags: the driver will never
    /// resize a finished partition, so the controller pins its units and
    /// excludes it from plan-movement accounting.
    pub finished: Vec<bool>,
    /// Per-directed-link delivered bandwidth estimates in bits/second
    /// (bytes moved / streaming time over the window).
    pub link_bw: Vec<(RegionId, RegionId, f64)>,
}

/// What the driver should change, produced by [`ElasticController::observe`].
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    /// New per-cloud allocations (always within region inventories).
    pub allocations: Vec<Allocation>,
    /// Relative plan movement that cleared the hysteresis gate (0 when
    /// only the topology went stale).
    pub plan_delta: f64,
    /// Straggler index of the new plan.
    pub straggler: usize,
    /// True when measured link bandwidth diverged past `bw_threshold`
    /// from the values the current sync topology was planned with; the
    /// driver should re-plan the topology against [`ReplanDecision::bw_view`].
    pub replan_topology: bool,
    /// The controller's current bandwidth belief for every tracked
    /// directed link (observed where measured, planning basis elsewhere).
    pub bw_view: Vec<(RegionId, RegionId, f64)>,
    /// Per-link codec reassignments committed this round (only links
    /// whose codec actually changed). Empty unless
    /// [`ElasticConfig::auto_compression`] is on; the driver records each
    /// as a `"compression"` replan event and re-routes those links'
    /// gradient payloads through the new codec.
    pub codec_changes: Vec<(RegionId, RegionId, LinkCodec)>,
    /// True when this decision was forced by a spot-market revocation
    /// ([`ElasticController::note_preemption`]): the hysteresis gate was
    /// bypassed, because a revocation is a step change the EWMA would
    /// otherwise take several windows to trust. The driver records the
    /// replan event with cause `"preemption"`.
    pub preemption_triggered: bool,
}

/// The control-plane re-scheduler (the scheduler function re-invoked
/// periodically, in paper terms).
pub struct ElasticController {
    cfg: ElasticConfig,
    env: CloudEnv,
    /// EWMA-smoothed per-cloud power scale (1.0 = nominal).
    scale: Vec<f64>,
    /// Units per cloud of the currently-applied plan.
    current_units: Vec<u32>,
    /// Bandwidth basis the current sync topology was planned with.
    bw_basis: Vec<(RegionId, RegionId, f64)>,
    /// EWMA-smoothed delivered-bandwidth estimates.
    bw_est: Vec<(RegionId, RegionId, f64)>,
    /// Immutable nominal (construction-time) bandwidths — the congestion
    /// reference for codec selection. Unlike `bw_basis` this never
    /// advances on commit, so a link that collapsed and re-planned still
    /// reads as congested until it actually recovers.
    bw_nominal: Vec<(RegionId, RegionId, f64)>,
    /// Current per-link codec assignment (absent = `LinkCodec::None`).
    codecs: Vec<(RegionId, RegionId, LinkCodec)>,
    /// Regions revoked by the spot market since the last decision; any
    /// pending entry forces the next `observe` to emit a decision with
    /// the hysteresis gate bypassed.
    preempted: Vec<RegionId>,
    /// Number of committed re-plans (diagnostic).
    pub replans: u64,
}

impl ElasticController {
    /// `initial` is the plan the run launched with; `nominal_bw` the
    /// directed-link bandwidths the initial topology was planned against.
    pub fn new(
        cfg: ElasticConfig,
        env: CloudEnv,
        initial: &[Allocation],
        nominal_bw: Vec<(RegionId, RegionId, f64)>,
    ) -> ElasticController {
        assert_eq!(initial.len(), env.regions.len());
        let n = env.regions.len();
        ElasticController {
            cfg,
            env,
            scale: vec![1.0; n],
            current_units: initial.iter().map(|a| a.total_units()).collect(),
            bw_est: nominal_bw.clone(),
            bw_basis: nominal_bw.clone(),
            bw_nominal: nominal_bw,
            codecs: Vec::new(),
            preempted: Vec::new(),
            replans: 0,
        }
    }

    /// The smoothed per-cloud power scales (diagnostic / tests).
    pub fn scales(&self) -> &[f64] {
        &self.scale
    }

    /// Units per cloud of the plan currently in force.
    pub fn current_units(&self) -> &[u32] {
        &self.current_units
    }

    /// The per-link codec assignment currently in force (diagnostic /
    /// tests). Links not listed ship dense (`LinkCodec::None`).
    pub fn codecs(&self) -> &[(RegionId, RegionId, LinkCodec)] {
        &self.codecs
    }

    /// Record a spot-market revocation in `region`. The next `observe`
    /// call bypasses the hysteresis gate and always emits a decision,
    /// flagged [`ReplanDecision::preemption_triggered`] — a revocation
    /// is a step change the smoothed samples would otherwise take
    /// several control windows to trust. Idempotent per region per
    /// window (double-revoking one region forces one decision).
    pub fn note_preemption(&mut self, region: RegionId) {
        if !self.preempted.contains(&region) {
            self.preempted.push(region);
        }
    }

    /// Re-base the controller on a new resource lease (the multi-job
    /// coordinator re-divided the shared inventory): `env` is the leased
    /// inventory this job may now plan within and `allocations` the
    /// within-lease plan just applied. Observed power scales and
    /// bandwidth estimates survive — churn the controller has already
    /// learned about does not vanish with the lease — but the plan
    /// baseline moves, so hysteresis is measured against what is actually
    /// deployed.
    pub fn reset_lease(&mut self, env: CloudEnv, allocations: &[Allocation]) {
        assert_eq!(env.regions.len(), self.scale.len(), "a lease cannot change the region count");
        assert_eq!(allocations.len(), self.scale.len(), "one allocation per region");
        // A lease re-division changes *inventory*, not where the data
        // sits: keep the residency this controller already knows (the
        // post-migration layout installed at deploy, plus any
        // `update_residency` from rebalances) — the coordinator's lease
        // env only carries the admission-time split.
        let mut env = env;
        for (region, known) in env.regions.iter_mut().zip(&self.env.regions) {
            region.data_samples = known.data_samples;
        }
        self.env = env;
        self.current_units = allocations.iter().map(|a| a.total_units()).collect();
    }

    /// Update the per-region resident sample counts the controller plans
    /// against (the data plane reassigned shards mid-run — physical
    /// replica copies, zero-byte handoffs onto existing replicas, or a
    /// delivery-time re-route after a destination finished): Algorithm-1
    /// candidates must match the training assignment actually in force,
    /// which the driver re-derives from the data plane's `assign` map
    /// (`sync_controller_residency`).
    pub fn update_residency(&mut self, samples: &[usize]) {
        assert_eq!(samples.len(), self.env.regions.len(), "one sample count per region");
        for (region, &s) in self.env.regions.iter_mut().zip(samples) {
            region.data_samples = s;
        }
    }

    /// Fold a monitoring sample in and decide whether to re-plan.
    ///
    /// Returns `Some` only when the candidate plan clears the hysteresis
    /// gate or the topology went stale; a returned decision is already
    /// *committed* (the controller's notion of the current plan advances),
    /// so feeding the same observations again returns `None` — the loop
    /// is idempotent under unchanged observations.
    pub fn observe(&mut self, sample: &MonitorSample) -> Option<ReplanDecision> {
        assert_eq!(sample.power_scale.len(), self.scale.len(), "one power scale per cloud");
        assert_eq!(sample.finished.len(), self.scale.len(), "one finished flag per cloud");
        let a = self.cfg.smoothing.clamp(0.0, 1.0);
        for (est, obs) in self.scale.iter_mut().zip(&sample.power_scale) {
            if let Some(s) = obs {
                // Guard against degenerate measurements; a cloud never
                // speeds past ~4x catalog nor below 1% of it.
                let s = s.clamp(0.01, 4.0);
                *est = (1.0 - a) * *est + a * s;
            }
        }
        for &(from, to, bw) in &sample.link_bw {
            if bw <= 0.0 {
                continue;
            }
            match self.bw_est.iter_mut().find(|(f, t, _)| *f == from && *t == to) {
                Some(entry) => entry.2 = (1.0 - a) * entry.2 + a * bw,
                None => self.bw_est.push((from, to, bw)),
            }
        }

        // Finished clouds neither drive the straggler reference (they
        // have no remaining work) nor get resized (the driver skips
        // them), so they are excluded from the matching and pinned at
        // their deployed units — a candidate that "moved" them would
        // advance this controller's baseline past reality and skew every
        // later hysteresis decision.
        if sample.finished.iter().all(|&f| f) {
            return None;
        }
        let active: Vec<bool> = sample.finished.iter().map(|f| !f).collect();
        let mut candidate = self.candidate_plan(&active);
        for (i, alloc) in candidate.allocations.iter_mut().enumerate() {
            if sample.finished[i] {
                *alloc = self.shaped_allocation(i, self.current_units[i]);
            }
        }
        let delta = plan_delta(&self.current_units, &candidate.allocations);
        // With `enabled == false` the controller runs compression-only
        // (`auto_compression`): it never moves load or re-plans the
        // topology — those stay the user's static choices. A pending
        // revocation (`note_preemption`) bypasses the hysteresis gate:
        // the decision fires even when the candidate barely moved, so
        // the driver can record the re-plan and re-balance immediately.
        let forced = self.cfg.enabled && !self.preempted.is_empty();
        let topo_stale = self.cfg.enabled && self.topology_stale();
        let load_moved =
            self.cfg.enabled && (delta > self.cfg.hysteresis || (forced && delta > 0.0));
        let codec_changes = self.commit_codec_changes();
        if !load_moved && !topo_stale && codec_changes.is_empty() && !forced {
            return None;
        }
        let decision = ReplanDecision {
            allocations: if load_moved {
                candidate.allocations.clone()
            } else {
                // Topology-only / compression-only re-plan keeps the
                // current allocations.
                self.current_allocations(&candidate)
            },
            plan_delta: if load_moved { delta } else { 0.0 },
            straggler: candidate.straggler,
            replan_topology: topo_stale,
            bw_view: self.bw_est.clone(),
            codec_changes,
            preemption_triggered: forced,
        };
        if load_moved {
            self.current_units =
                decision.allocations.iter().map(|al| al.total_units()).collect();
        }
        if topo_stale {
            self.bw_basis = self.bw_est.clone();
        }
        self.preempted.clear();
        self.replans += 1;
        Some(decision)
    }

    /// Re-run Algorithm 1 on the smoothed observed powers, over the
    /// still-active clouds only.
    fn candidate_plan(&self, active: &[bool]) -> Plan {
        optimal_matching_among(&self.env, &self.scale, active)
    }

    /// An allocation of `units` total units in region `i`, shaped
    /// greedily over the region's inventory (first device class first —
    /// the same order `greedy_plan` and the search enumerate).
    fn shaped_allocation(&self, i: usize, units: u32) -> Allocation {
        let mut left = units;
        let mut kept = Vec::new();
        for &(dev, max) in &self.env.regions[i].inventory {
            let take = left.min(max);
            if take > 0 {
                kept.push((dev, take));
                left -= take;
            }
        }
        Allocation::new(i, kept)
    }

    /// Reconstruct the in-force allocations (used when only the topology
    /// is stale): the candidate search is re-run at the committed unit
    /// counts' power targets, so we instead keep what is deployed. The
    /// driver never resizes on these.
    fn current_allocations(&self, candidate: &Plan) -> Vec<Allocation> {
        // Unit counts are the committed source of truth; shapes come from
        // the candidate (same inventories).
        candidate
            .allocations
            .iter()
            .zip(&self.current_units)
            .map(|(a, &units)| {
                if a.total_units() == units {
                    a.clone()
                } else {
                    self.shaped_allocation(a.region, units)
                }
            })
            .collect()
    }

    /// Re-score every tracked link's codec against its congestion and
    /// commit the reassignments, returning only the links that changed.
    /// Committing here is safe because any non-empty return fires a
    /// decision (it is part of `observe`'s gate), so the driver always
    /// sees exactly the changes the controller recorded — and feeding the
    /// same observations again returns an empty list (idempotent).
    fn commit_codec_changes(&mut self) -> Vec<(RegionId, RegionId, LinkCodec)> {
        let mut changes = Vec::new();
        if !self.cfg.auto_compression {
            return changes;
        }
        for i in 0..self.bw_est.len() {
            let (from, to, est) = self.bw_est[i];
            let nominal =
                match self.bw_nominal.iter().find(|(f, t, _)| *f == from && *t == to) {
                    Some(&(_, _, n)) => n,
                    None => {
                        // A link first observed mid-run (e.g. a late
                        // lease): its first estimate becomes the nominal.
                        self.bw_nominal.push((from, to, est));
                        est
                    }
                };
            if nominal <= 0.0 {
                continue;
            }
            let congestion = (1.0 - est / nominal).max(0.0);
            let want = codec_for(congestion);
            match self.codecs.iter_mut().find(|(f, t, _)| *f == from && *t == to) {
                Some(entry) => {
                    if entry.2 != want {
                        entry.2 = want;
                        changes.push((from, to, want));
                    }
                }
                None => {
                    if want != LinkCodec::None {
                        self.codecs.push((from, to, want));
                        changes.push((from, to, want));
                    }
                }
            }
        }
        changes
    }

    /// True when any planned link's delivered bandwidth diverged from the
    /// basis the current topology was computed against.
    fn topology_stale(&self) -> bool {
        for &(from, to, est) in &self.bw_est {
            let basis = self
                .bw_basis
                .iter()
                .find(|(f, t, _)| *f == from && *t == to)
                .map(|(_, _, b)| *b);
            if let Some(basis) = basis {
                if basis > 0.0 && (est - basis).abs() / basis > self.cfg.bw_threshold {
                    return true;
                }
            } else if est > 0.0 {
                return true;
            }
        }
        false
    }
}

/// Relative plan movement: summed |Δunits| over clouds, normalized by the
/// currently-allocated total. 0.0 = identical plans.
pub fn plan_delta(current_units: &[u32], candidate: &[Allocation]) -> f64 {
    let moved: u64 = candidate
        .iter()
        .zip(current_units)
        .map(|(a, &cur)| (a.total_units() as i64 - cur as i64).unsigned_abs())
        .sum();
    let base: u64 = current_units.iter().map(|&u| u as u64).sum();
    moved as f64 / base.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::devices::Device;

    fn four_cloud_env() -> CloudEnv {
        CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 1024),
            ("CQ", Device::Skylake, 12, 1024),
            ("BJ", Device::Skylake, 12, 1024),
            ("GZ", Device::IceLake, 12, 1024),
        ])
    }

    fn controller(cfg: ElasticConfig) -> ElasticController {
        let env = four_cloud_env();
        let initial = crate::sched::optimal_matching(&env).allocations;
        let bw: Vec<(usize, usize, f64)> = (0..4)
            .flat_map(|a| (0..4).filter(move |b| *b != a).map(move |b| (a, b, 100e6)))
            .collect();
        ElasticController::new(cfg, env, &initial, bw)
    }

    fn sample(scales: Vec<Option<f64>>) -> MonitorSample {
        let finished = vec![false; scales.len()];
        let mean_iter_s = vec![None; scales.len()];
        MonitorSample { t: 0.0, power_scale: scales, mean_iter_s, finished, link_bw: Vec::new() }
    }

    #[test]
    fn nominal_observations_never_replan() {
        let mut c = controller(ElasticConfig { enabled: true, ..Default::default() });
        for _ in 0..50 {
            assert!(c.observe(&sample(vec![Some(1.0); 4])).is_none());
        }
        assert_eq!(c.replans, 0);
    }

    #[test]
    fn straggler_slowdown_scales_the_slowed_cloud_up() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let before = c.current_units()[2];
        // BJ (a cut-down cloud) loses 65% of its compute.
        let dec = c
            .observe(&sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)]))
            .expect("a 65% power loss must clear hysteresis");
        assert!(dec.plan_delta > 0.0);
        assert_eq!(dec.straggler, 2, "the slowed cloud becomes the reference");
        assert!(
            dec.allocations[2].total_units() > before,
            "slowed cloud scales up: {} -> {}",
            before,
            dec.allocations[2].total_units()
        );
        for (a, r) in dec.allocations.iter().zip(&c.env.regions) {
            assert!(a.fits(r), "replan must fit inventory: {a:?}");
        }
    }

    #[test]
    fn decide_is_idempotent_after_commit() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let s = sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)]);
        assert!(c.observe(&s).is_some());
        assert!(c.observe(&s).is_none(), "same observations, same plan: no second replan");
        assert_eq!(c.replans, 1);
    }

    #[test]
    fn recovery_replans_back() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let initial = c.current_units().to_vec();
        c.observe(&sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)])).unwrap();
        let dec = c
            .observe(&sample(vec![Some(1.0); 4]))
            .expect("recovery to nominal must replan back");
        let back: Vec<u32> = dec.allocations.iter().map(|a| a.total_units()).collect();
        assert_eq!(back, initial, "nominal observations restore the nominal plan");
    }

    #[test]
    fn bandwidth_divergence_marks_topology_stale_without_resizing() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            bw_threshold: 0.5,
            ..Default::default()
        });
        let units = c.current_units().to_vec();
        let s = MonitorSample {
            t: 0.0,
            power_scale: vec![Some(1.0); 4],
            mean_iter_s: vec![None; 4],
            finished: vec![false; 4],
            link_bw: vec![(0, 1, 10e6), (1, 0, 10e6)], // 100 -> 10 Mbps
        };
        let dec = c.observe(&s).expect("10x bandwidth collapse is past threshold");
        assert!(dec.replan_topology);
        assert_eq!(dec.plan_delta, 0.0);
        let kept: Vec<u32> = dec.allocations.iter().map(|a| a.total_units()).collect();
        assert_eq!(kept, units, "topology-only replan keeps allocations");
        // Basis advanced: the same observation is no longer stale.
        assert!(c.observe(&s).is_none());
    }

    #[test]
    fn small_noise_stays_below_hysteresis() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            hysteresis: 0.2,
            ..Default::default()
        });
        // ±8% wobble: candidate plans move at most a core or two, never
        // a fifth of the fleet.
        for k in 0..40 {
            let w = if k % 2 == 0 { 0.92 } else { 1.08 };
            assert!(
                c.observe(&sample(vec![Some(w), Some(1.0 / w), Some(w), Some(1.0)])).is_none(),
                "noise within hysteresis must never replan (k={k})"
            );
        }
    }

    #[test]
    fn finished_clouds_are_pinned_at_their_deployed_units() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let before = c.current_units().to_vec();
        // BJ slows hard, but BJ already finished its shard: the candidate
        // would scale it up, yet the driver can't — the controller must
        // not move it (and here nothing else moves enough on its own).
        let mut s = sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)]);
        s.finished[2] = true;
        assert!(
            c.observe(&s).is_none(),
            "a finished cloud's slowdown must not drive a replan it can't receive"
        );
        assert_eq!(c.current_units(), &before[..], "baseline unchanged");
    }

    #[test]
    fn reset_lease_rebases_plan_and_keeps_observations() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        // Learn a slowdown on BJ first.
        c.observe(&sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)])).unwrap();
        let learned = c.scales().to_vec();
        // The coordinator shrinks the lease to 6 units per region.
        let lease_env = CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 6, 1024),
            ("CQ", Device::Skylake, 6, 1024),
            ("BJ", Device::Skylake, 6, 1024),
            ("GZ", Device::IceLake, 6, 1024),
        ]);
        let within = crate::sched::optimal_matching(&lease_env).allocations;
        c.reset_lease(lease_env.clone(), &within);
        assert_eq!(
            c.current_units(),
            within.iter().map(|a| a.total_units()).collect::<Vec<_>>().as_slice(),
            "baseline follows the applied within-lease plan"
        );
        assert_eq!(c.scales(), learned.as_slice(), "observed scales survive the lease change");
        // Later candidates must fit the leased inventory.
        let dec = c.observe(&sample(vec![Some(1.0), Some(1.0), Some(0.2), Some(1.0)]));
        if let Some(dec) = dec {
            for (a, r) in dec.allocations.iter().zip(&lease_env.regions) {
                assert!(a.fits(r), "replan escaped the lease: {a:?}");
            }
        }
    }

    fn bw_sample(link_bw: Vec<(usize, usize, f64)>) -> MonitorSample {
        MonitorSample {
            t: 0.0,
            power_scale: vec![Some(1.0); 4],
            mean_iter_s: vec![None; 4],
            finished: vec![false; 4],
            link_bw,
        }
    }

    fn auto_cfg() -> ElasticConfig {
        ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            auto_compression: true,
            ..Default::default()
        }
    }

    #[test]
    fn codec_scoring_crossovers() {
        assert_eq!(codec_for(0.0), LinkCodec::None, "healthy link ships dense");
        assert_eq!(codec_for(0.2), LinkCodec::None, "mild congestion not worth the penalty");
        assert_eq!(codec_for(0.5), LinkCodec::Q8, "halved bandwidth pays for quantization");
        assert_eq!(codec_for(0.9), LinkCodec::TopK, "collapsing link pays for sparsification");
    }

    #[test]
    fn collapsing_link_picks_topk_and_reverts_on_recovery() {
        let mut c = controller(auto_cfg());
        // GZ spur collapses 100 -> 10 Mbps (congestion 0.9).
        let dec = c
            .observe(&bw_sample(vec![(0, 2, 10e6), (2, 0, 10e6)]))
            .expect("a 10x collapse must fire a decision");
        assert!(
            dec.codec_changes.contains(&(0, 2, LinkCodec::TopK))
                && dec.codec_changes.contains(&(2, 0, LinkCodec::TopK)),
            "both collapsed directions switch to topk: {:?}",
            dec.codec_changes
        );
        // Recovery back to nominal reverts to dense.
        let dec = c
            .observe(&bw_sample(vec![(0, 2, 100e6), (2, 0, 100e6)]))
            .expect("recovery must fire (codec revert)");
        assert!(
            dec.codec_changes.contains(&(0, 2, LinkCodec::None))
                && dec.codec_changes.contains(&(2, 0, LinkCodec::None)),
            "recovered links revert to dense: {:?}",
            dec.codec_changes
        );
        assert!(c.codecs().iter().all(|&(_, _, k)| k == LinkCodec::None));
    }

    #[test]
    fn codec_only_change_fires_below_topology_threshold() {
        // 100 -> 50 Mbps: exactly at (not past) bw_threshold 0.5, so no
        // topology replan — but congestion 0.5 is past the q8 crossover,
        // so the compression decision alone must fire.
        let mut c = controller(auto_cfg());
        let dec = c
            .observe(&bw_sample(vec![(1, 3, 50e6), (3, 1, 50e6)]))
            .expect("codec change alone must fire a decision");
        assert!(!dec.replan_topology, "50% divergence is not past the topology threshold");
        assert_eq!(dec.plan_delta, 0.0, "no load moved");
        assert!(
            dec.codec_changes.contains(&(1, 3, LinkCodec::Q8))
                && dec.codec_changes.contains(&(3, 1, LinkCodec::Q8)),
            "halved links quantize: {:?}",
            dec.codec_changes
        );
        // Idempotent: same observations, no new changes, no decision.
        assert!(c.observe(&bw_sample(vec![(1, 3, 50e6), (3, 1, 50e6)])).is_none());
    }

    #[test]
    fn auto_compression_off_never_emits_codec_changes() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let dec = c
            .observe(&bw_sample(vec![(0, 2, 10e6), (2, 0, 10e6)]))
            .expect("collapse still fires a topology replan");
        assert!(dec.replan_topology);
        assert!(dec.codec_changes.is_empty(), "codec control is opt-in");
        assert!(c.codecs().is_empty());
    }

    #[test]
    fn compression_only_controller_never_moves_load_or_topology() {
        // `auto_compression` without `enabled`: codecs are the ONLY
        // thing the controller may change — load and topology stay the
        // user's static choices, whatever the observations say.
        let mut c = controller(ElasticConfig {
            smoothing: 1.0,
            auto_compression: true,
            ..Default::default()
        });
        let units = c.current_units().to_vec();
        let mut s = bw_sample(vec![(0, 2, 10e6), (2, 0, 10e6)]);
        s.power_scale = vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)];
        let dec = c.observe(&s).expect("the codec decision still fires");
        assert_eq!(dec.plan_delta, 0.0, "no load movement in compression-only mode");
        assert!(!dec.replan_topology, "no topology re-plan in compression-only mode");
        assert!(!dec.codec_changes.is_empty());
        assert_eq!(c.current_units(), &units[..], "baseline untouched");
    }

    #[test]
    fn nominal_basis_survives_topology_commits() {
        // After the collapse commits (bw_basis advances to 10 Mbps), the
        // link must still read as congested against the *nominal* 100
        // Mbps — a second sample at 10 Mbps stays topk, and only a real
        // recovery reverts it.
        let mut c = controller(auto_cfg());
        c.observe(&bw_sample(vec![(0, 2, 10e6), (2, 0, 10e6)])).unwrap();
        assert!(
            c.observe(&bw_sample(vec![(0, 2, 10e6), (2, 0, 10e6)])).is_none(),
            "steady collapsed state: no new decision"
        );
        assert!(
            c.codecs().contains(&(0, 2, LinkCodec::TopK)),
            "codec holds while the link stays collapsed: {:?}",
            c.codecs()
        );
    }

    #[test]
    fn preemption_bypasses_hysteresis_once() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        // Nominal observations never replan on their own...
        assert!(c.observe(&sample(vec![Some(1.0); 4])).is_none());
        // ...but a noted revocation forces the next decision through,
        // even though the candidate plan did not move past hysteresis.
        c.note_preemption(2);
        c.note_preemption(2); // double-revoke is idempotent
        let dec = c.observe(&sample(vec![Some(1.0); 4])).expect("preemption forces a decision");
        assert!(dec.preemption_triggered);
        assert_eq!(dec.plan_delta, 0.0, "nominal scales: no load actually moves");
        // Consumed: the following nominal sample is quiet again.
        assert!(c.observe(&sample(vec![Some(1.0); 4])).is_none());
    }

    #[test]
    fn preemption_flag_is_off_on_ordinary_replans() {
        let mut c = controller(ElasticConfig {
            enabled: true,
            smoothing: 1.0,
            ..Default::default()
        });
        let dec = c
            .observe(&sample(vec![Some(1.0), Some(1.0), Some(0.35), Some(1.0)]))
            .expect("a 65% power loss must clear hysteresis");
        assert!(!dec.preemption_triggered);
    }

    #[test]
    fn plan_delta_metric() {
        let a = |u: u32| Allocation::new(0, vec![(Device::Skylake, u)]);
        assert_eq!(plan_delta(&[8, 8], &[a(8), a(8)]), 0.0);
        assert!((plan_delta(&[8, 8], &[a(12), a(8)]) - 0.25).abs() < 1e-12);
        assert!((plan_delta(&[0], &[a(3)]) - 3.0).abs() < 1e-12, "empty base guards /0");
    }
}

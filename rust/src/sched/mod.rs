//! Elastic scheduling strategy — the paper's §III.B.
//!
//! Load modeling: divided by WAN sync moments, each partition repeats
//! `T_process = T_load + T_train`, and `T_train ∝ S_data / C_device`. The
//! *load power* of cloud i (formula (1)) is
//!
//! ```text
//! LP_i = ( Σ_m N_cpu,m · P_m  +  Σ_n N_gpu,n · P_n ) / S_data,i
//! ```
//!
//! i.e. compute power per resident sample. A higher LP finishes its local
//! epoch sooner; the straggler is the minimum-LP cloud *at full (greedy)
//! allocation*.
//!
//! Algorithm 1 (TABLE II, "Optimal Matching"): compute every cloud's
//! full-allocation LP, take the minimum as the reference, then for each
//! cloud brute-force the smallest allocation whose LP still ≥ the
//! reference — the straggler keeps everything, every other cloud releases
//! the cores it would only have spent waiting with. This module
//! reproduces the paper's TABLE IV plans exactly (tested below).

use crate::cloud::devices::Device;
use crate::cloud::{Allocation, CloudEnv};

/// The load power of an allocation against a data size (formula (1)).
pub fn load_power(alloc: &Allocation, data_samples: usize) -> f64 {
    assert!(data_samples > 0, "LP undefined for empty data");
    alloc.power() / data_samples as f64
}

/// A resourcing plan: one allocation per cloud + diagnostics.
#[derive(Debug, Clone)]
pub struct Plan {
    pub allocations: Vec<Allocation>,
    /// Full-allocation LP per cloud (the inputs to the matching).
    pub full_lp: Vec<f64>,
    /// Planned LP per cloud (after cutting down).
    pub planned_lp: Vec<f64>,
    /// Index of the straggler cloud (the reference).
    pub straggler: usize,
}

/// Run Algorithm 1 over the environment. `Res[N]` is each region's full
/// inventory; `S_data[N]` the per-region sample counts.
pub fn optimal_matching(env: &CloudEnv) -> Plan {
    assert!(!env.regions.is_empty());
    let full: Vec<Allocation> = env.greedy_plan();
    let full_lp: Vec<f64> =
        full.iter().zip(&env.regions).map(|(a, r)| load_power(a, r.data_samples)).collect();
    let (straggler, &min_lp) = full_lp
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("non-empty");

    let allocations: Vec<Allocation> = env
        .regions
        .iter()
        .enumerate()
        .map(|(i, region)| {
            if i == straggler {
                full[i].clone()
            } else {
                search_optimal_plan(&full[i], region.data_samples, min_lp)
            }
        })
        .collect();
    let planned_lp: Vec<f64> = allocations
        .iter()
        .zip(&env.regions)
        .map(|(a, r)| load_power(a, r.data_samples))
        .collect();
    Plan { allocations, full_lp, planned_lp, straggler }
}

/// Brute-force the smallest allocation (by total units, then by power)
/// with LP >= `target_lp` — the paper's `search_optimal_plan`.
///
/// The search enumerates unit counts per device type (inventories are
/// tens of units, so exhaustive enumeration is exact and instant).
fn search_optimal_plan(full: &Allocation, data_samples: usize, target_lp: f64) -> Allocation {
    // Tolerance: allocations are integral, target comes from f64 math.
    const EPS: f64 = 1e-9;
    let target_power = target_lp * data_samples as f64;

    let devices: Vec<(Device, u32)> = full.units.clone();
    let mut best: Option<(u32, f64, Vec<(Device, u32)>)> = None;

    // Enumerate the cartesian product of 0..=max units per device type.
    fn rec(
        devices: &[(Device, u32)],
        idx: usize,
        current: &mut Vec<(Device, u32)>,
        target_power: f64,
        best: &mut Option<(u32, f64, Vec<(Device, u32)>)>,
    ) {
        if idx == devices.len() {
            let power: f64 = current.iter().map(|(d, n)| d.power_of(*n)).sum();
            if power + 1e-12 >= target_power - 1e-9 {
                let units: u32 = current.iter().map(|(_, n)| *n).sum();
                let better = match best {
                    None => true,
                    Some((bu, bp, _)) => units < *bu || (units == *bu && power < *bp),
                };
                if better {
                    *best = Some((units, power, current.clone()));
                }
            }
            return;
        }
        let (dev, max) = devices[idx];
        for n in 0..=max {
            current.push((dev, n));
            rec(devices, idx + 1, current, target_power, best);
            current.pop();
        }
    }
    rec(&devices, 0, &mut Vec::new(), target_power - EPS, &mut best);

    let chosen = best.map(|(_, _, units)| units).unwrap_or_else(|| devices.clone());
    // Drop zero-unit entries for readability.
    let units: Vec<(Device, u32)> = chosen.into_iter().filter(|(_, n)| *n > 0).collect();
    Allocation::new(full.region, units)
}

/// Relative imbalance of a plan: max(LP)/min(LP) - 1. The elastic plan
/// drives this toward 0; greedy plans can be badly imbalanced.
pub fn imbalance(lps: &[f64]) -> f64 {
    let max = lps.iter().cloned().fold(f64::MIN, f64::max);
    let min = lps.iter().cloned().fold(f64::MAX, f64::min);
    if min <= 0.0 {
        return f64::INFINITY;
    }
    max / min - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Region;

    /// Paper TABLE IV case 1: data 1:1, SH=Cascade12, CQ=Sky12 -> 12:8.
    #[test]
    fn table4_case1() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1000, 1000);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0, "Cascade region is the straggler");
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 8);
    }

    /// TABLE IV case 2: data 2:1, Cascade/Cascade 12:12 -> 12:6.
    #[test]
    fn table4_case2() {
        let env = CloudEnv::new(vec![
            Region::new(0, "Shanghai", vec![(Device::CascadeLake, 12)], 2000),
            Region::new(1, "Chongqing", vec![(Device::CascadeLake, 12)], 1000),
        ]);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 6);
    }

    /// TABLE IV case 3: data 2:1, Cascade/Sky 12:12 -> 12:4.
    #[test]
    fn table4_case3() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 4);
    }

    /// Beyond the paper's two clouds: Algorithm 1 is region-count
    /// agnostic, and the engine's N-cloud topologies consume its plans
    /// directly — every non-straggler region must shed units down to the
    /// straggler's load power.
    #[test]
    fn four_region_plan_matches_straggler() {
        let env = CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 2000),
            ("CQ", Device::Skylake, 12, 1000),
            ("BJ", Device::Skylake, 12, 500),
            ("GZ", Device::IceLake, 12, 500),
        ]);
        let plan = optimal_matching(&env);
        // SH: most data per unit power -> lowest LP -> straggler.
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        let floor = plan.full_lp[0];
        for (i, lp) in plan.planned_lp.iter().enumerate() {
            assert!(*lp + 1e-9 >= floor, "region {i} planned below straggler");
        }
        // Every non-straggler region releases units it would idle on.
        for i in 1..4 {
            assert!(
                plan.allocations[i].total_units() < 12,
                "region {i} should shed units: {:?}",
                plan.allocations[i]
            );
        }
    }

    #[test]
    fn straggler_keeps_full_allocation() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 3000, 100);
        let plan = optimal_matching(&env);
        // SH has far more data -> lowest LP -> straggler keeps 12 cores.
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0], env.greedy_plan()[0]);
    }

    #[test]
    fn plan_lp_at_least_straggler_lp() {
        for (sh, cq) in [(1000, 1000), (2000, 1000), (1000, 2000), (500, 1500)] {
            let env = CloudEnv::tencent_two_region(Device::Skylake, sh, cq);
            let plan = optimal_matching(&env);
            let min_full = plan.full_lp[plan.straggler];
            for (i, lp) in plan.planned_lp.iter().enumerate() {
                assert!(
                    *lp + 1e-9 >= min_full,
                    "cloud {i} planned below the straggler: {lp} < {min_full}"
                );
            }
        }
    }

    #[test]
    fn plan_reduces_imbalance() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let plan = optimal_matching(&env);
        assert!(imbalance(&plan.planned_lp) <= imbalance(&plan.full_lp) + 1e-9);
        assert!(imbalance(&plan.planned_lp) < 0.35, "{:?}", plan.planned_lp);
    }

    #[test]
    fn plans_fit_inventories() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1234, 777);
        let plan = optimal_matching(&env);
        for (a, r) in plan.allocations.iter().zip(&env.regions) {
            assert!(a.fits(r));
        }
    }

    #[test]
    fn gpu_cloud_matches_cpu_straggler() {
        // A V100 cloud paired with a CPU cloud: the CPU side is the
        // straggler and the GPU side needs only its 1 device (can't go
        // below 1 without dropping to zero power).
        let env = CloudEnv::new(vec![
            Region::new(0, "cpu", vec![(Device::CascadeLake, 12)], 1000),
            Region::new(1, "gpu", vec![(Device::V100, 4)], 1000),
        ]);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[1].total_units(), 1);
    }

    #[test]
    fn mixed_inventory_search() {
        // Region with two device classes: search picks the cheapest mix.
        let env = CloudEnv::new(vec![
            Region::new(0, "a", vec![(Device::CascadeLake, 12)], 2000),
            Region::new(1, "b", vec![(Device::CascadeLake, 6), (Device::Skylake, 6)], 1000),
        ]);
        let plan = optimal_matching(&env);
        // target power = LP_a * 1000 = (12/3/2000)*1000 = 2.0
        let power: f64 = plan.allocations[1].power();
        assert!(power + 1e-9 >= 2.0);
        assert_eq!(plan.allocations[1].total_units(), 4, "{:?}", plan.allocations[1]);
    }
}

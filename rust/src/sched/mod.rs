//! Elastic scheduling strategy — the paper's §III.B.
//!
//! Load modeling: divided by WAN sync moments, each partition repeats
//! `T_process = T_load + T_train`, and `T_train ∝ S_data / C_device`. The
//! *load power* of cloud i (formula (1)) is
//!
//! ```text
//! LP_i = ( Σ_m N_cpu,m · P_m  +  Σ_n N_gpu,n · P_n ) / S_data,i
//! ```
//!
//! i.e. compute power per resident sample. A higher LP finishes its local
//! epoch sooner; the straggler is the minimum-LP cloud *at full (greedy)
//! allocation*.
//!
//! Algorithm 1 (TABLE II, "Optimal Matching"): compute every cloud's
//! full-allocation LP, take the minimum as the reference, then for each
//! cloud brute-force the smallest allocation whose LP still ≥ the
//! reference — the straggler keeps everything, every other cloud releases
//! the cores it would only have spent waiting with. This module
//! reproduces the paper's TABLE IV plans exactly (tested below).

pub mod elastic;

use crate::cloud::devices::Device;
use crate::cloud::{Allocation, CloudEnv};

/// The one scheduling tolerance: allocations are integral and device
/// powers are exact rationals, so the only noise is f64 rounding in the
/// LP arithmetic (~ulp scale). Every acceptance test in this module uses
/// this single constant in a single place (`search_optimal_plan`);
/// stacking tolerances across call layers is how allocations strictly
/// below the straggler's load power used to slip through.
pub const POWER_EPS: f64 = 1e-9;

/// The load power of an allocation against a data size (formula (1)).
///
/// Total over its whole domain (mirroring the PR-2 [`imbalance`] fix):
/// `None` means the region holds **no local data** — it finishes its
/// (empty) shard instantly, so it is not a straggler candidate and needs
/// no compute matched to it. The data-plane placement planner
/// legitimately produces such regions (compute-follows-data on a skewed
/// catalog); the old `assert!(data_samples > 0)` panicked on them.
pub fn load_power(alloc: &Allocation, data_samples: usize) -> Option<f64> {
    if data_samples == 0 {
        None
    } else {
        Some(alloc.power() / data_samples as f64)
    }
}

/// A resourcing plan: one allocation per cloud + diagnostics.
#[derive(Debug, Clone)]
pub struct Plan {
    pub allocations: Vec<Allocation>,
    /// Full-allocation LP per cloud (the inputs to the matching).
    /// `f64::INFINITY` marks a region with no local data: it finishes
    /// instantly, drives nothing, and is allocated nothing.
    pub full_lp: Vec<f64>,
    /// Planned LP per cloud (after cutting down).
    pub planned_lp: Vec<f64>,
    /// Index of the straggler cloud (the reference).
    pub straggler: usize,
}

/// Run Algorithm 1 over the environment. `Res[N]` is each region's full
/// inventory; `S_data[N]` the per-region sample counts.
pub fn optimal_matching(env: &CloudEnv) -> Plan {
    optimal_matching_observed(env, &vec![1.0; env.regions.len()])
}

/// Algorithm 1 against *observed* per-cloud compute powers: `scale[i]`
/// multiplies cloud `i`'s nominal (catalog) power — 1.0 means the cloud
/// delivers exactly what the catalog promises, 0.5 that co-tenancy or
/// churn halved it. The elastic control loop ([`elastic`]) feeds measured
/// scales back through this to re-plan mid-run; the static entry point
/// [`optimal_matching`] is the all-ones special case.
pub fn optimal_matching_observed(env: &CloudEnv, scale: &[f64]) -> Plan {
    optimal_matching_among(env, scale, &vec![true; env.regions.len()])
}

/// Algorithm 1 restricted to the `active` clouds: the straggler
/// reference is the minimum observed LP among active clouds only, and
/// inactive clouds keep their full allocation in the returned plan —
/// callers pin them separately (the elastic controller pins finished
/// partitions at their deployed units, since a cloud with no remaining
/// work must neither drive nor follow the load-power floor).
pub fn optimal_matching_among(env: &CloudEnv, scale: &[f64], active: &[bool]) -> Plan {
    assert!(!env.regions.is_empty());
    assert_eq!(scale.len(), env.regions.len(), "one power scale per region");
    assert_eq!(active.len(), env.regions.len(), "one active flag per region");
    assert!(scale.iter().all(|s| *s > 0.0), "power scales must be positive");
    assert!(active.iter().any(|&a| a), "at least one cloud must be active");
    let full: Vec<Allocation> = env.greedy_plan();
    // A data-less region's LP is +inf: done instantly, never the
    // reference, and its power target below is zero (no allocation).
    let lp_of = |a: &Allocation, samples: usize, s: f64| {
        load_power(a, samples).map(|lp| s * lp).unwrap_or(f64::INFINITY)
    };
    let full_lp: Vec<f64> = full
        .iter()
        .zip(&env.regions)
        .zip(scale)
        .map(|((a, r), s)| lp_of(a, r.data_samples, *s))
        .collect();
    let (straggler, &min_lp) = full_lp
        .iter()
        .enumerate()
        .filter(|(i, _)| active[*i])
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("at least one active cloud");

    let allocations: Vec<Allocation> = env
        .regions
        .iter()
        .enumerate()
        .map(|(i, region)| {
            if (i == straggler || !active[i]) && region.data_samples > 0 {
                full[i].clone()
            } else {
                // The cloud must deliver the straggler's observed LP, so
                // its *nominal* power target is inflated by 1/scale.
                // Zero resident samples ⇒ zero target ⇒ empty allocation.
                let target_power = if min_lp.is_finite() {
                    min_lp * region.data_samples as f64 / scale[i]
                } else {
                    0.0
                };
                search_optimal_plan(&full[i], target_power)
            }
        })
        .collect();
    let planned_lp: Vec<f64> = allocations
        .iter()
        .zip(&env.regions)
        .zip(scale)
        .map(|((a, r), s)| lp_of(a, r.data_samples, *s))
        .collect();
    Plan { allocations, full_lp, planned_lp, straggler }
}

/// Brute-force the smallest allocation (by total units, then by power)
/// with nominal power >= `target_power` — the paper's
/// `search_optimal_plan`.
///
/// The search enumerates unit counts per device type (inventories are
/// tens of units, so exhaustive enumeration is exact and instant).
/// Acceptance uses [`POWER_EPS`] exactly once: callers must pass the raw
/// target, not a pre-slackened one.
pub(crate) fn search_optimal_plan(full: &Allocation, target_power: f64) -> Allocation {
    let devices: Vec<(Device, u32)> = full.units.clone();
    let mut best: Option<(u32, f64, Vec<(Device, u32)>)> = None;

    // Enumerate the cartesian product of 0..=max units per device type.
    fn rec(
        devices: &[(Device, u32)],
        idx: usize,
        current: &mut Vec<(Device, u32)>,
        target_power: f64,
        best: &mut Option<(u32, f64, Vec<(Device, u32)>)>,
    ) {
        if idx == devices.len() {
            let power: f64 = current.iter().map(|(d, n)| d.power_of(*n)).sum();
            if power >= target_power - POWER_EPS {
                let units: u32 = current.iter().map(|(_, n)| *n).sum();
                let better = match best {
                    None => true,
                    Some((bu, bp, _)) => units < *bu || (units == *bu && power < *bp),
                };
                if better {
                    *best = Some((units, power, current.clone()));
                }
            }
            return;
        }
        let (dev, max) = devices[idx];
        for n in 0..=max {
            current.push((dev, n));
            rec(devices, idx + 1, current, target_power, best);
            current.pop();
        }
    }
    rec(&devices, 0, &mut Vec::new(), target_power, &mut best);

    let chosen = best.map(|(_, _, units)| units).unwrap_or_else(|| devices.clone());
    // Drop zero-unit entries for readability.
    let units: Vec<(Device, u32)> = chosen.into_iter().filter(|(_, n)| *n > 0).collect();
    Allocation::new(full.region, units)
}

/// Relative imbalance of a plan: max(LP)/min(LP) - 1. The elastic plan
/// drives this toward 0; greedy plans can be badly imbalanced.
///
/// Total over its whole domain: `None` means *no plan at all* (an empty
/// LP slice carries no imbalance signal — the old f64::MIN/f64::MAX fold
/// produced garbage here), while `Some(f64::INFINITY)` means the plan
/// contains a *stalled cloud* (a non-positive load power that would never
/// finish its shard).
pub fn imbalance(lps: &[f64]) -> Option<f64> {
    if lps.is_empty() {
        return None;
    }
    let max = lps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = lps.iter().cloned().fold(f64::INFINITY, f64::min);
    if min <= 0.0 {
        return Some(f64::INFINITY);
    }
    Some(max / min - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Region;

    /// Paper TABLE IV case 1: data 1:1, SH=Cascade12, CQ=Sky12 -> 12:8.
    #[test]
    fn table4_case1() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1000, 1000);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0, "Cascade region is the straggler");
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 8);
    }

    /// TABLE IV case 2: data 2:1, Cascade/Cascade 12:12 -> 12:6.
    #[test]
    fn table4_case2() {
        let env = CloudEnv::new(vec![
            Region::new(0, "Shanghai", vec![(Device::CascadeLake, 12)], 2000),
            Region::new(1, "Chongqing", vec![(Device::CascadeLake, 12)], 1000),
        ]);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 6);
    }

    /// TABLE IV case 3: data 2:1, Cascade/Sky 12:12 -> 12:4.
    #[test]
    fn table4_case3() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        assert_eq!(plan.allocations[1].total_units(), 4);
    }

    /// Beyond the paper's two clouds: Algorithm 1 is region-count
    /// agnostic, and the engine's N-cloud topologies consume its plans
    /// directly — every non-straggler region must shed units down to the
    /// straggler's load power.
    #[test]
    fn four_region_plan_matches_straggler() {
        let env = CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 2000),
            ("CQ", Device::Skylake, 12, 1000),
            ("BJ", Device::Skylake, 12, 500),
            ("GZ", Device::IceLake, 12, 500),
        ]);
        let plan = optimal_matching(&env);
        // SH: most data per unit power -> lowest LP -> straggler.
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0].total_units(), 12);
        let floor = plan.full_lp[0];
        for (i, lp) in plan.planned_lp.iter().enumerate() {
            assert!(*lp + 1e-9 >= floor, "region {i} planned below straggler");
        }
        // Every non-straggler region releases units it would idle on.
        for i in 1..4 {
            assert!(
                plan.allocations[i].total_units() < 12,
                "region {i} should shed units: {:?}",
                plan.allocations[i]
            );
        }
    }

    /// Regression (ISSUE-4 satellite): the data-plane placement planner
    /// legitimately produces regions with zero resident samples; the
    /// matching must hand them an empty allocation instead of panicking
    /// in `load_power`, and they must never drive the straggler floor.
    #[test]
    fn zero_data_region_is_total_not_a_panic() {
        let a = Allocation::new(0, vec![(Device::Skylake, 4)]);
        assert_eq!(load_power(&a, 0), None, "no data, no load power");
        assert!(load_power(&a, 100).unwrap() > 0.0);

        let env = CloudEnv::new(vec![
            Region::new(0, "SH", vec![(Device::CascadeLake, 12)], 2000),
            Region::new(1, "CQ", vec![(Device::Skylake, 12)], 0),
        ]);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0, "the data-holding region is the reference");
        assert_eq!(plan.allocations[0].total_units(), 12, "straggler keeps everything");
        assert_eq!(plan.allocations[1].total_units(), 0, "no data ⇒ no compute");
        assert_eq!(plan.full_lp[1], f64::INFINITY);
        assert!(plan.allocations.iter().zip(&env.regions).all(|(a, r)| a.fits(r)));
    }

    #[test]
    fn straggler_keeps_full_allocation() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 3000, 100);
        let plan = optimal_matching(&env);
        // SH has far more data -> lowest LP -> straggler keeps 12 cores.
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[0], env.greedy_plan()[0]);
    }

    #[test]
    fn plan_lp_at_least_straggler_lp() {
        for (sh, cq) in [(1000, 1000), (2000, 1000), (1000, 2000), (500, 1500)] {
            let env = CloudEnv::tencent_two_region(Device::Skylake, sh, cq);
            let plan = optimal_matching(&env);
            let min_full = plan.full_lp[plan.straggler];
            for (i, lp) in plan.planned_lp.iter().enumerate() {
                assert!(
                    *lp + 1e-9 >= min_full,
                    "cloud {i} planned below the straggler: {lp} < {min_full}"
                );
            }
        }
    }

    #[test]
    fn plan_reduces_imbalance() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let plan = optimal_matching(&env);
        let planned = imbalance(&plan.planned_lp).unwrap();
        let full = imbalance(&plan.full_lp).unwrap();
        assert!(planned <= full + 1e-9);
        assert!(planned < 0.35, "{:?}", plan.planned_lp);
    }

    #[test]
    fn imbalance_is_total() {
        assert_eq!(imbalance(&[]), None, "no plan is not the same as a balanced plan");
        assert_eq!(imbalance(&[0.0, 1.0]), Some(f64::INFINITY), "stalled cloud");
        assert_eq!(imbalance(&[-1.0]), Some(f64::INFINITY));
        assert_eq!(imbalance(&[2.0, 2.0]), Some(0.0));
        assert!((imbalance(&[3.0, 2.0]).unwrap() - 0.5).abs() < 1e-12);
    }

    /// Regression: the acceptance tolerance is applied once. The old code
    /// seeded the recursion with `target - 1e-9` and then compared
    /// `power + 1e-12 >= target - 1e-9`, accepting allocations up to
    /// ~2e-9 *below* the straggler's load power.
    #[test]
    fn search_tolerance_is_single_at_the_boundary() {
        let full = Allocation::new(0, vec![(Device::CascadeLake, 12)]);
        // 6 Cascade cores deliver power 2.0 (up to f64 rounding).
        let six = Device::CascadeLake.power_of(6);
        // Within one POWER_EPS of reachable: 6 cores are accepted.
        assert_eq!(search_optimal_plan(&full, six + 0.5 * POWER_EPS).total_units(), 6);
        // 1.5 epsilons above reachable: the old stacked tolerances let 6
        // cores through; the single tolerance must push to 7.
        assert_eq!(search_optimal_plan(&full, six + 1.5 * POWER_EPS).total_units(), 7);
        // Far above: unambiguous.
        assert_eq!(search_optimal_plan(&full, six + 1e-6).total_units(), 7);
    }

    #[test]
    fn observed_scales_shift_the_plan() {
        // Nominal: case-3 shape, CQ sheds to 4 cores (TABLE IV).
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        let nominal = optimal_matching(&env);
        assert_eq!(nominal.allocations[1].total_units(), 4);
        // CQ observed at 40% of catalog power: it must rent more cores to
        // still match the straggler's observed load power.
        let observed = optimal_matching_observed(&env, &[1.0, 0.4]);
        assert_eq!(observed.straggler, 0, "SH stays the reference");
        assert!(
            observed.allocations[1].total_units() > 4,
            "slowed cloud must scale up: {:?}",
            observed.allocations[1]
        );
        // And the planned observed LP still clears the straggler's.
        let floor = observed.full_lp[0];
        for lp in &observed.planned_lp {
            assert!(*lp + POWER_EPS / 1000.0 >= floor);
        }
    }

    #[test]
    fn plans_fit_inventories() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1234, 777);
        let plan = optimal_matching(&env);
        for (a, r) in plan.allocations.iter().zip(&env.regions) {
            assert!(a.fits(r));
        }
    }

    #[test]
    fn inactive_clouds_neither_drive_nor_follow_the_floor() {
        let env = CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 1000),
            ("CQ", Device::Skylake, 12, 1000),
            ("BJ", Device::Skylake, 12, 1000),
        ]);
        // BJ is slowest by far but inactive (finished): the reference
        // must come from the active pair (SH, LP 4/1000), not BJ.
        let plan = optimal_matching_among(&env, &[1.0, 1.0, 0.1], &[true, true, false]);
        assert_eq!(plan.straggler, 0, "straggler picked among active clouds only");
        assert_eq!(plan.allocations[1].total_units(), 8, "CQ matches SH, not slowed BJ");
        assert_eq!(plan.allocations[2].total_units(), 12, "inactive cloud left at full");
    }

    #[test]
    fn gpu_cloud_matches_cpu_straggler() {
        // A V100 cloud paired with a CPU cloud: the CPU side is the
        // straggler and the GPU side needs only its 1 device (can't go
        // below 1 without dropping to zero power).
        let env = CloudEnv::new(vec![
            Region::new(0, "cpu", vec![(Device::CascadeLake, 12)], 1000),
            Region::new(1, "gpu", vec![(Device::V100, 4)], 1000),
        ]);
        let plan = optimal_matching(&env);
        assert_eq!(plan.straggler, 0);
        assert_eq!(plan.allocations[1].total_units(), 1);
    }

    #[test]
    fn mixed_inventory_search() {
        // Region with two device classes: search picks the cheapest mix.
        let env = CloudEnv::new(vec![
            Region::new(0, "a", vec![(Device::CascadeLake, 12)], 2000),
            Region::new(1, "b", vec![(Device::CascadeLake, 6), (Device::Skylake, 6)], 1000),
        ]);
        let plan = optimal_matching(&env);
        // target power = LP_a * 1000 = (12/3/2000)*1000 = 2.0
        let power: f64 = plan.allocations[1].power();
        assert!(power + 1e-9 >= 2.0);
        assert_eq!(plan.allocations[1].total_units(), 4, "{:?}", plan.allocations[1]);
    }
}

//! Spot-market placement vs on-demand-only under a revocation trace.
//!
//! The same 4-cloud heterogeneous WAN (fat Shanghai spokes, a thin
//! Beijing–Guangzhou long haul) runs the same job twice over a
//! resident-data catalog with the joint data/compute planner:
//!
//! - **ondemand** — the seed behavior: every region rents at list
//!   price, capacity is never revoked;
//! - **spot** — the market subsystem on (`--spot`): the planner folds
//!   each region's expected effective spot rate — price trace plus the
//!   expected preemption/restore overhead — into its joint objective,
//!   compute bills at the discounted trace price on committed spot
//!   regions, and the market's revocation trace preempts pools mid-run
//!   (checkpoint capture, pool teardown, restore stall, lost in-flight
//!   steps re-run).
//!
//! The preemption rate is set high enough that revocations actually
//! land inside the short CI-scale horizon, so the reported numbers show
//! the real trade: dollars saved against a bounded makespan regression.
//! Reported per run: makespan, total cost and its compute/restore
//! split, revocations recovered, dollars saved vs list price, and the
//! `"preemption"` replan events the elastic controller fired. The
//! acceptance bars — spot strictly cheaper, makespan within 1.35x, and
//! exact step/epoch accounting across preemptions — are pinned by
//! `rust/tests/spot.rs`.

use crate::cloud::spot::SpotConfig;
use crate::coordinator::Coordinator;
use crate::dataplane::{self, Layout, PlacementSpec};
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::sync::{Strategy, SyncConfig};
use crate::train::metrics::replan_cause;
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

/// The experiment's market: a deep but volatile discount and a
/// revocation rate aggressive enough to land preemptions inside a
/// CI-scale run (mean one revocation per spot pool every 10 virtual
/// minutes).
fn market_knobs() -> SpotConfig {
    SpotConfig {
        enabled: true,
        discount: 0.35,
        volatility: 0.25,
        preempt_per_hour: 6.0,
        restore_stall_s: 30.0,
        segment_s: 300.0,
        seed: 0, // derive from the job seed
    }
}

fn run_market(coord: &Coordinator, base: &TrainConfig, spot: bool) -> TrainReport {
    let env = four_cloud_env(base.n_train);
    let mut cfg = base.clone();
    if spot {
        cfg.spot = market_knobs();
    }
    let meta = coord
        .runtime()
        .load_model(&cfg.model)
        .unwrap_or_else(|e| panic!("loading {}: {e}", cfg.model))
        .meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta)
        .unwrap_or_else(|e| panic!("{} plan: {e}", if spot { "spot" } else { "ondemand" }));
    let allocations = planned.plan.allocations.clone();
    crate::engine::driver::run_geo_training_planned(
        coord.runtime(),
        &env,
        allocations,
        cfg,
        Some(planned),
    )
    .unwrap_or_else(|e| panic!("{} run: {e}", if spot { "spot" } else { "ondemand" }))
}

/// `exp --id spot`: spot-aware placement + discounted billing +
/// revocation recovery vs the on-demand-only baseline on the 4-cloud
/// WAN.
pub fn spot_compare(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Spot market: tier-aware placement + revocation recovery, 4-cloud WAN, {model}");
    let (n_train, n_eval) = crate::data::default_sizes(model);

    let mut base = TrainConfig::new(model);
    base.epochs = scale.epochs(model).min(6);
    base.n_train = n_train;
    base.n_eval = n_eval;
    base.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    base.skip_eval = true;
    base.link_overrides = hetero_overrides();
    // Resident catalog under the joint planner: no migration is needed,
    // but the planner may still move training rights toward regions the
    // market rents out cheap.
    base.dataplane.placement = Some(PlacementSpec::new(Layout::Resident));
    // The elastic loop on in both runs (identically configured) so the
    // spot run's preemption-forced re-plans have a live controller to
    // fire through — and the baseline pays the same control overhead.
    base.elastic.enabled = true;

    let od = run_market(coord, &base, false);
    let sp = run_market(coord, &base, true);

    let row = |name: &str, r: &TrainReport| {
        vec![
            name.to_string(),
            format!("{:.0}s", r.total_time),
            format!("${:.4}", r.cost),
            format!("${:.4}", r.compute_cost),
            format!("${:.4}", r.restore_cost),
            format!("{}", r.preemptions),
            format!("${:.4}", r.spot_savings),
        ]
    };
    print_table(
        &["market", "makespan", "cost", "compute", "restore", "preempts", "saved"],
        &[row("ondemand", &od), row("spot", &sp)],
    );
    let cost_ratio = sp.cost / od.cost.max(1e-12);
    let makespan_ratio = sp.total_time / od.total_time.max(1e-9);
    println!("  spot/ondemand cost: {cost_ratio:.2}x  (< 1.0 = spot cheaper)");
    println!("  spot/ondemand makespan: {makespan_ratio:.2}x  (revocation overhead)");
    let pre = replan_cause::PREEMPTION;
    for ev in sp.replan_events.iter().filter(|ev| ev.cause.contains(pre)) {
        println!("  replan @{:.0}s [{}] delta={:.3}", ev.t, ev.cause, ev.plan_delta);
    }

    let run_json = |r: &TrainReport| {
        Json::obj(vec![
            ("total_time", Json::num(r.total_time)),
            ("cost_usd", Json::num(r.cost)),
            ("compute_cost_usd", Json::num(r.compute_cost)),
            ("restore_cost_usd", Json::num(r.restore_cost)),
            ("preemptions", Json::num(r.preemptions as f64)),
            ("spot_savings_usd", Json::num(r.spot_savings)),
            ("replans", Json::num(r.replan_events.len() as f64)),
        ])
    };
    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("ondemand", run_json(&od)),
        ("spot", run_json(&sp)),
        ("cost_ratio", Json::num(cost_ratio)),
        ("makespan_ratio", Json::num(makespan_ratio)),
    ]);
    save_result("spot", &doc);
    doc
}

//! Federated edge-cohort tier — full vs sampled participation under
//! dropout churn, beyond the paper's cloud-only deployment.
//!
//! A 4-cloud heterogeneous WAN (the shared testbed) hosts a six-figure
//! edge-client population carved into per-cloud cohort pools. Each cohort
//! round aggregates its clients locally into the cloud's PS (HiPS stage
//! 1) before the cloud joins the planned WAN sync (stage 2), so the WAN
//! planner still sees four nodes however many clients hang below. Two
//! runs compare:
//!
//! - **full** — every client participates every round (`sample_frac` 1,
//!   no dropout): the FedAvg upper bound on uplink traffic;
//! - **sampled** — 10% of each cohort is sampled per round and 5% of the
//!   sampled clients drop out as churn: the realistic cross-device
//!   regime. PS pushes are population-reweighted, so the *update counts
//!   match the full run exactly* while only the arrived clients' uplink
//!   bytes hit the wire.
//!
//! The acceptance bars (pinned in `rust/tests/federated.rs`): both runs
//! complete in a few thousand simulator events despite the 100k-client
//! population (cohort pooling — a round is ~2 events per cohort), equal
//! client-update totals, and strictly fewer WAN bytes for the sampled
//! run.

use crate::coordinator::Coordinator;
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::sync::{Strategy, SyncConfig};
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

/// Build the federated testbed config: `clients` edge clients over
/// `cohorts` per-cloud pools on the 4-cloud WAN.
pub(crate) fn federated_config(
    model: &str,
    scale: Scale,
    clients: usize,
    cohorts: usize,
    sample_frac: f64,
    dropout: f64,
) -> TrainConfig {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = scale.epochs(model).min(4);
    cfg.n_train = n_train;
    cfg.n_eval = n_eval;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    cfg.skip_eval = true;
    cfg.link_overrides = hetero_overrides();
    cfg.federated.clients = clients;
    cfg.federated.cohorts = cohorts;
    cfg.federated.sample_frac = sample_frac;
    cfg.federated.dropout = dropout;
    cfg.federated.validate().unwrap_or_else(|e| panic!("federated config: {e}"));
    cfg
}

fn run_one(coord: &Coordinator, cfg: TrainConfig, label: &str) -> TrainReport {
    let env = four_cloud_env(cfg.n_train);
    crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
        .unwrap_or_else(|e| panic!("federated {label}: {e}"))
}

/// `exp --id federated`: full vs sampled participation for a 100k-client
/// (quick) / 1M-client (full) population over 40 cohorts per cloud.
pub fn federated_compare(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    let clients = match scale {
        Scale::Quick => 100_000,
        Scale::Full => 1_000_000,
    };
    let cohorts = 40;
    println!(
        "Federated edge tier: {model}, {clients} clients / {cohorts} cohorts per cloud on the 4-cloud WAN"
    );

    let mut rows = Vec::new();
    let mut docs = Vec::new();
    let mut reports = Vec::new();
    for (label, frac, drop) in [("full", 1.0, 0.0), ("sampled", 0.1, 0.05)] {
        let cfg = federated_config(model, scale, clients, cohorts, frac, drop);
        let r = run_one(coord, cfg, label);
        let fed = r.federated.clone().unwrap_or_else(|| {
            panic!("federated {label}: report missing the federated block")
        });
        let updates: u64 = r.partitions.iter().map(|p| p.steps).sum();
        rows.push(vec![
            label.to_string(),
            format!("{:.0}s", r.total_time),
            format!("{}", fed.rounds),
            format!("{}", fed.participants),
            format!("{}", fed.dropouts),
            format!("{}", updates),
            format!("{:.1}MB", fed.uplink_bytes as f64 / 1e6),
            format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
        ]);
        docs.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("sample_frac", Json::num(frac)),
            ("dropout", Json::num(drop)),
            ("clients", Json::num(fed.clients as f64)),
            ("cohorts", Json::num(fed.cohorts as f64)),
            ("total_time_s", Json::num(r.total_time)),
            ("rounds", Json::num(fed.rounds as f64)),
            ("participants", Json::num(fed.participants as f64)),
            ("dropouts", Json::num(fed.dropouts as f64)),
            ("client_updates", Json::num(updates as f64)),
            ("uplink_bytes", Json::num(fed.uplink_bytes as f64)),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
            ("total_cost_usd", Json::num(r.cost)),
        ]));
        reports.push((label, r));
    }
    print_table(
        &["participation", "time", "rounds", "arrived", "dropped", "updates", "uplink", "WAN MB"],
        &rows,
    );
    let full = &reports[0].1;
    let sampled = &reports[1].1;
    println!(
        "  sampled vs full: {:.1}x fewer WAN bytes at equal update counts ({} client updates each)",
        full.wan_bytes as f64 / (sampled.wan_bytes as f64).max(1.0),
        full.partitions.iter().map(|p| p.steps).sum::<u64>(),
    );

    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("clients", Json::num(clients as f64)),
        ("cohorts_per_cloud", Json::num(cohorts as f64)),
        ("modes", Json::arr(docs)),
    ]);
    save_result("federated", &doc);
    doc
}

//! Fleet-scale simulation throughput — the perf trajectory's benchmark.
//!
//! Drives `run_fleet` over a synthetic Poisson trace big enough that the
//! simulator's three asymptotic optimizations all matter at once:
//! hundreds of concurrent jobs sharing one inventory (the indexed merged
//! clock), partitions whose one-worker-per-GPU pools are hundreds wide
//! (worker-cohort aggregation, `TrainConfig::cohort_threshold`), and one
//! joint data/compute admission per arrival (incremental re-planning
//! seeded from the fleet's incumbent assignment).
//!
//! Two legs:
//!
//! 1. **Throughput** — the full trace under fair-share leasing with
//!    cohorts on; reports `events_executed`, events per wall second,
//!    makespan and cost (saved to `results/fleetscale.json`).
//! 2. **Equivalence** — a small FIFO sub-trace run per-worker
//!    (`cohort_threshold = 0`) and again with cohorts, verifying the
//!    aggregation's accounting claim: identical step totals, compute
//!    cost within ~1%, and the ≥10x event reduction the trajectory
//!    tracks.
//!
//! Always uses the artifact-free `"synthetic"` model, so the benchmark
//! runs anywhere (CI included) without PJRT artifacts.

use crate::cloud::devices::Device;
use crate::cloud::CloudEnv;
use crate::coordinator::fleet::{
    poisson_arrivals, run_fleet, solo_estimate_s, FleetConfig, FleetReport, JobRequest,
    LeasePolicy,
};
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::sync::{Strategy, SyncConfig};
use crate::train::TrainConfig;
use crate::util::json::Json;

/// GPU units per region. GPU pools get one PS worker per unit
/// (`calib::worker_count` does not clamp them like CPU pools), so a full
/// lease is a 320-worker pool — 20 cohorts at the benchmark threshold.
const UNITS_PER_REGION: u32 = 320;
/// Cohort threshold the benchmark runs with: pools wider than this
/// simulate as `ceil(workers / 16)`-sized weighted waves.
const COHORT_THRESHOLD: usize = 16;
/// Per-partition steps each job runs per epoch (sets `n_train`).
const STEPS_PER_EPOCH: usize = 160;
/// Jobs in the per-worker vs cohort equivalence leg (FIFO, so each runs
/// at the full 320-wide pools where aggregation bites hardest).
const EQUIV_JOBS: usize = 2;

/// A `regions`-wide GPU fleet (alternating T4/V100), data evenly
/// resident so every job's admission splits evenly.
fn gpu_fleet_env(regions: usize, n_train: usize) -> CloudEnv {
    let names: Vec<String> = (0..regions).map(|r| format!("gpu{r:02}")).collect();
    let per = n_train / regions;
    let rows: Vec<(&str, Device, u32, usize)> = names
        .iter()
        .enumerate()
        .map(|(r, name)| {
            let dev = if r % 2 == 0 { Device::T4 } else { Device::V100 };
            let data = if r + 1 == regions { n_train - per * (regions - 1) } else { per };
            (name.as_str(), dev, UNITS_PER_REGION, data)
        })
        .collect();
    CloudEnv::multi_region(rows)
}

/// Sum of per-partition step counters across every job in a fleet run —
/// the accounting quantity cohort aggregation must preserve exactly.
fn total_steps(r: &FleetReport) -> u64 {
    r.jobs
        .iter()
        .map(|j| j.report.partitions.iter().map(|p| p.steps).sum::<u64>())
        .sum()
}

fn run_trace(
    coord: &Coordinator,
    env: &CloudEnv,
    policy: LeasePolicy,
    requests: &[JobRequest],
) -> anyhow::Result<FleetReport> {
    let cfg = FleetConfig::new(policy, env.clone());
    run_fleet(coord.runtime(), &cfg, requests)
}

/// `exp --id fleetscale`: synthetic fleet-scale throughput benchmark
/// (quick: 200 jobs / 16 regions; `--full`: 1000 jobs). `jobs` /
/// `regions` of 0 mean "use the scale default".
pub fn fleetscale(
    coord: &Coordinator,
    scale: Scale,
    jobs: usize,
    regions: usize,
) -> anyhow::Result<()> {
    let jobs = if jobs > 0 {
        jobs
    } else if scale == Scale::Full {
        1000
    } else {
        200
    };
    let regions = if regions > 0 { regions } else { 16 };

    let batch = coord.runtime().load_model("synthetic")?.meta.batch_size;
    let n_train = STEPS_PER_EPOCH * batch * regions;
    let env = gpu_fleet_env(regions, n_train);

    let mut template = TrainConfig::new("synthetic");
    template.epochs = 2;
    template.n_train = n_train;
    template.n_eval = batch * 8;
    template.sync = SyncConfig::new(Strategy::AsgdGa, 32);
    template.skip_eval = true;
    template.cohort_threshold = COHORT_THRESHOLD;

    // Fair-share service shrinks with concurrency, so the trace is only
    // stable when arrivals are slower than the full-fleet service rate:
    // mean gap 1.5x the solo estimate keeps utilization ~2/3 — jobs
    // overlap (the merged clock interleaves simulators) without
    // collapsing every lease to one unit (which would disable cohorts).
    let est = solo_estimate_s(&template, &env, batch).max(0.05);
    let mean = (est * 1.5).max(0.02);
    let arrivals = poisson_arrivals(jobs, mean, 4242);
    let requests: Vec<JobRequest> = arrivals
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), at, train)
        })
        .collect();

    println!(
        "Fleet-scale benchmark: {jobs} synthetic jobs on {regions} GPU regions \
         ({UNITS_PER_REGION} units each, cohort threshold {COHORT_THRESHOLD}, \
         mean gap {mean:.2}s, solo est {est:.1}s)"
    );

    // Leg 1 — throughput: the full trace, fair-share leasing, cohorts on.
    let fleet = run_trace(coord, &env, LeasePolicy::FairShare, &requests)?;
    println!("  {}", fleet.summary());

    // Leg 2 — equivalence: a FIFO sub-trace per-worker vs cohorts.
    let sub: Vec<JobRequest> = requests
        .iter()
        .take(EQUIV_JOBS)
        .map(|r| {
            let mut r = r.clone();
            r.train.cohort_threshold = 0;
            r
        })
        .collect();
    let per_worker = run_trace(coord, &env, LeasePolicy::Fifo, &sub)?;
    let sub_cohort: Vec<JobRequest> = requests.iter().take(EQUIV_JOBS).cloned().collect();
    let cohort = run_trace(coord, &env, LeasePolicy::Fifo, &sub_cohort)?;

    let reduction = per_worker.events_executed as f64 / cohort.events_executed.max(1) as f64;
    let cost_drift = if per_worker.compute_cost > 0.0 {
        (cohort.compute_cost - per_worker.compute_cost).abs() / per_worker.compute_cost
    } else {
        0.0
    };

    let leg = |name: &str, r: &FleetReport| -> Vec<String> {
        vec![
            name.to_string(),
            format!("{}", r.jobs.len()),
            format!("{}", r.events_executed),
            format!("{:.0}", r.events_per_wall_second()),
            format!("{}", total_steps(r)),
            format!("{:.0}s", r.makespan),
            format!("${:.2}", r.compute_cost),
        ]
    };
    print_table(
        &["leg", "jobs", "events", "events/s", "steps", "makespan", "compute"],
        &[
            leg("fleet (cohort)", &fleet),
            leg("equiv per-worker", &per_worker),
            leg("equiv cohort", &cohort),
        ],
    );
    println!(
        "  cohort aggregation: {reduction:.1}x fewer events, steps {} -> {}, \
         compute cost drift {:.2}%",
        total_steps(&per_worker),
        total_steps(&cohort),
        cost_drift * 100.0
    );

    let doc = Json::obj(vec![
        ("jobs", Json::num(jobs as f64)),
        ("regions", Json::num(regions as f64)),
        ("units_per_region", Json::num(UNITS_PER_REGION as f64)),
        ("cohort_threshold", Json::num(COHORT_THRESHOLD as f64)),
        ("mean_interarrival_s", Json::num(mean)),
        ("fleet", fleet.to_json()),
        ("equiv_jobs", Json::num(EQUIV_JOBS as f64)),
        ("per_worker_events", Json::num(per_worker.events_executed as f64)),
        ("cohort_events", Json::num(cohort.events_executed as f64)),
        ("event_reduction", Json::num(reduction)),
        ("per_worker_steps", Json::num(total_steps(&per_worker) as f64)),
        ("cohort_steps", Json::num(total_steps(&cohort) as f64)),
        ("compute_cost_drift", Json::num(cost_drift)),
    ]);
    save_result("fleetscale", &doc);
    Ok(())
}

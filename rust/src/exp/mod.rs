//! Experiment drivers — one per table/figure in the paper's evaluation.
//!
//! Each driver regenerates the rows/series the paper reports and saves a
//! JSON dump under `results/`. They are shared by the CLI
//! (`cloudless exp --id fig8`) and the bench targets (`cargo bench`).
//!
//! | id     | paper artifact                         | module      |
//! |--------|----------------------------------------|-------------|
//! | table1 | device speed quantification            | motivation  |
//! | fig2   | load-imbalance motivation              | motivation  |
//! | fig3   | WAN share motivation (ResNet18)        | motivation  |
//! | fig7   | usability: cloudless vs trivial PS     | usability   |
//! | table4 | elastic resourcing plans               | scheduling  |
//! | fig8   | time/cost with vs without elastic      | scheduling  |
//! | fig9   | accuracy with vs without elastic       | scheduling  |
//! | fig10  | sync strategies (ASGD/GA/AMA) time+acc | sync_exp    |
//! | fig11  | + SMA on self-hosted link              | sync_exp    |
//!
//! Beyond the paper: `topology` compares the engine's N-cloud sync
//! topologies (ring / hierarchical / bandwidth-tree) on a 4-cloud WAN
//! (module `topology_exp`); `elastic` pits the static plan against the
//! live re-scheduling control loop under injected resource churn and WAN
//! fluctuation (module `elastic_exp`; `scheduling` aliases `table4`);
//! `multijob` runs a Poisson trace of concurrent jobs over one
//! shared inventory, comparing FIFO vs fair-share vs cost-aware leasing
//! (module `multijob_exp`); `dataplane` compares the three
//! data/compute placement modes — plus a replica-seeded `joint:r2` run —
//! on a 70%-skewed dataset catalog (module `dataplane_exp`); and
//! `fleetscale` benchmarks the simulator itself — hundreds of jobs on a
//! 16-region GPU fleet, reporting events executed/second and the
//! per-worker vs cohort-aggregation equivalence (module
//! `fleetscale_exp`); and `federated` runs a 100k-client edge-cohort
//! tier below the 4 clouds, comparing full vs sampled participation
//! under dropout churn (module `federated_exp`); and `wanopt` pits the
//! net-layer optimizations — priority lanes, controller-picked per-link
//! compression, and 2-hop relay routes — against the static-FIFO fabric
//! under a mid-run link collapse (module `wanopt_exp`); and `spot` pits
//! spot-aware placement — discounted price traces, expected-preemption
//! planning, and revocation recovery — against the on-demand-only
//! baseline (module `spot_exp`). The full id → figure/config/bench
//! mapping lives in docs/EXPERIMENTS.md.

pub mod ablations;
pub mod dataplane_exp;
pub mod elastic_exp;
pub mod federated_exp;
pub mod fleetscale_exp;
pub mod motivation;
pub mod multijob_exp;
pub mod scheduling;
pub mod spot_exp;
pub mod sync_exp;
pub mod topology_exp;
pub mod usability;
pub mod wanopt_exp;

use std::path::PathBuf;

use crate::cloud::devices::Device;
use crate::cloud::CloudEnv;
use crate::net::LinkSpec;
use crate::util::json::Json;

/// The paper's WAN profile at a different nominal bandwidth.
pub(crate) fn wan_at(mbps: f64) -> LinkSpec {
    LinkSpec { bandwidth_bps: mbps * 1e6, ..LinkSpec::wan_100mbps() }
}

/// The canonical 4-cloud heterogeneous testbed shared by the topology,
/// elastic, and multijob experiments: Shanghai is the best-connected
/// region, the Beijing–Guangzhou long haul the thinnest (see
/// [`hetero_overrides`]); `n_train` samples split evenly, remainder to
/// Guangzhou.
pub(crate) fn four_cloud_env(n_train: usize) -> CloudEnv {
    let per = n_train / 4;
    CloudEnv::multi_region(vec![
        ("Shanghai", Device::CascadeLake, 12, per),
        ("Chongqing", Device::Skylake, 12, per),
        ("Beijing", Device::Skylake, 12, per),
        ("Guangzhou", Device::IceLake, 12, n_train - 3 * per),
    ])
}

/// The testbed's link overrides: fat 300 Mbps pipes to/from the Shanghai
/// hub, a congested 40 Mbps Beijing↔Guangzhou long haul.
pub(crate) fn hetero_overrides() -> Vec<(usize, usize, LinkSpec)> {
    let mut ov = Vec::new();
    for r in 1..4usize {
        ov.push((0, r, wan_at(300.0)));
        ov.push((r, 0, wan_at(300.0)));
    }
    ov.push((2, 3, wan_at(40.0)));
    ov.push((3, 2, wan_at(40.0)));
    ov
}

/// Experiment scale: quick (CI-sized) or full (paper-sized epochs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn from_flag(full: bool) -> Scale {
        if full {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Epochs per model at this scale. Full matches the paper's Table III
    /// settings (10 / 50 / 20); quick keeps curves meaningful within the
    /// 1-core CPU budget.
    pub fn epochs(&self, model: &str) -> usize {
        match (self, model) {
            (Scale::Full, "lenet") => 10,
            (Scale::Full, "resnet") => 50,
            (Scale::Full, "deepfm") => 20,
            (Scale::Full, _) => 10,
            (Scale::Quick, "lenet") => 8,
            (Scale::Quick, "resnet") => 8,
            (Scale::Quick, "deepfm") => 8,
            (Scale::Quick, _) => 4,
        }
    }

    /// The paper's three evaluation models.
    pub fn models(&self) -> &'static [&'static str] {
        &["lenet", "resnet", "deepfm"]
    }
}

/// Where experiment JSON dumps land (override: CLOUDLESS_RESULTS).
pub fn results_dir() -> PathBuf {
    std::env::var("CLOUDLESS_RESULTS").map(PathBuf::from).unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results")
    })
}

/// Persist an experiment result document.
pub fn save_result(name: &str, j: &Json) {
    let dir = results_dir();
    if std::fs::create_dir_all(&dir).is_ok() {
        let path = dir.join(format!("{name}.json"));
        if let Err(e) = std::fs::write(&path, j.to_string_pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("  [saved {}]", path.display());
        }
    }
}

/// Render an aligned text table.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("  {}", s.trim_end());
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_epochs() {
        assert_eq!(Scale::Full.epochs("resnet"), 50);
        assert_eq!(Scale::Quick.epochs("resnet"), 8);
        assert_eq!(Scale::Quick.models().len(), 3);
    }

    #[test]
    fn table_rendering_does_not_panic() {
        print_table(&["a", "bb"], &[vec!["xxx".into(), "y".into()]]);
    }
}

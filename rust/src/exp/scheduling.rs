//! TABLE IV + Fig 8 + Fig 9 — elastic scheduling evaluation.
//!
//! Three cases (data ratio x device mix, from the paper's TABLE IV), each
//! run with the greedy baseline plan (all 24 cores) and the elastic plan
//! from Algorithm 1. Fig 8 reports the time decomposition (execution vs
//! waiting) and monetary cost; Fig 9 the accuracy convergence. One run
//! per (case, model, plan) feeds both figures.

use crate::cloud::devices::Device;
use crate::cloud::{CloudEnv, Region};
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::sync::SyncConfig;
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

/// The paper's three scheduling cases. Data counts keep the published
/// ratios; absolute sizes scale to the model's dataset.
pub struct Case {
    pub id: usize,
    pub label: &'static str,
    pub cq_device: Device,
    pub ratio: (usize, usize),
    /// Expected elastic plan (SH:CQ units) per the paper's TABLE IV.
    pub paper_plan: (u32, u32),
}

pub const CASES: [Case; 3] = [
    Case { id: 1, label: "1:1 Cas/Sky", cq_device: Device::Skylake, ratio: (1, 1), paper_plan: (12, 8) },
    Case { id: 2, label: "2:1 Cas/Cas", cq_device: Device::CascadeLake, ratio: (2, 1), paper_plan: (12, 6) },
    Case { id: 3, label: "2:1 Cas/Sky", cq_device: Device::Skylake, ratio: (2, 1), paper_plan: (12, 4) },
];

pub fn env_for(case: &Case, n_train: usize) -> CloudEnv {
    // Keep the region data counts in the case's EXACT ratio (the paper's
    // Table IV plans are ratio-determined; integer leftovers from
    // `n_train` would otherwise tip Algorithm 1's ceiling by one core).
    let total = case.ratio.0 + case.ratio.1;
    let unit = (n_train / total).max(1);
    let sh = unit * case.ratio.0;
    let cq = unit * case.ratio.1;
    CloudEnv::new(vec![
        Region::new(0, "Shanghai", vec![(Device::CascadeLake, 12)], sh),
        Region::new(1, "Chongqing", vec![(case.cq_device, 12)], cq),
    ])
}

/// TABLE IV — print the elastic plans next to the paper's.
pub fn table4(coord: &Coordinator) -> Json {
    println!("TABLE IV: resourcing plans of elastic scheduling");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for case in &CASES {
        let env = env_for(case, 4096);
        let plan = coord.plan(&env);
        let (sh, cq) = (plan.allocations[0].total_units(), plan.allocations[1].total_units());
        rows.push(vec![
            format!("{}", case.id),
            case.label.to_string(),
            "12:12".into(),
            format!("{sh}:{cq}"),
            format!("{}:{}", case.paper_plan.0, case.paper_plan.1),
        ]);
        out.push(Json::obj(vec![
            ("case", Json::num(case.id as f64)),
            ("plan_sh", Json::num(sh as f64)),
            ("plan_cq", Json::num(cq as f64)),
            ("paper_sh", Json::num(case.paper_plan.0 as f64)),
            ("paper_cq", Json::num(case.paper_plan.1 as f64)),
        ]));
    }
    print_table(&["case", "setting", "baseline", "plan", "paper plan"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("table4", &doc);
    doc
}

struct PairResult {
    case_id: usize,
    model: String,
    greedy: TrainReport,
    elastic: TrainReport,
}

fn run_pairs(coord: &Coordinator, scale: Scale, with_eval: bool) -> Vec<PairResult> {
    let mut results = Vec::new();
    for model in scale.models() {
        let (n_train, n_eval) = crate::data::default_sizes(model);
        for case in &CASES {
            let env = env_for(case, n_train);
            let plan = coord.plan(&env);
            let mut pair = Vec::new();
            for (label, alloc) in
                [("greedy", env.greedy_plan()), ("elastic", plan.allocations.clone())]
            {
                let mut cfg = TrainConfig::new(model);
                cfg.epochs = scale.epochs(model);
                cfg.n_train = n_train;
                cfg.n_eval = n_eval;
                // ASGD-GA f8 keeps the WAN out of the bottleneck so the
                // experiment isolates *scheduling* effects (the paper's
                // sync-strategy comparison is Fig 10's job).
                cfg.sync = SyncConfig::new(crate::sync::Strategy::AsgdGa, 8);
                cfg.skip_eval = !with_eval;
                let report =
                    crate::train::run_geo_training(coord.runtime(), &env, alloc, cfg)
                        .unwrap_or_else(|e| panic!("{model} case {} {label}: {e}", case.id));
                pair.push(report);
            }
            let elastic = pair.pop().unwrap();
            let greedy = pair.pop().unwrap();
            results.push(PairResult { case_id: case.id, model: model.to_string(), greedy, elastic });
        }
    }
    results
}

/// Fig 8 — training time decomposition + cost, with vs without elastic
/// scheduling. Fig 9 — accuracy convergence for the same runs. Returns
/// (and saves) both documents.
pub fn fig8_fig9(coord: &Coordinator, scale: Scale, with_eval: bool) -> Json {
    println!("Fig 8 (+Fig 9): elastic scheduling vs greedy baseline");
    let pairs = run_pairs(coord, scale, with_eval);

    let mut rows = Vec::new();
    let mut out = Vec::new();
    for p in &pairs {
        let wait_red = if p.greedy.total_waiting() > 0.0 {
            1.0 - p.elastic.total_waiting() / p.greedy.total_waiting()
        } else {
            0.0
        };
        // The paper's "training cost" is instance-hours; compare the
        // compute component (our scaled-down virtual times inflate the
        // relative WAN-traffic share far beyond the paper's regime).
        let cost_red = 1.0 - p.elastic.compute_cost / p.greedy.compute_cost;
        rows.push(vec![
            p.model.clone(),
            format!("case{}", p.case_id),
            format!("{:.0}s/{:.0}s", p.greedy.total_time, p.elastic.total_time),
            format!("{:.0}s/{:.0}s", p.greedy.total_waiting(), p.elastic.total_waiting()),
            format!("{:.1}%", wait_red * 100.0),
            format!("${:.4}/${:.4}", p.greedy.compute_cost, p.elastic.compute_cost),
            format!("{:.1}%", cost_red * 100.0),
        ]);
        let mut fields = vec![
            ("model", Json::str(&p.model)),
            ("case", Json::num(p.case_id as f64)),
            ("greedy_time", Json::num(p.greedy.total_time)),
            ("elastic_time", Json::num(p.elastic.total_time)),
            ("greedy_waiting", Json::num(p.greedy.total_waiting())),
            ("elastic_waiting", Json::num(p.elastic.total_waiting())),
            ("waiting_reduction", Json::num(wait_red)),
            ("greedy_cost", Json::num(p.greedy.compute_cost)),
            ("elastic_cost", Json::num(p.elastic.compute_cost)),
            ("greedy_total_cost", Json::num(p.greedy.cost)),
            ("elastic_total_cost", Json::num(p.elastic.cost)),
            ("cost_reduction", Json::num(cost_red)),
        ];
        if with_eval {
            fields.push(("greedy_final_acc", Json::num(p.greedy.final_accuracy)));
            fields.push(("elastic_final_acc", Json::num(p.elastic.final_accuracy)));
            fields.push((
                "greedy_curve",
                Json::arr(p.greedy.curve.iter().map(|e| {
                    Json::obj(vec![
                        ("epoch", Json::num(e.epoch as f64)),
                        ("acc", Json::num(e.accuracy)),
                    ])
                })),
            ));
            fields.push((
                "elastic_curve",
                Json::arr(p.elastic.curve.iter().map(|e| {
                    Json::obj(vec![
                        ("epoch", Json::num(e.epoch as f64)),
                        ("acc", Json::num(e.accuracy)),
                    ])
                })),
            ));
        }
        out.push(Json::obj(fields));
    }
    print_table(
        &["model", "case", "time g/e", "wait g/e", "wait red.", "cost g/e", "cost red."],
        &rows,
    );
    println!("  (paper: waiting -46..95% lenet/resnet, -6.8..26% deepfm; cost -9.2..24%)");

    if with_eval {
        let acc_rows: Vec<Vec<String>> = pairs
            .iter()
            .map(|p| {
                vec![
                    p.model.clone(),
                    format!("case{}", p.case_id),
                    format!("{:.4}", p.greedy.final_accuracy),
                    format!("{:.4}", p.elastic.final_accuracy),
                ]
            })
            .collect();
        println!("Fig 9: accuracy with vs without elastic scheduling");
        print_table(&["model", "case", "greedy acc", "elastic acc"], &acc_rows);
    }

    let doc = Json::obj(vec![("pairs", Json::arr(out))]);
    save_result(if with_eval { "fig8_fig9" } else { "fig8" }, &doc);
    doc
}

//! Fig 7 — usability: Cloudless-Training (2 regions, 12+12 Cascade cores,
//! simple async SGD) vs trivial PS training (1 region, 24 Cascade cores)
//! with equal total resources, for all three models. The claim: similar
//! accuracy/loss convergence, i.e. geo-distribution does not hurt model
//! correctness.

use crate::cloud::devices::Device;
use crate::cloud::{CloudEnv, Region};
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::sync::SyncConfig;
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

fn curve_json(r: &TrainReport) -> Json {
    Json::arr(r.curve.iter().map(|e| {
        Json::obj(vec![
            ("epoch", Json::num(e.epoch as f64)),
            ("t", Json::num(e.t)),
            ("acc", Json::num(e.accuracy)),
            ("loss", Json::num(e.loss)),
        ])
    }))
}

pub fn fig7(coord: &Coordinator, scale: Scale) -> Json {
    println!("Fig 7: usability — Cloudless-Training vs trivial single-cloud PS");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in scale.models() {
        let epochs = scale.epochs(model);
        let (n_train, n_eval) = crate::data::default_sizes(model);

        // Trivial PS: one region with all 24 cores.
        let trivial_env = CloudEnv::new(vec![Region::new(
            0,
            "Shanghai",
            vec![(Device::CascadeLake, 24)],
            n_train,
        )]);
        // Cloudless: two regions, 12 cores each, data 1:1, simple ASGD.
        let cloudless_env = CloudEnv::tencent_two_region(
            Device::CascadeLake,
            n_train / 2,
            n_train - n_train / 2,
        );

        let mut reports: Vec<(String, TrainReport)> = Vec::new();
        for (label, env) in [("trivial", trivial_env), ("cloudless", cloudless_env)] {
            let mut cfg = TrainConfig::new(model);
            cfg.epochs = epochs;
            cfg.n_train = n_train;
            cfg.n_eval = n_eval;
            cfg.sync = SyncConfig::baseline(); // simple asynchronous SGD
            if label == "trivial" {
                // Per-PS worker parity: the 24-core single PS runs the
                // same 4 workers as each 12-core Cloudless partition, so
                // both systems see the same local staleness.
                cfg.worker_cores = 6;
            }
            let report =
                crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
                    .expect("fig7 run failed");
            rows.push(vec![
                model.to_string(),
                label.to_string(),
                format!("{epochs}"),
                format!("{:.4}", report.final_accuracy),
                format!("{:.4}", report.final_loss),
                format!("{:.0}s", report.total_time),
            ]);
            reports.push((label.to_string(), report));
        }
        // Correctness guarantee: final accuracies should be close.
        let accs: Vec<f64> = reports.iter().map(|(_, r)| r.final_accuracy).collect();
        let gap = (accs[0] - accs[1]).abs();
        rows.push(vec![
            model.to_string(),
            "gap".into(),
            String::new(),
            format!("{gap:.4}"),
            String::new(),
            String::new(),
        ]);
        out.push(Json::obj(vec![
            ("model", Json::str(*model)),
            ("trivial_acc", Json::num(accs[0])),
            ("cloudless_acc", Json::num(accs[1])),
            ("acc_gap", Json::num(gap)),
            ("trivial_curve", curve_json(&reports[0].1)),
            ("cloudless_curve", curve_json(&reports[1].1)),
        ]));
    }
    print_table(&["model", "system", "epochs", "final acc", "final loss", "virt time"], &rows);
    println!("  (paper: LeNet 0.9864 vs 0.9851, ResNet 0.79 vs 0.78, DeepFM 0.88 vs 0.84)");
    let doc = Json::obj(vec![("models", Json::arr(out))]);
    save_result("fig7", &doc);
    doc
}

//! Topology comparison — beyond the paper's fixed two-cloud pair.
//!
//! Runs the same AMA job on a 4-cloud environment with a heterogeneous
//! WAN (one well-connected hub region, one slow long-haul pair) under
//! each sync topology the engine plans:
//!
//! - `ring`           — the seed behavior generalized to N clouds;
//! - `hierarchical`   — HiPS-style hub aggregation (GeoMX);
//! - `bandwidth-tree` — greedy max-bandwidth spanning tree.
//!
//! Reported: virtual wall-clock, WAN bytes/time, and final accuracy. A
//! 2-cloud ring row runs first as the seed-parity reference: with two
//! regions the engine's ring plan *is* the seed's pairwise exchange
//! (weight 0.5), so its report values reproduce the pre-engine
//! `run_geo_training`.

use crate::cloud::devices::Device;
use crate::cloud::CloudEnv;
use crate::coordinator::Coordinator;
use crate::engine::TopologyKind;
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::net::LinkSpec;
use crate::sync::{Strategy, SyncConfig};
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

fn run_one(
    coord: &Coordinator,
    env: &CloudEnv,
    scale: Scale,
    topology: TopologyKind,
    overrides: Vec<(usize, usize, LinkSpec)>,
    model: &str,
) -> TrainReport {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = scale.epochs(model).min(6);
    cfg.n_train = n_train;
    cfg.n_eval = n_eval;
    cfg.sync = SyncConfig::new(Strategy::Ama, 8);
    cfg.topology = topology;
    cfg.link_overrides = overrides;
    crate::train::run_geo_training(coord.runtime(), env, env.greedy_plan(), cfg)
        .unwrap_or_else(|e| panic!("topology {}: {e}", topology.name()))
}

/// Compare Ring vs Hierarchical vs BandwidthTree on the 4-cloud WAN.
/// `model` is the experiment workload (`synthetic` runs artifact-free).
pub fn topology_compare(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Topology comparison: 4-cloud AMA f8 on a heterogeneous WAN ({model})");
    let (n_train, _) = crate::data::default_sizes(model);
    let mut rows = Vec::new();
    let mut out = Vec::new();

    // Seed-parity reference: 2-cloud ring = the paper's pairwise exchange.
    let env2 = CloudEnv::tencent_two_region(Device::Skylake, n_train / 2, n_train - n_train / 2);
    let r2 = run_one(coord, &env2, scale, TopologyKind::Ring, Vec::new(), model);
    rows.push(vec![
        "ring @2 (seed parity)".to_string(),
        format!("{:.0}s", r2.total_time),
        format!("{:.0}s", r2.total_wan_time()),
        format!("{:.1}MB", r2.wan_bytes as f64 / 1e6),
        format!("{:.4}", r2.final_accuracy),
    ]);
    out.push(Json::obj(vec![
        ("topology", Json::str("ring@2")),
        ("clouds", Json::num(2.0)),
        ("time", Json::num(r2.total_time)),
        ("wan_time", Json::num(r2.total_wan_time())),
        ("wan_bytes", Json::num(r2.wan_bytes as f64)),
        ("final_acc", Json::num(r2.final_accuracy)),
    ]));

    let env4 = four_cloud_env(n_train);
    for kind in [TopologyKind::Ring, TopologyKind::Hierarchical, TopologyKind::BandwidthTree] {
        let r = run_one(coord, &env4, scale, kind, hetero_overrides(), model);
        rows.push(vec![
            format!("{} @4", kind.name()),
            format!("{:.0}s", r.total_time),
            format!("{:.0}s", r.total_wan_time()),
            format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
            format!("{:.4}", r.final_accuracy),
        ]);
        out.push(Json::obj(vec![
            ("topology", Json::str(kind.name())),
            ("clouds", Json::num(4.0)),
            ("time", Json::num(r.total_time)),
            ("wan_time", Json::num(r.total_wan_time())),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
            ("wan_transfers", Json::num(r.wan_transfers as f64)),
            ("final_acc", Json::num(r.final_accuracy)),
        ]));
    }
    print_table(&["topology", "time", "WAN time", "WAN bytes", "final acc"], &rows);
    println!("  (hierarchical/tree avoid the 40 Mbps long haul the 4-ring must cross;");
    println!("   the hub fan-out trades per-sync bytes for fewer WAN-bound hops)");
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("topology_compare", &doc);
    doc
}

//! Ablations beyond the paper — design-choice sensitivity studies called
//! out in DESIGN.md:
//!
//! - sync-frequency sweep (1..32): where does the comm-relief saturate?
//! - WAN fluctuation severity: how noisy links distort training time;
//! - topology: ring vs pairwise exchange at 3 regions;
//! - worker granularity (cores per worker function): staleness vs
//!   parallelism;
//! - failure injection: drop-prob sensitivity (retry path).

use crate::cloud::devices::Device;
use crate::cloud::{CloudEnv, Region};
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::net::LinkSpec;
use crate::sync::{Strategy, SyncConfig};
use crate::train::TrainConfig;
use crate::util::json::Json;

fn base_cfg(model: &str, scale: Scale) -> (CloudEnv, TrainConfig) {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let env = CloudEnv::tencent_two_region(Device::Skylake, n_train / 2, n_train - n_train / 2);
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = scale.epochs(model).min(6);
    cfg.n_train = n_train;
    cfg.n_eval = n_eval;
    cfg.skip_eval = true;
    (env, cfg)
}

/// Sync-frequency sweep: time + WAN bytes vs frequency (ASGD-GA).
pub fn freq_sweep(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Ablation: sync-frequency sweep ({model}, ASGD-GA)");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for freq in [1u32, 2, 4, 8, 16, 32] {
        let (env, mut cfg) = base_cfg(model, scale);
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, freq);
        let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
            .expect("freq sweep run");
        rows.push(vec![
            format!("{freq}"),
            format!("{:.0}s", r.total_time),
            format!("{:.0}s", r.total_comm_wait()),
            format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
            format!("{}", r.wan_transfers),
        ]);
        out.push(Json::obj(vec![
            ("freq", Json::num(freq as f64)),
            ("time", Json::num(r.total_time)),
            ("comm_wait", Json::num(r.total_comm_wait())),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
        ]));
    }
    print_table(&["freq", "time", "comm wait", "WAN", "transfers"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("ablation_freq_sweep", &doc);
    doc
}

/// WAN fluctuation severity sweep (ASGD-GA f4).
pub fn fluctuation_sweep(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Ablation: WAN fluctuation severity ({model}, ASGD-GA f4)");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for sigma in [0.0, 0.1, 0.25, 0.5, 0.8] {
        let (env, mut cfg) = base_cfg(model, scale);
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
        cfg.link = LinkSpec { fluct_sigma: sigma, ..LinkSpec::wan_100mbps() };
        let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
            .expect("fluct sweep run");
        rows.push(vec![
            format!("{sigma:.2}"),
            format!("{:.1}s", r.total_time),
            format!("{:.1}s", r.total_comm_wait()),
        ]);
        out.push(Json::obj(vec![
            ("sigma", Json::num(sigma)),
            ("time", Json::num(r.total_time)),
            ("comm_wait", Json::num(r.total_comm_wait())),
        ]));
    }
    print_table(&["fluct sigma", "time", "comm wait"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("ablation_fluctuation", &doc);
    doc
}

/// Ring topology at 3 regions (beyond the paper's 2-region evaluation).
pub fn three_region_ring(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Ablation: 3-region ring topology ({model}, ASGD-GA f4)");
    let n = 4096;
    let env = CloudEnv::new(vec![
        Region::new(0, "Shanghai", vec![(Device::CascadeLake, 12)], n / 3),
        Region::new(1, "Chongqing", vec![(Device::Skylake, 12)], n / 3),
        Region::new(2, "Beijing", vec![(Device::Skylake, 12)], n - 2 * (n / 3)),
    ]);
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = scale.epochs(model);
    cfg.n_train = n;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
        .expect("3-region run");
    let rows = vec![vec![
        "3-region ring".to_string(),
        format!("{:.0}s", r.total_time),
        format!("{:.4}", r.final_accuracy),
        format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
    ]];
    print_table(&["topology", "time", "final acc", "WAN"], &rows);
    let doc = Json::obj(vec![
        ("time", Json::num(r.total_time)),
        ("final_acc", Json::num(r.final_accuracy)),
        ("wan_bytes", Json::num(r.wan_bytes as f64)),
    ]);
    save_result("ablation_three_region", &doc);
    doc
}

/// Worker granularity: cores per worker function.
pub fn worker_granularity(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Ablation: worker granularity ({model}, cores per worker fn)");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for wc in [1u32, 2, 3, 6, 12] {
        let (env, mut cfg) = base_cfg(model, scale);
        cfg.skip_eval = false;
        cfg.worker_cores = wc;
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
        let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
            .expect("granularity run");
        let stal = crate::util::mean(
            &r.partitions.iter().map(|p| p.mean_staleness).collect::<Vec<_>>(),
        );
        rows.push(vec![
            format!("{wc}"),
            format!("{:.0}s", r.total_time),
            format!("{:.2}", stal),
            format!("{:.4}", r.final_accuracy),
        ]);
        out.push(Json::obj(vec![
            ("worker_cores", Json::num(wc as f64)),
            ("time", Json::num(r.total_time)),
            ("staleness", Json::num(stal)),
            ("final_acc", Json::num(r.final_accuracy)),
        ]));
    }
    print_table(&["cores/worker", "time", "staleness", "final acc"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("ablation_worker_granularity", &doc);
    doc
}

/// Failure injection: transfer drop probability (retry path exercised).
pub fn drop_sensitivity(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Ablation: WAN drop probability ({model}, ASGD-GA f4)");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for drop in [0.0, 0.05, 0.2] {
        let (env, mut cfg) = base_cfg(model, scale);
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
        cfg.link = LinkSpec { drop_prob: drop, ..LinkSpec::wan_100mbps() };
        let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
            .expect("drop run");
        rows.push(vec![
            format!("{drop:.2}"),
            format!("{:.0}s", r.total_time),
            format!("{}", r.wan_transfers),
        ]);
        out.push(Json::obj(vec![
            ("drop_prob", Json::num(drop)),
            ("time", Json::num(r.total_time)),
            ("transfers", Json::num(r.wan_transfers as f64)),
        ]));
    }
    print_table(&["drop prob", "time", "transfers"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("ablation_drop", &doc);
    doc
}

/// Compression vs frequency reduction (extension; the paper's §II.C
/// surveys compression but adopts frequency reduction — here we compare
/// both on the comm-heavy DeepFM workload).
pub fn compression_vs_frequency(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    use crate::sync::Compression;
    println!("Ablation: compression vs frequency reduction ({model})");
    let settings: Vec<(&str, SyncConfig)> = vec![
        ("ASGD f1 (baseline)", SyncConfig::baseline()),
        ("ASGD-GA f8", SyncConfig::new(Strategy::AsgdGa, 8)),
        ("ASGD f1 + top-10%", SyncConfig::baseline()
            .with_compression(Compression::TopK { ratio: 0.10 })),
        ("ASGD f1 + q8", SyncConfig::baseline().with_compression(Compression::Q8)),
        ("GA f8 + top-10%", SyncConfig::new(Strategy::AsgdGa, 8)
            .with_compression(Compression::TopK { ratio: 0.10 })),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (label, sync) in settings {
        let (n_train, n_eval) = crate::data::default_sizes(model);
        let env = CloudEnv::tencent_two_region(Device::Skylake, n_train / 2, n_train / 2);
        let mut cfg = TrainConfig::new(model);
        cfg.epochs = scale.epochs(model);
        cfg.n_train = n_train;
        cfg.n_eval = n_eval;
        cfg.sync = sync;
        let r = crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
            .expect("compression run");
        rows.push(vec![
            label.to_string(),
            format!("{:.0}s", r.total_time),
            format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
            format!("{:.0}s", r.total_wan_time()),
            format!("{:.4}", r.final_accuracy),
        ]);
        out.push(Json::obj(vec![
            ("setting", Json::str(label)),
            ("time", Json::num(r.total_time)),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
            ("wan_time", Json::num(r.total_wan_time())),
            ("final_acc", Json::num(r.final_accuracy)),
        ]));
    }
    print_table(&["setting", "time", "WAN", "comm time", "final acc"], &rows);
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("ablation_compression", &doc);
    doc
}

/// Run every ablation on `model` (the CLI's `--model`; the bench targets
/// keep the historical lenet/deepfm defaults).
pub fn all(coord: &Coordinator, scale: Scale, model: &str) {
    freq_sweep(coord, scale, model);
    fluctuation_sweep(coord, scale, model);
    three_region_ring(coord, scale, model);
    worker_granularity(coord, scale, model);
    drop_sensitivity(coord, scale, model);
    // The comm-heavy deepfm is the interesting compression workload; keep
    // it unless the caller pinned an artifact-free model.
    let comp_model = if model == "synthetic" { "synthetic" } else { "deepfm" };
    compression_vs_frequency(coord, scale, comp_model);
}

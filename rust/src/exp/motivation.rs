//! Motivation experiments: TABLE I, Fig 2, Fig 3.

use crate::cloud::devices::{Device, BASELINE_ITER_S};
use crate::cloud::{CloudEnv, Region};
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::sync::{Strategy, SyncConfig};
use crate::train::TrainConfig;
use crate::util::json::Json;

/// TABLE I — training speed quantification of cloud resources.
/// Regenerates every row (TN / IN / IN-over-TN) from the device catalog
/// and prints the paper's published values alongside.
pub fn table1() -> Json {
    println!("TABLE I: Training speed quantification of cloud resources");
    let paper: &[(&str, f64, f64, f64)] = &[
        ("IceLake", 1.000, 1.000, 1.000),
        ("CascadeLake", 0.938, 0.666, 0.710),
        ("Skylake", 1.167, 0.973, 0.834),
        ("T4", 57.854, 59.629, 1.031),
        ("V100", 139.010, 154.042, 1.108),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (d, (pname, ptn, pin, pratio)) in Device::ALL.iter().zip(paper) {
        let info = d.info();
        rows.push(vec![
            info.name.to_string(),
            format!("{}", info.measured_cores),
            format!("{:.3}", info.tflops),
            format!("{:.3} ({ptn:.3})", d.tn()),
            format!("{:.3}s", info.iter_time_s),
            format!("{:.3} ({pin:.3})", d.in_norm()),
            format!("{:.3} ({pratio:.3})", d.in_tn_ratio()),
        ]);
        out.push(Json::obj(vec![
            ("device", Json::str(*pname)),
            ("tn", Json::num(d.tn())),
            ("in", Json::num(d.in_norm())),
            ("in_tn", Json::num(d.in_tn_ratio())),
            ("paper_tn", Json::num(*ptn)),
            ("paper_in", Json::num(*pin)),
            ("paper_in_tn", Json::num(*pratio)),
        ]));
    }
    print_table(
        &["device", "cores", "TFLOPS", "TN (paper)", "iter", "IN (paper)", "IN/TN (paper)"],
        &rows,
    );
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("table1", &doc);
    doc
}

/// Fig 2 — the load-imbalance motivation: training LeNet under various
/// heterogeneous allocations and uneven data distributions; the waiting
/// share grows with the mismatch.
pub fn fig2(coord: &Coordinator, scale: Scale) -> Json {
    println!("Fig 2: time proportion of training LeNet under heterogeneous allocations");
    let cases: &[(&str, Device, usize, usize)] = &[
        // label, CQ device, SH data, CQ data
        ("even data, same CPUs", Device::CascadeLake, 2048, 2048),
        ("2:1 data, same CPUs", Device::CascadeLake, 2731, 1365),
        ("even data, Cas/Sky", Device::Skylake, 2048, 2048),
        ("2:1 data, Cas/Sky", Device::Skylake, 2731, 1365),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (label, cq_dev, sh, cq) in cases {
        let env = CloudEnv::tencent_two_region(*cq_dev, *sh, *cq);
        let mut cfg = TrainConfig::new("lenet");
        cfg.epochs = scale.epochs("lenet").min(6);
        cfg.n_train = sh + cq;
        cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
        cfg.skip_eval = true;
        let report = crate::train::run_geo_training(
            coord.runtime(),
            &env,
            env.greedy_plan(),
            cfg,
        )
        .expect("fig2 run failed");
        for p in &report.partitions {
            let share = if report.total_time > 0.0 { p.waiting / report.total_time } else { 0.0 };
            rows.push(vec![
                label.to_string(),
                p.region.clone(),
                format!("{:.1}s", report.total_time),
                format!("{:.1}s", p.waiting),
                format!("{:.1}%", share * 100.0),
            ]);
            out.push(Json::obj(vec![
                ("case", Json::str(*label)),
                ("region", Json::str(&p.region)),
                ("total_s", Json::num(report.total_time)),
                ("waiting_s", Json::num(p.waiting)),
                ("waiting_share", Json::num(share)),
            ]));
        }
    }
    print_table(&["case", "region", "total", "waiting", "waiting %"], &rows);
    println!("  (paper: mismatched cases waste up to ~25% of one region's resources)");
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("fig2", &doc);
    doc
}

/// Fig 3 — WAN communication share of training ResNet18 (48 MB model) at
/// 100 Mbps, CPU vs GPU. Analytic: per-iteration compute time from the
/// device catalog vs payload serialization on the link model.
///
/// Calibration: the CPU row divides the catalog's 2-core iteration time
/// across 12 cores with a 0.45 parallel-scaling efficiency (PS-worker
/// scaling is sub-linear); the GPU row is the catalog's T4 measurement.
pub fn fig3() -> Json {
    println!("Fig 3: WAN communication share training ResNet18 (48MB) @ 100 Mbps");
    let payload_bytes = 48_000_000.0f64;
    let t_comm = payload_bytes * 8.0 / 100e6 + 0.015;

    let cpu_iter = Device::CascadeLake.info().iter_time_s * (2.0 / 12.0) / 0.45;
    let gpu_iter = Device::T4.info().iter_time_s;
    let rows_src: &[(&str, f64, f64)] = &[
        ("CPU (Cascade, 12 cores)", cpu_iter, 0.649),
        ("GPU (T4)", gpu_iter, 0.984),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for (label, t_compute, paper_share) in rows_src {
        let share = t_comm / (t_comm + t_compute);
        rows.push(vec![
            label.to_string(),
            format!("{:.3}s", t_compute),
            format!("{:.3}s", t_comm),
            format!("{:.1}%", share * 100.0),
            format!("{:.1}%", paper_share * 100.0),
        ]);
        out.push(Json::obj(vec![
            ("config", Json::str(*label)),
            ("t_compute_s", Json::num(*t_compute)),
            ("t_comm_s", Json::num(t_comm)),
            ("comm_share", Json::num(share)),
            ("paper_comm_share", Json::num(*paper_share)),
        ]));
    }
    print_table(&["config", "compute/iter", "WAN/sync", "comm share", "paper"], &rows);
    let _ = BASELINE_ITER_S; // catalog anchor, referenced for the record
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("fig3", &doc);
    doc
}

/// Single-region helper used by several experiments.
pub fn single_region_env(device: Device, units: u32, data: usize) -> CloudEnv {
    CloudEnv::new(vec![Region::new(0, "Shanghai", vec![(device, units)], data)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_matches_paper_shape() {
        let doc = fig3();
        let rows = doc.get("rows").as_arr().unwrap();
        let cpu = rows[0].get("comm_share").as_f64().unwrap();
        let gpu = rows[1].get("comm_share").as_f64().unwrap();
        // Paper: 64.9% (CPU), 98.4% (GPU).
        assert!((cpu - 0.649).abs() < 0.05, "cpu share {cpu}");
        assert!((gpu - 0.984).abs() < 0.01, "gpu share {gpu}");
        assert!(gpu > cpu);
    }

    #[test]
    fn table1_reproduces_all_rows() {
        let doc = table1();
        for row in doc.get("rows").as_arr().unwrap() {
            let tn = row.get("tn").as_f64().unwrap();
            let ptn = row.get("paper_tn").as_f64().unwrap();
            assert!((tn - ptn).abs() / ptn < 0.01, "{row:?}");
            let inn = row.get("in").as_f64().unwrap();
            let pin = row.get("paper_in").as_f64().unwrap();
            assert!((inn - pin).abs() / pin < 0.01, "{row:?}");
        }
    }
}

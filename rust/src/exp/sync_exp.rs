//! Fig 10 + Fig 11 — synchronization strategy evaluation.
//!
//! Fig 10: baseline ASGD (freq 1) vs ASGD-GA and AMA at sync frequency
//! {4, 8}, on the Tencent 100 Mbps WAN, for all three models: training
//! time, WAN communication time, and accuracy convergence.
//!
//! Fig 11: adds SMA (synchronous model averaging) on the self-hosted
//! Beijing–Shanghai link profile (the paper moved SMA off the public
//! cloud for cost reasons): SMA is slowest but most accurate.

use crate::cloud::devices::Device;
use crate::cloud::CloudEnv;
use crate::coordinator::Coordinator;
use crate::exp::{print_table, save_result, Scale};
use crate::net::LinkSpec;
use crate::sync::{Strategy, SyncConfig};
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

fn settings_fig10() -> Vec<(&'static str, SyncConfig)> {
    vec![
        ("ASGD f1", SyncConfig::baseline()),
        ("ASGD-GA f4", SyncConfig::new(Strategy::AsgdGa, 4)),
        ("ASGD-GA f8", SyncConfig::new(Strategy::AsgdGa, 8)),
        ("AMA f4", SyncConfig::new(Strategy::Ama, 4)),
        ("AMA f8", SyncConfig::new(Strategy::Ama, 8)),
    ]
}

fn run_one(
    coord: &Coordinator,
    model: &str,
    scale: Scale,
    sync: SyncConfig,
    link: LinkSpec,
) -> TrainReport {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let env = CloudEnv::tencent_two_region(Device::Skylake, n_train / 2, n_train - n_train / 2);
    let mut cfg = TrainConfig::new(model);
    cfg.epochs = scale.epochs(model);
    cfg.n_train = n_train;
    cfg.n_eval = n_eval;
    cfg.sync = sync;
    cfg.link = link;
    crate::train::run_geo_training(coord.runtime(), &env, env.greedy_plan(), cfg)
        .unwrap_or_else(|e| panic!("{model} {}: {e}", sync.strategy.name()))
}

fn report_fields(label: &str, r: &TrainReport, baseline: &TrainReport) -> (Vec<String>, Json) {
    let speedup = baseline.total_time / r.total_time;
    let comm_red = if baseline.total_wan_time() > 0.0 {
        1.0 - r.total_wan_time() / baseline.total_wan_time()
    } else {
        0.0
    };
    let row = vec![
        r.model.clone(),
        label.to_string(),
        format!("{:.0}s", r.total_time),
        format!("{:.2}x", speedup),
        format!("{:.0}s", r.total_wan_time()),
        format!("{:.1}%", comm_red * 100.0),
        format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
        format!("{:.4}", r.final_accuracy),
    ];
    let json = Json::obj(vec![
        ("model", Json::str(&r.model)),
        ("setting", Json::str(label)),
        ("strategy", Json::str(&r.strategy)),
        ("freq", Json::num(r.sync_freq as f64)),
        ("total_time", Json::num(r.total_time)),
        ("speedup", Json::num(speedup)),
        ("comm_wait", Json::num(r.total_comm_wait())),
        ("wan_time", Json::num(r.total_wan_time())),
        ("comm_reduction", Json::num(comm_red)),
        ("wan_bytes", Json::num(r.wan_bytes as f64)),
        ("final_acc", Json::num(r.final_accuracy)),
        (
            "curve",
            Json::arr(r.curve.iter().map(|e| {
                Json::obj(vec![
                    ("epoch", Json::num(e.epoch as f64)),
                    ("t", Json::num(e.t)),
                    ("acc", Json::num(e.accuracy)),
                ])
            })),
        ),
    ]);
    (row, json)
}

/// Fig 10 — ASGD vs ASGD-GA vs AMA at freq {1, 4, 8}.
pub fn fig10(coord: &Coordinator, scale: Scale) -> Json {
    println!("Fig 10: synchronization strategies on the 100 Mbps WAN");
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for model in scale.models() {
        let mut baseline: Option<TrainReport> = None;
        for (label, sync) in settings_fig10() {
            let r = run_one(coord, model, scale, sync, LinkSpec::wan_100mbps());
            let base = baseline.get_or_insert_with(|| r.clone());
            let (row, json) = report_fields(label, &r, base);
            rows.push(row);
            out.push(json);
        }
    }
    print_table(
        &["model", "setting", "time", "speedup", "comm", "comm red.", "WAN", "final acc"],
        &rows,
    );
    println!("  (paper: speedups up to 1.2x lenet/resnet, 1.7x deepfm;");
    println!("   comm time -48..58% at f4, -57..73% at f8)");
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("fig10", &doc);
    doc
}

/// Fig 11 — adds SMA on the self-hosted link (ResNet, as in the paper).
pub fn fig11(coord: &Coordinator, scale: Scale) -> Json {
    println!("Fig 11: + SMA in the self-hosted environment (ResNet)");
    let model = "resnet";
    let settings: Vec<(&str, SyncConfig)> = vec![
        ("ASGD f1", SyncConfig::baseline()),
        ("ASGD-GA f8", SyncConfig::new(Strategy::AsgdGa, 8)),
        ("AMA f8", SyncConfig::new(Strategy::Ama, 8)),
        ("SMA f8", SyncConfig::new(Strategy::Sma, 8)),
    ];
    let mut rows = Vec::new();
    let mut out = Vec::new();
    let mut baseline: Option<TrainReport> = None;
    for (label, sync) in settings {
        let r = run_one(coord, model, scale, sync, LinkSpec::self_hosted());
        let base = baseline.get_or_insert_with(|| r.clone());
        let (row, json) = report_fields(label, &r, base);
        rows.push(row);
        out.push(json);
    }
    print_table(
        &["model", "setting", "time", "speedup", "comm", "comm red.", "WAN", "final acc"],
        &rows,
    );
    println!("  (paper: SMA slowest (≈baseline time) but best accuracy)");
    let doc = Json::obj(vec![("rows", Json::arr(out))]);
    save_result("fig11", &doc);
    doc
}

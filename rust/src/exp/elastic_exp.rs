//! Elastic re-scheduling under churn — beyond the paper's one-shot plan.
//!
//! A 4-cloud heterogeneous WAN launches on the elastic *initial* plan
//! (Algorithm 1), then mid-run a non-straggler cloud loses 65% of its
//! delivered compute (co-tenancy churn) and the hub's fat WAN links
//! degrade (bandwidth weather). The same churn hits two runs:
//!
//! - **static** — the paper's behavior: the plan never changes, so the
//!   slowed cloud (already cut down by the initial plan) becomes a
//!   massive straggler and every other region burns money waiting;
//! - **elastic** — the `sched::elastic` control loop observes per-cloud
//!   step times and per-link delivered bandwidth, re-runs Optimal
//!   Matching on the *observed* powers, scales the slowed cloud back up
//!   through the FaaS autoscaler (and sheds units elsewhere), and
//!   re-plans the sync topology when the measured WAN diverges.
//!
//! Reported: end-to-end time, post-churn throughput recovery, waiting
//! time, compute cost, and the recorded `TrainReport.replan_events`.

use crate::cloud::CloudEnv;
use crate::coordinator::Coordinator;
use crate::engine::{ChurnEvent, TopologyKind};
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::sched::elastic::ElasticConfig;
use crate::sync::{Strategy, SyncConfig};
use crate::train::{calib, TrainConfig, TrainReport};
use crate::util::json::Json;

/// Rough virtual runtime estimate of the nominal run — places the churn
/// injection at ~30% and sizes the control interval, so the experiment
/// scales with model and epoch count instead of hardcoding seconds.
fn estimate_total_s(cfg: &TrainConfig, env: &CloudEnv, batch_size: usize) -> f64 {
    let base = if cfg.base_step_s > 0.0 {
        cfg.base_step_s
    } else {
        calib::default_base_step_s(&cfg.model)
    };
    // Straggler-bound: the straggler's shard at its full-inventory
    // throughput (steps_total * base / power, workers cancel). With
    // equal shards the straggler is the minimum-power region.
    let shard = cfg.n_train / env.regions.len().max(1);
    let steps = (shard.max(1) as f64 / batch_size.max(1) as f64).ceil() * cfg.epochs as f64;
    let power =
        env.greedy_plan().iter().map(|a| a.power()).fold(f64::INFINITY, f64::min);
    steps * base / power.max(1e-9)
}

struct RunPair {
    static_run: TrainReport,
    elastic_run: TrainReport,
    churn_t: f64,
}

fn run_pair(coord: &Coordinator, scale: Scale, model: &str) -> RunPair {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let env = four_cloud_env(n_train);
    let initial = coord.plan(&env).allocations;
    let batch_size = coord
        .runtime()
        .load_model(model)
        .unwrap_or_else(|e| panic!("loading {model}: {e}"))
        .meta
        .batch_size;

    let mut base = TrainConfig::new(model);
    base.epochs = scale.epochs(model).min(6);
    base.n_train = n_train;
    base.n_eval = n_eval;
    base.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    base.skip_eval = true;
    base.link_overrides = hetero_overrides();
    // Bandwidth-aware topology so the WAN churn has something to re-plan:
    // the initial max-bandwidth tree stars on Shanghai's fat links; after
    // the 0<->2 collapse the re-planned tree routes Beijing around it.
    base.topology = TopologyKind::BandwidthTree;

    let est = estimate_total_s(&base, &env, batch_size).max(1.0);
    let churn_t = (0.3 * est).max(1.0);
    // Mid-run churn: Beijing loses 65% of its compute; the fat Shanghai
    // links collapse to a tenth of their planned bandwidth.
    let churn = vec![
        ChurnEvent::PowerFactor { t: churn_t, region: 2, factor: 0.35 },
        ChurnEvent::LinkBandwidth { t: churn_t, from: 0, to: 2, bps: 30e6 },
        ChurnEvent::LinkBandwidth { t: churn_t, from: 2, to: 0, bps: 30e6 },
    ];

    let mut static_cfg = base.clone();
    static_cfg.churn = churn.clone();
    let static_run =
        crate::train::run_geo_training(coord.runtime(), &env, initial.clone(), static_cfg)
            .unwrap_or_else(|e| panic!("static run: {e}"));

    let mut elastic_cfg = base;
    elastic_cfg.churn = churn;
    elastic_cfg.elastic = ElasticConfig {
        enabled: true,
        interval_s: (est / 20.0).max(0.25),
        ..ElasticConfig::default()
    };
    let elastic_run =
        crate::train::run_geo_training(coord.runtime(), &env, initial, elastic_cfg)
            .unwrap_or_else(|e| panic!("elastic run: {e}"));

    RunPair { static_run, elastic_run, churn_t }
}

fn throughput(r: &TrainReport) -> f64 {
    let steps: u64 = r.partitions.iter().map(|p| p.steps).sum();
    steps as f64 / r.total_time.max(1e-9)
}

/// `exp --id elastic`: static vs elastic plans under injected mid-run
/// resource churn + WAN fluctuation.
pub fn elastic_compare(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("Elastic re-scheduling under churn: 4-cloud WAN, {model}");
    let pair = run_pair(coord, scale, model);
    let (s, e) = (&pair.static_run, &pair.elastic_run);

    let rows = vec![
        vec![
            "static".to_string(),
            format!("{:.0}s", s.total_time),
            format!("{:.2} steps/s", throughput(s)),
            format!("{:.0}s", s.total_waiting()),
            format!("${:.4}", s.compute_cost),
            format!("{}", s.replan_events.len()),
        ],
        vec![
            "elastic".to_string(),
            format!("{:.0}s", e.total_time),
            format!("{:.2} steps/s", throughput(e)),
            format!("{:.0}s", e.total_waiting()),
            format!("${:.4}", e.compute_cost),
            format!("{}", e.replan_events.len()),
        ],
    ];
    print_table(&["plan", "time", "throughput", "waiting", "compute cost", "replans"], &rows);
    let recovery = throughput(e) / throughput(s).max(1e-12);
    println!(
        "  churn at t={:.0}s (Beijing -65% compute, Shanghai links -90% bandwidth)",
        pair.churn_t
    );
    println!("  elastic/static throughput: {recovery:.2}x  (>= 1.0 = recovered)");
    for ev in &e.replan_events {
        println!(
            "  replan @{:.0}s [{}] delta={:.2} straggler={} units={:?} topo={}",
            ev.t, ev.cause, ev.plan_delta, ev.straggler, ev.units, ev.topology_replanned
        );
    }

    let run_json = |r: &TrainReport| {
        Json::obj(vec![
            ("total_time", Json::num(r.total_time)),
            ("throughput", Json::num(throughput(r))),
            ("waiting", Json::num(r.total_waiting())),
            ("compute_cost", Json::num(r.compute_cost)),
            ("replans", Json::num(r.replan_events.len() as f64)),
        ])
    };
    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("churn_t", Json::num(pair.churn_t)),
        ("static", run_json(s)),
        ("elastic", run_json(e)),
        ("throughput_recovery", Json::num(recovery)),
        (
            "replan_events",
            Json::arr(e.replan_events.iter().map(|ev| {
                Json::obj(vec![
                    ("t", Json::num(ev.t)),
                    ("cause", Json::str(&ev.cause)),
                    ("plan_delta", Json::num(ev.plan_delta)),
                    ("straggler", Json::num(ev.straggler as f64)),
                    ("topology_replanned", Json::Bool(ev.topology_replanned)),
                ])
            })),
        ),
    ]);
    save_result("elastic", &doc);
    doc
}

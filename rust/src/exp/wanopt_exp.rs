//! WAN link-scheduler optimizations vs the static-FIFO baseline.
//!
//! The same thin-GZ 4-cloud WAN (fat Shanghai spokes, a 40 Mbps
//! Chongqing–Guangzhou edge) suffers the same mid-run bandwidth
//! collapse on the Shanghai–Beijing pair, hitting two runs:
//!
//! - **fifo** — the seed behavior: one FIFO queue per link, the
//!   statically configured codec (dense), direct routes only;
//! - **wanopt** — the full net-layer stack: priority lanes
//!   (`--wan-lanes`: Control > Barrier > Gradient > BulkData),
//!   controller-picked per-link compression (`--auto-compression`: the
//!   collapsed link switches to topk and reverts on recovery), and
//!   2-hop relay routes (`--relay-routes`: the ring's thin edges route
//!   through Shanghai's fat spokes).
//!
//! The Ring topology makes relays non-vacuous (on the max-bandwidth
//! tree a relay never beats the tree's own edges — see
//! `engine::topology::relay_route`); compression is what rescues the
//! collapsed link's makespan. Reported: makespan, WAN bytes, WAN time,
//! and the `"compression"` replan events the controller recorded.

use crate::cloud::CloudEnv;
use crate::coordinator::Coordinator;
use crate::engine::{ChurnEvent, TopologyKind};
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::sched::elastic::ElasticConfig;
use crate::sync::{Strategy, SyncConfig};
use crate::train::{calib, TrainConfig, TrainReport};
use crate::util::json::Json;

/// Rough virtual runtime estimate of the nominal run (straggler-bound,
/// same shape as the elastic experiment's) — places the churn at ~30%
/// and sizes the control interval with the model instead of hardcoding
/// seconds.
fn estimate_total_s(cfg: &TrainConfig, env: &CloudEnv, batch_size: usize) -> f64 {
    let base = if cfg.base_step_s > 0.0 {
        cfg.base_step_s
    } else {
        calib::default_base_step_s(&cfg.model)
    };
    let shard = cfg.n_train / env.regions.len().max(1);
    let steps = (shard.max(1) as f64 / batch_size.max(1) as f64).ceil() * cfg.epochs as f64;
    let power =
        env.greedy_plan().iter().map(|a| a.power()).fold(f64::INFINITY, f64::min);
    steps * base / power.max(1e-9)
}

struct RunPair {
    fifo: TrainReport,
    wanopt: TrainReport,
    churn_t: f64,
}

fn run_pair(coord: &Coordinator, scale: Scale, model: &str) -> RunPair {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let env = four_cloud_env(n_train);
    let initial = coord.plan(&env).allocations;
    let batch_size = coord
        .runtime()
        .load_model(model)
        .unwrap_or_else(|e| panic!("loading {model}: {e}"))
        .meta
        .batch_size;

    let mut base = TrainConfig::new(model);
    base.epochs = scale.epochs(model).min(6);
    base.n_train = n_train;
    base.n_eval = n_eval;
    base.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    base.skip_eval = true;
    base.link_overrides = hetero_overrides();
    // Ring keeps the thin Chongqing->Guangzhou edge in the plan, so the
    // relay pass has something real to route around (a max-bandwidth
    // tree would simply avoid the thin edge).
    base.topology = TopologyKind::Ring;

    let est = estimate_total_s(&base, &env, batch_size).max(1.0);
    let churn_t = (0.3 * est).max(1.0);
    // Mid-run WAN weather: the fat Shanghai<->Beijing pair collapses to
    // ~3% of nominal — deep enough past the topk crossover that the
    // controller pays the sparsification penalty for the byte savings.
    let churn = vec![
        ChurnEvent::LinkBandwidth { t: churn_t, from: 0, to: 2, bps: 10e6 },
        ChurnEvent::LinkBandwidth { t: churn_t, from: 2, to: 0, bps: 10e6 },
    ];

    let mut fifo_cfg = base.clone();
    fifo_cfg.churn = churn.clone();
    let fifo = crate::train::run_geo_training(coord.runtime(), &env, initial.clone(), fifo_cfg)
        .unwrap_or_else(|e| panic!("fifo run: {e}"));

    let mut opt_cfg = base;
    opt_cfg.churn = churn;
    opt_cfg.wan_lanes = true;
    opt_cfg.relay_routes = true;
    // Compression-only control loop: `enabled` stays false, so the win
    // is attributable to the net-layer optimizations, not re-planning.
    opt_cfg.elastic = ElasticConfig {
        auto_compression: true,
        interval_s: (est / 20.0).max(0.25),
        ..ElasticConfig::default()
    };
    let wanopt = crate::train::run_geo_training(coord.runtime(), &env, initial, opt_cfg)
        .unwrap_or_else(|e| panic!("wanopt run: {e}"));

    RunPair { fifo, wanopt, churn_t }
}

/// `exp --id wanopt`: priority lanes + auto-compression + relay routes
/// vs the seed's static-FIFO fabric under a mid-run link collapse.
pub fn wanopt_compare(coord: &Coordinator, scale: Scale, model: &str) -> Json {
    println!("WAN link scheduler: lanes + auto-compression + relays, 4-cloud thin-GZ WAN, {model}");
    let pair = run_pair(coord, scale, model);
    let (f, o) = (&pair.fifo, &pair.wanopt);

    let row = |name: &str, r: &TrainReport| {
        vec![
            name.to_string(),
            format!("{:.0}s", r.total_time),
            format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
            format!("{:.0}s", r.total_wan_time()),
            format!("{}", r.replan_events.len()),
        ]
    };
    print_table(
        &["fabric", "makespan", "wan bytes", "wan time", "replans"],
        &[row("fifo", f), row("wanopt", o)],
    );
    let speedup = f.total_time / o.total_time.max(1e-9);
    println!(
        "  link collapse at t={:.0}s (Shanghai<->Beijing 300 -> 10 Mbps)",
        pair.churn_t
    );
    println!("  fifo/wanopt makespan: {speedup:.2}x  (> 1.0 = wanopt faster)");
    for ev in &o.replan_events {
        println!(
            "  replan @{:.0}s [{}] codecs={:?}",
            ev.t, ev.cause, ev.compression_changes
        );
    }

    let run_json = |r: &TrainReport| {
        Json::obj(vec![
            ("total_time", Json::num(r.total_time)),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
            ("wan_time", Json::num(r.total_wan_time())),
            ("replans", Json::num(r.replan_events.len() as f64)),
        ])
    };
    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("churn_t", Json::num(pair.churn_t)),
        ("fifo", run_json(f)),
        ("wanopt", run_json(o)),
        ("makespan_speedup", Json::num(speedup)),
        (
            "compression_events",
            Json::arr(o.replan_events.iter().flat_map(|ev| {
                ev.compression_changes.iter().map(move |(from, to, codec)| {
                    Json::obj(vec![
                        ("t", Json::num(ev.t)),
                        ("from", Json::num(*from as f64)),
                        ("to", Json::num(*to as f64)),
                        ("codec", Json::str(codec)),
                    ])
                })
            })),
        ),
    ]);
    save_result("wanopt", &doc);
    doc
}

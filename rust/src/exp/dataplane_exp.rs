//! Data-plane placement modes under a skewed catalog — beyond the
//! paper's fixed resident-data distribution.
//!
//! A 4-cloud heterogeneous WAN holds a dataset catalog with 70% of the
//! bytes resident in Shanghai — the *weakest* region (Cascade cores) —
//! while the fastest regions sit data-starved, and Guangzhou hangs off
//! thin 30 Mbps links. The same job runs under the three placement
//! strategies (`dataplane::placement`):
//!
//! - **compute-follows-data** — zero migration; Shanghai becomes a
//!   massive data straggler while 30+ fast cores idle elsewhere;
//! - **data-follows-compute** — blind power-proportional migration; the
//!   share shipped to Guangzhou crawls through the thin pipe (staging
//!   stalls) and every moved byte pays object-store egress;
//! - **joint** — shard moves only where the makespan payoff beats
//!   transfer time + egress: the hot data spreads to the fast,
//!   well-connected regions and Guangzhou is left nearly alone.
//!
//! A single-home spec adds a fourth run: the same layout seeded with a
//! second replica per shard (`:r2`) under the joint planner — consumers
//! read from the nearest pre-existing copy, so the hot region's load
//! spreads with little or no staged migration (and no extra egress).
//!
//! Reported per mode: end-to-end time, data-stall time, migrated bytes,
//! replica copies created, egress cost, and total cost — the acceptance
//! bars are the joint mode beating compute-follows-data on makespan and
//! data-follows-compute on total cost, and `joint:r2` beating the
//! single-home joint run on makespan (see `rust/tests/dataplane.rs`).

use crate::coordinator::Coordinator;
use crate::dataplane::{self, DataPlaneConfig, PlacementMode, PlacementSpec};
use crate::exp::{four_cloud_env, print_table, save_result, wan_at, Scale};
use crate::net::LinkSpec;
use crate::sync::{Strategy, SyncConfig};
use crate::train::{TrainConfig, TrainReport};
use crate::util::json::Json;

/// The data-plane testbed's WAN: a fat 300 Mbps core between Shanghai /
/// Chongqing / Beijing, thin 30 Mbps spurs to and from Guangzhou.
pub(crate) fn dataplane_overrides() -> Vec<(usize, usize, LinkSpec)> {
    let mut ov = Vec::new();
    for (a, b) in [(0usize, 1usize), (0, 2), (1, 2)] {
        ov.push((a, b, wan_at(300.0)));
        ov.push((b, a, wan_at(300.0)));
    }
    for r in 0..3usize {
        ov.push((r, 3, wan_at(30.0)));
        ov.push((3, r, wan_at(30.0)));
    }
    ov
}

fn run_mode(
    coord: &Coordinator,
    base: &TrainConfig,
    mode: PlacementMode,
) -> (TrainReport, f64) {
    let env = four_cloud_env(base.n_train);
    let mut cfg = base.clone();
    cfg.dataplane.mode = mode;
    let meta = coord
        .runtime()
        .load_model(&cfg.model)
        .unwrap_or_else(|e| panic!("loading {}: {e}", cfg.model))
        .meta;
    let planned = dataplane::plan_for(&env, &cfg, &meta)
        .unwrap_or_else(|e| panic!("{} plan: {e}", mode.name()));
    let est = planned.plan.est_run_s;
    let allocations = planned.plan.allocations.clone();
    let report = crate::engine::driver::run_geo_training_planned(
        coord.runtime(),
        &env,
        allocations,
        cfg,
        Some(planned),
    )
    .unwrap_or_else(|e| panic!("{} run: {e}", mode.name()));
    (report, est)
}

/// `exp --id dataplane`: the three placement modes (plus a `joint:r2`
/// replica-seeded run when the spec is single-home) on the skewed
/// 4-cloud catalog. `spec` overrides the default `skewed:8:0.7`.
pub fn dataplane_compare(
    coord: &Coordinator,
    scale: Scale,
    model: &str,
    spec: Option<&str>,
) -> Json {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let placement = match spec {
        Some(s) => PlacementSpec::from_name(s)
            .unwrap_or_else(|e| panic!("--data-placement: {e}")),
        None => PlacementSpec::new(crate::dataplane::Layout::Skewed { shards: 8, frac: 0.7 }),
    };

    let mut base = TrainConfig::new(model);
    base.epochs = scale.epochs(model).min(6);
    base.n_train = n_train;
    base.n_eval = n_eval;
    base.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    base.skip_eval = true;
    base.link_overrides = dataplane_overrides();
    base.dataplane = DataPlaneConfig {
        placement: Some(placement.clone()),
        // Paper-scale datasets dwarf the scaled-down sample counts here;
        // 256 KB/sample restores a realistic bytes-to-compute ratio.
        sample_bytes: 256 * 1024,
        ..DataPlaneConfig::default()
    };

    println!(
        "Data-plane placement on a skewed catalog: {model}, {} over 4 clouds (thin Guangzhou links)",
        placement.name()
    );

    let mut rows = Vec::new();
    let mut docs = Vec::new();
    let mut runs: Vec<(PlacementMode, TrainReport)> = Vec::new();
    let record = |label: &str,
                      r: &TrainReport,
                      est: f64,
                      rows: &mut Vec<Vec<String>>,
                      docs: &mut Vec<Json>| {
        let d = r.dataplane.clone().expect("data plane was configured");
        rows.push(vec![
            label.to_string(),
            format!("{:.1}s", r.total_time),
            format!("{:.1}s", d.stall_time),
            format!("{:.1}MB", d.moved_bytes as f64 / 1e6),
            format!("{}", d.replicas_created.len()),
            format!("${:.4}", d.egress_cost),
            format!("${:.4}", r.cost),
            format!("{:.1}s", est),
        ]);
        docs.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("placement", Json::str(&d.placement)),
            ("total_time_s", Json::num(r.total_time)),
            ("stall_s", Json::num(d.stall_time)),
            ("moved_bytes", Json::num(d.moved_bytes as f64)),
            ("moved_shards", Json::num(d.moved_shards as f64)),
            ("replicas_created", Json::num(d.replicas_created.len() as f64)),
            ("egress_cost_usd", Json::num(d.egress_cost)),
            ("total_cost_usd", Json::num(r.cost)),
            ("est_run_s", Json::num(est)),
            ("wan_bytes", Json::num(r.wan_bytes as f64)),
        ]));
    };
    for mode in PlacementMode::ALL {
        let (r, est) = run_mode(coord, &base, mode);
        record(mode.name(), &r, est, &mut rows, &mut docs);
        runs.push((mode, r));
    }
    // A fourth run when the spec is single-home: the same layout seeded
    // with a second replica per shard, under the joint planner —
    // consumers read from the nearest pre-existing copy, so the hot
    // region's load spreads with little or no staged migration.
    let replicated = if placement.replication == 1 {
        let mut rep = base.clone();
        rep.dataplane.placement = Some(placement.clone().with_replication(2));
        let (r, est) = run_mode(coord, &rep, PlacementMode::Joint);
        record("joint:r2", &r, est, &mut rows, &mut docs);
        Some(r)
    } else {
        None
    };
    print_table(
        &["placement", "time", "data stall", "moved", "copies", "egress", "total cost", "est"],
        &rows,
    );
    let by = |m: PlacementMode| &runs.iter().find(|(k, _)| *k == m).unwrap().1;
    let (cfd, dfc, joint) = (
        by(PlacementMode::ComputeFollowsData),
        by(PlacementMode::DataFollowsCompute),
        by(PlacementMode::Joint),
    );
    println!(
        "  joint vs compute-follows-data: {:.2}x faster;  joint vs data-follows-compute: {:.2}x cheaper",
        cfd.total_time / joint.total_time.max(1e-9),
        dfc.cost / joint.cost.max(1e-12),
    );
    if let Some(rep) = &replicated {
        println!(
            "  joint:r2 vs joint:r1: {:.2}x faster at {:.1}MB vs {:.1}MB migrated",
            joint.total_time / rep.total_time.max(1e-9),
            rep.dataplane.as_ref().map_or(0.0, |d| d.moved_bytes as f64 / 1e6),
            joint.dataplane.as_ref().map_or(0.0, |d| d.moved_bytes as f64 / 1e6),
        );
    }

    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("placement", Json::str(placement.name())),
        ("modes", Json::arr(docs)),
    ]);
    save_result("dataplane", &doc);
    doc
}

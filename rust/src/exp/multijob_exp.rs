//! Multi-job scheduling policies under contention — beyond the paper's
//! single workflow.
//!
//! A Poisson trace of identical training jobs arrives at a 4-cloud
//! heterogeneous WAN (the topology/elastic testbed). All jobs share one
//! inventory and one fabric; the fleet coordinator
//! (`coordinator::fleet`) arbitrates:
//!
//! - **fifo** — head-of-line batch scheduling: each job runs at its full
//!   solo plan, later arrivals queue. Fast for the first job, brutal for
//!   the last.
//! - **fair-share** — every arrival re-divides each region's units
//!   evenly (weighted) across active jobs, shrinking running jobs
//!   through autoscaler resizes.
//! - **cost-aware** — fair shares trimmed to each job's Algorithm-1 plan
//!   within the share, so capacity the plan would idle admits queued
//!   jobs earlier.
//!
//! Reported per policy: fleet makespan, mean job slowdown (vs the
//! analytic solo estimate), Jain's fairness index over job progress
//! rates, queueing, total cost, and lease re-division counts.

use crate::coordinator::fleet::{
    poisson_arrivals, run_fleet, solo_estimate_s, FleetConfig, FleetReport, JobRequest,
    LeasePolicy, MultiJobParams,
};
use crate::coordinator::Coordinator;
use crate::exp::{four_cloud_env, hetero_overrides, print_table, save_result, Scale};
use crate::sched::elastic::ElasticConfig;
use crate::sync::{Strategy, SyncConfig};
use crate::train::TrainConfig;
use crate::util::json::Json;

fn policies_of(params: &MultiJobParams) -> Vec<LeasePolicy> {
    match params.policy {
        Some(p) => vec![p],
        None => vec![LeasePolicy::Fifo, LeasePolicy::FairShare, LeasePolicy::CostAware],
    }
}

/// `exp --id multijob`: concurrent training workflows over one shared
/// 4-cloud inventory, FIFO vs fair-share vs cost-aware leasing on a
/// Poisson job-arrival trace.
pub fn multijob_compare(
    coord: &Coordinator,
    scale: Scale,
    model: &str,
    params: &MultiJobParams,
) -> Json {
    let (n_train, n_eval) = crate::data::default_sizes(model);
    let env = four_cloud_env(n_train);
    let batch_size = coord
        .runtime()
        .load_model(model)
        .unwrap_or_else(|e| panic!("loading {model}: {e}"))
        .meta
        .batch_size;

    let mut template = TrainConfig::new(model);
    template.epochs = scale.epochs(model).min(4);
    template.n_train = n_train;
    template.n_eval = n_eval;
    template.sync = SyncConfig::new(Strategy::AsgdGa, 8);
    template.skip_eval = true;
    let est = solo_estimate_s(&template, &env, batch_size).max(1.0);
    // Each job keeps its own elastic control loop re-planning within its
    // lease (the two-level control story).
    template.elastic = ElasticConfig {
        enabled: true,
        interval_s: (est / 10.0).max(0.25),
        hysteresis: 0.2,
        bw_threshold: 0.5,
        smoothing: 0.5,
        auto_compression: false,
    };

    // Poisson arrivals dense enough that the fleet actually overlaps.
    let mean = if params.mean_interarrival_s > 0.0 {
        params.mean_interarrival_s
    } else {
        (est / 3.0).max(0.5)
    };
    let arrivals = poisson_arrivals(params.jobs, mean, 1234);
    let requests: Vec<JobRequest> = arrivals
        .iter()
        .enumerate()
        .map(|(i, &at)| {
            let mut train = template.clone();
            train.seed = template.seed ^ ((i as u64 + 1) << 8);
            JobRequest::new(&format!("job{i}"), at, train)
        })
        .collect();

    println!(
        "Multi-job control plane: {} x {model} on a shared 4-cloud WAN (mean gap {:.1}s, solo est {:.0}s)",
        params.jobs, mean, est
    );

    let mut reports: Vec<FleetReport> = Vec::new();
    for policy in policies_of(params) {
        let mut cfg = FleetConfig::new(policy, env.clone());
        cfg.link_overrides = hetero_overrides();
        cfg.min_units = params.min_units;
        let report = run_fleet(coord.runtime(), &cfg, &requests)
            .unwrap_or_else(|e| panic!("{} fleet: {e}", policy.name()));
        reports.push(report);
    }

    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            vec![
                r.policy.clone(),
                format!("{:.0}s", r.makespan),
                format!("{:.2}x", r.mean_slowdown),
                format!("{:.3}", r.jain_fairness),
                format!("{:.0}s", r.total_queue_wait()),
                format!("${:.4}", r.total_cost),
                format!("{:.1}MB", r.wan_bytes as f64 / 1e6),
                format!("{}", r.lease_events),
            ]
        })
        .collect();
    print_table(
        &["policy", "makespan", "slowdown", "jain", "queue", "cost", "wan", "leases"],
        &rows,
    );
    for r in &reports {
        println!("  {}", r.summary());
    }

    let doc = Json::obj(vec![
        ("model", Json::str(model)),
        ("jobs", Json::num(params.jobs as f64)),
        ("mean_interarrival_s", Json::num(mean)),
        ("solo_estimate_s", Json::num(est)),
        ("arrivals", Json::arr(arrivals.iter().map(|a| Json::num(*a)))),
        ("policies", Json::arr(reports.iter().map(|r| r.to_json()))),
    ]);
    save_result("multijob", &doc);
    doc
}

//! Synthetic dataset substrate.
//!
//! The paper trains on MNIST (LeNet), CIFAR-10 (ResNet) and Frappe
//! (DeepFM). Those files are not available offline, so each gets a
//! deterministic synthetic stand-in with the same tensor geometry and a
//! *learnable* structure (class-conditional prototypes for images, a
//! planted factorization model for CTR, a Markov chain for the LM
//! corpus). The paper's claims are relative (framework A vs B on the same
//! data), which such datasets preserve — see DESIGN.md §2. Sample counts
//! are scaled to the 1-core CPU budget; epochs stay proportional.
//!
//! Everything derives from `Pcg32` streams of the experiment seed, so
//! every partition regenerates identical data without any cross-region
//! "download".

use crate::runtime::{ModelMeta, Tensor};
use crate::util::rng::Pcg32;

/// An in-memory dataset with model-shaped features.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Flat features: x_elems per example (f32 models) or fields (i32).
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    /// Labels: one per example (classifiers) or per token (LM).
    pub y_i32: Vec<i32>,
    pub y_f32: Vec<f32>,
    pub n: usize,
    pub x_elems: usize,
    pub y_elems: usize,
    pub x_is_f32: bool,
    pub y_is_f32: bool,
}

/// Default scaled-down sample counts per model (train, eval).
/// Paper-scale: MNIST 60k / CIFAR 50k / Frappe 200k.
pub fn default_sizes(model: &str) -> (usize, usize) {
    match model {
        "lenet" => (4096, 1024),
        "resnet" => (2048, 512),
        "deepfm" => (16384, 4096),
        "synthetic" => (512, 128), // CI smoke: milliseconds end to end
        _ => (1024, 256),          // transformer windows
    }
}

/// Generate the train+eval datasets for a model from its metadata.
pub fn generate(meta: &ModelMeta, n_train: usize, n_eval: usize, seed: u64) -> (Dataset, Dataset) {
    let gen = |n: usize, split: u64| -> Dataset {
        let mut rng = Pcg32::new(seed ^ 0xDA7A, split);
        if !meta.vocab_sizes.is_empty() {
            ctr_dataset(meta, n, seed, &mut rng)
        } else if meta.vocab > 0 {
            lm_dataset(meta, n, seed, &mut rng)
        } else {
            image_dataset(meta, n, seed, &mut rng)
        }
    };
    (gen(n_train, 1), gen(n_eval, 2))
}

/// Class-conditional prototype images: x = snr * proto[class] + noise.
/// Prototypes are shared between train/eval (drawn from a split-
/// independent stream), so eval measures real generalization.
fn image_dataset(meta: &ModelMeta, n: usize, seed: u64, rng: &mut Pcg32) -> Dataset {
    let x_elems = meta.x_elems_per_example();
    let classes = meta.num_classes.max(2);
    let mut proto_rng = Pcg32::new(seed ^ 0x9407, 0xC1A5);
    let protos: Vec<f32> = (0..classes * x_elems).map(|_| proto_rng.normal_f32()).collect();

    let snr = 0.6f32;
    let label_noise = 0.02;
    let mut x = Vec::with_capacity(n * x_elems);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.usize_below(classes);
        let base = &protos[c * x_elems..(c + 1) * x_elems];
        for &p in base {
            x.push(snr * p + rng.normal_f32());
        }
        let label =
            if rng.f64() < label_noise { rng.usize_below(classes) as i32 } else { c as i32 };
        y.push(label);
    }
    Dataset {
        x_f32: x,
        x_i32: Vec::new(),
        y_i32: y,
        y_f32: Vec::new(),
        n,
        x_elems,
        y_elems: 1,
        x_is_f32: true,
        y_is_f32: false,
    }
}

/// Planted-model CTR data (Frappe stand-in): y ~ Bernoulli(sigmoid of a
/// hidden first-order + pairwise-interaction model over field ids).
fn ctr_dataset(meta: &ModelMeta, n: usize, seed: u64, rng: &mut Pcg32) -> Dataset {
    // hidden model drawn from a split-independent stream
    let mut hid = Pcg32::new(seed_mix(seed), 0xF12A);
    let fields = meta.vocab_sizes.len();
    let k = 4usize; // hidden embedding dim
    let total_vocab: usize = meta.vocab_sizes.iter().sum();
    // Signal strength sets the Bayes accuracy of the task (~0.85 with
    // these scales — near the paper's Frappe AUC regime); weaker planted
    // models leave labels near coin flips and nothing to learn.
    let w: Vec<f32> = (0..total_vocab).map(|_| 0.7 * hid.normal_f32()).collect();
    let v: Vec<f32> = (0..total_vocab * k).map(|_| 0.45 * hid.normal_f32()).collect();

    let mut offsets = vec![0usize; fields];
    let mut off = 0;
    for (f, &vs) in meta.vocab_sizes.iter().enumerate() {
        offsets[f] = off;
        off += vs;
    }

    let mut x = Vec::with_capacity(n * fields);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut logit = -0.1f32;
        let mut sum_v = vec![0f32; k];
        let mut sum_sq = vec![0f32; k];
        for (f, &vs) in meta.vocab_sizes.iter().enumerate() {
            let id = rng.usize_below(vs);
            x.push(id as i32);
            let gid = offsets[f] + id;
            logit += w[gid];
            for d in 0..k {
                let e = v[gid * k + d];
                sum_v[d] += e;
                sum_sq[d] += e * e;
            }
        }
        for d in 0..k {
            logit += 0.5 * (sum_v[d] * sum_v[d] - sum_sq[d]);
        }
        let p = 1.0 / (1.0 + (-logit as f64).exp());
        y.push(if rng.f64() < p { 1.0 } else { 0.0 });
    }
    Dataset {
        x_f32: Vec::new(),
        x_i32: x,
        y_i32: Vec::new(),
        y_f32: y,
        n,
        x_elems: fields,
        y_elems: 1,
        x_is_f32: false,
        y_is_f32: true,
    }
}

/// Synthetic corpus: order-1 Markov chain with a few favored successors
/// per token; windows of seq+1 tokens -> (x, next-token y).
fn lm_dataset(meta: &ModelMeta, n: usize, seed: u64, rng: &mut Pcg32) -> Dataset {
    let vocab = meta.vocab;
    let seq = meta.x_shape[0];
    let mut hid = Pcg32::new(seed_mix(seed), 0x3A9F);
    // transition table: each token has 4 favored successors (80%) else uniform
    let succ: Vec<[u32; 4]> = (0..vocab)
        .map(|_| [hid.below(vocab as u32), hid.below(vocab as u32),
                  hid.below(vocab as u32), hid.below(vocab as u32)])
        .collect();
    let mut x = Vec::with_capacity(n * seq);
    let mut y = Vec::with_capacity(n * seq);
    let mut tok = rng.below(vocab as u32);
    for _ in 0..n {
        for _ in 0..seq {
            x.push(tok as i32);
            let next = if rng.f64() < 0.8 {
                succ[tok as usize][rng.usize_below(4)]
            } else {
                rng.below(vocab as u32)
            };
            y.push(next as i32);
            tok = next;
        }
    }
    Dataset {
        x_f32: Vec::new(),
        x_i32: x,
        y_i32: y,
        y_f32: Vec::new(),
        n,
        x_elems: seq,
        y_elems: seq,
        x_is_f32: false,
        y_is_f32: false,
    }
}

fn seed_mix(seed: u64) -> u64 {
    seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x5EED
}

impl Dataset {
    /// Materialize a batch of `batch` examples given example indices
    /// (indices wrap around the dataset).
    pub fn batch(&self, idxs: &[usize], meta: &ModelMeta) -> (Tensor, Tensor) {
        let b = idxs.len();
        let x_dims = {
            let mut d = vec![b as i64];
            d.extend(meta.x_shape.iter().map(|&v| v as i64));
            d
        };
        let x = if self.x_is_f32 {
            let mut out = Vec::with_capacity(b * self.x_elems);
            for &i in idxs {
                let i = i % self.n;
                out.extend_from_slice(&self.x_f32[i * self.x_elems..(i + 1) * self.x_elems]);
            }
            Tensor::f32(out, x_dims)
        } else {
            let mut out = Vec::with_capacity(b * self.x_elems);
            for &i in idxs {
                let i = i % self.n;
                out.extend_from_slice(&self.x_i32[i * self.x_elems..(i + 1) * self.x_elems]);
            }
            Tensor::i32(out, x_dims)
        };
        let y_dims = if self.y_elems > 1 {
            vec![b as i64, self.y_elems as i64]
        } else {
            vec![b as i64]
        };
        let y = if self.y_is_f32 {
            let mut out = Vec::with_capacity(b * self.y_elems);
            for &i in idxs {
                let i = i % self.n;
                out.extend_from_slice(&self.y_f32[i * self.y_elems..(i + 1) * self.y_elems]);
            }
            Tensor::f32(out, y_dims)
        } else {
            let mut out = Vec::with_capacity(b * self.y_elems);
            for &i in idxs {
                let i = i % self.n;
                out.extend_from_slice(&self.y_i32[i * self.y_elems..(i + 1) * self.y_elems]);
            }
            Tensor::i32(out, y_dims)
        };
        (x, y)
    }
}

/// A shard of example indices assigned to one region, with epoch-shuffled
/// batch iteration.
#[derive(Debug, Clone)]
pub struct Shard {
    pub indices: Vec<usize>,
    cursor: usize,
    rng: Pcg32,
}

impl Shard {
    pub fn new(indices: Vec<usize>, seed: u64, stream: u64) -> Shard {
        let mut s = Shard { indices, cursor: 0, rng: Pcg32::new(seed ^ 0x5A4D, stream) };
        s.rng.shuffle(&mut s.indices);
        s
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Steps per epoch at batch size `b` (ceil; the tail wraps).
    pub fn steps_per_epoch(&self, b: usize) -> usize {
        self.indices.len().div_ceil(b).max(1)
    }

    /// Append newly-available sample indices (a migrated shard landed —
    /// see `dataplane::migration`). Appended plainly at the tail: they
    /// join the current pass immediately and mix into the shuffle from
    /// the next epoch on.
    pub fn extend(&mut self, extra: impl IntoIterator<Item = usize>) {
        self.indices.extend(extra);
    }

    /// Remove every index in `[start, end)` (a shard migrated away).
    /// The cursor is re-based so the current pass continues over the
    /// surviving indices without skipping or repeating any.
    pub fn remove_range(&mut self, start: usize, end: usize) {
        let cursor = self.cursor;
        let mut removed_before = 0usize;
        let mut kept = Vec::with_capacity(self.indices.len());
        for (pos, &i) in self.indices.iter().enumerate() {
            if (start..end).contains(&i) {
                if pos < cursor {
                    removed_before += 1;
                }
            } else {
                kept.push(i);
            }
        }
        self.indices = kept;
        self.cursor = (cursor - removed_before).min(self.indices.len());
    }

    /// Next batch of indices; reshuffles at each epoch boundary.
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(b);
        for _ in 0..b {
            if self.cursor >= self.indices.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.indices);
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
        out
    }
}

/// Split `n_train` examples across regions proportionally to
/// `fractions` (the pre-existing data distribution). Contiguous ranges —
/// data never crosses the WAN.
pub fn shard_by_fraction(n_train: usize, fractions: &[f64], seed: u64) -> Vec<Shard> {
    assert!(!fractions.is_empty());
    let total: f64 = fractions.iter().sum();
    let mut shards = Vec::with_capacity(fractions.len());
    let mut start = 0usize;
    for (i, &f) in fractions.iter().enumerate() {
        let count = if i + 1 == fractions.len() {
            n_train - start
        } else {
            ((n_train as f64) * f / total).round() as usize
        };
        let end = (start + count).min(n_train);
        shards.push(Shard::new((start..end).collect(), seed, i as u64));
        start = end;
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image_meta() -> ModelMeta {
        ModelMeta::parse(
            r#"{"name":"lenet","param_count":1,"batch_size":8,"x_shape":[28,28,1],
                "x_dtype":"f32","y_dtype":"i32","num_classes":10,"meta":{}}"#,
        )
        .unwrap()
    }

    fn ctr_meta() -> ModelMeta {
        ModelMeta::parse(
            r#"{"name":"deepfm","param_count":1,"batch_size":8,"x_shape":[3],
                "x_dtype":"i32","y_dtype":"f32","num_classes":2,
                "meta":{"vocab_sizes":[10,20,30]}}"#,
        )
        .unwrap()
    }

    fn lm_meta() -> ModelMeta {
        ModelMeta::parse(
            r#"{"name":"transformer","param_count":1,"batch_size":4,"x_shape":[16],
                "x_dtype":"i32","y_dtype":"i32","num_classes":0,"meta":{"vocab":64}}"#,
        )
        .unwrap()
    }

    #[test]
    fn image_dataset_shape_and_determinism() {
        let (tr, ev) = generate(&image_meta(), 100, 20, 7);
        assert_eq!(tr.n, 100);
        assert_eq!(tr.x_f32.len(), 100 * 784);
        assert!(tr.y_i32.iter().all(|&y| (0..10).contains(&y)));
        let (tr2, _) = generate(&image_meta(), 100, 20, 7);
        assert_eq!(tr.x_f32, tr2.x_f32);
        assert_eq!(tr.y_i32, tr2.y_i32);
        // train and eval differ
        assert_ne!(tr.x_f32[..784], ev.x_f32[..784]);
    }

    #[test]
    fn image_classes_are_separable() {
        // Nearest-prototype classification on the generated data should
        // beat chance by a lot — the "learnable" property.
        let meta = image_meta();
        let (tr, _) = generate(&meta, 400, 10, 3);
        // estimate class means from data itself
        let mut means = vec![0f32; 10 * 784];
        let mut counts = [0usize; 10];
        for i in 0..tr.n {
            let c = tr.y_i32[i] as usize;
            counts[c] += 1;
            for j in 0..784 {
                means[c * 784 + j] += tr.x_f32[i * 784 + j];
            }
        }
        for c in 0..10 {
            if counts[c] > 0 {
                for j in 0..784 {
                    means[c * 784 + j] /= counts[c] as f32;
                }
            }
        }
        let mut correct = 0;
        for i in 0..tr.n {
            let xi = &tr.x_f32[i * 784..(i + 1) * 784];
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = xi.iter().zip(&means[a * 784..(a + 1) * 784]).map(|(x, m)| (x - m) * (x - m)).sum();
                    let db: f32 = xi.iter().zip(&means[b * 784..(b + 1) * 784]).map(|(x, m)| (x - m) * (x - m)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == tr.y_i32[i] {
                correct += 1;
            }
        }
        assert!(correct > 300, "nearest-prototype accuracy too low: {correct}/400");
    }

    #[test]
    fn ctr_dataset_valid_ids_and_balance() {
        let meta = ctr_meta();
        let (tr, _) = generate(&meta, 2000, 10, 11);
        for i in 0..tr.n {
            for (f, &vs) in meta.vocab_sizes.iter().enumerate() {
                let id = tr.x_i32[i * 3 + f];
                assert!((0..vs as i32).contains(&id));
            }
        }
        let pos: f64 = tr.y_f32.iter().map(|&y| y as f64).sum::<f64>() / tr.n as f64;
        assert!((0.15..0.85).contains(&pos), "degenerate label balance {pos}");
    }

    #[test]
    fn lm_dataset_next_token_structure() {
        let meta = lm_meta();
        let (tr, _) = generate(&meta, 50, 5, 13);
        assert_eq!(tr.x_i32.len(), 50 * 16);
        assert_eq!(tr.y_i32.len(), 50 * 16);
        // y[t] is x[t+1] within a window (chain continuity)
        for w in 0..50 {
            for t in 0..15 {
                assert_eq!(tr.y_i32[w * 16 + t], tr.x_i32[w * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn batch_materialization() {
        let meta = image_meta();
        let (tr, _) = generate(&meta, 32, 8, 1);
        let (x, y) = tr.batch(&[0, 1, 2, 3, 4, 5, 6, 7], &meta);
        match x {
            Tensor::F32 { data, dims } => {
                assert_eq!(dims, vec![8, 28, 28, 1]);
                assert_eq!(data.len(), 8 * 784);
            }
            _ => panic!("expected f32 batch"),
        }
        match y {
            Tensor::I32 { data, dims } => {
                assert_eq!(dims, vec![8]);
                assert_eq!(data.len(), 8);
            }
            _ => panic!("expected i32 labels"),
        }
    }

    #[test]
    fn shard_fractions() {
        let shards = shard_by_fraction(300, &[2.0, 1.0], 5);
        assert_eq!(shards[0].len(), 200);
        assert_eq!(shards[1].len(), 100);
        // disjoint and complete
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort();
        assert_eq!(all, (0..300).collect::<Vec<_>>());
    }

    #[test]
    fn shard_extend_and_remove_range() {
        let mut s = Shard::new((0..8).collect(), 3, 0);
        s.extend(vec![8, 9]);
        assert_eq!(s.len(), 10);
        let mut seen: Vec<usize> = (0..2).flat_map(|_| s.next_batch(5)).collect();
        seen.sort();
        assert_eq!(seen, (0..10).collect::<Vec<_>>(), "extended pass covers everything");

        // Removal mid-pass: surviving indices are each drawn exactly once
        // before the pass wraps.
        let mut s = Shard::new((0..8).collect(), 3, 1);
        let first: Vec<usize> = s.next_batch(2);
        s.remove_range(0, 4);
        assert_eq!(s.len(), 4);
        let survivors_drawn: Vec<usize> =
            first.iter().copied().filter(|&i| i >= 4).collect();
        let mut rest = Vec::new();
        while rest.len() + survivors_drawn.len() < 4 {
            rest.extend(s.next_batch(1));
        }
        let mut all: Vec<usize> = survivors_drawn.into_iter().chain(rest).collect();
        all.sort();
        all.dedup();
        assert_eq!(all, vec![4, 5, 6, 7], "no survivor skipped or repeated");

        // Removing everything empties the shard without panicking.
        let mut e = Shard::new((0..4).collect(), 1, 2);
        e.remove_range(0, 4);
        assert!(e.is_empty());
    }

    #[test]
    fn shard_batches_cover_epoch() {
        let mut s = Shard::new((0..10).collect(), 1, 0);
        assert_eq!(s.steps_per_epoch(4), 3);
        let mut seen = Vec::new();
        for _ in 0..2 {
            seen.extend(s.next_batch(4));
        }
        seen.extend(s.next_batch(2));
        seen.sort();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }
}

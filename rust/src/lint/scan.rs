//! Token-level scanner for the repo lint (zero external deps — no syn, no regex).
//!
//! The scanner is deliberately not a full Rust parser: rules match on small token
//! sequences, so all we need is a lexer that is *exact* about what is code and what
//! is not. Comments and string contents never become `Ident` tokens, which is what
//! lets the lint module itself (whose rule tables spell the forbidden names as
//! string literals) scan clean under its own rules.
//!
//! Besides the token stream, `SourceFile` precomputes three views the rules share:
//! `#[cfg(test)]` / `#[test]` line spans (rules that only govern shipping code skip
//! them), enclosing-`fn` spans (the accounting registries are keyed by function
//! name), and the `// lint:allow(rule-id)` suppression table.

/// Token class. `Str` carries the *contents* of the literal (quotes and raw-string
/// hashes stripped) so doc-sync rules can read keys out of `get("key")` calls.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// One `// lint:allow(...)` entry: the code line it governs plus one rule id.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub rule: String,
    /// Set by the runner when the allow suppresses at least one finding.
    pub used: std::cell::Cell<bool>,
}

/// A scanned source file: token stream plus the derived views rules consume.
pub struct SourceFile {
    /// Repo-relative path with `/` separators (e.g. `rust/src/engine/driver.rs`).
    pub path: String,
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
    /// Lines holding a `lint:allow` comment that does not parse.
    pub malformed_allows: Vec<u32>,
    /// Line spans (inclusive) covered by `#[cfg(test)]` / `#[test]` items.
    test_spans: Vec<(u32, u32)>,
    /// `(open_brace_token, close_brace_token, fn_name)` for every `fn` body.
    fn_spans: Vec<(usize, usize, String)>,
    /// Whole file is test scope (anything under `tests/`).
    pub is_test_file: bool,
}

impl SourceFile {
    pub fn parse(path: String, text: &str) -> SourceFile {
        let is_test_file = path.contains("tests/");
        let (tokens, allows, malformed_allows) = lex(text);
        let test_spans = find_test_spans(&tokens);
        let fn_spans = find_fn_spans(&tokens);
        SourceFile { path, tokens, allows, malformed_allows, test_spans, fn_spans, is_test_file }
    }

    /// True when `line` belongs to test scope (a `tests/` file or a `#[cfg(test)]`
    /// / `#[test]` item). Rules restricted to shipping code skip such lines.
    pub fn is_test_line(&self, line: u32) -> bool {
        self.is_test_file || self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// Name of the innermost `fn` whose body contains token `i`, if any.
    pub fn enclosing_fn(&self, i: usize) -> Option<&str> {
        self.fn_spans
            .iter()
            .filter(|&&(open, close, _)| open < i && i < close)
            .max_by_key(|&&(open, _, _)| open)
            .map(|(_, _, name)| name.as_str())
    }

    /// Token span `(open_brace, close_brace)` of the first `fn name` body.
    pub fn fn_span(&self, name: &str) -> Option<(usize, usize)> {
        self.fn_spans.iter().find(|(_, _, n)| n == name).map(|&(a, b, _)| (a, b))
    }

    /// True when a `lint:allow(rule)` governs `line`.
    pub fn allowed(&self, line: u32, rule: &str) -> bool {
        let mut hit = false;
        for a in &self.allows {
            if a.line == line && a.rule == rule {
                a.used.set(true);
                hit = true;
            }
        }
        hit
    }
}

/// Lex `text` into tokens, collecting `lint:allow` comments along the way.
/// An allow on a line that already holds code governs that line; an allow on a
/// comment-only line governs the next line that holds code.
fn lex(text: &str) -> (Vec<Token>, Vec<Allow>, Vec<u32>) {
    let b = text.as_bytes();
    let mut toks: Vec<Token> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut malformed: Vec<u32> = Vec::new();
    // Rules parsed from comment-only lines, waiting for the next code line.
    let mut pending: Vec<String> = Vec::new();
    let (mut i, mut line) = (0usize, 1u32);
    let mut last_tok_line = 0u32;
    let attach = |toks: &mut Vec<Token>, pending: &mut Vec<String>, allows: &mut Vec<Allow>| {
        if let Some(t) = toks.last() {
            let ln = t.line;
            for r in pending.drain(..) {
                allows.push(Allow { line: ln, rule: r, used: std::cell::Cell::new(false) });
            }
        }
    };
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // Line comment — the only place the suppression grammar lives.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            // The directive must BE the comment (`// lint:allow(...)`), not merely
            // appear in one: doc comments (`///`, `//!`) and prose that quotes the
            // grammar are plain text. Stripping exactly `//` leaves doc comments
            // starting with `/` or `!`, which never match.
            let directive = text[start + 2..i].trim_start();
            if directive.starts_with("lint:allow") {
                match parse_allow(directive) {
                    Some(rules) if last_tok_line == line => {
                        for r in rules {
                            allows.push(Allow { line, rule: r, used: std::cell::Cell::new(false) });
                        }
                    }
                    Some(rules) => pending.extend(rules),
                    None => malformed.push(line),
                }
            }
            continue;
        }
        // Block comment (nested).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Identifier / keyword — or the prefix of a raw/byte string literal.
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            let word = &text[start..i];
            let next = b.get(i).copied();
            if matches!(word, "r" | "b" | "br") && matches!(next, Some(b'"') | Some(b'#')) {
                if let Some((val, ni, nl)) = lex_raw_or_byte_str(text, b, i, line, word) {
                    toks.push(Token { kind: Kind::Str, text: val, line });
                    last_tok_line = line;
                    attach(&mut toks, &mut pending, &mut allows);
                    line = nl;
                    i = ni;
                    continue;
                }
            }
            toks.push(Token { kind: Kind::Ident, text: word.to_string(), line });
            last_tok_line = line;
            attach(&mut toks, &mut pending, &mut allows);
            continue;
        }
        // Number (loose: consumes suffixes/hex; never eats a `..` range).
        if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            }
            toks.push(Token { kind: Kind::Num, text: text[start..i].to_string(), line });
            last_tok_line = line;
            attach(&mut toks, &mut pending, &mut allows);
            continue;
        }
        // String literal.
        if c == b'"' {
            let (val, ni, nl) = lex_quoted(text, b, i + 1, line);
            toks.push(Token { kind: Kind::Str, text: val, line });
            last_tok_line = line;
            attach(&mut toks, &mut pending, &mut allows);
            line = nl;
            i = ni;
            continue;
        }
        // Char literal vs lifetime. A lifetime is `'` + ident not closed by `'`.
        if c == b'\'' {
            if i + 2 < b.len() && b[i + 1] == b'\\' {
                // Escaped char literal: skip to the closing quote.
                i += 2;
                while i < b.len() && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
            } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                i += 3; // plain char literal 'x'
            } else {
                // Lifetime: consume the tick and let the ident lex normally.
                i += 1;
            }
            continue;
        }
        // Non-ASCII outside comments/strings: skip the whole char, never a token.
        if c >= 0x80 {
            i += 1;
            while i < b.len() && (b[i] & 0xC0) == 0x80 {
                i += 1;
            }
            continue;
        }
        // Punctuation — longest match first so `::`, `=>`, `..` stay atomic.
        const MULTI: [&str; 19] = [
            "..=", "...", "<<=", ">>=", "::", "->", "=>", "==", "!=", "<=", ">=", "..", "&&",
            "||", "<<", ">>", "+=", "-=", "*=",
        ];
        let rest = &text[i..];
        let m = MULTI.iter().find(|p| rest.starts_with(**p));
        let p = match m {
            Some(p) => (*p).to_string(),
            None => (c as char).to_string(),
        };
        i += p.len();
        toks.push(Token { kind: Kind::Punct, text: p, line });
        last_tok_line = line;
        attach(&mut toks, &mut pending, &mut allows);
    }
    (toks, allows, malformed)
}

/// Parse `lint:allow(rule-a, rule-b)` out of a line comment. Returns `None` when
/// the grammar is malformed (missing parens, empty list, bad characters).
fn parse_allow(comment: &str) -> Option<Vec<String>> {
    let at = comment.find("lint:allow")?;
    let rest = &comment[at + "lint:allow".len()..];
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let inner = &rest[..close];
    let mut rules = Vec::new();
    let id_char = |c: u8| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-';
    for part in inner.split(',') {
        let id = part.trim();
        if id.is_empty() || !id.bytes().all(id_char) {
            return None;
        }
        rules.push(id.to_string());
    }
    if rules.is_empty() {
        None
    } else {
        Some(rules)
    }
}

/// Lex a normal `"..."` body starting just past the opening quote.
/// Returns (contents, next index, next line).
fn lex_quoted(text: &str, b: &[u8], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let start = i;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => break,
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    let end = i.min(b.len());
    (text[start..end].to_string(), (end + 1).min(b.len()), line)
}

/// Lex the tail of a raw/byte string whose prefix word (`r`, `b`, `br`) ended at
/// `i`. Returns (contents, next index, next line) or `None` if it is not actually
/// a string (e.g. stray `#`).
fn lex_raw_or_byte_str(
    text: &str,
    b: &[u8],
    mut i: usize,
    mut line: u32,
    word: &str,
) -> Option<(String, usize, u32)> {
    if word == "b" && b.get(i) == Some(&b'"') {
        let (v, ni, nl) = lex_quoted(text, b, i + 1, line);
        return Some((v, ni, nl));
    }
    // Raw forms: r"..."  r#"..."#  br#"..."# (any number of #).
    let mut hashes = 0usize;
    while b.get(i) == Some(&b'#') {
        hashes += 1;
        i += 1;
    }
    if b.get(i) != Some(&b'"') {
        return None;
    }
    i += 1;
    let start = i;
    let closer: String = std::iter::once('"').chain(std::iter::repeat('#').take(hashes)).collect();
    while i < b.len() {
        if b[i] == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if text[i..].starts_with(&closer) {
            let v = text[start..i].to_string();
            return Some((v, i + closer.len(), line));
        }
        i += 1;
    }
    Some((text[start..].to_string(), b.len(), line))
}

/// Locate `#[cfg(test)]` / `#[test]` items and return their line spans.
fn find_test_spans(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text == "#" && i + 1 < toks.len() && toks[i + 1].text == "[" {
            let is_test = matches(toks, i + 2, &["test", "]"])
                || matches(toks, i + 2, &["cfg", "(", "test", ")", "]"]);
            if is_test {
                // Skip any further attributes, then brace-match the item body.
                let mut j = i + 2;
                while j < toks.len() && toks[j].text != "{" && toks[j].text != ";" {
                    j += 1;
                }
                if j < toks.len() && toks[j].text == "{" {
                    if let Some(close) = brace_match(toks, j) {
                        spans.push((toks[i].line, toks[close].line));
                        i = j + 1; // nested #[test] fns inside a cfg(test) mod still recorded
                        continue;
                    }
                }
            }
        }
        i += 1;
    }
    spans
}

/// Locate every `fn name ... { body }` and record its body's token span.
fn find_fn_spans(toks: &[Token]) -> Vec<(usize, usize, String)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == Kind::Ident && toks[i].text == "fn" && i + 1 < toks.len() {
            let name_tok = &toks[i + 1];
            if name_tok.kind == Kind::Ident {
                // Find the body `{`, bailing at `;` (trait method declaration).
                let mut j = i + 2;
                let mut depth = 0i32;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" if depth == 0 => break,
                        ";" if depth == 0 => {
                            j = toks.len();
                            break;
                        }
                        _ => {}
                    }
                    j += 1;
                }
                if j < toks.len() {
                    if let Some(close) = brace_match(toks, j) {
                        spans.push((j, close, name_tok.text.clone()));
                    }
                }
            }
        }
        i += 1;
    }
    spans
}

/// Index of the `}` matching the `{` at `open`, or `None` when unbalanced.
fn brace_match(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

/// True when the token texts at `toks[at..]` equal `pat`.
pub fn matches(toks: &[Token], at: usize, pat: &[&str]) -> bool {
    pat.iter().enumerate().all(|(k, p)| toks.get(at + k).map(|t| t.text == *p).unwrap_or(false))
}

//! The rule set for `cloudless lint`.
//!
//! Each rule enforces an invariant the paper's claims rest on — bit-determinism of
//! seeded runs, exact billing/replan accounting, or code↔doc agreement. Rules match
//! on token sequences from [`super::scan`]; every forbidden name below is spelled as
//! a *string literal* precisely so this module never trips its own checks.
//!
//! Registries (`WALLCLOCK_SITES`, `BILLING_CONSTRUCT_SITES`, `BILLING_OPEN_SITES`)
//! are the single place new sites get reviewed into: a rule failure tells you to
//! audit the new site's invariant first, then add it here.

use super::scan::{matches, Kind, SourceFile};
use super::{Finding, Project};

pub trait Rule {
    /// Stable kebab-case id, used in findings and `lint:allow(...)`.
    fn id(&self) -> &'static str;
    /// One-line invariant statement (docs/DEVELOPMENT.md mirrors these).
    fn summary(&self) -> &'static str;
    fn check(&self, p: &Project, out: &mut Vec<Finding>);
}

/// Every rule, in documentation order. `lint-allow` (suppression hygiene) is
/// enforced by the runner itself and is listed in [`ALL_RULE_IDS`] only.
pub fn registry() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(NoUnorderedCollections),
        Box::new(NoWallclock),
        Box::new(InstantNowAllowlist),
        Box::new(Pcg32ExplicitSeed),
        Box::new(BillingSiteRegistry),
        Box::new(ReplanCauseRegistry),
        Box::new(NoDefaultSpread),
        Box::new(ConfigDocSync),
        Box::new(ExpDocSync),
        Box::new(FlagDocSync),
    ]
}

pub const ALL_RULE_IDS: [&str; 11] = [
    "no-unordered-collections",
    "no-wallclock",
    "instant-now-allowlist",
    "pcg32-explicit-seed",
    "billing-site-registry",
    "replan-cause-registry",
    "no-default-spread",
    "config-doc-sync",
    "exp-doc-sync",
    "flag-doc-sync",
    "lint-allow",
];

pub fn known_rule(id: &str) -> bool {
    ALL_RULE_IDS.contains(&id)
}

fn push(out: &mut Vec<Finding>, file: &str, line: u32, rule: &'static str, message: String) {
    out.push(Finding { file: file.to_string(), line, rule, message });
}

// ---------------------------------------------------------------- determinism

/// Hash collections iterate in randomized order; a single `for` over one changes
/// report bytes between runs. Simulator and report paths use BTree collections.
struct NoUnorderedCollections;

impl Rule for NoUnorderedCollections {
    fn id(&self) -> &'static str {
        "no-unordered-collections"
    }
    fn summary(&self) -> &'static str {
        "sim/report paths must use BTreeMap/BTreeSet, never hash collections"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in p.files.iter().filter(|f| f.path.contains("src/") && !f.is_test_file) {
            for t in &f.tokens {
                let banned = t.kind == Kind::Ident && (t.text == "HashMap" || t.text == "HashSet");
                if banned && !f.is_test_line(t.line) {
                    let msg = format!(
                        "`{}` iterates in randomized order and breaks bit-determinism — use the BTree sibling",
                        t.text
                    );
                    push(out, &f.path, t.line, self.id(), msg);
                }
            }
        }
    }
}

/// Ambient entropy sources. Everywhere, tests included: a test that consults the
/// wall clock or a thread-local RNG is flaky by construction.
struct NoWallclock;

impl Rule for NoWallclock {
    fn id(&self) -> &'static str {
        "no-wallclock"
    }
    fn summary(&self) -> &'static str {
        "no SystemTime / thread_rng / rand::random — derive Pcg32 streams from the config seed"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in &p.files {
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind != Kind::Ident {
                    continue;
                }
                let hit = t.text == "SystemTime"
                    || t.text == "thread_rng"
                    || (t.text == "rand" && matches(&f.tokens, i + 1, &["::", "random"]));
                if hit {
                    let msg = format!(
                        "`{}` is ambient nondeterminism — seed a Pcg32 stream from the config instead",
                        t.text
                    );
                    push(out, &f.path, t.line, self.id(), msg);
                }
            }
        }
    }
}

/// The only legitimate wall-clock reads are self-measurement (fleet throughput,
/// driver wall-time, calibration) — one site each, and nowhere else.
struct InstantNowAllowlist;

const WALLCLOCK_SITES: [&str; 3] =
    ["src/coordinator/fleet.rs", "src/engine/driver.rs", "src/train/calib.rs"];

impl Rule for InstantNowAllowlist {
    fn id(&self) -> &'static str {
        "instant-now-allowlist"
    }
    fn summary(&self) -> &'static str {
        "Instant::now only at the allowlisted self-measurement sites (one per file)"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in &p.files {
            let allowlisted = WALLCLOCK_SITES.iter().any(|s| f.path.ends_with(s));
            let mut seen = 0u32;
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind == Kind::Ident
                    && t.text == "Instant"
                    && matches(&f.tokens, i + 1, &["::", "now"])
                {
                    seen += 1;
                    if !allowlisted {
                        let msg = "wall-clock read outside the allowlisted self-measurement sites \
                                   (fleet.rs / driver.rs / calib.rs)"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    } else if seen > 1 {
                        let msg = "only one wall-clock site is allowlisted per file — fold this \
                                   read into the existing one"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                }
            }
        }
    }
}

/// Every RNG stream must visibly derive from a seed: `Pcg32::new(...)`'s first
/// argument has to contain a literal or a seed-named value, and raw struct
/// literals (which bypass the stream-derivation constructor) are banned outside
/// the defining module.
struct Pcg32ExplicitSeed;

impl Rule for Pcg32ExplicitSeed {
    fn id(&self) -> &'static str {
        "pcg32-explicit-seed"
    }
    fn summary(&self) -> &'static str {
        "every Pcg32 construction takes an explicitly derived seed"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in p.files.iter().filter(|f| !f.path.ends_with("src/util/rng.rs")) {
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind != Kind::Ident || t.text != "Pcg32" {
                    continue;
                }
                if matches(&f.tokens, i + 1, &["::", "new", "("]) {
                    if !first_arg_is_seed_derived(f, i + 4) {
                        let msg = "Pcg32::new's seed argument must be explicitly derived — a \
                                   literal, or an expression naming a seed"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                } else if matches(&f.tokens, i + 1, &["{"]) {
                    let prev = i.checked_sub(1).map(|j| f.tokens[j].text.as_str());
                    if prev != Some("->") && prev != Some("impl") {
                        let msg = "construct RNGs via Pcg32::new(seed, stream) — raw struct \
                                   literals bypass seed derivation"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                }
            }
        }
    }
}

/// Scan the first argument starting at token `j` (just past the open paren);
/// true when it contains a numeric literal or a seed-named identifier.
fn first_arg_is_seed_derived(f: &SourceFile, mut j: usize) -> bool {
    let mut depth = 0i32;
    while j < f.tokens.len() {
        let t = &f.tokens[j];
        match t.text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                if depth == 0 {
                    return false;
                }
                depth -= 1;
            }
            "," if depth == 0 => return false,
            _ => {
                if t.kind == Kind::Num {
                    return true;
                }
                if t.kind == Kind::Ident && t.text.to_ascii_lowercase().contains("seed") {
                    return true;
                }
            }
        }
        j += 1;
    }
    false
}

// ----------------------------------------------------------------- accounting

/// Billing is segment-based: a segment opens when `alloc_since` is written and
/// closes when a `BilledAllocation` is constructed at the traced market rate.
/// Both halves live at a handful of audited sites; a new site means a new
/// open/close pairing to review, so constructions and opens outside the
/// registries are findings.
struct BillingSiteRegistry;

const BILLING_CONSTRUCT_SITES: [(&str, &[&str]); 2] = [
    ("src/engine/driver.rs", &["finalize_report", "preempt_partition", "resize_to_allocations"]),
    ("src/dataplane/placement.rs", &["default_time_value_per_hour", "evaluate"]),
];

const BILLING_OPEN_SITES: [(&str, &[&str]); 1] = [(
    "src/engine/driver.rs",
    &["deploy_job_planned", "restore_partition", "resize_to_allocations"],
)];

fn registered(regs: &[(&str, &[&str])], path: &str, func: Option<&str>) -> bool {
    let Some(func) = func else { return false };
    regs.iter().any(|(p, fns)| path.ends_with(p) && fns.contains(&func))
}

impl Rule for BillingSiteRegistry {
    fn id(&self) -> &'static str {
        "billing-site-registry"
    }
    fn summary(&self) -> &'static str {
        "billing segment opens (alloc_since writes) and closes (BilledAllocation constructions) only at registered, audited sites"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        let skip = |f: &&SourceFile| !f.path.ends_with("src/cloud/cost.rs") && !f.is_test_file;
        for f in p.files.iter().filter(skip) {
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind != Kind::Ident || f.is_test_line(t.line) {
                    continue;
                }
                if t.text == "BilledAllocation" {
                    let construct = matches(&f.tokens, i + 1, &["{"])
                        || matches(&f.tokens, i + 1, &["::", "on_demand"]);
                    if construct && !registered(&BILLING_CONSTRUCT_SITES, &f.path, f.enclosing_fn(i))
                    {
                        let msg = "unregistered billing close — audit that this segment's open \
                                   (alloc_since) is paired and the rate is the traced market \
                                   rate, then add the fn to BILLING_CONSTRUCT_SITES"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                } else if t.text == "alloc_since" {
                    let next = f.tokens.get(i + 1).map(|n| n.text.as_str());
                    let write = next == Some("=")
                        || (next == Some(":") && f.enclosing_fn(i).is_some());
                    if write && !registered(&BILLING_OPEN_SITES, &f.path, f.enclosing_fn(i)) {
                        let msg = "unregistered billing open — audit that every path from here \
                                   reaches a BilledAllocation close, then add the fn to \
                                   BILLING_OPEN_SITES"
                            .to_string();
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                }
            }
        }
    }
}

/// Every `ReplanEvent` cause string comes from the one registry in
/// `train::metrics::replan_cause`; ad-hoc literals drift (a typo'd cause is
/// silently never matched by the experiments that filter on it).
struct ReplanCauseRegistry;

impl Rule for ReplanCauseRegistry {
    fn id(&self) -> &'static str {
        "replan-cause-registry"
    }
    fn summary(&self) -> &'static str {
        "ReplanEvent cause strings come from train::metrics::replan_cause, nowhere else"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in p.files.iter().filter(|f| !f.path.ends_with("src/train/metrics.rs")) {
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind != Kind::Ident || (t.text != "cause" && t.text != "causes") {
                    continue;
                }
                for j in i + 1..=(i + 4).min(f.tokens.len().saturating_sub(1)) {
                    let n = &f.tokens[j];
                    if n.line != t.line {
                        break;
                    }
                    if n.kind == Kind::Str && cause_like(&n.text) {
                        let msg = format!(
                            "cause literal \"{}\" — use the constants in train::metrics::replan_cause (one registry)",
                            n.text
                        );
                        push(out, &f.path, n.line, self.id(), msg);
                        break;
                    }
                }
            }
        }
    }
}

/// A lowercase word that plausibly is a cause tag (`"lease"`, `"load+bandwidth"`).
fn cause_like(s: &str) -> bool {
    s.len() >= 3
        && s.as_bytes()[0].is_ascii_lowercase()
        && s.bytes().all(|c| c.is_ascii_lowercase() || c == b'_' || c == b'+' || c == b'-')
}

/// `..Default::default()` in a Config/Report/Params/Event/Spec literal absorbs
/// any field added later without the author ever seeing it — the exact drift the
/// struct-literal completeness sweeps of earlier PRs existed to catch.
struct NoDefaultSpread;

const DRIFT_SUFFIXES: [&str; 5] = ["Config", "Report", "Params", "Event", "Spec"];

impl Rule for NoDefaultSpread {
    fn id(&self) -> &'static str {
        "no-default-spread"
    }
    fn summary(&self) -> &'static str {
        "no ..Default::default() in Config/Report/Params/Event/Spec literals — spell every field"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in p.files.iter().filter(|f| !f.is_test_file) {
            // Stack of the token preceding each open brace: for a struct literal
            // that is the struct's name.
            let mut openers: Vec<Option<usize>> = Vec::new();
            for (i, t) in f.tokens.iter().enumerate() {
                match t.text.as_str() {
                    "{" => openers.push(i.checked_sub(1)),
                    "}" => {
                        openers.pop();
                    }
                    ".." if matches(&f.tokens, i + 1, &["Default", "::", "default", "("])
                        && !f.is_test_line(t.line) =>
                    {
                        let opener = openers.last().copied().flatten().map(|o| &f.tokens[o]);
                        if let Some(o) = opener {
                            let drifty = o.kind == Kind::Ident
                                && DRIFT_SUFFIXES.iter().any(|s| o.text.ends_with(s));
                            if drifty {
                                let msg = format!(
                                    "..Default::default() in `{}` hides fields added later — spell every field so additions get reviewed",
                                    o.text
                                );
                                push(out, &f.path, t.line, self.id(), msg);
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
    }
}

// ------------------------------------------------------------------- doc-sync

/// Every config key parsed out of the JSON config has a backticked row in
/// docs/CONFIG.md — the doc drift PRs 3/5/9 kept re-fixing by hand.
struct ConfigDocSync;

impl Rule for ConfigDocSync {
    fn id(&self) -> &'static str {
        "config-doc-sync"
    }
    fn summary(&self) -> &'static str {
        "every config key parsed in src/config/ has a row in docs/CONFIG.md"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        for f in p.files.iter().filter(|f| f.path.ends_with("src/config/mod.rs")) {
            for (i, t) in f.tokens.iter().enumerate() {
                let getter = t.kind == Kind::Ident
                    && t.text == "get"
                    && i > 0
                    && f.tokens[i - 1].text == "."
                    && matches(&f.tokens, i + 1, &["("])
                    && f.tokens.get(i + 2).map(|k| k.kind == Kind::Str).unwrap_or(false);
                if getter && !f.is_test_line(t.line) {
                    let key = &f.tokens[i + 2].text;
                    if !p.docs.config_md.contains(&format!("`{key}`")) {
                        let msg = format!(
                            "config key \"{key}\" is parsed here but has no `{key}` row in docs/CONFIG.md"
                        );
                        push(out, &f.path, t.line, self.id(), msg);
                    }
                }
            }
        }
    }
}

/// The exp-id surface stays in sync three ways: every id registered in
/// `cmd_exp` has a docs/EXPERIMENTS.md row; every id CI smokes actually exists;
/// every extension id (whose drivers all accept `--model synthetic`) has a CI
/// smoke invocation. Paper-reproduction ids need model artifacts, which CI does
/// not build, so the smoke requirement covers the extensions table.
struct ExpDocSync;

impl Rule for ExpDocSync {
    fn id(&self) -> &'static str {
        "exp-doc-sync"
    }
    fn summary(&self) -> &'static str {
        "exp ids: registered ⇒ documented; documented ⇒ registered; extensions ⇒ CI-smoked"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        let Some(f) = p.files.iter().find(|f| f.path.ends_with("src/main.rs")) else { return };
        let Some((open, close)) = f.fn_span("cmd_exp") else {
            push(out, &f.path, 1, self.id(), "cannot locate fn cmd_exp in src/main.rs".into());
            return;
        };
        // Alias groups from the match arms: `"fig9" | "fig8_fig9" => ...`.
        // "all" is the union runner, registered implicitly (no doc row needed).
        let mut groups: Vec<(Vec<String>, u32)> = Vec::new();
        let mut cur: Vec<String> = Vec::new();
        for i in open..close {
            let t = &f.tokens[i];
            if t.kind != Kind::Str {
                continue;
            }
            match f.tokens.get(i + 1).map(|n| n.text.as_str()) {
                Some("|") => cur.push(t.text.clone()),
                Some("=>") => {
                    cur.push(t.text.clone());
                    groups.push((std::mem::take(&mut cur), t.line));
                }
                _ => cur.clear(),
            }
        }
        let mut ids: Vec<&str> =
            groups.iter().flat_map(|(g, _)| g.iter()).map(|s| s.as_str()).collect();
        ids.push("all");
        // (a) registered ⇒ documented.
        for (group, line) in &groups {
            for id in group {
                if !p.docs.experiments_md.contains(&format!("`{id}`")) {
                    let msg = format!(
                        "exp id \"{id}\" is registered here but has no `{id}` row in docs/EXPERIMENTS.md"
                    );
                    push(out, &f.path, *line, self.id(), msg);
                }
            }
        }
        // (b) CI smokes only registered ids.
        let smoked = id_mentions(&p.docs.ci_yml);
        for (id, line) in &smoked {
            if !ids.contains(&id.as_str()) {
                let msg = format!("CI smokes `exp --id {id}`, which is not registered in cmd_exp");
                push(out, ".github/workflows/ci.yml", *line, self.id(), msg);
            }
        }
        // (c) every extension-table id is registered and its alias group is smoked.
        let smoked_ids: Vec<&str> = smoked.iter().map(|(id, _)| id.as_str()).collect();
        for (ext, line) in extension_ids(&p.docs.experiments_md) {
            let Some((group, _)) = groups.iter().find(|(g, _)| g.contains(&ext)) else {
                let msg =
                    format!("extension exp `{ext}` is documented but not registered in cmd_exp");
                push(out, "docs/EXPERIMENTS.md", line, self.id(), msg);
                continue;
            };
            if !group.iter().any(|id| smoked_ids.contains(&id.as_str())) {
                let msg = format!(
                    "extension exp `{ext}` has no CI smoke — add `exp --id {ext}` to .github/workflows/ci.yml"
                );
                push(out, "docs/EXPERIMENTS.md", line, self.id(), msg);
            }
        }
        // (d) every `--id X` the docs mention is a real id.
        for (id, line) in id_mentions(&p.docs.experiments_md) {
            if !ids.contains(&id.as_str()) {
                let msg = format!("docs mention `--id {id}`, which is not registered in cmd_exp");
                push(out, "docs/EXPERIMENTS.md", line, self.id(), msg);
            }
        }
    }
}

/// Every `--id <word>` mention in `text` with its 1-based line number.
fn id_mentions(text: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(at) = rest.find("--id") {
            rest = &rest[at + 4..];
            let word: String = rest
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !word.is_empty() {
                out.push((word, ln as u32 + 1));
            }
        }
    }
    out
}

/// First-column backticked ids of the EXPERIMENTS.md "Extensions beyond the
/// paper" table, with line numbers.
fn extension_ids(md: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut in_ext = false;
    for (ln, line) in md.lines().enumerate() {
        if line.starts_with("## ") {
            in_ext = line.starts_with("## Extensions");
            continue;
        }
        if in_ext && line.starts_with("| `") {
            if let Some(end) = line[3..].find('`') {
                out.push((line[3..3 + end].to_string(), ln as u32 + 1));
            }
        }
    }
    out
}

/// Every CLI flag `main.rs` reads has a `--flag` mention in docs/CONFIG.md
/// (either a config-key row's CLI column or the flags-without-keys section).
struct FlagDocSync;

const ARG_METHODS: [&str; 7] = ["get", "get_or", "flag", "usize", "u64", "f64", "parsed"];

impl Rule for FlagDocSync {
    fn id(&self) -> &'static str {
        "flag-doc-sync"
    }
    fn summary(&self) -> &'static str {
        "every CLI flag read in src/main.rs is documented in docs/CONFIG.md"
    }
    fn check(&self, p: &Project, out: &mut Vec<Finding>) {
        let mut seen: Vec<String> = Vec::new();
        for f in p.files.iter().filter(|f| f.path.ends_with("src/main.rs")) {
            for (i, t) in f.tokens.iter().enumerate() {
                if t.kind != Kind::Ident || t.text != "args" {
                    continue;
                }
                if f.tokens.get(i + 1).map(|n| n.text.as_str()) != Some(".") {
                    continue;
                }
                let Some(m) = f.tokens.get(i + 2) else { continue };
                if m.kind != Kind::Ident || !ARG_METHODS.contains(&m.text.as_str()) {
                    continue;
                }
                // Skip an optional turbofish between the method and its args.
                let mut j = i + 3;
                if f.tokens.get(j).map(|n| n.text.as_str()) == Some("::") {
                    while j < f.tokens.len() && f.tokens[j].text != "(" {
                        j += 1;
                    }
                }
                let is_call = f.tokens.get(j).map(|n| n.text.as_str()) == Some("(")
                    && f.tokens.get(j + 1).map(|k| k.kind == Kind::Str).unwrap_or(false);
                if !is_call {
                    continue;
                }
                let flag = f.tokens[j + 1].text.clone();
                if seen.contains(&flag) {
                    continue;
                }
                seen.push(flag.clone());
                if !contains_flag(&p.docs.config_md, &flag) {
                    let msg = format!(
                        "CLI flag --{flag} is undocumented — add it to docs/CONFIG.md (CLI column or the flags-without-keys section)"
                    );
                    push(out, &f.path, t.line, self.id(), msg);
                }
            }
        }
    }
}

/// True when `--name` appears in `md` at a flag boundary (not as a prefix of a
/// longer flag, so `--n-train` never satisfies `--n-eval`).
fn contains_flag(md: &str, name: &str) -> bool {
    let pat = format!("--{name}");
    let mut from = 0;
    while let Some(pos) = md[from..].find(&pat) {
        let end = from + pos + pat.len();
        let boundary = md[end..]
            .chars()
            .next()
            .map(|c| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'))
            .unwrap_or(true);
        if boundary {
            return true;
        }
        from = end;
    }
    false
}

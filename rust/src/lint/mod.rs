//! `cloudless lint` — a repo-specific static-analysis pass with zero external
//! dependencies (std only; no syn, no regex).
//!
//! Every result this repo reports rests on three invariant families the paper's
//! claims depend on: **determinism** (seeded runs are bit-reproducible — paper
//! §IV's correctness guarantee), **accounting** (billing segments and re-plan
//! causes are exact — §III.C), and **doc-sync** (the config/experiment surface
//! matches its documentation). PRs 1–9 verified all three by hand; this module
//! machine-checks them on every build.
//!
//! Layout: [`scan`] lexes Rust sources into tokens (comments and string contents
//! never become identifiers), [`rules`] holds the [`rules::Rule`] implementations
//! and their site registries, [`walk`] enumerates the tree deterministically.
//! Entry points: [`lint_repo`] (CLI and the repo-tree test) and [`lint_files`]
//! (fixture tests, in-memory).
//!
//! Suppression grammar: `// lint:allow(rule-id)` — same line as the finding, or
//! the line directly above it; several ids separated by commas. The directive
//! must be the entire comment (doc comments and prose mentions are plain text).
//! Unknown ids, malformed grammar, and allows that suppress nothing are
//! themselves findings (rule `lint-allow`), so suppressions cannot rot silently.

pub mod rules;
pub mod scan;
pub mod walk;

use std::path::Path;

use anyhow::Result;

pub use walk::DocContext;

/// One lint violation, pinned to `file:line` with a stable rule id.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// The scanned tree plus the doc-sync inputs; what every rule sees.
pub struct Project {
    pub files: Vec<scan::SourceFile>,
    pub docs: DocContext,
}

/// Outcome of a lint run. `render()` is byte-stable for a given tree.
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `file:line: [rule] message` per finding
    /// (sorted), then a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        if self.clean() {
            out.push_str(&format!(
                "lint: clean — {} files scanned, {} suppressed\n",
                self.files_scanned, self.suppressed
            ));
        } else {
            out.push_str(&format!(
                "lint: {} finding(s) across {} files scanned, {} suppressed\n",
                self.findings.len(),
                self.files_scanned,
                self.suppressed
            ));
        }
        out
    }
}

/// Lint an in-memory tree of `(path, contents)` files against `docs`.
/// This is the fixture-test entry point; [`lint_repo`] feeds it the real tree.
pub fn lint_files(files: Vec<(String, String)>, docs: DocContext) -> LintReport {
    let sources: Vec<scan::SourceFile> =
        files.into_iter().map(|(p, t)| scan::SourceFile::parse(p, &t)).collect();
    let files_scanned = sources.len();
    let project = Project { files: sources, docs };

    let mut findings = Vec::new();
    for rule in rules::registry() {
        rule.check(&project, &mut findings);
    }

    // Suppression hygiene (rule `lint-allow`): bad grammar and unknown ids are
    // findings in their own right and can never be self-suppressed.
    for f in &project.files {
        for &line in &f.malformed_allows {
            findings.push(Finding {
                file: f.path.clone(),
                line,
                rule: "lint-allow",
                message: "malformed lint:allow — expected `// lint:allow(rule-id[, rule-id])`"
                    .to_string(),
            });
        }
        for a in &f.allows {
            if !rules::known_rule(&a.rule) {
                findings.push(Finding {
                    file: f.path.clone(),
                    line: a.line,
                    rule: "lint-allow",
                    message: format!("lint:allow names unknown rule \"{}\"", a.rule),
                });
            }
        }
    }

    // Apply suppressions.
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for fd in findings {
        let hit = fd.rule != "lint-allow"
            && project
                .files
                .iter()
                .find(|f| f.path == fd.file)
                .map(|f| f.allowed(fd.line, fd.rule))
                .unwrap_or(false);
        if hit {
            suppressed += 1;
        } else {
            kept.push(fd);
        }
    }

    // A well-formed allow that suppresses nothing is dead weight — flag it so
    // suppressions are removed when the underlying code is fixed.
    for f in &project.files {
        for a in &f.allows {
            if rules::known_rule(&a.rule) && !a.used.get() {
                kept.push(Finding {
                    file: f.path.clone(),
                    line: a.line,
                    rule: "lint-allow",
                    message: format!("lint:allow({}) suppresses nothing — remove it", a.rule),
                });
            }
        }
    }

    kept.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    kept.dedup_by(|a, b| {
        a.file == b.file && a.line == b.line && a.rule == b.rule && a.message == b.message
    });
    LintReport { findings: kept, suppressed, files_scanned }
}

/// Lint the real repo rooted at `root` (the directory holding `rust/` and
/// `docs/`). Walks `rust/src` + `rust/tests` and loads the doc-sync inputs.
pub fn lint_repo(root: &Path) -> Result<LintReport> {
    let files = walk::rust_sources(root)?;
    let docs = walk::load_docs(root)?;
    Ok(lint_files(files, docs))
}

//! Deterministic file walker for `cloudless lint`.
//!
//! Collects every `.rs` file under `rust/src/` and `rust/tests/` (sorted, so the
//! findings report is byte-stable across runs and machines) plus the three
//! documents the doc-sync rules check against.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// The docs the doc-sync rules cross-check code against.
pub struct DocContext {
    pub config_md: String,
    pub experiments_md: String,
    pub ci_yml: String,
}

/// All `.rs` files under `root/rust/src` and `root/rust/tests`, as
/// `(repo-relative path, contents)`, sorted by path.
pub fn rust_sources(root: &Path) -> Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    for dir in ["rust/src", "rust/tests"] {
        let abs = root.join(dir);
        if abs.is_dir() {
            collect(&abs, &mut files)?;
        }
    }
    let mut out = Vec::new();
    for p in files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let text = fs::read_to_string(&p).with_context(|| format!("reading {}", p.display()))?;
        out.push((rel, text));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("walking {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Load the doc-sync inputs from their canonical repo locations.
pub fn load_docs(root: &Path) -> Result<DocContext> {
    let read = |rel: &str| {
        fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading doc-sync input {rel}"))
    };
    Ok(DocContext {
        config_md: read("docs/CONFIG.md")?,
        experiments_md: read("docs/EXPERIMENTS.md")?,
        ci_yml: read(".github/workflows/ci.yml")?,
    })
}

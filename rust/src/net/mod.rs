//! WAN/LAN network substrate.
//!
//! Models the inter-cloud links the paper trains over: Shanghai–Chongqing
//! at 100 Mbps (Tencent Cloud's maximum inter-region setting) with the
//! bandwidth fluctuations the paper repeatedly blames for noisy declines
//! ("since the fluctuations in WAN, the decline is not as twice as
//! expected"). A transfer on a directed link serializes FIFO behind earlier
//! transfers (PS communicators send over one connection), takes
//! `bytes*8 / (bandwidth * fluct)` to serialize plus propagation latency,
//! and can be failure-injected (drop probability, outage windows).
//!
//! All stochasticity comes from a per-link PCG stream seeded from the
//! experiment seed, so runs replay deterministically.
//!
//! # Priority lanes
//!
//! With [`Fabric::set_lanes`] enabled, each directed link schedules four
//! priority lanes instead of one FIFO: every transfer carries a
//! [`TrafficClass`] (Control > Barrier > Gradient > BulkData). A transfer
//! waits behind its own lane's backlog and — capped at
//! [`MAX_PRIORITY_WAIT_S`] — behind higher-priority lanes; it never waits
//! for lower-priority traffic (preemption at serialization boundaries,
//! modeled as bounded capacity overlap). The cap is the no-starvation
//! guarantee: bulk shard migration proceeds within a bounded wait even
//! under an adversarial Control flood. With lanes disabled (the default)
//! the scheduling path is byte-for-byte identical to the historical
//! single-FIFO fabric — the `tests/wan_sched.rs` equivalence property.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::sim::Time;
use crate::util::rng::Pcg32;

/// Region identifier (index into the cloud's region table).
pub type RegionId = usize;

/// Longest a lower-priority transfer will yield to higher-priority lanes
/// before starting anyway (virtual seconds). This bounds bulk-lane wait
/// under an adversarial flood of latency-critical traffic: no starvation.
pub const MAX_PRIORITY_WAIT_S: Time = 1.0;

/// Traffic class of a WAN transfer; lower lane index = higher priority.
///
/// - [`TrafficClass::Control`] — coordinator RPCs, leases, monitor pulls;
/// - [`TrafficClass::Barrier`] — synchronous barrier (SMA) exchanges,
///   latency-critical: a barrier must not queue behind a shard migration;
/// - [`TrafficClass::Gradient`] — asynchronous gradient/parameter sync
///   payloads, the steady-state training traffic;
/// - [`TrafficClass::BulkData`] — shard migration / dataset bulk moves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TrafficClass {
    Control,
    Barrier,
    Gradient,
    BulkData,
}

impl TrafficClass {
    /// Number of lanes a link schedules.
    pub const COUNT: usize = 4;

    /// Lane index (0 = highest priority).
    pub fn lane(self) -> usize {
        match self {
            TrafficClass::Control => 0,
            TrafficClass::Barrier => 1,
            TrafficClass::Gradient => 2,
            TrafficClass::BulkData => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrafficClass::Control => "control",
            TrafficClass::Barrier => "barrier",
            TrafficClass::Gradient => "gradient",
            TrafficClass::BulkData => "bulk",
        }
    }
}

/// Static description of a directed link.
#[derive(Debug, Clone)]
pub struct LinkSpec {
    /// Nominal bandwidth in bits/second.
    pub bandwidth_bps: f64,
    /// One-way propagation latency in seconds.
    pub latency_s: f64,
    /// Sigma of the mean-1 lognormal bandwidth fluctuation multiplier
    /// (0.0 = perfectly stable link).
    pub fluct_sigma: f64,
    /// Probability a transfer is dropped (failure injection; retried by
    /// the communicator layer).
    pub drop_prob: f64,
    /// Fixed per-transfer setup cost (TCP slow-start / gRPC framing):
    /// small payloads on a long-RTT WAN never reach line rate, so each
    /// transfer pays this before streaming at `bandwidth_bps`.
    pub setup_s: f64,
}

impl LinkSpec {
    /// The paper's evaluation WAN: 100 Mbps, ~30 ms cross-China RTT/2,
    /// visible fluctuation.
    pub fn wan_100mbps() -> Self {
        LinkSpec {
            bandwidth_bps: 100e6,
            latency_s: 0.015,
            fluct_sigma: 0.25,
            drop_prob: 0.0,
            setup_s: 0.09, // ~3 RTT of cwnd ramp on the cross-China path
        }
    }

    /// Intra-cloud LAN: >=10 Gbps, sub-ms latency, stable
    /// (the paper: WAN is "at least 50 times slower than LAN").
    pub fn lan() -> Self {
        LinkSpec { bandwidth_bps: 10e9, latency_s: 0.0005, fluct_sigma: 0.0, drop_prob: 0.0, setup_s: 0.0 }
    }

    /// The self-hosted Beijing–Shanghai cluster pair used for SMA (Fig 11):
    /// dedicated link, steadier than the public-cloud WAN.
    pub fn self_hosted() -> Self {
        LinkSpec { bandwidth_bps: 300e6, latency_s: 0.012, fluct_sigma: 0.1, drop_prob: 0.0, setup_s: 0.05 }
    }
}

/// Outcome of scheduling one transfer on a link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// When serialization began (>= submit time; queued behind FIFO).
    pub start: Time,
    /// When the last byte left the sender.
    pub done: Time,
    /// When the payload is available at the receiver.
    pub arrival: Time,
    /// True if the transfer was dropped (arrival/done are then meaningless).
    pub dropped: bool,
}

impl Transfer {
    /// Queueing + serialization + propagation as seen by the sender.
    pub fn total_delay(&self, submitted: Time) -> Time {
        self.arrival - submitted
    }
}

/// One directed link with live state.
#[derive(Debug)]
struct Link {
    spec: LinkSpec,
    busy_until: Time,
    rng: Pcg32,
    // stats
    bytes: u64,
    transfers: u64,
    drops: u64,
    busy_time: Time,
    /// Busy time minus the fixed per-transfer setup: the share actually
    /// spent streaming bytes, so `bytes*8/stream_time` recovers the
    /// delivered bandwidth even for small payloads (the elastic control
    /// loop's WAN observation).
    stream_time: Time,
    queue_delay: Time,
    /// Outage windows (failure injection): transfers cannot start inside.
    outages: Vec<(Time, Time)>,
    /// Per-lane serialization horizon (lanes mode; lane 0 = Control).
    lane_busy: [Time; TrafficClass::COUNT],
    /// Per-lane traffic attribution (kept in both modes — accounting only,
    /// never consulted by the scheduler).
    lane: [LaneStats; TrafficClass::COUNT],
}

/// Per-lane share of a link's statistics (see [`TrafficClass::lane`] for
/// the index order: Control, Barrier, Gradient, BulkData).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LaneStats {
    pub bytes: u64,
    pub transfers: u64,
    pub busy_time: Time,
}

/// Per-link statistics snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkStats {
    pub bytes: u64,
    pub transfers: u64,
    pub drops: u64,
    pub busy_time: Time,
    /// Serialization time net of per-transfer setup (see `Link`):
    /// `Δbytes * 8 / Δstream_time` over an observation window is the
    /// delivered-bandwidth estimate the elastic control loop samples.
    pub stream_time: Time,
    pub queue_delay: Time,
    /// Per-traffic-class attribution of `bytes`/`transfers`/`busy_time`
    /// (delivered transfers only; drops are not attributed to a lane).
    pub lanes: [LaneStats; TrafficClass::COUNT],
}

/// The network fabric: directed (from, to) -> link.
pub struct Fabric {
    links: BTreeMap<(RegionId, RegionId), Link>,
    default_lan: LinkSpec,
    seed: u64,
    lanes: bool,
}

impl Fabric {
    pub fn new(seed: u64) -> Self {
        Fabric { links: BTreeMap::new(), default_lan: LinkSpec::lan(), seed, lanes: false }
    }

    /// Enable or disable priority-lane scheduling (default: off, the
    /// historical single-FIFO behavior — byte-identical timings).
    pub fn set_lanes(&mut self, on: bool) {
        self.lanes = on;
    }

    /// Whether priority-lane scheduling is active.
    pub fn lanes_enabled(&self) -> bool {
        self.lanes
    }

    /// Install a directed link. For a symmetric WAN install both directions
    /// (they fluctuate independently, as real paths do).
    pub fn add_link(&mut self, from: RegionId, to: RegionId, spec: LinkSpec) {
        let stream = 0x11AA ^ ((from as u64) << 32) ^ to as u64;
        self.links.insert(
            (from, to),
            Link {
                spec,
                busy_until: 0.0,
                rng: Pcg32::new(self.seed, stream),
                bytes: 0,
                transfers: 0,
                drops: 0,
                busy_time: 0.0,
                stream_time: 0.0,
                queue_delay: 0.0,
                outages: Vec::new(),
                lane_busy: [0.0; TrafficClass::COUNT],
                lane: [LaneStats::default(); TrafficClass::COUNT],
            },
        );
    }

    /// Install the same spec in both directions.
    pub fn add_duplex(&mut self, a: RegionId, b: RegionId, spec: LinkSpec) {
        self.add_link(a, b, spec.clone());
        self.add_link(b, a, spec);
    }

    /// The standard WAN build: a full directed mesh over `n` regions at
    /// `link`, then per-pair `overrides` — what both the single-job
    /// driver and the multi-job fleet install.
    pub fn full_mesh(
        seed: u64,
        n: usize,
        link: &LinkSpec,
        overrides: &[(RegionId, RegionId, LinkSpec)],
    ) -> Fabric {
        let mut f = Fabric::new(seed);
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    f.add_link(a, b, link.clone());
                }
            }
        }
        for (a, b, spec) in overrides {
            f.add_link(*a, *b, spec.clone());
        }
        f
    }

    /// Inject an outage window on a directed link.
    pub fn add_outage(&mut self, from: RegionId, to: RegionId, from_t: Time, to_t: Time) {
        if let Some(l) = self.links.get_mut(&(from, to)) {
            l.outages.push((from_t, to_t));
        }
    }

    /// Mutate a directed link's nominal bandwidth mid-run (WAN churn
    /// injection; subsequent transfers and planning reads see the new
    /// value). No-op on links that were never installed.
    pub fn set_bandwidth(&mut self, from: RegionId, to: RegionId, bps: f64) {
        if let Some(l) = self.links.get_mut(&(from, to)) {
            l.spec.bandwidth_bps = bps.max(1.0);
        }
    }

    fn ensure_link(&mut self, from: RegionId, to: RegionId) -> &mut Link {
        if !self.links.contains_key(&(from, to)) {
            let spec = self.default_lan.clone();
            self.add_link(from, to, spec);
        }
        self.links.get_mut(&(from, to)).unwrap()
    }

    /// Schedule a transfer of `bytes` submitted at `now`; returns its
    /// timing. Untagged traffic rides the [`TrafficClass::Gradient`] lane.
    pub fn transfer(&mut self, from: RegionId, to: RegionId, bytes: u64, now: Time) -> Transfer {
        self.transfer_class(from, to, bytes, now, TrafficClass::Gradient)
    }

    /// Schedule a transfer of `bytes` of traffic class `class` submitted
    /// at `now`; returns its timing.
    ///
    /// Lanes off (default): `class` affects only the per-lane statistics
    /// attribution — queueing is the single FIFO, identical to the
    /// historical [`Fabric::transfer`]. Lanes on: the transfer queues
    /// behind its own lane, yields to higher-priority lanes for at most
    /// [`MAX_PRIORITY_WAIT_S`], and ignores lower-priority backlogs. The
    /// RNG draw order (drop, then fluctuation) is the same in both modes,
    /// so toggling lanes never perturbs the stochastic stream.
    pub fn transfer_class(
        &mut self,
        from: RegionId,
        to: RegionId,
        bytes: u64,
        now: Time,
        class: TrafficClass,
    ) -> Transfer {
        let lanes = self.lanes;
        let link = self.ensure_link(from, to);
        link.transfers += 1;

        if link.spec.drop_prob > 0.0 && (link.rng.f64() as f64) < link.spec.drop_prob {
            link.drops += 1;
            return Transfer { start: now, done: now, arrival: f64::INFINITY, dropped: true };
        }

        let c = class.lane();
        let mut start = if lanes {
            // Own-lane backlog is binding; higher-priority backlog yields
            // a bounded wait; lower-priority backlog is preempted at the
            // next serialization boundary (modeled as no wait at all).
            let higher =
                link.lane_busy[..c].iter().copied().fold(f64::NEG_INFINITY, f64::max);
            now.max(link.lane_busy[c]).max(higher.min(now + MAX_PRIORITY_WAIT_S))
        } else {
            now.max(link.busy_until)
        };
        // Outage windows push the start past the window end.
        for &(o_from, o_to) in &link.outages {
            if start >= o_from && start < o_to {
                start = o_to;
            }
        }
        let fluct = if link.spec.fluct_sigma > 0.0 {
            link.rng.lognormal_mean1(link.spec.fluct_sigma)
        } else {
            1.0
        };
        let stream = (bytes as f64) * 8.0 / (link.spec.bandwidth_bps * fluct);
        let ser = link.spec.setup_s + stream;
        let done = start + ser;
        let arrival = done + link.spec.latency_s;

        link.queue_delay += start - now;
        link.busy_time += ser;
        link.stream_time += stream;
        if lanes {
            link.lane_busy[c] = done;
            link.busy_until = link.busy_until.max(done);
        } else {
            link.busy_until = done;
        }
        link.bytes += bytes;
        link.lane[c].bytes += bytes;
        link.lane[c].transfers += 1;
        link.lane[c].busy_time += ser;
        Transfer { start, done, arrival, dropped: false }
    }

    /// Pure estimate (no state change): expected transfer seconds at
    /// nominal bandwidth. Used by analytic experiments (Fig 3).
    pub fn estimate(&self, from: RegionId, to: RegionId, bytes: u64) -> Time {
        let spec = self
            .links
            .get(&(from, to))
            .map(|l| l.spec.clone())
            .unwrap_or_else(|| self.default_lan.clone());
        spec.setup_s + (bytes as f64) * 8.0 / spec.bandwidth_bps + spec.latency_s
    }

    /// Nominal bandwidth (bits/s) of an installed directed link — the
    /// planning input for bandwidth-aware sync topologies
    /// (`engine::topology`). `None` when no link has been installed.
    pub fn link_bandwidth(&self, from: RegionId, to: RegionId) -> Option<f64> {
        self.links.get(&(from, to)).map(|l| l.spec.bandwidth_bps)
    }

    /// Full spec of an installed directed link (the data-plane placement
    /// planner's transfer-time inputs). `None` when no link is installed.
    pub fn link_spec(&self, from: RegionId, to: RegionId) -> Option<LinkSpec> {
        self.links.get(&(from, to)).map(|l| l.spec.clone())
    }

    /// One-way propagation latency of an installed directed link (the
    /// communicator's ack-RTT share). `None` when no link is installed.
    pub fn link_latency(&self, from: RegionId, to: RegionId) -> Option<f64> {
        self.links.get(&(from, to)).map(|l| l.spec.latency_s)
    }

    pub fn stats(&self, from: RegionId, to: RegionId) -> Option<LinkStats> {
        self.links.get(&(from, to)).map(|l| LinkStats {
            bytes: l.bytes,
            transfers: l.transfers,
            drops: l.drops,
            busy_time: l.busy_time,
            stream_time: l.stream_time,
            queue_delay: l.queue_delay,
            lanes: l.lane,
        })
    }

    /// Total bytes carried on all inter-region links (WAN traffic for the
    /// cost model).
    pub fn total_wan_bytes(&self) -> u64 {
        self.links
            .iter()
            .filter(|((a, b), _)| a != b)
            .map(|(_, l)| l.bytes)
            .sum()
    }
}

/// A cloneable handle to one [`Fabric`] shared by several concurrently
/// simulated training jobs (the multi-job coordinator's WAN): every clone
/// sees the same FIFO queues, fluctuation streams, and statistics, so a
/// transfer issued by one job delays the next job's payload on the same
/// directed link — real cross-job WAN contention, not N private copies.
///
/// The API mirrors the [`Fabric`] methods the engine uses; interior
/// mutability keeps call sites identical whether the fabric is private
/// (single-job `run_geo_training`) or shared (a job fleet).
#[derive(Clone)]
pub struct SharedFabric(Rc<RefCell<Fabric>>);

impl SharedFabric {
    pub fn new(fabric: Fabric) -> SharedFabric {
        SharedFabric(Rc::new(RefCell::new(fabric)))
    }

    /// Install a directed link (see [`Fabric::add_link`]).
    pub fn add_link(&self, from: RegionId, to: RegionId, spec: LinkSpec) {
        self.0.borrow_mut().add_link(from, to, spec)
    }

    /// Schedule a transfer (see [`Fabric::transfer`]).
    pub fn transfer(&self, from: RegionId, to: RegionId, bytes: u64, now: Time) -> Transfer {
        self.0.borrow_mut().transfer(from, to, bytes, now)
    }

    /// Schedule a class-tagged transfer (see [`Fabric::transfer_class`]).
    pub fn transfer_class(
        &self,
        from: RegionId,
        to: RegionId,
        bytes: u64,
        now: Time,
        class: TrafficClass,
    ) -> Transfer {
        self.0.borrow_mut().transfer_class(from, to, bytes, now, class)
    }

    /// Enable or disable priority-lane scheduling (see [`Fabric::set_lanes`]).
    pub fn set_lanes(&self, on: bool) {
        self.0.borrow_mut().set_lanes(on)
    }

    /// Mutate a directed link's nominal bandwidth mid-run.
    pub fn set_bandwidth(&self, from: RegionId, to: RegionId, bps: f64) {
        self.0.borrow_mut().set_bandwidth(from, to, bps)
    }

    /// Nominal bandwidth of an installed directed link.
    pub fn link_bandwidth(&self, from: RegionId, to: RegionId) -> Option<f64> {
        self.0.borrow().link_bandwidth(from, to)
    }

    /// One-way propagation latency of an installed directed link.
    pub fn link_latency(&self, from: RegionId, to: RegionId) -> Option<f64> {
        self.0.borrow().link_latency(from, to)
    }

    /// Per-link statistics snapshot (aggregated over every sharing job).
    pub fn stats(&self, from: RegionId, to: RegionId) -> Option<LinkStats> {
        self.0.borrow().stats(from, to)
    }

    /// Total bytes carried on all inter-region links, across every job
    /// sharing this fabric.
    pub fn total_wan_bytes(&self) -> u64 {
        self.0.borrow().total_wan_bytes()
    }

    /// Run a closure against the underlying [`Fabric`] (planning reads
    /// that take `&Fabric`, e.g. `engine::topology` plans).
    pub fn with<R>(&self, f: impl FnOnce(&Fabric) -> R) -> R {
        f(&self.0.borrow())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stable_wan() -> LinkSpec {
        LinkSpec { bandwidth_bps: 100e6, latency_s: 0.015, fluct_sigma: 0.0, drop_prob: 0.0, setup_s: 0.0 }
    }

    #[test]
    fn serialization_time_exact_when_stable() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        // 48 MB at 100 Mbps = 3.84 s  (the paper's ResNet18 sync payload)
        let t = f.transfer(0, 1, 48_000_000, 0.0);
        assert!((t.done - 3.84).abs() < 1e-9, "{t:?}");
        assert!((t.arrival - 3.855).abs() < 1e-9);
    }

    #[test]
    fn fifo_queueing() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        let t1 = f.transfer(0, 1, 12_500_000, 0.0); // 1.0 s
        let t2 = f.transfer(0, 1, 12_500_000, 0.2); // queued behind t1
        assert!((t1.done - 1.0).abs() < 1e-9);
        assert!((t2.start - 1.0).abs() < 1e-9);
        assert!((t2.done - 2.0).abs() < 1e-9);
        let st = f.stats(0, 1).unwrap();
        assert!((st.queue_delay - 0.8).abs() < 1e-9);
    }

    #[test]
    fn directions_are_independent() {
        let mut f = Fabric::new(1);
        f.add_duplex(0, 1, stable_wan());
        let fwd = f.transfer(0, 1, 12_500_000, 0.0);
        let rev = f.transfer(1, 0, 12_500_000, 0.0);
        assert!((fwd.start - 0.0).abs() < 1e-12);
        assert!((rev.start - 0.0).abs() < 1e-12, "reverse path must not queue behind forward");
    }

    #[test]
    fn fluctuation_changes_times_but_is_deterministic() {
        let run = |seed| {
            let mut f = Fabric::new(seed);
            f.add_link(0, 1, LinkSpec::wan_100mbps());
            (0..10).map(|i| f.transfer(0, 1, 1_000_000, i as f64 * 10.0).done).collect::<Vec<_>>()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b, "same seed must replay identically");
        assert_ne!(a, c, "different seed should differ");
        // Mean-1 fluctuation: average serialization near nominal (incl. setup).
        let nominal = 1_000_000.0 * 8.0 / 100e6 + LinkSpec::wan_100mbps().setup_s;
        let avg: f64 =
            a.iter().zip(0..).map(|(d, i)| d - (i as f64 * 10.0)).sum::<f64>() / a.len() as f64;
        assert!((avg - nominal).abs() < nominal, "avg {avg} vs nominal {nominal}");
    }

    #[test]
    fn default_lan_for_unknown_pairs() {
        let mut f = Fabric::new(1);
        let t = f.transfer(3, 3, 10_000_000, 0.0);
        assert!(t.done < 0.01, "LAN transfer should be fast: {t:?}");
    }

    #[test]
    fn drops_and_outages() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, LinkSpec { drop_prob: 1.0, ..stable_wan() });
        let t = f.transfer(0, 1, 1000, 0.0);
        assert!(t.dropped);
        assert_eq!(f.stats(0, 1).unwrap().drops, 1);

        let mut f2 = Fabric::new(1);
        f2.add_link(0, 1, stable_wan());
        f2.add_outage(0, 1, 0.0, 5.0);
        let t2 = f2.transfer(0, 1, 1000, 1.0);
        assert!(t2.start >= 5.0, "transfer must wait out the outage: {t2:?}");
    }

    #[test]
    fn stream_time_excludes_setup_overhead() {
        // A tiny payload on a link with a big setup cost: naive
        // bytes/busy_time would read kilobits; bytes/stream_time (the
        // elastic loop's delivered-bandwidth estimate) recovers the true
        // line rate.
        let mut f = Fabric::new(1);
        f.add_link(0, 1, LinkSpec { setup_s: 0.09, ..stable_wan() });
        f.transfer(0, 1, 1000, 0.0); // 80 us of streaming at 100 Mbps
        let st = f.stats(0, 1).unwrap();
        let bw = st.bytes as f64 * 8.0 / st.stream_time;
        assert!((bw - 100e6).abs() < 1.0, "delivered {bw} != line rate");
        assert!(st.busy_time > 0.09, "busy time still includes setup");
        // No traffic -> no streaming time to divide by.
        let mut f2 = Fabric::new(1);
        f2.add_link(0, 1, stable_wan());
        assert_eq!(f2.stats(0, 1).unwrap().stream_time, 0.0);
    }

    #[test]
    fn set_bandwidth_changes_subsequent_transfers() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        let fast = f.transfer(0, 1, 12_500_000, 0.0); // 1.0 s at 100 Mbps
        f.set_bandwidth(0, 1, 10e6);
        let slow = f.transfer(0, 1, 12_500_000, 10.0); // 10 s at 10 Mbps
        assert!((fast.done - 1.0).abs() < 1e-9);
        assert!((slow.done - 20.0).abs() < 1e-9, "{slow:?}");
        assert_eq!(f.link_bandwidth(0, 1), Some(10e6));
    }

    #[test]
    fn full_mesh_installs_every_directed_pair_and_overrides() {
        let slow = LinkSpec { bandwidth_bps: 10e6, ..stable_wan() };
        let f = Fabric::full_mesh(1, 3, &stable_wan(), &[(0, 2, slow)]);
        for a in 0..3 {
            for b in 0..3 {
                if a != b {
                    assert!(f.link_bandwidth(a, b).is_some(), "missing link {a}->{b}");
                }
            }
        }
        assert_eq!(f.link_bandwidth(0, 2), Some(10e6), "override applied after the mesh");
        assert_eq!(f.link_bandwidth(0, 0), None, "no self links");
    }

    #[test]
    fn shared_fabric_clones_contend_on_one_link() {
        // Two jobs holding clones of the same fabric: the second job's
        // transfer queues behind the first's on the shared FIFO link.
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        let shared = SharedFabric::new(f);
        let job_a = shared.clone();
        let job_b = shared.clone();
        let t1 = job_a.transfer(0, 1, 12_500_000, 0.0); // 1.0 s
        let t2 = job_b.transfer(0, 1, 12_500_000, 0.2); // queued behind job A
        assert!((t1.done - 1.0).abs() < 1e-9);
        assert!((t2.start - 1.0).abs() < 1e-9, "cross-job queueing: {t2:?}");
        // Stats and bandwidth mutations are visible through every clone.
        assert_eq!(shared.stats(0, 1).unwrap().transfers, 2);
        job_a.set_bandwidth(0, 1, 10e6);
        assert_eq!(job_b.link_bandwidth(0, 1), Some(10e6));
        assert_eq!(shared.total_wan_bytes(), 25_000_000);
        assert_eq!(shared.with(|f| f.estimate(0, 1, 0) > 0.0), true);
    }

    #[test]
    fn lanes_off_transfer_class_matches_fifo() {
        // With lanes disabled, class-tagged transfers schedule exactly
        // like the historical FIFO — same Transfer timings, same
        // aggregate stats — regardless of the class mix.
        let classes = [
            TrafficClass::BulkData,
            TrafficClass::Control,
            TrafficClass::Gradient,
            TrafficClass::Barrier,
            TrafficClass::BulkData,
        ];
        let mut fifo = Fabric::new(9);
        let mut tagged = Fabric::new(9);
        fifo.add_link(0, 1, LinkSpec::wan_100mbps());
        tagged.add_link(0, 1, LinkSpec::wan_100mbps());
        for (i, class) in classes.iter().enumerate() {
            let t = i as f64 * 0.1;
            let a = fifo.transfer(0, 1, 1_000_000, t);
            let b = tagged.transfer_class(0, 1, 1_000_000, t, *class);
            assert_eq!(a, b, "lanes-off transfer {i} diverged");
        }
        let sa = fifo.stats(0, 1).unwrap();
        let sb = tagged.stats(0, 1).unwrap();
        assert_eq!((sa.bytes, sa.transfers, sa.busy_time, sa.queue_delay),
                   (sb.bytes, sb.transfers, sb.busy_time, sb.queue_delay));
    }

    #[test]
    fn lanes_on_priority_preempts_bulk_backlog() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        f.set_lanes(true);
        // 10 s of bulk backlog, then a barrier submitted at t=0.5: it
        // must start immediately, not behind the migration.
        f.transfer_class(0, 1, 125_000_000, 0.0, TrafficClass::BulkData); // 10 s
        let b = f.transfer_class(0, 1, 125_000, 0.5, TrafficClass::Barrier); // 10 ms
        assert!((b.start - 0.5).abs() < 1e-9, "barrier queued behind bulk: {b:?}");
        // But a second barrier queues behind the first (its own lane).
        let b2 = f.transfer_class(0, 1, 125_000, 0.5, TrafficClass::Barrier);
        assert!((b2.start - b.done).abs() < 1e-9, "{b2:?}");
    }

    #[test]
    fn lanes_on_bulk_wait_is_bounded() {
        // Adversarial Control flood: bulk still starts within
        // MAX_PRIORITY_WAIT_S — the no-starvation bound.
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        f.set_lanes(true);
        for i in 0..100 {
            f.transfer_class(0, 1, 12_500_000, i as f64 * 0.01, TrafficClass::Control);
        }
        let bulk = f.transfer_class(0, 1, 1_000_000, 2.0, TrafficClass::BulkData);
        assert!(
            bulk.start <= 2.0 + MAX_PRIORITY_WAIT_S + 1e-9,
            "bulk starved past the bound: {bulk:?}"
        );
    }

    #[test]
    fn lane_stats_conserve_link_bytes() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, LinkSpec::wan_100mbps());
        f.set_lanes(true);
        f.transfer_class(0, 1, 100, 0.0, TrafficClass::Control);
        f.transfer_class(0, 1, 2_000, 0.0, TrafficClass::Barrier);
        f.transfer_class(0, 1, 30_000, 0.0, TrafficClass::Gradient);
        f.transfer_class(0, 1, 400_000, 0.0, TrafficClass::BulkData);
        let st = f.stats(0, 1).unwrap();
        assert_eq!(st.lanes.iter().map(|l| l.bytes).sum::<u64>(), st.bytes);
        assert_eq!(st.lanes.iter().map(|l| l.transfers).sum::<u64>(), st.transfers);
        assert_eq!(st.lanes[TrafficClass::BulkData.lane()].bytes, 400_000);
    }

    #[test]
    fn wan_bytes_excludes_intra_region() {
        let mut f = Fabric::new(1);
        f.add_link(0, 1, stable_wan());
        f.transfer(0, 1, 500, 0.0);
        f.transfer(2, 2, 999, 0.0);
        assert_eq!(f.total_wan_bytes(), 500);
    }
}

//! Gradient compression — the *other* family of WAN-synchronization
//! optimizations the paper surveys (§II.C: "compressing data size of
//! synchronization, like DGC, top-K") but does not adopt. Implemented
//! here as an extension so the ablation bench can compare *compression*
//! against the paper's *frequency reduction* on the same link model.
//!
//! Two codecs:
//! - [`TopK`]: keep the k largest-magnitude coordinates (DGC-style
//!   sparsification, error feedback left to the caller via residuals);
//! - [`QuantQ8`]: linear int8 quantization with per-chunk scales.
//!
//! Both encode to a compact wire format (what the WAN fabric bills) and
//! decode back to a dense vector.

use crate::util::rng::Pcg32;

/// A compressed gradient on the wire.
#[derive(Debug, Clone)]
pub enum Compressed {
    /// (indices, values, original length)
    Sparse { idx: Vec<u32>, val: Vec<f32>, len: usize },
    /// (per-chunk scales, int8 payload, original length, chunk size)
    Quant { scales: Vec<f32>, data: Vec<i8>, len: usize, chunk: usize },
}

impl Compressed {
    /// Bytes this payload occupies on the WAN (plus a small header).
    pub fn wire_bytes(&self) -> u64 {
        let body = match self {
            Compressed::Sparse { idx, val, .. } => idx.len() * 4 + val.len() * 4,
            Compressed::Quant { scales, data, .. } => scales.len() * 4 + data.len(),
        };
        body as u64 + 64
    }

    /// Decode back to a dense vector.
    pub fn decode(&self) -> Vec<f32> {
        match self {
            Compressed::Sparse { idx, val, len } => {
                let mut out = vec![0.0f32; *len];
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out[i as usize] = v;
                }
                out
            }
            Compressed::Quant { scales, data, len, chunk } => {
                let mut out = Vec::with_capacity(*len);
                for (ci, block) in data.chunks(*chunk).enumerate() {
                    let s = scales[ci];
                    for &q in block {
                        out.push(q as f32 * s);
                    }
                }
                out.truncate(*len);
                out
            }
        }
    }
}

/// Top-k magnitude sparsification. Returns the compressed payload and the
/// residual (what error feedback re-accumulates locally, DGC-style).
pub struct TopK {
    /// Fraction of coordinates kept (0 < ratio <= 1).
    pub ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0, "ratio must be in (0, 1]");
        TopK { ratio }
    }

    pub fn encode(&self, g: &[f32]) -> (Compressed, Vec<f32>) {
        let len = g.len();
        let k = ((len as f64 * self.ratio).ceil() as usize).clamp(1, len);
        // Threshold selection via partial sort of magnitudes.
        let mut order: Vec<u32> = (0..len as u32).collect();
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            g[b as usize]
                .abs()
                .partial_cmp(&g[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        let val: Vec<f32> = idx.iter().map(|&i| g[i as usize]).collect();
        let mut residual = g.to_vec();
        for &i in &idx {
            residual[i as usize] = 0.0;
        }
        (Compressed::Sparse { idx, val, len }, residual)
    }
}

/// Linear int8 quantization with per-chunk max-abs scaling.
pub struct QuantQ8 {
    pub chunk: usize,
}

impl Default for QuantQ8 {
    fn default() -> Self {
        QuantQ8 { chunk: 2048 }
    }
}

impl QuantQ8 {
    pub fn encode(&self, g: &[f32]) -> Compressed {
        let chunk = self.chunk.max(1);
        let mut scales = Vec::with_capacity(g.len().div_ceil(chunk));
        let mut data = Vec::with_capacity(g.len());
        for block in g.chunks(chunk) {
            let max = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
            scales.push(scale);
            for &x in block {
                data.push((x / scale).round().clamp(-127.0, 127.0) as i8);
            }
        }
        Compressed::Quant { scales, data, len: g.len(), chunk }
    }
}

/// Stochastic top-k sampling baseline (for comparison against exact
/// top-k): keeps k uniformly random coordinates.
pub fn random_k(g: &[f32], ratio: f64, rng: &mut Pcg32) -> (Compressed, Vec<f32>) {
    let len = g.len();
    let k = ((len as f64 * ratio).ceil() as usize).clamp(1, len);
    let mut order: Vec<u32> = (0..len as u32).collect();
    rng.shuffle(&mut order);
    let mut idx: Vec<u32> = order[..k].to_vec();
    idx.sort_unstable();
    let val: Vec<f32> = idx.iter().map(|&i| g[i as usize]).collect();
    let mut residual = g.to_vec();
    for &i in &idx {
        residual[i as usize] = 0.0;
    }
    (Compressed::Sparse { idx, val, len }, residual)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i as f32 * 0.37).sin()) * (1.0 + (i % 17) as f32)).collect()
    }

    #[test]
    fn topk_keeps_largest_and_residual_complements() {
        let g = grad(1000);
        let (c, residual) = TopK::new(0.1).encode(&g);
        let decoded = c.decode();
        // decoded + residual == g exactly
        for i in 0..g.len() {
            assert_eq!(decoded[i] + residual[i], g[i]);
        }
        // kept values dominate dropped values in magnitude
        let kept_min = decoded
            .iter()
            .filter(|v| **v != 0.0)
            .map(|v| v.abs())
            .fold(f32::MAX, f32::min);
        let dropped_max = residual.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(kept_min >= dropped_max, "{kept_min} < {dropped_max}");
        // 10% of 1000 = 100 coordinates
        match &c {
            Compressed::Sparse { idx, .. } => assert_eq!(idx.len(), 100),
            _ => panic!(),
        }
    }

    #[test]
    fn topk_wire_savings() {
        let g = grad(10_000);
        let dense_bytes = (g.len() * 4) as u64;
        let (c, _) = TopK::new(0.01).encode(&g);
        assert!(c.wire_bytes() < dense_bytes / 10, "{} vs {}", c.wire_bytes(), dense_bytes);
    }

    #[test]
    fn topk_ratio_one_is_lossless() {
        let g = grad(64);
        let (c, residual) = TopK::new(1.0).encode(&g);
        assert_eq!(c.decode(), g);
        assert!(residual.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quant_roundtrip_error_bounded() {
        let g = grad(5000);
        let c = QuantQ8::default().encode(&g);
        let decoded = c.decode();
        assert_eq!(decoded.len(), g.len());
        for block in g.chunks(2048).zip(decoded.chunks(2048)) {
            let max = block.0.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let step = max / 127.0;
            for (a, b) in block.0.iter().zip(block.1.iter()) {
                assert!((a - b).abs() <= step * 0.5 + 1e-6, "{a} vs {b} (step {step})");
            }
        }
        // ~4x smaller than dense f32
        assert!(c.wire_bytes() < (g.len() as u64 * 4) / 3);
    }

    #[test]
    fn quant_handles_zeros_and_tail() {
        let c = QuantQ8 { chunk: 8 }.encode(&[0.0; 20]);
        assert_eq!(c.decode(), vec![0.0; 20]);
        let g = grad(13); // non-multiple of chunk
        let c2 = QuantQ8 { chunk: 8 }.encode(&g);
        assert_eq!(c2.decode().len(), 13);
    }

    #[test]
    fn random_k_residual_complements() {
        let g = grad(200);
        let mut rng = Pcg32::new(1, 2);
        let (c, residual) = random_k(&g, 0.25, &mut rng);
        let decoded = c.decode();
        for i in 0..g.len() {
            assert_eq!(decoded[i] + residual[i], g[i]);
        }
    }

    #[test]
    fn topk_beats_random_k_in_captured_energy() {
        let g = grad(2000);
        let energy = |v: &[f32]| v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
        let (top, _) = TopK::new(0.05).encode(&g);
        let mut rng = Pcg32::new(3, 4);
        let (rnd, _) = random_k(&g, 0.05, &mut rng);
        assert!(energy(&top.decode()) > energy(&rnd.decode()) * 2.0);
    }

    #[test]
    #[should_panic]
    fn zero_ratio_rejected() {
        TopK::new(0.0);
    }
}

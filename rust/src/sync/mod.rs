//! WAN model-synchronization strategies — the paper's §III.C.
//!
//! Four strategies over the basic WAN sync mechanism (each PS sends its
//! state to exactly **one** peer PS per sync; the global communicator
//! plans the topology):
//!
//! | strategy | condition        | payload              | pattern      | receiver update |
//! |----------|------------------|----------------------|--------------|-----------------|
//! | ASGD     | every update     | accumulated gradient | asynchronous | SGD             |
//! | ASGD-GA  | every F updates  | accumulated gradient | asynchronous | SGD             |
//! | AMA      | every F updates  | model parameters     | asynchronous | averaging       |
//! | SMA      | every F updates  | model parameters     | barrier      | averaging       |
//!
//! ASGD (freq=1) is the paper's baseline — "a simple multi-regional cloud
//! variant of trivial ML training". ASGD-GA keeps merging local gradients
//! between syncs so no information is lost, only freshness. MA variants
//! ship parameters and average on receipt; the averaging weight comes
//! from the sync topology's per-edge plan (`engine::topology`, Metropolis
//! weights over the undirected support — 0.5 between two clouds,
//! matching the paper's setting — applied through sequential-arrival
//! compensation at the receiver).

pub mod compression;

use compression::{Compressed, QuantQ8, TopK};

use crate::ps::PsState;

/// Which of the paper's strategies to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Baseline: asynchronous SGD, sync every local update.
    Asgd,
    /// Asynchronous SGD with gradient accumulation (sync every `freq`).
    AsgdGa,
    /// Inter-PS model averaging, asynchronous pattern.
    Ama,
    /// Inter-PS model averaging, synchronous (barrier) pattern.
    Sma,
}

impl Strategy {
    /// Parse a strategy name (case-insensitive); `"ma"` is accepted as an
    /// alias for the asynchronous model-averaging variant. The error
    /// message lists every valid name, so CLI/config callers can surface
    /// it verbatim.
    ///
    /// This is the `"strategy"` config key / `--strategy` flag surface
    /// (see docs/CONFIG.md):
    ///
    /// ```
    /// use cloudless::sync::{Strategy, SyncConfig};
    ///
    /// let s = Strategy::from_name("asgd-ga").unwrap();
    /// assert_eq!(s, Strategy::AsgdGa);
    /// assert_eq!(Strategy::from_name("ma").unwrap(), Strategy::Ama);
    ///
    /// // ASGD pins the sync frequency to 1 (the paper's baseline).
    /// let cfg = SyncConfig::new(Strategy::from_name("asgd").unwrap(), 8);
    /// assert_eq!(cfg.freq, 1);
    ///
    /// // Unknown names return the full list of valid ones.
    /// let err = Strategy::from_name("nope").unwrap_err();
    /// assert!(err.contains("asgd-ga") && err.contains("sma"));
    /// ```
    pub fn from_name(s: &str) -> Result<Strategy, String> {
        match s.to_ascii_lowercase().as_str() {
            "asgd" | "baseline" => Ok(Strategy::Asgd),
            "asgd-ga" | "asgd_ga" | "ga" => Ok(Strategy::AsgdGa),
            "ama" | "ma" => Ok(Strategy::Ama),
            "sma" => Ok(Strategy::Sma),
            other => Err(format!(
                "unknown sync strategy {other:?} (valid: asgd, asgd-ga, ama, ma, sma)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Asgd => "ASGD",
            Strategy::AsgdGa => "ASGD-GA",
            Strategy::Ama => "AMA",
            Strategy::Sma => "SMA",
        }
    }

    /// True for barrier-style strategies.
    pub fn is_synchronous(&self) -> bool {
        matches!(self, Strategy::Sma)
    }

    /// True if the payload is a gradient (vs model parameters).
    pub fn sends_gradient(&self) -> bool {
        matches!(self, Strategy::Asgd | Strategy::AsgdGa)
    }
}

/// Optional gradient compression (extension beyond the paper; see
/// [`compression`]). Applies to gradient payloads only — MA ships full
/// parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compression {
    None,
    /// DGC-style top-k sparsification with error feedback; keeps `ratio`
    /// of coordinates.
    TopK { ratio: f64 },
    /// Linear int8 quantization (per-2048-chunk scales).
    Q8,
}

impl Compression {
    /// Parse a codec name (case-insensitive): `"none"`, `"q8"`, or
    /// `"topk"` with an optional `:ratio` suffix. This is the
    /// `"compression"` config key / `--compression` flag surface (see
    /// docs/CONFIG.md); the experimental random-k codec
    /// ([`compression::random_k`]) is ablation-only and has no config
    /// name.
    ///
    /// ```
    /// use cloudless::sync::{Compression, Strategy, SyncConfig};
    ///
    /// assert_eq!(Compression::from_name("none").unwrap(), Compression::None);
    /// assert_eq!(Compression::from_name("q8").unwrap(), Compression::Q8);
    /// assert_eq!(
    ///     Compression::from_name("topk:0.25").unwrap(),
    ///     Compression::TopK { ratio: 0.25 },
    /// );
    /// // Bare "topk" uses the DGC-style 1% default.
    /// assert_eq!(
    ///     Compression::from_name("topk").unwrap(),
    ///     Compression::TopK { ratio: 0.01 },
    /// );
    ///
    /// // Codecs ride on the sync config; they only shrink gradient
    /// // payloads (model-averaging strategies ship full parameters).
    /// let cfg = SyncConfig::new(Strategy::AsgdGa, 8)
    ///     .with_compression(Compression::from_name("q8").unwrap());
    /// assert_eq!(cfg.compression, Compression::Q8);
    ///
    /// assert!(Compression::from_name("gzip").is_err());
    /// assert!(Compression::from_name("topk:0").is_err());
    /// ```
    pub fn from_name(s: &str) -> Result<Compression, String> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "none" => Ok(Compression::None),
            "q8" | "quantq8" | "int8" => Ok(Compression::Q8),
            other => match other.strip_prefix("topk") {
                Some(rest) => {
                    let ratio = match rest.strip_prefix(':') {
                        None if rest.is_empty() => 0.01,
                        Some(r) => r.parse::<f64>().map_err(|_| {
                            format!("bad top-k ratio {r:?} (want e.g. \"topk:0.25\")")
                        })?,
                        None => {
                            return Err(format!(
                                "unknown compression {other:?} (valid: none, topk[:ratio], q8)"
                            ))
                        }
                    };
                    if !(ratio > 0.0 && ratio <= 1.0) {
                        return Err(format!("top-k ratio must be in (0, 1], got {ratio}"));
                    }
                    Ok(Compression::TopK { ratio })
                }
                None => Err(format!(
                    "unknown compression {other:?} (valid: none, topk[:ratio], q8)"
                )),
            },
        }
    }

    /// Stable name (inverse of [`Compression::from_name`]).
    pub fn name(&self) -> String {
        match self {
            Compression::None => "none".to_string(),
            Compression::TopK { ratio } => format!("topk:{ratio}"),
            Compression::Q8 => "q8".to_string(),
        }
    }
}

/// Full synchronization configuration. (Averaging weights are no longer
/// part of this config: they are planned per edge by the sync topology —
/// see `engine::topology`.)
#[derive(Debug, Clone, Copy)]
pub struct SyncConfig {
    pub strategy: Strategy,
    /// Synchronization frequency in local updates (ASGD pins this to 1).
    pub freq: u32,
    /// Gradient compression codec (extension; default None).
    pub compression: Compression,
}

impl SyncConfig {
    pub fn new(strategy: Strategy, freq: u32) -> SyncConfig {
        let freq = if strategy == Strategy::Asgd { 1 } else { freq.max(1) };
        SyncConfig { strategy, freq, compression: Compression::None }
    }

    pub fn with_compression(mut self, c: Compression) -> SyncConfig {
        self.compression = c;
        self
    }

    pub fn baseline() -> SyncConfig {
        SyncConfig::new(Strategy::Asgd, 1)
    }

    /// The synchronization condition: sync after this local update?
    pub fn should_sync(&self, ps: &PsState) -> bool {
        ps.updates_since_sync >= self.freq
    }
}

/// What travels over the WAN between PS communicators.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Accumulated gradient + how many worker steps it merged.
    Gradient { grad: Vec<f32>, steps: u32 },
    /// Compressed accumulated gradient (extension codecs).
    CompressedGradient { packed: Compressed, steps: u32 },
    /// Model parameters for averaging.
    Params(Vec<f32>),
}

impl Payload {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Gradient { grad, .. } => (grad.len() * 4) as u64 + 64,
            Payload::CompressedGradient { packed, .. } => packed.wire_bytes(),
            Payload::Params(p) => (p.len() * 4) as u64 + 64,
        }
    }
}

/// Build the payload this strategy sends (mutates PS send-side state).
pub fn make_payload(cfg: &SyncConfig, ps: &mut PsState) -> Payload {
    if cfg.strategy.sends_gradient() {
        let (grad, steps) = ps.take_accumulated();
        encode_gradient(cfg.compression, &grad, steps, ps)
    } else {
        Payload::Params(ps.snapshot_params())
    }
}

/// Encode one already-drained accumulated gradient under `codec`.
///
/// Split out of [`make_payload`] for per-link elastic compression: one
/// sync may ship the same accumulated gradient under several codecs (one
/// encode per codec group), with [`PsState::take_accumulated`] called
/// exactly once. TopK folds its DGC error feedback — the dropped mass
/// re-enters the accumulator and ships with a later sync — once per
/// encode, so only the mass actually withheld from the top-k edges is
/// ever re-sent.
pub fn encode_gradient(
    codec: Compression,
    grad: &[f32],
    steps: u32,
    ps: &mut PsState,
) -> Payload {
    match codec {
        Compression::None => Payload::Gradient { grad: grad.to_vec(), steps },
        Compression::TopK { ratio } => {
            let (packed, residual) = TopK::new(ratio).encode(grad);
            crate::runtime::vecops::accumulate_inplace(&mut ps.accum, &residual);
            Payload::CompressedGradient { packed, steps }
        }
        Compression::Q8 => {
            let packed = QuantQ8::default().encode(grad);
            Payload::CompressedGradient { packed, steps }
        }
    }
}

/// Apply a received payload per the strategy's update rule.
///
/// `remote_weight` is the weight given to the incoming model for
/// averaging payloads (the receiver keeps `1 - remote_weight` of its
/// local model); the engine passes the *effective* sequential weight
/// (`engine::topology::sequential_weight` over the plan edge's
/// Metropolis weight — 0.5 between two clouds). Gradient payloads
/// ignore it.
pub fn apply_payload(cfg: &SyncConfig, ps: &mut PsState, payload: &Payload, remote_weight: f32) {
    match payload {
        Payload::Gradient { grad, .. } => ps.apply_remote_gradient(grad),
        Payload::CompressedGradient { packed, .. } => {
            ps.apply_remote_gradient(&packed.decode())
        }
        Payload::Params(remote) => ps.average_with(remote, 1.0 - remote_weight),
    }
}

/// Plan the seed's single-peer ring: each PS sends to exactly one peer
/// per sync. For 2 clouds this is a pairwise exchange; for N > 2 a ring —
/// both satisfy the paper's "only one other PS each time" traffic cap.
///
/// Compatibility helper: richer N-cloud shapes (hierarchical hub,
/// bandwidth-aware trees) live in `engine::topology` and carry per-edge
/// averaging weights; this remains for callers that only need the peer
/// permutation.
pub fn plan_topology(n: usize) -> Vec<usize> {
    assert!(n >= 1);
    (0..n).map(|i| (i + 1) % n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ps_with_updates(k: u32) -> PsState {
        let mut ps = PsState::new(vec![0.0; 4], 0.1);
        for i in 0..k {
            ps.push_gradient(&[1.0, 1.0, 1.0, 1.0], i as u64);
        }
        ps
    }

    #[test]
    fn asgd_forces_freq_one() {
        let cfg = SyncConfig::new(Strategy::Asgd, 8);
        assert_eq!(cfg.freq, 1);
        assert!(cfg.should_sync(&ps_with_updates(1)));
    }

    #[test]
    fn asgd_ga_condition_counts_updates() {
        let cfg = SyncConfig::new(Strategy::AsgdGa, 4);
        assert!(!cfg.should_sync(&ps_with_updates(3)));
        assert!(cfg.should_sync(&ps_with_updates(4)));
        assert!(cfg.should_sync(&ps_with_updates(5)));
    }

    #[test]
    fn gradient_payload_is_accumulated_sum() {
        let cfg = SyncConfig::new(Strategy::AsgdGa, 4);
        let mut ps = ps_with_updates(4);
        match make_payload(&cfg, &mut ps) {
            Payload::Gradient { grad, steps } => {
                assert_eq!(steps, 4);
                assert_eq!(grad, vec![4.0; 4], "GA must merge all 4 gradients");
            }
            _ => panic!("ASGD-GA sends gradients"),
        }
        assert_eq!(ps.updates_since_sync, 0, "condition resets after send");
    }

    #[test]
    fn ma_payload_is_params() {
        let cfg = SyncConfig::new(Strategy::Ama, 4);
        let mut ps = ps_with_updates(4);
        let expect = ps.params.clone();
        match make_payload(&cfg, &mut ps) {
            Payload::Params(p) => assert_eq!(p, expect),
            _ => panic!("MA sends params"),
        }
    }

    #[test]
    fn receiver_updates_follow_strategy() {
        let ga = SyncConfig::new(Strategy::AsgdGa, 2);
        let mut ps = PsState::new(vec![1.0, 1.0], 0.5);
        apply_payload(&ga, &mut ps, &Payload::Gradient { grad: vec![1.0, -1.0], steps: 2 }, 0.5);
        assert_eq!(ps.params, vec![0.5, 1.5]); // p -= lr*g

        let ma = SyncConfig::new(Strategy::Ama, 2);
        let mut ps2 = PsState::new(vec![1.0, 3.0], 0.5);
        apply_payload(&ma, &mut ps2, &Payload::Params(vec![3.0, 1.0]), 0.5);
        assert_eq!(ps2.params, vec![2.0, 2.0]); // 0.5/0.5 average

        // In-degree-derived weights: a hub receiving from 3 leaves gives
        // each remote model 1/4 (keeps 3/4 locally).
        let mut hub = PsState::new(vec![4.0, 4.0], 0.5);
        apply_payload(&ma, &mut hub, &Payload::Params(vec![0.0, 8.0]), 0.25);
        assert_eq!(hub.params, vec![3.0, 5.0]);
    }

    #[test]
    fn payload_wire_bytes() {
        let p = Payload::Params(vec![0.0; 1000]);
        assert_eq!(p.wire_bytes(), 4064);
    }

    #[test]
    fn topology_is_single_peer_ring() {
        assert_eq!(plan_topology(2), vec![1, 0]); // pairwise exchange
        assert_eq!(plan_topology(4), vec![1, 2, 3, 0]); // ring
        // every node sends to exactly one, receives from exactly one
        let topo = plan_topology(5);
        let mut recv_counts = vec![0; 5];
        for &to in &topo {
            recv_counts[to] += 1;
        }
        assert!(recv_counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn compressed_payload_roundtrip_and_feedback() {
        let cfg = SyncConfig::new(Strategy::AsgdGa, 4)
            .with_compression(Compression::TopK { ratio: 0.25 });
        let mut ps = PsState::new(vec![0.0; 8], 0.1);
        ps.push_gradient(&[8.0, 1.0, -6.0, 0.5, 0.25, -0.1, 7.0, 2.0], 0);
        let payload = make_payload(&cfg, &mut ps);
        match &payload {
            Payload::CompressedGradient { packed, steps } => {
                assert_eq!(*steps, 1);
                let dense = packed.decode();
                // 25% of 8 = 2 largest coordinates kept: 8.0 and 7.0
                assert_eq!(dense.iter().filter(|v| **v != 0.0).count(), 2);
                assert_eq!(dense[0], 8.0);
                assert_eq!(dense[6], 7.0);
            }
            other => panic!("expected compressed payload, got {other:?}"),
        }
        // error feedback: dropped coordinates live on in the accumulator
        assert!(ps.accum[2] != 0.0 && ps.accum[0] == 0.0);
        // receiver applies the sparse gradient via SGD
        let mut peer = PsState::new(vec![0.0; 8], 0.1);
        apply_payload(&cfg, &mut peer, &payload, 0.5);
        assert!((peer.params[0] + 0.8).abs() < 1e-6);
        assert_eq!(peer.params[1], 0.0);
    }

    #[test]
    fn q8_payload_is_smaller_on_wire() {
        let cfg = SyncConfig::new(Strategy::AsgdGa, 1).with_compression(Compression::Q8);
        let mut ps = PsState::new(vec![0.0; 10_000], 0.1);
        let g: Vec<f32> = (0..10_000).map(|i| (i as f32 * 0.01).sin()).collect();
        ps.push_gradient(&g, 0);
        let packed = make_payload(&cfg, &mut ps);
        let dense = Payload::Gradient { grad: g, steps: 1 };
        assert!(packed.wire_bytes() * 3 < dense.wire_bytes());
    }

    #[test]
    fn compression_names_round_trip() {
        for c in [Compression::None, Compression::Q8, Compression::TopK { ratio: 0.25 }] {
            assert_eq!(Compression::from_name(&c.name()), Ok(c));
        }
        assert_eq!(Compression::from_name("TOPK:0.5"), Ok(Compression::TopK { ratio: 0.5 }));
        assert_eq!(Compression::from_name("int8"), Ok(Compression::Q8));
        assert!(Compression::from_name("topk:").is_err());
        assert!(Compression::from_name("topkx").is_err());
        assert!(Compression::from_name("topk:-0.1").is_err());
        let err = Compression::from_name("gzip").unwrap_err();
        assert!(err.contains("topk") && err.contains("q8"), "{err}");
    }

    #[test]
    fn strategy_properties() {
        assert!(Strategy::Sma.is_synchronous());
        assert!(!Strategy::Ama.is_synchronous());
        assert!(Strategy::Asgd.sends_gradient());
        assert!(Strategy::AsgdGa.sends_gradient());
        assert!(!Strategy::Ama.sends_gradient());
        assert_eq!(Strategy::from_name("asgd-ga"), Ok(Strategy::AsgdGa));
        assert_eq!(Strategy::from_name("ma"), Ok(Strategy::Ama), "\"ma\" aliases AMA");
        assert_eq!(Strategy::from_name("MA"), Ok(Strategy::Ama));
        let err = Strategy::from_name("nope").unwrap_err();
        assert!(
            err.contains("asgd-ga") && err.contains("sma") && err.contains("nope"),
            "error must list valid names: {err}"
        );
    }
}

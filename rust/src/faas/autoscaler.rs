//! Replica autoscaling policies for the FaaS substrate.
//!
//! OpenFaaS scales functions on invocation pressure; Cloudless-Training's
//! training plane additionally scales *by plan* (the elastic scheduler
//! decides worker counts) and scales-to-zero on local finish. This module
//! provides both policies over the runtime's replica primitives, plus the
//! pressure-based policy for the control-plane functions.

use super::{FaasRuntime, ReplicaId, ReplicaState};
use crate::sim::Time;

/// Scaling decision for one reconciliation tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleAction {
    /// Spawn this many new replicas.
    Up(u32),
    /// Terminate these replicas.
    Down(Vec<ReplicaId>),
    Hold,
}

/// Plan-driven policy: keep exactly `target` ready-or-starting replicas
/// (what the elastic scheduler's resourcing plan dictates per cloud).
pub fn reconcile_to_target(rt: &FaasRuntime, key: &str, target: u32) -> ScaleAction {
    let live: Vec<_> = rt
        .replicas_of(key)
        .into_iter()
        .filter(|r| r.state != ReplicaState::Terminated)
        .collect();
    let n = live.len() as u32;
    if n < target {
        ScaleAction::Up(target - n)
    } else if n > target {
        // Terminate the youngest first (they have the least warm state).
        let mut extra: Vec<_> = live.into_iter().collect();
        extra.sort_by(|a, b| b.started_at.partial_cmp(&a.started_at).unwrap());
        ScaleAction::Down(extra.into_iter().take((n - target) as usize).map(|r| r.id).collect())
    } else {
        ScaleAction::Hold
    }
}

/// Pressure policy for stateless control-plane functions: one replica per
/// `per_replica` in-flight invocations, within [1, max].
pub fn pressure_target(in_flight: u32, per_replica: u32, max: u32) -> u32 {
    in_flight.div_ceil(per_replica.max(1)).clamp(1, max)
}

/// Apply a decision against the runtime at `now`; returns spawned ids.
pub fn apply(
    rt: &mut FaasRuntime,
    key: &str,
    action: &ScaleAction,
    now: Time,
) -> anyhow::Result<Vec<ReplicaId>> {
    match action {
        ScaleAction::Hold => Ok(Vec::new()),
        ScaleAction::Up(n) => {
            let mut spawned = Vec::new();
            for _ in 0..*n {
                let (id, _) = rt.scale_up(key, now)?;
                spawned.push(id);
            }
            Ok(spawned)
        }
        ScaleAction::Down(ids) => {
            for id in ids {
                rt.terminate(*id, now);
            }
            Ok(Vec::new())
        }
    }
}

/// Plan-driven resize in one call (what the elastic control loop's
/// re-plan application uses): reconcile `key` to `target` replicas,
/// apply the decision at `now`, and return `(spawned, live)` — the
/// newly-spawned replica ids (still cold-starting) and the full
/// surviving replica set after the action.
pub fn resize_pool(
    rt: &mut FaasRuntime,
    key: &str,
    target: u32,
    now: Time,
) -> anyhow::Result<(Vec<ReplicaId>, Vec<ReplicaId>)> {
    let action = reconcile_to_target(rt, key, target);
    let spawned = apply(rt, key, &action, now)?;
    let live: Vec<ReplicaId> = rt
        .replicas_of(key)
        .into_iter()
        .filter(|r| r.state != ReplicaState::Terminated)
        .map(|r| r.id)
        .collect();
    Ok((spawned, live))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::{FunctionKind, FunctionSpec};

    fn rt_with_workers(n: u32) -> (FaasRuntime, String) {
        let mut rt = FaasRuntime::new();
        let key = rt.register(FunctionSpec::new("w", "c0", FunctionKind::Worker, 0));
        for i in 0..n {
            let (id, _) = rt.scale_up(&key, i as f64).unwrap();
            rt.mark_ready(id);
        }
        (rt, key)
    }

    #[test]
    fn reconcile_scales_up_to_plan() {
        let (mut rt, key) = rt_with_workers(2);
        let action = reconcile_to_target(&rt, &key, 5);
        assert_eq!(action, ScaleAction::Up(3));
        let spawned = apply(&mut rt, &key, &action, 10.0).unwrap();
        assert_eq!(spawned.len(), 3);
        assert_eq!(reconcile_to_target(&rt, &key, 5), ScaleAction::Hold);
    }

    #[test]
    fn reconcile_scales_down_youngest_first() {
        let (mut rt, key) = rt_with_workers(4);
        let action = reconcile_to_target(&rt, &key, 2);
        match &action {
            ScaleAction::Down(ids) => {
                assert_eq!(ids.len(), 2);
                // youngest two were started at t=2 and t=3
                for id in ids {
                    assert!(rt.replica(*id).unwrap().started_at >= 2.0);
                }
            }
            other => panic!("expected Down, got {other:?}"),
        }
        apply(&mut rt, &key, &action, 20.0).unwrap();
        assert_eq!(rt.ready_replicas_of(&key).len(), 2);
    }

    #[test]
    fn terminated_replicas_dont_count() {
        let (mut rt, key) = rt_with_workers(3);
        let ids: Vec<_> = rt.ready_replicas_of(&key).iter().map(|r| r.id).collect();
        rt.terminate(ids[0], 5.0);
        assert_eq!(reconcile_to_target(&rt, &key, 3), ScaleAction::Up(1));
    }

    #[test]
    fn resize_pool_round_trips() {
        let (mut rt, key) = rt_with_workers(3);
        let (spawned, live) = resize_pool(&mut rt, &key, 6, 10.0).unwrap();
        assert_eq!(spawned.len(), 3);
        assert_eq!(live.len(), 6);
        let (spawned, live) = resize_pool(&mut rt, &key, 2, 20.0).unwrap();
        assert!(spawned.is_empty());
        assert_eq!(live.len(), 2);
        let (spawned, live) = resize_pool(&mut rt, &key, 2, 30.0).unwrap();
        assert!(spawned.is_empty(), "hold is a no-op");
        assert_eq!(live.len(), 2);
    }

    #[test]
    fn pressure_targets() {
        assert_eq!(pressure_target(0, 10, 8), 1);
        assert_eq!(pressure_target(25, 10, 8), 3);
        assert_eq!(pressure_target(1000, 10, 8), 8);
        assert_eq!(pressure_target(5, 0, 8), 5); // degenerate per_replica clamps to 1
    }
}

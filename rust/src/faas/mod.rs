//! Serverless (FaaS) substrate — the stand-in for the paper's customized
//! OpenFaaS deployment.
//!
//! The paper extends OpenFaaS in exactly two ways (§IMPLEMENTATION):
//!   1. a *workflow* entity — a DAG of functions the gateway can deploy
//!      and invoke as a unit (see [`workflow`]);
//!   2. *function addressing* — a table mapping each function replica's
//!      identity to its (possibly dynamic) endpoint, kept fresh as
//!      replicas churn, plus WAN identities assigned by the global
//!      communicator so PS communicators in different clouds can reach
//!      each other.
//!
//! This module provides both, plus the base runtime pieces they sit on:
//! function specs, replicas with lifecycle (cold start -> ready ->
//! terminated), a gateway that routes invocations, and replica scaling
//! (training workers are "terminated immediately after the local training
//! finishes" — that release is what the cost model bills).

pub mod autoscaler;
pub mod workflow;

use std::collections::BTreeMap;
use std::fmt;

use crate::net::RegionId;
use crate::sim::Time;

/// Role a function plays in the Cloudless-Training topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionKind {
    /// Control plane: loads the scheduling strategy, generates plans.
    Scheduler,
    /// Control plane: assigns WAN identities to PS communicators.
    GlobalCommunicator,
    /// Physical plane: stateful parameter server (one per cloud).
    ParameterServer,
    /// Physical plane: gRPC sender/receiver bridging a PS onto the WAN.
    PsCommunicator,
    /// Physical plane: training worker (pull, SGD, push).
    Worker,
    /// Anything else.
    Generic,
}

/// Static description of a deployable function.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub name: String,
    pub namespace: String,
    pub kind: FunctionKind,
    pub region: RegionId,
    /// Cold-start latency when a replica must be spawned to serve an
    /// invocation (OpenFaaS pulls + starts the container).
    pub cold_start_s: Time,
}

impl FunctionSpec {
    pub fn new(name: &str, namespace: &str, kind: FunctionKind, region: RegionId) -> Self {
        // Defaults reflect measured OpenFaaS cold starts (sub-second for
        // warm images; training workers carry heavier images).
        let cold_start_s = match kind {
            FunctionKind::Worker => 2.5,
            FunctionKind::ParameterServer => 2.0,
            _ => 0.8,
        };
        FunctionSpec { name: name.into(), namespace: namespace.into(), kind, region, cold_start_s }
    }

    pub fn key(&self) -> String {
        format!("{}/{}", self.namespace, self.name)
    }
}

/// A network endpoint. Cluster-local endpoints are 10.x addresses; WAN
/// identities (assigned by the global communicator) are public.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Endpoint {
    pub ip: [u8; 4],
    pub port: u16,
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}.{}.{}:{}", self.ip[0], self.ip[1], self.ip[2], self.ip[3], self.port)
    }
}

pub type ReplicaId = u64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    Starting,
    Ready,
    Terminated,
}

/// A live function replica.
#[derive(Debug, Clone)]
pub struct Replica {
    pub id: ReplicaId,
    pub function: String, // spec key
    pub region: RegionId,
    pub endpoint: Endpoint,
    pub state: ReplicaState,
    pub started_at: Time,
    pub ready_at: Time,
    pub terminated_at: Option<Time>,
}

impl Replica {
    /// Seconds this replica held resources in [start, end-of-life|now].
    pub fn held_seconds(&self, now: Time) -> Time {
        self.terminated_at.unwrap_or(now) - self.started_at
    }
}

/// The function addressing table — the paper's second OpenFaaS extension.
/// Identity -> endpoint, with live remapping ("the endpoint of functions
/// can be dynamic, the mapping should also be updated in real-time").
#[derive(Debug, Default)]
pub struct AddressingTable {
    entries: BTreeMap<ReplicaId, Endpoint>,
    /// WAN identities assigned by the global communicator (replica ->
    /// public endpoint). Only PS communicators get one.
    wan_identities: BTreeMap<ReplicaId, Endpoint>,
    remaps: u64,
}

impl AddressingTable {
    pub fn bind(&mut self, replica: ReplicaId, ep: Endpoint) {
        if let Some(old) = self.entries.insert(replica, ep) {
            if old != ep {
                self.remaps += 1;
            }
        }
    }

    pub fn lookup(&self, replica: ReplicaId) -> Option<Endpoint> {
        self.entries.get(&replica).copied()
    }

    pub fn assign_wan_identity(&mut self, replica: ReplicaId, ep: Endpoint) {
        self.wan_identities.insert(replica, ep);
    }

    pub fn wan_identity(&self, replica: ReplicaId) -> Option<Endpoint> {
        self.wan_identities.get(&replica).copied()
    }

    pub fn remap_count(&self) -> u64 {
        self.remaps
    }

    pub fn unbind(&mut self, replica: ReplicaId) {
        self.entries.remove(&replica);
        self.wan_identities.remove(&replica);
    }
}

/// Outcome of routing an invocation through the gateway.
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    pub replica: ReplicaId,
    /// Delay before the function body runs (0 for a warm replica; cold
    /// start otherwise).
    pub dispatch_delay: Time,
    pub cold: bool,
}

/// The FaaS runtime for one federation of clusters: function registry +
/// replica lifecycle + gateway routing + addressing.
pub struct FaasRuntime {
    specs: BTreeMap<String, FunctionSpec>,
    replicas: BTreeMap<ReplicaId, Replica>,
    by_function: BTreeMap<String, Vec<ReplicaId>>,
    pub addressing: AddressingTable,
    next_replica: ReplicaId,
    next_port: u16,
    rr_counters: BTreeMap<String, usize>,
    invocations: u64,
    cold_starts: u64,
}

impl Default for FaasRuntime {
    fn default() -> Self {
        Self::new()
    }
}

impl FaasRuntime {
    pub fn new() -> Self {
        FaasRuntime {
            specs: BTreeMap::new(),
            replicas: BTreeMap::new(),
            by_function: BTreeMap::new(),
            addressing: AddressingTable::default(),
            next_replica: 1,
            next_port: 31000,
            rr_counters: BTreeMap::new(),
            invocations: 0,
            cold_starts: 0,
        }
    }

    /// Register (deploy) a function. Idempotent on the key.
    pub fn register(&mut self, spec: FunctionSpec) -> String {
        let key = spec.key();
        self.specs.entry(key.clone()).or_insert(spec);
        self.by_function.entry(key.clone()).or_default();
        key
    }

    pub fn spec(&self, key: &str) -> Option<&FunctionSpec> {
        self.specs.get(key)
    }

    fn alloc_endpoint(&mut self, region: RegionId) -> Endpoint {
        let port = self.next_port;
        self.next_port = self.next_port.wrapping_add(1).max(31000);
        // Cluster-local address space per region: 10.<region>.0.x
        Endpoint { ip: [10, region as u8, 0, (port % 250) as u8 + 1], port }
    }

    /// Spawn a replica of `key` at `now`; it becomes Ready after the
    /// function's cold start. Returns the replica id and its ready time.
    pub fn scale_up(&mut self, key: &str, now: Time) -> anyhow::Result<(ReplicaId, Time)> {
        let spec = self
            .specs
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("unknown function {key}"))?
            .clone();
        let id = self.next_replica;
        self.next_replica += 1;
        let ep = self.alloc_endpoint(spec.region);
        let ready_at = now + spec.cold_start_s;
        self.replicas.insert(
            id,
            Replica {
                id,
                function: key.to_string(),
                region: spec.region,
                endpoint: ep,
                state: ReplicaState::Starting,
                started_at: now,
                ready_at,
                terminated_at: None,
            },
        );
        self.by_function.get_mut(key).unwrap().push(id);
        self.addressing.bind(id, ep);
        self.cold_starts += 1;
        Ok((id, ready_at))
    }

    /// Mark a starting replica ready (the trainer calls this when the sim
    /// clock reaches `ready_at`).
    pub fn mark_ready(&mut self, id: ReplicaId) {
        if let Some(r) = self.replicas.get_mut(&id) {
            r.state = ReplicaState::Ready;
        }
    }

    /// Terminate a replica, releasing its resources at `now` (serverless
    /// scale-to-zero when local training finishes).
    pub fn terminate(&mut self, id: ReplicaId, now: Time) {
        if let Some(r) = self.replicas.get_mut(&id) {
            if r.state != ReplicaState::Terminated {
                r.state = ReplicaState::Terminated;
                r.terminated_at = Some(now);
                self.addressing.unbind(id);
            }
        }
    }

    /// Simulate a replica being rescheduled onto a new node: its endpoint
    /// changes and the addressing table must follow (the paper's
    /// "difficulty": dynamic endpoints).
    pub fn reschedule(&mut self, id: ReplicaId) -> Option<Endpoint> {
        let region = self.replicas.get(&id)?.region;
        let ep = self.alloc_endpoint(region);
        let r = self.replicas.get_mut(&id)?;
        r.endpoint = ep;
        self.addressing.bind(id, ep);
        Some(ep)
    }

    pub fn replica(&self, id: ReplicaId) -> Option<&Replica> {
        self.replicas.get(&id)
    }

    pub fn replicas_of(&self, key: &str) -> Vec<&Replica> {
        self.by_function
            .get(key)
            .map(|ids| ids.iter().filter_map(|id| self.replicas.get(id)).collect())
            .unwrap_or_default()
    }

    pub fn ready_replicas_of(&self, key: &str) -> Vec<&Replica> {
        self.replicas_of(key)
            .into_iter()
            .filter(|r| r.state == ReplicaState::Ready)
            .collect()
    }

    /// Gateway: route an invocation to a ready replica (round-robin), or
    /// cold-start one if none exists.
    pub fn invoke(&mut self, key: &str, now: Time) -> anyhow::Result<Invocation> {
        self.invocations += 1;
        let ready: Vec<ReplicaId> =
            self.ready_replicas_of(key).into_iter().map(|r| r.id).collect();
        if !ready.is_empty() {
            let ctr = self.rr_counters.entry(key.to_string()).or_insert(0);
            let replica = ready[*ctr % ready.len()];
            *ctr += 1;
            return Ok(Invocation { replica, dispatch_delay: 0.0, cold: false });
        }
        // Cold start path.
        let (id, ready_at) = self.scale_up(key, now)?;
        Ok(Invocation { replica: id, dispatch_delay: ready_at - now, cold: true })
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.invocations, self.cold_starts)
    }

    /// Total held core-seconds proxy: seconds each non-control replica of
    /// `key` was alive in [0, now].
    pub fn held_seconds_of(&self, key: &str, now: Time) -> Time {
        self.replicas_of(key).iter().map(|r| r.held_seconds(now)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt_with(name: &str, kind: FunctionKind) -> (FaasRuntime, String) {
        let mut rt = FaasRuntime::new();
        let key = rt.register(FunctionSpec::new(name, "train", kind, 0));
        (rt, key)
    }

    #[test]
    fn cold_start_then_warm() {
        let (mut rt, key) = rt_with("worker", FunctionKind::Worker);
        let inv1 = rt.invoke(&key, 0.0).unwrap();
        assert!(inv1.cold);
        assert!((inv1.dispatch_delay - 2.5).abs() < 1e-9);
        rt.mark_ready(inv1.replica);
        let inv2 = rt.invoke(&key, 3.0).unwrap();
        assert!(!inv2.cold);
        assert_eq!(inv2.dispatch_delay, 0.0);
        assert_eq!(inv2.replica, inv1.replica);
        assert_eq!(rt.stats(), (2, 1));
    }

    #[test]
    fn round_robin_across_ready_replicas() {
        let (mut rt, key) = rt_with("ps", FunctionKind::ParameterServer);
        let (a, _) = rt.scale_up(&key, 0.0).unwrap();
        let (b, _) = rt.scale_up(&key, 0.0).unwrap();
        rt.mark_ready(a);
        rt.mark_ready(b);
        let r1 = rt.invoke(&key, 5.0).unwrap().replica;
        let r2 = rt.invoke(&key, 5.0).unwrap().replica;
        let r3 = rt.invoke(&key, 5.0).unwrap().replica;
        assert_ne!(r1, r2);
        assert_eq!(r1, r3);
    }

    #[test]
    fn addressing_follows_reschedule() {
        let (mut rt, key) = rt_with("ps-comm", FunctionKind::PsCommunicator);
        let (id, _) = rt.scale_up(&key, 0.0).unwrap();
        let ep0 = rt.addressing.lookup(id).unwrap();
        let ep1 = rt.reschedule(id).unwrap();
        assert_ne!(ep0, ep1);
        assert_eq!(rt.addressing.lookup(id), Some(ep1));
        assert_eq!(rt.addressing.remap_count(), 1);
    }

    #[test]
    fn wan_identity_assignment() {
        let (mut rt, key) = rt_with("ps-comm", FunctionKind::PsCommunicator);
        let (id, _) = rt.scale_up(&key, 0.0).unwrap();
        assert_eq!(rt.addressing.wan_identity(id), None);
        let wan = Endpoint { ip: [101, 32, 4, 7], port: 443 };
        rt.addressing.assign_wan_identity(id, wan);
        assert_eq!(rt.addressing.wan_identity(id), Some(wan));
    }

    #[test]
    fn terminate_releases_and_unbinds() {
        let (mut rt, key) = rt_with("worker", FunctionKind::Worker);
        let (id, ready_at) = rt.scale_up(&key, 1.0).unwrap();
        rt.mark_ready(id);
        rt.terminate(id, 11.0);
        let r = rt.replica(id).unwrap();
        assert_eq!(r.state, ReplicaState::Terminated);
        assert!((r.held_seconds(99.0) - 10.0).abs() < 1e-9);
        assert_eq!(rt.addressing.lookup(id), None);
        assert!(ready_at > 1.0);
        // terminated replicas never serve invocations
        let inv = rt.invoke(&key, 12.0).unwrap();
        assert!(inv.cold);
        assert_ne!(inv.replica, id);
    }

    #[test]
    fn unknown_function_errors() {
        let mut rt = FaasRuntime::new();
        assert!(rt.invoke("train/nope", 0.0).is_err());
        assert!(rt.scale_up("train/nope", 0.0).is_err());
    }

    #[test]
    fn endpoints_are_region_scoped() {
        let mut rt = FaasRuntime::new();
        let k0 = rt.register(FunctionSpec::new("a", "ns", FunctionKind::Generic, 0));
        let k1 = rt.register(FunctionSpec::new("b", "ns", FunctionKind::Generic, 3));
        let (r0, _) = rt.scale_up(&k0, 0.0).unwrap();
        let (r1, _) = rt.scale_up(&k1, 0.0).unwrap();
        assert_eq!(rt.replica(r0).unwrap().endpoint.ip[1], 0);
        assert_eq!(rt.replica(r1).unwrap().endpoint.ip[1], 3);
    }
}

//! Serverless workflow DAGs — the paper's first OpenFaaS extension.
//!
//! "Workflow is added as a new entity in OpenFaaS, allowing to define DAG
//! of workflow. The OpenFaaS gateway is extended to recognize workflow
//! invocations and invoke internal workflow functions."
//!
//! A [`WorkflowDef`] is a named DAG over function specs; deploying it
//! registers every function with the runtime, and a [`WorkflowInstance`]
//! tracks node execution state, releasing successor nodes as their
//! dependencies complete. The Cloudless-Training startup sequence
//! (scheduler -> communicator addressing -> per-cloud sub-workflows with
//! PS / PS-communicator / workers) is expressed as one of these.

use std::collections::BTreeMap;

use super::{FaasRuntime, FunctionSpec};

/// Index of a node within its workflow.
pub type NodeId = usize;

#[derive(Debug, Clone)]
pub struct WorkflowNode {
    pub spec: FunctionSpec,
    /// Nodes that must complete before this node may run.
    pub deps: Vec<NodeId>,
}

/// A named DAG of functions.
#[derive(Debug, Clone)]
pub struct WorkflowDef {
    pub name: String,
    pub nodes: Vec<WorkflowNode>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Blocked,
    Ready,
    Running,
    Done,
}

impl WorkflowDef {
    pub fn new(name: &str) -> Self {
        WorkflowDef { name: name.to_string(), nodes: Vec::new() }
    }

    /// Add a node; returns its id for use in later `deps`.
    pub fn add(&mut self, spec: FunctionSpec, deps: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(deps.iter().all(|d| *d < id), "deps must reference earlier nodes");
        self.nodes.push(WorkflowNode { spec, deps });
        id
    }

    /// Validate the DAG: dep indices in range, no cycles. Returns a
    /// topological order.
    pub fn validate(&self) -> anyhow::Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                anyhow::ensure!(d < n, "workflow {}: node {i} dep {d} out of range", self.name);
                anyhow::ensure!(d != i, "workflow {}: node {i} depends on itself", self.name);
                indeg[i] += 1;
                succ[d].push(i);
            }
        }
        let mut order = Vec::with_capacity(n);
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(i) = queue.pop() {
            order.push(i);
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        anyhow::ensure!(order.len() == n, "workflow {}: cycle detected", self.name);
        Ok(order)
    }
}

/// A deployed, executing workflow.
pub struct WorkflowInstance {
    pub def: WorkflowDef,
    pub states: Vec<NodeState>,
    /// Function keys as registered with the runtime, indexed by node.
    pub keys: Vec<String>,
}

impl WorkflowInstance {
    /// Validate + register every node's function with the runtime.
    pub fn deploy(def: WorkflowDef, rt: &mut FaasRuntime) -> anyhow::Result<WorkflowInstance> {
        def.validate()?;
        let keys: Vec<String> =
            def.nodes.iter().map(|n| rt.register(n.spec.clone())).collect();
        let states = def
            .nodes
            .iter()
            .map(|n| if n.deps.is_empty() { NodeState::Ready } else { NodeState::Blocked })
            .collect();
        Ok(WorkflowInstance { def, states, keys })
    }

    /// Nodes currently ready to run.
    pub fn ready_nodes(&self) -> Vec<NodeId> {
        self.states
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == NodeState::Ready)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn start(&mut self, node: NodeId) {
        assert_eq!(self.states[node], NodeState::Ready, "node {node} not ready");
        self.states[node] = NodeState::Running;
    }

    /// Mark a node done; unblocks successors whose deps are all done.
    /// Returns newly-ready node ids.
    pub fn complete(&mut self, node: NodeId) -> Vec<NodeId> {
        assert!(
            matches!(self.states[node], NodeState::Running | NodeState::Ready),
            "node {node} not running"
        );
        self.states[node] = NodeState::Done;
        let mut newly = Vec::new();
        for i in 0..self.def.nodes.len() {
            if self.states[i] == NodeState::Blocked
                && self.def.nodes[i].deps.iter().all(|&d| self.states[d] == NodeState::Done)
            {
                self.states[i] = NodeState::Ready;
                newly.push(i);
            }
        }
        newly
    }

    pub fn all_done(&self) -> bool {
        self.states.iter().all(|s| *s == NodeState::Done)
    }

    /// Per-state node counts (for progress displays).
    pub fn summary(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for s in &self.states {
            let k = match s {
                NodeState::Blocked => "blocked",
                NodeState::Ready => "ready",
                NodeState::Running => "running",
                NodeState::Done => "done",
            };
            *m.entry(k).or_insert(0) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::FunctionKind;

    fn spec(name: &str) -> FunctionSpec {
        FunctionSpec::new(name, "wf", FunctionKind::Generic, 0)
    }

    fn diamond() -> WorkflowDef {
        // a -> {b, c} -> d
        let mut def = WorkflowDef::new("diamond");
        let a = def.add(spec("a"), vec![]);
        let b = def.add(spec("b"), vec![a]);
        let c = def.add(spec("c"), vec![a]);
        let _d = def.add(spec("d"), vec![b, c]);
        def
    }

    #[test]
    fn topological_validation() {
        let order = diamond().validate().unwrap();
        let pos = |x: NodeId| order.iter().position(|&i| i == x).unwrap();
        assert!(pos(0) < pos(1) && pos(0) < pos(2));
        assert!(pos(1) < pos(3) && pos(2) < pos(3));
    }

    #[test]
    fn cycle_detected() {
        // Manufacture a cycle by hand (add() forbids forward deps).
        let mut def = diamond();
        def.nodes[0].deps = vec![3];
        assert!(def.validate().is_err());
    }

    #[test]
    fn self_dep_rejected() {
        let mut def = WorkflowDef::new("selfy");
        def.nodes.push(WorkflowNode { spec: spec("x"), deps: vec![0] });
        assert!(def.validate().is_err());
    }

    #[test]
    fn execution_releases_dependents() {
        let mut rt = FaasRuntime::new();
        let mut inst = WorkflowInstance::deploy(diamond(), &mut rt).unwrap();
        assert_eq!(inst.ready_nodes(), vec![0]);
        inst.start(0);
        let newly = inst.complete(0);
        assert_eq!(newly, vec![1, 2]);
        inst.start(1);
        assert!(inst.complete(1).is_empty(), "d still blocked on c");
        inst.start(2);
        assert_eq!(inst.complete(2), vec![3]);
        inst.start(3);
        inst.complete(3);
        assert!(inst.all_done());
    }

    #[test]
    fn deploy_registers_functions() {
        let mut rt = FaasRuntime::new();
        let inst = WorkflowInstance::deploy(diamond(), &mut rt).unwrap();
        for key in &inst.keys {
            assert!(rt.spec(key).is_some(), "function {key} not registered");
        }
    }

    #[test]
    fn summary_counts() {
        let mut rt = FaasRuntime::new();
        let mut inst = WorkflowInstance::deploy(diamond(), &mut rt).unwrap();
        inst.start(0);
        let s = inst.summary();
        assert_eq!(s["running"], 1);
        assert_eq!(s["blocked"], 3);
    }
}

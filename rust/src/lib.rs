//! # Cloudless-Training
//!
//! A from-scratch reproduction of *Cloudless-Training: A Framework to
//! Improve Efficiency of Geo-Distributed ML Training* (Tan, Shi, Lv, Zhao
//! — CS.DC 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the serverless geo-distributed training
//!   coordinator: control plane (elastic scheduler + global communicator
//!   addressing), the layered training [`engine`] (driver → partition →
//!   comm → topology; per-cloud PS workflows with pluggable N-cloud sync
//!   topologies), WAN synchronization strategies (ASGD / ASGD-GA / AMA /
//!   SMA), and every substrate they need (FaaS runtime, WAN fabric,
//!   cloud/device/cost models, discrete-event simulator).
//! - **L2** — JAX models (LeNet / ResNet-lite / DeepFM / Transformer),
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! - **L1** — Pallas kernels (tiled matmul, fused bias+act, PS vector
//!   ops) called from L2.
//!
//! Python never runs on the training path: the `runtime` module loads the
//! HLO artifacts through PJRT (`xla` crate) and executes them natively.
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod exp;
pub mod faas;
pub mod net;
pub mod prop;
pub mod ps;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sync;
pub mod train;
pub mod util;

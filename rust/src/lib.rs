//! # Cloudless-Training
//!
//! A from-scratch reproduction of *Cloudless-Training: A Framework to
//! Improve Efficiency of Geo-Distributed ML Training* (Tan, Shi, Lv, Zhao
//! — CS.DC 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the serverless geo-distributed training
//!   coordinator: control plane (elastic scheduler + global communicator
//!   addressing), the multi-job fleet coordinator
//!   ([`coordinator::fleet`] — N concurrent workflows leasing slices of
//!   one shared inventory, contending on one shared WAN), the physical
//!   [`dataplane`] (dataset catalog, joint data/compute placement, WAN
//!   shard migration with staging gates), the layered
//!   training [`engine`] (driver → partition → comm → topology;
//!   per-cloud PS workflows with pluggable N-cloud sync topologies), WAN
//!   synchronization strategies (ASGD / ASGD-GA / AMA / SMA) with
//!   optional gradient compression, and every substrate they need (FaaS
//!   runtime, WAN fabric, cloud/device/cost models, discrete-event
//!   simulator).
//! - **L2** — JAX models (LeNet / ResNet-lite / DeepFM / Transformer),
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! - **L1** — Pallas kernels (tiled matmul, fused bias+act, PS vector
//!   ops) called from L2.
//!
//! Python never runs on the training path: the `runtime` module loads the
//! HLO artifacts through PJRT (`xla` crate) and executes them natively.
//!
//! Repository documentation (paths relative to the repo root):
//!
//! - `docs/ARCHITECTURE.md` — the layer diagram and the data flow
//!   between the elastic control loop, the training driver, and the
//!   multi-job coordinator;
//! - `docs/EXPERIMENTS.md` — every `cloudless exp --id` mapped to its
//!   paper figure/table, config file, and bench target;
//! - `docs/CONFIG.md` — the full config-key and CLI-flag reference.

pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataplane;
pub mod engine;
pub mod exp;
pub mod faas;
pub mod lint;
pub mod net;
pub mod prop;
pub mod ps;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sync;
pub mod train;
pub mod util;

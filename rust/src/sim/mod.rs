//! Discrete-event simulation engine.
//!
//! Why a DES: the paper's evaluation runs on 2 Tencent Cloud regions over a
//! 100 Mbps WAN with CPU *and* GPU instances — none of which exist in this
//! testbed. Every experiment therefore executes **real numerics** (PJRT
//! train steps) while a **virtual clock** advances by *modeled* durations
//! (compute time from the device catalog, WAN time from the link model).
//! Everything is deterministic under a seed: events at equal timestamps are
//! ordered by schedule sequence number.
//!
//! The engine is deliberately minimal: handlers are boxed `FnOnce`
//! closures receiving `(&mut Sim, &mut W)`, so any component can schedule
//! follow-up events without an entity registry.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type Time = f64;

type Handler<W> = Box<dyn FnOnce(&mut Sim<W>, &mut W)>;

struct Scheduled<W> {
    at: Time,
    seq: u64,
    f: Handler<W>,
}

impl<W> PartialEq for Scheduled<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<W> Eq for Scheduled<W> {}
impl<W> PartialOrd for Scheduled<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Scheduled<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties broken
        // by sequence number so execution order is deterministic.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The simulator: a clock + an event heap over a world `W`.
pub struct Sim<W> {
    now: Time,
    seq: u64,
    executed: u64,
    heap: BinaryHeap<Scheduled<W>>,
}

impl<W> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Sim<W> {
    pub fn new() -> Self {
        Sim { now: 0.0, seq: 0, executed: 0, heap: BinaryHeap::new() }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Virtual time of the earliest pending event (`None` when the heap
    /// is empty). The multi-job coordinator interleaves several `Sim`s
    /// over one shared clock by always stepping the simulator whose next
    /// event is earliest; this peek is what makes that merge possible
    /// without executing anything.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|s| s.at)
    }

    /// Schedule `f` to run `delay` seconds from now (clamped to >= 0).
    pub fn schedule<F>(&mut self, delay: Time, f: F)
    where
        F: FnOnce(&mut Sim<W>, &mut W) + 'static,
    {
        debug_assert!(delay.is_finite(), "non-finite delay {delay}");
        let at = self.now + delay.max(0.0);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, f: Box::new(f) });
    }

    /// Schedule at an absolute virtual time (>= now).
    pub fn schedule_at<F>(&mut self, at: Time, f: F)
    where
        F: FnOnce(&mut Sim<W>, &mut W) + 'static,
    {
        self.schedule(at - self.now, f)
    }

    /// Run one event; returns false when the heap is empty.
    pub fn step(&mut self, world: &mut W) -> bool {
        match self.heap.pop() {
            None => false,
            Some(ev) => {
                debug_assert!(ev.at >= self.now, "time went backwards");
                self.now = ev.at;
                self.executed += 1;
                (ev.f)(self, world);
                true
            }
        }
    }

    /// Run until no events remain.
    pub fn run(&mut self, world: &mut W) {
        while self.step(world) {}
    }

    /// Run until no events remain or `limit` events executed (runaway guard).
    /// Returns true if the heap drained.
    pub fn run_with_limit(&mut self, world: &mut W, limit: u64) -> bool {
        let start = self.executed;
        while self.executed - start < limit {
            if !self.step(world) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<Vec<(f64, &'static str)>> = Sim::new();
        let mut log = Vec::new();
        sim.schedule(2.0, |s, w: &mut Vec<_>| w.push((s.now(), "b")));
        sim.schedule(1.0, |s, w: &mut Vec<_>| w.push((s.now(), "a")));
        sim.schedule(3.0, |s, w: &mut Vec<_>| w.push((s.now(), "c")));
        sim.run(&mut log);
        assert_eq!(log, vec![(1.0, "a"), (2.0, "b"), (3.0, "c")]);
    }

    #[test]
    fn ties_break_by_schedule_order() {
        let mut sim: Sim<Vec<u32>> = Sim::new();
        let mut log = Vec::new();
        for i in 0..10u32 {
            sim.schedule(1.0, move |_, w: &mut Vec<u32>| w.push(i));
        }
        sim.run(&mut log);
        assert_eq!(log, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_chain() {
        // A chain of events each scheduling the next: a worker loop shape.
        struct W {
            count: u32,
        }
        fn tick(sim: &mut Sim<W>, w: &mut W) {
            w.count += 1;
            if w.count < 5 {
                sim.schedule(1.5, tick);
            }
        }
        let mut sim = Sim::new();
        let mut w = W { count: 0 };
        sim.schedule(0.0, tick);
        sim.run(&mut w);
        assert_eq!(w.count, 5);
        assert!((sim.now() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn negative_delay_clamps_to_now() {
        let mut sim: Sim<Vec<f64>> = Sim::new();
        let mut log = Vec::new();
        sim.schedule(1.0, |s, w: &mut Vec<f64>| {
            s.schedule(-5.0, |s2, w2: &mut Vec<f64>| w2.push(s2.now()));
            w.push(s.now());
        });
        sim.run(&mut log);
        assert_eq!(log, vec![1.0, 1.0]);
    }

    #[test]
    fn run_with_limit_stops() {
        struct W;
        fn forever(sim: &mut Sim<W>, _w: &mut W) {
            sim.schedule(1.0, forever);
        }
        let mut sim = Sim::new();
        sim.schedule(0.0, forever);
        let drained = sim.run_with_limit(&mut W, 100);
        assert!(!drained);
        assert_eq!(sim.executed(), 100);
    }

    #[test]
    fn peek_time_sees_earliest_without_executing() {
        let mut sim: Sim<Vec<f64>> = Sim::new();
        assert_eq!(sim.peek_time(), None);
        sim.schedule(2.0, |_, _: &mut Vec<f64>| {});
        sim.schedule(1.0, |_, _: &mut Vec<f64>| {});
        assert_eq!(sim.peek_time(), Some(1.0));
        assert_eq!(sim.executed(), 0, "peek must not run anything");
        let mut w = Vec::new();
        sim.step(&mut w);
        assert_eq!(sim.peek_time(), Some(2.0));
    }

    #[test]
    fn schedule_at_absolute() {
        let mut sim: Sim<Vec<f64>> = Sim::new();
        let mut log = Vec::new();
        sim.schedule(2.0, |s, _w: &mut Vec<f64>| {
            s.schedule_at(10.0, |s2, w2: &mut Vec<f64>| w2.push(s2.now()));
        });
        sim.run(&mut log);
        assert_eq!(log, vec![10.0]);
    }
}

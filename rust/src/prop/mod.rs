//! Minimal property-based testing framework (no proptest offline).
//!
//! `forall(cases, gen, check)` draws `cases` random inputs from `gen`
//! (seeded deterministically, streams decorrelated per case) and asserts
//! `check` on each; failures report the case seed so they replay exactly:
//!
//! ```no_run
//! use cloudless::prop::forall;
//! forall(200, |r| (r.below(100), r.below(100)), |&(a, b)| {
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Base seed; override with CLOUDLESS_PROP_SEED for exploration.
fn base_seed() -> u64 {
    std::env::var("CLOUDLESS_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xC10D)
}

/// Run `check` against `cases` generated inputs.
pub fn forall<T, G, C>(cases: usize, mut gen: G, mut check: C)
where
    G: FnMut(&mut Pcg32) -> T,
    C: FnMut(&T),
    T: std::fmt::Debug,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Pcg32::new(seed, case as u64);
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(&input)));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {case} (CLOUDLESS_PROP_SEED={seed}):\n  input: {input:?}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Random f32 vector in [-1, 1).
pub fn vec_f32(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(50, |r| r.below(10), |_| {});
        forall(50, |r| r.below(10), |_| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(50, |r| r.below(10), |&x| assert!(x < 5));
    }

    #[test]
    fn deterministic_generation() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        forall(20, |r| r.next_u64(), |&x| a.push(x));
        forall(20, |r| r.next_u64(), |&x| b.push(x));
        assert_eq!(a, b);
    }
}

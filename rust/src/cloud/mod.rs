//! Multi-regional cloud substrate: regions, resource inventories,
//! allocations, and dataset distribution.
//!
//! This is the stand-in for the paper's Tencent Cloud environment
//! (Shanghai + Chongqing regions; self-hosted Beijing + Shanghai for
//! Fig 11). A [`Region`] owns a device inventory and a fraction of the
//! pre-existing training data; an [`Allocation`] is what the elastic
//! scheduler (or the greedy baseline) decides to actually rent.

pub mod cost;
pub mod devices;
pub mod spot;

use devices::Device;

use crate::net::RegionId;

/// A cloud region with a resource inventory and resident data.
#[derive(Debug, Clone)]
pub struct Region {
    pub id: RegionId,
    pub name: String,
    /// Maximum rentable units per device type (cores for CPU, devices for
    /// GPU) — the "available cloud resources" the scheduler probes.
    pub inventory: Vec<(Device, u32)>,
    /// Number of locally-resident training samples (the pre-existing data
    /// distribution; moving it over the WAN is what geo-training avoids).
    pub data_samples: usize,
}

impl Region {
    pub fn new(id: RegionId, name: &str, inventory: Vec<(Device, u32)>, data: usize) -> Self {
        Region { id, name: name.to_string(), inventory, data_samples: data }
    }

    pub fn max_units(&self, d: Device) -> u32 {
        self.inventory.iter().find(|(dev, _)| *dev == d).map(|(_, n)| *n).unwrap_or(0)
    }
}

/// Resources actually rented in one region for a training job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub region: RegionId,
    /// (device, units) pairs; units are cores (CPU) or devices (GPU).
    pub units: Vec<(Device, u32)>,
}

impl Allocation {
    pub fn new(region: RegionId, units: Vec<(Device, u32)>) -> Self {
        Allocation { region, units }
    }

    /// Total compute power in IN units (see devices::Device::power_of).
    pub fn power(&self) -> f64 {
        self.units.iter().map(|(d, n)| d.power_of(*n)).sum()
    }

    /// Total allocated units (for greedy-vs-elastic comparisons).
    pub fn total_units(&self) -> u32 {
        self.units.iter().map(|(_, n)| n).sum()
    }

    /// True if this allocation fits the region's inventory.
    pub fn fits(&self, region: &Region) -> bool {
        self.units.iter().all(|(d, n)| *n <= region.max_units(*d))
    }
}

/// The full multi-cloud environment for one training job.
#[derive(Debug, Clone)]
pub struct CloudEnv {
    pub regions: Vec<Region>,
}

impl CloudEnv {
    pub fn new(regions: Vec<Region>) -> Self {
        debug_assert!(regions.iter().enumerate().all(|(i, r)| r.id == i));
        CloudEnv { regions }
    }

    /// The paper's evaluation setup: Shanghai (Cascade Lake) + Chongqing
    /// (`cq_device`), 12 cores each, with a data split of
    /// `sh_data : cq_data` samples.
    pub fn tencent_two_region(
        cq_device: Device,
        sh_data: usize,
        cq_data: usize,
    ) -> Self {
        CloudEnv::new(vec![
            Region::new(0, "Shanghai", vec![(Device::CascadeLake, 12)], sh_data),
            Region::new(1, "Chongqing", vec![(cq_device, 12)], cq_data),
        ])
    }

    /// An N-region environment from `(name, device, units, data)` rows —
    /// the N-cloud scenarios the engine's pluggable sync topologies open
    /// up (region ids follow row order).
    pub fn multi_region(rows: Vec<(&str, Device, u32, usize)>) -> Self {
        CloudEnv::new(
            rows.into_iter()
                .enumerate()
                .map(|(i, (name, dev, units, data))| {
                    Region::new(i, name, vec![(dev, units)], data)
                })
                .collect(),
        )
    }

    /// Greedy baseline plan: rent everything every region offers
    /// (the paper: "all baseline experiments use a greedy strategy to
    /// consume all available 24 CPU cores, 12 from each region").
    pub fn greedy_plan(&self) -> Vec<Allocation> {
        self.regions
            .iter()
            .map(|r| Allocation::new(r.id, r.inventory.clone()))
            .collect()
    }

    pub fn total_samples(&self) -> usize {
        self.regions.iter().map(|r| r.data_samples).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tencent_env_shape() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 2000, 1000);
        assert_eq!(env.regions.len(), 2);
        assert_eq!(env.regions[0].name, "Shanghai");
        assert_eq!(env.regions[0].max_units(Device::CascadeLake), 12);
        assert_eq!(env.regions[1].max_units(Device::Skylake), 12);
        assert_eq!(env.total_samples(), 3000);
    }

    #[test]
    fn allocation_power_uses_class_powers() {
        let a = Allocation::new(0, vec![(Device::CascadeLake, 12)]);
        assert!((a.power() - 4.0).abs() < 1e-9); // 12 * 1/3
        let b = Allocation::new(1, vec![(Device::Skylake, 8)]);
        assert!((b.power() - 4.0).abs() < 1e-9); // 8 * 1/2 — Table IV case 1!
    }

    #[test]
    fn multi_region_builder() {
        let env = CloudEnv::multi_region(vec![
            ("SH", Device::CascadeLake, 12, 1000),
            ("CQ", Device::Skylake, 12, 1000),
            ("BJ", Device::Skylake, 8, 500),
            ("GZ", Device::IceLake, 6, 500),
        ]);
        assert_eq!(env.regions.len(), 4);
        assert_eq!(env.regions[2].id, 2);
        assert_eq!(env.regions[3].name, "GZ");
        assert_eq!(env.total_samples(), 3000);
    }

    #[test]
    fn greedy_takes_everything() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1, 1);
        let plans = env.greedy_plan();
        assert_eq!(plans[0].total_units(), 12);
        assert_eq!(plans[1].total_units(), 12);
        assert!(plans[0].fits(&env.regions[0]));
    }

    #[test]
    fn fits_rejects_over_allocation() {
        let env = CloudEnv::tencent_two_region(Device::Skylake, 1, 1);
        let too_much = Allocation::new(0, vec![(Device::CascadeLake, 13)]);
        assert!(!too_much.fits(&env.regions[0]));
        let wrong_device = Allocation::new(0, vec![(Device::V100, 1)]);
        assert!(!wrong_device.fits(&env.regions[0]));
    }
}

//! Monetary cost model for geo-distributed training.
//!
//! The paper's Fig 8(d-f) reports "training cost" reductions of 9.2%–24.0%
//! from elastic scheduling. Cost here has the components users pay for on
//! Tencent Cloud: (1) compute — allocated cores/devices are billed from
//! allocation to release (so *waiting* for stragglers burns money),
//! (2) WAN sync traffic at a flat egress rate, and (3) bulk object-store
//! egress for dataset shard migrations, priced **per source region**
//! (clouds discount egress from their hub regions; the data plane's
//! placement planner trades these prices against makespan).

use crate::cloud::devices::Device;
use crate::net::RegionId;
use crate::sim::Time;

/// Billing rates. Defaults approximate Tencent Cloud list prices; the
/// experiments only depend on them through relative cost, not absolutes.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// WAN egress price per GB (USD) for sync traffic, and the fallback
    /// rate for object-store egress from regions beyond the table below.
    pub wan_per_gb: f64,
    /// Object-store egress price per GB, indexed by `RegionId` — the
    /// data plane's shard-migration rate. Hub regions (low ids in the
    /// shipped environments) are discounted relative to edge regions.
    pub egress_per_gb: Vec<f64>,
    /// Storage rent per GB-hour (USD) for *persisted replica copies* —
    /// every physical copy of a shard is billed from its creation (or
    /// job start, for seeded copies) to job end. The default tracks
    /// object-store list prices (~$0.02/GB-month ≈ $2.8e-5/GB-hour);
    /// tiny per-run, but it breaks the "copies are a free lunch once
    /// created" degeneracy: a planner offered rent-heavy pricing stops
    /// materializing marginal replicas.
    pub storage_per_gb_hour: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            wan_per_gb: 0.12,
            egress_per_gb: vec![0.08, 0.10, 0.10, 0.12],
            storage_per_gb_hour: 2.8e-5,
        }
    }
}

/// One allocation interval to bill: `units` cores/devices of `device`
/// held for `held_s` seconds at `rate` × the on-demand list price
/// (1.0 = on-demand; a spot segment carries its trace-averaged price
/// multiplier — see `cloud::spot::SpotMarket::avg_price_mult`).
#[derive(Debug, Clone)]
pub struct BilledAllocation {
    pub device: Device,
    pub units: u32,
    pub held_s: Time,
    pub rate: f64,
}

impl BilledAllocation {
    /// An on-demand (rate 1.0) interval — the historical constructor.
    pub fn on_demand(device: Device, units: u32, held_s: Time) -> BilledAllocation {
        BilledAllocation { device, units, held_s, rate: 1.0 }
    }

    /// What the same interval would have cost on-demand minus what it
    /// actually cost: the segment's spot savings (0 for on-demand).
    pub fn savings_vs_on_demand(&self, m: &CostModel) -> f64 {
        m.compute_cost(&BilledAllocation { rate: 1.0, ..self.clone() }) - m.compute_cost(self)
    }
}

impl CostModel {
    /// Compute cost of one allocation interval (market rate applied).
    pub fn compute_cost(&self, a: &BilledAllocation) -> f64 {
        a.device.info().price_per_unit_hour * a.units as f64 * a.held_s / 3600.0 * a.rate
    }

    /// WAN sync-traffic cost (flat rate).
    pub fn wan_cost(&self, bytes: u64) -> f64 {
        self.wan_per_gb * bytes as f64 / 1e9
    }

    /// Object-store egress cost of moving `bytes` *out of* region
    /// `from` (dataset shard migration). Regions beyond the price table
    /// fall back to the flat WAN rate.
    pub fn egress_cost(&self, from: RegionId, bytes: u64) -> f64 {
        let rate = self.egress_per_gb.get(from).copied().unwrap_or(self.wan_per_gb);
        rate * bytes as f64 / 1e9
    }

    /// The planner's scalar for materializing one replica copy of
    /// `bytes` out of region `from`: the object-store egress — paid
    /// **once per created replica**, never per reader of the new copy —
    /// plus the time-valued transfer seconds. The data plane's read
    /// assignment picks each consumer's source replica by minimizing
    /// this, so on symmetric links the cheaper-egress region wins.
    pub fn copy_objective(
        &self,
        from: RegionId,
        bytes: u64,
        transfer_s: Time,
        time_value_per_hour: f64,
    ) -> f64 {
        self.egress_cost(from, bytes) + time_value_per_hour * transfer_s / 3600.0
    }

    /// Storage rent for one persisted replica copy of `bytes` held for
    /// `held_s` seconds.
    pub fn storage_cost(&self, bytes: u64, held_s: Time) -> f64 {
        self.storage_per_gb_hour * bytes as f64 / 1e9 * held_s / 3600.0
    }

    /// Total job cost.
    pub fn total(&self, allocations: &[BilledAllocation], wan_bytes: u64) -> f64 {
        allocations.iter().map(|a| self.compute_cost(a)).sum::<f64>() + self.wan_cost(wan_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_cost_scales_linearly() {
        let m = CostModel::default();
        let base = BilledAllocation::on_demand(Device::CascadeLake, 12, 3600.0);
        let twice = BilledAllocation::on_demand(Device::CascadeLake, 12, 7200.0);
        assert!((m.compute_cost(&twice) - 2.0 * m.compute_cost(&base)).abs() < 1e-12);
        // 12 cores * $0.04/h * 1h
        assert!((m.compute_cost(&base) - 0.48).abs() < 1e-9);
    }

    #[test]
    fn spot_rate_discounts_the_segment() {
        let m = CostModel::default();
        let od = BilledAllocation::on_demand(Device::CascadeLake, 12, 3600.0);
        let spot = BilledAllocation { rate: 0.35, ..od.clone() };
        assert!((m.compute_cost(&spot) - 0.35 * m.compute_cost(&od)).abs() < 1e-12);
        assert!((spot.savings_vs_on_demand(&m) - 0.65 * m.compute_cost(&od)).abs() < 1e-12);
        assert_eq!(od.savings_vs_on_demand(&m), 0.0);
    }

    #[test]
    fn wan_cost() {
        let m = CostModel::default();
        assert!((m.wan_cost(5_000_000_000) - 0.6).abs() < 1e-9);
    }

    #[test]
    fn egress_is_priced_per_source_region() {
        let m = CostModel::default();
        // Hub egress (region 0) is cheaper than edge egress (region 3).
        assert!(m.egress_cost(0, 1_000_000_000) < m.egress_cost(3, 1_000_000_000));
        assert!((m.egress_cost(0, 1_000_000_000) - 0.08).abs() < 1e-9);
        // Off-table regions fall back to the flat WAN rate.
        assert!((m.egress_cost(99, 1_000_000_000) - m.wan_cost(1_000_000_000)).abs() < 1e-12);
        assert_eq!(m.egress_cost(1, 0), 0.0);
    }

    #[test]
    fn copy_objective_trades_egress_against_time() {
        let m = CostModel::default();
        let gb = 1_000_000_000u64;
        // Equal transfer times: the hub's cheaper egress wins.
        assert!(m.copy_objective(0, gb, 10.0, 4.0) < m.copy_objective(3, gb, 10.0, 4.0));
        // A much slower source loses even at the cheaper egress rate:
        // 1h of extra transfer at $4/h dwarfs a $0.04 egress gap.
        assert!(m.copy_objective(0, gb, 3600.0, 4.0) > m.copy_objective(3, gb, 10.0, 4.0));
        // Zero time value degenerates to pure egress.
        assert!((m.copy_objective(2, gb, 99.0, 0.0) - m.egress_cost(2, gb)).abs() < 1e-12);
    }

    #[test]
    fn storage_rent_scales_with_bytes_and_time() {
        let m = CostModel::default();
        let gb = 1_000_000_000u64;
        assert!((m.storage_cost(gb, 3600.0) - m.storage_per_gb_hour).abs() < 1e-12);
        assert!(
            (m.storage_cost(2 * gb, 1800.0) - m.storage_cost(gb, 3600.0)).abs() < 1e-12,
            "GB-hours commute"
        );
        assert_eq!(m.storage_cost(0, 1e9), 0.0);
        let free = CostModel { storage_per_gb_hour: 0.0, ..CostModel::default() };
        assert_eq!(free.storage_cost(gb, 1e6), 0.0, "zero rate restores the free lunch");
    }

    #[test]
    fn shorter_hold_is_cheaper() {
        // The elastic-scheduling claim in miniature: fewer cores held for
        // the same duration cost less.
        let m = CostModel::default();
        let greedy = vec![
            BilledAllocation::on_demand(Device::CascadeLake, 12, 1000.0),
            BilledAllocation::on_demand(Device::Skylake, 12, 1000.0),
        ];
        let elastic = vec![
            BilledAllocation::on_demand(Device::CascadeLake, 12, 1000.0),
            BilledAllocation::on_demand(Device::Skylake, 8, 1000.0),
        ];
        assert!(m.total(&elastic, 0) < m.total(&greedy, 0));
    }
}

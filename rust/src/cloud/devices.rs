//! Device catalog — the paper's TABLE I ("Training speed quantification of
//! cloud resources").
//!
//! The paper samples cloud devices, measures ResNet18/CIFAR-10 iteration
//! time, and normalizes: TN = TFLOPS / TFLOPS_baseline, IN = iter_baseline
//! / iter_device (higher = faster), with Intel Xeon IceLake (2 cores) as
//! the baseline row. The elastic scheduler quantifies per-core compute
//! power from these measurements; following the paper's own rounding
//! ("the ratio load power of [Cascade and Sky] is about 2:3"), the
//! scheduler uses *class powers* (Cascade 1/3, Sky 1/2 per core), which is
//! exactly what reproduces the paper's Table IV plans (12:8, 12:6, 12:4).
//!
//! Substitution note (DESIGN.md §2): GPUs don't exist in this testbed; the
//! catalog carries the paper's published ratios so the simulator can model
//! them in virtual time. The local CPU is calibrated as the IceLake
//! baseline row (power 1.0 in IN units).

/// CPU or accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    Gpu,
}

/// One catalog row, as published in TABLE I.
#[derive(Debug, Clone)]
pub struct DeviceType {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// Cores in the measured configuration (2 CPU cores / full CUDA count).
    pub measured_cores: u32,
    /// TFLOPS of the measured configuration.
    pub tflops: f64,
    /// Measured ResNet18 iteration time (seconds).
    pub iter_time_s: f64,
    /// Per-core "class power" the scheduler quantifies loads with (IN
    /// units; GPUs are allocated whole, so class power is per device).
    pub class_power_per_core: f64,
    /// Price per core-hour (CPU) or device-hour (GPU), USD — cost model.
    pub price_per_unit_hour: f64,
}

/// Baseline row constants (IceLake, 2 cores).
pub const BASELINE_TFLOPS: f64 = 0.096;
pub const BASELINE_ITER_S: f64 = 3.697;

/// Device ids into the catalog (rows resolved by [`Device::info`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Device {
    IceLake,
    CascadeLake,
    Skylake,
    T4,
    V100,
    /// CPU-serverless (function) capacity: weaker shared cores at a
    /// deep per-core-hour discount — the serverless-vs-VM tier choice
    /// studied in arXiv 2509.14920. Not a paper TABLE I row; the
    /// numbers follow the same IN calibration methodology.
    Serverless,
}

impl Device {
    pub const ALL: [Device; 6] = [
        Device::IceLake,
        Device::CascadeLake,
        Device::Skylake,
        Device::T4,
        Device::V100,
        Device::Serverless,
    ];

    pub fn info(self) -> &'static DeviceType {
        match self {
            Device::IceLake => &ICELAKE,
            Device::CascadeLake => &CASCADE,
            Device::Skylake => &SKYLAKE,
            Device::T4 => &T4,
            Device::V100 => &V100,
            Device::Serverless => &SERVERLESS,
        }
    }

    pub fn from_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().as_str() {
            "icelake" | "ice" => Some(Device::IceLake),
            "cascadelake" | "cascade" | "cas" => Some(Device::CascadeLake),
            "skylake" | "sky" => Some(Device::Skylake),
            "t4" => Some(Device::T4),
            "v100" => Some(Device::V100),
            "serverless" | "faas" | "fn" => Some(Device::Serverless),
            _ => None,
        }
    }

    /// TFLOPS normalization (TN) of the measured configuration.
    pub fn tn(self) -> f64 {
        self.info().tflops / BASELINE_TFLOPS
    }

    /// Iteration-time normalization (IN): baseline_iter / device_iter.
    pub fn in_norm(self) -> f64 {
        BASELINE_ITER_S / self.info().iter_time_s
    }

    /// IN/TN ratio — the paper's "how well TFLOPS predicts speed" column.
    pub fn in_tn_ratio(self) -> f64 {
        self.in_norm() / self.tn()
    }

    /// Compute power (IN units) of an allocation of `units` cores (CPU) or
    /// devices (GPU), using the scheduler's class powers.
    pub fn power_of(self, units: u32) -> f64 {
        self.info().class_power_per_core * units as f64
    }
}

static ICELAKE: DeviceType = DeviceType {
    name: "Intel Xeon IceLake",
    kind: DeviceKind::Cpu,
    measured_cores: 2,
    tflops: 0.096,
    iter_time_s: 3.697,
    class_power_per_core: 0.5,
    price_per_unit_hour: 0.045,
};

static CASCADE: DeviceType = DeviceType {
    name: "Intel Xeon Cascade Lake",
    kind: DeviceKind::Cpu,
    measured_cores: 2,
    tflops: 0.090,
    iter_time_s: 5.549,
    // Paper: Cascade:Sky class ratio "about 2:3" -> 1/3 vs 1/2 per core.
    class_power_per_core: 1.0 / 3.0,
    price_per_unit_hour: 0.040,
};

static SKYLAKE: DeviceType = DeviceType {
    name: "Intel Xeon Skylake",
    kind: DeviceKind::Cpu,
    measured_cores: 2,
    tflops: 0.112,
    iter_time_s: 3.800,
    class_power_per_core: 0.5,
    price_per_unit_hour: 0.038,
};

static T4: DeviceType = DeviceType {
    name: "Nvidia T4",
    kind: DeviceKind::Gpu,
    measured_cores: 2560,
    tflops: 5.554,
    iter_time_s: 0.062,
    // GPUs allocate whole devices: class power per device = IN.
    class_power_per_core: 59.629,
    price_per_unit_hour: 0.80,
};

static V100: DeviceType = DeviceType {
    name: "Nvidia V100",
    kind: DeviceKind::Gpu,
    measured_cores: 5120,
    tflops: 13.345,
    iter_time_s: 0.024,
    class_power_per_core: 154.042,
    price_per_unit_hour: 2.50,
};

static SERVERLESS: DeviceType = DeviceType {
    name: "CPU Serverless (function cores)",
    kind: DeviceKind::Cpu,
    measured_cores: 2,
    tflops: 0.070,
    // Shared function cores run the baseline workload ~half IceLake's
    // speed; class power rounds to 1/4 per core (vs IceLake's 1/2).
    iter_time_s: 7.394,
    class_power_per_core: 0.25,
    price_per_unit_hour: 0.020,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_tn_values_match_paper() {
        // Paper TABLE I column TN: 1.000, 0.938, 1.167, 57.854, 139.010.
        assert!((Device::IceLake.tn() - 1.0).abs() < 1e-9);
        assert!((Device::CascadeLake.tn() - 0.938).abs() < 2e-3);
        assert!((Device::Skylake.tn() - 1.167).abs() < 2e-3);
        assert!((Device::T4.tn() - 57.854).abs() < 2e-2);
        assert!((Device::V100.tn() - 139.010).abs() < 2e-2);
    }

    #[test]
    fn table1_in_values_match_paper() {
        // Paper TABLE I column IN: 1.000, 0.666, 0.973, 59.629, 154.042.
        assert!((Device::IceLake.in_norm() - 1.0).abs() < 1e-9);
        assert!((Device::CascadeLake.in_norm() - 0.666).abs() < 1e-3);
        assert!((Device::Skylake.in_norm() - 0.973).abs() < 1e-3);
        assert!((Device::T4.in_norm() - 59.629).abs() < 5e-2);
        assert!((Device::V100.in_norm() - 154.042).abs() < 5e-2);
    }

    #[test]
    fn table1_ratio_column() {
        // Paper TABLE I column IN/TN: 1.000, 0.710, 0.834, 1.031, 1.108.
        for (d, want) in [
            (Device::IceLake, 1.000),
            (Device::CascadeLake, 0.710),
            (Device::Skylake, 0.834),
            (Device::T4, 1.031),
            (Device::V100, 1.108),
        ] {
            assert!((d.in_tn_ratio() - want).abs() < 5e-3, "{d:?}: {}", d.in_tn_ratio());
        }
    }

    #[test]
    fn class_power_ratio_is_two_thirds() {
        let cas = Device::CascadeLake.power_of(1);
        let sky = Device::Skylake.power_of(1);
        assert!((cas / sky - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn from_name_roundtrip() {
        for d in Device::ALL {
            let short = match d {
                Device::IceLake => "ice",
                Device::CascadeLake => "cascade",
                Device::Skylake => "sky",
                Device::T4 => "t4",
                Device::V100 => "v100",
                Device::Serverless => "serverless",
            };
            assert_eq!(Device::from_name(short), Some(d));
        }
        assert_eq!(Device::from_name("tpu"), None);
    }

    #[test]
    fn serverless_tier_is_cheap_and_slow() {
        let s = Device::Serverless;
        assert_eq!(s.info().kind, DeviceKind::Cpu);
        // Half an IceLake core's class power at under half its price.
        assert!((s.power_of(2) - 0.5).abs() < 1e-9);
        assert!(s.info().price_per_unit_hour < 0.5 * Device::IceLake.info().price_per_unit_hour);
        // Cheaper per unit of compute power than any fixed CPU tier —
        // the reason the tier exists — but slower per core.
        let per_power = |d: Device| d.info().price_per_unit_hour / d.info().class_power_per_core;
        for d in [Device::IceLake, Device::CascadeLake, Device::Skylake] {
            assert!(per_power(s) < per_power(d), "{d:?}");
        }
        assert!(s.in_norm() < Device::IceLake.in_norm());
    }
}

//! The spot market: per-region preemptible capacity with deterministic
//! price and revocation traces.
//!
//! The paper's elastic scheduler adapts workflows to "the heterogeneity
//! of available cloud resources" (§Abstract, Algorithm 1), but until
//! this module every tier was fixed on-demand capacity: rentable at list
//! price, never revoked. Real clouds sell the same cores at a deep
//! discount as *preemptible* (spot) instances — the serverless cost
//! study arXiv 2509.14920 and HeterPS (arXiv 2111.10635) both put the
//! real cost wins in tier choice — at the price of revocation on short
//! notice. This module makes that a genuine trade instead of a free
//! lunch:
//!
//! - a **price trace** per (region, device tier): a piecewise-constant
//!   multiplier on the on-demand rate, one independent draw per
//!   [`SpotConfig::segment_s`] window around the configured
//!   [`SpotConfig::discount`];
//! - a **revocation trace** per region: exponential interarrival times
//!   at [`SpotConfig::preempt_per_hour`];
//! - an **expected-cost rate** ([`SpotMarket::effective_rate`]) that
//!   folds the expected number of preemptions and the checkpoint/restore
//!   stall each one costs into one multiplier the placement planner can
//!   compare against on-demand's 1.0 — [`plan_markets`] picks the
//!   [`Market`] per region exactly that way.
//!
//! Both traces are **deterministic and prefix-stable**: every price
//! segment and every revocation sequence is derived from a fresh
//! [`Pcg32`] stream keyed by `(seed, region, device, segment)`, so the
//! value at virtual time `t` never depends on how much of the trace was
//! queried before it, and two runs with the same seed see byte-identical
//! markets. With `enabled: false` nothing here is ever consulted — the
//! on-demand-only path is byte-identical to the pre-spot engine
//! (`rust/tests/spot.rs` pins this).

use crate::cloud::devices::Device;
use crate::cloud::CloudEnv;
use crate::net::RegionId;
use crate::sim::Time;
use crate::util::rng::Pcg32;

/// The `"spot"` config block / `--spot*` CLI surface. Off by default;
/// every field is validated by [`SpotConfig::validate`] so out-of-range
/// values are config errors, not silent clamps.
#[derive(Debug, Clone, PartialEq)]
pub struct SpotConfig {
    /// Master switch. Off = the market is never consulted and the run
    /// is byte-identical to the on-demand-only engine.
    pub enabled: bool,
    /// Mean spot price as a multiplier on the on-demand rate, in (0, 1]
    /// (0.35 = the typical ~65% spot discount).
    pub discount: f64,
    /// Relative half-range of the per-segment price noise, in [0, 1):
    /// each segment draws uniformly in `discount * (1 ± volatility)`.
    pub volatility: f64,
    /// Mean revocations per hour per spot pool (exponential
    /// interarrival). 0 = prices fluctuate but capacity is never taken.
    pub preempt_per_hour: f64,
    /// Virtual seconds a revoked pool stalls for checkpoint restore +
    /// re-provisioning before training resumes (real simulated time —
    /// lost in-flight steps are re-run after it).
    pub restore_stall_s: f64,
    /// Price-trace segment length in virtual seconds (one independent
    /// price draw per segment).
    pub segment_s: f64,
    /// Trace seed; 0 derives it from the job seed so `train --seed`
    /// reproduces the whole market.
    pub seed: u64,
}

impl Default for SpotConfig {
    fn default() -> Self {
        SpotConfig {
            enabled: false,
            discount: 0.35,
            volatility: 0.25,
            preempt_per_hour: 0.5,
            restore_stall_s: 30.0,
            segment_s: 300.0,
            seed: 0,
        }
    }
}

impl SpotConfig {
    /// Range-check the knobs (shared by the config parser and the CLI).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.discount > 0.0 && self.discount <= 1.0) {
            return Err(format!(
                "spot discount must be in (0, 1], got {}",
                self.discount
            ));
        }
        if !(0.0..1.0).contains(&self.volatility) {
            return Err(format!(
                "spot volatility must be in [0, 1), got {}",
                self.volatility
            ));
        }
        if !(self.preempt_per_hour >= 0.0) || !self.preempt_per_hour.is_finite() {
            return Err(format!(
                "spot preempt_per_hour must be >= 0 and finite, got {}",
                self.preempt_per_hour
            ));
        }
        if !(self.restore_stall_s >= 0.0) || !self.restore_stall_s.is_finite() {
            return Err(format!(
                "spot restore_stall_s must be >= 0 and finite, got {}",
                self.restore_stall_s
            ));
        }
        if !(self.segment_s > 0.0) || !self.segment_s.is_finite() {
            return Err(format!(
                "spot segment_s must be > 0 and finite, got {}",
                self.segment_s
            ));
        }
        Ok(())
    }
}

/// Which market a region's capacity is rented on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Market {
    /// List price, never revoked (the historical behavior).
    OnDemand,
    /// Discounted by the price trace, revocable by the preemption trace.
    Spot,
}

impl Market {
    pub fn name(&self) -> &'static str {
        match self {
            Market::OnDemand => "on-demand",
            Market::Spot => "spot",
        }
    }
}

/// Stable per-device code for trace stream derivation (position in the
/// catalog — extends automatically as the catalog grows).
fn dev_code(d: Device) -> u64 {
    Device::ALL.iter().position(|x| *x == d).unwrap_or(0) as u64
}

/// One job's view of the spot market: deterministic price + revocation
/// traces derived from a single seed.
#[derive(Debug, Clone)]
pub struct SpotMarket {
    cfg: SpotConfig,
    seed: u64,
}

impl SpotMarket {
    /// Build the market for one job. A zero `cfg.seed` derives the trace
    /// seed from `job_seed` so the whole market follows `--seed`.
    pub fn new(cfg: &SpotConfig, job_seed: u64) -> SpotMarket {
        let seed = if cfg.seed != 0 {
            cfg.seed
        } else {
            job_seed ^ 0x5D07_A11C_E5D0_7A11
        };
        SpotMarket { cfg: cfg.clone(), seed }
    }

    pub fn config(&self) -> &SpotConfig {
        &self.cfg
    }

    /// Spot price multiplier (vs the on-demand rate) for `dev` capacity
    /// in `region` at virtual time `t`. Piecewise-constant: one
    /// independent uniform draw in `discount * (1 ± volatility)` per
    /// `segment_s` window, keyed by `(seed, region, dev, segment)` so
    /// any segment is computable without its predecessors
    /// (prefix-stable).
    pub fn price_mult(&self, region: RegionId, dev: Device, t: Time) -> f64 {
        let seg = (t.max(0.0) / self.cfg.segment_s).floor() as u64;
        let stream = 0xA11C_E000u64 ^ ((region as u64) << 8) ^ dev_code(dev);
        let mut rng = Pcg32::new(self.seed.wrapping_add(seg.wrapping_mul(0x9E37_79B9_7F4A_7C15)), stream);
        let u = rng.f64();
        let mult = self.cfg.discount * (1.0 + self.cfg.volatility * (2.0 * u - 1.0));
        mult.clamp(0.01, 1.0)
    }

    /// Exact time-average of [`SpotMarket::price_mult`] over `[t0, t1]`
    /// (the piecewise-constant integral, not a sample) — what a closed
    /// billing segment is charged at.
    pub fn avg_price_mult(&self, region: RegionId, dev: Device, t0: Time, t1: Time) -> f64 {
        let (t0, t1) = (t0.max(0.0), t1.max(0.0));
        if t1 <= t0 {
            return self.price_mult(region, dev, t0);
        }
        let seg_s = self.cfg.segment_s;
        let first = (t0 / seg_s).floor() as u64;
        let last = (t1 / seg_s).ceil() as u64;
        let mut acc = 0.0;
        for seg in first..last {
            let lo = (seg as f64 * seg_s).max(t0);
            let hi = ((seg + 1) as f64 * seg_s).min(t1);
            if hi > lo {
                acc += self.price_mult(region, dev, seg as f64 * seg_s) * (hi - lo);
            }
        }
        acc / (t1 - t0)
    }

    /// Revocation instants for `region`'s spot pool within
    /// `[0, horizon_s)`: exponential interarrival at `preempt_per_hour`,
    /// drawn sequentially from a per-region stream (prefix-stable — a
    /// longer horizon only appends).
    pub fn preemption_times(&self, region: RegionId, horizon_s: Time) -> Vec<Time> {
        let mut out = Vec::new();
        if self.cfg.preempt_per_hour <= 0.0 || horizon_s <= 0.0 {
            return out;
        }
        let mean_s = 3600.0 / self.cfg.preempt_per_hour;
        let mut rng = Pcg32::new(self.seed, 0x9E37_0000 ^ region as u64);
        let mut t = 0.0;
        loop {
            t += -mean_s * (1.0 - rng.f64()).ln();
            if t >= horizon_s {
                return out;
            }
            out.push(t);
        }
    }

    /// Expected revocations over `dt` virtual seconds.
    pub fn expected_preemptions(&self, dt: Time) -> f64 {
        self.cfg.preempt_per_hour * dt.max(0.0) / 3600.0
    }

    /// The planner's scalar: the expected per-unit-hour cost of renting
    /// `dev` in `region` on the spot market over a `horizon_s` run, as a
    /// multiplier on the on-demand rate. The expected preemptions each
    /// stretch the run by `restore_stall_s` (plus the re-run of lost
    /// in-flight work, dominated by the stall), all billed at the spot
    /// rate:
    ///
    /// ```text
    /// effective = avg_price * (1 + E[preemptions] * restore_stall / horizon)
    /// ```
    ///
    /// Spot wins exactly when this is below on-demand's 1.0 — which is
    /// how [`plan_markets`] chooses.
    pub fn effective_rate(&self, region: RegionId, dev: Device, horizon_s: Time) -> f64 {
        let h = horizon_s.max(1.0);
        let avg = self.avg_price_mult(region, dev, 0.0, h);
        let overhead = self.expected_preemptions(h) * self.cfg.restore_stall_s / h;
        avg * (1.0 + overhead)
    }
}

/// Pick the market per region: spot wherever its expected effective rate
/// (price trace + expected preemption/restore overhead) undercuts
/// on-demand, judged on the region's first inventory tier over the
/// job's estimated horizon. Disabled spot = all on-demand.
pub fn plan_markets(env: &CloudEnv, market: Option<&SpotMarket>, horizon_s: Time) -> Vec<Market> {
    let n = env.regions.len();
    let market = match market {
        Some(m) if m.config().enabled => m,
        _ => return vec![Market::OnDemand; n],
    };
    env.regions
        .iter()
        .map(|r| {
            let dev = r.inventory.first().map(|(d, _)| *d).unwrap_or(Device::IceLake);
            if market.effective_rate(r.id, dev, horizon_s) < 1.0 {
                Market::Spot
            } else {
                Market::OnDemand
            }
        })
        .collect()
}

/// Per-region compute price multipliers for the placement planner's
/// joint objective: 1.0 for on-demand regions, the (expected-preemption
/// adjusted) effective spot rate for spot regions — never above 1.0,
/// because a region whose spot rate beats on-demand is rented there and
/// one that doesn't is rented on-demand.
pub fn rate_scale(env: &CloudEnv, market: Option<&SpotMarket>, horizon_s: Time) -> Vec<f64> {
    let markets = plan_markets(env, market, horizon_s);
    env.regions
        .iter()
        .zip(&markets)
        .map(|(r, m)| match m {
            Market::OnDemand => 1.0,
            Market::Spot => {
                let dev = r.inventory.first().map(|(d, _)| *d).unwrap_or(Device::IceLake);
                market
                    .map(|mk| mk.effective_rate(r.id, dev, horizon_s).min(1.0))
                    .unwrap_or(1.0)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Region;

    fn cfg() -> SpotConfig {
        SpotConfig { enabled: true, ..SpotConfig::default() }
    }

    fn env() -> CloudEnv {
        CloudEnv::new(vec![
            Region::new(0, "A", vec![(Device::CascadeLake, 12)], 100),
            Region::new(1, "B", vec![(Device::Skylake, 12)], 100),
        ])
    }

    #[test]
    fn validate_rejects_out_of_range() {
        for bad in [
            SpotConfig { discount: 0.0, ..cfg() },
            SpotConfig { discount: 1.5, ..cfg() },
            SpotConfig { volatility: 1.0, ..cfg() },
            SpotConfig { volatility: -0.1, ..cfg() },
            SpotConfig { preempt_per_hour: -1.0, ..cfg() },
            SpotConfig { preempt_per_hour: f64::NAN, ..cfg() },
            SpotConfig { restore_stall_s: -1.0, ..cfg() },
            SpotConfig { segment_s: 0.0, ..cfg() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} must be rejected");
        }
        assert!(cfg().validate().is_ok());
        assert!(SpotConfig::default().validate().is_ok(), "defaults are valid");
    }

    #[test]
    fn price_trace_is_deterministic_and_bounded() {
        let a = SpotMarket::new(&cfg(), 42);
        let b = SpotMarket::new(&cfg(), 42);
        for seg in 0..40 {
            let t = seg as f64 * 300.0 + 1.0;
            let pa = a.price_mult(0, Device::CascadeLake, t);
            assert_eq!(pa, b.price_mult(0, Device::CascadeLake, t), "same seed, same trace");
            // discount 0.35 ± 25%
            assert!((0.2625..=0.4375).contains(&pa), "segment {seg}: {pa}");
        }
        let c = SpotMarket::new(&cfg(), 43);
        let diff = (0..40).any(|seg| {
            let t = seg as f64 * 300.0 + 1.0;
            a.price_mult(0, Device::CascadeLake, t) != c.price_mult(0, Device::CascadeLake, t)
        });
        assert!(diff, "different seeds must differ somewhere");
    }

    #[test]
    fn price_differs_across_regions_and_tiers() {
        let m = SpotMarket::new(&cfg(), 42);
        let r0 = (0..40).map(|s| m.price_mult(0, Device::Skylake, s as f64 * 300.0)).sum::<f64>();
        let r1 = (0..40).map(|s| m.price_mult(1, Device::Skylake, s as f64 * 300.0)).sum::<f64>();
        let d1 = (0..40).map(|s| m.price_mult(0, Device::T4, s as f64 * 300.0)).sum::<f64>();
        assert!(r0 != r1, "regions draw independent traces");
        assert!(r0 != d1, "tiers draw independent traces");
    }

    #[test]
    fn avg_price_is_the_exact_piecewise_integral() {
        let m = SpotMarket::new(&cfg(), 7);
        // Spanning two half segments: the average is the midpoint.
        let p0 = m.price_mult(0, Device::Skylake, 0.0);
        let p1 = m.price_mult(0, Device::Skylake, 300.0);
        let avg = m.avg_price_mult(0, Device::Skylake, 150.0, 450.0);
        assert!((avg - 0.5 * (p0 + p1)).abs() < 1e-12);
        // Inside one segment the average is the segment price.
        assert_eq!(m.avg_price_mult(0, Device::Skylake, 10.0, 20.0), p0);
        // Degenerate interval falls back to the instant price.
        assert_eq!(m.avg_price_mult(0, Device::Skylake, 50.0, 50.0), p0);
    }

    #[test]
    fn prefix_stability_querying_further_never_rewrites_history() {
        let m = SpotMarket::new(&cfg(), 42);
        let early = m.avg_price_mult(0, Device::CascadeLake, 0.0, 600.0);
        let _far = m.price_mult(0, Device::CascadeLake, 1e6);
        assert_eq!(early, m.avg_price_mult(0, Device::CascadeLake, 0.0, 600.0));
        let short = m.preemption_times(0, 3600.0);
        let long = m.preemption_times(0, 36_000.0);
        assert!(long.len() >= short.len());
        assert_eq!(&long[..short.len()], &short[..], "longer horizon only appends");
    }

    #[test]
    fn preemption_times_follow_the_rate() {
        let heavy = SpotMarket::new(&SpotConfig { preempt_per_hour: 6.0, ..cfg() }, 42);
        let light = SpotMarket::new(&SpotConfig { preempt_per_hour: 0.5, ..cfg() }, 42);
        let h = 40.0 * 3600.0;
        let nh = heavy.preemption_times(0, h).len();
        let nl = light.preemption_times(0, h).len();
        assert!(nh > nl, "6/h must revoke more than 0.5/h ({nh} vs {nl})");
        // Rough mean check: 6/h over 40h ≈ 240, allow wide slack.
        assert!((120..=480).contains(&nh), "{nh}");
        let none = SpotMarket::new(&SpotConfig { preempt_per_hour: 0.0, ..cfg() }, 42);
        assert!(none.preemption_times(0, h).is_empty());
        assert!(heavy.preemption_times(0, 0.0).is_empty());
    }

    #[test]
    fn effective_rate_folds_in_preemption_overhead() {
        let calm = SpotMarket::new(&SpotConfig { preempt_per_hour: 0.0, ..cfg() }, 42);
        let churny =
            SpotMarket::new(&SpotConfig { preempt_per_hour: 30.0, restore_stall_s: 240.0, ..cfg() }, 42);
        let h = 3600.0;
        let base = calm.effective_rate(0, Device::Skylake, h);
        let loaded = churny.effective_rate(0, Device::Skylake, h);
        assert!(loaded > base, "preemption overhead must raise the rate");
        // 30 preempts × 240 s = 2h of stall on a 1h run: triple the price.
        assert!((loaded / base - 3.0).abs() < 1e-9);
    }

    #[test]
    fn markets_pick_spot_only_when_it_wins() {
        let e = env();
        let cheap = SpotMarket::new(&cfg(), 42);
        assert_eq!(plan_markets(&e, Some(&cheap), 3600.0), vec![Market::Spot, Market::Spot]);
        // A market whose stalls eat the whole discount goes on-demand.
        let ruinous = SpotMarket::new(
            &SpotConfig { preempt_per_hour: 60.0, restore_stall_s: 600.0, ..cfg() },
            42,
        );
        assert_eq!(
            plan_markets(&e, Some(&ruinous), 3600.0),
            vec![Market::OnDemand, Market::OnDemand]
        );
        // Disabled market: always on-demand, never consulted.
        let off = SpotMarket::new(&SpotConfig::default(), 42);
        assert_eq!(plan_markets(&e, Some(&off), 3600.0), vec![Market::OnDemand, Market::OnDemand]);
        assert_eq!(plan_markets(&e, None, 3600.0), vec![Market::OnDemand, Market::OnDemand]);
    }

    #[test]
    fn rate_scale_is_one_on_demand_and_below_one_on_spot() {
        let e = env();
        assert_eq!(rate_scale(&e, None, 3600.0), vec![1.0, 1.0]);
        let m = SpotMarket::new(&cfg(), 42);
        let scale = rate_scale(&e, Some(&m), 3600.0);
        assert!(scale.iter().all(|&s| s > 0.0 && s < 1.0), "{scale:?}");
    }

    #[test]
    fn market_names() {
        assert_eq!(Market::OnDemand.name(), "on-demand");
        assert_eq!(Market::Spot.name(), "spot");
    }
}
